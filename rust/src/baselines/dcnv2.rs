//! DCNv2 baseline (Wang et al., WWW'21) — the paper's "Tensorflow-based
//! strong baseline", reimplemented natively so it trains single-pass on
//! the same stream as the FW engines.
//!
//! Architecture:
//!   e_f   = emb[bucket_f] · x_f                    (field embeddings, dim K)
//!   x_0   = concat(e_1 .. e_F)                     (D = F·K)
//!   x_l+1 = x_0 ⊙ (W_l x_l + b_l) + x_l            (cross layers)
//!   logit = w_out · x_L + b_out
//!
//! Trained with per-coordinate AdaGrad like the other engines.

use crate::baselines::OnlineModel;
use crate::feature::Example;
use crate::util::math::sigmoid;
use crate::util::rng::Pcg32;

/// Native DCNv2.
pub struct DcnV2 {
    name: String,
    fields: usize,
    k: usize,
    mask: u32,
    /// Embedding table [buckets * k].
    emb: Vec<f32>,
    acc_emb: Vec<f32>,
    /// Cross-layer weights, each [d * d] + bias [d].
    cross_w: Vec<Vec<f32>>,
    cross_b: Vec<Vec<f32>>,
    acc_w: Vec<Vec<f32>>,
    acc_b: Vec<Vec<f32>>,
    /// Output head.
    w_out: Vec<f32>,
    acc_out: Vec<f32>,
    b_out: f32,
    acc_bout: f32,
    pub lr: f32,
    pub power_t: f32,
    // scratch
    xs: Vec<Vec<f32>>, // x_0 .. x_L
    pre: Vec<Vec<f32>>, // W_l x_l + b_l per layer
}

impl std::fmt::Debug for DcnV2 {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DcnV2").finish_non_exhaustive()
    }
}

impl DcnV2 {
    pub fn new(
        buckets: u32,
        fields: usize,
        k: usize,
        cross_layers: usize,
        lr: f32,
        seed: u64,
    ) -> Self {
        assert!(buckets.is_power_of_two());
        let d = fields * k;
        let mut rng = Pcg32::seeded(seed);
        let emb: Vec<f32> =
            (0..buckets as usize * k).map(|_| rng.normal() * 0.05).collect();
        let mut cross_w: Vec<Vec<f32>> = Vec::new();
        let mut cross_b: Vec<Vec<f32>> = Vec::new();
        for _ in 0..cross_layers {
            let span = (1.0 / d as f32).sqrt();
            cross_w.push((0..d * d).map(|_| rng.range_f32(-span, span)).collect());
            cross_b.push(vec![0.0; d]);
        }
        let w_out = (0..d).map(|_| rng.normal() * 0.05).collect();
        DcnV2 {
            name: "DCNv2".into(),
            fields,
            k,
            mask: buckets - 1,
            acc_emb: vec![1.0; emb.len()],
            emb,
            acc_w: cross_w.iter().map(|w| vec![1.0; w.len()]).collect(),
            acc_b: cross_b.iter().map(|b| vec![1.0; b.len()]).collect(),
            cross_w,
            cross_b,
            acc_out: vec![1.0; d],
            w_out,
            b_out: 0.0,
            acc_bout: 1.0,
            lr,
            power_t: 0.5,
            xs: Vec::new(),
            pre: Vec::new(),
        }
    }

    fn d(&self) -> usize {
        self.fields * self.k
    }

    fn forward(&mut self, ex: &Example) -> f32 {
        let d = self.d();
        let nl = self.cross_w.len();
        self.xs.resize(nl + 1, Vec::new());
        self.pre.resize(nl, Vec::new());
        // x0 from embeddings
        let x0: &mut Vec<f32> = &mut self.xs[0];
        x0.resize(d, 0.0);
        for (f, slot) in ex.slots.iter().enumerate() {
            let b = (slot.bucket & self.mask) as usize;
            for kk in 0..self.k {
                x0[f * self.k + kk] = self.emb[b * self.k + kk] * slot.value;
            }
        }
        for l in 0..nl {
            let (head, tail) = self.xs.split_at_mut(l + 1);
            let x = &head[l];
            let x0 = &head[0];
            let w = &self.cross_w[l];
            let b = &self.cross_b[l];
            let pre = &mut self.pre[l];
            pre.resize(d, 0.0);
            // pre = W x + b (row-major [out=d rows][in=d cols])
            for o in 0..d {
                let row = &w[o * d..(o + 1) * d];
                pre[o] = crate::simd::dot::dot(row, x) + b[o];
            }
            let nxt = &mut tail[0];
            nxt.resize(d, 0.0);
            for i in 0..d {
                nxt[i] = x0[i] * pre[i] + x[i];
            }
        }
        let last = &self.xs[nl];
        crate::simd::dot::dot(&self.w_out, last) + self.b_out
    }

    #[inline]
    fn ada(lr: f32, pt: f32, acc: &mut f32, w: &mut f32, g: f32) {
        *acc += g * g;
        let denom = if pt == 0.5 { acc.sqrt() } else { acc.powf(pt) };
        *w -= lr * g / denom;
    }
}

impl OnlineModel for DcnV2 {
    fn name(&self) -> &str {
        &self.name
    }

    fn learn(&mut self, ex: &Example) -> f32 {
        let logit = self.forward(ex);
        let p = sigmoid(logit);
        let dloss = (p - ex.label) * ex.importance;
        if dloss == 0.0 {
            return p;
        }
        let d = self.d();
        let nl = self.cross_w.len();
        // head
        let mut dx = vec![0f32; d]; // dL/dx_L
        {
            let last = &self.xs[nl];
            for i in 0..d {
                dx[i] = dloss * self.w_out[i];
                Self::ada(
                    self.lr,
                    self.power_t,
                    &mut self.acc_out[i],
                    &mut self.w_out[i],
                    dloss * last[i],
                );
            }
            Self::ada(self.lr, self.power_t, &mut self.acc_bout, &mut self.b_out, dloss);
        }
        let mut dx0_total = vec![0f32; d];
        // cross layers, last to first:
        // y = x0 ⊙ pre + x ;   pre = W x + b
        // dpre = x0 ⊙ dy ; dx = W^T dpre + dy ; dx0 += pre ⊙ dy
        for l in (0..nl).rev() {
            let x = &self.xs[l];
            let x0 = &self.xs[0];
            let pre = &self.pre[l];
            let mut dpre = vec![0f32; d];
            for i in 0..d {
                dpre[i] = x0[i] * dx[i];
                dx0_total[i] += pre[i] * dx[i];
            }
            let w = &mut self.cross_w[l];
            let acc_w = &mut self.acc_w[l];
            let mut dx_new = dx.clone(); // the +x skip term (dy)
            for o in 0..d {
                let g_o = dpre[o];
                let row = o * d;
                if g_o != 0.0 {
                    for i in 0..d {
                        // dx via pre-update W
                        dx_new[i] += w[row + i] * g_o;
                        Self::ada(
                            self.lr,
                            self.power_t,
                            &mut acc_w[row + i],
                            &mut w[row + i],
                            g_o * x[i],
                        );
                    }
                }
                Self::ada(
                    self.lr,
                    self.power_t,
                    &mut self.acc_b[l][o],
                    &mut self.cross_b[l][o],
                    g_o,
                );
            }
            dx = dx_new;
        }
        // After the loop `dx` is dL/dx_0 through the skip/matmul chain;
        // dx0_total already holds the accumulated ⊙ contributions.
        for i in 0..d {
            dx0_total[i] += dx[i];
        }
        // embeddings
        for (f, slot) in ex.slots.iter().enumerate() {
            if slot.value == 0.0 {
                continue;
            }
            let b = (slot.bucket & self.mask) as usize;
            for kk in 0..self.k {
                let idx = b * self.k + kk;
                Self::ada(
                    self.lr,
                    self.power_t,
                    &mut self.acc_emb[idx],
                    &mut self.emb[idx],
                    dx0_total[f * self.k + kk] * slot.value,
                );
            }
        }
        p
    }

    fn predict(&mut self, ex: &Example) -> f32 {
        let logit = self.forward(ex);
        sigmoid(logit)
    }

    fn num_weights(&self) -> usize {
        self.emb.len()
            + self.cross_w.iter().map(Vec::len).sum::<usize>()
            + self.cross_b.iter().map(Vec::len).sum::<usize>()
            + self.w_out.len()
            + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{DatasetSpec, SyntheticStream};
    use crate::eval::RollingAuc;

    #[test]
    fn learns_above_chance() {
        let mut m = DcnV2::new(256, 4, 2, 2, 0.05, 3);
        let mut s = SyntheticStream::with_buckets(DatasetSpec::tiny(), 16, 256);
        let mut roll = RollingAuc::new(2000);
        for _ in 0..16_000 {
            let ex = s.next_example();
            let p = m.learn(&ex);
            roll.add(p, ex.label);
        }
        let last = *roll.points.last().unwrap();
        assert!(last > 0.60, "auc {last}");
    }

    #[test]
    fn overfits_single_example() {
        let mut m = DcnV2::new(64, 4, 2, 2, 0.2, 4);
        let mut s = SyntheticStream::with_buckets(DatasetSpec::tiny(), 17, 64);
        let mut ex = s.next_example();
        ex.label = 0.0;
        for _ in 0..300 {
            m.learn(&ex);
        }
        assert!(m.predict(&ex) < 0.1);
    }

    #[test]
    fn finite_gradient_check_output_layer() {
        // numeric check on one embedding coordinate
        let mut m = DcnV2::new(64, 3, 2, 1, 0.0, 5); // lr=0 -> no updates
        let mut s = SyntheticStream::with_buckets(DatasetSpec::tiny(), 18, 64);
        let mut spec = DatasetSpec::tiny();
        spec.cat_fields = 2;
        let _ = spec;
        let ex = {
            let mut e = s.next_example();
            e.slots.truncate(3);
            e
        };
        let logit_at = |m: &mut DcnV2| m.forward(&ex);
        let base = logit_at(&mut m);
        let bucket = (ex.slots[1].bucket & m.mask) as usize;
        let idx = bucket * m.k;
        let eps = 1e-3;
        m.emb[idx] += eps;
        let up = logit_at(&mut m);
        m.emb[idx] -= 2.0 * eps;
        let down = logit_at(&mut m);
        m.emb[idx] += eps;
        let numeric = (up - down) / (2.0 * eps);
        assert!(numeric.is_finite());
        assert!((up - base).abs() < 1.0); // smooth
    }

    #[test]
    fn weights_finite_under_training() {
        let mut m = DcnV2::new(128, 4, 3, 3, 0.1, 6);
        let mut s = SyntheticStream::with_buckets(DatasetSpec::tiny(), 19, 128);
        for _ in 0..4000 {
            m.learn(&s.next_example());
        }
        assert!(m.emb.iter().all(|w| w.is_finite()));
        assert!(m.w_out.iter().all(|w| w.is_finite()));
    }
}
