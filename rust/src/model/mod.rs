//! The DeepFFM model core: weight layout/pool, AdaGrad optimizer, and
//! the LR / FFM / neural blocks composed by [`regressor::Regressor`].
//!
//! Blocks mirror the structure of the production engine (block_ffm.rs,
//! block_neural.rs, regressor.rs in Fwumious Wabbit); each implements a
//! hand-derived backward pass and is validated by finite-difference
//! gradient checks in its unit tests.

pub mod block_ffm;
pub mod block_lr;
pub mod block_neural;
pub mod io;
pub mod optimizer;
pub mod regressor;
pub mod weights;

/// Reusable per-thread scratch space.  All forward/backward temporaries
/// live here so the hot path performs zero allocations per example.
#[derive(Clone, Debug, Default)]
pub struct Workspace {
    /// FFM pair interaction values, strict upper triangle, row-major.
    pub pairs: Vec<f32>,
    /// MergeNormLayer output [1 + P].
    pub merged: Vec<f32>,
    /// Pre-norm merged vector (needed by the RMS-norm backward).
    pub merged_raw: Vec<f32>,
    /// RMS of merged_raw.
    pub rms: f32,
    /// Per-layer post-activation outputs.
    pub activations: Vec<Vec<f32>>,
    /// LR block output.
    pub lr_out: f32,
    /// Final logit.
    pub logit: f32,
    /// Gradient scratch, one buffer per layer boundary.
    pub grad_bufs: Vec<Vec<f32>>,
    /// Gradient w.r.t. merged (post-norm).
    pub dmerged: Vec<f32>,
    /// Assembled ctx+candidate slots for the context-cache fast path.
    pub partial_slots: Vec<crate::feature::FeatureSlot>,
}

impl Workspace {
    pub fn new() -> Self {
        Self::default()
    }
}
