//! # Fwumious — CPU-based Deep FFMs at 300M+ predictions per second
//!
//! A full reproduction of the system described in *"A Bag of Tricks for
//! Scaling CPU-based Deep FFMs to more than 300m Predictions per Second"*
//! (Škrlj et al., KDD '24): a Rust, CPU-only Deep Field-aware
//! Factorization Machine engine with online (single-pass) training,
//! Hogwild multithreading, ReLU-aware sparse weight updates, a serving
//! layer with context caching and runtime SIMD dispatch, and a weight
//! transfer plane built on 16-bit dynamic quantization plus byte-level
//! model patching.
//!
//! ## Layering
//!
//! * **L3 (this crate)** — the coordinator and the paper's contribution:
//!   training, serving, quantization/patching, AutoML, evaluation.
//! * **L2/L1 (`python/compile`)** — the same DeepFFM forward expressed in
//!   JAX with the FFM interaction as a Pallas kernel, AOT-lowered to HLO
//!   text artifacts which `runtime` loads through PJRT for
//!   cross-validation and accelerator-offload deployments.
//!
//! Python never runs on the request path; the serving binary is
//! self-contained once `make artifacts` has produced the HLO files.
//!
//! ## Feature flags
//!
//! * `pjrt` (off by default) — compiles the `runtime` module and the
//!   PJRT cross-check test.  Requires the external `xla` and `anyhow`
//!   crates (unavailable in the hermetic offline build); the default
//!   build is dependency-free.

// Every unsafe operation inside an `unsafe fn` must sit in an explicit
// `unsafe {}` block with its own SAFETY justification — the blanket
// unsafety of the enclosing fn is not a license (`fw audit` enforces
// the comments; this lint enforces the blocks).
#![deny(unsafe_op_in_unsafe_fn)]
// Public types are debuggable: operators print engine/fleet state when
// triaging incidents, and `#[derive(Debug)]` omissions are cheapest to
// catch at the definition site.
#![warn(missing_debug_implementations)]

pub mod audit;
pub mod automl;
pub mod baselines;
pub mod cli;
pub mod config;
pub mod data;
pub mod deploy;
pub mod eval;
pub mod feature;
pub mod fleet;
pub mod model;
pub mod obs;
pub mod patch;
pub mod quant;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod serve;
pub mod simd;
pub mod testutil;
pub mod train;
pub mod transfer;
pub mod util;
