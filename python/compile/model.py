"""L2: the DeepFFM forward graph in JAX (build-time only).

Mirrors §2.1 of the paper:

    Dffm(W, w_b, w_c, x) = ffnn(W, MergeNormLayer(lr(w_b, x),
                                 DiagMask(ffm(w_c, x))))

and mirrors, *bit-for-bit in structure*, the Rust native forward pass in
``rust/src/model/`` — the integration test
``rust/tests/pjrt_cross_check.rs`` feeds identical weights/indices to
both and asserts agreement.  Any change to the spec below must be made
in both places (the spec constants are exported through the artifact
manifest).

Cross-layer ABI (shared with rust/src/model/*.rs):
  * feature order     — one feature per field, fields 0..F-1
  * pair order        — strict upper triangle, row-major
  * MergeNormLayer    — concat([lr_out, ffm_pairs]) then RMS-normalize
                        with eps=1e-6
  * hidden activation — ReLU
  * output            — sigmoid(h @ w_out + b_out + lr_out)  (residual LR)

The FFM interaction itself is the L1 Pallas kernel, so lowering this
function produces a single HLO module containing the kernel body.
"""

from __future__ import annotations

from typing import List, NamedTuple, Sequence

import jax
import jax.numpy as jnp

from compile.kernels.ffm_interaction import ffm_interaction
from compile.kernels.ref import triu_flatten

MERGE_NORM_EPS = 1e-6


class DeepFfmConfig(NamedTuple):
    """Static architecture description (baked into the HLO artifact)."""

    fields: int          # F
    latent_dim: int      # K
    buckets: int         # N — hashed weight rows per table
    hidden: tuple        # hidden layer widths, () for pure FFM
    batch: int           # B — the AOT batch size

    @property
    def pairs(self) -> int:
        return self.fields * (self.fields - 1) // 2

    @property
    def merged_dim(self) -> int:
        return 1 + self.pairs

    def name(self) -> str:
        h = "x".join(str(w) for w in self.hidden) if self.hidden else "ffm"
        return (f"deepffm_f{self.fields}_k{self.latent_dim}"
                f"_n{self.buckets}_h{h}_b{self.batch}")


def mlp_param_shapes(cfg: DeepFfmConfig) -> List[tuple]:
    """Ordered MLP parameter shapes: (W1, b1, ..., Wn, bn, w_out, b_out).

    Empty for a pure-FFM config (no neural block).
    """
    if not cfg.hidden:
        return []
    shapes = []
    prev = cfg.merged_dim
    for h in cfg.hidden:
        shapes.append((prev, h))
        shapes.append((h,))
        prev = h
    shapes.append((prev,))   # w_out
    shapes.append(())        # b_out
    return shapes


def lr_forward(lr_table: jnp.ndarray, idx: jnp.ndarray,
               vals: jnp.ndarray) -> jnp.ndarray:
    """Logistic-regression block: sum_f w[idx[b,f]] * x[b,f].  [B]."""
    return jnp.sum(lr_table[idx] * vals, axis=1)


def merge_norm_layer(lr_out: jnp.ndarray,
                     ffm_flat: jnp.ndarray) -> jnp.ndarray:
    """MergeNormLayer: concat LR + masked FFM outputs, RMS-normalize."""
    merged = jnp.concatenate([lr_out[:, None], ffm_flat], axis=1)
    rms = jnp.sqrt(jnp.mean(merged * merged, axis=1, keepdims=True)
                   + MERGE_NORM_EPS)
    return merged / rms


def deep_ffm_forward(cfg: DeepFfmConfig,
                     lr_table: jnp.ndarray,
                     ffm_table: jnp.ndarray,
                     mlp_params: Sequence[jnp.ndarray],
                     idx: jnp.ndarray,
                     vals: jnp.ndarray) -> jnp.ndarray:
    """Full DeepFFM forward: probabilities [B].

    Args:
      lr_table:   [N] hashed LR weights.
      ffm_table:  [N, F, K] hashed field-aware latents.
      mlp_params: flat list matching ``mlp_param_shapes`` ([] for FFM).
      idx:        [B, F] int32 hashed bucket per field.
      vals:       [B, F] f32 feature values.
    """
    lr_out = lr_forward(lr_table, idx, vals)                 # [B]
    emb = ffm_table[idx]                                     # [B, F, F, K]
    pairs = ffm_interaction(emb, vals)                       # [B, F, F]
    ffm_flat = triu_flatten(pairs)                           # [B, P]

    if not cfg.hidden:
        # Pure FFM: logit = LR + sum of pair interactions.
        return jax.nn.sigmoid(lr_out + jnp.sum(ffm_flat, axis=1))

    h = merge_norm_layer(lr_out, ffm_flat)                   # [B, 1+P]
    params = list(mlp_params)
    for _ in cfg.hidden:
        w, b = params.pop(0), params.pop(0)
        h = jax.nn.relu(h @ w + b)
    w_out, b_out = params.pop(0), params.pop(0)
    logit = h @ w_out + b_out + lr_out                       # residual LR
    return jax.nn.sigmoid(logit)


def make_batched_fn(cfg: DeepFfmConfig):
    """Return fn(lr_table, ffm_table, *mlp, idx, vals) -> (probs,) for AOT.

    The 1-tuple return matches the rust loader's ``to_tuple1`` unwrap.
    """

    def fn(lr_table, ffm_table, *rest):
        *mlp, idx, vals = rest
        return (deep_ffm_forward(cfg, lr_table, ffm_table, mlp, idx, vals),)

    return fn


def example_args(cfg: DeepFfmConfig, seed: int = 0):
    """Concrete small example arguments (used by tests, not by AOT)."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 8)
    lr_table = jax.random.normal(ks[0], (cfg.buckets,)) * 0.1
    ffm_table = jax.random.normal(
        ks[1], (cfg.buckets, cfg.fields, cfg.latent_dim)) * 0.1
    mlp = []
    for i, shape in enumerate(mlp_param_shapes(cfg)):
        mlp.append(jax.random.normal(ks[2 + i % 5], shape) * 0.1)
    idx = jax.random.randint(ks[6], (cfg.batch, cfg.fields), 0, cfg.buckets)
    vals = jnp.ones((cfg.batch, cfg.fields), jnp.float32)
    return lr_table, ffm_table, mlp, idx, vals


def arg_specs(cfg: DeepFfmConfig):
    """ShapeDtypeStructs in AOT argument order."""
    specs = [
        jax.ShapeDtypeStruct((cfg.buckets,), jnp.float32),
        jax.ShapeDtypeStruct((cfg.buckets, cfg.fields, cfg.latent_dim),
                             jnp.float32),
    ]
    for shape in mlp_param_shapes(cfg):
        specs.append(jax.ShapeDtypeStruct(shape, jnp.float32))
    specs.append(jax.ShapeDtypeStruct((cfg.batch, cfg.fields), jnp.int32))
    specs.append(jax.ShapeDtypeStruct((cfg.batch, cfg.fields), jnp.float32))
    return specs
