//! Multi-vector kernels for request-level batched candidate scoring
//! and minibatch training.
//!
//! The serving hot path (§5) scores B candidates that all share one
//! request context; the Hogwild training loop (§4.2) pushes B-example
//! micro-batches through the same dense tower.  The single-vector
//! kernels in [`super::dot`] stream the neural block's weight matrix
//! from memory once *per candidate*; the kernels here restructure the
//! inner loops candidate-major so each weight row is loaded once per
//! 4-candidate register block:
//!
//! * [`matmul_rowmajor`] — a register-blocked `B×in · in×out` GEMM-lite
//!   for the neural block forward (4 batch rows × 16 output columns per
//!   tile, AVX2+FMA with a scalar fallback).
//! * [`matmul_transposed`] — the backward's upstream-gradient GEMM
//!   `dX = dY·Wᵀ` over the same row-major weight matrix (no transpose
//!   materialized: a dX element is a contiguous-row dot product).
//! * [`matmul_xt_dy`] — the backward's accumulating weight-gradient
//!   GEMM `dW += Xᵀ·dY`, reducing a whole micro-batch into one gradient
//!   matrix so the optimizer applies one update per coordinate per
//!   micro-batch instead of one per example.
//! * [`rowwise_sum`] / [`rowwise_sumsq`] — batched horizontal sums over
//!   the rows of a `B × n` matrix, used for the batched FFM logit and
//!   the batched MergeNorm RMS.
//!
//! Numerical contract (the serving and training layers rely on it): at
//! a fixed ISA level every output element is produced by the same
//! operation sequence regardless of the batch size, so scoring a
//! candidate alone (B = 1) is **bit-identical** to scoring it inside a
//! larger batch, and — for the accumulating [`matmul_xt_dy`] — reducing
//! a batch in consecutive segments is bit-identical to reducing it in
//! one call.  That is why the kernels never take the "skip zero inputs"
//! shortcut of the single-vector matvec, and why the remainder paths
//! mirror the blocked paths' per-element accumulation order exactly.
//!
//! Every kernel exists per rung of the [`IsaLevel`] ladder.  The
//! AVX-512 variants widen the AVX2 4×16 register tile to 4×32 (two zmm
//! accumulators per batch row) with the same explicit reduction trees;
//! the column tiling depends only on `cols`, never on the batch size,
//! so the invariance contract holds on every rung independently.
//! Outputs narrower than one zmm (cols < 16) stay on the ymm kernels —
//! every AVX-512 CPU also has avx2+fma.

use super::{isa_level, IsaLevel};

/// Batched dense forward: `out[b*cols + j] = bias[j] + Σ_i x[b*rows + i]
/// * w[i*cols + j]` for `b` in `0..batch`.
///
/// `w` is the neural block's row-major `[rows × cols]` matrix; `x`
/// holds `batch` input rows back to back.  The AVX2 kernel loads each
/// weight strip once per 4-candidate block instead of once per
/// candidate, turning the per-candidate matvec's latency-bound
/// accumulator chains into 8 independent chains per tile.
pub fn matmul_rowmajor(
    x: &[f32],
    batch: usize,
    w: &[f32],
    rows: usize,
    cols: usize,
    bias: Option<&[f32]>,
    out: &mut [f32],
) {
    debug_assert!(rows > 0 && cols > 0);
    debug_assert_eq!(x.len(), batch * rows);
    debug_assert_eq!(w.len(), rows * cols);
    debug_assert_eq!(out.len(), batch * cols);
    match isa_level() {
        IsaLevel::Scalar => matmul_scalar(x, batch, w, rows, cols, bias, out),
        #[cfg(target_arch = "x86_64")]
        IsaLevel::Avx2Fma => {
            if cols >= 8 {
                // SAFETY: `isa_level` returns Avx2Fma only after
                // runtime CPUID confirmed avx2+fma; the shape contract
                // the kernel indexes by is asserted above.
                unsafe { matmul_avx2(x, batch, w, rows, cols, bias, out) }
            } else {
                matmul_scalar(x, batch, w, rows, cols, bias, out)
            }
        }
        #[cfg(target_arch = "x86_64")]
        IsaLevel::Avx512 => {
            if cols >= 16 {
                // SAFETY: `isa_level` returns Avx512 only after runtime
                // CPUID confirmed avx512f/bw/dq/vl (+avx2+fma); the
                // shape contract the kernel indexes by is asserted
                // above.
                unsafe { matmul_avx512(x, batch, w, rows, cols, bias, out) }
            } else if cols >= 8 {
                // SAFETY: Avx512 implies CPUID-confirmed avx2+fma; same
                // shape contract as above.
                unsafe { matmul_avx2(x, batch, w, rows, cols, bias, out) }
            } else {
                matmul_scalar(x, batch, w, rows, cols, bias, out)
            }
        }
        #[cfg(not(target_arch = "x86_64"))]
        _ => matmul_scalar(x, batch, w, rows, cols, bias, out),
    }
}

/// Portable batched matmul (also the non-x86 fallback).
pub fn matmul_scalar(
    x: &[f32],
    batch: usize,
    w: &[f32],
    rows: usize,
    cols: usize,
    bias: Option<&[f32]>,
    out: &mut [f32],
) {
    for (xr, or) in x
        .chunks_exact(rows)
        .zip(out.chunks_exact_mut(cols))
        .take(batch)
    {
        match bias {
            Some(bv) => or.copy_from_slice(bv),
            None => or.fill(0.0),
        }
        for (i, &xi) in xr.iter().enumerate() {
            for (o, &wv) in or.iter_mut().zip(&w[i * cols..(i + 1) * cols]) {
                *o += xi * wv;
            }
        }
    }
}

/// Batched upstream-gradient backprop: `out[b*rows + i] = Σ_j
/// dy[b*cols + j] * w[i*cols + j]` — i.e. `dX = dY·Wᵀ` against the
/// same row-major `[rows × cols]` weight matrix the forward used.
///
/// No transpose is materialized: because `w` is row-major, element
/// `(b, i)` is the dot product of two contiguous length-`cols` strips
/// (`dy` row `b` and `w` row `i`).  The AVX2 kernel loads each weight
/// row once per 4-batch-row register block.  Per-element operation
/// order is independent of the batch size (module contract), so a
/// gradient row backpropagated alone is bit-identical to the same row
/// inside a larger micro-batch.
pub fn matmul_transposed(
    dy: &[f32],
    batch: usize,
    w: &[f32],
    rows: usize,
    cols: usize,
    out: &mut [f32],
) {
    debug_assert!(rows > 0 && cols > 0);
    debug_assert_eq!(dy.len(), batch * cols);
    debug_assert_eq!(w.len(), rows * cols);
    debug_assert_eq!(out.len(), batch * rows);
    match isa_level() {
        IsaLevel::Scalar => matmul_transposed_scalar(dy, batch, w, rows, cols, out),
        #[cfg(target_arch = "x86_64")]
        IsaLevel::Avx2Fma => {
            if cols >= 8 {
                // SAFETY: `isa_level` returns Avx2Fma only after
                // runtime CPUID confirmed avx2+fma; the shape contract
                // the kernel indexes by is asserted above.
                unsafe { matmul_transposed_avx2(dy, batch, w, rows, cols, out) }
            } else {
                matmul_transposed_scalar(dy, batch, w, rows, cols, out)
            }
        }
        #[cfg(target_arch = "x86_64")]
        IsaLevel::Avx512 => {
            if cols >= 16 {
                // SAFETY: `isa_level` returns Avx512 only after runtime
                // CPUID confirmed avx512f/bw/dq/vl (+avx2+fma); the
                // shape contract the kernel indexes by is asserted
                // above.
                unsafe { matmul_transposed_avx512(dy, batch, w, rows, cols, out) }
            } else if cols >= 8 {
                // SAFETY: Avx512 implies CPUID-confirmed avx2+fma; same
                // shape contract as above.
                unsafe { matmul_transposed_avx2(dy, batch, w, rows, cols, out) }
            } else {
                matmul_transposed_scalar(dy, batch, w, rows, cols, out)
            }
        }
        #[cfg(not(target_arch = "x86_64"))]
        _ => matmul_transposed_scalar(dy, batch, w, rows, cols, out),
    }
}

/// Portable `dX = dY·Wᵀ` (also the non-x86 fallback).
pub fn matmul_transposed_scalar(
    dy: &[f32],
    batch: usize,
    w: &[f32],
    rows: usize,
    cols: usize,
    out: &mut [f32],
) {
    for (dyr, or) in dy
        .chunks_exact(cols)
        .zip(out.chunks_exact_mut(rows))
        .take(batch)
    {
        for (i, o) in or.iter_mut().enumerate() {
            let mut s = 0.0f32;
            for (&g, &wv) in dyr.iter().zip(&w[i * cols..(i + 1) * cols]) {
                s += g * wv;
            }
            *o = s;
        }
    }
}

/// Accumulating weight-gradient GEMM: `dw[i*cols + j] += Σ_b
/// x[b*rows + i] * dy[b*cols + j]` — i.e. `dW += Xᵀ·dY`, the minibatch
/// reduction of the dense tower's per-example outer products.
///
/// `dw` is read-modify-written so callers can fold several consecutive
/// micro-segments into one gradient matrix; per element the batch rows
/// are consumed in order with one FMA each, so a segmented reduction is
/// bit-identical to a single call over the concatenated batch (at a
/// fixed ISA level — module contract).
pub fn matmul_xt_dy(
    x: &[f32],
    batch: usize,
    dy: &[f32],
    rows: usize,
    cols: usize,
    dw: &mut [f32],
) {
    debug_assert!(rows > 0 && cols > 0);
    debug_assert_eq!(x.len(), batch * rows);
    debug_assert_eq!(dy.len(), batch * cols);
    debug_assert_eq!(dw.len(), rows * cols);
    match isa_level() {
        IsaLevel::Scalar => matmul_xt_dy_scalar(x, batch, dy, rows, cols, dw),
        #[cfg(target_arch = "x86_64")]
        IsaLevel::Avx2Fma => {
            if cols >= 8 {
                // SAFETY: `isa_level` returns Avx2Fma only after
                // runtime CPUID confirmed avx2+fma; the shape contract
                // the kernel indexes by is asserted above.
                unsafe { matmul_xt_dy_avx2(x, batch, dy, rows, cols, dw) }
            } else {
                matmul_xt_dy_scalar(x, batch, dy, rows, cols, dw)
            }
        }
        #[cfg(target_arch = "x86_64")]
        IsaLevel::Avx512 => {
            if cols >= 16 {
                // SAFETY: `isa_level` returns Avx512 only after runtime
                // CPUID confirmed avx512f/bw/dq/vl (+avx2+fma); the
                // shape contract the kernel indexes by is asserted
                // above.
                unsafe { matmul_xt_dy_avx512(x, batch, dy, rows, cols, dw) }
            } else if cols >= 8 {
                // SAFETY: Avx512 implies CPUID-confirmed avx2+fma; same
                // shape contract as above.
                unsafe { matmul_xt_dy_avx2(x, batch, dy, rows, cols, dw) }
            } else {
                matmul_xt_dy_scalar(x, batch, dy, rows, cols, dw)
            }
        }
        #[cfg(not(target_arch = "x86_64"))]
        _ => matmul_xt_dy_scalar(x, batch, dy, rows, cols, dw),
    }
}

/// Portable `dW += Xᵀ·dY` (also the non-x86 fallback).
pub fn matmul_xt_dy_scalar(
    x: &[f32],
    batch: usize,
    dy: &[f32],
    rows: usize,
    cols: usize,
    dw: &mut [f32],
) {
    for (i, dwr) in dw.chunks_exact_mut(cols).enumerate() {
        for (j, o) in dwr.iter_mut().enumerate() {
            let mut s = *o;
            for b in 0..batch {
                s += x[b * rows + i] * dy[b * cols + j];
            }
            *o = s;
        }
    }
}

/// `out[b] = Σ_j m[b*cols + j]` — batched horizontal sum over rows.
pub fn rowwise_sum(m: &[f32], rows: usize, cols: usize, out: &mut [f32]) {
    debug_assert!(cols > 0);
    debug_assert_eq!(m.len(), rows * cols);
    debug_assert_eq!(out.len(), rows);
    match isa_level() {
        IsaLevel::Scalar => rowwise_sum_scalar(m, cols, out),
        #[cfg(target_arch = "x86_64")]
        IsaLevel::Avx2Fma => {
            if cols >= 8 {
                // SAFETY: `isa_level` returns Avx2Fma only after
                // runtime CPUID confirmed avx2+fma; the shape contract
                // the kernel indexes by is asserted above.
                unsafe { rowwise_sum_avx2(m, cols, out) }
            } else {
                rowwise_sum_scalar(m, cols, out)
            }
        }
        #[cfg(target_arch = "x86_64")]
        IsaLevel::Avx512 => {
            if cols >= 16 {
                // SAFETY: `isa_level` returns Avx512 only after runtime
                // CPUID confirmed avx512f/bw/dq/vl (+avx2+fma); the
                // shape contract the kernel indexes by is asserted
                // above.
                unsafe { rowwise_sum_avx512(m, cols, out) }
            } else if cols >= 8 {
                // SAFETY: Avx512 implies CPUID-confirmed avx2+fma; same
                // shape contract as above.
                unsafe { rowwise_sum_avx2(m, cols, out) }
            } else {
                rowwise_sum_scalar(m, cols, out)
            }
        }
        #[cfg(not(target_arch = "x86_64"))]
        _ => rowwise_sum_scalar(m, cols, out),
    }
}

/// `out[b] = Σ_j m[b*cols + j]²` — batched sum of squares (the batched
/// MergeNorm's per-candidate RMS numerator).
pub fn rowwise_sumsq(m: &[f32], rows: usize, cols: usize, out: &mut [f32]) {
    debug_assert!(cols > 0);
    debug_assert_eq!(m.len(), rows * cols);
    debug_assert_eq!(out.len(), rows);
    match isa_level() {
        IsaLevel::Scalar => rowwise_sumsq_scalar(m, cols, out),
        #[cfg(target_arch = "x86_64")]
        IsaLevel::Avx2Fma => {
            if cols >= 8 {
                // SAFETY: `isa_level` returns Avx2Fma only after
                // runtime CPUID confirmed avx2+fma; the shape contract
                // the kernel indexes by is asserted above.
                unsafe { rowwise_sumsq_avx2(m, cols, out) }
            } else {
                rowwise_sumsq_scalar(m, cols, out)
            }
        }
        #[cfg(target_arch = "x86_64")]
        IsaLevel::Avx512 => {
            if cols >= 16 {
                // SAFETY: `isa_level` returns Avx512 only after runtime
                // CPUID confirmed avx512f/bw/dq/vl (+avx2+fma); the
                // shape contract the kernel indexes by is asserted
                // above.
                unsafe { rowwise_sumsq_avx512(m, cols, out) }
            } else if cols >= 8 {
                // SAFETY: Avx512 implies CPUID-confirmed avx2+fma; same
                // shape contract as above.
                unsafe { rowwise_sumsq_avx2(m, cols, out) }
            } else {
                rowwise_sumsq_scalar(m, cols, out)
            }
        }
        #[cfg(not(target_arch = "x86_64"))]
        _ => rowwise_sumsq_scalar(m, cols, out),
    }
}

fn rowwise_sum_scalar(m: &[f32], cols: usize, out: &mut [f32]) {
    for (row, o) in m.chunks_exact(cols).zip(out.iter_mut()) {
        let mut s = 0.0f32;
        for &v in row {
            s += v;
        }
        *o = s;
    }
}

fn rowwise_sumsq_scalar(m: &[f32], cols: usize, out: &mut [f32]) {
    for (row, o) in m.chunks_exact(cols).zip(out.iter_mut()) {
        let mut s = 0.0f32;
        for &v in row {
            s += v * v;
        }
        *o = s;
    }
}

// ------------------------------------------------------------------ avx2

/// # Safety
/// Caller must ensure the CPU supports avx2+fma (runtime-detected) and
/// the [`matmul_rowmajor`] shape contract: `x.len() == batch * rows`,
/// `w.len() == rows * cols`, `out.len() == batch * cols`, and
/// `bias.len() == cols` when given.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn matmul_avx2(
    x: &[f32],
    batch: usize,
    w: &[f32],
    rows: usize,
    cols: usize,
    bias: Option<&[f32]>,
    out: &mut [f32],
) {
    let mut b = 0usize;
    while b + 4 <= batch {
        // SAFETY: b + 4 <= batch keeps rows b..b+4 inside the caller's
        // shape contract, which is forwarded verbatim.
        unsafe { mm_rows::<4>(x, b, w, rows, cols, bias, out) };
        b += 4;
    }
    while b < batch {
        // SAFETY: b < batch — same contract, one row.
        unsafe { mm_rows::<1>(x, b, w, rows, cols, bias, out) };
        b += 1;
    }
}

/// `R` batch rows through all column tiles.  Per-element accumulation
/// order is independent of `R` (bias load, then one FMA per input row
/// in order) — the bit-identity contract of the module.
///
/// # Safety
/// Caller must ensure the CPU supports avx2+fma, the [`matmul_avx2`]
/// shape contract, and `b + R <= batch`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
#[inline]
#[allow(clippy::needless_range_loop)]
unsafe fn mm_rows<const R: usize>(
    x: &[f32],
    b: usize,
    w: &[f32],
    rows: usize,
    cols: usize,
    bias: Option<&[f32]>,
    out: &mut [f32],
) {
    use std::arch::x86_64::*;
    let wp = w.as_ptr();
    let mut xp = [std::ptr::null::<f32>(); R];
    for (r, p) in xp.iter_mut().enumerate() {
        // SAFETY: b + R <= batch and x.len() == batch * rows keep each
        // row pointer (read through offsets 0..rows below) in bounds.
        *p = unsafe { x.as_ptr().add((b + r) * rows) };
    }
    let mut j = 0usize;
    // 16-wide column tiles: 2 weight loads serve R candidates (2R FMAs)
    while j + 16 <= cols {
        let mut acc0 = [_mm256_setzero_ps(); R];
        let mut acc1 = [_mm256_setzero_ps(); R];
        if let Some(bv) = bias {
            // SAFETY: j + 16 <= cols == bv.len() bounds both loads.
            unsafe {
                let b0 = _mm256_loadu_ps(bv.as_ptr().add(j));
                let b1 = _mm256_loadu_ps(bv.as_ptr().add(j + 8));
                for r in 0..R {
                    acc0[r] = b0;
                    acc1[r] = b1;
                }
            }
        }
        for i in 0..rows {
            // SAFETY: i < rows and j + 16 <= cols keep the two weight
            // strips inside w (rows * cols); xp[r] reads offset
            // i < rows of an in-bounds input row.
            unsafe {
                let w0 = _mm256_loadu_ps(wp.add(i * cols + j));
                let w1 = _mm256_loadu_ps(wp.add(i * cols + j + 8));
                for r in 0..R {
                    let vx = _mm256_set1_ps(*xp[r].add(i));
                    acc0[r] = _mm256_fmadd_ps(vx, w0, acc0[r]);
                    acc1[r] = _mm256_fmadd_ps(vx, w1, acc1[r]);
                }
            }
        }
        for r in 0..R {
            // SAFETY: b + r < batch and j + 16 <= cols keep both
            // stores inside out (batch * cols).
            unsafe {
                _mm256_storeu_ps(out.as_mut_ptr().add((b + r) * cols + j), acc0[r]);
                _mm256_storeu_ps(
                    out.as_mut_ptr().add((b + r) * cols + j + 8),
                    acc1[r],
                );
            }
        }
        j += 16;
    }
    while j + 8 <= cols {
        let mut acc = [_mm256_setzero_ps(); R];
        if let Some(bv) = bias {
            // SAFETY: j + 8 <= cols == bv.len() bounds the load.
            let b0 = unsafe { _mm256_loadu_ps(bv.as_ptr().add(j)) };
            for a in acc.iter_mut() {
                *a = b0;
            }
        }
        for i in 0..rows {
            // SAFETY: i < rows, j + 8 <= cols — weight strip and input
            // element in bounds as in the 16-wide tile above.
            unsafe {
                let w0 = _mm256_loadu_ps(wp.add(i * cols + j));
                for r in 0..R {
                    let vx = _mm256_set1_ps(*xp[r].add(i));
                    acc[r] = _mm256_fmadd_ps(vx, w0, acc[r]);
                }
            }
        }
        for r in 0..R {
            // SAFETY: b + r < batch, j + 8 <= cols — store in bounds.
            unsafe {
                _mm256_storeu_ps(out.as_mut_ptr().add((b + r) * cols + j), acc[r]);
            }
        }
        j += 8;
    }
    while j < cols {
        for r in 0..R {
            let mut s = match bias {
                Some(bv) => bv[j],
                None => 0.0,
            };
            for i in 0..rows {
                // SAFETY: i < rows, j < cols — scalar tail reads of an
                // input element and a weight element, both in bounds.
                s += unsafe { *xp[r].add(i) * *wp.add(i * cols + j) };
            }
            out[(b + r) * cols + j] = s;
        }
        j += 1;
    }
}

/// # Safety
/// Caller must ensure the CPU supports avx2+fma (runtime-detected) and
/// the [`matmul_transposed`] shape contract: `dy.len() == batch * cols`,
/// `w.len() == rows * cols`, `out.len() == batch * rows`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn matmul_transposed_avx2(
    dy: &[f32],
    batch: usize,
    w: &[f32],
    rows: usize,
    cols: usize,
    out: &mut [f32],
) {
    let mut b = 0usize;
    while b + 4 <= batch {
        // SAFETY: b + 4 <= batch keeps rows b..b+4 inside the caller's
        // shape contract, which is forwarded verbatim.
        unsafe { mm_t_rows::<4>(dy, b, w, rows, cols, out) };
        b += 4;
    }
    while b < batch {
        // SAFETY: b < batch — same contract, one row.
        unsafe { mm_t_rows::<1>(dy, b, w, rows, cols, out) };
        b += 1;
    }
}

/// `R` gradient rows against all weight rows.  Per-element sequence
/// (vector FMAs over the 8-wide column tiles in order, one horizontal
/// reduction, then the scalar column remainder) is independent of `R` —
/// the bit-identity contract of the module.
///
/// # Safety
/// Caller must ensure the CPU supports avx2+fma, the
/// [`matmul_transposed_avx2`] shape contract, and `b + R <= batch`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
#[inline]
#[allow(clippy::needless_range_loop)]
unsafe fn mm_t_rows<const R: usize>(
    dy: &[f32],
    b: usize,
    w: &[f32],
    rows: usize,
    cols: usize,
    out: &mut [f32],
) {
    use std::arch::x86_64::*;
    let wp = w.as_ptr();
    let mut gp = [std::ptr::null::<f32>(); R];
    for (r, p) in gp.iter_mut().enumerate() {
        // SAFETY: b + R <= batch and dy.len() == batch * cols keep
        // each gradient-row pointer (read through offsets 0..cols
        // below) in bounds.
        *p = unsafe { dy.as_ptr().add((b + r) * cols) };
    }
    for i in 0..rows {
        // SAFETY: i < rows and w.len() == rows * cols keep row i (read
        // through offsets 0..cols below) in bounds.
        let wrow = unsafe { wp.add(i * cols) };
        let mut acc = [_mm256_setzero_ps(); R];
        let mut j = 0usize;
        // one weight-row load serves R gradient rows (R FMAs)
        while j + 8 <= cols {
            // SAFETY: j + 8 <= cols bounds the weight-row load and
            // each gradient-row load.
            unsafe {
                let wv = _mm256_loadu_ps(wrow.add(j));
                for r in 0..R {
                    let gv = _mm256_loadu_ps(gp[r].add(j));
                    acc[r] = _mm256_fmadd_ps(gv, wv, acc[r]);
                }
            }
            j += 8;
        }
        let mut s = [0f32; R];
        for r in 0..R {
            // SAFETY: avx2 is enabled per this fn's contract (hsum8 is
            // value-only).
            s[r] = unsafe { hsum8(acc[r]) };
        }
        while j < cols {
            // SAFETY: j < cols — scalar tail reads, in bounds.
            unsafe {
                let wj = *wrow.add(j);
                for r in 0..R {
                    s[r] += *gp[r].add(j) * wj;
                }
            }
            j += 1;
        }
        for r in 0..R {
            out[(b + r) * rows + i] = s[r];
        }
    }
}

/// # Safety
/// Caller must ensure the CPU supports avx2+fma (runtime-detected) and
/// the [`matmul_xt_dy`] shape contract: `x.len() == batch * rows`,
/// `dy.len() == batch * cols`, `dw.len() == rows * cols`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
#[allow(clippy::needless_range_loop)]
unsafe fn matmul_xt_dy_avx2(
    x: &[f32],
    batch: usize,
    dy: &[f32],
    rows: usize,
    cols: usize,
    dw: &mut [f32],
) {
    use std::arch::x86_64::*;
    let xp = x.as_ptr();
    let dyp = dy.as_ptr();
    // 4 weight rows per block: one dy-row load feeds 4 FMAs.  The batch
    // loop is innermost per element so segmented reductions replay the
    // exact accumulation sequence (module contract).
    let mut i = 0usize;
    while i < rows {
        let ri = (rows - i).min(4);
        let mut j = 0usize;
        while j + 8 <= cols {
            let mut acc = [_mm256_setzero_ps(); 4];
            for r in 0..ri {
                // SAFETY: i + r < rows and j + 8 <= cols bound the
                // 8-lane load inside dw (rows * cols).
                acc[r] = unsafe {
                    _mm256_loadu_ps(dw.as_ptr().add((i + r) * cols + j))
                };
            }
            for b in 0..batch {
                // SAFETY: b < batch and j + 8 <= cols bound the dy
                // load; b < batch and i + r < rows bound the x deref.
                unsafe {
                    let gv = _mm256_loadu_ps(dyp.add(b * cols + j));
                    for r in 0..ri {
                        let vx = _mm256_set1_ps(*xp.add(b * rows + i + r));
                        acc[r] = _mm256_fmadd_ps(vx, gv, acc[r]);
                    }
                }
            }
            for r in 0..ri {
                // SAFETY: same bounds as the matching load above.
                unsafe {
                    _mm256_storeu_ps(
                        dw.as_mut_ptr().add((i + r) * cols + j),
                        acc[r],
                    );
                }
            }
            j += 8;
        }
        while j < cols {
            for r in 0..ri {
                let mut s = dw[(i + r) * cols + j];
                for b in 0..batch {
                    // SAFETY: b < batch, i + r < rows, j < cols —
                    // scalar-tail reads inside x and dy.
                    s += unsafe {
                        *xp.add(b * rows + i + r) * *dyp.add(b * cols + j)
                    };
                }
                dw[(i + r) * cols + j] = s;
            }
            j += 1;
        }
        i += ri;
    }
}

/// # Safety
/// Caller must ensure the CPU supports avx2 — the body is value-only
/// intrinsics (no memory access).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
#[inline]
unsafe fn hsum8(v: std::arch::x86_64::__m256) -> f32 {
    use std::arch::x86_64::*;
    let hi = _mm256_extractf128_ps::<1>(v);
    let lo = _mm256_castps256_ps128(v);
    let s4 = _mm_add_ps(hi, lo);
    let s2 = _mm_add_ps(s4, _mm_movehl_ps(s4, s4));
    _mm_cvtss_f32(_mm_add_ss(s2, _mm_shuffle_ps::<1>(s2, s2)))
}

/// # Safety
/// Caller must ensure the CPU supports avx2+fma (runtime-detected);
/// slice bounds are enforced by `chunks_exact` below.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn rowwise_sum_avx2(m: &[f32], cols: usize, out: &mut [f32]) {
    use std::arch::x86_64::*;
    for (row, o) in m.chunks_exact(cols).zip(out.iter_mut()) {
        let p = row.as_ptr();
        let mut acc = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 8 <= cols {
            // SAFETY: i + 8 <= cols == row.len() bounds the 8-lane
            // unaligned load.
            acc = _mm256_add_ps(acc, unsafe { _mm256_loadu_ps(p.add(i)) });
            i += 8;
        }
        // SAFETY: avx2 is enabled per this fn's contract.
        let mut s = unsafe { hsum8(acc) };
        while i < cols {
            s += row[i];
            i += 1;
        }
        *o = s;
    }
}

/// # Safety
/// Caller must ensure the CPU supports avx2+fma (runtime-detected);
/// slice bounds are enforced by `chunks_exact` below.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn rowwise_sumsq_avx2(m: &[f32], cols: usize, out: &mut [f32]) {
    use std::arch::x86_64::*;
    for (row, o) in m.chunks_exact(cols).zip(out.iter_mut()) {
        let p = row.as_ptr();
        let mut acc = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 8 <= cols {
            // SAFETY: i + 8 <= cols == row.len() bounds the 8-lane
            // unaligned load.
            let v = unsafe { _mm256_loadu_ps(p.add(i)) };
            acc = _mm256_fmadd_ps(v, v, acc);
            i += 8;
        }
        // SAFETY: avx2 is enabled per this fn's contract.
        let mut s = unsafe { hsum8(acc) };
        while i < cols {
            s += row[i] * row[i];
            i += 1;
        }
        *o = s;
    }
}

// ---------------------------------------------------------------- avx512

/// # Safety
/// Caller must ensure the CPU supports avx512f/bw/dq/vl (+avx2+fma,
/// runtime-detected) and the [`matmul_rowmajor`] shape contract:
/// `x.len() == batch * rows`, `w.len() == rows * cols`,
/// `out.len() == batch * cols`, and `bias.len() == cols` when given.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512bw,avx512dq,avx512vl,avx2,fma")]
unsafe fn matmul_avx512(
    x: &[f32],
    batch: usize,
    w: &[f32],
    rows: usize,
    cols: usize,
    bias: Option<&[f32]>,
    out: &mut [f32],
) {
    let mut b = 0usize;
    while b + 4 <= batch {
        // SAFETY: b + 4 <= batch keeps rows b..b+4 inside the caller's
        // shape contract, which is forwarded verbatim.
        unsafe { mm_rows512::<4>(x, b, w, rows, cols, bias, out) };
        b += 4;
    }
    while b < batch {
        // SAFETY: b < batch — same contract, one row.
        unsafe { mm_rows512::<1>(x, b, w, rows, cols, bias, out) };
        b += 1;
    }
}

/// `R` batch rows through all column tiles — the AVX2 4×16 tile widened
/// to 4×32 (two zmm accumulators per batch row).  Per-element
/// accumulation order is independent of `R` and of the batch size (bias
/// load, then one FMA per input row in order) — the bit-identity
/// contract of the module.  Column coverage: 32-wide zmm pairs, one
/// 16-wide zmm, one 8-wide ymm, scalar tail — a function of `cols`
/// only.
///
/// # Safety
/// Caller must ensure the CPU supports avx512f/bw/dq/vl (+avx2+fma),
/// the [`matmul_avx512`] shape contract, and `b + R <= batch`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512bw,avx512dq,avx512vl,avx2,fma")]
#[inline]
#[allow(clippy::needless_range_loop)]
unsafe fn mm_rows512<const R: usize>(
    x: &[f32],
    b: usize,
    w: &[f32],
    rows: usize,
    cols: usize,
    bias: Option<&[f32]>,
    out: &mut [f32],
) {
    use std::arch::x86_64::*;
    let wp = w.as_ptr();
    let mut xp = [std::ptr::null::<f32>(); R];
    for (r, p) in xp.iter_mut().enumerate() {
        // SAFETY: b + R <= batch and x.len() == batch * rows keep each
        // row pointer (read through offsets 0..rows below) in bounds.
        *p = unsafe { x.as_ptr().add((b + r) * rows) };
    }
    let mut j = 0usize;
    // 32-wide column tiles: 2 zmm weight loads serve R candidates
    // (2R FMAs)
    while j + 32 <= cols {
        let mut acc0 = [_mm512_setzero_ps(); R];
        let mut acc1 = [_mm512_setzero_ps(); R];
        if let Some(bv) = bias {
            // SAFETY: j + 32 <= cols == bv.len() bounds both loads.
            unsafe {
                let b0 = _mm512_loadu_ps(bv.as_ptr().add(j));
                let b1 = _mm512_loadu_ps(bv.as_ptr().add(j + 16));
                for r in 0..R {
                    acc0[r] = b0;
                    acc1[r] = b1;
                }
            }
        }
        for i in 0..rows {
            // SAFETY: i < rows and j + 32 <= cols keep the two weight
            // strips inside w (rows * cols); xp[r] reads offset
            // i < rows of an in-bounds input row.
            unsafe {
                let w0 = _mm512_loadu_ps(wp.add(i * cols + j));
                let w1 = _mm512_loadu_ps(wp.add(i * cols + j + 16));
                for r in 0..R {
                    let vx = _mm512_set1_ps(*xp[r].add(i));
                    acc0[r] = _mm512_fmadd_ps(vx, w0, acc0[r]);
                    acc1[r] = _mm512_fmadd_ps(vx, w1, acc1[r]);
                }
            }
        }
        for r in 0..R {
            // SAFETY: b + r < batch and j + 32 <= cols keep both
            // stores inside out (batch * cols).
            unsafe {
                _mm512_storeu_ps(out.as_mut_ptr().add((b + r) * cols + j), acc0[r]);
                _mm512_storeu_ps(
                    out.as_mut_ptr().add((b + r) * cols + j + 16),
                    acc1[r],
                );
            }
        }
        j += 32;
    }
    while j + 16 <= cols {
        let mut acc = [_mm512_setzero_ps(); R];
        if let Some(bv) = bias {
            // SAFETY: j + 16 <= cols == bv.len() bounds the load.
            let b0 = unsafe { _mm512_loadu_ps(bv.as_ptr().add(j)) };
            for a in acc.iter_mut() {
                *a = b0;
            }
        }
        for i in 0..rows {
            // SAFETY: i < rows, j + 16 <= cols — weight strip and input
            // element in bounds as in the 32-wide tile above.
            unsafe {
                let w0 = _mm512_loadu_ps(wp.add(i * cols + j));
                for r in 0..R {
                    let vx = _mm512_set1_ps(*xp[r].add(i));
                    acc[r] = _mm512_fmadd_ps(vx, w0, acc[r]);
                }
            }
        }
        for r in 0..R {
            // SAFETY: b + r < batch, j + 16 <= cols — store in bounds.
            unsafe {
                _mm512_storeu_ps(out.as_mut_ptr().add((b + r) * cols + j), acc[r]);
            }
        }
        j += 16;
    }
    while j + 8 <= cols {
        let mut acc = [_mm256_setzero_ps(); R];
        if let Some(bv) = bias {
            // SAFETY: j + 8 <= cols == bv.len() bounds the load.
            let b0 = unsafe { _mm256_loadu_ps(bv.as_ptr().add(j)) };
            for a in acc.iter_mut() {
                *a = b0;
            }
        }
        for i in 0..rows {
            // SAFETY: i < rows, j + 8 <= cols — weight strip and input
            // element in bounds as in the 32-wide tile above.
            unsafe {
                let w0 = _mm256_loadu_ps(wp.add(i * cols + j));
                for r in 0..R {
                    let vx = _mm256_set1_ps(*xp[r].add(i));
                    acc[r] = _mm256_fmadd_ps(vx, w0, acc[r]);
                }
            }
        }
        for r in 0..R {
            // SAFETY: b + r < batch, j + 8 <= cols — store in bounds.
            unsafe {
                _mm256_storeu_ps(out.as_mut_ptr().add((b + r) * cols + j), acc[r]);
            }
        }
        j += 8;
    }
    while j < cols {
        for r in 0..R {
            let mut s = match bias {
                Some(bv) => bv[j],
                None => 0.0,
            };
            for i in 0..rows {
                // SAFETY: i < rows, j < cols — scalar tail reads of an
                // input element and a weight element, both in bounds.
                s += unsafe { *xp[r].add(i) * *wp.add(i * cols + j) };
            }
            out[(b + r) * cols + j] = s;
        }
        j += 1;
    }
}

/// # Safety
/// Caller must ensure the CPU supports avx512f/bw/dq/vl (+avx2+fma,
/// runtime-detected) and the [`matmul_transposed`] shape contract:
/// `dy.len() == batch * cols`, `w.len() == rows * cols`,
/// `out.len() == batch * rows`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512bw,avx512dq,avx512vl,avx2,fma")]
unsafe fn matmul_transposed_avx512(
    dy: &[f32],
    batch: usize,
    w: &[f32],
    rows: usize,
    cols: usize,
    out: &mut [f32],
) {
    let mut b = 0usize;
    while b + 4 <= batch {
        // SAFETY: b + 4 <= batch keeps rows b..b+4 inside the caller's
        // shape contract, which is forwarded verbatim.
        unsafe { mm_t_rows512::<4>(dy, b, w, rows, cols, out) };
        b += 4;
    }
    while b < batch {
        // SAFETY: b < batch — same contract, one row.
        unsafe { mm_t_rows512::<1>(dy, b, w, rows, cols, out) };
        b += 1;
    }
}

/// `R` gradient rows against all weight rows, 16-lane tiles.
/// Per-element sequence (zmm FMAs over the 16-wide column tiles in
/// order, one deterministic [`super::dot::hsum16`] reduction, then the
/// scalar column remainder) is independent of `R` — the bit-identity
/// contract of the module.
///
/// # Safety
/// Caller must ensure the CPU supports avx512f/bw/dq/vl (+avx2+fma),
/// the [`matmul_transposed_avx512`] shape contract, and
/// `b + R <= batch`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512bw,avx512dq,avx512vl,avx2,fma")]
#[inline]
#[allow(clippy::needless_range_loop)]
unsafe fn mm_t_rows512<const R: usize>(
    dy: &[f32],
    b: usize,
    w: &[f32],
    rows: usize,
    cols: usize,
    out: &mut [f32],
) {
    use std::arch::x86_64::*;
    let wp = w.as_ptr();
    let mut gp = [std::ptr::null::<f32>(); R];
    for (r, p) in gp.iter_mut().enumerate() {
        // SAFETY: b + R <= batch and dy.len() == batch * cols keep
        // each gradient-row pointer (read through offsets 0..cols
        // below) in bounds.
        *p = unsafe { dy.as_ptr().add((b + r) * cols) };
    }
    for i in 0..rows {
        // SAFETY: i < rows and w.len() == rows * cols keep row i (read
        // through offsets 0..cols below) in bounds.
        let wrow = unsafe { wp.add(i * cols) };
        let mut acc = [_mm512_setzero_ps(); R];
        let mut j = 0usize;
        // one weight-row load serves R gradient rows (R FMAs)
        while j + 16 <= cols {
            // SAFETY: j + 16 <= cols bounds the weight-row load and
            // each gradient-row load.
            unsafe {
                let wv = _mm512_loadu_ps(wrow.add(j));
                for r in 0..R {
                    let gv = _mm512_loadu_ps(gp[r].add(j));
                    acc[r] = _mm512_fmadd_ps(gv, wv, acc[r]);
                }
            }
            j += 16;
        }
        let mut s = [0f32; R];
        for r in 0..R {
            // SAFETY: avx512f+avx512dq are enabled per this fn's
            // contract (hsum16 is value-only).
            s[r] = unsafe { super::dot::hsum16(acc[r]) };
        }
        while j < cols {
            // SAFETY: j < cols — scalar tail reads, in bounds.
            unsafe {
                let wj = *wrow.add(j);
                for r in 0..R {
                    s[r] += *gp[r].add(j) * wj;
                }
            }
            j += 1;
        }
        for r in 0..R {
            out[(b + r) * rows + i] = s[r];
        }
    }
}

/// # Safety
/// Caller must ensure the CPU supports avx512f/bw/dq/vl (+avx2+fma,
/// runtime-detected) and the [`matmul_xt_dy`] shape contract:
/// `x.len() == batch * rows`, `dy.len() == batch * cols`,
/// `dw.len() == rows * cols`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512bw,avx512dq,avx512vl,avx2,fma")]
#[allow(clippy::needless_range_loop)]
unsafe fn matmul_xt_dy_avx512(
    x: &[f32],
    batch: usize,
    dy: &[f32],
    rows: usize,
    cols: usize,
    dw: &mut [f32],
) {
    use std::arch::x86_64::*;
    let xp = x.as_ptr();
    let dyp = dy.as_ptr();
    // 4 weight rows per block: one dy-row load feeds 4 FMAs.  The batch
    // loop is innermost per element so segmented reductions replay the
    // exact accumulation sequence (module contract).
    let mut i = 0usize;
    while i < rows {
        let ri = (rows - i).min(4);
        let mut j = 0usize;
        while j + 16 <= cols {
            let mut acc = [_mm512_setzero_ps(); 4];
            for r in 0..ri {
                // SAFETY: i + r < rows and j + 16 <= cols bound the
                // 16-lane load inside dw (rows * cols).
                acc[r] = unsafe {
                    _mm512_loadu_ps(dw.as_ptr().add((i + r) * cols + j))
                };
            }
            for b in 0..batch {
                // SAFETY: b < batch and j + 16 <= cols bound the dy
                // load; b < batch and i + r < rows bound the x deref.
                unsafe {
                    let gv = _mm512_loadu_ps(dyp.add(b * cols + j));
                    for r in 0..ri {
                        let vx = _mm512_set1_ps(*xp.add(b * rows + i + r));
                        acc[r] = _mm512_fmadd_ps(vx, gv, acc[r]);
                    }
                }
            }
            for r in 0..ri {
                // SAFETY: same bounds as the matching load above.
                unsafe {
                    _mm512_storeu_ps(
                        dw.as_mut_ptr().add((i + r) * cols + j),
                        acc[r],
                    );
                }
            }
            j += 16;
        }
        while j + 8 <= cols {
            let mut acc = [_mm256_setzero_ps(); 4];
            for r in 0..ri {
                // SAFETY: i + r < rows and j + 8 <= cols bound the
                // 8-lane load inside dw (rows * cols).
                acc[r] = unsafe {
                    _mm256_loadu_ps(dw.as_ptr().add((i + r) * cols + j))
                };
            }
            for b in 0..batch {
                // SAFETY: b < batch and j + 8 <= cols bound the dy
                // load; b < batch and i + r < rows bound the x deref.
                unsafe {
                    let gv = _mm256_loadu_ps(dyp.add(b * cols + j));
                    for r in 0..ri {
                        let vx = _mm256_set1_ps(*xp.add(b * rows + i + r));
                        acc[r] = _mm256_fmadd_ps(vx, gv, acc[r]);
                    }
                }
            }
            for r in 0..ri {
                // SAFETY: same bounds as the matching load above.
                unsafe {
                    _mm256_storeu_ps(
                        dw.as_mut_ptr().add((i + r) * cols + j),
                        acc[r],
                    );
                }
            }
            j += 8;
        }
        while j < cols {
            for r in 0..ri {
                let mut s = dw[(i + r) * cols + j];
                for b in 0..batch {
                    // SAFETY: b < batch, i + r < rows, j < cols —
                    // scalar-tail reads inside x and dy.
                    s += unsafe {
                        *xp.add(b * rows + i + r) * *dyp.add(b * cols + j)
                    };
                }
                dw[(i + r) * cols + j] = s;
            }
            j += 1;
        }
        i += ri;
    }
}

/// # Safety
/// Caller must ensure the CPU supports avx512f/bw/dq/vl (+avx2+fma,
/// runtime-detected); slice bounds are enforced by `chunks_exact`
/// below.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512bw,avx512dq,avx512vl,avx2,fma")]
unsafe fn rowwise_sum_avx512(m: &[f32], cols: usize, out: &mut [f32]) {
    use std::arch::x86_64::*;
    for (row, o) in m.chunks_exact(cols).zip(out.iter_mut()) {
        let p = row.as_ptr();
        let mut acc = _mm512_setzero_ps();
        let mut i = 0usize;
        while i + 16 <= cols {
            // SAFETY: i + 16 <= cols == row.len() bounds the 16-lane
            // unaligned load.
            acc = _mm512_add_ps(acc, unsafe { _mm512_loadu_ps(p.add(i)) });
            i += 16;
        }
        // SAFETY: avx512f+avx512dq are enabled per this fn's contract.
        let mut s = unsafe { super::dot::hsum16(acc) };
        while i < cols {
            s += row[i];
            i += 1;
        }
        *o = s;
    }
}

/// # Safety
/// Caller must ensure the CPU supports avx512f/bw/dq/vl (+avx2+fma,
/// runtime-detected); slice bounds are enforced by `chunks_exact`
/// below.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512bw,avx512dq,avx512vl,avx2,fma")]
unsafe fn rowwise_sumsq_avx512(m: &[f32], cols: usize, out: &mut [f32]) {
    use std::arch::x86_64::*;
    for (row, o) in m.chunks_exact(cols).zip(out.iter_mut()) {
        let p = row.as_ptr();
        let mut acc = _mm512_setzero_ps();
        let mut i = 0usize;
        while i + 16 <= cols {
            // SAFETY: i + 16 <= cols == row.len() bounds the 16-lane
            // unaligned load.
            let v = unsafe { _mm512_loadu_ps(p.add(i)) };
            acc = _mm512_fmadd_ps(v, v, acc);
            i += 16;
        }
        // SAFETY: avx512f+avx512dq are enabled per this fn's contract.
        let mut s = unsafe { super::dot::hsum16(acc) };
        while i < cols {
            s += row[i] * row[i];
            i += 1;
        }
        *o = s;
    }
}

/// True when every AVX-512 feature the kernels above need is present
/// (false under Miri, whose probe is compiled out) — the guard the
/// concrete-kernel test impl lists use to bypass global dispatch.
#[cfg(target_arch = "x86_64")]
pub(crate) fn avx512_available() -> bool {
    super::best_available() >= IsaLevel::Avx512
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn randvec(rng: &mut Pcg32, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal()).collect()
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Pcg32::seeded(11);
        for (batch, rows, cols) in [
            (1, 5, 16),
            (3, 7, 8),
            (4, 13, 16),
            (5, 9, 32),
            (9, 46, 16),
            (2, 7, 7),
            (6, 11, 20),
            (8, 10, 72),
            (7, 1, 9),
        ] {
            let x = randvec(&mut rng, batch * rows);
            let w = randvec(&mut rng, rows * cols);
            let bias = randvec(&mut rng, cols);
            for with_bias in [false, true] {
                let b = if with_bias { Some(&bias[..]) } else { None };
                let mut out = vec![0f32; batch * cols];
                matmul_rowmajor(&x, batch, &w, rows, cols, b, &mut out);
                for bb in 0..batch {
                    for j in 0..cols {
                        let mut want = if with_bias { bias[j] } else { 0.0 };
                        for i in 0..rows {
                            want += x[bb * rows + i] * w[i * cols + j];
                        }
                        let got = out[bb * cols + j];
                        assert!(
                            (got - want).abs() < 1e-3 * (1.0 + want.abs()),
                            "b={batch} r={rows} c={cols} elem=({bb},{j}): {got} vs {want}"
                        );
                    }
                }
            }
        }
    }

    /// Concrete kernels under test, bypassing the forceable global
    /// dispatch (other tests may flip [`force_scalar`] concurrently).
    fn matmul_impls() -> Vec<(
        &'static str,
        fn(&[f32], usize, &[f32], usize, usize, Option<&[f32]>, &mut [f32]),
    )> {
        let mut impls: Vec<(
            &'static str,
            fn(&[f32], usize, &[f32], usize, usize, Option<&[f32]>, &mut [f32]),
        )> = vec![("scalar", matmul_scalar)];
        #[cfg(target_arch = "x86_64")]
        if std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
        {
            fn avx2(
                x: &[f32],
                batch: usize,
                w: &[f32],
                rows: usize,
                cols: usize,
                bias: Option<&[f32]>,
                out: &mut [f32],
            ) {
                // SAFETY: the feature-detect guard above confirmed
                // avx2+fma; the test passes shape-consistent slices.
                unsafe { matmul_avx2(x, batch, w, rows, cols, bias, out) }
            }
            impls.push(("avx2", avx2));
        }
        #[cfg(target_arch = "x86_64")]
        if avx512_available() {
            fn avx512(
                x: &[f32],
                batch: usize,
                w: &[f32],
                rows: usize,
                cols: usize,
                bias: Option<&[f32]>,
                out: &mut [f32],
            ) {
                // SAFETY: the avx512_available guard above confirmed
                // avx512f/bw/dq/vl (+avx2+fma); the test passes
                // shape-consistent slices.
                unsafe { matmul_avx512(x, batch, w, rows, cols, bias, out) }
            }
            impls.push(("avx512", avx512));
        }
        impls
    }

    #[test]
    fn matmul_batch_invariant_bitwise() {
        // The serving layer depends on B=1 results being bit-identical
        // to the same row scored inside any larger batch, per kernel.
        let mut rng = Pcg32::seeded(12);
        for (batch, rows, cols) in [(6, 17, 16), (9, 8, 24), (5, 30, 40), (8, 46, 16)] {
            let x = randvec(&mut rng, batch * rows);
            let w = randvec(&mut rng, rows * cols);
            let bias = randvec(&mut rng, cols);
            for (name, mm) in matmul_impls() {
                let mut full = vec![0f32; batch * cols];
                mm(&x, batch, &w, rows, cols, Some(&bias), &mut full);
                for b in 0..batch {
                    let mut one = vec![0f32; cols];
                    mm(
                        &x[b * rows..(b + 1) * rows],
                        1,
                        &w,
                        rows,
                        cols,
                        Some(&bias),
                        &mut one,
                    );
                    assert_eq!(one, full[b * cols..(b + 1) * cols], "{name} row {b}");
                }
            }
        }
    }

    #[test]
    fn matmul_impls_agree_within_tolerance() {
        let mut rng = Pcg32::seeded(13);
        let (batch, rows, cols) = (6, 23, 48);
        let x = randvec(&mut rng, batch * rows);
        let w = randvec(&mut rng, rows * cols);
        let mut slow = vec![0f32; batch * cols];
        matmul_scalar(&x, batch, &w, rows, cols, None, &mut slow);
        for (name, mm) in matmul_impls() {
            let mut fast = vec![0f32; batch * cols];
            mm(&x, batch, &w, rows, cols, None, &mut fast);
            for (a, b) in fast.iter().zip(&slow) {
                assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "{name}");
            }
        }
    }

    #[test]
    fn matmul_transposed_matches_naive() {
        let mut rng = Pcg32::seeded(21);
        for (batch, rows, cols) in [
            (1, 5, 16),
            (3, 7, 8),
            (4, 13, 16),
            (5, 9, 32),
            (2, 7, 7),
            (6, 11, 20),
            (7, 1, 9),
            (9, 46, 16),
        ] {
            let dy = randvec(&mut rng, batch * cols);
            let w = randvec(&mut rng, rows * cols);
            let mut out = vec![0f32; batch * rows];
            matmul_transposed(&dy, batch, &w, rows, cols, &mut out);
            for b in 0..batch {
                for i in 0..rows {
                    let mut want = 0.0f32;
                    for j in 0..cols {
                        want += dy[b * cols + j] * w[i * cols + j];
                    }
                    let got = out[b * rows + i];
                    assert!(
                        (got - want).abs() < 1e-3 * (1.0 + want.abs()),
                        "b={batch} r={rows} c={cols} elem=({b},{i}): {got} vs {want}"
                    );
                }
            }
        }
    }

    /// Concrete transposed kernels, bypassing global dispatch.
    fn matmul_t_impls() -> Vec<(
        &'static str,
        fn(&[f32], usize, &[f32], usize, usize, &mut [f32]),
    )> {
        let mut impls: Vec<(
            &'static str,
            fn(&[f32], usize, &[f32], usize, usize, &mut [f32]),
        )> = vec![("scalar", matmul_transposed_scalar)];
        #[cfg(target_arch = "x86_64")]
        if std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
        {
            fn avx2(
                dy: &[f32],
                batch: usize,
                w: &[f32],
                rows: usize,
                cols: usize,
                out: &mut [f32],
            ) {
                // SAFETY: the feature-detect guard above confirmed
                // avx2+fma; the test passes shape-consistent slices.
                unsafe { matmul_transposed_avx2(dy, batch, w, rows, cols, out) }
            }
            impls.push(("avx2", avx2));
        }
        #[cfg(target_arch = "x86_64")]
        if avx512_available() {
            fn avx512(
                dy: &[f32],
                batch: usize,
                w: &[f32],
                rows: usize,
                cols: usize,
                out: &mut [f32],
            ) {
                // SAFETY: the avx512_available guard above confirmed
                // avx512f/bw/dq/vl (+avx2+fma); the test passes
                // shape-consistent slices.
                unsafe { matmul_transposed_avx512(dy, batch, w, rows, cols, out) }
            }
            impls.push(("avx512", avx512));
        }
        impls
    }

    #[test]
    fn matmul_transposed_batch_invariant_bitwise() {
        // A gradient row backpropagated alone must be bit-identical to
        // the same row inside any larger micro-batch, per kernel.
        let mut rng = Pcg32::seeded(22);
        for (batch, rows, cols) in [(6, 17, 16), (9, 8, 24), (5, 30, 44), (8, 46, 13)] {
            let dy = randvec(&mut rng, batch * cols);
            let w = randvec(&mut rng, rows * cols);
            for (name, mm) in matmul_t_impls() {
                let mut full = vec![0f32; batch * rows];
                mm(&dy, batch, &w, rows, cols, &mut full);
                for b in 0..batch {
                    let mut one = vec![0f32; rows];
                    mm(&dy[b * cols..(b + 1) * cols], 1, &w, rows, cols, &mut one);
                    assert_eq!(one, full[b * rows..(b + 1) * rows], "{name} row {b}");
                }
            }
        }
    }

    #[test]
    fn matmul_xt_dy_accumulates_and_matches_naive() {
        let mut rng = Pcg32::seeded(23);
        for (batch, rows, cols) in [
            (1, 5, 16),
            (3, 7, 8),
            (4, 13, 16),
            (5, 9, 32),
            (2, 7, 7),
            (6, 11, 20),
            (8, 3, 9),
        ] {
            let x = randvec(&mut rng, batch * rows);
            let dy = randvec(&mut rng, batch * cols);
            let base = randvec(&mut rng, rows * cols);
            let mut dw = base.clone();
            matmul_xt_dy(&x, batch, &dy, rows, cols, &mut dw);
            for i in 0..rows {
                for j in 0..cols {
                    let mut want = base[i * cols + j];
                    for b in 0..batch {
                        want += x[b * rows + i] * dy[b * cols + j];
                    }
                    let got = dw[i * cols + j];
                    assert!(
                        (got - want).abs() < 1e-3 * (1.0 + want.abs()),
                        "b={batch} r={rows} c={cols} elem=({i},{j}): {got} vs {want}"
                    );
                }
            }
        }
    }

    /// Concrete accumulating kernels, bypassing global dispatch.
    fn matmul_xt_impls() -> Vec<(
        &'static str,
        fn(&[f32], usize, &[f32], usize, usize, &mut [f32]),
    )> {
        let mut impls: Vec<(
            &'static str,
            fn(&[f32], usize, &[f32], usize, usize, &mut [f32]),
        )> = vec![("scalar", matmul_xt_dy_scalar)];
        #[cfg(target_arch = "x86_64")]
        if std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
        {
            fn avx2(
                x: &[f32],
                batch: usize,
                dy: &[f32],
                rows: usize,
                cols: usize,
                dw: &mut [f32],
            ) {
                // SAFETY: the feature-detect guard above confirmed
                // avx2+fma; the test passes shape-consistent slices.
                unsafe { matmul_xt_dy_avx2(x, batch, dy, rows, cols, dw) }
            }
            impls.push(("avx2", avx2));
        }
        #[cfg(target_arch = "x86_64")]
        if avx512_available() {
            fn avx512(
                x: &[f32],
                batch: usize,
                dy: &[f32],
                rows: usize,
                cols: usize,
                dw: &mut [f32],
            ) {
                // SAFETY: the avx512_available guard above confirmed
                // avx512f/bw/dq/vl (+avx2+fma); the test passes
                // shape-consistent slices.
                unsafe { matmul_xt_dy_avx512(x, batch, dy, rows, cols, dw) }
            }
            impls.push(("avx512", avx512));
        }
        impls
    }

    #[test]
    fn matmul_xt_dy_segment_invariant_bitwise() {
        // Reducing a batch in consecutive segments (accumulating into
        // the same dw) must be bit-identical to one full-batch call.
        let mut rng = Pcg32::seeded(24);
        for (batch, rows, cols) in [(6, 17, 16), (9, 8, 24), (7, 30, 44), (8, 46, 13)] {
            let x = randvec(&mut rng, batch * rows);
            let dy = randvec(&mut rng, batch * cols);
            for (name, mm) in matmul_xt_impls() {
                let mut full = vec![0f32; rows * cols];
                mm(&x, batch, &dy, rows, cols, &mut full);
                for split in [1, batch / 2, batch - 1] {
                    let mut seg = vec![0f32; rows * cols];
                    mm(&x[..split * rows], split, &dy[..split * cols], rows, cols, &mut seg);
                    mm(
                        &x[split * rows..],
                        batch - split,
                        &dy[split * cols..],
                        rows,
                        cols,
                        &mut seg,
                    );
                    assert_eq!(seg, full, "{name} split {split}");
                }
            }
        }
    }

    #[test]
    fn rowwise_sums_match_naive() {
        let mut rng = Pcg32::seeded(14);
        for (rows, cols) in [(1, 3), (4, 8), (3, 17), (5, 46), (2, 64), (6, 9)] {
            let m = randvec(&mut rng, rows * cols);
            let mut sum = vec![0f32; rows];
            let mut ssq = vec![0f32; rows];
            rowwise_sum(&m, rows, cols, &mut sum);
            rowwise_sumsq(&m, rows, cols, &mut ssq);
            for r in 0..rows {
                let want_s: f32 = m[r * cols..(r + 1) * cols].iter().sum();
                let want_q: f32 = m[r * cols..(r + 1) * cols].iter().map(|v| v * v).sum();
                assert!((sum[r] - want_s).abs() < 1e-3 * (1.0 + want_s.abs()));
                assert!((ssq[r] - want_q).abs() < 1e-3 * (1.0 + want_q.abs()));
            }
        }
    }

    #[test]
    fn rowwise_sums_batch_invariant_bitwise() {
        // Per concrete kernel (dispatch-independent): a row's sum of
        // squares is identical alone or inside a batch.
        let mut rng = Pcg32::seeded(15);
        let (rows, cols) = (7, 46);
        let m = randvec(&mut rng, rows * cols);
        let mut impls: Vec<(&'static str, fn(&[f32], usize, &mut [f32]))> =
            vec![("scalar", rowwise_sumsq_scalar)];
        #[cfg(target_arch = "x86_64")]
        if std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
        {
            fn avx2(m: &[f32], cols: usize, out: &mut [f32]) {
                // SAFETY: the feature-detect guard above confirmed
                // avx2+fma; the test passes shape-consistent slices.
                unsafe { rowwise_sumsq_avx2(m, cols, out) }
            }
            impls.push(("avx2", avx2));
        }
        #[cfg(target_arch = "x86_64")]
        if avx512_available() {
            fn avx512(m: &[f32], cols: usize, out: &mut [f32]) {
                // SAFETY: the avx512_available guard above confirmed
                // avx512f/bw/dq/vl (+avx2+fma); the test passes
                // shape-consistent slices.
                unsafe { rowwise_sumsq_avx512(m, cols, out) }
            }
            impls.push(("avx512", avx512));
        }
        for (name, ssq) in impls {
            let mut full = vec![0f32; rows];
            ssq(&m, cols, &mut full);
            for r in 0..rows {
                let mut one = vec![0f32; 1];
                ssq(&m[r * cols..(r + 1) * cols], cols, &mut one);
                assert_eq!(one[0], full[r], "{name} row {r}");
            }
        }
    }
}
