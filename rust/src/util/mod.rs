//! Small self-contained utilities: PRNG, math, histograms, varints,
//! JSON, LZ compression, timing.  The offline build environment ships
//! no `rand`, `serde`, `flate2` or `criterion`, so these substrates are
//! implemented here.

pub mod bench_env;
pub mod compress;
pub mod crc32;
pub mod histogram;
pub mod json;
pub mod math;
pub mod rng;
pub mod timer;
pub mod varint;
