//! Per-coordinate update rules.
//!
//! The engine uses VW-style adaptive (AdaGrad) updates with a tunable
//! `power_t` — one of the hyperparameters the paper's model search
//! sweeps ("power of t, learning rates for different types of blocks").
//!
//! Blocks are generic over [`UpdateRule`] so the same backward code
//! serves the AdaGrad hot path, plain SGD, and the gradient recorder
//! used by finite-difference tests.

/// A per-coordinate update applied at gradient-sink time.
pub trait UpdateRule {
    /// Apply the update for pool index `idx` given gradient `g`.
    fn update(&mut self, idx: usize, w: &mut f32, acc: &mut f32, g: f32);
}

/// AdaGrad with power_t and optional L2-on-gradient.
#[derive(Clone, Copy, Debug)]
pub struct AdaGrad {
    pub lr: f32,
    pub power_t: f32,
    pub l2: f32,
}

impl AdaGrad {
    pub fn new(lr: f32, power_t: f32, l2: f32) -> Self {
        AdaGrad { lr, power_t, l2 }
    }
}

impl UpdateRule for AdaGrad {
    #[inline]
    fn update(&mut self, _idx: usize, w: &mut f32, acc: &mut f32, g: f32) {
        let g = g + self.l2 * *w;
        *acc += g * g;
        // step = lr * g / acc^power_t; power_t in [0, 1].
        let denom = if self.power_t == 0.5 {
            acc.sqrt()
        } else if self.power_t == 0.0 {
            1.0
        } else {
            acc.powf(self.power_t)
        };
        *w -= self.lr * g / denom;
    }
}

/// Plain SGD (power_t = 0 AdaGrad without accumulator churn).
#[derive(Clone, Copy, Debug)]
pub struct Sgd {
    pub lr: f32,
}

impl UpdateRule for Sgd {
    #[inline]
    fn update(&mut self, _idx: usize, w: &mut f32, _acc: &mut f32, g: f32) {
        *w -= self.lr * g;
    }
}

/// Records gradients instead of updating — the finite-difference
/// harness compares these against numeric gradients.
#[derive(Clone, Debug, Default)]
pub struct GradRecorder {
    /// (pool index, gradient) in emission order.
    pub grads: Vec<(usize, f32)>,
}

impl GradRecorder {
    pub fn dense(&self, total: usize) -> Vec<f32> {
        let mut out = vec![0f32; total];
        for &(i, g) in &self.grads {
            out[i] += g;
        }
        out
    }
}

impl UpdateRule for GradRecorder {
    #[inline]
    fn update(&mut self, idx: usize, _w: &mut f32, _acc: &mut f32, g: f32) {
        self.grads.push((idx, g));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adagrad_first_step_is_lr_g() {
        let mut opt = AdaGrad::new(0.1, 0.5, 0.0);
        let mut w = 0.0f32;
        let mut acc = 0.0f32;
        opt.update(0, &mut w, &mut acc, 1.0);
        // acc becomes 1.0, denom 1.0 -> step = lr
        assert!((w + 0.1).abs() < 1e-6, "w={w}");
        assert_eq!(acc, 1.0);
    }

    #[test]
    fn adagrad_steps_shrink() {
        let mut opt = AdaGrad::new(0.1, 0.5, 0.0);
        let mut w = 0.0f32;
        let mut acc = 1.0f32;
        let mut prev_step = f32::MAX;
        for _ in 0..10 {
            let before = w;
            opt.update(0, &mut w, &mut acc, 1.0);
            let step = (before - w).abs();
            assert!(step <= prev_step + 1e-9);
            prev_step = step;
        }
    }

    #[test]
    fn power_t_zero_is_constant_rate() {
        let mut opt = AdaGrad::new(0.2, 0.0, 0.0);
        let mut w = 0.0f32;
        let mut acc = 1.0f32;
        opt.update(0, &mut w, &mut acc, 1.0);
        opt.update(0, &mut w, &mut acc, 1.0);
        assert!((w + 0.4).abs() < 1e-6);
    }

    #[test]
    fn l2_pulls_towards_zero() {
        let mut opt = AdaGrad::new(0.1, 0.0, 0.5);
        let mut w = 1.0f32;
        let mut acc = 1.0f32;
        opt.update(0, &mut w, &mut acc, 0.0);
        assert!(w < 1.0);
    }

    #[test]
    fn sgd_simple() {
        let mut opt = Sgd { lr: 0.5 };
        let (mut w, mut acc) = (1.0f32, 0.0f32);
        opt.update(3, &mut w, &mut acc, 0.4);
        assert!((w - 0.8).abs() < 1e-7);
        assert_eq!(acc, 0.0);
    }

    #[test]
    fn recorder_accumulates() {
        let mut r = GradRecorder::default();
        let (mut w, mut acc) = (1.0f32, 1.0f32);
        r.update(2, &mut w, &mut acc, 0.5);
        r.update(2, &mut w, &mut acc, 0.25);
        r.update(0, &mut w, &mut acc, -1.0);
        assert_eq!(w, 1.0); // untouched
        let dense = r.dense(4);
        assert_eq!(dense, vec![-1.0, 0.0, 0.75, 0.0]);
    }
}
