//! CRC-32 (IEEE 802.3 polynomial, the zlib/gzip variant) — the
//! integrity guard on durable checkpoint files.  The offline build has
//! no `crc32fast`; a 256-entry table computed at compile time is plenty
//! for checkpoint-sized payloads.

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xedb8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static TABLE: [u32; 256] = build_table();

/// CRC-32 of `data` (init 0xFFFFFFFF, final xor — matches zlib's
/// `crc32(0, ...)`).
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = 0xffff_ffffu32;
    for &b in data {
        c = TABLE[((c ^ b as u32) & 0xff) as usize] ^ (c >> 8);
    }
    c ^ 0xffff_ffff
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // reference values from zlib's crc32()
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xcbf4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414f_a339);
    }

    #[test]
    fn sensitive_to_single_bit_flips() {
        let data = vec![0xa5u8; 4096];
        let base = crc32(&data);
        for i in [0usize, 1, 2047, 4095] {
            let mut corrupt = data.clone();
            corrupt[i] ^= 1;
            assert_ne!(crc32(&corrupt), base, "flip at {i} undetected");
        }
    }

    #[test]
    fn incremental_consistency() {
        // same bytes, different call patterns, same digest
        let a: Vec<u8> = (0..=255).collect();
        assert_eq!(crc32(&a), crc32(&a.clone()));
    }
}
