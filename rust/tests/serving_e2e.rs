//! Serving-layer integration: context-cache equivalence under load,
//! SIMD on/off numeric agreement, multi-model routing, and throughput
//! sanity on the full engine.

// Soak/e2e scale: far too slow under the Miri interpreter (~1000x);
// the nightly Miri job covers the scalar kernels and unit props
// instead.
#![cfg(not(miri))]

use fwumious::config::{ModelConfig, ServeConfig};
use fwumious::data::synthetic::{DatasetSpec, SyntheticStream};
use fwumious::model::regressor::Regressor;
use fwumious::model::Workspace;
use fwumious::serve::router::Router;
use fwumious::serve::server::ServingEngine;
use fwumious::serve::trace::TraceGenerator;
use fwumious::serve::{ModelHandle, Request};

fn trained(cfg: &ModelConfig, seed: u64, n: usize) -> Regressor {
    let mut reg = Regressor::new(cfg);
    let mut ws = Workspace::new();
    let mut spec = DatasetSpec::tiny();
    spec.cat_fields = cfg.fields - spec.cont_fields;
    let mut s = SyntheticStream::with_buckets(spec, seed, cfg.buckets);
    for _ in 0..n {
        let ex = s.next_example();
        reg.learn(&ex, &mut ws);
    }
    reg
}

#[test]
fn cached_and_uncached_engines_agree() {
    let cfg = ModelConfig::deep_ffm(6, 2, 1 << 10, &[8]);
    let reg = trained(&cfg, 21, 3000);

    let run = |cache: usize, trace_seed: u64| -> Vec<f32> {
        let router = Router::new(2);
        router.register("m", ModelHandle::new(reg.clone()));
        let engine = ServingEngine::start(
            router,
            ServeConfig {
                workers: 2,
                max_batch: 32,
                max_wait_us: 50,
                context_cache_entries: cache,
                max_group_candidates: 1024,
                ..ServeConfig::default()
            },
        );
        let mut gen = TraceGenerator::new(trace_seed, 6, 3, 1 << 10, 4);
        let mut all = Vec::new();
        for _ in 0..300 {
            let resp = engine.score(gen.next_request("m")).unwrap();
            all.extend(resp.scores);
        }
        engine.shutdown();
        all
    };
    let with_cache = run(4096, 5);
    let without = run(0, 5);
    assert_eq!(with_cache.len(), without.len());
    for (a, b) in with_cache.iter().zip(&without) {
        assert!((a - b).abs() < 1e-6, "cache changed scores: {a} vs {b}");
    }
}

#[test]
fn simd_and_scalar_serving_agree() {
    let cfg = ModelConfig::deep_ffm(6, 4, 1 << 10, &[16]);
    let reg = trained(&cfg, 23, 3000);
    let mut gen = TraceGenerator::new(9, 6, 3, 1 << 10, 4);
    let reqs: Vec<Request> = (0..100).map(|_| gen.next_request("m")).collect();

    let run = |scalar: bool| -> Vec<f32> {
        // Scoped forcing: the guard restores the prior (unforced) state
        // even if an assertion below unwinds, so a failed run no longer
        // leaves the WHOLE binary stuck on the scalar path.  It does
        // not serialize against tests running concurrently on other
        // threads — the dispatch atomic is process-global — so those
        // can still observe scalar dispatch for this guard's lifetime
        // (a pre-existing property of ISA forcing, now bounded to this
        // scope instead of leaking forever).
        let _guard = scalar.then(fwumious::simd::ForcedIsaGuard::scalar);
        let mut ws = Workspace::new();
        let mut out = Vec::new();
        for r in &reqs {
            let cp = reg.context_partial(&r.context);
            for c in &r.candidates {
                out.push(reg.predict_with_partial(&cp, c, &mut ws));
            }
        }
        out
    };
    let simd = run(false);
    let scalar = run(true);
    for (a, b) in simd.iter().zip(&scalar) {
        assert!((a - b).abs() < 1e-4, "simd {a} vs scalar {b}");
    }
}

#[test]
fn multi_model_routing() {
    let cfg_a = ModelConfig::ffm(6, 2, 1 << 10);
    let mut cfg_b = cfg_a.clone();
    cfg_b.seed = 4242;
    let reg_a = trained(&cfg_a, 31, 2000);
    let reg_b = trained(&cfg_b, 32, 2000);
    let router = Router::new(2);
    router.register("a", ModelHandle::new(reg_a.clone()));
    router.register("b", ModelHandle::new(reg_b.clone()));
    let engine = ServingEngine::start(
        router,
        ServeConfig { workers: 2, ..Default::default() },
    );
    let mut gen = TraceGenerator::new(10, 6, 3, 1 << 10, 2);
    let mut diffs = 0;
    for _ in 0..100 {
        let mut req = gen.next_request("a");
        let ra = engine.score(req.clone()).unwrap();
        req.model = "b".into();
        let rb = engine.score(req).unwrap();
        if ra
            .scores
            .iter()
            .zip(&rb.scores)
            .any(|(x, y)| (x - y).abs() > 1e-6)
        {
            diffs += 1;
        }
    }
    assert!(diffs > 90, "different models must score differently ({diffs})");
    assert_eq!(engine.shutdown().errors, 0);
}

#[test]
fn engine_sustains_load_across_many_workers() {
    let cfg = ModelConfig::deep_ffm(6, 2, 1 << 12, &[8]);
    let reg = trained(&cfg, 41, 2000);
    let router = Router::new(4);
    router.register("m", ModelHandle::new(reg));
    let engine = ServingEngine::start(
        router,
        ServeConfig {
            workers: 4,
            max_batch: 128,
            max_wait_us: 100,
            context_cache_entries: 8192,
            max_group_candidates: 1024,
            ..ServeConfig::default()
        },
    );
    let mut gen = TraceGenerator::new(12, 6, 3, 1 << 12, 8);
    let n = 2000;
    let t = std::time::Instant::now();
    let mut pending = Vec::new();
    for _ in 0..n {
        pending.push(engine.submit(gen.next_request("m")).unwrap());
        if pending.len() >= 256 {
            for rx in pending.drain(..) {
                rx.recv().unwrap().unwrap();
            }
        }
    }
    for rx in pending {
        rx.recv().unwrap().unwrap();
    }
    let secs = t.elapsed().as_secs_f64();
    let stats = engine.shutdown();
    assert_eq!(stats.requests, n as u64);
    assert_eq!(stats.candidates, (n * 8) as u64);
    assert_eq!(stats.errors, 0);
    assert!(stats.latency.as_ref().unwrap().count() == n as u64);
    // loose sanity: thousands of requests per second even in debug
    assert!(
        (n as f64 / secs) > 500.0,
        "throughput {:.0} req/s suspiciously low",
        n as f64 / secs
    );
}
