//! §4.2 / §4.3 — training throughput: per-example vs minibatch Hogwild.
//!
//! The paper's training story is wall-clock: Hogwild took big models
//! "from multiple weeks to days", and §4.3 places the FLOPs in the deep
//! layers.  This bench measures the batched-training tentpole — the
//! same Hogwild chunk trained example-at-a-time through `learn()` and
//! micro-batch-at-a-time through `learn_batch()`, where the dense
//! neural tower runs on the `simd::batch` GEMM-lite spine
//! (`matmul_rowmajor` forward, `matmul_transposed` / `matmul_xt_dy`
//! backward) and the optimizer applies one summed update per coordinate
//! per micro-batch instead of one per example.  Sparse LR/FFM blocks
//! stay per-example in both arms.
//!
//! Emits machine-readable `BENCH_train_throughput.json` (examples/sec
//! for both arms per thread count, the batched-vs-per-example speedup
//! ratio) so future PRs can diff regressions.  `--smoke` runs a
//! CI-sized variant.

use fwumious::config::ModelConfig;
use fwumious::data::synthetic::{DatasetSpec, SyntheticStream};
use fwumious::feature::Example;
use fwumious::model::regressor::Regressor;
use fwumious::train::hogwild::{train_chunk_batched, HogwildConfig};
use fwumious::util::bench_env;
use fwumious::util::json::{arr, num, obj};

/// Micro-batch size for the batched arm (a 256-example Hogwild slice
/// carves into 32 of these).
const MINIBATCH: usize = 8;

struct Arm {
    examples_per_sec: f64,
    wall_seconds: f64,
}

fn run_arm(cfg: &ModelConfig, data: &[Example], threads: usize, minibatch: usize) -> Arm {
    let mut reg = Regressor::new(cfg);
    // warm-up: page in the weight tables and size the workspaces
    let warm = data.len().min(2_048);
    train_chunk_batched(
        &mut reg,
        &data[..warm],
        HogwildConfig { threads },
        usize::MAX,
        minibatch,
    );
    let stats = train_chunk_batched(
        &mut reg,
        &data[warm..],
        HogwildConfig { threads },
        usize::MAX,
        minibatch,
    );
    assert!(
        reg.pool.weights.iter().all(|w| w.is_finite()),
        "non-finite weights after training (minibatch {minibatch})"
    );
    Arm {
        examples_per_sec: stats.examples as f64 / stats.wall_seconds,
        wall_seconds: stats.wall_seconds,
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let spec = DatasetSpec::criteo_like();
    let buckets = if smoke { 1u32 << 14 } else { 1u32 << 18 };
    let n = if smoke { 24_000 } else { 200_000 };
    // Deep-FFM config: merged_dim 79 into [64, 32] — §4.3's "FLOPs live
    // in the deep layers" regime where the GEMM spine pays off.
    let cfg = ModelConfig::deep_ffm(spec.fields(), 8, buckets, &[64, 32]);
    println!(
        "== Training throughput: per-example vs minibatch (SIMD {}{}) ==\n",
        fwumious::simd::isa_name(),
        if smoke { ", smoke" } else { "" }
    );
    println!(
        "model: DeepFFM {} fields, K={}, hidden {:?}; chunk {} examples, minibatch {}",
        cfg.fields, cfg.latent_dim, cfg.hidden, n, MINIBATCH
    );
    let mut stream = SyntheticStream::with_buckets(spec, 47, buckets);
    let data = stream.take_examples(n);

    let max_threads = std::thread::available_parallelism()
        .map(|p| p.get().min(if smoke { 2 } else { 8 }))
        .unwrap_or(if smoke { 2 } else { 4 });
    println!(
        "\n{:>8} {:>16} {:>16} {:>9}",
        "threads", "per-example ex/s", "batched ex/s", "speedup"
    );
    let mut rows = Vec::new();
    let mut single_thread_speedup = 0f64;
    let mut t = 1usize;
    while t <= max_threads {
        let per = run_arm(&cfg, &data, t, 1);
        let bat = run_arm(&cfg, &data, t, MINIBATCH);
        let speedup = bat.examples_per_sec / per.examples_per_sec;
        if t == 1 {
            single_thread_speedup = speedup;
        }
        println!(
            "{:>8} {:>16.0} {:>16.0} {:>8.2}x",
            t, per.examples_per_sec, bat.examples_per_sec, speedup
        );
        rows.push(obj(vec![
            ("threads", num(t as f64)),
            ("per_example_examples_per_sec", num(per.examples_per_sec)),
            ("batched_examples_per_sec", num(bat.examples_per_sec)),
            ("per_example_wall_seconds", num(per.wall_seconds)),
            ("batched_wall_seconds", num(bat.wall_seconds)),
            ("speedup", num(speedup)),
        ]));
        t *= 2;
    }

    let path = bench_env::write_report(
        "train_throughput",
        smoke,
        vec![
            ("fields", num(cfg.fields as f64)),
            ("latent_dim", num(cfg.latent_dim as f64)),
            ("minibatch", num(MINIBATCH as f64)),
            ("chunk_examples", num(n as f64)),
            ("arms", arr(rows)),
            (
                "speedup_batched_vs_per_example",
                num(single_thread_speedup),
            ),
        ],
    );
    println!("report -> {path}");
    // Documented guarantee (README / ISSUE acceptance): the batched arm
    // clears 1.3x examples/sec over per-example training on the deep
    // config wherever the SIMD kernels are live.  Asserted after the
    // report write so a regression still leaves the numbers on disk.
    // The smoke run only reports the ratio — its chunk is too small to
    // fail CI on shared-runner scheduling jitter rather than on a real
    // regression.
    if smoke || !fwumious::simd::simd_active() {
        println!(
            "(1.3x floor not enforced: {})",
            if smoke { "smoke run" } else { "scalar dispatch host" }
        );
    } else {
        assert!(
            single_thread_speedup >= 1.3,
            "batched training speedup {single_thread_speedup:.2}x below the 1.3x floor"
        );
    }
}
