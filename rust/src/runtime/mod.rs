//! PJRT runtime: load the AOT artifacts produced by `python/compile`
//! (JAX DeepFFM with the Pallas FFM kernel, lowered to HLO text) and
//! execute them on the CPU PJRT client via the `xla` crate.
//!
//! HLO *text* is the interchange format — jax ≥ 0.5 emits protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see DESIGN.md §2 and the aot recipe).
//!
//! Used for (a) the L1==L2==L3 cross-check tests against
//! `artifacts/golden.json` and (b) accelerator-offload deployments of
//! the serving engine.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{parse, Json};

/// One argument slot of an artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct ArgSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "i32"
}

impl ArgSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Manifest entry describing one compiled model variant.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub fields: usize,
    pub latent_dim: usize,
    pub buckets: usize,
    pub hidden: Vec<usize>,
    pub batch: usize,
    pub args: Vec<ArgSpec>,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactSpec>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json", dir.display()))?;
        let v = parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let mut artifacts = Vec::new();
        for a in v.get("artifacts").as_arr().unwrap_or(&[]) {
            let args = a
                .get("args")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|arg| ArgSpec {
                    name: arg.get("name").as_str().unwrap_or("").to_string(),
                    shape: arg
                        .get("shape")
                        .f64_vec()
                        .iter()
                        .map(|&x| x as usize)
                        .collect(),
                    dtype: arg.get("dtype").as_str().unwrap_or("f32").to_string(),
                })
                .collect();
            artifacts.push(ArtifactSpec {
                name: a.get("name").as_str().unwrap_or("").to_string(),
                file: a.get("file").as_str().unwrap_or("").to_string(),
                fields: a.get("fields").as_usize().unwrap_or(0),
                latent_dim: a.get("latent_dim").as_usize().unwrap_or(0),
                buckets: a.get("buckets").as_usize().unwrap_or(0),
                hidden: a
                    .get("hidden")
                    .f64_vec()
                    .iter()
                    .map(|&x| x as usize)
                    .collect(),
                batch: a.get("batch").as_usize().unwrap_or(0),
                args,
            });
        }
        Ok(Manifest { artifacts, dir: dir.to_path_buf() })
    }

    pub fn find(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }
}

/// Concrete argument value for execution.
#[derive(Clone, Debug)]
pub enum ArgValue {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// The PJRT engine: one CPU client, many compiled executables.
pub struct PjrtEngine {
    client: xla::PjRtClient,
}

impl std::fmt::Debug for PjrtEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PjrtEngine").finish_non_exhaustive()
    }
}

impl PjrtEngine {
    pub fn cpu() -> Result<Self> {
        Ok(PjrtEngine { client: xla::PjRtClient::cpu()? })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one artifact.
    pub fn compile(&self, manifest: &Manifest, name: &str) -> Result<CompiledModel> {
        let spec = manifest
            .find(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?
            .clone();
        let path = manifest.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(CompiledModel { spec, exe })
    }
}

/// A compiled model variant, ready to execute.
pub struct CompiledModel {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl std::fmt::Debug for CompiledModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CompiledModel").finish_non_exhaustive()
    }
}

impl CompiledModel {
    /// Execute with positional arguments matching the manifest's arg
    /// specs.  Returns the probability vector `[batch]`.
    pub fn run(&self, args: &[ArgValue]) -> Result<Vec<f32>> {
        if args.len() != self.spec.args.len() {
            bail!(
                "artifact '{}' takes {} args, got {}",
                self.spec.name,
                self.spec.args.len(),
                args.len()
            );
        }
        let mut literals = Vec::with_capacity(args.len());
        for (spec, arg) in self.spec.args.iter().zip(args) {
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            let lit = match (spec.dtype.as_str(), arg) {
                ("f32", ArgValue::F32(v)) => {
                    if v.len() != spec.elements() {
                        bail!(
                            "arg '{}' wants {} elements, got {}",
                            spec.name,
                            spec.elements(),
                            v.len()
                        );
                    }
                    let lit = xla::Literal::vec1(v);
                    if dims.len() > 1 { lit.reshape(&dims)? } else { lit }
                }
                ("i32", ArgValue::I32(v)) => {
                    if v.len() != spec.elements() {
                        bail!(
                            "arg '{}' wants {} elements, got {}",
                            spec.name,
                            spec.elements(),
                            v.len()
                        );
                    }
                    let lit = xla::Literal::vec1(v);
                    if dims.len() > 1 { lit.reshape(&dims)? } else { lit }
                }
                (dt, _) => bail!("arg '{}' dtype mismatch ({dt})", spec.name),
            };
            literals.push(lit);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True -> unwrap the 1-tuple.
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

/// Default artifact directory (crate root / artifacts).
pub fn default_artifact_dir() -> PathBuf {
    PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
}

/// Golden vectors exported by `python/compile/golden.py`.
#[derive(Clone, Debug)]
pub struct Golden {
    pub name: String,
    pub fields: usize,
    pub latent_dim: usize,
    pub buckets: usize,
    pub hidden: Vec<usize>,
    pub batch: usize,
    pub lr_table: Vec<f32>,
    pub ffm_table: Vec<f32>,
    pub mlp: Vec<Vec<f32>>,
    pub idx: Vec<i32>,
    pub vals: Vec<f32>,
    pub probs: Vec<f32>,
}

/// Load `artifacts/golden.json`.
pub fn load_goldens(dir: &Path) -> Result<Vec<Golden>> {
    let text = std::fs::read_to_string(dir.join("golden.json"))
        .with_context(|| format!("reading {}/golden.json", dir.display()))?;
    let v = parse(&text).map_err(|e| anyhow!("golden parse: {e}"))?;
    let f32s = |j: &Json| -> Vec<f32> { j.f64_vec().iter().map(|&x| x as f32).collect() };
    let mut out = Vec::new();
    for g in v.as_arr().unwrap_or(&[]) {
        out.push(Golden {
            name: g.get("name").as_str().unwrap_or("").to_string(),
            fields: g.get("fields").as_usize().unwrap_or(0),
            latent_dim: g.get("latent_dim").as_usize().unwrap_or(0),
            buckets: g.get("buckets").as_usize().unwrap_or(0),
            hidden: g.get("hidden").f64_vec().iter().map(|&x| x as usize).collect(),
            batch: g.get("batch").as_usize().unwrap_or(0),
            lr_table: f32s(g.get("lr_table")),
            ffm_table: f32s(g.get("ffm_table")),
            mlp: g
                .get("mlp")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(f32s)
                .collect(),
            idx: g.get("idx").f64_vec().iter().map(|&x| x as i32).collect(),
            vals: f32s(g.get("vals")),
            probs: f32s(g.get("probs")),
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_ready() -> bool {
        default_artifact_dir().join("manifest.json").exists()
    }

    #[test]
    fn manifest_parses() {
        if !artifacts_ready() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Manifest::load(&default_artifact_dir()).unwrap();
        assert!(m.artifacts.len() >= 3);
        let a = &m.artifacts[0];
        assert_eq!(a.args.first().unwrap().name, "lr_table");
        assert_eq!(a.args.last().unwrap().name, "vals");
        assert!(m.find(&a.name).is_some());
        assert!(m.find("nonexistent").is_none());
    }

    #[test]
    fn goldens_parse() {
        if !artifacts_ready() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let gs = load_goldens(&default_artifact_dir()).unwrap();
        assert_eq!(gs.len(), 2);
        let g = &gs[0];
        assert_eq!(g.probs.len(), g.batch);
        assert_eq!(g.lr_table.len(), g.buckets);
        assert_eq!(g.idx.len(), g.batch * g.fields);
    }

    // Full PJRT execution is exercised by rust/tests/pjrt_cross_check.rs
    // (integration test) to keep unit-test cycles fast.
}
