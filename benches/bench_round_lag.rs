//! Round-lag benchmark for the online deployment plane (§3 + §6).
//!
//! The operational metric of the always-online regime is the *publish
//! lag*: how long after a training round closes is the serving layer
//! scoring on the new weights?  lag = encode + wire + decode + swap.
//! This bench drives [`fwumious::deploy::DeploymentLoop`] through
//! steady-state rounds under each of the four Table-4 wire modes and
//! reports the per-stage breakdown plus the bandwidth bill.
//!
//! Paper-shaped expectation: quantization + patching cut both bytes on
//! the wire and wire seconds by ~an order of magnitude at the cost of
//! milliseconds of encode/decode — so the lag is dominated by the link
//! for Raw and by (cheap) CPU work for QuantPatch.
//!
//! Emits `BENCH_round_lag.json` (per mode: median bytes/round, lag
//! p50/p90/max) for regression tracking; `--smoke` runs a CI-sized
//! variant.

use fwumious::config::{ModelConfig, ServeConfig};
use fwumious::data::synthetic::DatasetSpec;
use fwumious::deploy::{DeployConfig, DeploymentLoop};
use fwumious::transfer::UpdateMode;
use fwumious::util::bench_env;
use fwumious::util::json::{arr, num, obj, s};
use fwumious::util::math::{median, percentile};

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (rounds, per_round, bits) = if smoke { (3, 4_000, 14) } else { (6, 20_000, 18) };
    let spec = DatasetSpec::criteo_like();
    let buckets = 1u32 << bits;
    let model = ModelConfig::deep_ffm(spec.fields(), 4, buckets, &[16]);
    let threads = std::thread::available_parallelism()
        .map(|n| n.get().min(4))
        .unwrap_or(2);

    println!(
        "== round lag: train {} examples/round, {} rounds/mode, {} hogwild thread(s), 1 Gbps link{} ==\n",
        per_round,
        rounds,
        threads,
        if smoke { " (smoke)" } else { "" }
    );
    println!(
        "{:<28} {:>10} {:>9} {:>9} {:>9} {:>9} {:>10}",
        "mode", "update(B)", "%raw", "encode", "wire", "apply", "lag(s)"
    );

    let mut mode_rows = Vec::new();
    for mode in UpdateMode::ALL {
        let mut cfg = DeployConfig::new(model.clone(), spec.clone(), mode);
        cfg.examples_per_round = per_round;
        cfg.train_threads = threads;
        cfg.holdout_examples = 0; // lag only; skip AUC evaluation
        cfg.serve = ServeConfig { workers: 2, ..Default::default() };
        let mut dl = DeploymentLoop::new(cfg);

        let mut update_bytes = Vec::new();
        let mut encode_s = Vec::new();
        let mut wire_s = Vec::new();
        let mut apply_s = Vec::new();
        let mut lag_s = Vec::new();
        let mut raw_bytes = 0usize;
        for r in 0..rounds {
            let rep = dl.run_round().expect("round failed");
            if r == 0 {
                continue; // bootstrap round ships full files in patch modes
            }
            update_bytes.push(rep.update_bytes as f64);
            encode_s.push(rep.encode_seconds);
            wire_s.push(rep.wire_seconds);
            apply_s.push(rep.apply_seconds);
            lag_s.push(rep.lag_seconds);
            raw_bytes = rep.raw_bytes;
        }
        println!(
            "{:<28} {:>10.0} {:>8.2}% {:>7.1}ms {:>8.4} {:>7.1}ms {:>10.4}",
            mode.label(),
            median(&update_bytes),
            median(&update_bytes) / raw_bytes as f64 * 100.0,
            median(&encode_s) * 1e3,
            median(&wire_s),
            median(&apply_s) * 1e3,
            median(&lag_s)
        );
        mode_rows.push(obj(vec![
            ("mode", s(mode.label())),
            ("bytes_per_round_median", num(median(&update_bytes))),
            ("raw_bytes", num(raw_bytes as f64)),
            ("encode_seconds_median", num(median(&encode_s))),
            ("wire_seconds_median", num(median(&wire_s))),
            ("apply_seconds_median", num(median(&apply_s))),
            ("lag_seconds_p50", num(percentile(&lag_s, 0.5))),
            ("lag_seconds_p90", num(percentile(&lag_s, 0.9))),
            ("lag_seconds_max", num(percentile(&lag_s, 1.0))),
        ]));
        dl.shutdown();
    }

    let path = bench_env::write_report(
        "round_lag",
        smoke,
        vec![
            ("rounds", num(rounds as f64)),
            ("examples_per_round", num(per_round as f64)),
            ("train_threads", num(threads as f64)),
            ("modes", arr(mode_rows)),
        ],
    );
    println!(
        "\nexpected shape: raw lag ≈ full-file wire time; quant ≈ half of it;"
    );
    println!("patch modes collapse steady-state wire time — lag becomes CPU-bound.");
    println!("report -> {path}");
}
