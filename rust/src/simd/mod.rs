//! §5 — SIMD-instruction-aware forward pass.
//!
//! "These hardware instruction level optimizations needed to be
//! carefully implemented as the space of serving hardware is not
//! homogeneous, meaning that on-the-fly instruction detection, and
//! subsequent utilization of appropriate binary needed to be put in
//! place."
//!
//! This module implements exactly that: the hot kernels (dot products,
//! axpy, dense matvec, the FFM pairwise inner loop) exist in a scalar
//! form and an AVX2+FMA form, and a process-wide dispatch decision is
//! taken once at startup via `is_x86_feature_detected!`.  Benchmarks
//! (Figure 5) can force the scalar path through [`force_scalar`].

pub mod batch;
pub mod dot;

use std::sync::atomic::{AtomicU8, Ordering};

/// Selected instruction set.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IsaLevel {
    Scalar = 0,
    Avx2Fma = 1,
}

const UNSET: u8 = u8::MAX;
static FORCED: AtomicU8 = AtomicU8::new(UNSET);
static RESOLVED: AtomicU8 = AtomicU8::new(UNSET);

/// Detect the best ISA available on this machine (honouring any
/// force).  The CPUID probe runs once; afterwards this is a single
/// relaxed atomic load — cheap enough for per-kernel dispatch.
#[inline]
pub fn isa_level() -> IsaLevel {
    match FORCED.load(Ordering::Relaxed) {
        0 => return IsaLevel::Scalar,
        1 => return IsaLevel::Avx2Fma,
        _ => {}
    }
    let r = RESOLVED.load(Ordering::Relaxed);
    if r != UNSET {
        return if r == 1 { IsaLevel::Avx2Fma } else { IsaLevel::Scalar };
    }
    let d = detect();
    RESOLVED.store(d as u8, Ordering::Relaxed);
    d
}

fn detect() -> IsaLevel {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
        {
            return IsaLevel::Avx2Fma;
        }
    }
    IsaLevel::Scalar
}

/// Force a specific ISA level (Figure 5's SIMD-disabled control runs).
pub fn force_scalar(on: bool) {
    FORCED.store(
        if on { IsaLevel::Scalar as u8 } else { UNSET },
        Ordering::Relaxed,
    );
}

/// True when the AVX2+FMA path is live.
pub fn simd_active() -> bool {
    isa_level() == IsaLevel::Avx2Fma
}

/// Human-readable description for logs/metrics.
pub fn isa_name() -> &'static str {
    match isa_level() {
        IsaLevel::Scalar => "scalar",
        IsaLevel::Avx2Fma => "avx2+fma",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn force_scalar_round_trip() {
        force_scalar(true);
        assert_eq!(isa_level(), IsaLevel::Scalar);
        force_scalar(false);
        let _ = isa_level(); // whatever the host supports
    }

    #[test]
    fn isa_name_nonempty() {
        assert!(!isa_name().is_empty());
    }
}
