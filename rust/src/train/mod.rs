//! Training jobs: single-pass online trainer, §4.2 Hogwild
//! multithreaded trainer, and the §4.1 prefetched warm-up driver.

pub mod hogwild;
pub mod warmup;

use crate::eval::RollingAuc;
use crate::feature::Example;
use crate::model::regressor::Regressor;
use crate::model::Workspace;

/// Single-threaded online trainer with progressive validation.
pub struct Trainer {
    pub reg: Regressor,
    pub ws: Workspace,
    pub eval: RollingAuc,
    pub examples_seen: usize,
}

impl std::fmt::Debug for Trainer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Trainer").finish_non_exhaustive()
    }
}

impl Trainer {
    pub fn new(reg: Regressor) -> Self {
        Self::with_window(reg, 30_000)
    }

    /// `window` — rolling-AUC window (the paper uses 30k).
    pub fn with_window(reg: Regressor, window: usize) -> Self {
        Trainer {
            reg,
            ws: Workspace::new(),
            eval: RollingAuc::new(window),
            examples_seen: 0,
        }
    }

    /// Learn one example; returns the progressive-validation score.
    #[inline]
    pub fn learn(&mut self, ex: &Example) -> f32 {
        let p = self.reg.learn(ex, &mut self.ws);
        self.eval.add(p, ex.label);
        self.examples_seen += 1;
        p
    }

    /// Learn a chunk.
    pub fn learn_chunk(&mut self, chunk: &[Example]) {
        for ex in chunk {
            self.learn(ex);
        }
    }

    /// Learn a chunk through `minibatch`-example micro-batches on the
    /// batched GEMM-lite spine ([`Regressor::learn_batch`]).
    /// `minibatch <= 1` is the per-example loop (bit-identical).
    pub fn learn_chunk_batched(&mut self, chunk: &[Example], minibatch: usize) {
        if minibatch <= 1 {
            self.learn_chunk(chunk);
            return;
        }
        let mut scores = Vec::new();
        for mb in chunk.chunks(minibatch) {
            self.reg.learn_batch(mb, &mut self.ws, &mut scores);
            for (&p, ex) in scores.iter().zip(mb) {
                self.eval.add(p, ex.label);
            }
            self.examples_seen += mb.len();
        }
    }

    /// Micro-batch size for held-out evaluation: big enough to keep the
    /// GEMM spine fed, small enough that the batch-strided workspace
    /// stays cache-resident.
    pub const EVAL_BATCH: usize = 256;

    /// Evaluate (without learning) on a held-out slice; returns AUC.
    ///
    /// Scoring runs through [`Regressor::predict_batch`]'s GEMM spine
    /// in [`EVAL_BATCH`](Self::EVAL_BATCH)-example micro-batches (the
    /// ROADMAP "batched evaluation" follow-on of the batched-training
    /// PR) instead of one `predict` call per example.  For Linear/FFM
    /// every per-row operation is literally the per-example sequence,
    /// so the AUC is bit-equal to the per-example loop; for DeepFFM the
    /// dense tower runs the batched GEMM (`matmul_rowmajor`) instead of
    /// the single-vector matvec — same math, different accumulation
    /// order — so scores agree to ~1e-6 and the rank-based AUC is
    /// equal unless two holdout scores near-tie at that resolution.
    /// `batched_eval_auc_matches_per_example` pins both contracts.
    pub fn test_auc(&mut self, test: &[Example]) -> f64 {
        let mut scores = Vec::with_capacity(test.len());
        let mut labels = Vec::with_capacity(test.len());
        let mut chunk = Vec::new();
        for mb in test.chunks(Self::EVAL_BATCH) {
            self.reg.predict_batch(mb, &mut self.ws, &mut chunk);
            scores.extend_from_slice(&chunk);
            labels.extend(mb.iter().map(|ex| ex.label));
        }
        crate::eval::auc(&scores, &labels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::data::synthetic::{DatasetSpec, SyntheticStream};

    #[test]
    fn trainer_improves_over_stream() {
        let cfg = ModelConfig::ffm(4, 2, 256);
        let mut t = Trainer::with_window(Regressor::new(&cfg), 2000);
        let mut s = SyntheticStream::with_buckets(DatasetSpec::tiny(), 5, 256);
        for _ in 0..16_000 {
            let ex = s.next_example();
            t.learn(&ex);
        }
        assert_eq!(t.examples_seen, 16_000);
        let pts = &t.eval.points;
        assert!(pts.len() >= 7);
        let early = pts[0];
        let late = pts[pts.len() - 1];
        assert!(late > early, "late {late} <= early {early}");
    }

    #[test]
    fn batched_trainer_improves_over_stream() {
        let cfg = ModelConfig::deep_ffm(4, 2, 256, &[8]);
        let mut t = Trainer::with_window(Regressor::new(&cfg), 2000);
        let mut s = SyntheticStream::with_buckets(DatasetSpec::tiny(), 15, 256);
        let chunk: Vec<_> = (0..16_000).map(|_| s.next_example()).collect();
        t.learn_chunk_batched(&chunk, 8);
        assert_eq!(t.examples_seen, 16_000);
        let pts = &t.eval.points;
        assert!(pts.len() >= 7);
        assert!(
            pts[pts.len() - 1] > pts[0],
            "late {} <= early {}",
            pts[pts.len() - 1],
            pts[0]
        );
    }

    #[test]
    fn batched_eval_auc_matches_per_example() {
        // The batched GEMM-spine evaluation must be invisible in the
        // number, on a holdout that is NOT a multiple of EVAL_BATCH so
        // the remainder micro-batch path runs too.  Linear/FFM rows go
        // through literally the per-example code, so their AUC is
        // pinned BIT-equal.  DeepFFM's dense tower runs the batched
        // GEMM instead of the single-vector matvec (different
        // accumulation order, scores agree to ~1e-6, ranks only flip
        // on a near-tie at that resolution), so its AUC is pinned to
        // within one rank step rather than asserted bit-equal — exact
        // equality there would hinge on the seed producing no
        // near-ties.
        use crate::config::Architecture;
        for arch in [Architecture::Linear, Architecture::Ffm, Architecture::DeepFfm] {
            let cfg = match arch {
                Architecture::Linear => ModelConfig::linear(4, 256),
                Architecture::Ffm => ModelConfig::ffm(4, 2, 256),
                Architecture::DeepFfm => ModelConfig::deep_ffm(4, 2, 256, &[8]),
            };
            let mut t = Trainer::new(Regressor::new(&cfg));
            let mut s = SyntheticStream::with_buckets(DatasetSpec::tiny(), 61, 256);
            for _ in 0..3000 {
                let ex = s.next_example();
                t.learn(&ex);
            }
            let n = Trainer::EVAL_BATCH + 77;
            let test: Vec<_> = (0..n).map(|_| s.next_example()).collect();
            let batched = t.test_auc(&test);
            let mut scores = Vec::new();
            let mut labels = Vec::new();
            for ex in &test {
                scores.push(t.reg.predict(ex, &mut t.ws));
                labels.push(ex.label);
            }
            let per_example = crate::eval::auc(&scores, &labels);
            if arch == Architecture::DeepFfm {
                // one flipped pair moves AUC by exactly 1/(pos*neg);
                // allow a couple of flips
                let pos = labels.iter().filter(|&&y| y > 0.5).count();
                let rank_step = 1.0 / (pos * (n - pos)) as f64;
                assert!(
                    (batched - per_example).abs() <= 2.0 * rank_step,
                    "{arch:?}: batched {batched} vs per-example {per_example}"
                );
            } else {
                assert_eq!(
                    batched, per_example,
                    "{arch:?}: batched eval AUC diverged from per-example"
                );
            }
        }
    }

    #[test]
    fn test_auc_does_not_learn() {
        let cfg = ModelConfig::ffm(4, 2, 256);
        let mut t = Trainer::new(Regressor::new(&cfg));
        let mut s = SyntheticStream::with_buckets(DatasetSpec::tiny(), 6, 256);
        for _ in 0..4000 {
            let ex = s.next_example();
            t.learn(&ex);
        }
        let test: Vec<_> = (0..2000).map(|_| s.next_example()).collect();
        let w_before = t.reg.pool.weights.clone();
        let a1 = t.test_auc(&test);
        let a2 = t.test_auc(&test);
        assert_eq!(a1, a2);
        assert_eq!(t.reg.pool.weights, w_before);
        assert!(a1 > 0.55, "test auc {a1}");
    }
}
