//! Parser for the VW-inspired text input format.
//!
//! Grammar (one example per line):
//!
//! ```text
//! [label] [importance] |NS tok[:val] tok[:val] ... |NS2 tok ...
//! ```
//!
//! * `label` — `1`/`0` (also accepts `-1` as 0, VW convention).
//! * `importance` — optional positive float.
//! * `|NS` — namespace group; `NS` must exist in the [`Schema`].
//! * `tok:val` — feature token with explicit value; bare tokens get the
//!   namespace transform's default treatment.
//!
//! One feature per field is kept (production layout): if a namespace
//! repeats or lists several tokens, the *last* one wins.

use crate::feature::hash;
use crate::feature::namespace::{Schema, Transform};
use crate::feature::{Example, FeatureSlot};

/// Parse error with line context.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "parse error: {}", self.msg)
    }
}

impl std::error::Error for ParseError {}

fn err(msg: impl Into<String>) -> ParseError {
    ParseError { msg: msg.into() }
}

/// Streaming parser bound to a schema and a bucket mask.
#[derive(Clone, Debug)]
pub struct VwParser {
    schema: Schema,
    mask: u32,
}

impl VwParser {
    /// `buckets` must be a power of two.
    pub fn new(schema: Schema, buckets: u32) -> Self {
        assert!(buckets.is_power_of_two(), "bucket count must be 2^n");
        VwParser { schema, mask: buckets - 1 }
    }

    pub fn schema(&self) -> &Schema {
        &self.schema
    }

    /// Parse one line into an [`Example`].
    pub fn parse_line(&self, line: &str) -> Result<Example, ParseError> {
        let mut ex = Example::empty(self.schema.fields());
        let mut rest = line.trim();
        if rest.is_empty() {
            return Err(err("empty line"));
        }

        // Header (before the first '|'): label [importance]
        let bar = rest.find('|');
        let header = match bar {
            Some(i) => &rest[..i],
            None => rest,
        };
        let mut htoks = header.split_ascii_whitespace();
        if let Some(lab) = htoks.next() {
            ex.label = match lab {
                "1" | "1.0" | "+1" => 1.0,
                "0" | "0.0" | "-1" => 0.0,
                other => other
                    .parse::<f32>()
                    .map_err(|_| err(format!("bad label '{other}'")))
                    .map(|v| if v > 0.0 { 1.0 } else { 0.0 })?,
            };
        }
        if let Some(imp) = htoks.next() {
            let w: f32 = imp
                .parse()
                .map_err(|_| err(format!("bad importance '{imp}'")))?;
            if w <= 0.0 {
                return Err(err("importance must be positive"));
            }
            ex.importance = w;
        }
        if htoks.next().is_some() {
            return Err(err("too many header tokens"));
        }

        rest = match bar {
            Some(i) => &rest[i..],
            None => return Ok(ex), // label-only line
        };

        // Namespace groups.
        for group in rest.split('|').skip(1) {
            let mut toks = group.split_ascii_whitespace();
            let ns_name = toks.next().ok_or_else(|| err("empty namespace"))?;
            let ns = self
                .schema
                .by_name(ns_name)
                .ok_or_else(|| err(format!("unknown namespace '{ns_name}'")))?;
            for tok in toks {
                let (name, raw_val) = match tok.split_once(':') {
                    Some((n, v)) => {
                        let val: f32 = v
                            .parse()
                            .map_err(|_| err(format!("bad value in '{tok}'")))?;
                        (n, val)
                    }
                    None => (tok, 1.0),
                };
                let (token_id, value) = match ns.transform {
                    // Categorical: the token string is the identity.
                    Transform::Categorical => (name.to_string(), 1.0),
                    // Continuous: token names the feature, value is
                    // transformed.
                    t => (name.to_string(), t.apply(raw_val)),
                };
                let bucket = hash::feature_bucket(ns.seed, &token_id, self.mask);
                ex.slots[ns.field as usize] =
                    FeatureSlot { field: ns.field, bucket, value };
            }
        }
        Ok(ex)
    }

    /// Parse many lines, skipping (counting) bad ones.
    pub fn parse_lines(&self, text: &str) -> (Vec<Example>, usize) {
        let mut out = Vec::new();
        let mut bad = 0;
        for line in text.lines() {
            if line.trim().is_empty() {
                continue;
            }
            match self.parse_line(line) {
                Ok(ex) => out.push(ex),
                Err(_) => bad += 1,
            }
        }
        (out, bad)
    }
}

/// Serialize an example back to vw-format (for datagen / debugging).
/// Buckets are emitted as `h<bucket>` tokens — hashing is not inverted.
pub fn to_vw_line(ex: &Example, schema: &Schema) -> String {
    let mut s = String::new();
    if ex.is_labeled() {
        s.push_str(if ex.label > 0.5 { "1" } else { "0" });
        if ex.importance != 1.0 {
            s.push_str(&format!(" {}", ex.importance));
        }
    }
    for slot in &ex.slots {
        if slot.value == 0.0 {
            continue;
        }
        let ns = &schema.namespaces[slot.field as usize];
        if slot.value == 1.0 {
            s.push_str(&format!(" |{} h{}", ns.name, slot.bucket));
        } else {
            s.push_str(&format!(" |{} h{}:{}", ns.name, slot.bucket, slot.value));
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::feature::namespace::Schema;

    fn parser() -> VwParser {
        VwParser::new(Schema::categorical(&["A", "B", "C"]), 1 << 10)
    }

    #[test]
    fn parses_basic_line() {
        let ex = parser().parse_line("1 |A user5 |B ad9").unwrap();
        assert_eq!(ex.label, 1.0);
        assert_eq!(ex.importance, 1.0);
        assert!(ex.slots[0].value == 1.0);
        assert!(ex.slots[1].value == 1.0);
        assert!(ex.slots[2].value == 0.0); // C absent
    }

    #[test]
    fn negative_label_maps_to_zero() {
        assert_eq!(parser().parse_line("-1 |A x").unwrap().label, 0.0);
    }

    #[test]
    fn importance_parsed() {
        let ex = parser().parse_line("0 2.5 |A x").unwrap();
        assert_eq!(ex.importance, 2.5);
        assert!(parser().parse_line("0 -1.0 |A x").is_err());
    }

    #[test]
    fn unknown_namespace_rejected() {
        assert!(parser().parse_line("1 |Z x").is_err());
    }

    #[test]
    fn values_and_transforms() {
        let schema = Schema::ctr_style(1, 1); // I1 log1p, C1 categorical
        let p = VwParser::new(schema, 1 << 10);
        let ex = p.parse_line("1 |I1 price:7.389056 |C1 tok:9").unwrap();
        // log1p(7.389056) = ln(8.389056) ≈ 2.1269
        assert!((ex.slots[0].value - (1f32 + 7.389056).ln()).abs() < 1e-5);
        assert_eq!(ex.slots[1].value, 1.0); // categorical forces 1.0
    }

    #[test]
    fn last_token_wins_within_namespace() {
        let a = parser().parse_line("1 |A first second").unwrap();
        let b = parser().parse_line("1 |A second").unwrap();
        assert_eq!(a.slots[0], b.slots[0]);
    }

    #[test]
    fn same_token_same_bucket_across_lines() {
        let a = parser().parse_line("1 |A user5").unwrap();
        let b = parser().parse_line("0 |A user5 |B x").unwrap();
        assert_eq!(a.slots[0].bucket, b.slots[0].bucket);
    }

    #[test]
    fn unlabeled_line_for_serving() {
        let ex = parser().parse_line("|A u1 |B a2").unwrap();
        assert!(!ex.is_labeled());
    }

    #[test]
    fn parse_lines_counts_bad() {
        let (exs, bad) = parser().parse_lines("1 |A x\n\n1 |Q y\n0 |B z\n");
        assert_eq!(exs.len(), 2);
        assert_eq!(bad, 1);
    }

    #[test]
    fn roundtrip_through_vw_line() {
        let p = parser();
        let ex = p.parse_line("1 |A u7 |C c3").unwrap();
        let line = to_vw_line(&ex, p.schema());
        let re = p.parse_line(&line).unwrap();
        assert_eq!(re.label, ex.label);
        // bucket identity survives the h<bucket> re-hash only as a
        // deterministic mapping; values/fields must match exactly
        assert_eq!(re.slots.len(), ex.slots.len());
        assert_eq!(re.slots[1].value, 0.0);
    }

    #[test]
    fn bad_value_rejected() {
        assert!(parser().parse_line("1 |A x:notanumber").is_err());
    }
}
