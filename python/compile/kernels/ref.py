"""Pure-jnp oracle for the field-aware FFM interaction (DiagMask variant).

This is the correctness reference for the Pallas kernel in
``ffm_interaction.py`` and (via exported golden vectors) for the Rust
native forward pass.  Semantics follow §2.1 of the paper:

    FFM(w, x) = sum_{j1 < j2} <w_{j1,f(j2)}, w_{j2,f(j1)}> * x_{j1} x_{j2}

with one feature per field (the production layout of Fwumious Wabbit),
so f(j) == j and the latent tensor for one example is ``emb[F, F, K]``
where ``emb[i, g, :]`` is the latent vector of the feature in field ``i``
used when interacting with field ``g``.

The *DiagMask* keeps only the strict upper triangle (i < j), halving the
number of pair combinations that downstream blocks must process.  The
kernel therefore emits the full ``[F, F]`` interaction matrix with the
lower triangle and diagonal zeroed; the model flattens the upper
triangle into the MergeNormLayer input.
"""

from __future__ import annotations

import jax.numpy as jnp


def ffm_interaction_ref(emb: jnp.ndarray, vals: jnp.ndarray) -> jnp.ndarray:
    """Reference field-aware interaction.

    Args:
      emb:  [B, F, F, K] latent vectors; emb[b, i, g] = latents of the
            field-i feature oriented toward field g.
      vals: [B, F] feature values (1.0 for plain categorical one-hots).

    Returns:
      [B, F, F] with out[b, i, j] = <emb[b,i,j], emb[b,j,i]> * x_i * x_j
      for i < j, zero elsewhere (DiagMask).
    """
    b, f, f2, k = emb.shape
    assert f == f2, "latent tensor must be [B, F, F, K]"
    # <emb[b,i,j,:], emb[b,j,i,:]>  -> einsum over k with transposed fields
    dots = jnp.einsum("bijk,bjik->bij", emb, emb)
    xx = vals[:, :, None] * vals[:, None, :]  # [B, F, F]
    mask = jnp.triu(jnp.ones((f, f), dtype=emb.dtype), k=1)
    return dots * xx * mask[None, :, :]


def ffm_scalar_ref(emb: jnp.ndarray, vals: jnp.ndarray) -> jnp.ndarray:
    """Scalar FFM output: sum of the masked pair interactions. [B]."""
    return ffm_interaction_ref(emb, vals).sum(axis=(1, 2))


def triu_flatten(pair_mat: jnp.ndarray) -> jnp.ndarray:
    """Flatten the strict upper triangle of [B, F, F] into [B, F*(F-1)/2].

    Row-major order: (0,1), (0,2), ..., (0,F-1), (1,2), ...  This order is
    part of the cross-layer ABI — rust/src/model/block_ffm.rs emits pair
    outputs in the same order.
    """
    b, f, _ = pair_mat.shape
    iu = jnp.triu_indices(f, k=1)
    return pair_mat[:, iu[0], iu[1]]
