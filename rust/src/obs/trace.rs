//! Sampled structured tracing: a 1-in-N request sampler plus discrete
//! system events, emitted as one JSON object per line (JSONL).
//!
//! Sampling is a single relaxed `fetch_add` per submit; unsampled
//! requests pay nothing else. Only sampled requests (and low-rate
//! discrete events like overload transitions, fleet catch-ups, and
//! deploy swaps) reach the sink, so the sink's mutex is statistically
//! off the hot path.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::json::Json;

#[derive(Debug)]
enum SinkInner {
    Stderr,
    File(Mutex<BufWriter<File>>),
    Memory(Mutex<Vec<String>>),
}

/// Where trace lines go. Cloning shares the sink.
#[derive(Clone, Debug)]
pub struct TraceSink(Arc<SinkInner>);

impl TraceSink {
    pub fn stderr() -> Self {
        TraceSink(Arc::new(SinkInner::Stderr))
    }

    pub fn file(path: &str) -> std::io::Result<Self> {
        let f = File::create(path)?;
        Ok(TraceSink(Arc::new(SinkInner::File(Mutex::new(
            BufWriter::new(f),
        )))))
    }

    /// In-memory sink for tests; read back with `drain`.
    pub fn memory() -> Self {
        TraceSink(Arc::new(SinkInner::Memory(Mutex::new(Vec::new()))))
    }

    pub fn emit(&self, event: &Json) {
        let line = event.to_string();
        match &*self.0 {
            SinkInner::Stderr => eprintln!("{line}"),
            SinkInner::File(w) => {
                let mut w = w.lock().unwrap();
                let _ = writeln!(w, "{line}");
            }
            SinkInner::Memory(v) => v.lock().unwrap().push(line),
        }
    }

    pub fn flush(&self) {
        if let SinkInner::File(w) = &*self.0 {
            let _ = w.lock().unwrap().flush();
        }
    }

    /// Take every line captured so far (memory sinks only; other sinks
    /// return an empty vec).
    pub fn drain(&self) -> Vec<String> {
        match &*self.0 {
            SinkInner::Memory(v) => std::mem::take(&mut *v.lock().unwrap()),
            _ => Vec::new(),
        }
    }
}

/// 1-in-N request sampler + event emitter. Cloning shares the counter
/// and sink, so every submit path sees one global sample cadence.
#[derive(Clone, Debug)]
pub struct RequestTracer {
    every: u64,
    counter: Arc<AtomicU64>,
    sink: TraceSink,
}

impl RequestTracer {
    /// `every == 0` disables request sampling entirely (discrete
    /// events still flow — they are low-rate by construction).
    pub fn new(every: u64, sink: TraceSink) -> Self {
        RequestTracer {
            every,
            counter: Arc::new(AtomicU64::new(0)),
            sink,
        }
    }

    /// Decide whether this request is sampled; returns its trace id if
    /// so. Costs one relaxed `fetch_add` either way.
    pub fn try_sample(&self) -> Option<u64> {
        if self.every == 0 {
            return None;
        }
        // ordering: Relaxed — the counter only spaces samples; exact
        // cross-thread spacing is not required and nothing else is
        // published through it.
        let n = self.counter.fetch_add(1, Ordering::Relaxed);
        (n % self.every == 0).then_some(n)
    }

    /// Emit one JSONL event (caller builds the object with
    /// `util::json` builders).
    pub fn emit(&self, event: &Json) {
        self.sink.emit(event);
    }

    pub fn flush(&self) {
        self.sink.flush();
    }

    pub fn sink(&self) -> &TraceSink {
        &self.sink
    }
}

/// FNV-1a over raw bytes — used to turn an exact context-group key
/// into a compact, log-safe hash for trace events.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::{num, obj, s};

    #[test]
    fn one_in_n_sampling_is_exact() {
        let t = RequestTracer::new(3, TraceSink::memory());
        let sampled: Vec<_> = (0..30).filter_map(|_| t.try_sample()).collect();
        assert_eq!(sampled.len(), 10);
        assert_eq!(sampled[0], 0);
        assert_eq!(sampled[1], 3);
    }

    #[test]
    fn zero_disables_sampling() {
        let t = RequestTracer::new(0, TraceSink::memory());
        assert!((0..100).filter_map(|_| t.try_sample()).next().is_none());
    }

    #[test]
    fn memory_sink_captures_jsonl() {
        let sink = TraceSink::memory();
        let t = RequestTracer::new(1, sink.clone());
        t.emit(&obj(vec![
            ("event", s("stage")),
            ("trace", num(7.0)),
            ("ns", num(123.0)),
        ]));
        let lines = sink.drain();
        assert_eq!(lines.len(), 1);
        let parsed = crate::util::json::parse(&lines[0]).expect("valid json");
        assert_eq!(parsed.get("event").as_str(), Some("stage"));
    }

    #[test]
    fn fnv_is_stable_and_spreads() {
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_ne!(fnv1a64(b"model-a|ctx1"), fnv1a64(b"model-a|ctx2"));
    }
}
