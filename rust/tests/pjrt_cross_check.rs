//! The three-layer equivalence triangle (DESIGN.md §2):
//!
//!   L1 (Pallas kernel) == L2 (JAX model)  — checked by pytest
//!   L2 (JAX model)     == golden vectors  — `python -m compile.golden`
//!   golden             == PJRT execution  — `pjrt_matches_golden`
//!   golden             == Rust native     — `native_forward_matches_golden`
//!
//! Passing all four proves the Rust serving hot path computes exactly
//! the same function as the JAX/Pallas definition, and that the AOT
//! artifact loaded through the xla crate is faithful.
//!
//! Requires `make artifacts` (tests self-skip when artifacts are absent)
//! and a build with `--features pjrt` (see rust/Cargo.toml — the target
//! declares `required-features = ["pjrt"]`).

#![cfg(feature = "pjrt")]

use fwumious::config::ModelConfig;
use fwumious::feature::{Example, FeatureSlot};
use fwumious::model::regressor::Regressor;
use fwumious::model::Workspace;
use fwumious::runtime::{
    default_artifact_dir, load_goldens, ArgValue, Golden, Manifest, PjrtEngine,
};

fn artifacts_ready() -> bool {
    default_artifact_dir().join("golden.json").exists()
}

/// Build a native Regressor whose weight pool holds the golden tables,
/// in direct-index mode (golden idx values ARE bucket indices).
fn native_from_golden(g: &Golden) -> Regressor {
    let cfg = if g.hidden.is_empty() {
        ModelConfig::ffm(g.fields, g.latent_dim, g.buckets as u32)
    } else {
        ModelConfig::deep_ffm(g.fields, g.latent_dim, g.buckets as u32, &g.hidden)
    };
    let mut reg = Regressor::new(&cfg);
    let l = reg.layout.clone();
    // LR table
    reg.pool.weights[l.lr_off..l.lr_off + l.lr_len].copy_from_slice(&g.lr_table);
    // FFM table: [N, F, K] row-major == pool's (bucket, toward, k) order
    reg.pool.weights[l.ffm_off..l.ffm_off + l.ffm_len].copy_from_slice(&g.ffm_table);
    // MLP params: (W1, b1, ..., w_out, b_out) in layout order
    let mut mi = 0;
    for lay in &l.layers {
        let w = &g.mlp[mi];
        reg.pool.weights[lay.w_off..lay.w_off + lay.rows * lay.cols]
            .copy_from_slice(w);
        let b = &g.mlp[mi + 1];
        reg.pool.weights[lay.b_off..lay.b_off + lay.cols].copy_from_slice(b);
        mi += 2;
    }
    if !g.hidden.is_empty() {
        reg.pool.weights[l.w_out_off..l.w_out_off + l.w_out_len]
            .copy_from_slice(&g.mlp[mi]);
        reg.pool.weights[l.b_out_off] = g.mlp[mi + 1][0];
    }
    reg
}

fn golden_example(g: &Golden, b: usize) -> Example {
    let slots = (0..g.fields)
        .map(|f| FeatureSlot {
            field: f as u16,
            bucket: g.idx[b * g.fields + f] as u32,
            value: g.vals[b * g.fields + f],
        })
        .collect();
    Example { label: f32::NAN, importance: 1.0, slots }
}

#[test]
fn native_forward_matches_golden() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let goldens = load_goldens(&default_artifact_dir()).unwrap();
    assert!(goldens.len() >= 2, "want deep + ffm goldens");
    for g in &goldens {
        let reg = native_from_golden(g);
        let mut ws = Workspace::new();
        for b in 0..g.batch {
            let ex = golden_example(g, b);
            let p = reg.predict(&ex, &mut ws);
            let want = g.probs[b];
            assert!(
                (p - want).abs() < 1e-5,
                "{} example {b}: native {p} vs golden {want}",
                g.name
            );
        }
    }
}

#[test]
fn pjrt_matches_golden() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let dir = default_artifact_dir();
    let manifest = Manifest::load(&dir).unwrap();
    let goldens = load_goldens(&dir).unwrap();
    let engine = PjrtEngine::cpu().unwrap();
    for g in &goldens {
        let compiled = engine.compile(&manifest, &g.name).unwrap();
        let mut argv = vec![
            ArgValue::F32(g.lr_table.clone()),
            ArgValue::F32(g.ffm_table.clone()),
        ];
        for m in &g.mlp {
            argv.push(ArgValue::F32(m.clone()));
        }
        argv.push(ArgValue::I32(g.idx.clone()));
        argv.push(ArgValue::F32(g.vals.clone()));
        let probs = compiled.run(&argv).unwrap();
        assert_eq!(probs.len(), g.batch);
        for (b, (&got, &want)) in probs.iter().zip(&g.probs).enumerate() {
            assert!(
                (got - want).abs() < 1e-5,
                "{} example {b}: pjrt {got} vs golden {want}",
                g.name
            );
        }
    }
}

#[test]
fn native_and_pjrt_agree_on_fresh_inputs() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    // Same golden weights, NEW random indices/values: agreement must
    // hold beyond the exported batch.
    use fwumious::util::rng::Pcg32;
    let dir = default_artifact_dir();
    let manifest = Manifest::load(&dir).unwrap();
    let goldens = load_goldens(&dir).unwrap();
    let engine = PjrtEngine::cpu().unwrap();
    let mut rng = Pcg32::seeded(2024);
    for g in &goldens {
        let reg = native_from_golden(g);
        let compiled = engine.compile(&manifest, &g.name).unwrap();
        let mut ws = Workspace::new();
        for _round in 0..3 {
            let idx: Vec<i32> = (0..g.batch * g.fields)
                .map(|_| rng.below(g.buckets as u32) as i32)
                .collect();
            let vals: Vec<f32> = (0..g.batch * g.fields)
                .map(|_| rng.range_f32(0.1, 2.0))
                .collect();
            let mut argv = vec![
                ArgValue::F32(g.lr_table.clone()),
                ArgValue::F32(g.ffm_table.clone()),
            ];
            for m in &g.mlp {
                argv.push(ArgValue::F32(m.clone()));
            }
            argv.push(ArgValue::I32(idx.clone()));
            argv.push(ArgValue::F32(vals.clone()));
            let pjrt = compiled.run(&argv).unwrap();
            for b in 0..g.batch {
                let slots = (0..g.fields)
                    .map(|f| FeatureSlot {
                        field: f as u16,
                        bucket: idx[b * g.fields + f] as u32,
                        value: vals[b * g.fields + f],
                    })
                    .collect();
                let ex = Example { label: f32::NAN, importance: 1.0, slots };
                let native = reg.predict(&ex, &mut ws);
                assert!(
                    (native - pjrt[b]).abs() < 1e-5,
                    "{} fresh example {b}: native {native} vs pjrt {}",
                    g.name,
                    pjrt[b]
                );
            }
        }
    }
}
