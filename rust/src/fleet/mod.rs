//! The multi-data-center weight distribution fabric.
//!
//! PR 1's deployment plane runs exactly one trainer→server pipe.  The
//! paper's regime is a *fleet*: one training site continuously
//! publishing to N data centers × M replicas each, where cross-DC
//! bandwidth is the billed resource and every replica must keep
//! serving a consistent version while updates race across lossy links.
//! This module is that fan-out layer:
//!
//! ```text
//!                      ┌────────────── DC 0 ──────────────┐
//!            inter-DC  │  head ──intra──► replica 1..M-1  │
//!   trainer ══════════►│  (fan-out tree: 1 WAN crossing)  │
//!      ║               └──────────────────────────────────┘
//!      ║  star: M WAN crossings per DC instead
//!      ╚══════════════► DC 1 … DC N-1   (same choice per DC)
//! ```
//!
//! * [`topology`] — DCs, replicas, per-link bandwidth/RTT/loss.
//! * [`planner`] — star vs fan-out-tree routes, chosen to minimize
//!   inter-DC bytes (the §6 bandwidth trick, generalized).
//! * [`replica`] — per-replica delta-chain version tracking over
//!   [`crate::transfer::UpdateReceiver`].
//! * [`FleetFabric`] — encode once, distribute per plan with bounded
//!   retries, heal broken chains via the catch-up protocol
//!   (folded/sequential chained-patch replay vs full-snapshot resync,
//!   whichever ships fewer bytes).
//! * [`health`] — the Healthy → Lagging → Suspect → Dead replica state
//!   machine; publish and serving-side routing go around Suspect/Dead
//!   replicas instead of stalling on them.
//! * [`checkpoint`] — durable CRC-guarded fabric checkpoints; a
//!   killed-and-restarted fabric or replica resumes bit-identically.
//! * [`metrics`] — per-link byte ledgers, publish lag per replica,
//!   max version skew, convergence counters.
//! * [`soak`] — the fleet-wide soak harness; [`chaos`] — the same
//!   harness under crash/partition/stall fault injection.

pub mod chaos;
pub mod checkpoint;
pub mod health;
pub mod metrics;
pub mod planner;
pub mod replica;
pub mod soak;
pub mod topology;

pub use checkpoint::{FabricCheckpoint, ReplicaCheckpoint};
pub use health::{HealthBoard, HealthPolicy, HealthState, HealthTracker};
pub use metrics::{FleetMetrics, LagStat, LinkLedger};
pub use planner::{plan, DcRoute, DistributionPlan, Strategy};
pub use replica::{ApplyVerdict, FleetReplica};
pub use topology::{DcSpec, LinkSpec, ReplicaId, SimLink, Topology};

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use crate::config::ServeConfig;
use crate::model::regressor::Regressor;
use crate::obs::{Counter, Gauge, HistogramShard, ObsRegistry, RequestTracer};
use crate::patch::{self, Patch};
use crate::serve::server::ServeStats;
use crate::transfer::{
    FleetError, UpdateMode, UpdatePipeline, UpdateReceiver, WireUpdate,
};
use crate::util::json::{num, obj, s};
use crate::util::rng::Pcg32;

/// Bounded-retry discipline for publish shipments: a failed attempt
/// costs the per-link timeout, then backs off exponentially (capped)
/// with deterministic jitter drawn from the fabric's seeded RNG — so
/// two runs with the same seed retry at identical simulated instants.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Total shipment attempts per target (1 = no retry).
    pub max_attempts: u32,
    /// Simulated seconds a failed attempt costs before it is declared
    /// lost (the per-link timeout).
    pub timeout_seconds: f64,
    /// First backoff; doubles per retry.
    pub base_backoff_seconds: f64,
    /// Backoff cap.
    pub max_backoff_seconds: f64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 3,
            timeout_seconds: 0.5,
            base_backoff_seconds: 0.05,
            max_backoff_seconds: 1.0,
        }
    }
}

impl RetryPolicy {
    /// Capped exponential backoff before retry number `attempt + 1`,
    /// jittered into `[50%, 100%)` of the nominal value.
    pub fn backoff_seconds(&self, attempt: u32, rng: &mut Pcg32) -> f64 {
        let nominal = (self.base_backoff_seconds
            * 2f64.powi(attempt.min(30) as i32))
        .min(self.max_backoff_seconds);
        nominal * (0.5 + 0.5 * rng.next_f64())
    }
}

/// Configuration of one fleet fabric.
#[derive(Clone, Debug)]
pub struct FleetConfig {
    pub topology: Topology,
    /// Wire encoding (the four Table-4 arms).
    pub mode: UpdateMode,
    /// Route policy resolved by the [`planner`] each round.
    pub strategy: Strategy,
    /// Catch-up window: a replica at most this many updates behind may
    /// be healed by replaying the retained patch chain; farther behind
    /// (or when replay would cost more bytes than a full file) it gets
    /// a full-snapshot resync.
    pub max_chain: usize,
    /// Start a live serving engine per replica (None = headless
    /// distribution sim — links and versions only).
    pub serve: Option<ServeConfig>,
    /// Name replicas register their model under.
    pub model_name: String,
    /// Seed for the deterministic loss/retry-jitter simulation.
    pub seed: u64,
    /// Health state-machine thresholds.
    pub health: HealthPolicy,
    /// Publish shipment retry discipline.
    pub retry: RetryPolicy,
}

impl FleetConfig {
    pub fn new(topology: Topology, mode: UpdateMode) -> Self {
        FleetConfig {
            topology,
            mode,
            strategy: Strategy::Auto,
            max_chain: 8,
            serve: None,
            model_name: "ctr".into(),
            seed: 0xf1ee7,
            health: HealthPolicy::default(),
            retry: RetryPolicy::default(),
        }
    }
}

/// How a catch-up was resolved.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CatchUpKind {
    /// Replica was already at head; nothing shipped.
    None,
    /// Replayed this many retained chained updates — as one folded
    /// patch when the chain could be merged, else in order.
    Replay { updates: usize },
    /// Shipped a full snapshot of this many bytes.
    Resync { bytes: usize },
}

/// Everything observed about one publish round.
#[derive(Clone, Debug)]
pub struct RoundOutcome {
    /// Publish sequence number of this round's update (1-based).
    pub seq: u64,
    /// Bytes of the encoded update on the wire.
    pub update_bytes: usize,
    /// Size of the raw inference file (the baseline).
    pub raw_bytes: usize,
    /// Replicas that received this round's update via distribution or
    /// were pulled to head by catch-up during the round.
    pub delivered: usize,
    /// Shipments lost this round (replicas left behind).
    pub dropped: usize,
    /// Replicas not shipped to at all because their health state was
    /// Suspect/Dead (routed around, recovery probes take over).
    pub skipped_unhealthy: usize,
    /// Shipment retry attempts spent this round.
    pub retries: u64,
    /// Catch-ups resolved by patch-chain replay this round.
    pub replays: u64,
    /// Catch-ups resolved by full resync this round.
    pub resyncs: u64,
    /// `head - min(replica seq)` after the round.
    pub max_skew: u64,
    /// Encoder wall time.
    pub encode_seconds: f64,
}

/// Live metric handles the fabric updates as events happen (the
/// snapshot path is [`FleetMetrics::export_to`]; these keep the shared
/// registry current between snapshots).  All names are get-or-create,
/// so snapshot exports refresh the same cells.
struct FleetObs {
    retries: Gauge,
    transitions: Counter,
    replay_ns: HistogramShard,
    health: Vec<Gauge>,
}

/// The distribution fabric: one sender-side pipeline fanned out to
/// every replica in the topology over simulated links.
pub struct FleetFabric {
    cfg: FleetConfig,
    /// Bootstrap model every replica (re)starts from; kept for
    /// crash-restart of individual replicas.
    template: Regressor,
    pipeline: UpdatePipeline,
    /// In-order receiver that never misses an update: the reference
    /// every replica must converge to, and the source of pre-swap
    /// expected state for the soak's torn-response check.
    reference: UpdateReceiver,
    reference_model: Option<Regressor>,
    /// Retained per-round updates (`log[i]` is publish seq `i+1`) —
    /// the sender side of the catch-up replay path.
    log: Vec<WireUpdate>,
    /// Everything before this index is already payload-blanked, so
    /// [`compact_log`](Self::compact_log) stays O(1) per round.
    log_blanked: usize,
    /// Merged single-hop patch for the retained window, refreshed by
    /// [`compact_log`](Self::compact_log): `(from_seq, update)` where
    /// `update` rebases a replica at `from_seq` straight to head.
    fold_cache: Option<(u64, WireUpdate)>,
    head: u64,
    replicas: Vec<FleetReplica>,
    /// Per-DC trainer→DC links.
    inter: Vec<SimLink>,
    /// Per-DC intra-DC re-distribution links.
    intra: Vec<SimLink>,
    rng: Pcg32,
    /// Fault injector: force-drop the next N shipments (hard losses,
    /// never retried — one injected drop is one missed delivery).
    forced_drops: u32,
    /// Fault injector: per-DC inter-link partition, in remaining
    /// publish rounds.
    partitioned: Vec<u64>,
    /// Fault injector: per-replica stall (frozen process), in
    /// remaining publish rounds.
    stalled: Vec<u64>,
    /// Per-replica health trackers (fabric-side state machine).
    trackers: Vec<HealthTracker>,
    /// Shared lock-free health view for serving-side routing.
    board: Arc<HealthBoard>,
    rounds: u64,
    max_skew: u64,
    replays: u64,
    resyncs: u64,
    converged_rounds: u64,
    retries: u64,
    skipped_publishes: u64,
    lag: Vec<LagStat>,
    obs: Option<FleetObs>,
    /// Discrete-event sink (publish rounds, catch-up replays/resyncs,
    /// health transitions); None = no tracing cost beyond this check.
    tracer: Option<RequestTracer>,
}

impl std::fmt::Debug for FleetFabric {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FleetFabric").finish_non_exhaustive()
    }
}

impl FleetFabric {
    /// Build the fleet: every replica bootstraps from `template`
    /// (structure + initial weights) at sequence 0.
    pub fn new(cfg: FleetConfig, template: &Regressor) -> Self {
        let mut reference = UpdateReceiver::new(cfg.mode);
        reference.set_template(template.clone());
        let replicas: Vec<FleetReplica> = cfg
            .topology
            .replica_ids()
            .into_iter()
            .map(|id| {
                FleetReplica::new(
                    id,
                    cfg.mode,
                    template,
                    cfg.serve.as_ref(),
                    &cfg.model_name,
                )
            })
            .collect();
        let inter = cfg.topology.dcs.iter().map(|d| SimLink::new(d.inter)).collect();
        let intra = cfg.topology.dcs.iter().map(|d| SimLink::new(d.intra)).collect();
        let rng = Pcg32::seeded(cfg.seed);
        let lag = vec![LagStat::default(); replicas.len()];
        let trackers = vec![HealthTracker::default(); replicas.len()];
        let board = Arc::new(HealthBoard::new(replicas.len()));
        let partitioned = vec![0; cfg.topology.dcs.len()];
        let stalled = vec![0; replicas.len()];
        let pipeline = UpdatePipeline::new(cfg.mode);
        FleetFabric {
            cfg,
            template: template.clone(),
            pipeline,
            reference,
            reference_model: None,
            log: Vec::new(),
            log_blanked: 0,
            fold_cache: None,
            head: 0,
            replicas,
            inter,
            intra,
            rng,
            forced_drops: 0,
            partitioned,
            stalled,
            trackers,
            board,
            rounds: 0,
            max_skew: 0,
            replays: 0,
            resyncs: 0,
            converged_rounds: 0,
            retries: 0,
            skipped_publishes: 0,
            lag,
            obs: None,
            tracer: None,
        }
    }

    /// Attach a discrete-event tracer: publish rounds, catch-up
    /// replays/resyncs, health transitions, and restarts are emitted
    /// as JSONL events.
    pub fn set_tracer(&mut self, tracer: RequestTracer) {
        self.tracer = Some(tracer);
    }

    /// Attach a shared metrics registry: health gauges
    /// (`fw_fleet_replica_health{replica=..}`), the publish-retry
    /// gauge, the health-transition counter, and the recovery replay
    /// histogram (`fw_recovery_replay_ns`) are kept live as events
    /// happen.  [`FleetMetrics::export_to`] refreshes the same cells
    /// at snapshot time.
    pub fn set_obs(&mut self, reg: &ObsRegistry) {
        let health: Vec<Gauge> = (0..self.replicas.len())
            .map(|i| {
                reg.gauge(
                    &format!("fw_fleet_replica_health{{replica=\"{i}\"}}"),
                    "replica health (0=healthy 1=lagging 2=suspect 3=dead)",
                )
            })
            .collect();
        for (i, g) in health.iter().enumerate() {
            g.set(self.trackers[i].state().as_gauge() as f64);
        }
        let obs = FleetObs {
            retries: reg.gauge(
                "fw_fleet_publish_retries",
                "cumulative publish shipment retry attempts",
            ),
            transitions: reg.counter(
                "fw_fleet_health_transitions_total",
                "replica health state transitions",
            ),
            replay_ns: reg.histogram_shard(
                "fw_recovery_replay_ns",
                "crash-recovery replay/catch-up wall time (ns)",
            ),
            health,
        };
        obs.retries.set(self.retries as f64);
        self.obs = Some(obs);
    }

    /// Publish one trained snapshot to the whole fleet.
    pub fn publish(&mut self, reg: &Regressor) -> Result<RoundOutcome, FleetError> {
        self.publish_with(reg, |_, _| {})
    }

    /// [`publish`](Self::publish) with a hook that observes the
    /// reconstructed model *before any replica can swap it in* — the
    /// soak harness registers expected probe scores there, so traffic
    /// hitting any replica can always attribute a response to a known
    /// version (the fleet-wide torn-response invariant).
    pub fn publish_with(
        &mut self,
        reg: &Regressor,
        before_swap: impl FnOnce(u64, &Regressor),
    ) -> Result<RoundOutcome, FleetError> {
        let seq = self.head + 1;
        let update = self.pipeline.encode(reg);
        let raw_bytes = self.pipeline.last_raw_len().unwrap_or(0);
        let fresh = self.reference.apply(&update)?;
        before_swap(seq, &fresh);
        self.reference_model = Some(fresh);
        let update_bytes = update.bytes.len();
        let encode_seconds = update.encode_seconds;
        self.log.push(update);
        self.head = seq;

        let plan = planner::plan(&self.cfg.topology, self.cfg.strategy);
        let mut delivered = 0usize;
        let mut dropped = 0usize;
        let mut skipped = 0usize;
        let mut contacted = vec![false; self.replicas.len()];
        let replays0 = self.replays;
        let resyncs0 = self.resyncs;
        let retries0 = self.retries;
        for (dc, route) in plan.per_dc.iter().enumerate() {
            let n_replicas = self.cfg.topology.dcs[dc].replicas;
            // Suspect/Dead replicas are routed around: no WAN bytes
            // spent on a black hole; the recovery probe owns them.
            let serving: Vec<usize> = (0..n_replicas)
                .filter(|&r| {
                    let idx = self
                        .cfg
                        .topology
                        .flat_index(ReplicaId { dc, replica: r });
                    self.trackers[idx].state().serving()
                })
                .collect();
            skipped += n_replicas - serving.len();
            if serving.is_empty() {
                continue;
            }
            match route {
                DcRoute::Star => {
                    for &r in &serving {
                        let idx = self
                            .cfg
                            .topology
                            .flat_index(ReplicaId { dc, replica: r });
                        match self.ship_inter_retrying(dc, idx, update_bytes) {
                            Some(secs) => {
                                self.apply_at(dc, r, encode_seconds + secs)?;
                                delivered += 1;
                                contacted[idx] = true;
                            }
                            None => dropped += 1,
                        }
                    }
                }
                DcRoute::Tree { head } => {
                    // the designated head relays intra-DC; if it is
                    // unhealthy, the first serving replica takes over
                    let head_r = if serving.contains(head) {
                        *head
                    } else {
                        serving[0]
                    };
                    let head_idx = self
                        .cfg
                        .topology
                        .flat_index(ReplicaId { dc, replica: head_r });
                    match self.ship_inter_retrying(dc, head_idx, update_bytes) {
                        None => dropped += serving.len(),
                        Some(head_secs) => {
                            self.apply_at(dc, head_r, encode_seconds + head_secs)?;
                            delivered += 1;
                            contacted[head_idx] = true;
                            for &r in &serving {
                                if r == head_r {
                                    continue;
                                }
                                let idx = self
                                    .cfg
                                    .topology
                                    .flat_index(ReplicaId { dc, replica: r });
                                match self.ship_intra_retrying(dc, idx, update_bytes)
                                {
                                    Some(secs) => {
                                        self.apply_at(
                                            dc,
                                            r,
                                            encode_seconds + head_secs + secs,
                                        )?;
                                        delivered += 1;
                                        contacted[idx] = true;
                                    }
                                    None => dropped += 1,
                                }
                            }
                        }
                    }
                }
            }
        }

        self.probe_unhealthy(&mut contacted);
        self.compact_log();
        self.observe_health(&contacted);
        self.skipped_publishes += skipped as u64;
        let max_skew = self.current_skew();
        self.max_skew = self.max_skew.max(max_skew);
        self.rounds += 1;
        if max_skew == 0 {
            self.converged_rounds += 1;
        }
        // fault countdowns tick per publish round
        for p in &mut self.partitioned {
            *p = p.saturating_sub(1);
        }
        for st in &mut self.stalled {
            *st = st.saturating_sub(1);
        }
        if let Some(tr) = self.tracer.as_ref() {
            tr.emit(&obj(vec![
                ("event", s("fleet_publish")),
                ("seq", num(seq as f64)),
                ("update_bytes", num(update_bytes as f64)),
                ("delivered", num(delivered as f64)),
                ("dropped", num(dropped as f64)),
                ("skipped_unhealthy", num(skipped as f64)),
                ("retries", num((self.retries - retries0) as f64)),
                ("max_skew", num(max_skew as f64)),
            ]));
        }
        Ok(RoundOutcome {
            seq,
            update_bytes,
            raw_bytes,
            delivered,
            dropped,
            skipped_unhealthy: skipped,
            retries: self.retries - retries0,
            replays: self.replays - replays0,
            resyncs: self.resyncs - resyncs0,
            max_skew,
            encode_seconds,
        })
    }

    /// Bring replica `idx` (flattened DC-major index) to the head
    /// version.  The catch-up protocol: when the replica's mode chains
    /// updates, it is within the replay window, and the retained
    /// patches sum to fewer bytes than a full snapshot, the missed
    /// chain is replayed — as one *folded* patch
    /// ([`crate::patch::fold_chain`]) when the links merge, so a deep
    /// catch-up is a single hop; in order otherwise.  Beyond the
    /// window a full-snapshot resync ships the sender's current base
    /// file.  Catch-up payloads move over a *reliable* control channel
    /// (lost shipments are retransmitted and billed), but a
    /// partitioned DC or stalled replica is unreachable even for that
    /// — the attempt fails fast with a matchable error.
    pub fn catch_up(&mut self, idx: usize) -> Result<CatchUpKind, FleetError> {
        let from = self.replicas[idx].seq();
        if from >= self.head {
            return Ok(CatchUpKind::None);
        }
        let dc = self.replicas[idx].id.dc;
        if self.partitioned[dc] > 0 {
            return Err(FleetError::LinkDown { dc });
        }
        if self.stalled[idx] > 0 {
            return Err(FleetError::Unreachable { replica: idx });
        }
        let missed = (self.head - from) as usize;
        let replay_bytes: usize = self.log[from as usize..self.head as usize]
            .iter()
            .map(|u| u.bytes.len())
            .sum();
        let full_len = self
            .pipeline
            .sent_bytes()
            .map(|b| b.len())
            .ok_or(FleetError::NothingPublished)?;
        // compact_log guarantees the last max_chain entries are intact;
        // the emptiness check is insurance against window-math drift
        let replay = self.cfg.mode.is_chained()
            && missed <= self.cfg.max_chain
            && replay_bytes < full_len
            && self.log[from as usize..self.head as usize]
                .iter()
                .all(|u| !u.bytes.is_empty());
        if replay {
            // single hop when ≥2 patch links merge (seq 1 is the
            // bootstrap full file, never part of a fold)
            if missed >= 2 && from >= 1 {
                if let Some(folded) = self.folded_update(from) {
                    let secs = self.ship_reliable_inter(dc, folded.bytes.len());
                    let verdict = self.replicas[idx].deliver_jump(self.head, &folded)?;
                    debug_assert_eq!(verdict, ApplyVerdict::Applied);
                    self.lag[idx].record(secs);
                    self.replays += 1;
                    if let Some(tr) = self.tracer.as_ref() {
                        tr.emit(&obj(vec![
                            ("event", s("fleet_catch_up")),
                            ("kind", s("replay")),
                            ("folded", num(1.0)),
                            ("replica", num(idx as f64)),
                            ("updates", num(missed as f64)),
                        ]));
                    }
                    return Ok(CatchUpKind::Replay { updates: missed });
                }
            }
            for seq in from + 1..=self.head {
                let len = self.log[(seq - 1) as usize].bytes.len();
                let secs = self.ship_reliable_inter(dc, len);
                let verdict =
                    self.replicas[idx].deliver(seq, &self.log[(seq - 1) as usize])?;
                debug_assert_eq!(verdict, ApplyVerdict::Applied);
                self.lag[idx].record(secs);
            }
            self.replays += 1;
            if let Some(tr) = self.tracer.as_ref() {
                tr.emit(&obj(vec![
                    ("event", s("fleet_catch_up")),
                    ("kind", s("replay")),
                    ("folded", num(0.0)),
                    ("replica", num(idx as f64)),
                    ("updates", num(missed as f64)),
                ]));
            }
            Ok(CatchUpKind::Replay { updates: missed })
        } else {
            // `full_len` above already proved a published base exists;
            // stay fallible anyway so a logic drift surfaces as an
            // error, not a panic mid-catch-up
            let full = self
                .pipeline
                .sent_bytes()
                .ok_or(FleetError::NothingPublished)?
                .to_vec();
            let secs = self.ship_reliable_inter(dc, full.len());
            self.replicas[idx].resync(self.head, &full)?;
            self.lag[idx].record(secs);
            self.resyncs += 1;
            if let Some(tr) = self.tracer.as_ref() {
                tr.emit(&obj(vec![
                    ("event", s("fleet_catch_up")),
                    ("kind", s("resync")),
                    ("replica", num(idx as f64)),
                    ("bytes", num(full.len() as f64)),
                ]));
            }
            Ok(CatchUpKind::Resync { bytes: full.len() })
        }
    }

    /// End-of-run barrier: catch every straggler up to head.  Returns
    /// how many replicas needed it.  (Production runs this implicitly
    /// — the next round's gap triggers the same protocol.)
    pub fn converge(&mut self) -> Result<usize, FleetError> {
        let mut fixed = 0;
        for idx in 0..self.replicas.len() {
            if self.replicas[idx].seq() < self.head {
                self.catch_up(idx)?;
                fixed += 1;
            }
        }
        Ok(fixed)
    }

    // -------------------------------------------------- fault injection

    /// Force the next `n` shipments (any link) to be lost — the
    /// deterministic fault injector behind the soak/property tests.
    /// Forced drops are hard losses: they are *not* retried, so one
    /// injected drop is exactly one missed delivery.
    pub fn force_drops(&mut self, n: u32) {
        self.forced_drops += n;
    }

    /// Partition DC `dc` from the trainer for the next `rounds`
    /// publish rounds: every inter-DC shipment (including catch-up
    /// probes) to it fails.
    pub fn partition_dc(&mut self, dc: usize, rounds: u64) {
        self.partitioned[dc] = self.partitioned[dc].max(rounds);
    }

    /// Stall replica `idx` for the next `rounds` publish rounds: the
    /// process is frozen, so every shipment to it fails until the
    /// stall clears.
    pub fn stall_replica(&mut self, idx: usize, rounds: u64) {
        self.stalled[idx] = self.stalled[idx].max(rounds);
    }

    // ----------------------------------------------- checkpoint/restart

    /// Snapshot the complete distribution state (see
    /// [`FabricCheckpoint`]).
    pub fn checkpoint(&self) -> FabricCheckpoint {
        let (prev_raw, prev_quant) = self.pipeline.export_state();
        FabricCheckpoint {
            mode: self.cfg.mode,
            head: self.head,
            rng_state: self.rng.state(),
            prev_raw,
            prev_quant,
            log: self.log.iter().map(|u| u.bytes.clone()).collect(),
            log_blanked: self.log_blanked as u64,
            replicas: (0..self.replicas.len())
                .map(|i| self.checkpoint_replica(i))
                .collect(),
            rounds: self.rounds,
            max_skew: self.max_skew,
            replays: self.replays,
            resyncs: self.resyncs,
            converged_rounds: self.converged_rounds,
            retries: self.retries,
            skipped_publishes: self.skipped_publishes,
            lag: self.lag.clone(),
            inter: self.inter.iter().map(|l| l.ledger).collect(),
            intra: self.intra.iter().map(|l| l.ledger).collect(),
            forced_drops: self.forced_drops,
            partitioned: self.partitioned.clone(),
            stalled: self.stalled.clone(),
        }
    }

    /// One replica's durable cursor (seq + receiver base + health).
    pub fn checkpoint_replica(&self, idx: usize) -> ReplicaCheckpoint {
        ReplicaCheckpoint {
            seq: self.replicas[idx].seq(),
            base: self.replicas[idx].base_bytes().map(|b| b.to_vec()),
            health: self.trackers[idx].state().as_gauge(),
            failed_rounds: self.trackers[idx].failed_rounds(),
        }
    }

    /// Write the fabric checkpoint to `path` (CRC-sealed, temp-file +
    /// rename, see [`checkpoint::write_atomic`]).
    pub fn write_checkpoint(&self, path: &Path) -> Result<(), FleetError> {
        checkpoint::write_atomic(path, &self.checkpoint().to_bytes())
    }

    /// Rebuild a fabric from a checkpoint.  The restored fabric is
    /// **bit-identical** to the one that wrote the checkpoint: same
    /// pipeline diff bases, same retained log, same replica cursors
    /// and bases, same RNG position, same counters/ledgers — so the
    /// next publish behaves exactly as it would have without the
    /// crash.
    pub fn restore(
        cfg: FleetConfig,
        template: &Regressor,
        ckpt: &FabricCheckpoint,
    ) -> Result<FleetFabric, FleetError> {
        if ckpt.mode != cfg.mode {
            return Err(FleetError::Corrupt(format!(
                "checkpoint mode {:?} != configured {:?}",
                ckpt.mode, cfg.mode
            )));
        }
        let mut fab = FleetFabric::new(cfg, template);
        if ckpt.replicas.len() != fab.replicas.len()
            || ckpt.partitioned.len() != fab.partitioned.len()
            || ckpt.stalled.len() != fab.stalled.len()
            || ckpt.inter.len() != fab.inter.len()
            || ckpt.intra.len() != fab.intra.len()
            || ckpt.lag.len() != fab.lag.len()
        {
            return Err(FleetError::Corrupt(
                "checkpoint topology does not match configuration".into(),
            ));
        }
        fab.pipeline
            .restore_state(ckpt.prev_raw.clone(), ckpt.prev_quant.clone())?;
        if let Some(base) = fab.pipeline.sent_bytes().map(|b| b.to_vec()) {
            let fresh = fab.reference.resync(&base)?;
            fab.reference_model = Some(fresh);
        }
        fab.log = ckpt
            .log
            .iter()
            .map(|b| WireUpdate {
                mode: ckpt.mode,
                bytes: b.clone(),
                encode_seconds: 0.0,
            })
            .collect();
        fab.log_blanked = ckpt.log_blanked as usize;
        fab.head = ckpt.head;
        fab.rng = Pcg32::from_state(ckpt.rng_state.0, ckpt.rng_state.1);
        for (i, rc) in ckpt.replicas.iter().enumerate() {
            fab.replicas[i].restore(rc.seq, rc.base.as_deref())?;
            fab.trackers[i] = HealthTracker::restore(
                HealthState::from_gauge(rc.health),
                rc.failed_rounds,
            );
            fab.board.set(i, fab.trackers[i].state());
        }
        fab.rounds = ckpt.rounds;
        fab.max_skew = ckpt.max_skew;
        fab.replays = ckpt.replays;
        fab.resyncs = ckpt.resyncs;
        fab.converged_rounds = ckpt.converged_rounds;
        fab.retries = ckpt.retries;
        fab.skipped_publishes = ckpt.skipped_publishes;
        fab.lag = ckpt.lag.clone();
        for (link, l) in fab.inter.iter_mut().zip(&ckpt.inter) {
            link.ledger = *l;
        }
        for (link, l) in fab.intra.iter_mut().zip(&ckpt.intra) {
            link.ledger = *l;
        }
        fab.forced_drops = ckpt.forced_drops;
        fab.partitioned = ckpt.partitioned.clone();
        fab.stalled = ckpt.stalled.clone();
        fab.refresh_fold_cache();
        Ok(fab)
    }

    /// [`restore`](Self::restore) from a sealed checkpoint file.
    pub fn restore_from_path(
        cfg: FleetConfig,
        template: &Regressor,
        path: &Path,
    ) -> Result<FleetFabric, FleetError> {
        let payload = checkpoint::read_file(path)?;
        let ckpt = FabricCheckpoint::from_bytes(&payload)?;
        Self::restore(cfg, template, &ckpt)
    }

    /// Kill-and-restart replica `idx` from its durable cursor: the old
    /// replica (and its serving engine) is torn down, a fresh one
    /// bootstraps from the template, restores to the checkpointed
    /// seq/base, and is healed to head via catch-up.  Recovery wall
    /// time lands in the `fw_recovery_replay_ns` histogram.  If the
    /// replica is currently unreachable (partition/stall), the restart
    /// still succeeds — it just stays at the checkpointed seq until
    /// the recovery probe can reach it.
    pub fn restart_replica(
        &mut self,
        idx: usize,
        ckpt: &ReplicaCheckpoint,
    ) -> Result<CatchUpKind, FleetError> {
        let t = Instant::now();
        let id = self.replicas[idx].id;
        let fresh = FleetReplica::new(
            id,
            self.cfg.mode,
            &self.template,
            self.cfg.serve.as_ref(),
            &self.cfg.model_name,
        );
        let old = std::mem::replace(&mut self.replicas[idx], fresh);
        old.shutdown();
        self.replicas[idx].restore(ckpt.seq, ckpt.base.as_deref())?;
        self.trackers[idx] = HealthTracker::restore(
            HealthState::from_gauge(ckpt.health),
            ckpt.failed_rounds,
        );
        self.board.set(idx, self.trackers[idx].state());
        let kind = match self.catch_up(idx) {
            Ok(k) => k,
            Err(FleetError::LinkDown { .. })
            | Err(FleetError::Unreachable { .. }) => CatchUpKind::None,
            Err(e) => return Err(e),
        };
        if let Some(o) = &self.obs {
            o.replay_ns.record_ns(t.elapsed().as_nanos() as u64);
        }
        if let Some(tr) = self.tracer.as_ref() {
            tr.emit(&obj(vec![
                ("event", s("fleet_restart")),
                ("replica", num(idx as f64)),
                ("from_seq", num(ckpt.seq as f64)),
                ("to_seq", num(self.replicas[idx].seq() as f64)),
            ]));
        }
        Ok(kind)
    }

    // ------------------------------------------------------ internals

    fn apply_at(&mut self, dc: usize, r: usize, lag_seconds: f64) -> Result<(), FleetError> {
        let idx = self.cfg.topology.flat_index(ReplicaId { dc, replica: r });
        let seq = self.head;
        let verdict = self.replicas[idx].deliver(seq, &self.log[(seq - 1) as usize])?;
        match verdict {
            ApplyVerdict::Applied => {
                self.lag[idx].record(lag_seconds);
                Ok(())
            }
            ApplyVerdict::Duplicate => Ok(()),
            ApplyVerdict::Gap => {
                // the replica fell behind earlier (dropped update);
                // heal the chain now
                self.catch_up(idx).map(|_| ())
            }
        }
    }

    /// Attempt catch-up on every non-serving (Suspect/Dead) replica —
    /// the recovery probe.  A reachable replica is healed (and counts
    /// as contacted this round, resurrecting it through the health
    /// machine); one behind a partition or stall stays down.  Probe
    /// recovery wall time lands in `fw_recovery_replay_ns`.
    fn probe_unhealthy(&mut self, contacted: &mut [bool]) {
        for idx in 0..self.replicas.len() {
            if self.trackers[idx].state().serving() {
                continue;
            }
            let dc = self.replicas[idx].id.dc;
            if self.partitioned[dc] > 0 || self.stalled[idx] > 0 {
                continue; // probe times out; heartbeat age keeps growing
            }
            let t = Instant::now();
            if self.catch_up(idx).is_ok() {
                contacted[idx] = true;
                if let Some(o) = &self.obs {
                    o.replay_ns.record_ns(t.elapsed().as_nanos() as u64);
                }
            }
        }
    }

    /// Fold each replica's round outcome into its health tracker and
    /// publish transitions to the board, gauges, and tracer.
    fn observe_health(&mut self, contacted: &[bool]) {
        for idx in 0..self.replicas.len() {
            let lag = self.head - self.replicas[idx].seq();
            if let Some((from, to)) =
                self.trackers[idx].observe(contacted[idx], lag, &self.cfg.health)
            {
                if let Some(o) = &self.obs {
                    o.transitions.inc();
                    o.health[idx].set(to.as_gauge() as f64);
                }
                if let Some(tr) = self.tracer.as_ref() {
                    tr.emit(&obj(vec![
                        ("event", s("fleet_health")),
                        ("replica", num(idx as f64)),
                        ("from", s(from.label())),
                        ("to", s(to.label())),
                    ]));
                }
            }
            self.board.set(idx, self.trackers[idx].state());
        }
        if let Some(o) = &self.obs {
            o.retries.set(self.retries as f64);
        }
    }

    /// Drop retained payloads that the replay path can never use, and
    /// refresh the folded single-hop patch for the surviving window.
    /// The log keeps one slot per seq (indexing), but only the newest
    /// `max_chain` entries are replayable (and non-chained modes never
    /// replay at all — their catch-up is always a resync of the
    /// current base).  Without this, a long Raw-mode run would retain
    /// every full snapshot ever published.
    fn compact_log(&mut self) {
        let keep = if self.cfg.mode.is_chained() {
            self.cfg.max_chain.max(1)
        } else {
            1
        };
        let blank_upto = self.log.len().saturating_sub(keep);
        let start = self.log_blanked.min(blank_upto);
        for u in &mut self.log[start..blank_upto] {
            u.bytes = Vec::new();
        }
        self.log_blanked = self.log_blanked.max(blank_upto);
        self.refresh_fold_cache();
    }

    /// Merge the whole retained patch window into one cached
    /// single-hop update (the deep-catch-up fast path).  Seq 1 is the
    /// bootstrap full file, never a patch, so the window starts at log
    /// index 1 at the earliest.
    fn refresh_fold_cache(&mut self) {
        self.fold_cache = None;
        if !self.cfg.mode.is_chained() {
            return;
        }
        let win_start = self.log_blanked.max(1) as u64;
        if self.head < win_start + 2 {
            return; // fewer than 2 links — nothing to merge
        }
        self.fold_cache =
            self.fold_window(win_start).map(|u| (win_start, u));
    }

    /// The folded catch-up update for a replica at seq `from`: the
    /// cached window fold when it matches, an on-demand fold
    /// otherwise.  None when the chain cannot be merged (corrupt or
    /// length-changing links) — the caller falls back to sequential
    /// replay.
    fn folded_update(&mut self, from: u64) -> Option<WireUpdate> {
        if let Some((cached_from, u)) = &self.fold_cache {
            if *cached_from == from {
                return Some(u.clone());
            }
        }
        self.fold_window(from)
    }

    fn fold_window(&self, from: u64) -> Option<WireUpdate> {
        let entries = &self.log[from as usize..self.head as usize];
        let patches: Result<Vec<Patch>, String> =
            entries.iter().map(|u| Patch::from_wire(&u.bytes)).collect();
        let folded =
            patch::fold_chain(&patches.ok()?, self.pipeline.compression).ok()?;
        Some(WireUpdate {
            mode: self.cfg.mode,
            bytes: folded.to_wire(),
            encode_seconds: 0.0,
        })
    }

    fn take_forced_drop(&mut self) -> bool {
        if self.forced_drops > 0 {
            self.forced_drops -= 1;
            true
        } else {
            false
        }
    }

    fn ship_inter(&mut self, dc: usize, len: usize) -> Option<f64> {
        let force = self.take_forced_drop();
        self.inter[dc].ship(len, &mut self.rng, force)
    }

    /// Publish-path inter-DC shipment with the bounded-retry
    /// discipline.  A forced drop is a hard loss (one billed failed
    /// attempt, no retry).  A partitioned DC or stalled target fails
    /// every attempt (each billed — the sender pays for bytes pushed
    /// into a black hole until the timeout).  Probabilistic link loss
    /// is retried with capped exponential backoff and deterministic
    /// jitter; failed attempts add the timeout + backoff to the
    /// delivery lag.
    fn ship_inter_retrying(
        &mut self,
        dc: usize,
        target: usize,
        len: usize,
    ) -> Option<f64> {
        if self.take_forced_drop() {
            let secs = self.inter[dc].spec.transfer_seconds(len);
            self.inter[dc].ledger.record(len, secs, false);
            return None;
        }
        let mut elapsed = 0.0;
        let max = self.cfg.retry.max_attempts.max(1);
        for attempt in 0..max {
            let shipped = if self.partitioned[dc] > 0 || self.stalled[target] > 0 {
                let secs = self.inter[dc].spec.transfer_seconds(len);
                self.inter[dc].ledger.record(len, secs, false);
                None
            } else {
                self.inter[dc].ship(len, &mut self.rng, false)
            };
            match shipped {
                Some(secs) => return Some(elapsed + secs),
                None => {
                    elapsed += self.cfg.retry.timeout_seconds;
                    if attempt + 1 < max {
                        elapsed +=
                            self.cfg.retry.backoff_seconds(attempt, &mut self.rng);
                        self.retries += 1;
                    }
                }
            }
        }
        None
    }

    /// Intra-DC twin of
    /// [`ship_inter_retrying`](Self::ship_inter_retrying).  Partitions
    /// cut only the trainer→DC link; inside the DC only a stalled
    /// target is unreachable.
    fn ship_intra_retrying(
        &mut self,
        dc: usize,
        target: usize,
        len: usize,
    ) -> Option<f64> {
        if self.take_forced_drop() {
            let secs = self.intra[dc].spec.transfer_seconds(len);
            self.intra[dc].ledger.record(len, secs, false);
            return None;
        }
        let mut elapsed = 0.0;
        let max = self.cfg.retry.max_attempts.max(1);
        for attempt in 0..max {
            let shipped = if self.stalled[target] > 0 {
                let secs = self.intra[dc].spec.transfer_seconds(len);
                self.intra[dc].ledger.record(len, secs, false);
                None
            } else {
                self.intra[dc].ship(len, &mut self.rng, false)
            };
            match shipped {
                Some(secs) => return Some(elapsed + secs),
                None => {
                    elapsed += self.cfg.retry.timeout_seconds;
                    if attempt + 1 < max {
                        elapsed +=
                            self.cfg.retry.backoff_seconds(attempt, &mut self.rng);
                        self.retries += 1;
                    }
                }
            }
        }
        None
    }

    /// Reliable (retransmitting) inter-DC shipment for catch-up
    /// traffic; every attempt is billed, delivery is guaranteed.  After
    /// a bounded number of lossy retries the final retransmission is
    /// forced through (and billed as a delivery), so even a 100%-loss
    /// link cannot leave the ledger claiming convergence happened with
    /// zero successful shipments.  (Reachability — partition/stall —
    /// is checked by [`catch_up`](Self::catch_up) before this runs.)
    fn ship_reliable_inter(&mut self, dc: usize, len: usize) -> f64 {
        let mut total = 0.0;
        for _ in 0..63 {
            match self.ship_inter(dc, len) {
                Some(secs) => return total + secs,
                None => total += self.inter[dc].spec.transfer_seconds(len),
            }
        }
        let secs = self.inter[dc].spec.transfer_seconds(len);
        self.inter[dc].ledger.record(len, secs, true);
        total + secs
    }

    fn current_skew(&self) -> u64 {
        self.replicas.iter().map(|r| self.head - r.seq()).max().unwrap_or(0)
    }

    // ------------------------------------------------------ accessors

    pub fn config(&self) -> &FleetConfig {
        &self.cfg
    }

    pub fn topology(&self) -> &Topology {
        &self.cfg.topology
    }

    /// Current head publish sequence (0 before the first round).
    pub fn head(&self) -> u64 {
        self.head
    }

    /// All replicas, flattened DC-major.
    pub fn replicas(&self) -> &[FleetReplica] {
        &self.replicas
    }

    /// Health state of replica `idx`.
    pub fn health(&self, idx: usize) -> HealthState {
        self.trackers[idx].state()
    }

    /// Shared lock-free health board — clone the `Arc` into traffic
    /// drivers for serving-side route-around.
    pub fn health_board(&self) -> &Arc<HealthBoard> {
        &self.board
    }

    /// The reference model every replica must converge to (None before
    /// the first publish).
    pub fn reference(&self) -> Option<&Regressor> {
        self.reference_model.as_ref()
    }

    /// Sender-side base file for the current head (the resync payload).
    pub fn sender_base(&self) -> Option<&[u8]> {
        self.pipeline.sent_bytes()
    }

    /// Metrics snapshot.
    pub fn metrics(&self) -> FleetMetrics {
        FleetMetrics {
            rounds: self.rounds,
            max_version_skew: self.max_skew,
            replays: self.replays,
            resyncs: self.resyncs,
            converged_rounds: self.converged_rounds,
            retries: self.retries,
            skipped_publishes: self.skipped_publishes,
            health: self.trackers.iter().map(|t| t.state().as_gauge()).collect(),
            lag: self.lag.clone(),
            inter: self.inter.iter().map(|l| l.ledger).collect(),
            intra: self.intra.iter().map(|l| l.ledger).collect(),
        }
    }

    /// Stop all replica engines; returns their final serving stats
    /// (None entries for headless replicas).
    pub fn shutdown(self) -> Vec<Option<ServeStats>> {
        self.replicas.into_iter().map(|r| r.shutdown()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::data::synthetic::{DatasetSpec, SyntheticStream};
    use crate::model::Workspace;

    fn trained_snapshots(n: usize, per: usize) -> (Regressor, Vec<Regressor>) {
        let cfg = ModelConfig::ffm(4, 2, 1 << 9);
        let template = Regressor::new(&cfg);
        let mut reg = template.clone();
        let mut ws = Workspace::new();
        let mut s = SyntheticStream::with_buckets(DatasetSpec::tiny(), 9, 1 << 9);
        let mut out = Vec::new();
        for _ in 0..n {
            for _ in 0..per {
                let ex = s.next_example();
                reg.learn(&ex, &mut ws);
            }
            out.push(reg.clone());
        }
        (template, out)
    }

    fn fabric(mode: UpdateMode, dcs: usize, replicas: usize, template: &Regressor) -> FleetFabric {
        let topo = Topology::uniform(dcs, replicas, LinkSpec::wan(), LinkSpec::lan());
        FleetFabric::new(FleetConfig::new(topo, mode), template)
    }

    #[test]
    fn lossless_fleet_converges_every_round() {
        for mode in UpdateMode::ALL {
            let (template, snaps) = trained_snapshots(3, 250);
            let mut fab = fabric(mode, 2, 2, &template);
            for (i, snap) in snaps.iter().enumerate() {
                let o = fab.publish(snap).unwrap();
                assert_eq!(o.seq, i as u64 + 1);
                assert_eq!(o.delivered, 4, "{mode:?}");
                assert_eq!(o.dropped, 0);
                assert_eq!(o.skipped_unhealthy, 0);
                assert_eq!(o.retries, 0);
                assert_eq!(o.max_skew, 0, "{mode:?}");
            }
            assert_eq!(fab.converge().unwrap(), 0);
            let reference = fab.reference().unwrap().pool.weights.clone();
            for rep in fab.replicas() {
                assert_eq!(rep.seq(), fab.head());
                assert_eq!(
                    rep.model().pool.weights,
                    reference,
                    "{mode:?} replica {:?} diverged",
                    rep.id
                );
            }
            let m = fab.metrics();
            assert_eq!(m.rounds, 3);
            assert_eq!(m.converged_rounds, 3);
            assert_eq!(m.drops(), 0);
            // auto strategy on 2-replica DCs = tree: one inter shipment
            // per DC per round
            assert_eq!(
                m.inter.iter().map(|l| l.messages).sum::<u64>(),
                2 * 3,
                "{mode:?}"
            );
        }
    }

    #[test]
    fn forced_drop_triggers_catchup_in_chained_modes() {
        for mode in [UpdateMode::PatchOnly, UpdateMode::QuantPatch] {
            let (template, snaps) = trained_snapshots(3, 250);
            let mut fab = fabric(mode, 1, 2, &template);
            fab.publish(&snaps[0]).unwrap();
            // lose round 2's single inter shipment: the whole DC tree
            // misses seq 2 (forced drops are hard losses — no retry)
            fab.force_drops(1);
            let o2 = fab.publish(&snaps[1]).unwrap();
            assert_eq!(o2.dropped, 2, "{mode:?}");
            assert_eq!(o2.retries, 0, "{mode:?}");
            assert_eq!(o2.max_skew, 1, "{mode:?}");
            // round 3 arrives: the head replica hits a gap and the
            // catch-up protocol replays the missed link
            let o3 = fab.publish(&snaps[2]).unwrap();
            assert_eq!(o3.max_skew, 0, "{mode:?}");
            assert!(o3.replays + o3.resyncs >= 1, "{mode:?}");
            let reference = fab.reference().unwrap().pool.weights.clone();
            for rep in fab.replicas() {
                assert_eq!(rep.model().pool.weights, reference, "{mode:?}");
            }
        }
    }

    #[test]
    fn full_file_modes_self_heal_without_catchup() {
        // raw/quant updates are self-contained: a dropped round needs
        // no protocol, the next delivery skips ahead
        let (template, snaps) = trained_snapshots(3, 250);
        let mut fab = fabric(UpdateMode::Raw, 1, 2, &template);
        fab.publish(&snaps[0]).unwrap();
        fab.force_drops(1);
        let o2 = fab.publish(&snaps[1]).unwrap();
        assert_eq!(o2.max_skew, 1);
        let o3 = fab.publish(&snaps[2]).unwrap();
        assert_eq!(o3.max_skew, 0);
        assert_eq!(o3.replays + o3.resyncs, 0);
        assert_eq!(fab.converge().unwrap(), 0);
    }

    #[test]
    fn max_chain_zero_forces_resync() {
        let (template, snaps) = trained_snapshots(3, 250);
        let topo = Topology::uniform(1, 2, LinkSpec::wan(), LinkSpec::lan());
        let mut cfg = FleetConfig::new(topo, UpdateMode::QuantPatch);
        cfg.max_chain = 0;
        let mut fab = FleetFabric::new(cfg, &template);
        fab.publish(&snaps[0]).unwrap();
        fab.force_drops(1);
        fab.publish(&snaps[1]).unwrap();
        let o3 = fab.publish(&snaps[2]).unwrap();
        assert_eq!(o3.replays, 0);
        assert!(o3.resyncs >= 1);
        let m = fab.metrics();
        assert_eq!(m.replays, 0);
        assert!(m.resyncs >= 1);
    }

    #[test]
    fn converge_pulls_final_round_stragglers() {
        let (template, snaps) = trained_snapshots(2, 250);
        let mut fab = fabric(UpdateMode::QuantPatch, 1, 2, &template);
        fab.publish(&snaps[0]).unwrap();
        fab.force_drops(1); // final round's only inter shipment lost
        let o = fab.publish(&snaps[1]).unwrap();
        assert_eq!(o.dropped, 2);
        assert_eq!(fab.converge().unwrap(), 2);
        let reference = fab.reference().unwrap().pool.weights.clone();
        for rep in fab.replicas() {
            assert_eq!(rep.seq(), 2);
            assert_eq!(rep.model().pool.weights, reference);
        }
        let m = fab.metrics();
        assert!(m.replays + m.resyncs >= 1);
        assert_eq!(m.max_version_skew, 1);
    }

    #[test]
    fn star_and_tree_byte_accounting() {
        let (template, snaps) = trained_snapshots(2, 250);
        for (strategy, inter_per_round, intra_per_round) in [
            (Strategy::Star, 3usize, 0usize),
            (Strategy::Tree, 1, 2),
        ] {
            let topo = Topology::uniform(1, 3, LinkSpec::wan(), LinkSpec::lan());
            let mut cfg = FleetConfig::new(topo, UpdateMode::Raw);
            cfg.strategy = strategy;
            let mut fab = FleetFabric::new(cfg, &template);
            let mut expect_inter = 0u64;
            let mut expect_intra = 0u64;
            for snap in &snaps {
                let o = fab.publish(snap).unwrap();
                expect_inter += (o.update_bytes * inter_per_round) as u64;
                expect_intra += (o.update_bytes * intra_per_round) as u64;
            }
            let m = fab.metrics();
            assert_eq!(m.inter_bytes(), expect_inter, "{strategy:?}");
            assert_eq!(m.intra_bytes(), expect_intra, "{strategy:?}");
        }
    }

    #[test]
    fn log_compaction_keeps_only_the_replayable_window() {
        // non-chained modes never replay: one retained payload slot
        let (template, snaps) = trained_snapshots(3, 250);
        let mut fab = fabric(UpdateMode::Raw, 1, 1, &template);
        for snap in &snaps {
            fab.publish(snap).unwrap();
        }
        assert_eq!(fab.log.len(), 3, "one slot per seq survives");
        let retained = fab.log.iter().filter(|u| !u.bytes.is_empty()).count();
        assert_eq!(retained, 1);

        // chained modes keep the max_chain newest payloads
        let (template, snaps) = trained_snapshots(4, 250);
        let topo = Topology::uniform(1, 1, LinkSpec::wan(), LinkSpec::lan());
        let mut cfg = FleetConfig::new(topo, UpdateMode::QuantPatch);
        cfg.max_chain = 2;
        let mut fab = FleetFabric::new(cfg, &template);
        for snap in &snaps {
            fab.publish(snap).unwrap();
        }
        let retained = fab.log.iter().filter(|u| !u.bytes.is_empty()).count();
        assert_eq!(retained, 2);
        // the blanked prefix is exactly the oldest entries
        assert!(fab.log[0].bytes.is_empty() && fab.log[1].bytes.is_empty());
    }

    #[test]
    fn lag_includes_tree_second_hop() {
        let (template, snaps) = trained_snapshots(1, 250);
        let topo = Topology::uniform(1, 2, LinkSpec::wan(), LinkSpec::lan());
        let mut cfg = FleetConfig::new(topo, UpdateMode::Raw);
        cfg.strategy = Strategy::Tree;
        let mut fab = FleetFabric::new(cfg, &template);
        fab.publish(&snaps[0]).unwrap();
        let m = fab.metrics();
        // replica 1 rides head's WAN hop plus its own LAN hop
        assert!(m.lag[1].last_seconds > m.lag[0].last_seconds);
    }

    #[test]
    fn deep_catchup_replays_one_folded_hop() {
        for mode in [UpdateMode::PatchOnly, UpdateMode::QuantPatch] {
            let (template, snaps) = trained_snapshots(5, 250);
            let mut fab = fabric(mode, 1, 2, &template);
            fab.publish(&snaps[0]).unwrap();
            fab.publish(&snaps[1]).unwrap();
            // lose rounds 3 and 4 entirely: both replicas fall 2 behind
            fab.force_drops(1);
            fab.publish(&snaps[2]).unwrap();
            fab.force_drops(1);
            let o4 = fab.publish(&snaps[3]).unwrap();
            assert_eq!(o4.max_skew, 2, "{mode:?}");
            let inter_msgs_before: u64 =
                fab.metrics().inter.iter().map(|l| l.messages).sum();
            // round 5 delivery hits a 2-update gap at the tree head:
            // the fold path must heal it in a single catch-up hop
            let o5 = fab.publish(&snaps[4]).unwrap();
            assert_eq!(o5.max_skew, 0, "{mode:?}");
            assert!(o5.replays >= 1, "{mode:?}");
            let inter_msgs_after: u64 =
                fab.metrics().inter.iter().map(|l| l.messages).sum();
            // one publish shipment + one folded catch-up shipment per
            // replica — NOT one shipment per missed link (2 replicas ×
            // 2 missed links would be 4 catch-up hops unfolded)
            assert_eq!(inter_msgs_after - inter_msgs_before, 3, "{mode:?}");
            let reference = fab.reference().unwrap().pool.weights.clone();
            for rep in fab.replicas() {
                assert_eq!(rep.model().pool.weights, reference, "{mode:?}");
            }
        }
    }

    #[test]
    fn stall_walks_replica_to_dead_and_probe_resurrects() {
        let (template, snaps) = trained_snapshots(8, 120);
        let topo = Topology::uniform(1, 2, LinkSpec::wan(), LinkSpec::lan());
        let mut cfg = FleetConfig::new(topo, UpdateMode::QuantPatch);
        cfg.strategy = Strategy::Star;
        let mut fab = FleetFabric::new(cfg, &template);
        fab.publish(&snaps[0]).unwrap();
        assert_eq!(fab.health(1), HealthState::Healthy);
        // freeze replica 1 for 4 rounds: Lagging → Suspect → Dead
        fab.stall_replica(1, 4);
        fab.publish(&snaps[1]).unwrap();
        assert_eq!(fab.health(1), HealthState::Lagging);
        let o3 = fab.publish(&snaps[2]).unwrap();
        assert_eq!(fab.health(1), HealthState::Suspect);
        assert!(o3.retries > 0, "stalled shipments must be retried");
        // Suspect → skipped by publish, probe still can't reach it
        let o4 = fab.publish(&snaps[3]).unwrap();
        assert_eq!(o4.skipped_unhealthy, 1);
        let o5 = fab.publish(&snaps[4]).unwrap();
        assert_eq!(o5.skipped_unhealthy, 1);
        assert_eq!(fab.health(1), HealthState::Dead);
        assert!(!fab.health_board().get(1).serving());
        assert_eq!(fab.health_board().route(1), 0, "traffic routed around");
        // stall expired: the recovery probe heals it the next round
        let o6 = fab.publish(&snaps[5]).unwrap();
        assert_eq!(fab.health(1), HealthState::Healthy, "{o6:?}");
        assert_eq!(o6.max_skew, 0);
        assert_eq!(fab.health_board().route(1), 1);
        let reference = fab.reference().unwrap().pool.weights.clone();
        assert_eq!(fab.replicas()[1].model().pool.weights, reference);
        let m = fab.metrics();
        assert!(m.retries > 0);
        assert!(m.skipped_publishes >= 2);
    }

    #[test]
    fn partition_downs_a_dc_and_heals_after() {
        let (template, snaps) = trained_snapshots(6, 120);
        let mut fab = fabric(UpdateMode::QuantPatch, 2, 1, &template);
        fab.publish(&snaps[0]).unwrap();
        fab.partition_dc(1, 2);
        let o2 = fab.publish(&snaps[1]).unwrap();
        assert_eq!(o2.dropped, 1);
        assert!(o2.retries > 0, "partitioned shipments are retried");
        // catch-up across the partition is a matchable LinkDown
        assert_eq!(fab.catch_up(1), Err(FleetError::LinkDown { dc: 1 }));
        fab.publish(&snaps[2]).unwrap();
        assert!(fab.health(1) > HealthState::Healthy);
        // partition expired: next round heals the replica
        let o4 = fab.publish(&snaps[3]).unwrap();
        assert_eq!(o4.max_skew, 0, "{o4:?}");
        assert_eq!(fab.health(1), HealthState::Healthy);
        let reference = fab.reference().unwrap().pool.weights.clone();
        assert_eq!(fab.replicas()[1].model().pool.weights, reference);
    }

    #[test]
    fn checkpoint_restore_resumes_bit_identically() {
        for mode in UpdateMode::ALL {
            let (template, snaps) = trained_snapshots(6, 150);
            // reference: uninterrupted run over all six rounds
            let mut gold = fabric(mode, 2, 2, &template);
            gold.force_drops(1);
            for snap in &snaps {
                gold.publish(snap).unwrap();
            }
            // crashed run: checkpoint after round 3, restore, continue
            let mut fab = fabric(mode, 2, 2, &template);
            fab.force_drops(1);
            for snap in &snaps[..3] {
                fab.publish(snap).unwrap();
            }
            let ckpt = fab.checkpoint();
            let bytes = ckpt.to_bytes();
            drop(fab); // the crash
            let restored = FabricCheckpoint::from_bytes(&bytes).unwrap();
            let topo =
                Topology::uniform(2, 2, LinkSpec::wan(), LinkSpec::lan());
            let mut fab =
                FleetFabric::restore(FleetConfig::new(topo, mode), &template, &restored)
                    .unwrap();
            for snap in &snaps[3..] {
                fab.publish(snap).unwrap();
            }
            // bit-identical: same head, same replica weights, same
            // sender base, same ledgers as the uninterrupted run
            assert_eq!(fab.head(), gold.head(), "{mode:?}");
            assert_eq!(fab.sender_base(), gold.sender_base(), "{mode:?}");
            for (a, b) in fab.replicas().iter().zip(gold.replicas()) {
                assert_eq!(a.seq(), b.seq(), "{mode:?}");
                assert_eq!(
                    a.model().pool.weights,
                    b.model().pool.weights,
                    "{mode:?}"
                );
                assert_eq!(a.base_bytes(), b.base_bytes(), "{mode:?}");
            }
            let (ma, mb) = (fab.metrics(), gold.metrics());
            assert_eq!(ma.rounds, mb.rounds);
            assert_eq!(ma.inter_bytes(), mb.inter_bytes(), "{mode:?}");
            assert_eq!(ma.intra_bytes(), mb.intra_bytes(), "{mode:?}");
            assert_eq!(ma.replays, mb.replays, "{mode:?}");
            assert_eq!(ma.resyncs, mb.resyncs, "{mode:?}");
        }
    }

    #[test]
    fn replica_restart_recovers_from_cursor() {
        let (template, snaps) = trained_snapshots(4, 150);
        let mut fab = fabric(UpdateMode::QuantPatch, 1, 2, &template);
        fab.publish(&snaps[0]).unwrap();
        fab.publish(&snaps[1]).unwrap();
        let ckpt = fab.checkpoint_replica(1);
        assert_eq!(ckpt.seq, 2);
        // two more rounds happen while the replica is "down", then it
        // restarts from its durable cursor and catches up
        fab.publish(&snaps[2]).unwrap();
        fab.publish(&snaps[3]).unwrap();
        let kind = fab.restart_replica(1, &ckpt).unwrap();
        assert!(matches!(kind, CatchUpKind::Replay { .. } | CatchUpKind::Resync { .. }));
        assert_eq!(fab.replicas()[1].seq(), fab.head());
        let reference = fab.reference().unwrap().pool.weights.clone();
        assert_eq!(fab.replicas()[1].model().pool.weights, reference);
    }

    #[test]
    fn fleet_obs_exports_health_retries_and_recovery() {
        let (template, snaps) = trained_snapshots(6, 120);
        let topo = Topology::uniform(1, 2, LinkSpec::wan(), LinkSpec::lan());
        let mut cfg = FleetConfig::new(topo, UpdateMode::QuantPatch);
        cfg.strategy = Strategy::Star;
        let mut fab = FleetFabric::new(cfg, &template);
        let reg = ObsRegistry::new();
        fab.set_obs(&reg);
        fab.publish(&snaps[0]).unwrap();
        fab.stall_replica(1, 3);
        for snap in &snaps[1..5] {
            fab.publish(snap).unwrap();
        }
        // replica 1 walked the ladder and was resurrected — all of it
        // visible in the shared registry
        assert_eq!(
            reg.gauge_value("fw_fleet_replica_health{replica=\"1\"}"),
            Some(0.0),
            "resurrected replica gauges healthy"
        );
        assert!(
            reg.counter_value("fw_fleet_health_transitions_total").unwrap() >= 3
        );
        assert!(reg.gauge_value("fw_fleet_publish_retries").unwrap() > 0.0);
        let recovered = reg
            .histogram_snapshot("fw_recovery_replay_ns")
            .expect("recovery histogram registered");
        assert!(recovered.count() >= 1, "probe recovery recorded");
        // snapshot export composes with the live handles on the same
        // registry (same names, same kinds — no collisions)
        fab.metrics().export_to(&reg);
        let text = reg.render_prometheus();
        crate::testutil::check_prometheus_text(&text).expect("well-formed");
        assert!(text.contains("fw_fleet_replica_health"));
        assert!(text.contains("fw_fleet_publish_retries"));
        assert!(text.contains("fw_recovery_replay_ns"));
    }
}
