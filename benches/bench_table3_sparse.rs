//! Table 3 — speedups from §4.3 sparse weight updates, by depth.
//!
//! Paper: 1.3× / 1.8× / 2.4× / 3.5× for 1–4 hidden layers (dense
//! backward vs ReLU-aware sparse backward).  The speedup must GROW
//! with depth: deeper nets have more dead-ReLU branches to skip.

use fwumious::config::ModelConfig;
use fwumious::data::synthetic::{DatasetSpec, SyntheticStream};
use fwumious::model::regressor::Regressor;
use fwumious::model::Workspace;
use fwumious::util::bench_env;
use fwumious::util::json::{arr, num, obj};
use fwumious::util::timer::median_time;

fn train_time(cfg: &ModelConfig, sparse: bool, data: &[fwumious::feature::Example]) -> f64 {
    median_time(1, 3, || {
        let mut c = cfg.clone();
        c.sparse_updates = sparse;
        let mut reg = Regressor::new(&c);
        let mut ws = Workspace::new();
        for ex in data {
            reg.learn(ex, &mut ws);
        }
        reg
    })
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let spec = DatasetSpec::criteo_like();
    let buckets = 1u32 << 16;
    // Production regime (§4.3): "deep layers, albeit being
    // parameter-wise in minority compared to FFM part, take up
    // considerable amount of time during optimization" — width 64
    // makes the neural block the dominant backward cost, as in the
    // paper's models.
    let width = 64;
    let n = 20_000;
    let mut s = SyntheticStream::with_buckets(spec.clone(), 17, buckets);
    let data = s.take_examples(n);

    println!("== Table 3: sparse-update speedups ({n} examples, width {width}) ==");
    println!(
        "{:<14} {:>10} {:>10} {:>9}",
        "#hidden", "dense", "sparse", "speedup"
    );
    let mut speedups = Vec::new();
    let mut rows = Vec::new();
    for layers in 1..=4usize {
        let hidden = vec![width; layers];
        let mut cfg = ModelConfig::deep_ffm(spec.fields(), 4, buckets, &hidden);
        cfg.power_t = 0.5; // sqrt fast path (production default)
        let dense = train_time(&cfg, false, &data);
        let sparse = train_time(&cfg, true, &data);
        let speedup = dense / sparse;
        speedups.push(speedup);
        println!(
            "{:<14} {:>9.3}s {:>9.3}s {:>8.2}x",
            layers, dense, sparse, speedup
        );
        rows.push(obj(vec![
            ("hidden_layers", num(layers as f64)),
            ("dense_seconds", num(dense)),
            ("sparse_seconds", num(sparse)),
            ("speedup", num(speedup)),
        ]));
    }
    println!("\npaper:          1.3x       1.8x       2.4x       3.5x");
    println!(
        "measured:       {}",
        speedups
            .iter()
            .map(|s| format!("{s:.2}x"))
            .collect::<Vec<_>>()
            .join("       ")
    );
    let monotone = speedups.windows(2).all(|w| w[1] >= w[0] * 0.92);
    println!(
        "speedup grows with depth: {}",
        if monotone { "yes ✓" } else { "no (investigate)" }
    );
    let path = bench_env::write_report(
        "table3_sparse",
        smoke,
        vec![
            ("examples", num(n as f64)),
            ("hidden_width", num(width as f64)),
            ("depths", arr(rows)),
            ("speedup_monotone", fwumious::util::json::Json::Bool(monotone)),
        ],
    );
    println!("report -> {path}");
}
