//! Namespace (field) descriptors: how raw input groups map onto the
//! model's FFM fields, including value transforms.
//!
//! The paper's preprocessing is deliberately minimal: "log transform of
//! continuous features was conducted and no additional data pruning".

use crate::feature::hash;

/// Value transform applied to a namespace's feature values.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Transform {
    /// Keep the parsed value (default 1.0 for bare categoricals).
    None,
    /// ln(1 + max(v, 0)) — the paper's continuous-feature treatment.
    Log1p,
    /// Clamp negatives to zero then sqrt (useful for count features).
    Sqrt,
    /// Treat as categorical: value forced to 1.0, the number becomes
    /// part of the token identity.
    Categorical,
}

impl Transform {
    #[inline]
    pub fn apply(&self, v: f32) -> f32 {
        match self {
            Transform::None => v,
            Transform::Log1p => (1.0 + v.max(0.0)).ln(),
            Transform::Sqrt => v.max(0.0).sqrt(),
            Transform::Categorical => 1.0,
        }
    }
}

/// One field's descriptor.
#[derive(Clone, Debug)]
pub struct Namespace {
    /// Single-letter name in the vw-format input (`|A ...`).
    pub name: String,
    /// Field index in the model.
    pub field: u16,
    /// Hash seed derived from the name.
    pub seed: u32,
    /// Value transform.
    pub transform: Transform,
}

/// The full input schema: an ordered set of namespaces.
#[derive(Clone, Debug)]
pub struct Schema {
    pub namespaces: Vec<Namespace>,
}

impl Schema {
    /// Build a schema from namespace names, all-categorical.
    pub fn categorical(names: &[&str]) -> Self {
        Schema {
            namespaces: names
                .iter()
                .enumerate()
                .map(|(i, n)| Namespace {
                    name: n.to_string(),
                    field: i as u16,
                    seed: hash::namespace_seed(n),
                    transform: Transform::Categorical,
                })
                .collect(),
        }
    }

    /// Criteo-style schema: `num_cont` Log1p namespaces then
    /// `num_cat` categorical ones, named I1.. / C1.. .
    pub fn ctr_style(num_cont: usize, num_cat: usize) -> Self {
        let mut namespaces = Vec::new();
        for i in 0..num_cont {
            let name = format!("I{}", i + 1);
            namespaces.push(Namespace {
                seed: hash::namespace_seed(&name),
                name,
                field: i as u16,
                transform: Transform::Log1p,
            });
        }
        for i in 0..num_cat {
            let name = format!("C{}", i + 1);
            namespaces.push(Namespace {
                seed: hash::namespace_seed(&name),
                name,
                field: (num_cont + i) as u16,
                transform: Transform::Categorical,
            });
        }
        Schema { namespaces }
    }

    pub fn fields(&self) -> usize {
        self.namespaces.len()
    }

    /// Find a namespace by name.
    pub fn by_name(&self, name: &str) -> Option<&Namespace> {
        self.namespaces.iter().find(|n| n.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transforms() {
        assert_eq!(Transform::None.apply(2.5), 2.5);
        assert!((Transform::Log1p.apply(0.0)).abs() < 1e-7);
        assert!((Transform::Log1p.apply((1f32).exp() - 1.0) - 1.0).abs() < 1e-6);
        assert_eq!(Transform::Log1p.apply(-5.0), 0.0);
        assert_eq!(Transform::Sqrt.apply(9.0), 3.0);
        assert_eq!(Transform::Categorical.apply(42.0), 1.0);
    }

    #[test]
    fn ctr_schema_layout() {
        let s = Schema::ctr_style(13, 26);
        assert_eq!(s.fields(), 39);
        assert_eq!(s.namespaces[0].name, "I1");
        assert_eq!(s.namespaces[0].transform, Transform::Log1p);
        assert_eq!(s.namespaces[13].name, "C1");
        assert_eq!(s.namespaces[13].transform, Transform::Categorical);
        assert_eq!(s.namespaces[38].field, 38);
    }

    #[test]
    fn by_name_lookup() {
        let s = Schema::categorical(&["A", "B", "C"]);
        assert_eq!(s.by_name("B").unwrap().field, 1);
        assert!(s.by_name("Z").is_none());
        // distinct hash seeds per namespace
        assert_ne!(s.namespaces[0].seed, s.namespaces[1].seed);
    }
}
