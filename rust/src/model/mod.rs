//! The DeepFFM model core: weight layout/pool, AdaGrad optimizer, and
//! the LR / FFM / neural blocks composed by [`regressor::Regressor`].
//!
//! Blocks mirror the structure of the production engine (block_ffm.rs,
//! block_neural.rs, regressor.rs in Fwumious Wabbit); each implements a
//! hand-derived backward pass and is validated by finite-difference
//! gradient checks in its unit tests.

pub mod block_ffm;
pub mod block_lr;
pub mod block_neural;
pub mod io;
pub mod optimizer;
pub mod regressor;
pub mod weights;

/// Batch-strided gradient scratch for the batched dense-tower backward
/// ([`block_neural::NeuralBlock::backward_batch`]): the upstream-
/// gradient ping-pong pair and the per-layer summed weight-gradient
/// accumulator.  Sized lazily, reused across micro-batches.
#[derive(Clone, Debug, Default)]
pub struct BatchGradBufs {
    /// dL/d(layer output), batch-strided `B × cols` (ping).
    pub dh: Vec<f32>,
    /// dL/d(layer input), batch-strided `B × rows` (pong).
    pub dx: Vec<f32>,
    /// Micro-batch-summed weight gradient for one layer (`rows × cols`).
    pub wgrad: Vec<f32>,
}

/// Reusable per-thread scratch space.  All forward/backward temporaries
/// live here so the hot path performs zero allocations per example (or,
/// on the batched scoring path, per *request*).
///
/// The batched candidate-scoring path
/// ([`regressor::Regressor::predict_batch_with_partial`]) and the
/// batched training path ([`regressor::Regressor::learn_batch`]) reuse
/// `pairs`, `merged`, `merged_raw`, `activations` and `dmerged`
/// **batch-strided**: `B` logical rows laid out back to back.  Every
/// element is rewritten on every call, so a single
/// workspace can be shared across models of different geometry (fields
/// / latent dim / hidden widths) without stale-buffer carry-over — a
/// regression test in `tests/props.rs` pins this.
#[derive(Clone, Debug, Default)]
pub struct Workspace {
    /// FFM pair interaction values, strict upper triangle, row-major
    /// (`B × P` batch-strided on the batched path).
    pub pairs: Vec<f32>,
    /// MergeNormLayer output [1 + P] (`B × (1+P)` batched).
    pub merged: Vec<f32>,
    /// Pre-norm merged vector (needed by the RMS-norm backward).
    pub merged_raw: Vec<f32>,
    /// RMS of merged_raw (last scored candidate on the batched path).
    pub rms: f32,
    /// Per-layer post-activation outputs (`B × cols` batched).
    pub activations: Vec<Vec<f32>>,
    /// LR block output.
    pub lr_out: f32,
    /// Final logit.
    pub logit: f32,
    /// Gradient scratch, one buffer per layer boundary.
    pub grad_bufs: Vec<Vec<f32>>,
    /// Gradient w.r.t. merged (post-norm).
    pub dmerged: Vec<f32>,
    /// Flattened candidate slots (`B × (F−C)`, candidate-major) for the
    /// batched partial kernel.
    pub cand_slots: Vec<crate::feature::FeatureSlot>,
    /// Per-candidate LR partial sums.
    pub batch_lr: Vec<f32>,
    /// Per-candidate horizontal-sum scratch (FFM logit / MergeNorm ssq).
    pub batch_acc: Vec<f32>,
    /// Per-candidate neural head outputs.
    pub batch_heads: Vec<f32>,
    /// Score buffer backing the single-candidate delegation.
    pub batch_scores: Vec<f32>,
    /// Per-chunk score scratch for the capped union-slate path
    /// ([`regressor::Regressor::predict_batch_with_partial_capped`]):
    /// the chunk loop scores into this buffer and appends to the
    /// caller's output, so a hot context's union slate never grows the
    /// batch-strided buffers beyond the configured cap.
    pub group_scores: Vec<f32>,
    /// Per-row MergeNorm RMS on the batched training path (the serving
    /// path only keeps the last row's RMS in `rms`).
    pub batch_rms: Vec<f32>,
    /// Per-example dL/dlogit on the batched training path.
    pub batch_d: Vec<f32>,
    /// Dense-tower backward scratch for the batched training path.
    pub batch_grads: BatchGradBufs,
}

impl Workspace {
    pub fn new() -> Self {
        Self::default()
    }
}
