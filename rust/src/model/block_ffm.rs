//! Field-aware factorization block (the red block of Figure 2):
//!
//! `ffm(w, x) = Σ_{j1<j2} ⟨w_{j1,f2}, w_{j2,f1}⟩ · x_{j1} x_{j2}`
//!
//! with the *DiagMask* — only the strict upper triangle of field pairs
//! is produced, "inducing half smaller number of combinations requiring
//! down-stream processing".
//!
//! Layout: the latent row of a bucket is `[fields * k]` floats,
//! field-major (`toward_field * k + kk`), so the inner dot product of a
//! pair is two contiguous stride-1 K-vectors — the property both the
//! CPU SIMD path (rust) and the Pallas kernel's VMEM tiling (python)
//! exploit.  Pair emission order (row-major upper triangle) is part of
//! the cross-layer ABI shared with `python/compile/kernels/ref.py`.

use crate::feature::Example;
use crate::model::optimizer::UpdateRule;
use crate::model::weights::Layout;
use crate::simd::dot;

/// Compute all pair interactions into `pairs` (len = F*(F-1)/2).
/// Returns the scalar FFM output (sum of pairs).
///
/// SIMD dispatch happens once per example (§5): the AVX2 kernels below
/// prefetch every latent row up front (the pair loop's gathers are the
/// dominant memory cost) and keep the whole O(F²) loop inside one
/// `#[target_feature]` region.
pub fn forward(
    weights: &[f32],
    layout: &Layout,
    fields: usize,
    k: usize,
    ex: &Example,
    pairs: &mut [f32],
) -> f32 {
    #[cfg(target_arch = "x86_64")]
    if crate::simd::isa_level() == crate::simd::IsaLevel::Avx2Fma
        && (k == 4 || k % 8 == 0)
    {
        return unsafe { forward_avx2(weights, layout, fields, k, ex, pairs) };
    }
    forward_generic(weights, layout, fields, k, ex, pairs)
}

/// Portable pair loop (also the SIMD-disabled control arm of Fig. 5).
pub fn forward_generic(
    weights: &[f32],
    layout: &Layout,
    fields: usize,
    k: usize,
    ex: &Example,
    pairs: &mut [f32],
) -> f32 {
    debug_assert_eq!(pairs.len(), fields * (fields - 1) / 2);
    let fk = fields * k;
    let base = layout.ffm_off;
    let mut total = 0.0f32;
    let mut p = 0;
    for i in 0..fields {
        let si = &ex.slots[i];
        if si.value == 0.0 {
            // whole row of pairs is zero
            for j in (i + 1)..fields {
                pairs[p] = 0.0;
                p += 1;
                let _ = j;
            }
            continue;
        }
        let row_i = base + si.bucket as usize * fk;
        for j in (i + 1)..fields {
            let sj = &ex.slots[j];
            if sj.value == 0.0 {
                pairs[p] = 0.0;
                p += 1;
                continue;
            }
            let row_j = base + sj.bucket as usize * fk;
            // ⟨w_{i, toward j}, w_{j, toward i}⟩
            let a = &weights[row_i + j * k..row_i + j * k + k];
            let b = &weights[row_j + i * k..row_j + i * k + k];
            let v = dot::dot(a, b) * si.value * sj.value;
            pairs[p] = v;
            total += v;
            p += 1;
        }
    }
    total
}

/// Whole-loop AVX2 kernel: prefetches all F latent rows, then runs the
/// masked pair loop with vector dots (SSE4.1 `dpps` for K=4, 256-bit
/// FMA + horizontal sum for K multiple of 8).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma,sse4.1")]
unsafe fn forward_avx2(
    weights: &[f32],
    layout: &Layout,
    fields: usize,
    k: usize,
    ex: &Example,
    pairs: &mut [f32],
) -> f32 {
    use std::arch::x86_64::*;
    let fk = fields * k;
    let base = layout.ffm_off;
    // Prefetch every row referenced by this example: the pair loop
    // reads F*(F-1) scattered K-strips; issuing the loads early
    // overlaps the misses with compute.
    for s in &ex.slots {
        if s.value != 0.0 {
            let row = weights.as_ptr().add(base + s.bucket as usize * fk);
            let mut off = 0usize;
            while off < fk {
                _mm_prefetch::<_MM_HINT_T0>(row.add(off) as *const i8);
                off += 16; // one cache line of f32
            }
        }
    }
    let mut total = 0.0f32;
    let mut p = 0usize;
    for i in 0..fields {
        let si = &ex.slots[i];
        if si.value == 0.0 {
            for _ in (i + 1)..fields {
                pairs[p] = 0.0;
                p += 1;
            }
            continue;
        }
        let row_i = weights.as_ptr().add(base + si.bucket as usize * fk);
        for j in (i + 1)..fields {
            let sj = &ex.slots[j];
            if sj.value == 0.0 {
                pairs[p] = 0.0;
                p += 1;
                continue;
            }
            let row_j = weights.as_ptr().add(base + sj.bucket as usize * fk);
            let a = row_i.add(j * k);
            let b = row_j.add(i * k);
            let d = if k == 4 {
                let va = _mm_loadu_ps(a);
                let vb = _mm_loadu_ps(b);
                _mm_cvtss_f32(_mm_dp_ps::<0xF1>(va, vb))
            } else {
                // k % 8 == 0
                let mut acc = _mm256_setzero_ps();
                let mut kk = 0;
                while kk < k {
                    let va = _mm256_loadu_ps(a.add(kk));
                    let vb = _mm256_loadu_ps(b.add(kk));
                    acc = _mm256_fmadd_ps(va, vb, acc);
                    kk += 8;
                }
                let hi = _mm256_extractf128_ps::<1>(acc);
                let lo = _mm256_castps256_ps128(acc);
                let s4 = _mm_add_ps(hi, lo);
                let s2 = _mm_add_ps(s4, _mm_movehl_ps(s4, s4));
                _mm_cvtss_f32(_mm_add_ss(s2, _mm_shuffle_ps::<1>(s2, s2)))
            };
            let v = d * si.value * sj.value;
            pairs[p] = v;
            total += v;
            p += 1;
        }
    }
    total
}

/// Partial pair computation for the §5 context cache: computes only
/// the pairs involving at least one CANDIDATE field (j >= ctx_len),
/// leaving the context×context entries of `pairs` untouched (the
/// caller fills those from the cached partial).  `all_slots` must hold
/// context slots in fields `0..ctx_len` and candidate slots after.
pub fn forward_partial(
    weights: &[f32],
    layout: &Layout,
    fields: usize,
    k: usize,
    ctx_len: usize,
    all_slots: &[crate::feature::FeatureSlot],
    pairs: &mut [f32],
) {
    #[cfg(target_arch = "x86_64")]
    if crate::simd::isa_level() == crate::simd::IsaLevel::Avx2Fma
        && (k == 4 || k % 8 == 0)
    {
        unsafe {
            forward_partial_avx2(weights, layout, fields, k, ctx_len, all_slots, pairs)
        };
        return;
    }
    forward_partial_generic(weights, layout, fields, k, ctx_len, all_slots, pairs);
}

/// Portable partial pair loop.
pub fn forward_partial_generic(
    weights: &[f32],
    layout: &Layout,
    fields: usize,
    k: usize,
    ctx_len: usize,
    all_slots: &[crate::feature::FeatureSlot],
    pairs: &mut [f32],
) {
    let fk = fields * k;
    let base = layout.ffm_off;
    for i in 0..fields {
        let si = &all_slots[i];
        let j0 = (i + 1).max(ctx_len);
        // row-major upper triangle: indices for fixed i are contiguous
        let row_base = i * (2 * fields - i - 1) / 2;
        if si.value == 0.0 {
            for j in j0..fields {
                pairs[row_base + (j - i - 1)] = 0.0;
            }
            continue;
        }
        let row_i = base + si.bucket as usize * fk;
        for j in j0..fields {
            let sj = &all_slots[j];
            let pi = row_base + (j - i - 1);
            if sj.value == 0.0 {
                pairs[pi] = 0.0;
                continue;
            }
            let row_j = base + sj.bucket as usize * fk;
            let a = &weights[row_i + j * k..row_i + j * k + k];
            let b = &weights[row_j + i * k..row_j + i * k + k];
            pairs[pi] = dot::dot(a, b) * si.value * sj.value;
        }
    }
}

/// AVX2 partial pair loop with candidate-row prefetch.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma,sse4.1")]
unsafe fn forward_partial_avx2(
    weights: &[f32],
    layout: &Layout,
    fields: usize,
    k: usize,
    ctx_len: usize,
    all_slots: &[crate::feature::FeatureSlot],
    pairs: &mut [f32],
) {
    use std::arch::x86_64::*;
    let fk = fields * k;
    let base = layout.ffm_off;
    for s in &all_slots[ctx_len..] {
        if s.value != 0.0 {
            let row = weights.as_ptr().add(base + s.bucket as usize * fk);
            let mut off = 0usize;
            while off < fk {
                _mm_prefetch::<_MM_HINT_T0>(row.add(off) as *const i8);
                off += 16;
            }
        }
    }
    for i in 0..fields {
        let si = &all_slots[i];
        let j0 = (i + 1).max(ctx_len);
        let row_base = i * (2 * fields - i - 1) / 2;
        if si.value == 0.0 {
            for j in j0..fields {
                pairs[row_base + (j - i - 1)] = 0.0;
            }
            continue;
        }
        let row_i = weights.as_ptr().add(base + si.bucket as usize * fk);
        for j in j0..fields {
            let sj = &all_slots[j];
            let pi = row_base + (j - i - 1);
            if sj.value == 0.0 {
                pairs[pi] = 0.0;
                continue;
            }
            let row_j = weights.as_ptr().add(base + sj.bucket as usize * fk);
            let a = row_i.add(j * k);
            let b = row_j.add(i * k);
            let d = if k == 4 {
                _mm_cvtss_f32(_mm_dp_ps::<0xF1>(_mm_loadu_ps(a), _mm_loadu_ps(b)))
            } else {
                let mut acc = _mm256_setzero_ps();
                let mut kk = 0;
                while kk < k {
                    acc = _mm256_fmadd_ps(
                        _mm256_loadu_ps(a.add(kk)),
                        _mm256_loadu_ps(b.add(kk)),
                        acc,
                    );
                    kk += 8;
                }
                let hi = _mm256_extractf128_ps::<1>(acc);
                let lo = _mm256_castps256_ps128(acc);
                let s4 = _mm_add_ps(hi, lo);
                let s2 = _mm_add_ps(s4, _mm_movehl_ps(s4, s4));
                _mm_cvtss_f32(_mm_add_ss(s2, _mm_shuffle_ps::<1>(s2, s2)))
            };
            pairs[pi] = d * si.value * sj.value;
        }
    }
}

/// Backward from per-pair gradients `dpairs` (same order as `forward`).
///
/// For pair (i, j):
///   d w_{i,j,kk} = dpair · w_{j,i,kk} · x_i x_j
///   d w_{j,i,kk} = dpair · w_{i,j,kk} · x_i x_j
///
/// Both sides read the *pre-update* latent values (copied to a small
/// stack buffer before updating), matching the analytic gradient.
pub fn backward<U: UpdateRule>(
    weights: &mut [f32],
    acc: &mut [f32],
    layout: &Layout,
    fields: usize,
    k: usize,
    ex: &Example,
    dpairs: &[f32],
    rule: &mut U,
) {
    debug_assert_eq!(dpairs.len(), fields * (fields - 1) / 2);
    let fk = fields * k;
    let base = layout.ffm_off;
    let mut buf = [0f32; 64];
    let mut p = 0;
    for i in 0..fields {
        let (vi, bi) = (ex.slots[i].value, ex.slots[i].bucket);
        for j in (i + 1)..fields {
            let g = dpairs[p];
            p += 1;
            let (vj, bj) = (ex.slots[j].value, ex.slots[j].bucket);
            if g == 0.0 || vi == 0.0 || vj == 0.0 {
                continue;
            }
            let scale = g * vi * vj;
            let off_i = base + bi as usize * fk + j * k;
            let off_j = base + bj as usize * fk + i * k;
            debug_assert!(k <= 64, "latent dim > stack buffer");
            buf[..k].copy_from_slice(&weights[off_i..off_i + k]);
            for kk in 0..k {
                let gj = scale * buf[kk]; // uses pre-update w_i
                let gi = scale * weights[off_j + kk];
                rule.update(off_i + kk, &mut weights[off_i + kk], &mut acc[off_i + kk], gi);
                rule.update(off_j + kk, &mut weights[off_j + kk], &mut acc[off_j + kk], gj);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::feature::{Example, FeatureSlot};
    use crate::model::optimizer::GradRecorder;
    use crate::model::weights::{Layout, WeightPool};
    use crate::util::rng::Pcg32;

    fn setup(fields: usize, k: usize) -> (ModelConfig, Layout, WeightPool, Example) {
        let cfg = ModelConfig::ffm(fields, k, 32);
        let layout = Layout::new(&cfg);
        let mut pool = WeightPool::init(&cfg, &layout);
        let mut rng = Pcg32::seeded(42);
        for w in &mut pool.weights[layout.ffm_off..] {
            *w = rng.normal() * 0.3;
        }
        let slots = (0..fields)
            .map(|f| FeatureSlot {
                field: f as u16,
                bucket: rng.below(32),
                value: 0.5 + rng.next_f32(),
            })
            .collect();
        (cfg, layout, pool, Example { label: 1.0, importance: 1.0, slots })
    }

    #[test]
    fn forward_matches_naive() {
        let (cfg, layout, pool, ex) = setup(5, 3);
        let mut pairs = vec![0f32; cfg.pairs()];
        let total = forward(&pool.weights, &layout, 5, 3, &ex, &mut pairs);
        // naive recomputation
        let fk = 5 * 3;
        let mut want_total = 0.0;
        let mut p = 0;
        for i in 0..5 {
            for j in (i + 1)..5 {
                let wi = layout.ffm_off + ex.slots[i].bucket as usize * fk + j * 3;
                let wj = layout.ffm_off + ex.slots[j].bucket as usize * fk + i * 3;
                let mut d = 0.0;
                for kk in 0..3 {
                    d += pool.weights[wi + kk] * pool.weights[wj + kk];
                }
                let v = d * ex.slots[i].value * ex.slots[j].value;
                assert!((pairs[p] - v).abs() < 1e-5, "pair {p}");
                want_total += v;
                p += 1;
            }
        }
        assert!((total - want_total).abs() < 1e-4);
    }

    #[test]
    fn simd_kernel_matches_generic() {
        for k in [4usize, 8, 16] {
            let (cfg, layout, pool, ex) = setup(5, k);
            let mut pairs_simd = vec![0f32; cfg.pairs()];
            let mut pairs_gen = vec![0f32; cfg.pairs()];
            let t1 = forward(&pool.weights, &layout, 5, k, &ex, &mut pairs_simd);
            let t2 =
                forward_generic(&pool.weights, &layout, 5, k, &ex, &mut pairs_gen);
            assert!((t1 - t2).abs() < 1e-4 * (1.0 + t2.abs()), "k={k}");
            for (a, b) in pairs_simd.iter().zip(&pairs_gen) {
                assert!((a - b).abs() < 1e-5, "k={k}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn absent_field_zeroes_its_pairs() {
        let (cfg, layout, pool, mut ex) = setup(4, 2);
        ex.slots[1].value = 0.0;
        let mut pairs = vec![0f32; cfg.pairs()];
        forward(&pool.weights, &layout, 4, 2, &ex, &mut pairs);
        // pairs touching field 1: (0,1)=idx0, (1,2)=idx3, (1,3)=idx4
        assert_eq!(pairs[0], 0.0);
        assert_eq!(pairs[3], 0.0);
        assert_eq!(pairs[4], 0.0);
        assert_ne!(pairs[1], 0.0); // (0,2)
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let (cfg, layout, mut pool, ex) = setup(4, 2);
        let f = |w: &[f32]| -> f32 {
            let mut pairs = vec![0f32; cfg.pairs()];
            // loss = weighted sum of pairs with fixed coefficients
            forward(w, &layout, 4, 2, &ex, &mut pairs);
            pairs
                .iter()
                .enumerate()
                .map(|(p, v)| (p as f32 * 0.3 - 0.7) * v)
                .sum()
        };
        let dpairs: Vec<f32> =
            (0..cfg.pairs()).map(|p| p as f32 * 0.3 - 0.7).collect();
        let mut rec = GradRecorder::default();
        let mut acc = pool.acc.clone();
        let w0 = pool.weights.clone();
        backward(&mut pool.weights, &mut acc, &layout, 4, 2, &ex, &dpairs, &mut rec);
        assert_eq!(pool.weights, w0, "recorder must not mutate");
        let analytic = rec.dense(layout.total);
        let eps = 1e-3;
        let mut checked = 0;
        for idx in layout.ffm_off..layout.total {
            if analytic[idx] == 0.0 {
                continue;
            }
            let mut wp = w0.clone();
            wp[idx] += eps;
            let mut wm = w0.clone();
            wm[idx] -= eps;
            let numeric = (f(&wp) - f(&wm)) / (2.0 * eps);
            assert!(
                (numeric - analytic[idx]).abs() < 2e-2 * (1.0 + numeric.abs()),
                "idx={idx} numeric={numeric} analytic={}",
                analytic[idx]
            );
            checked += 1;
        }
        assert!(checked >= 8, "checked only {checked} coords");
    }

    #[test]
    fn shared_bucket_pair_gradients_accumulate() {
        // Two fields hashed to the SAME bucket: gradients touch the
        // same latent row twice and must both apply.
        let cfg = ModelConfig::ffm(2, 2, 8);
        let layout = Layout::new(&cfg);
        let mut pool = WeightPool::init(&cfg, &layout);
        for (i, w) in pool.weights[layout.ffm_off..].iter_mut().enumerate() {
            *w = 0.1 * (i as f32 + 1.0);
        }
        let ex = Example {
            label: 1.0,
            importance: 1.0,
            slots: vec![
                FeatureSlot { field: 0, bucket: 3, value: 1.0 },
                FeatureSlot { field: 1, bucket: 3, value: 1.0 },
            ],
        };
        let mut rec = GradRecorder::default();
        let mut acc = pool.acc.clone();
        backward(&mut pool.weights, &mut acc, &layout, 2, 2, &ex, &[1.0], &mut rec);
        assert_eq!(rec.grads.len(), 4); // 2 sides * k=2
    }
}
