//! Table 4 — impact of model quantization + patching on the update
//! files of a production-shaped CTR model.
//!
//! Paper (per online update):
//!   no processing      —        100% size
//!   fw-quantization    —   2s,   50%
//!   fw-patcher         —  45s,  30±5%
//!   patcher + quant    —   8s,   3±2%
//!
//! We train a ~50 MB DeepFFM online and measure each mode's steady-
//! state update size (% of raw) and encode time across rounds.

use fwumious::config::ModelConfig;
use fwumious::data::synthetic::{DatasetSpec, SyntheticStream};
use fwumious::model::regressor::Regressor;
use fwumious::model::Workspace;
use fwumious::transfer::{UpdateMode, UpdatePipeline};
use fwumious::util::bench_env;
use fwumious::util::json::{arr, num, obj, s, Json};
use fwumious::util::timer::fmt_duration;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let spec = DatasetSpec::criteo_like();
    let buckets = 1u32 << 18;
    let cfg = ModelConfig::deep_ffm(spec.fields(), 4, buckets, &[16]);
    let mut reg = Regressor::new(&cfg);
    let mut ws = Workspace::new();
    let mut stream = SyntheticStream::with_buckets(spec, 23, buckets);

    // warm the model so weight files are dense/realistic
    for _ in 0..150_000 {
        let ex = stream.next_example();
        reg.learn(&ex, &mut ws);
    }
    let raw_bytes = fwumious::model::io::to_bytes(&reg, false).len();
    println!(
        "model: {} weights, raw inference file {:.1} MB (optimizer state already dropped: full training file would be 2x)",
        reg.num_weights(),
        raw_bytes as f64 / 1e6
    );
    println!("online round = 30k examples; 3 measured rounds after a warm round\n");
    println!(
        "{:<30} {:>12} {:>14} {:>10}",
        "weight processing", "avg time", "update size", "% of raw"
    );

    let rounds = 4; // first round bootstraps patch bases
    let per_round = 30_000;
    let mut order = Vec::new();
    let mut mode_rows = Vec::new();
    for mode in UpdateMode::ALL {
        let mut pipe = UpdatePipeline::new(mode);
        let mut model = reg.clone();
        let mut s2 = SyntheticStream::with_buckets(DatasetSpec::criteo_like(), 29, buckets);
        let mut sizes = Vec::new();
        let mut times = Vec::new();
        for round in 0..rounds {
            for _ in 0..per_round {
                let ex = s2.next_example();
                model.learn(&ex, &mut ws);
            }
            let u = pipe.encode(&model);
            if round > 0 {
                sizes.push(u.bytes.len() as f64);
                times.push(u.encode_seconds);
            }
        }
        let avg_size = sizes.iter().sum::<f64>() / sizes.len() as f64;
        let avg_time = times.iter().sum::<f64>() / times.len() as f64;
        println!(
            "{:<30} {:>12} {:>11.2} MB {:>9.2}%",
            mode.label(),
            fmt_duration(avg_time),
            avg_size / 1e6,
            avg_size / raw_bytes as f64 * 100.0
        );
        order.push((mode, avg_size));
        mode_rows.push(obj(vec![
            ("mode", s(mode.label())),
            ("avg_encode_seconds", num(avg_time)),
            ("avg_update_bytes", num(avg_size)),
            ("pct_of_raw", num(avg_size / raw_bytes as f64 * 100.0)),
        ]));
    }
    println!("\npaper shape: raw(100%) > quant(50%) > patch(30±5%) > quant+patch(3±2%)");
    let ok = order[0].1 > order[1].1
        && order[1].1 > order[3].1
        && order[2].1 > order[3].1;
    println!("ordering holds: {}", if ok { "yes ✓" } else { "no (investigate)" });
    let path = bench_env::write_report(
        "table4_quant",
        smoke,
        vec![
            ("raw_bytes", num(raw_bytes as f64)),
            ("rounds_measured", num((rounds - 1) as f64)),
            ("examples_per_round", num(per_round as f64)),
            ("modes", arr(mode_rows)),
            ("ordering_holds", Json::Bool(ok)),
        ],
    );
    println!("report -> {path}");
}
