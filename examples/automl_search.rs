//! AutoML model search (§2.2): parallel random search over DeepFFM
//! hyperparameters on a synthetic dataset, reporting each config's
//! stability statistics and the pooled Table-1-style row.
//!
//! ```bash
//! cargo run --release --example automl_search
//! ```

use std::sync::Arc;

use fwumious::automl::{pooled_stats, random_search, SearchSpace};
use fwumious::baselines::FwModel;
use fwumious::config::ModelConfig;
use fwumious::data::synthetic::{DatasetSpec, SyntheticStream};
use fwumious::model::regressor::Regressor;

fn main() {
    let spec = DatasetSpec::criteo_like();
    let buckets = 1u32 << 16;
    let fields = spec.fields();
    let mut s = SyntheticStream::with_buckets(spec.clone(), 5, buckets);
    let train = Arc::new(s.take_examples(120_000));
    let test = Arc::new(s.take_examples(30_000));
    let threads = std::thread::available_parallelism()
        .map(|n| n.get().min(8))
        .unwrap_or(4);
    let configs = 16;
    println!(
        "random search: {configs} DeepFFM configs × {} examples on {} ({} threads)",
        train.len(),
        spec.name,
        threads
    );

    let t = std::time::Instant::now();
    let results = random_search(
        &SearchSpace::default(),
        configs,
        threads,
        2024,
        train,
        test,
        30_000, // the paper's rolling window
        |c| {
            let mut cfg = ModelConfig::deep_ffm(fields, c.latent_dim, buckets, &c.hidden);
            cfg.lr = c.lr;
            cfg.ffm_lr = c.ffm_lr;
            cfg.nn_lr = c.nn_lr;
            cfg.power_t = c.power_t;
            cfg.l2 = c.l2;
            cfg.seed = c.seed;
            FwModel::new("FW-DeepFFM", Regressor::new(&cfg))
        },
    );
    println!("searched in {:.1}s\n", t.elapsed().as_secs_f64());

    println!(
        "{:<4} {:>5} {:>12} {:>6} {:>6} {:>7} {:>7} {:>8}",
        "id", "k", "hidden", "lr", "pt", "test", "avg", "logloss"
    );
    let mut best: Option<&fwumious::automl::RunResult> = None;
    for r in &results {
        println!(
            "{:<4} {:>5} {:>12} {:>6.3} {:>6.2} {:>7.4} {:>7.4} {:>8.4}",
            r.config.id,
            r.config.latent_dim,
            format!("{:?}", r.config.hidden),
            r.config.lr,
            r.config.power_t,
            r.stats.test,
            r.stats.avg,
            r.mean_logloss,
        );
        if best.map(|b| r.stats.test > b.stats.test).unwrap_or(true) {
            best = Some(r);
        }
    }
    let pooled = pooled_stats(&results);
    println!("\npooled   {}", pooled.row("FW-DeepFFM"));
    let best = best.unwrap();
    println!(
        "best: config {} (k={}, hidden {:?}, lr {:.3}) → test AUC {:.4}",
        best.config.id,
        best.config.latent_dim,
        best.config.hidden,
        best.config.lr,
        best.stats.test
    );
}
