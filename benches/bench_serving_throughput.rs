//! Headline claim — "more than 300m predictions per second" (fleet-
//! wide, CPU-only).
//!
//! Measures single-core and multi-worker candidate-scoring throughput
//! of the full serving engine (router → batcher → context cache → SIMD
//! forward) and extrapolates the core count needed for 300M preds/s.
//! The paper's fleet is hundreds of multi-core servers across DCs, so
//! the reproduced claim is "preds/s/core × fleet cores > 300M with a
//! plausible fleet".

use fwumious::config::{ModelConfig, ServeConfig};
use fwumious::data::synthetic::{DatasetSpec, SyntheticStream};
use fwumious::model::regressor::Regressor;
use fwumious::model::Workspace;
use fwumious::serve::router::Router;
use fwumious::serve::server::ServingEngine;
use fwumious::serve::trace::TraceGenerator;
use fwumious::serve::ModelHandle;

fn trained_model() -> Regressor {
    let spec = DatasetSpec::criteo_like();
    let buckets = 1u32 << 18;
    let cfg = ModelConfig::deep_ffm(spec.fields(), 4, buckets, &[16]);
    let mut reg = Regressor::new(&cfg);
    let mut ws = Workspace::new();
    let mut s = SyntheticStream::with_buckets(spec, 41, buckets);
    for _ in 0..60_000 {
        let ex = s.next_example();
        reg.learn(&ex, &mut ws);
    }
    reg
}

fn run_engine(reg: &Regressor, workers: usize, requests: usize, fanout: usize) -> (f64, f64) {
    let router = Router::new(workers);
    router.register("m", ModelHandle::new(reg.clone()));
    let engine = ServingEngine::start(
        router,
        ServeConfig {
            workers,
            max_batch: 256,
            max_wait_us: 200,
            context_cache_entries: 65_536,
        },
    );
    let fields = reg.cfg.fields;
    let mut gen = TraceGenerator::new(17, fields, fields / 2, reg.cfg.buckets, fanout);
    let reqs = gen.take(requests, "m");
    let t = std::time::Instant::now();
    let mut pending = Vec::with_capacity(1024);
    for (i, req) in reqs.into_iter().enumerate() {
        pending.push(engine.submit(req).expect("submit"));
        if pending.len() >= 1024 || i + 1 == requests {
            for rx in pending.drain(..) {
                rx.recv().unwrap().expect("score");
            }
        }
    }
    let secs = t.elapsed().as_secs_f64();
    let stats = engine.shutdown();
    assert_eq!(stats.errors, 0);
    (stats.candidates as f64 / secs, stats.cache_hit_rate())
}

fn main() {
    println!("== Headline: candidate-scoring throughput (SIMD {}) ==\n", fwumious::simd::isa_name());
    let reg = trained_model();
    println!(
        "model: DeepFFM {} fields, K=4, hidden [16], {:.0} MB weights",
        reg.cfg.fields,
        reg.num_weights() as f64 * 4.0 / 1e6
    );
    let fanout = 16;
    let max_workers = std::thread::available_parallelism()
        .map(|n| n.get().min(16))
        .unwrap_or(8);
    println!(
        "\n{:>8} {:>14} {:>16} {:>8}",
        "workers", "preds/s", "preds/s/core", "hit%"
    );
    let mut per_core_best = 0f64;
    let mut w = 1;
    while w <= max_workers {
        let requests = 6_000 * w;
        let (pps, hit) = run_engine(&reg, w, requests, fanout);
        per_core_best = per_core_best.max(pps / w as f64);
        println!(
            "{:>8} {:>14.0} {:>16.0} {:>7.1}%",
            w,
            pps,
            pps / w as f64,
            hit * 100.0
        );
        w *= 2;
    }
    println!(
        "\n→ 300M preds/s needs ≈{:.0} cores at the measured per-core rate;",
        300e6 / per_core_best
    );
    println!("  the paper's multi-DC fleet (hundreds of servers × tens of cores) clears that.");
}
