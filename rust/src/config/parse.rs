//! Key=value config parsing for the CLI and AutoML spec files.
//!
//! Format: one `key = value` per line, `#` comments, sections ignored.
//! This replaces a TOML/serde dependency (unavailable offline) with the
//! subset the launcher actually needs.

use std::collections::BTreeMap;

use crate::config::{Architecture, ConfigError, ModelConfig};

/// Parse `key = value` text into a map.
pub fn parse_kv(text: &str) -> BTreeMap<String, String> {
    let mut map = BTreeMap::new();
    for line in text.lines() {
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() || line.starts_with('[') {
            continue;
        }
        if let Some((k, v)) = line.split_once('=') {
            map.insert(k.trim().to_string(), v.trim().to_string());
        }
    }
    map
}

fn get_f32(
    map: &BTreeMap<String, String>,
    key: &'static str,
    default: f32,
) -> Result<f32, ConfigError> {
    match map.get(key) {
        Some(v) => v
            .parse()
            .map_err(|_| ConfigError::BadValue { key, got: v.clone() }),
        None => Ok(default),
    }
}

fn get_usize(
    map: &BTreeMap<String, String>,
    key: &'static str,
    default: usize,
) -> Result<usize, ConfigError> {
    match map.get(key) {
        Some(v) => v
            .parse()
            .map_err(|_| ConfigError::BadValue { key, got: v.clone() }),
        None => Ok(default),
    }
}

/// Build a [`ModelConfig`] from parsed keys, starting from defaults.
///
/// Recognized keys: `arch` (linear|ffm|deepffm), `fields`, `latent_dim`
/// (aka `k`), `bits` (buckets = 2^bits), `hidden` (comma list), `lr`,
/// `ffm_lr`, `nn_lr`, `power_t`, `l2`, `init_ffm`, `sparse_updates`,
/// `seed`.
pub fn model_config_from_kv(map: &BTreeMap<String, String>) -> Result<ModelConfig, ConfigError> {
    let fields = get_usize(map, "fields", 8)?;
    let latent = match map.get("latent_dim").or_else(|| map.get("k")) {
        Some(v) => v.parse().map_err(|_| ConfigError::BadValue {
            key: "latent_dim",
            got: v.clone(),
        })?,
        None => 4,
    };
    let bits = get_usize(map, "bits", 18)?;
    if bits > 30 {
        return Err(ConfigError::Invalid("bits too large (max 30)"));
    }
    let hidden: Vec<usize> = match map.get("hidden") {
        Some(v) if !v.is_empty() => v
            .split(',')
            .map(|t| {
                t.trim().parse().map_err(|_| ConfigError::BadValue {
                    key: "hidden",
                    got: v.clone(),
                })
            })
            .collect::<Result<_, _>>()?,
        _ => vec![16],
    };
    let arch = match map.get("arch").map(|s| s.as_str()) {
        None | Some("deepffm") => Architecture::DeepFfm,
        Some("ffm") => Architecture::Ffm,
        Some("linear") => Architecture::Linear,
        Some(other) => {
            return Err(ConfigError::UnknownValue {
                what: "arch",
                got: other.to_string(),
                want: "linear|ffm|deepffm",
            })
        }
    };
    let mut cfg = match arch {
        Architecture::DeepFfm => ModelConfig::deep_ffm(fields, latent, 1 << bits, &hidden),
        Architecture::Ffm | Architecture::Linear => {
            if map.contains_key("hidden") {
                return Err(ConfigError::Unsupported(format!(
                    "arch {arch:?} cannot take hidden layers"
                )));
            }
            if arch == Architecture::Ffm {
                ModelConfig::ffm(fields, latent, 1 << bits)
            } else {
                ModelConfig::linear(fields, 1 << bits)
            }
        }
    };
    cfg.lr = get_f32(map, "lr", cfg.lr)?;
    cfg.ffm_lr = get_f32(map, "ffm_lr", cfg.ffm_lr)?;
    cfg.nn_lr = get_f32(map, "nn_lr", cfg.nn_lr)?;
    cfg.power_t = get_f32(map, "power_t", cfg.power_t)?;
    cfg.l2 = get_f32(map, "l2", cfg.l2)?;
    cfg.init_ffm = get_f32(map, "init_ffm", cfg.init_ffm)?;
    if let Some(v) = map.get("sparse_updates") {
        cfg.sparse_updates = v == "true" || v == "1";
    }
    if let Some(v) = map.get("seed") {
        cfg.seed = v
            .parse()
            .map_err(|_| ConfigError::BadValue { key: "seed", got: v.clone() })?;
    }
    cfg.validate()?;
    Ok(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kv_parsing_with_comments() {
        let m = parse_kv("a = 1 # comment\n# whole line\n[section]\nb=x y\n");
        assert_eq!(m.get("a").unwrap(), "1");
        assert_eq!(m.get("b").unwrap(), "x y");
        assert_eq!(m.len(), 2);
    }

    #[test]
    fn full_model_config() {
        let m = parse_kv(
            "arch = deepffm\nfields = 10\nk = 8\nbits = 12\nhidden = 32,16\nlr = 0.2\npower_t = 0.5\nsparse_updates = false\nseed = 99\n",
        );
        let cfg = model_config_from_kv(&m).unwrap();
        assert_eq!(cfg.fields, 10);
        assert_eq!(cfg.latent_dim, 8);
        assert_eq!(cfg.buckets, 4096);
        assert_eq!(cfg.hidden, vec![32, 16]);
        assert_eq!(cfg.lr, 0.2);
        assert!(!cfg.sparse_updates);
        assert_eq!(cfg.seed, 99);
    }

    #[test]
    fn defaults_fill_in() {
        let cfg = model_config_from_kv(&parse_kv("")).unwrap();
        assert_eq!(cfg.fields, 8);
        assert_eq!(cfg.buckets, 1 << 18);
    }

    #[test]
    fn errors_reported() {
        assert!(model_config_from_kv(&parse_kv("arch = quantum")).is_err());
        assert!(model_config_from_kv(&parse_kv("lr = fast")).is_err());
        assert!(model_config_from_kv(&parse_kv("bits = 40")).is_err());
        // linear arch with explicit hidden -> validation error
        assert!(model_config_from_kv(&parse_kv("arch = linear\nhidden = 4")).is_err());
    }
}
