//! VW-linear baseline: hashed logistic regression with adaptive
//! (AdaGrad) per-coordinate learning rates — the core of Vowpal
//! Wabbit's default reduction, which FW derives from (§2.1).

use crate::baselines::OnlineModel;
use crate::feature::Example;
use crate::util::math::sigmoid;

/// Hashed adaptive logistic regression.
pub struct VwLinear {
    name: String,
    weights: Vec<f32>,
    acc: Vec<f32>,
    pub lr: f32,
    pub power_t: f32,
    pub l2: f32,
    mask: u32,
}

impl std::fmt::Debug for VwLinear {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("VwLinear").finish_non_exhaustive()
    }
}

impl VwLinear {
    pub fn new(buckets: u32, lr: f32, power_t: f32) -> Self {
        assert!(buckets.is_power_of_two());
        VwLinear {
            name: "VW-linear".into(),
            weights: vec![0.0; buckets as usize],
            acc: vec![1.0; buckets as usize],
            lr,
            power_t,
            l2: 0.0,
            mask: buckets - 1,
        }
    }

    #[inline]
    fn logit(&self, ex: &Example) -> f32 {
        let mut s = 0.0;
        for slot in &ex.slots {
            if slot.value != 0.0 {
                s += self.weights[(slot.bucket & self.mask) as usize] * slot.value;
            }
        }
        s
    }
}

impl OnlineModel for VwLinear {
    fn name(&self) -> &str {
        &self.name
    }

    fn learn(&mut self, ex: &Example) -> f32 {
        let p = sigmoid(self.logit(ex));
        let d = (p - ex.label) * ex.importance;
        if d != 0.0 {
            for slot in &ex.slots {
                if slot.value == 0.0 {
                    continue;
                }
                let i = (slot.bucket & self.mask) as usize;
                let g = d * slot.value + self.l2 * self.weights[i];
                self.acc[i] += g * g;
                let denom = if self.power_t == 0.5 {
                    self.acc[i].sqrt()
                } else {
                    self.acc[i].powf(self.power_t)
                };
                self.weights[i] -= self.lr * g / denom;
            }
        }
        p
    }

    fn predict(&mut self, ex: &Example) -> f32 {
        sigmoid(self.logit(ex))
    }

    fn num_weights(&self) -> usize {
        self.weights.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{DatasetSpec, SyntheticStream};
    use crate::eval::RollingAuc;

    #[test]
    fn learns_above_chance() {
        let mut m = VwLinear::new(256, 0.2, 0.5);
        let mut s = SyntheticStream::with_buckets(DatasetSpec::tiny(), 11, 256);
        let mut roll = RollingAuc::new(2000);
        for _ in 0..14_000 {
            let ex = s.next_example();
            let p = m.learn(&ex);
            roll.add(p, ex.label);
        }
        let last = *roll.points.last().unwrap();
        assert!(last > 0.60, "auc {last}");
    }

    #[test]
    fn prediction_pure() {
        let mut m = VwLinear::new(256, 0.2, 0.5);
        let mut s = SyntheticStream::with_buckets(DatasetSpec::tiny(), 12, 256);
        let ex = s.next_example();
        let a = m.predict(&ex);
        let b = m.predict(&ex);
        assert_eq!(a, b);
        assert_eq!(a, 0.5); // zero weights
    }
}
