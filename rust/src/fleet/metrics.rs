//! The fleet-wide distribution ledger.
//!
//! Every byte the fabric moves is accounted per link class (inter-DC
//! vs intra-DC, per data center), because the paper's §6 economics are
//! exactly this split: cross-DC bandwidth is the expensive resource the
//! quantize+patch pipeline exists to save, while intra-DC re-fan-out is
//! nearly free.  On top of the byte ledgers the fabric tracks the
//! operational health signals of a replicated deployment: publish lag
//! per replica, the worst version skew ever observed, and how often the
//! catch-up protocol had to replay patch chains or fall back to full
//! resyncs.

/// Byte/time/loss ledger of one simulated link.
#[derive(Clone, Copy, Debug, Default)]
pub struct LinkLedger {
    /// Bytes the sender pushed onto the link (lost shipments included —
    /// the sender pays for them either way).
    pub bytes: u64,
    /// Simulated wire seconds spent.
    pub seconds: f64,
    /// Shipments attempted.
    pub messages: u64,
    /// Shipments lost in transit.
    pub drops: u64,
}

impl LinkLedger {
    /// Account one shipment attempt.
    pub fn record(&mut self, len: usize, seconds: f64, delivered: bool) {
        self.bytes += len as u64;
        self.seconds += seconds;
        self.messages += 1;
        if !delivered {
            self.drops += 1;
        }
    }

    /// Fold another ledger into this one.
    pub fn absorb(&mut self, other: &LinkLedger) {
        self.bytes += other.bytes;
        self.seconds += other.seconds;
        self.messages += other.messages;
        self.drops += other.drops;
    }
}

/// Publish-lag accumulator for one replica.
#[derive(Clone, Copy, Debug, Default)]
pub struct LagStat {
    /// Updates this replica received through normal distribution or
    /// catch-up (duplicates excluded).
    pub publishes: u64,
    /// Sum of per-update publish lags (encode + wire path).
    pub total_seconds: f64,
    /// Lag of the most recent update.
    pub last_seconds: f64,
}

impl LagStat {
    pub fn record(&mut self, seconds: f64) {
        self.publishes += 1;
        self.total_seconds += seconds;
        self.last_seconds = seconds;
    }

    pub fn mean_seconds(&self) -> f64 {
        if self.publishes == 0 {
            0.0
        } else {
            self.total_seconds / self.publishes as f64
        }
    }
}

/// Snapshot of everything a fleet run has measured.
#[derive(Clone, Debug, Default)]
pub struct FleetMetrics {
    /// Publish rounds executed.
    pub rounds: u64,
    /// Worst `head_seq - replica_seq` observed at any round boundary.
    pub max_version_skew: u64,
    /// Catch-ups resolved by replaying retained chained patches.
    pub replays: u64,
    /// Catch-ups resolved by shipping a full snapshot.
    pub resyncs: u64,
    /// Rounds that ended with every replica at the head version.
    pub converged_rounds: u64,
    /// Publish shipment retry attempts (failed attempts that were
    /// given another try under the fabric's [`RetryPolicy`]).
    ///
    /// [`RetryPolicy`]: crate::fleet::RetryPolicy
    pub retries: u64,
    /// Publish shipments skipped because the target replica was
    /// Suspect/Dead (routed around instead of stalling on it).
    pub skipped_publishes: u64,
    /// Per-replica health state, gauge-encoded (0=healthy 1=lagging
    /// 2=suspect 3=dead), flattened DC-major.
    pub health: Vec<u8>,
    /// Per-replica publish lag (flattened DC-major, same order as
    /// [`crate::fleet::topology::Topology::replica_ids`]).
    pub lag: Vec<LagStat>,
    /// Per-DC trainer→DC (inter-DC) link ledgers.
    pub inter: Vec<LinkLedger>,
    /// Per-DC intra-DC re-distribution link ledgers.
    pub intra: Vec<LinkLedger>,
}

impl FleetMetrics {
    /// Total bytes pushed across data-center boundaries — the paper's
    /// headline cost metric, and what the route planner minimizes.
    pub fn inter_bytes(&self) -> u64 {
        self.inter.iter().map(|l| l.bytes).sum()
    }

    /// Total bytes re-distributed inside data centers.
    pub fn intra_bytes(&self) -> u64 {
        self.intra.iter().map(|l| l.bytes).sum()
    }

    /// Shipments lost across all links.
    pub fn drops(&self) -> u64 {
        self.inter.iter().chain(self.intra.iter()).map(|l| l.drops).sum()
    }

    /// Mean publish lag across replicas that received at least one
    /// update.
    pub fn mean_lag_seconds(&self) -> f64 {
        let live: Vec<&LagStat> =
            self.lag.iter().filter(|l| l.publishes > 0).collect();
        if live.is_empty() {
            0.0
        } else {
            live.iter().map(|l| l.mean_seconds()).sum::<f64>() / live.len() as f64
        }
    }

    /// Export this snapshot into a metrics registry.  Every sample is a
    /// gauge set from the snapshot's absolute values, so re-exporting
    /// after each round refreshes the same series instead of
    /// double-counting (the fabric is the source of truth; the registry
    /// is a view).
    pub fn export_to(&self, reg: &crate::obs::ObsRegistry) {
        reg.gauge("fw_fleet_rounds", "publish rounds executed")
            .set(self.rounds as f64);
        reg.gauge(
            "fw_fleet_max_version_skew",
            "worst head-replica version skew observed",
        )
        .set(self.max_version_skew as f64);
        reg.gauge("fw_fleet_replays", "catch-ups resolved by patch-chain replay")
            .set(self.replays as f64);
        reg.gauge("fw_fleet_resyncs", "catch-ups resolved by full snapshot")
            .set(self.resyncs as f64);
        reg.gauge("fw_fleet_converged_rounds", "rounds ending fully converged")
            .set(self.converged_rounds as f64);
        reg.gauge(
            "fw_fleet_publish_retries",
            "cumulative publish shipment retry attempts",
        )
        .set(self.retries as f64);
        reg.gauge(
            "fw_fleet_skipped_publishes",
            "publish shipments skipped for unhealthy replicas",
        )
        .set(self.skipped_publishes as f64);
        for (r, h) in self.health.iter().enumerate() {
            reg.gauge(
                &format!("fw_fleet_replica_health{{replica=\"{r}\"}}"),
                "replica health (0=healthy 1=lagging 2=suspect 3=dead)",
            )
            .set(*h as f64);
        }
        for (class, links) in [("inter", &self.inter), ("intra", &self.intra)] {
            for (dc, l) in links.iter().enumerate() {
                reg.gauge(
                    &format!("fw_fleet_link_bytes{{class=\"{class}\",dc=\"{dc}\"}}"),
                    "bytes pushed per link class and data center",
                )
                .set(l.bytes as f64);
                reg.gauge(
                    &format!("fw_fleet_link_drops{{class=\"{class}\",dc=\"{dc}\"}}"),
                    "shipments lost per link class and data center",
                )
                .set(l.drops as f64);
            }
        }
        for (r, lag) in self.lag.iter().enumerate() {
            reg.gauge(
                &format!("fw_fleet_replica_lag_seconds{{replica=\"{r}\"}}"),
                "mean publish lag per replica (seconds)",
            )
            .set(lag.mean_seconds());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_accounts_drops_and_bytes() {
        let mut l = LinkLedger::default();
        l.record(1000, 0.5, true);
        l.record(1000, 0.5, false);
        assert_eq!(l.bytes, 2000);
        assert_eq!(l.messages, 2);
        assert_eq!(l.drops, 1);
        let mut m = LinkLedger::default();
        m.absorb(&l);
        m.absorb(&l);
        assert_eq!(m.bytes, 4000);
        assert_eq!(m.drops, 2);
    }

    #[test]
    fn lag_stat_mean() {
        let mut s = LagStat::default();
        assert_eq!(s.mean_seconds(), 0.0);
        s.record(1.0);
        s.record(3.0);
        assert_eq!(s.publishes, 2);
        assert!((s.mean_seconds() - 2.0).abs() < 1e-12);
        assert_eq!(s.last_seconds, 3.0);
    }

    #[test]
    fn metrics_totals() {
        let mut m = FleetMetrics::default();
        m.inter = vec![LinkLedger::default(); 2];
        m.intra = vec![LinkLedger::default(); 2];
        m.inter[0].record(100, 0.1, true);
        m.inter[1].record(200, 0.1, false);
        m.intra[0].record(50, 0.01, true);
        assert_eq!(m.inter_bytes(), 300);
        assert_eq!(m.intra_bytes(), 50);
        assert_eq!(m.drops(), 1);
        m.lag = vec![LagStat::default(); 3];
        m.lag[0].record(2.0);
        m.lag[2].record(4.0);
        assert!((m.mean_lag_seconds() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn export_is_idempotent_and_labeled() {
        let mut m = FleetMetrics::default();
        m.rounds = 5;
        m.replays = 2;
        m.inter = vec![LinkLedger::default(); 2];
        m.intra = vec![LinkLedger::default(); 2];
        m.inter[1].record(4096, 0.2, true);
        m.lag = vec![LagStat::default(); 2];
        m.lag[0].record(1.5);
        let reg = crate::obs::ObsRegistry::new();
        m.export_to(&reg);
        m.export_to(&reg); // second export refreshes, never double-counts
        assert_eq!(reg.gauge_value("fw_fleet_rounds"), Some(5.0));
        assert_eq!(reg.gauge_value("fw_fleet_replays"), Some(2.0));
        assert_eq!(
            reg.gauge_value("fw_fleet_link_bytes{class=\"inter\",dc=\"1\"}"),
            Some(4096.0)
        );
        assert_eq!(
            reg.gauge_value("fw_fleet_replica_lag_seconds{replica=\"0\"}"),
            Some(1.5)
        );
        let text = reg.render_prometheus();
        crate::testutil::check_prometheus_text(&text).expect("well-formed");
        assert_eq!(text.matches("# TYPE fw_fleet_link_bytes gauge").count(), 1);
    }
}
