//! Data plane: synthetic CTR stream generators (substituting the
//! Criteo/Avazu/KDD2012 Kaggle dumps, see DESIGN.md §3), chunked
//! readers and the §4.1 asynchronous prefetcher.

pub mod prefetch;
pub mod synthetic;

use crate::feature::Example;

/// A source of training examples, consumed in chunks.  Implemented by
/// the synthetic generators and by file readers; the prefetcher wraps
/// any `DataSource` to overlap generation/IO with learning (§4.1).
pub trait DataSource: Send {
    /// Fill `out` with up to `n` examples; returns how many were
    /// produced.  0 means the stream is exhausted.
    fn next_chunk(&mut self, n: usize, out: &mut Vec<Example>) -> usize;
}

/// Adapter: any iterator of examples is a source.
pub struct IterSource<I: Iterator<Item = Example> + Send> {
    iter: I,
}

impl<I: Iterator<Item = Example> + Send> std::fmt::Debug for IterSource<I> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("IterSource").finish_non_exhaustive()
    }
}

impl<I: Iterator<Item = Example> + Send> IterSource<I> {
    pub fn new(iter: I) -> Self {
        IterSource { iter }
    }
}

impl<I: Iterator<Item = Example> + Send> DataSource for IterSource<I> {
    fn next_chunk(&mut self, n: usize, out: &mut Vec<Example>) -> usize {
        let mut produced = 0;
        for _ in 0..n {
            match self.iter.next() {
                Some(ex) => {
                    out.push(ex);
                    produced += 1;
                }
                None => break,
            }
        }
        produced
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::feature::Example;

    #[test]
    fn iter_source_chunks() {
        let exs: Vec<Example> = (0..10).map(|_| Example::empty(3)).collect();
        let mut src = IterSource::new(exs.into_iter());
        let mut buf = Vec::new();
        assert_eq!(src.next_chunk(4, &mut buf), 4);
        assert_eq!(src.next_chunk(4, &mut buf), 4);
        assert_eq!(src.next_chunk(4, &mut buf), 2);
        assert_eq!(src.next_chunk(4, &mut buf), 0);
        assert_eq!(buf.len(), 10);
    }
}
