//! Fleet-wide soak harness: the deployment-plane soak of
//! [`crate::deploy::harness`], scaled out to a multi-DC fleet with
//! fault injection.
//!
//! Runs N train→publish rounds through a [`FleetFabric`] while traffic
//! threads score a fixed probe set against **every replica's** serving
//! engine concurrently, then asserts the fleet invariants:
//!
//! 1. **No torn/mixed-version responses, fleet-wide** — every response
//!    from any replica matches the scores of exactly one published
//!    version (expected scores are registered before any replica can
//!    swap that version in).
//! 2. **Bit-identical convergence** — after the final catch-up, every
//!    replica's weights equal the reference receiver's bit for bit, in
//!    every update mode, even when shipments were force-dropped
//!    mid-run and replicas healed through replay/resync.
//! 3. **Catch-up actually runs** — injected drops leave version skew
//!    behind, and (for chained modes) the protocol repairs it.

use std::collections::HashSet;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, RwLock};

use crate::config::{ModelConfig, ServeConfig};
use crate::data::synthetic::{DatasetSpec, SyntheticStream};
use crate::deploy::harness::probe_scores;
use crate::fleet::{
    FleetConfig, FleetFabric, FleetMetrics, LinkSpec, RoundOutcome, Strategy,
    Topology,
};
use crate::model::regressor::Regressor;
use crate::serve::server::ServeClient;
use crate::serve::trace::TraceGenerator;
use crate::serve::Request;
use crate::train::hogwild::{train_chunk, HogwildConfig};
use crate::transfer::UpdateMode;

/// Fleet soak parameters.
#[derive(Clone, Debug)]
pub struct FleetSoakConfig {
    pub mode: UpdateMode,
    pub strategy: Strategy,
    /// Data centers (the ISSUE floor is 3).
    pub dcs: usize,
    /// Replicas per DC (floor 2).
    pub replicas_per_dc: usize,
    /// Train→publish rounds (floor 5).
    pub rounds: usize,
    pub examples_per_round: usize,
    pub train_threads: usize,
    /// Concurrent traffic-driver threads (each cycles over every
    /// replica's client).
    pub traffic_threads: usize,
    /// Distinct probe requests.
    pub probes: usize,
    /// Shipments force-dropped at the start of round `drop_round` —
    /// deterministic fault injection exercising the catch-up protocol.
    pub forced_drops: u32,
    pub drop_round: usize,
    pub seed: u64,
}

impl FleetSoakConfig {
    /// `cargo test`-sized but real: 3 DCs × 2 replicas, 5 rounds,
    /// 2 injected drops, live engines and concurrent traffic.
    pub fn quick(mode: UpdateMode) -> Self {
        FleetSoakConfig {
            mode,
            strategy: Strategy::Auto,
            dcs: 3,
            replicas_per_dc: 2,
            rounds: 5,
            examples_per_round: 1_200,
            train_threads: 2,
            traffic_threads: 2,
            probes: 12,
            forced_drops: 2,
            drop_round: 1,
            seed: 0xf1ee7,
        }
    }
}

/// Everything a fleet soak observed.
#[derive(Clone, Debug)]
pub struct FleetSoakReport {
    pub mode: UpdateMode,
    pub rounds: Vec<RoundOutcome>,
    /// Probe responses checked across all replicas and threads.
    pub probe_checks: u64,
    /// Responses matching NO published version (must be 0).
    pub torn_responses: u64,
    /// Distinct published versions observed being served.
    pub versions_observed: usize,
    /// Replicas that needed the end-of-run catch-up barrier.
    pub caught_up_at_converge: usize,
    /// Every replica's weights bit-identical to each other.
    pub replicas_bit_identical: bool,
    /// ... and to the reference receiver's reconstruction.
    pub replicas_match_reference: bool,
    /// Serving errors summed over replica engines.
    pub serve_errors: u64,
    pub metrics: FleetMetrics,
}

impl FleetSoakReport {
    /// Panic (with context) unless every fleet invariant held.
    pub fn assert_healthy(&self) {
        let mode = self.mode;
        assert_eq!(
            self.torn_responses, 0,
            "{mode:?}: {} of {} responses matched no published version",
            self.torn_responses, self.probe_checks
        );
        assert!(self.probe_checks > 0, "{mode:?}: no probes were scored");
        assert!(
            self.versions_observed >= 2,
            "{mode:?}: only {} version(s) served — no live swap observed",
            self.versions_observed
        );
        assert!(
            self.replicas_bit_identical,
            "{mode:?}: replicas diverged at convergence"
        );
        assert!(
            self.replicas_match_reference,
            "{mode:?}: converged replicas differ from the reference"
        );
        assert_eq!(self.serve_errors, 0, "{mode:?}: serving errors");
        if self.metrics.drops() > 0 {
            assert!(
                self.metrics.max_version_skew >= 1,
                "{mode:?}: drops happened but no skew was ever recorded"
            );
            if mode.is_chained() {
                assert!(
                    self.metrics.replays + self.metrics.resyncs >= 1,
                    "{mode:?}: chained mode dropped updates but never caught up"
                );
            }
        }
    }
}

/// Published versions: (seq, per-probe expected scores).  Seq 0 is the
/// bootstrap template every replica starts serving.
type Published = Arc<RwLock<Vec<(u64, Vec<Vec<f32>>)>>>;

fn traffic_driver(
    clients: Vec<ServeClient>,
    probes: Vec<Request>,
    published: Published,
    stop: Arc<AtomicBool>,
    offset: usize,
) -> (u64, u64, HashSet<u64>) {
    let mut checks = 0u64;
    let mut torn = 0u64;
    let mut versions = HashSet::new();
    let mut i = offset;
    // ordering: Relaxed — the flag only ends the loop; drivers join
    // afterwards, so no data is published through it.
    while !stop.load(Ordering::Relaxed) {
        let probe_idx = i % probes.len();
        let client = &clients[i % clients.len()];
        i += 1;
        let resp = match client.score(probes[probe_idx].clone()) {
            Ok(r) => r,
            Err(_) => break, // engines shut down under us
        };
        checks += 1;
        // Poison recovery: snapshots are appended whole under the
        // guard, so a poisoned lock still holds every complete entry.
        let reg = published.read().unwrap_or_else(|e| e.into_inner());
        match reg
            .iter()
            .rev()
            .find(|(_, scores)| scores[probe_idx] == resp.scores)
        {
            Some((seq, _)) => {
                versions.insert(*seq);
            }
            None => torn += 1,
        }
    }
    (checks, torn, versions)
}

/// Run one fleet soak; invariant verdicts live in the report (see
/// [`FleetSoakReport::assert_healthy`]).
pub fn run_fleet_soak(cfg: FleetSoakConfig) -> FleetSoakReport {
    // same 5-field tiny-shaped task as the single-pipe deploy soak
    let mut spec = DatasetSpec::tiny();
    spec.cat_fields = 4;
    let fields = spec.fields();
    let model_cfg = ModelConfig::deep_ffm(fields, 2, 1 << 12, &[8]);
    let mut trainer = Regressor::new(&model_cfg);
    let mut stream =
        SyntheticStream::with_buckets(spec, cfg.seed, model_cfg.buckets);

    let topo = Topology::uniform(
        cfg.dcs,
        cfg.replicas_per_dc,
        LinkSpec::wan(),
        LinkSpec::lan(),
    );
    let mut fcfg = FleetConfig::new(topo, cfg.mode);
    fcfg.strategy = cfg.strategy;
    fcfg.seed = cfg.seed ^ 0x11;
    fcfg.serve = Some(ServeConfig {
        workers: 1,
        max_batch: 32,
        max_wait_us: 100,
        context_cache_entries: 1_024,
        max_group_candidates: 1024,
        ..ServeConfig::default()
    });
    let model_name = fcfg.model_name.clone();
    let mut fabric = FleetFabric::new(fcfg, &trainer);

    // fixed probe set (2 context fields, 4 candidates each)
    let mut gen = TraceGenerator::new(
        cfg.seed ^ 0x7ea5,
        fields,
        2,
        model_cfg.buckets,
        4,
    );
    let probes: Vec<Request> = (0..cfg.probes.max(1))
        .map(|_| gen.next_request(&model_name))
        .collect();

    // register the bootstrap (seq 0) before any traffic flows
    let published: Published = Arc::new(RwLock::new(vec![(
        0,
        probe_scores(&trainer, &probes),
    )]));
    let stop = Arc::new(AtomicBool::new(false));

    let clients: Vec<ServeClient> = fabric
        .replicas()
        .iter()
        .map(|r| {
            // FleetSoakConfig always sets `serve` on the fleet config
            r.client()
                .unwrap_or_else(|| panic!("soak replica has no serving engine"))
        })
        .collect();
    let mut drivers = Vec::new();
    for t in 0..cfg.traffic_threads.max(1) {
        let clients = clients.clone();
        let probes = probes.clone();
        let published = published.clone();
        let stop = stop.clone();
        drivers.push(
            std::thread::Builder::new()
                .name(format!("fw-fleet-traffic-{t}"))
                .spawn(move || traffic_driver(clients, probes, published, stop, t))
                .unwrap_or_else(|e| {
                    // a soak without its drivers observes nothing
                    panic!("cannot spawn traffic driver {t}: {e}")
                }),
        );
    }

    let mut rounds = Vec::with_capacity(cfg.rounds);
    for r in 0..cfg.rounds {
        if r == cfg.drop_round {
            fabric.force_drops(cfg.forced_drops);
        }
        let chunk = stream.take_examples(cfg.examples_per_round);
        train_chunk(
            &mut trainer,
            &chunk,
            HogwildConfig { threads: cfg.train_threads.max(1) },
            1_000,
        );
        let published2 = published.clone();
        let probes_ref = &probes;
        let outcome = fabric
            .publish_with(&trainer, |seq, fresh| {
                let scores = probe_scores(fresh, probes_ref);
                // poison recovery: see `traffic_driver`
                published2
                    .write()
                    .unwrap_or_else(|e| e.into_inner())
                    .push((seq, scores));
            })
            .unwrap_or_else(|e| panic!("{:?} round {r}: {e}", cfg.mode));
        rounds.push(outcome);
    }

    // end-of-run barrier: every replica must reach the head version
    let caught_up_at_converge =
        fabric.converge().unwrap_or_else(|e| panic!("converge: {e}"));

    // convergence invariants (traffic still flowing)
    let reference = fabric
        .reference()
        .unwrap_or_else(|| {
            panic!("{:?}: no reference model after {} rounds", cfg.mode, cfg.rounds)
        })
        .pool
        .weights
        .clone();
    let first = fabric.replicas()[0].model().pool.weights.clone();
    let mut replicas_bit_identical = true;
    let mut replicas_match_reference = true;
    for rep in fabric.replicas() {
        assert_eq!(
            rep.seq(),
            fabric.head(),
            "{:?}: replica {:?} behind after converge",
            cfg.mode,
            rep.id
        );
        let model = rep.model();
        if model.pool.weights != first {
            replicas_bit_identical = false;
        }
        if model.pool.weights != reference {
            replicas_match_reference = false;
        }
    }

    // ordering: Relaxed — see the load in `traffic_driver`.
    stop.store(true, Ordering::Relaxed);
    let mut probe_checks = 0u64;
    let mut torn_responses = 0u64;
    let mut versions = HashSet::new();
    for d in drivers {
        let (c, t, v) = match d.join() {
            Ok(r) => r,
            // re-raise the driver's own panic (it carries the failed
            // invariant) instead of a generic join failure
            Err(payload) => std::panic::resume_unwind(payload),
        };
        probe_checks += c;
        torn_responses += t;
        versions.extend(v);
    }

    let metrics = fabric.metrics();
    let mode = cfg.mode;
    let serve_errors = fabric
        .shutdown()
        .into_iter()
        .flatten()
        .map(|s| s.errors)
        .sum();
    FleetSoakReport {
        mode,
        rounds,
        probe_checks,
        torn_responses,
        versions_observed: versions.len(),
        caught_up_at_converge,
        replicas_bit_identical,
        replicas_match_reference,
        serve_errors,
        metrics,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_fleet_soak_smoke() {
        // 2 rounds, 2 DCs only — the full ≥5-round ≥3-DC soaks for all
        // four modes run in tests/fleet_soak_e2e.rs
        let mut cfg = FleetSoakConfig::quick(UpdateMode::QuantPatch);
        cfg.rounds = 2;
        cfg.dcs = 2;
        cfg.examples_per_round = 600;
        cfg.forced_drops = 1;
        let report = run_fleet_soak(cfg);
        assert_eq!(report.rounds.len(), 2);
        assert_eq!(report.torn_responses, 0);
        assert!(report.replicas_bit_identical);
        assert!(report.replicas_match_reference);
        assert!(report.metrics.drops() >= 1);
    }
}
