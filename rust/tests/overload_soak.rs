//! Overload-plane soak: drive one worker far past saturation, watch
//! degraded mode engage, then trickle traffic and watch it disengage.
//!
//! The invariants under test:
//!
//! 1. A flood beyond capacity makes queue waits blow the SLO; the
//!    deadline pass fast-fails those requests (`DeadlineExpired`) and
//!    their waits push the windowed p99 over the SLO, so the
//!    hysteresis controller walks down the degrade ladder — visible in
//!    `ServeStats` as `degraded_transitions`/`degrade_level` and at
//!    the response level as truncated slates.
//! 2. Served latency stays bounded near the SLO even mid-flood: the
//!    deadline filter runs right before kernel work, so anything that
//!    reaches the scorer waited less than the SLO and only pays one
//!    batch of scoring on top.  Expired requests never pollute the
//!    latency histogram.
//! 3. When the flood stops, in-SLO trickle traffic re-arms the
//!    controller back to `Full`: the ladder disengages and full slates
//!    come back.

// Soak/e2e scale: far too slow under the Miri interpreter (~1000x);
// the nightly Miri job covers the scalar kernels and unit props
// instead.
#![cfg(not(miri))]

use fwumious::config::{ModelConfig, ServeConfig, ShedPolicy};
use fwumious::model::regressor::Regressor;
use fwumious::serve::router::Router;
use fwumious::serve::server::ServingEngine;
use fwumious::serve::trace::TraceGenerator;
use fwumious::serve::{ModelHandle, Request, ServeError};

const FANOUT: usize = 64;
const SLO_US: u64 = 10_000;
const DEGRADED_CAP: usize = 8;

#[test]
fn degraded_mode_engages_under_flood_and_disengages_on_trickle() {
    let cfg = ModelConfig::deep_ffm(6, 4, 1 << 12, &[16, 16]);
    let reg = Regressor::new(&cfg);
    let router = Router::new(1);
    router.register("m", ModelHandle::new(reg));
    let engine = ServingEngine::start(
        router,
        ServeConfig {
            workers: 1,
            max_batch: 256,
            max_wait_us: 100,
            context_cache_entries: 4_096,
            queue_depth: 16_384,
            shed_policy: ShedPolicy::RejectNew,
            request_slo_us: SLO_US,
            degraded_max_candidates: DEGRADED_CAP,
            ..ServeConfig::default()
        },
    );

    // Phase 1: flood.  Pre-generate so the burst hits the queue at
    // submit speed, far faster than one worker can score 64-candidate
    // DeepFFM slates — queue waits blow through the 10ms SLO.
    let mut gen = TraceGenerator::new(0x50a4, 6, 3, cfg.buckets, FANOUT);
    let flood: Vec<Request> = gen.take(8_000, "m");
    let rxs: Vec<_> = flood
        .into_iter()
        .map(|r| engine.submit(r).expect("queue_depth covers the flood"))
        .collect();
    let mut served_flood = 0u64;
    let mut expired = 0u64;
    for rx in rxs {
        match rx.recv().expect("worker replies") {
            Ok(_) => served_flood += 1,
            Err(ServeError::DeadlineExpired { waited_us, slo_us }) => {
                assert!(waited_us >= slo_us, "expired early: {waited_us} < {slo_us}");
                expired += 1;
            }
            Err(e) => panic!("unexpected flood error: {e}"),
        }
    }
    assert_eq!(served_flood + expired, 8_000);
    assert!(expired > 0, "flood never overran the SLO");

    // Degraded mode must be ENGAGED and visible in the stats now.
    // (Replies are emitted before the worker's stats update lands, so
    // give the final batch's counters a moment to settle.)
    let mut mid = engine.stats();
    let settle = std::time::Instant::now();
    while mid.deadline_expired != expired
        && settle.elapsed() < std::time::Duration::from_secs(2)
    {
        std::thread::sleep(std::time::Duration::from_millis(1));
        mid = engine.stats();
    }
    assert_eq!(mid.deadline_expired, expired);
    assert!(
        mid.degraded_transitions >= 1,
        "flood produced no degrade transition"
    );
    assert!(
        mid.degrade_level >= 1,
        "flood left the engine at Full ({})",
        mid.degrade_label()
    );

    // Phase 2: trickle.  Closed-loop, one request at a time — waits are
    // linger + one small batch, far under the recovery threshold.
    let mut lens = Vec::with_capacity(200);
    for _ in 0..200 {
        let resp = engine.score(gen.next_request("m")).expect("trickle serves");
        lens.push(resp.scores.len());
    }
    // Entered degraded: the first trickle slate is truncated.
    assert_eq!(
        lens[0], DEGRADED_CAP,
        "first trickle response should still be degraded"
    );
    // Left degraded: the ladder re-armed and full slates came back.
    assert_eq!(
        *lens.last().unwrap(),
        FANOUT,
        "slates never recovered to full fanout"
    );

    let stats = engine.shutdown();
    assert_eq!(stats.errors, 0);
    assert_eq!(
        stats.degrade_level, 0,
        "controller stuck at {} after trickle",
        stats.degrade_label()
    );
    assert!(
        stats.degraded_transitions >= 2,
        "expected engage + disengage, saw {} transition(s)",
        stats.degraded_transitions
    );
    // Histogram holds served requests only — expired never pollute it —
    // and the deadline filter bounds served latency near the SLO (one
    // batch of scoring on top of a sub-SLO wait).
    let hist = stats.latency.as_ref().expect("latency histogram");
    assert_eq!(hist.count(), stats.requests - stats.deadline_expired);
    assert_eq!(hist.count(), served_flood + 200);
    let p99_us = hist.quantile_ns(0.99) / 1e3;
    assert!(
        p99_us <= 3.0 * SLO_US as f64,
        "served p99 {p99_us:.0}us not bounded near the {SLO_US}us SLO"
    );
}
