//! One serving replica in the fleet: delta-chain version tracking
//! around an [`UpdateReceiver`], an atomically hot-swappable
//! [`ModelHandle`], and (optionally) a live [`ServingEngine`].
//!
//! The replica is where the chain discipline is enforced.  A byte
//! patch for round N only means anything against the base produced by
//! round N-1 — and because weight files keep a fixed length, applying
//! it to the *wrong* base would silently "succeed" and corrupt the
//! replica.  [`FleetReplica::deliver`] therefore gates every chained
//! update on the expected sequence number and reports a [`Gap`]
//! instead of touching the receiver, leaving the catch-up protocol
//! (replay or resync, see [`crate::fleet::FleetFabric::catch_up`]) to
//! heal the chain.
//!
//! [`Gap`]: ApplyVerdict::Gap

use std::sync::Arc;

use crate::config::ServeConfig;
use crate::model::regressor::Regressor;
use crate::serve::router::Router;
use crate::serve::server::{ServeClient, ServeStats, ServingEngine};
use crate::serve::ModelHandle;
use crate::transfer::{FleetError, UpdateMode, UpdateReceiver, WireUpdate};

use super::topology::ReplicaId;

/// What a delivery attempt did.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ApplyVerdict {
    /// The update advanced this replica to its sequence number.
    Applied,
    /// The replica already has this (or a newer) version; ignored.
    Duplicate,
    /// Chained update arrived out of sequence; the replica refused it
    /// (applying a patch against the wrong base would corrupt the
    /// weights) and needs the catch-up protocol.
    Gap,
}

/// One fleet replica: versioned receiver + serving slot.
pub struct FleetReplica {
    pub id: ReplicaId,
    receiver: UpdateReceiver,
    handle: ModelHandle,
    engine: Option<ServingEngine>,
    seq: u64,
}

impl std::fmt::Debug for FleetReplica {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FleetReplica").finish_non_exhaustive()
    }
}

impl FleetReplica {
    /// Bootstrap a replica from the structural template (the model
    /// every DC starts serving at version 0, before the first round).
    /// With `serve` set, a live engine is started and the replica's
    /// model registered under `model_name`.
    pub fn new(
        id: ReplicaId,
        mode: UpdateMode,
        template: &Regressor,
        serve: Option<&ServeConfig>,
        model_name: &str,
    ) -> Self {
        let mut receiver = UpdateReceiver::new(mode);
        receiver.set_template(template.clone());
        let handle = ModelHandle::new(template.clone());
        let engine = serve.map(|cfg| {
            let router = Router::new(cfg.workers);
            router.register(model_name, handle.clone());
            ServingEngine::start(router, cfg.clone())
        });
        FleetReplica { id, receiver, handle, engine, seq: 0 }
    }

    /// Last applied publish sequence (0 = still on the bootstrap).
    pub fn seq(&self) -> u64 {
        self.seq
    }

    /// The hot-swappable serving slot.
    pub fn handle(&self) -> &ModelHandle {
        &self.handle
    }

    /// Currently served model snapshot.
    pub fn model(&self) -> Arc<Regressor> {
        self.handle.load()
    }

    /// Traffic handle, when this replica serves.
    pub fn client(&self) -> Option<ServeClient> {
        self.engine.as_ref().map(|e| e.client())
    }

    /// Deliver publish `seq`.  Chained modes require exact sequence;
    /// full-file modes (raw/quant) may skip ahead, since every update
    /// is self-contained.
    pub fn deliver(
        &mut self,
        seq: u64,
        update: &WireUpdate,
    ) -> Result<ApplyVerdict, FleetError> {
        if seq <= self.seq {
            return Ok(ApplyVerdict::Duplicate);
        }
        if seq != self.seq + 1 && update.mode.is_chained() {
            return Ok(ApplyVerdict::Gap);
        }
        let fresh = self.receiver.apply(update)?;
        self.install(seq, fresh);
        Ok(ApplyVerdict::Applied)
    }

    /// Deliver a *folded* catch-up patch: one synthetic update composed
    /// from the retained chain ([`crate::patch::fold_chain`]) that
    /// rebases this replica from its current base straight to `seq`.
    /// The in-sequence gate is intentionally bypassed — the fabric
    /// folds the chain starting exactly at this replica's sequence, so
    /// the composed patch is valid against the current base even
    /// though it spans multiple publishes.
    pub fn deliver_jump(
        &mut self,
        seq: u64,
        update: &WireUpdate,
    ) -> Result<ApplyVerdict, FleetError> {
        if seq <= self.seq {
            return Ok(ApplyVerdict::Duplicate);
        }
        let fresh = self.receiver.apply(update)?;
        self.install(seq, fresh);
        Ok(ApplyVerdict::Applied)
    }

    /// Full-snapshot resync: jump straight to `seq` from the sender's
    /// base file, whatever state the chain was in.
    pub fn resync(&mut self, seq: u64, full_base: &[u8]) -> Result<(), FleetError> {
        let fresh = self.receiver.resync(full_base)?;
        self.install(seq, fresh);
        Ok(())
    }

    /// Restore a freshly constructed replica to a checkpointed
    /// position: install `base` (this replica's own receiver base at
    /// checkpoint time) at sequence `seq`.  `base == None` means the
    /// replica had never received an update — it stays on the
    /// bootstrap template at seq 0.  Because the base bytes *are* the
    /// chain state, the restored replica accepts the next chained
    /// update exactly as the crashed one would have.
    pub fn restore(
        &mut self,
        seq: u64,
        base: Option<&[u8]>,
    ) -> Result<(), FleetError> {
        match base {
            Some(bytes) => self.resync(seq, bytes),
            None => {
                if seq != 0 {
                    return Err(FleetError::Corrupt(format!(
                        "checkpoint claims seq {seq} with no base bytes"
                    )));
                }
                Ok(())
            }
        }
    }

    /// Receiver-side base file (bit-compared against the sender's in
    /// the soak invariants).
    pub fn base_bytes(&self) -> Option<&[u8]> {
        self.receiver.base_bytes()
    }

    fn install(&mut self, seq: u64, fresh: Regressor) {
        self.handle.swap(fresh);
        if let Some(engine) = &self.engine {
            engine.invalidate_caches();
        }
        self.seq = seq;
    }

    /// Stop serving; returns the engine's final statistics, if any.
    pub fn shutdown(self) -> Option<ServeStats> {
        self.engine.map(|e| e.shutdown())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::data::synthetic::{DatasetSpec, SyntheticStream};
    use crate::model::Workspace;
    use crate::transfer::UpdatePipeline;

    fn snapshots(n: usize) -> (Regressor, Vec<Regressor>) {
        let cfg = ModelConfig::ffm(4, 2, 1 << 9);
        let template = Regressor::new(&cfg);
        let mut reg = template.clone();
        let mut ws = Workspace::new();
        let mut s = SyntheticStream::with_buckets(DatasetSpec::tiny(), 5, 1 << 9);
        let mut out = Vec::new();
        for _ in 0..n {
            for _ in 0..250 {
                let ex = s.next_example();
                reg.learn(&ex, &mut ws);
            }
            out.push(reg.clone());
        }
        (template, out)
    }

    fn rid() -> ReplicaId {
        ReplicaId { dc: 0, replica: 0 }
    }

    #[test]
    fn in_order_chain_applies() {
        let (template, snaps) = snapshots(3);
        let mut pipe = UpdatePipeline::new(UpdateMode::QuantPatch);
        let mut rep =
            FleetReplica::new(rid(), UpdateMode::QuantPatch, &template, None, "m");
        assert_eq!(rep.seq(), 0);
        for (i, snap) in snaps.iter().enumerate() {
            let u = pipe.encode(snap);
            assert_eq!(rep.deliver(i as u64 + 1, &u).unwrap(), ApplyVerdict::Applied);
            assert_eq!(rep.seq(), i as u64 + 1);
        }
        assert_eq!(rep.base_bytes(), pipe.sent_bytes());
    }

    #[test]
    fn chained_gap_is_refused_and_base_untouched() {
        let (template, snaps) = snapshots(3);
        let mut pipe = UpdatePipeline::new(UpdateMode::PatchOnly);
        let mut rep =
            FleetReplica::new(rid(), UpdateMode::PatchOnly, &template, None, "m");
        let u1 = pipe.encode(&snaps[0]);
        let u2 = pipe.encode(&snaps[1]);
        let u3 = pipe.encode(&snaps[2]);
        assert_eq!(rep.deliver(1, &u1).unwrap(), ApplyVerdict::Applied);
        let base_before = rep.base_bytes().map(|b| b.to_vec());
        // drop u2, attempt u3: refused, state unchanged
        assert_eq!(rep.deliver(3, &u3).unwrap(), ApplyVerdict::Gap);
        assert_eq!(rep.seq(), 1);
        assert_eq!(rep.base_bytes().map(|b| b.to_vec()), base_before);
        // replaying the missed link heals the chain
        assert_eq!(rep.deliver(2, &u2).unwrap(), ApplyVerdict::Applied);
        assert_eq!(rep.deliver(3, &u3).unwrap(), ApplyVerdict::Applied);
        assert_eq!(rep.base_bytes(), pipe.sent_bytes());
        assert_eq!(
            rep.model().pool.weights,
            snaps[2].pool.weights,
            "patch chain must land on the trainer's weights"
        );
    }

    #[test]
    fn full_file_modes_skip_ahead() {
        for mode in [UpdateMode::Raw, UpdateMode::Quant] {
            let (template, snaps) = snapshots(3);
            let mut pipe = UpdatePipeline::new(mode);
            let mut rep = FleetReplica::new(rid(), mode, &template, None, "m");
            let _u1 = pipe.encode(&snaps[0]);
            let _u2 = pipe.encode(&snaps[1]);
            let u3 = pipe.encode(&snaps[2]);
            // u1/u2 never arrive; u3 is self-contained
            assert_eq!(rep.deliver(3, &u3).unwrap(), ApplyVerdict::Applied, "{mode:?}");
            assert_eq!(rep.seq(), 3);
        }
    }

    #[test]
    fn duplicates_and_stale_updates_ignored() {
        let (template, snaps) = snapshots(2);
        let mut pipe = UpdatePipeline::new(UpdateMode::Raw);
        let mut rep = FleetReplica::new(rid(), UpdateMode::Raw, &template, None, "m");
        let u1 = pipe.encode(&snaps[0]);
        let u2 = pipe.encode(&snaps[1]);
        assert_eq!(rep.deliver(1, &u1).unwrap(), ApplyVerdict::Applied);
        assert_eq!(rep.deliver(1, &u1).unwrap(), ApplyVerdict::Duplicate);
        assert_eq!(rep.deliver(2, &u2).unwrap(), ApplyVerdict::Applied);
        assert_eq!(rep.deliver(1, &u1).unwrap(), ApplyVerdict::Duplicate);
        assert_eq!(rep.seq(), 2);
    }

    #[test]
    fn resync_heals_a_broken_chain() {
        let (template, snaps) = snapshots(3);
        let mut pipe = UpdatePipeline::new(UpdateMode::QuantPatch);
        let mut rep =
            FleetReplica::new(rid(), UpdateMode::QuantPatch, &template, None, "m");
        let u1 = pipe.encode(&snaps[0]);
        rep.deliver(1, &u1).unwrap();
        let _u2 = pipe.encode(&snaps[1]);
        let u3 = pipe.encode(&snaps[2]);
        assert_eq!(rep.deliver(3, &u3).unwrap(), ApplyVerdict::Gap);
        rep.resync(3, pipe.sent_bytes().unwrap()).unwrap();
        assert_eq!(rep.seq(), 3);
        assert_eq!(rep.base_bytes(), pipe.sent_bytes());
    }

    #[test]
    fn serving_replica_swaps_on_install() {
        let (template, snaps) = snapshots(1);
        let mut pipe = UpdatePipeline::new(UpdateMode::Raw);
        let serve = ServeConfig {
            workers: 1,
            max_batch: 8,
            max_wait_us: 50,
            context_cache_entries: 64,
            max_group_candidates: 1024,
            ..ServeConfig::default()
        };
        let mut rep =
            FleetReplica::new(rid(), UpdateMode::Raw, &template, Some(&serve), "m");
        assert!(rep.client().is_some());
        let v0 = rep.handle().version();
        rep.deliver(1, &pipe.encode(&snaps[0])).unwrap();
        assert_eq!(rep.handle().version(), v0 + 1);
        let stats = rep.shutdown().unwrap();
        assert_eq!(stats.errors, 0);
    }
}
