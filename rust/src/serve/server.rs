//! The serving engine: a thread pool of scoring workers fed through
//! context-affinity shards, with dynamic batching, per-worker context
//! caches, hot model swapping, and latency metrics.
//!
//! Python is nowhere near this path: workers score through the native
//! Rust forward pass (SIMD-dispatched) against `Arc`-snapshotted weight
//! pools.  The same engine can host a PJRT-backed model through the
//! feature-gated `runtime` module for cross-validation deployments.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::ServeConfig;
use crate::model::Workspace;
use crate::serve::batcher::DynamicBatcher;
use crate::serve::context_cache::ContextCache;
use crate::serve::router::Router;
use crate::serve::{Request, Response};
use crate::util::histogram::LatencyHistogram;

/// Aggregated serving statistics.
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    pub requests: u64,
    pub candidates: u64,
    pub batches: u64,
    /// Context groups scored (each is one context-partial lookup and at
    /// most ⌈candidates / max_group_candidates⌉ kernel passes).
    pub groups: u64,
    /// Requests that shared their context group with at least one
    /// other request of the same flushed batch (cross-request
    /// coalescing wins; `requests - groups` over-counts error cases).
    pub coalesced_requests: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Live context-cache entries summed across workers (as of each
    /// worker's last scored batch).
    pub cache_entries: u64,
    pub errors: u64,
    pub latency: Option<LatencyHistogram>,
}

impl ServeStats {
    pub fn cache_hit_rate(&self) -> f64 {
        let t = self.cache_hits + self.cache_misses;
        if t == 0 {
            0.0
        } else {
            self.cache_hits as f64 / t as f64
        }
    }
}

struct Job {
    req: Request,
    enqueued: Instant,
    reply: SyncSender<Result<Response, String>>,
}

struct WorkerShared {
    stats: ServeStats,
}

/// Clonable request-submission handle onto a running engine.
///
/// The deployment plane's traffic drivers run on their own threads;
/// each owns a `ServeClient` clone (the worker senders are `Send` but
/// sharing one engine reference across threads is not required this
/// way).  Clones may outlive [`ServingEngine::shutdown`]: workers exit
/// on a stop flag rather than channel closure, and any submit after
/// shutdown returns an error instead of hanging.
#[derive(Clone)]
pub struct ServeClient {
    router: Router,
    senders: Vec<SyncSender<Job>>,
    stop: Arc<AtomicBool>,
}

impl ServeClient {
    /// Submit a request; returns the reply channel.
    pub fn submit(
        &self,
        req: Request,
    ) -> Result<Receiver<Result<Response, String>>, String> {
        if self.stop.load(Ordering::Acquire) {
            return Err("engine is shut down".to_string());
        }
        let shard = self.router.shard_for(&req) % self.senders.len();
        let (reply, rx) = sync_channel(1);
        self.senders[shard]
            .send(Job { req, enqueued: Instant::now(), reply })
            .map_err(|_| "engine is shut down".to_string())?;
        Ok(rx)
    }

    /// Score a request synchronously.
    pub fn score(&self, req: Request) -> Result<Response, String> {
        let rx = self.submit(req)?;
        rx.recv().map_err(|_| "worker dropped reply".to_string())?
    }
}

/// The serving engine.
pub struct ServingEngine {
    pub router: Router,
    cfg: ServeConfig,
    client: ServeClient,
    workers: Vec<JoinHandle<()>>,
    shared: Vec<Arc<Mutex<WorkerShared>>>,
    /// Bumped by [`invalidate_caches`](Self::invalidate_caches); workers
    /// clear their context caches when they observe a new epoch.
    cache_epoch: Arc<AtomicU64>,
}

impl ServingEngine {
    /// Spawn `cfg.workers` scoring threads.
    pub fn start(router: Router, cfg: ServeConfig) -> Self {
        let workers_n = cfg.workers.max(1);
        let cache_epoch = Arc::new(AtomicU64::new(0));
        let stop = Arc::new(AtomicBool::new(false));
        let mut senders = Vec::new();
        let mut workers = Vec::new();
        let mut shared = Vec::new();
        for w in 0..workers_n {
            let (tx, rx) = sync_channel::<Job>(4096);
            let sh = Arc::new(Mutex::new(WorkerShared {
                stats: ServeStats { latency: Some(LatencyHistogram::new()), ..Default::default() },
            }));
            let router = router.clone();
            let cfg = cfg.clone();
            let sh2 = sh.clone();
            let epoch = cache_epoch.clone();
            let stop2 = stop.clone();
            let handle = std::thread::Builder::new()
                .name(format!("fw-serve-{w}"))
                .spawn(move || worker_loop(rx, router, cfg, sh2, epoch, stop2))
                .expect("spawn worker");
            senders.push(tx);
            workers.push(handle);
            shared.push(sh);
        }
        let client = ServeClient { router: router.clone(), senders, stop };
        ServingEngine { router, cfg, client, workers, shared, cache_epoch }
    }

    /// Score a request synchronously.
    pub fn score(&self, req: Request) -> Result<Response, String> {
        self.client.score(req)
    }

    /// Submit a request; returns the reply channel.
    pub fn submit(
        &self,
        req: Request,
    ) -> Result<Receiver<Result<Response, String>>, String> {
        self.client.submit(req)
    }

    /// A clonable submission handle for traffic-driver threads.
    pub fn client(&self) -> ServeClient {
        self.client.clone()
    }

    /// Clear every worker's context cache (the §6 swap hook).
    ///
    /// Correctness never depends on this — cache keys embed the model
    /// version, so partials computed against swapped-out weights are
    /// unreachable the moment [`crate::serve::ModelHandle::swap`] bumps
    /// the version ("stale partials must never be served").  The epoch
    /// bump reclaims their memory immediately: any batch scored after a
    /// submit that follows this call sees the new epoch (channel send /
    /// receive orders the Release bump before the Acquire load).
    pub fn invalidate_caches(&self) {
        self.cache_epoch.fetch_add(1, Ordering::Release);
    }

    /// Aggregate statistics across workers.
    pub fn stats(&self) -> ServeStats {
        let mut out = ServeStats { latency: Some(LatencyHistogram::new()), ..Default::default() };
        for sh in &self.shared {
            let s = sh.lock().expect("stats lock");
            out.requests += s.stats.requests;
            out.candidates += s.stats.candidates;
            out.batches += s.stats.batches;
            out.groups += s.stats.groups;
            out.coalesced_requests += s.stats.coalesced_requests;
            out.cache_hits += s.stats.cache_hits;
            out.cache_misses += s.stats.cache_misses;
            out.cache_entries += s.stats.cache_entries;
            out.errors += s.stats.errors;
            if let (Some(a), Some(b)) = (out.latency.as_mut(), s.stats.latency.as_ref()) {
                a.merge(b);
            }
        }
        out
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// Drain queues, join workers, then report final statistics.
    ///
    /// Robust against leaked [`ServeClient`] clones: workers exit on
    /// the stop flag (draining what is already queued) even while
    /// clones keep the input channels open; later submits through a
    /// leftover clone fail with an error rather than hanging.
    pub fn shutdown(mut self) -> ServeStats {
        self.client.stop.store(true, Ordering::Release);
        self.client.senders.clear(); // closes channels unless clones remain
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        self.stats()
    }
}

/// Clear the worker's cache when the engine's epoch moved (model swap).
fn sync_cache_epoch(epoch: &AtomicU64, seen: &mut u64, cache: &mut ContextCache) {
    let e = epoch.load(Ordering::Acquire);
    if e != *seen {
        *seen = e;
        cache.clear();
    }
}

fn worker_loop(
    rx: Receiver<Job>,
    router: Router,
    cfg: ServeConfig,
    shared: Arc<Mutex<WorkerShared>>,
    epoch: Arc<AtomicU64>,
    stop: Arc<AtomicBool>,
) {
    let mut batcher: DynamicBatcher<(Instant, SyncSender<Result<Response, String>>)> =
        DynamicBatcher::new(cfg.max_batch, Duration::from_micros(cfg.max_wait_us));
    let mut cache = ContextCache::new(cfg.context_cache_entries);
    let mut seen_epoch = epoch.load(Ordering::Acquire);
    let mut ws = Workspace::new();
    loop {
        let wait = batcher
            .time_until_deadline()
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(wait) {
            Ok(job) => {
                let tag = (job.enqueued, job.reply);
                if let Some(batch) = batcher.push(job.req, tag) {
                    sync_cache_epoch(&epoch, &mut seen_epoch, &mut cache);
                    score_batch(batch, &router, &cfg, &mut cache, &mut ws, &shared);
                }
            }
            Err(RecvTimeoutError::Timeout) => {
                if stop.load(Ordering::Acquire) {
                    // shutdown with client clones still alive: drain
                    // whatever is already queued, then exit
                    while let Ok(job) = rx.try_recv() {
                        let tag = (job.enqueued, job.reply);
                        if let Some(batch) = batcher.push(job.req, tag) {
                            sync_cache_epoch(&epoch, &mut seen_epoch, &mut cache);
                            score_batch(batch, &router, &cfg, &mut cache, &mut ws, &shared);
                        }
                    }
                    if let Some(batch) = batcher.drain() {
                        sync_cache_epoch(&epoch, &mut seen_epoch, &mut cache);
                        score_batch(batch, &router, &cfg, &mut cache, &mut ws, &shared);
                    }
                    return;
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                if let Some(batch) = batcher.drain() {
                    sync_cache_epoch(&epoch, &mut seen_epoch, &mut cache);
                    score_batch(batch, &router, &cfg, &mut cache, &mut ws, &shared);
                }
                return;
            }
        }
        if let Some(batch) = batcher.poll_deadline() {
            sync_cache_epoch(&epoch, &mut seen_epoch, &mut cache);
            score_batch(batch, &router, &cfg, &mut cache, &mut ws, &shared);
        }
    }
}

/// Outcome counters of one coalesced scoring pass (observability).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CoalescePlan {
    /// Context groups planned over the slate.
    pub groups: u64,
    /// Requests that shared their group with at least one other.
    pub coalesced_requests: u64,
}

/// Score a flushed slate of requests with cross-request coalescing —
/// the flushed batch, not the request, is the unit of kernel work.
///
/// Requests are grouped by (model, context) via
/// [`crate::serve::batcher::context_groups`]; each group resolves its
/// model ONCE (one atomic (version, model) read — pairing version N
/// with model N+1 across a concurrent swap would mix stale cached
/// partials into fresh-model responses, see
/// [`crate::serve::ModelHandle`] docs), takes ONE context-cache
/// lookup/insert, and scores every member's candidates as one union
/// slate through `predict_batch_with_partial_capped` (chunked at
/// `max_group_candidates` so a hot context cannot blow the workspace).
/// Scores scatter back to per-request responses preserving request
/// order.
///
/// Error isolation is per request: a malformed request (bad candidate
/// width) fails alone — its group-mates still score.  Whole-group
/// failures (unknown model, context covering every field) are
/// per-request errors too, just identical ones.
///
/// By the kernels' batch-size-invariance contract the union-slate
/// scores are **bit-identical** to scoring each request through its
/// own `predict_batch_with_partial` call
/// (`prop_grouped_scoring_matches_per_request` pins this).
///
/// Results stream through `emit(request_index, result)` as soon as
/// they exist — validation errors immediately, scores right after
/// their group's kernel pass — so the engine replies to a request the
/// moment its group completes instead of after the whole slate (early
/// groups don't pay the later groups' scoring time in latency).
/// `emit` fires exactly once per request; across groups it follows
/// first-seen group order, within a group request order.
pub fn score_requests_coalesced_with(
    router: &Router,
    cache: &mut ContextCache,
    ws: &mut Workspace,
    max_group_candidates: usize,
    requests: &[Request],
    mut emit: impl FnMut(usize, Result<Response, String>),
) -> CoalescePlan {
    let mut plan = CoalescePlan::default();
    let mut scores: Vec<f32> = Vec::new();
    for group in crate::serve::batcher::context_groups(requests.iter()) {
        plan.groups += 1;
        if group.members.len() > 1 {
            plan.coalesced_requests += group.members.len() as u64;
        }
        let first = &requests[group.members[0]];
        let handle = match router.resolve(&first.model) {
            Some(h) => h,
            None => {
                for &i in &group.members {
                    emit(i, Err(format!("unknown model '{}'", first.model)));
                }
                continue;
            }
        };
        let (version, model) = handle.load_versioned();
        if first.context.len() >= model.cfg.fields {
            for &i in &group.members {
                emit(i, Err("context covers all fields; no candidate slots".into()));
            }
            continue;
        }
        let need = model.cfg.fields - first.context.len();
        // Per-request validation: one malformed request must not fail
        // its group-mates (it errors out immediately, alone).
        let mut valid = Vec::with_capacity(group.members.len());
        for &i in &group.members {
            match requests[i].candidates.iter().find(|c| c.len() != need) {
                Some(cand) => emit(
                    i,
                    Err(format!(
                        "candidate has {} slots, model needs {need}",
                        cand.len(),
                    )),
                ),
                None => valid.push(i),
            }
        }
        if valid.is_empty() {
            continue;
        }
        // ONE context-partial lookup/insert per group.
        let cp =
            cache.get_or_compute_named(&model, &first.model, version, &first.context);
        // Union slate: every valid member's candidates, request order.
        let mut slate: Vec<&[crate::feature::FeatureSlot]> =
            Vec::with_capacity(group.candidates);
        for &i in &valid {
            for cand in &requests[i].candidates {
                slate.push(cand.as_slice());
            }
        }
        model.predict_batch_with_partial_capped(
            &cp,
            &slate,
            max_group_candidates,
            ws,
            &mut scores,
        );
        // Scatter back, preserving request order within the group.
        let mut off = 0usize;
        for &i in &valid {
            let n = requests[i].candidates.len();
            emit(i, Ok(Response { scores: scores[off..off + n].to_vec() }));
            off += n;
        }
    }
    plan
}

/// [`score_requests_coalesced_with`] collecting results into a Vec
/// indexed like `requests` (tests, benches, batch-oriented callers).
pub fn score_requests_coalesced(
    router: &Router,
    cache: &mut ContextCache,
    ws: &mut Workspace,
    max_group_candidates: usize,
    requests: &[Request],
) -> (Vec<Result<Response, String>>, CoalescePlan) {
    let mut results: Vec<Option<Result<Response, String>>> = Vec::new();
    results.resize_with(requests.len(), || None);
    let plan = score_requests_coalesced_with(
        router,
        cache,
        ws,
        max_group_candidates,
        requests,
        |i, r| results[i] = Some(r),
    );
    let results = results
        .into_iter()
        .map(|r| r.expect("every request planned into a group"))
        .collect();
    (results, plan)
}

fn score_batch(
    batch: crate::serve::batcher::Batch<(Instant, SyncSender<Result<Response, String>>)>,
    router: &Router,
    cfg: &ServeConfig,
    cache: &mut ContextCache,
    ws: &mut Workspace,
    shared: &Arc<Mutex<WorkerShared>>,
) {
    let mut candidates = 0u64;
    let mut errors = 0u64;
    let mut hist = LatencyHistogram::new();
    let (hits0, misses0) = (cache.hits, cache.misses);

    #[allow(clippy::type_complexity)]
    let (reqs, tags): (
        Vec<Request>,
        Vec<(Instant, SyncSender<Result<Response, String>>)>,
    ) = batch.items.into_iter().unzip();
    // Streamed scatter: each request is answered the moment its group
    // completes, so requests in early groups don't pay the later
    // groups' scoring time in (real or recorded) latency.
    let mut tags: Vec<_> = tags.into_iter().map(Some).collect();
    let plan = score_requests_coalesced_with(
        router,
        cache,
        ws,
        cfg.max_group_candidates,
        &reqs,
        |i, result| {
            match &result {
                Ok(resp) => candidates += resp.scores.len() as u64,
                Err(_) => errors += 1,
            }
            let (enqueued, reply) =
                tags[i].take().expect("planner emits each request once");
            hist.record(enqueued.elapsed());
            let _ = reply.send(result); // receiver may have gone away
        },
    );

    let mut sh = shared.lock().expect("stats lock");
    sh.stats.requests += reqs.len() as u64;
    sh.stats.candidates += candidates;
    sh.stats.batches += 1;
    sh.stats.groups += plan.groups;
    sh.stats.coalesced_requests += plan.coalesced_requests;
    sh.stats.errors += errors;
    sh.stats.cache_hits += cache.hits - hits0;
    sh.stats.cache_misses += cache.misses - misses0;
    sh.stats.cache_entries = cache.entries() as u64;
    if let Some(l) = sh.stats.latency.as_mut() {
        l.merge(&hist);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::model::regressor::Regressor;
    use crate::serve::trace::TraceGenerator;
    use crate::serve::ModelHandle;

    fn engine(workers: usize, cache: usize) -> (ServingEngine, TraceGenerator) {
        let cfg = ModelConfig::deep_ffm(6, 2, 1 << 10, &[8]);
        let reg = Regressor::new(&cfg);
        let router = Router::new(workers);
        router.register("ctr", ModelHandle::new(reg));
        let serve_cfg = ServeConfig {
            workers,
            max_batch: 64,
            max_wait_us: 100,
            context_cache_entries: cache,
            max_group_candidates: 1024,
        };
        let gen = TraceGenerator::new(7, 6, 3, 1 << 10, 4);
        (ServingEngine::start(router, serve_cfg), gen)
    }

    #[test]
    fn scores_requests_end_to_end() {
        let (eng, mut gen) = engine(2, 1024);
        for _ in 0..200 {
            let req = gen.next_request("ctr");
            let n = req.candidates.len();
            let resp = eng.score(req).unwrap();
            assert_eq!(resp.scores.len(), n);
            assert!(resp.scores.iter().all(|s| (0.0..=1.0).contains(s)));
        }
        let stats = eng.shutdown();
        assert_eq!(stats.requests, 200);
        assert!(stats.candidates >= 200);
        assert!(stats.cache_hits + stats.cache_misses >= 200);
    }

    #[test]
    fn unknown_model_is_an_error_not_a_crash() {
        let (eng, mut gen) = engine(1, 0);
        let req = gen.next_request("nope");
        assert!(eng.score(req).is_err());
        let stats = eng.shutdown();
        assert_eq!(stats.errors, 1);
    }

    #[test]
    fn concurrent_submissions() {
        let (eng, mut gen) = engine(4, 1024);
        let reqs: Vec<Request> =
            (0..400).map(|_| gen.next_request("ctr")).collect();
        let rxs: Vec<_> = reqs
            .into_iter()
            .map(|r| {
                let n = r.candidates.len();
                (n, eng.submit(r).unwrap())
            })
            .collect();
        for (n, rx) in rxs {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.scores.len(), n);
        }
        let stats = eng.shutdown();
        assert_eq!(stats.requests, 400);
        assert!(stats.latency.unwrap().count() == 400);
    }

    #[test]
    fn hot_swap_serves_new_weights() {
        let cfg = ModelConfig::linear(4, 256);
        let reg0 = Regressor::new(&cfg);
        let router = Router::new(1);
        let handle = ModelHandle::new(reg0);
        router.register("m", handle.clone());
        let eng = ServingEngine::start(
            router,
            ServeConfig {
                workers: 1,
                max_batch: 8,
                max_wait_us: 50,
                context_cache_entries: 64,
                max_group_candidates: 1024,
            },
        );
        let mut gen = TraceGenerator::new(9, 4, 2, 256, 2);
        let req = gen.next_request("m");
        let before = eng.score(req.clone()).unwrap();
        // swap in a model with shifted LR weights -> all scores change
        let mut reg1 = Regressor::new(&cfg);
        for w in reg1.pool.weights.iter_mut() {
            *w = 0.5;
        }
        handle.swap(reg1);
        let after = eng.score(req).unwrap();
        assert_ne!(before, after);
        assert!(after.scores.iter().all(|&s| s > 0.6)); // positive weights
        eng.shutdown();
    }

    #[test]
    fn swap_never_serves_stale_partials() {
        // Regression test for the context_cache.rs invariant: after a
        // weight swap the engine must never serve partials computed
        // against the old weights.  Single worker, single repeated
        // context -> the cache is primed and hot before the swap.
        let cfg = ModelConfig::deep_ffm(6, 2, 1 << 10, &[8]);
        let reg0 = Regressor::new(&cfg);
        let handle = ModelHandle::new(reg0);
        let router = Router::new(1);
        router.register("m", handle.clone());
        let eng = ServingEngine::start(
            router,
            ServeConfig {
                workers: 1,
                max_batch: 8,
                max_wait_us: 50,
                context_cache_entries: 1024,
                max_group_candidates: 1024,
            },
        );
        let mut gen = TraceGenerator::new(17, 6, 3, 1 << 10, 4);
        let mut req = gen.next_request("m");
        // pin a single context so both pre-swap requests share it
        let r2 = gen.next_request("m");
        req.candidates.extend(r2.candidates);
        let before1 = eng.score(req.clone()).unwrap();
        let before2 = eng.score(req.clone()).unwrap();
        assert_eq!(before1, before2); // cache hit served identical scores

        // swap in visibly different weights
        let mut reg1 = Regressor::new(&cfg);
        for w in reg1.pool.weights.iter_mut() {
            *w = 0.25;
        }
        handle.swap(reg1);
        eng.invalidate_caches();

        let after = eng.score(req.clone()).unwrap();
        assert_ne!(before1, after, "stale partials served after swap");
        // scores must equal a fresh computation against the NEW model
        // through the same partial-forward path
        let current = handle.load();
        let mut ws = Workspace::new();
        let cp = current.context_partial(&req.context);
        for (i, cand) in req.candidates.iter().enumerate() {
            let direct = current.predict_with_partial(&cp, cand, &mut ws);
            assert_eq!(after.scores[i], direct, "candidate {i} mismatch");
        }
        let stats = eng.shutdown();
        // 1 miss (prime) + 1 hit (repeat) + 1 miss (post-swap recompute)
        assert_eq!(stats.cache_hits, 1);
        assert_eq!(stats.cache_misses, 2);
        // the epoch clear dropped the pre-swap entry: only the fresh one
        // remains live
        assert_eq!(stats.cache_entries, 1);
    }

    #[test]
    fn client_clones_submit_from_other_threads() {
        let (eng, mut gen) = engine(2, 1024);
        let reqs: Vec<Request> = (0..120).map(|_| gen.next_request("ctr")).collect();
        let mut joins = Vec::new();
        for t in 0..3 {
            let client = eng.client();
            let reqs = reqs.clone();
            joins.push(std::thread::spawn(move || {
                let mut scored = 0usize;
                for (i, req) in reqs.into_iter().enumerate() {
                    if i % 3 == t {
                        let resp = client.score(req).unwrap();
                        scored += resp.scores.len();
                    }
                }
                scored
            }));
        }
        let total: usize = joins.into_iter().map(|j| j.join().unwrap()).sum();
        assert!(total >= 120);
        let stats = eng.shutdown();
        assert_eq!(stats.requests, 120);
        assert_eq!(stats.errors, 0);
    }

    #[test]
    fn shutdown_does_not_hang_with_leaked_client() {
        let (eng, mut gen) = engine(2, 64);
        let leaked = eng.client();
        eng.score(gen.next_request("ctr")).unwrap();
        // the live clone keeps the channels open; workers must exit on
        // the stop flag anyway
        let stats = eng.shutdown();
        assert_eq!(stats.requests, 1);
        // post-shutdown submits through the leftover clone fail cleanly
        assert!(leaked.score(gen.next_request("ctr")).is_err());
    }

    #[test]
    fn coalesced_slate_matches_per_request_and_isolates_errors() {
        // one flushed slate: 3 requests sharing context A (one of them
        // malformed), 1 on context B, 1 for an unknown model.  The
        // malformed request and the unknown model fail ALONE; everyone
        // else scores bitwise what the per-request path produces.
        let cfg = ModelConfig::deep_ffm(6, 2, 1 << 10, &[8]);
        let reg = Regressor::new(&cfg);
        let router = Router::new(1);
        router.register("ctr", ModelHandle::new(reg.clone()));
        let mut gen = TraceGenerator::new(51, 6, 3, 1 << 10, 4);
        let a = gen.next_request("ctr");
        let b = gen.next_request("ctr");
        let mut a2 = gen.next_request("ctr");
        a2.context = a.context.clone();
        let mut bad = gen.next_request("ctr");
        bad.context = a.context.clone();
        let _ = bad.candidates[1].pop(); // wrong width: 2 slots, model needs 3
        let mut alien = gen.next_request("nope");
        alien.context = a.context.clone();
        let reqs = vec![a.clone(), bad.clone(), b.clone(), alien.clone(), a2.clone()];
        let mut cache = ContextCache::new(1024);
        let mut ws = Workspace::new();
        let (results, plan) = score_requests_coalesced(&router, &mut cache, &mut ws, 1024, &reqs);
        assert_eq!(results.len(), 5);
        // groups: A{a, bad, a2}, B{b}, alien (model name splits groups)
        assert_eq!(plan.groups, 3);
        assert_eq!(plan.coalesced_requests, 3);
        assert!(results[1].as_ref().unwrap_err().contains("2 slots"));
        assert!(results[3].as_ref().unwrap_err().contains("unknown model"));
        // survivors match the per-request batched path bitwise
        let mut ws_ref = Workspace::new();
        for (i, req) in [(0usize, &a), (2, &b), (4, &a2)] {
            let cp = reg.context_partial(&req.context);
            let mut want = Vec::new();
            reg.predict_batch_with_partial(&cp, &req.candidates, &mut ws_ref, &mut want);
            assert_eq!(
                results[i].as_ref().unwrap().scores,
                want,
                "request {i} diverged from the per-request path"
            );
        }
        // ONE cache lookup per group that reached scoring: A and B
        assert_eq!(cache.misses, 2);
        assert_eq!(cache.hits, 0);
        // a second identical slate hits both cached partials
        let (_, plan2) = score_requests_coalesced(&router, &mut cache, &mut ws, 1024, &reqs);
        assert_eq!(plan2, plan);
        assert_eq!(cache.misses, 2);
        assert_eq!(cache.hits, 2);
    }

    #[test]
    fn engine_coalesces_same_context_submissions() {
        // Same-context requests submitted together route to one shard
        // (context-affinity) and — whenever the batcher flushes them in
        // one batch — score as one group.  Responses must be correct
        // and per-request regardless of how the flushes land.
        let (eng, mut gen) = engine(1, 4096);
        let donor = gen.next_request("ctr");
        let reqs: Vec<Request> = (0..40)
            .map(|_| {
                let mut r = gen.next_request("ctr");
                r.context = donor.context.clone();
                r
            })
            .collect();
        let handle = eng.router.resolve("ctr").unwrap();
        let model = handle.load();
        let rxs: Vec<_> = reqs.iter().map(|r| eng.submit(r.clone()).unwrap()).collect();
        let mut ws = Workspace::new();
        let cp = model.context_partial(&donor.context);
        for (req, rx) in reqs.iter().zip(rxs) {
            let resp = rx.recv().unwrap().unwrap();
            let mut want = Vec::new();
            model.predict_batch_with_partial(&cp, &req.candidates, &mut ws, &mut want);
            assert_eq!(resp.scores, want);
        }
        let stats = eng.shutdown();
        assert_eq!(stats.requests, 40);
        assert_eq!(stats.errors, 0);
        // every batch planned at least one group, never more groups
        // than requests
        assert!(stats.groups >= stats.batches);
        assert!(stats.groups <= stats.requests);
        // one partial per (batch, context): misses+hits == groups here
        assert_eq!(stats.cache_hits + stats.cache_misses, stats.groups);
    }

    #[test]
    fn oversized_group_is_chunked_by_the_workspace_cap() {
        // max_group_candidates 4 with a 5-request / 20-candidate shared
        // context: scores must still be bitwise the uncapped ones.
        let cfg = ModelConfig::deep_ffm(6, 2, 1 << 10, &[8]);
        let reg = Regressor::new(&cfg);
        let router = Router::new(1);
        router.register("ctr", ModelHandle::new(reg.clone()));
        let mut gen = TraceGenerator::new(77, 6, 3, 1 << 10, 4);
        let donor = gen.next_request("ctr");
        let reqs: Vec<Request> = (0..5)
            .map(|_| {
                let mut r = gen.next_request("ctr");
                r.context = donor.context.clone();
                r
            })
            .collect();
        let mut ws = Workspace::new();
        let mut cache = ContextCache::new(64);
        let (capped, plan) = score_requests_coalesced(&router, &mut cache, &mut ws, 4, &reqs);
        let (uncapped, _) = score_requests_coalesced(
            &router,
            &mut cache,
            &mut ws,
            usize::MAX,
            &reqs,
        );
        assert_eq!(plan.groups, 1);
        assert_eq!(plan.coalesced_requests, 5);
        for (a, b) in capped.iter().zip(&uncapped) {
            assert_eq!(a.as_ref().unwrap().scores, b.as_ref().unwrap().scores);
        }
    }

    #[test]
    fn cache_hits_accumulate_on_zipf_contexts() {
        let (eng, mut gen) = engine(1, 4096);
        for _ in 0..500 {
            let req = gen.next_request("ctr");
            eng.score(req).unwrap();
        }
        let stats = eng.shutdown();
        assert!(
            stats.cache_hits > 100,
            "hit rate {} too low",
            stats.cache_hit_rate()
        );
    }
}
