//! Baseline engines for the §2.2 benchmark (Table 1 / Figure 3):
//! VW-style linear and MLP models, and a native-Rust DCNv2.
//!
//! All engines (including the FW regressor) implement [`OnlineModel`]
//! so the benchmark harness can drive them uniformly, single-pass with
//! progressive validation — the paper's protocol.

pub mod dcnv2;
pub mod vw_linear;
pub mod vw_mlp;

use crate::feature::Example;
use crate::model::regressor::Regressor;
use crate::model::Workspace;

/// A single-pass online binary classifier.
pub trait OnlineModel: Send {
    /// Name used in report rows ("FW-DeepFFM", "VW-linear", ...).
    fn name(&self) -> &str;
    /// Learn one example, returning the pre-update prediction.
    fn learn(&mut self, ex: &Example) -> f32;
    /// Predict without learning.
    fn predict(&mut self, ex: &Example) -> f32;
    /// Parameter count (for reports).
    fn num_weights(&self) -> usize;
}

/// FW engines (our regressor) as an [`OnlineModel`].
pub struct FwModel {
    pub name: String,
    pub reg: Regressor,
    ws: Workspace,
}

impl std::fmt::Debug for FwModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FwModel").finish_non_exhaustive()
    }
}

impl FwModel {
    pub fn new(name: &str, reg: Regressor) -> Self {
        FwModel { name: name.to_string(), reg, ws: Workspace::new() }
    }
}

impl OnlineModel for FwModel {
    fn name(&self) -> &str {
        &self.name
    }

    fn learn(&mut self, ex: &Example) -> f32 {
        self.reg.learn(ex, &mut self.ws)
    }

    fn predict(&mut self, ex: &Example) -> f32 {
        self.reg.predict(ex, &mut self.ws)
    }

    fn num_weights(&self) -> usize {
        self.reg.num_weights()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::data::synthetic::{DatasetSpec, SyntheticStream};

    #[test]
    fn fw_model_wraps_regressor() {
        let cfg = ModelConfig::ffm(4, 2, 256);
        let mut m = FwModel::new("FW-FFM", Regressor::new(&cfg));
        assert_eq!(m.name(), "FW-FFM");
        assert!(m.num_weights() > 0);
        let mut s = SyntheticStream::with_buckets(DatasetSpec::tiny(), 2, 256);
        let ex = s.next_example();
        let p1 = m.predict(&ex);
        let p2 = m.learn(&ex);
        assert_eq!(p1, p2);
    }
}
