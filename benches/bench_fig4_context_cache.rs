//! Figure 4 — impact of context caching on inference time (§5).
//!
//! "FW does an additional pass only with the context part, where it
//! identifies and caches frequent parts of the context.  On subsequent
//! candidate passes it reuses this information on-the-fly instead of
//! re-calculating it for each context-candidate pair."
//!
//! The no-cache arm therefore performs the *full* per-candidate
//! pipeline the pre-§5 engine did: hash the context features, assemble
//! the example, run the complete forward.  The cached arm keys the
//! radix tree on the raw context bytes, so a hit skips context
//! hashing, slot assembly and the context part of the forward pass.
//! Expected: clear per-candidate speedup, growing with context
//! repetition (smaller / more skewed context universes).

use fwumious::config::ModelConfig;
use fwumious::data::synthetic::{DatasetSpec, SyntheticStream};
use fwumious::feature::{hash, Example, FeatureSlot};
use fwumious::model::regressor::Regressor;
use fwumious::model::Workspace;
use fwumious::serve::context_cache::ContextCache;
use fwumious::util::bench_env;
use fwumious::util::json::{arr, num, obj};
use fwumious::util::rng::{Pcg32, Zipf};
use fwumious::util::timer::median_time;

/// Raw (unhashed) request: context ids + candidate id groups.
struct RawRequest {
    ctx_ids: Vec<u64>,
    cand_ids: Vec<Vec<u64>>,
}

fn gen_trace(
    n: usize,
    ctx_fields: usize,
    cand_fields: usize,
    fanout: usize,
    universe: u64,
    zipf_s: f64,
) -> Vec<RawRequest> {
    let mut rng = Pcg32::seeded(99);
    let ctx_zipf = Zipf::new(universe, zipf_s);
    let cand_zipf = Zipf::new(100_000, 1.1);
    (0..n)
        .map(|_| {
            let cid = ctx_zipf.sample(&mut rng);
            let ctx_ids = (0..ctx_fields)
                .map(|f| cid.wrapping_mul(0x9e37_79b9).wrapping_add(f as u64))
                .collect();
            let cand_ids = (0..fanout)
                .map(|_| {
                    let k = cand_zipf.sample(&mut rng);
                    (0..cand_fields)
                        .map(|f| k.wrapping_mul(0xdead_beef).wrapping_add(f as u64))
                        .collect()
                })
                .collect();
            RawRequest { ctx_ids, cand_ids }
        })
        .collect()
}

#[inline]
fn hash_slots(ids: &[u64], first_field: usize, mask: u32, out: &mut Vec<FeatureSlot>) {
    for (i, &id) in ids.iter().enumerate() {
        let field = (first_field + i) as u16;
        out.push(FeatureSlot {
            field,
            bucket: hash::id_bucket(field as u32 + 1, id, mask),
            value: 1.0,
        });
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let spec = DatasetSpec::criteo_like();
    let buckets = 1u32 << 18;
    let mask = buckets - 1;
    let fields = spec.fields();
    let ctx_fields = 8; // large context (user/page), small candidate part
    let cand_fields = fields - ctx_fields;
    let cfg = ModelConfig::deep_ffm(fields, 4, buckets, &[16]);
    let mut reg = Regressor::new(&cfg);
    let mut ws = Workspace::new();
    let mut s = SyntheticStream::with_buckets(spec, 31, buckets);
    for _ in 0..80_000 {
        let ex = s.next_example();
        reg.learn(&ex, &mut ws);
    }

    let requests = 4_000;
    let fanout = 16;
    println!("== Figure 4: context caching impact (fields={fields}, ctx={ctx_fields}, fanout={fanout}) ==");
    println!(
        "{:<26} {:>12} {:>12} {:>9} {:>8}",
        "context universe", "no-cache", "cached", "speedup", "hit%"
    );

    let mut rows = Vec::new();
    for (universe, zipf_s) in [(100u64, 1.3), (1_000, 1.2), (10_000, 1.1), (100_000, 1.05)] {
        let trace = gen_trace(requests, ctx_fields, cand_fields, fanout, universe, zipf_s);

        // no cache: per candidate — hash context + candidate, assemble,
        // full forward (the pre-§5 engine)
        let no_cache = median_time(1, 3, || {
            let mut total = 0.0f32;
            let mut full = Example::empty(fields);
            for req in &trace {
                for cand in &req.cand_ids {
                    full.slots.clear();
                    hash_slots(&req.ctx_ids, 0, mask, &mut full.slots);
                    hash_slots(cand, ctx_fields, mask, &mut full.slots);
                    total += reg.predict(&full, &mut ws);
                }
            }
            total
        });

        // cached: raw context bytes key the radix tree; hits skip
        // context hashing + assembly + context-partial computation
        let mut hit_rate = 0.0;
        let cached = median_time(1, 3, || {
            let mut cache = ContextCache::new(1 << 16);
            let mut total = 0.0f32;
            let mut key = Vec::with_capacity(ctx_fields * 8);
            let mut cand_slots = Vec::with_capacity(cand_fields);
            for req in &trace {
                key.clear();
                for id in &req.ctx_ids {
                    key.extend_from_slice(&id.to_le_bytes());
                }
                let cp = cache.get_or_compute_keyed(&key, || {
                    let mut ctx_slots = Vec::with_capacity(ctx_fields);
                    hash_slots(&req.ctx_ids, 0, mask, &mut ctx_slots);
                    reg.context_partial(&ctx_slots)
                });
                for cand in &req.cand_ids {
                    cand_slots.clear();
                    hash_slots(cand, ctx_fields, mask, &mut cand_slots);
                    total += reg.predict_with_partial(&cp, &cand_slots, &mut ws);
                }
            }
            hit_rate = cache.hit_rate();
            total
        });
        let per_cand_nc = no_cache / (requests * fanout) as f64 * 1e9;
        let per_cand_c = cached / (requests * fanout) as f64 * 1e9;
        println!(
            "{:<26} {:>9.0}ns {:>9.0}ns {:>8.2}x {:>7.1}%",
            format!("{universe} ctxs (zipf {zipf_s})"),
            per_cand_nc,
            per_cand_c,
            no_cache / cached,
            hit_rate * 100.0
        );
        rows.push(obj(vec![
            ("context_universe", num(universe as f64)),
            ("zipf_s", num(zipf_s)),
            ("no_cache_ns_per_candidate", num(per_cand_nc)),
            ("cached_ns_per_candidate", num(per_cand_c)),
            ("speedup", num(no_cache / cached)),
            ("hit_rate", num(hit_rate)),
        ]));
    }
    let path = bench_env::write_report(
        "fig4_context_cache",
        smoke,
        vec![
            ("requests", num(requests as f64)),
            ("fanout", num(fanout as f64)),
            ("context_fields", num(ctx_fields as f64)),
            ("universes", arr(rows)),
        ],
    );
    println!("\nreport -> {path}");
    println!("expected: speedup > 1 throughout, largest for small/skewed context universes");
    println!("(the production regime: every request's candidates share one context).");
}
