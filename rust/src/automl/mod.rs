//! §2.2 — AutoML: hyperparameter / architecture search.
//!
//! The paper's Table 1 and Figure 3 aggregate "tens of thousands of
//! runs that represented different algorithm configurations (both
//! hyperparameters and field specifications)".  This module is that
//! harness: a seeded random search over the engine's hyperparameter
//! space, executed across worker threads, producing per-configuration
//! rolling-AUC traces and the stability table.

use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};

use crate::baselines::OnlineModel;
use crate::eval::{RollingAuc, StabilityStats};
use crate::feature::Example;
use crate::util::rng::Pcg32;

/// One point in the search space (engine-agnostic: the factory closure
/// interprets it).
#[derive(Clone, Debug)]
pub struct CandidateConfig {
    pub id: usize,
    pub lr: f32,
    pub ffm_lr: f32,
    pub nn_lr: f32,
    pub power_t: f32,
    pub l2: f32,
    pub latent_dim: usize,
    pub hidden: Vec<usize>,
    pub seed: u64,
}

/// Search-space bounds for random sampling.
#[derive(Clone, Debug)]
pub struct SearchSpace {
    pub lr: (f32, f32),
    pub power_t: (f32, f32),
    pub latent_dims: Vec<usize>,
    pub hidden_options: Vec<Vec<usize>>,
    pub l2: (f32, f32),
}

impl Default for SearchSpace {
    fn default() -> Self {
        SearchSpace {
            lr: (0.01, 0.5),
            power_t: (0.2, 0.6),
            latent_dims: vec![2, 4, 8],
            hidden_options: vec![vec![8], vec![16], vec![16, 16], vec![32]],
            l2: (0.0, 1e-4),
        }
    }
}

impl SearchSpace {
    /// Sample one configuration.
    pub fn sample(&self, id: usize, rng: &mut Pcg32) -> CandidateConfig {
        CandidateConfig {
            id,
            lr: rng.range_f32(self.lr.0, self.lr.1),
            ffm_lr: rng.range_f32(self.lr.0, self.lr.1) * 0.5,
            nn_lr: rng.range_f32(self.lr.0, self.lr.1) * 0.25,
            power_t: rng.range_f32(self.power_t.0, self.power_t.1),
            l2: rng.range_f32(self.l2.0, self.l2.1),
            latent_dim: *rng.choose(&self.latent_dims),
            hidden: rng.choose(&self.hidden_options).clone(),
            seed: rng.next_u64(),
        }
    }
}

/// Result of evaluating one candidate: its rolling trace + stability.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub config: CandidateConfig,
    pub trace: Vec<f64>,
    pub stats: StabilityStats,
    pub mean_logloss: f64,
    pub rig: f64,
    pub train_seconds: f64,
}

/// Drive one model over a stream, single pass, returning its result.
pub fn evaluate_model<M: OnlineModel>(
    config: CandidateConfig,
    mut model: M,
    train: &[Example],
    test: &[Example],
    window: usize,
) -> RunResult {
    let t = std::time::Instant::now();
    let mut roll = RollingAuc::new(window);
    for ex in train {
        let p = model.learn(ex);
        roll.add(p, ex.label);
    }
    roll.finish();
    let mut scores = Vec::with_capacity(test.len());
    let mut labels = Vec::with_capacity(test.len());
    for ex in test {
        scores.push(model.predict(ex));
        labels.push(ex.label);
    }
    let test_auc = crate::eval::auc(&scores, &labels);
    RunResult {
        config,
        stats: StabilityStats::from_trace(&roll.points, test_auc),
        mean_logloss: roll.mean_logloss(),
        rig: roll.rig(),
        trace: roll.points,
        train_seconds: t.elapsed().as_secs_f64(),
    }
}

/// Random search: sample `n_configs`, evaluate each on its own copy of
/// the data across `threads` workers.
///
/// `factory(config) -> model` builds the engine under test; the same
/// search harness therefore sweeps FW variants *and* baselines.
pub fn random_search<F, M>(
    space: &SearchSpace,
    n_configs: usize,
    threads: usize,
    seed: u64,
    train: Arc<Vec<Example>>,
    test: Arc<Vec<Example>>,
    window: usize,
    factory: F,
) -> Vec<RunResult>
where
    F: Fn(&CandidateConfig) -> M + Send + Sync,
    M: OnlineModel,
{
    let mut rng = Pcg32::seeded(seed);
    let configs: Vec<CandidateConfig> =
        (0..n_configs).map(|i| space.sample(i, &mut rng)).collect();
    let work = Arc::new(Mutex::new(configs));
    let (tx, rx) = channel::<RunResult>();
    let factory = &factory;
    std::thread::scope(|scope| {
        for _ in 0..threads.max(1) {
            let work = work.clone();
            let tx = tx.clone();
            let train = train.clone();
            let test = test.clone();
            scope.spawn(move || loop {
                let cfg = {
                    let mut q = work.lock().expect("automl queue");
                    match q.pop() {
                        Some(c) => c,
                        None => return,
                    }
                };
                let model = factory(&cfg);
                let result = evaluate_model(cfg, model, &train, &test, window);
                if tx.send(result).is_err() {
                    return;
                }
            });
        }
        drop(tx);
    });
    let mut results: Vec<RunResult> = rx.into_iter().collect();
    results.sort_by_key(|r| r.config.id);
    results
}

/// Aggregate many runs of one engine into a single Table-1 row: the
/// paper pools all configurations' window AUCs ("traces of all trained
/// models (per engine)").
pub fn pooled_stats(results: &[RunResult]) -> StabilityStats {
    let pooled: Vec<f64> =
        results.iter().flat_map(|r| r.trace.iter().cloned()).collect();
    let best_test = results
        .iter()
        .map(|r| r.stats.test)
        .fold(f64::MIN, f64::max);
    StabilityStats::from_trace(&pooled, best_test)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::FwModel;
    use crate::config::ModelConfig;
    use crate::data::synthetic::{DatasetSpec, SyntheticStream};
    use crate::model::regressor::Regressor;

    fn data(n: usize, seed: u64) -> Arc<Vec<Example>> {
        let mut s = SyntheticStream::with_buckets(DatasetSpec::tiny(), seed, 256);
        Arc::new(s.take_examples(n))
    }

    fn ffm_factory(c: &CandidateConfig) -> FwModel {
        let mut cfg = ModelConfig::ffm(4, c.latent_dim, 256);
        cfg.lr = c.lr;
        cfg.ffm_lr = c.ffm_lr;
        cfg.power_t = c.power_t;
        cfg.seed = c.seed;
        FwModel::new("FW-FFM", Regressor::new(&cfg))
    }

    #[test]
    fn search_returns_all_configs_in_order() {
        let train = data(3000, 1);
        let test = data(500, 2);
        let results = random_search(
            &SearchSpace::default(),
            6,
            3,
            99,
            train,
            test,
            1000,
            ffm_factory,
        );
        assert_eq!(results.len(), 6);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.config.id, i);
            assert!(!r.trace.is_empty());
            assert!(r.stats.test > 0.3 && r.stats.test < 1.0);
        }
    }

    #[test]
    fn search_deterministic_configs() {
        let space = SearchSpace::default();
        let mut a = Pcg32::seeded(5);
        let mut b = Pcg32::seeded(5);
        let ca = space.sample(0, &mut a);
        let cb = space.sample(0, &mut b);
        assert_eq!(ca.lr, cb.lr);
        assert_eq!(ca.hidden, cb.hidden);
    }

    #[test]
    fn pooled_stats_cover_all_traces() {
        let train = data(2500, 3);
        let test = data(400, 4);
        let results = random_search(
            &SearchSpace::default(),
            4,
            2,
            7,
            train,
            test,
            500,
            ffm_factory,
        );
        let pooled = pooled_stats(&results);
        let n_points: usize = results.iter().map(|r| r.trace.len()).sum();
        assert!(n_points >= 16);
        assert!(pooled.max >= pooled.avg && pooled.avg >= pooled.min);
        assert!(pooled.test >= results.iter().map(|r| r.stats.test).fold(f64::MIN, f64::max) - 1e-12);
    }

    #[test]
    fn evaluate_reports_costs() {
        let train = data(1000, 5);
        let test = data(200, 6);
        let cfg = SearchSpace::default().sample(0, &mut Pcg32::seeded(1));
        let r = evaluate_model(cfg.clone(), ffm_factory(&cfg), &train, &test, 300);
        assert!(r.train_seconds > 0.0);
        assert!(r.mean_logloss > 0.0);
        assert!(r.rig.abs() < 1.0);
    }
}
