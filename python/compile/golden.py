"""Golden-vector export for cross-layer validation.

Generates deterministic (seeded) weights + inputs for the smallest AOT
variant, evaluates the L2 JAX model, and writes everything as JSON.  The
Rust tests (``rust/tests/pjrt_cross_check.rs``) then assert that

  1. the PJRT-loaded HLO artifact reproduces these probabilities, and
  2. the native Rust forward pass (fed the same tables in direct-index
     mode) reproduces them too,

closing the L1 (pallas) == L2 (jax) == L3 (rust) triangle.
"""

from __future__ import annotations

import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

from compile.model import (DeepFfmConfig, deep_ffm_forward, example_args,
                           mlp_param_shapes)

GOLDEN_CFG = DeepFfmConfig(fields=4, latent_dim=2, buckets=256,
                           hidden=(8,), batch=8)
GOLDEN_FFM_CFG = DeepFfmConfig(fields=4, latent_dim=2, buckets=256,
                               hidden=(), batch=8)


def flat(a) -> list:
    return np.asarray(a, dtype=np.float64).reshape(-1).tolist()


def export(cfg: DeepFfmConfig, seed: int) -> dict:
    lr_table, ffm_table, mlp, idx, vals = example_args(cfg, seed=seed)
    # Non-trivial values exercise the x_i * x_j product path.
    vals = vals * (1.0 + 0.25 * jnp.arange(cfg.fields, dtype=jnp.float32))
    probs = deep_ffm_forward(cfg, lr_table, ffm_table, mlp, idx, vals)
    return {
        "name": cfg.name(),
        "seed": seed,
        "fields": cfg.fields,
        "latent_dim": cfg.latent_dim,
        "buckets": cfg.buckets,
        "hidden": list(cfg.hidden),
        "batch": cfg.batch,
        "lr_table": flat(lr_table),
        "ffm_table": flat(ffm_table),
        "mlp": [flat(p) for p in mlp],
        "mlp_shapes": [list(s) for s in mlp_param_shapes(cfg)],
        "idx": np.asarray(idx).reshape(-1).tolist(),
        "vals": flat(vals),
        "probs": flat(probs),
    }


def main() -> None:
    out_dir = sys.argv[1] if len(sys.argv) > 1 else "../artifacts"
    os.makedirs(out_dir, exist_ok=True)
    goldens = [export(GOLDEN_CFG, seed=7), export(GOLDEN_FFM_CFG, seed=11)]
    path = os.path.join(out_dir, "golden.json")
    with open(path, "w") as f:
        json.dump(goldens, f)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
