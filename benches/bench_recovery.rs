//! Recovery bench: what a crash costs, per update mode.
//!
//! Three numbers matter for the crash-recovery plane:
//!
//! 1. **Checkpoint cost** — bytes on disk and write time for the
//!    fabric's durable `FWCKPT1` checkpoint (model base + retained
//!    patch log + cursors).  This is the steady-state tax paid every
//!    `checkpoint_every` rounds.
//! 2. **Fabric restore** — wall time to rebuild the whole distribution
//!    plane (pipeline, reference, log, every replica + its serving
//!    engine) from that file.
//! 3. **Replica restart-to-first-prediction** — a replica killed with
//!    a cursor `lag` rounds behind head: time from teardown to the
//!    first successfully served score, and the bytes the catch-up
//!    shipped to get there (one folded patch hop for chained modes
//!    inside the replay window, a full base otherwise).
//!
//! Emits `BENCH_recovery.json`.  `--smoke` runs a CI-sized variant.
//! After the report is written, every mode asserts the recovered
//! replica is bit-identical to the reference — a bench that recovers
//! wrong weights fast is not a recovery bench.

use std::time::Instant;

use fwumious::config::{ModelConfig, ServeConfig};
use fwumious::data::synthetic::{DatasetSpec, SyntheticStream};
use fwumious::fleet::{FleetConfig, FleetFabric, LinkSpec, Topology};
use fwumious::model::regressor::Regressor;
use fwumious::model::Workspace;
use fwumious::serve::trace::TraceGenerator;
use fwumious::transfer::UpdateMode;
use fwumious::util::bench_env;
use fwumious::util::json::{arr, num, obj, s};

struct Row {
    mode: UpdateMode,
    ckpt_bytes: u64,
    ckpt_write_ms: f64,
    fabric_restore_ms: f64,
    restart_lag: u64,
    restart_ms: f64,
    replay_bytes: u64,
    replays: u64,
    resyncs: u64,
}

fn run_mode(mode: UpdateMode, rounds: usize, examples: usize) -> Row {
    let mut spec = DatasetSpec::tiny();
    spec.cat_fields = 4;
    let fields = spec.fields();
    let model_cfg = ModelConfig::deep_ffm(fields, 2, 1 << 12, &[16]);
    let template = Regressor::new(&model_cfg);
    let mut trainer = template.clone();
    let mut ws = Workspace::new();
    let mut stream =
        SyntheticStream::with_buckets(spec, 0xbe4c, model_cfg.buckets);

    let topo = Topology::uniform(2, 2, LinkSpec::wan(), LinkSpec::lan());
    let mut fcfg = FleetConfig::new(topo, mode);
    fcfg.seed = 0xbe4c ^ 7;
    fcfg.serve = Some(ServeConfig {
        workers: 1,
        max_batch: 32,
        max_wait_us: 100,
        context_cache_entries: 1_024,
        max_group_candidates: 1024,
        ..ServeConfig::default()
    });
    let model_name = fcfg.model_name.clone();
    let mut fabric = FleetFabric::new(fcfg.clone(), &template);
    let ckpt_path = std::env::temp_dir().join(format!(
        "fw_bench_recovery_{}_{:?}.ckpt",
        std::process::id(),
        mode
    ));

    // train + publish; freeze replica 0's durable cursor at half-way,
    // as if that were the last checkpoint before its crash
    let half = rounds / 2;
    let mut cursor = fabric.checkpoint_replica(0);
    for r in 0..rounds {
        for _ in 0..examples {
            let ex = stream.next_example();
            trainer.learn(&ex, &mut ws);
        }
        fabric.publish(&trainer).expect("lossless publish");
        if r + 1 == half {
            cursor = fabric.checkpoint_replica(0);
        }
    }

    // 1. checkpoint cost at head
    let t = Instant::now();
    fabric.write_checkpoint(&ckpt_path).expect("checkpoint write");
    let ckpt_write_ms = t.elapsed().as_secs_f64() * 1e3;
    let ckpt_bytes = std::fs::metadata(&ckpt_path).expect("ckpt stat").len();

    // 2. whole-fabric restore (serving engines included)
    let t = Instant::now();
    let restored =
        FleetFabric::restore_from_path(fcfg.clone(), &template, &ckpt_path)
            .expect("fabric restore");
    let fabric_restore_ms = t.elapsed().as_secs_f64() * 1e3;
    assert_eq!(restored.head(), fabric.head(), "{mode:?}: restore lost head");
    let _ = restored.shutdown();

    // 3. replica crash: restart from the stale cursor, catch up to
    //    head, serve the first prediction
    let before = fabric.metrics();
    let restart_lag = fabric.head() - cursor.seq;
    let mut gen = TraceGenerator::new(9, fields, 2, model_cfg.buckets, 4);
    let probe = gen.next_request(&model_name);
    let t = Instant::now();
    fabric
        .restart_replica(0, &cursor)
        .expect("replica restart");
    let client = fabric.replicas()[0].client().expect("replica serves");
    let resp = client.score(probe.clone()).expect("first score");
    let restart_ms = t.elapsed().as_secs_f64() * 1e3;
    assert_eq!(resp.scores.len(), probe.candidates.len());
    let after = fabric.metrics();

    // correctness gate: fast recovery of the wrong weights doesn't count
    assert_eq!(fabric.replicas()[0].seq(), fabric.head(), "{mode:?}");
    assert_eq!(
        fabric.replicas()[0].model().pool.weights,
        fabric.reference().expect("rounds ran").pool.weights,
        "{mode:?}: restarted replica diverged from reference"
    );

    let _ = fabric.shutdown();
    let _ = std::fs::remove_file(&ckpt_path);
    Row {
        mode,
        ckpt_bytes,
        ckpt_write_ms,
        fabric_restore_ms,
        restart_lag,
        restart_ms,
        replay_bytes: (after.inter_bytes() + after.intra_bytes())
            - (before.inter_bytes() + before.intra_bytes()),
        replays: after.replays - before.replays,
        resyncs: after.resyncs - before.resyncs,
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (rounds, examples) = if smoke { (6, 300) } else { (12, 1_200) };
    println!(
        "== Crash recovery: checkpoint, restore, restart costs (SIMD {}{}) ==\n",
        fwumious::simd::isa_name(),
        if smoke { ", smoke" } else { "" }
    );
    println!(
        "{:>10} {:>10} {:>10} {:>11} {:>5} {:>11} {:>11} {:>7} {:>7}",
        "mode",
        "ckpt B",
        "write ms",
        "restore ms",
        "lag",
        "restart ms",
        "replay B",
        "replays",
        "resyncs"
    );
    let mut rows = Vec::new();
    for mode in UpdateMode::ALL {
        let row = run_mode(mode, rounds, examples);
        println!(
            "{:>10} {:>10} {:>10.2} {:>11.2} {:>5} {:>11.2} {:>11} {:>7} {:>7}",
            format!("{:?}", row.mode),
            row.ckpt_bytes,
            row.ckpt_write_ms,
            row.fabric_restore_ms,
            row.restart_lag,
            row.restart_ms,
            row.replay_bytes,
            row.replays,
            row.resyncs
        );
        rows.push(row);
    }

    let path = bench_env::write_report(
        "recovery",
        smoke,
        vec![
            ("rounds", num(rounds as f64)),
            ("examples_per_round", num(examples as f64)),
            (
                "modes",
                arr(rows
                    .iter()
                    .map(|r| {
                        obj(vec![
                            ("mode", s(&format!("{:?}", r.mode))),
                            ("checkpoint_bytes", num(r.ckpt_bytes as f64)),
                            ("checkpoint_write_ms", num(r.ckpt_write_ms)),
                            ("fabric_restore_ms", num(r.fabric_restore_ms)),
                            ("restart_lag_rounds", num(r.restart_lag as f64)),
                            ("restart_to_first_score_ms", num(r.restart_ms)),
                            ("replay_bytes", num(r.replay_bytes as f64)),
                            ("replays", num(r.replays as f64)),
                            ("resyncs", num(r.resyncs as f64)),
                        ])
                    })
                    .collect()),
            ),
        ],
    );
    println!("\nreport -> {path}");

    // every restart actually moved bytes and resolved via replay or
    // resync — a zero-byte "recovery" means the crash never happened
    for r in &rows {
        assert!(r.replay_bytes > 0, "{:?}: restart shipped nothing", r.mode);
        assert!(
            r.replays + r.resyncs >= 1,
            "{:?}: restart neither replayed nor resynced",
            r.mode
        );
    }
    println!("all modes recovered to bit-identical weights from a cold restart.");
}
