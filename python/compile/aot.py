"""AOT lowering: JAX DeepFFM (+ Pallas kernel) -> HLO text artifacts.

Interchange format is HLO *text*, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
bundled xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/gen_hlo.py and DESIGN.md §2.

Emits, per model variant:
    artifacts/<name>.hlo.txt     — the HLO module
plus a single ``artifacts/manifest.json`` describing every artifact's
argument order/shapes so the Rust runtime can validate its inputs.

Run via ``make artifacts`` (no-op when inputs are unchanged).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from compile.model import (DeepFfmConfig, arg_specs, make_batched_fn,
                           mlp_param_shapes)

# The artifact set shipped to the Rust serving layer.  Small bucket
# counts keep PJRT argument transfers cheap in tests; production-size
# tables live in the native Rust path.
VARIANTS = [
    DeepFfmConfig(fields=8, latent_dim=4, buckets=4096, hidden=(16,), batch=32),
    DeepFfmConfig(fields=8, latent_dim=4, buckets=4096, hidden=(), batch=32),
    DeepFfmConfig(fields=8, latent_dim=4, buckets=4096, hidden=(16, 16), batch=32),
    DeepFfmConfig(fields=4, latent_dim=2, buckets=256, hidden=(8,), batch=8),
    DeepFfmConfig(fields=4, latent_dim=2, buckets=256, hidden=(), batch=8),
]


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(cfg: DeepFfmConfig) -> str:
    fn = make_batched_fn(cfg)
    lowered = jax.jit(fn).lower(*arg_specs(cfg))
    return to_hlo_text(lowered)


def manifest_entry(cfg: DeepFfmConfig) -> dict:
    args = [
        {"name": "lr_table", "shape": [cfg.buckets], "dtype": "f32"},
        {"name": "ffm_table",
         "shape": [cfg.buckets, cfg.fields, cfg.latent_dim], "dtype": "f32"},
    ]
    for i, shape in enumerate(mlp_param_shapes(cfg)):
        args.append({"name": f"mlp_{i}", "shape": list(shape), "dtype": "f32"})
    args.append({"name": "idx", "shape": [cfg.batch, cfg.fields],
                 "dtype": "i32"})
    args.append({"name": "vals", "shape": [cfg.batch, cfg.fields],
                 "dtype": "f32"})
    return {
        "name": cfg.name(),
        "file": f"{cfg.name()}.hlo.txt",
        "fields": cfg.fields,
        "latent_dim": cfg.latent_dim,
        "buckets": cfg.buckets,
        "hidden": list(cfg.hidden),
        "batch": cfg.batch,
        "pairs": cfg.pairs,
        "merged_dim": cfg.merged_dim,
        "merge_norm_eps": 1e-6,
        "args": args,
        "output": {"shape": [cfg.batch], "dtype": "f32",
                   "note": "1-tuple of probabilities; unwrap via to_tuple1"},
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"abi_version": 1, "artifacts": []}
    for cfg in VARIANTS:
        text = lower_variant(cfg)
        path = os.path.join(args.out_dir, f"{cfg.name()}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append(manifest_entry(cfg))
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
