//! Mini property-testing framework (proptest is unavailable offline).
//!
//! A property runs against many generated cases; on failure the seed
//! is reported so the case can be replayed deterministically:
//!
//! ```no_run
//! # // no_run: doctest binaries don't inherit the xla rpath rustflags
//! use fwumious::testutil::{prop, Gen};
//! prop(100, |g: &mut Gen| {
//!     let xs = g.vec_f32(0..64, -10.0, 10.0);
//!     let sum: f32 = xs.iter().sum();
//!     assert!(sum.is_finite());
//! });
//! ```

use crate::util::rng::Pcg32;

/// Case generator handed to each property invocation.
pub struct Gen {
    rng: Pcg32,
    pub case: usize,
    pub seed: u64,
}

impl Gen {
    pub fn rng(&mut self) -> &mut Pcg32 {
        &mut self.rng
    }

    pub fn usize_in(&mut self, range: std::ops::Range<usize>) -> usize {
        if range.is_empty() {
            return range.start;
        }
        range.start + self.rng.below((range.end - range.start) as u32) as usize
    }

    pub fn u32(&mut self) -> u32 {
        self.rng.next_u32()
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range_f32(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.coin(0.5)
    }

    /// Random byte vector with length drawn from `len`.
    pub fn bytes(&mut self, len: std::ops::Range<usize>) -> Vec<u8> {
        let n = self.usize_in(len);
        (0..n).map(|_| (self.rng.next_u32() & 0xff) as u8).collect()
    }

    /// Random f32 vector with length drawn from `len`.
    pub fn vec_f32(&mut self, len: std::ops::Range<usize>, lo: f32, hi: f32) -> Vec<f32> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.f32_in(lo, hi)).collect()
    }

    /// Random normal-distributed f32 vector.
    pub fn vec_normal(&mut self, len: std::ops::Range<usize>, scale: f32) -> Vec<f32> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.rng.normal() * scale).collect()
    }
}

/// Run `f` against `cases` generated cases.  Panics (with the failing
/// seed) on the first failure.  Set `FW_PROP_SEED` to replay one case.
pub fn prop(cases: usize, mut f: impl FnMut(&mut Gen)) {
    if let Ok(seed_str) = std::env::var("FW_PROP_SEED") {
        let seed: u64 = seed_str.parse().expect("FW_PROP_SEED must be u64");
        let mut g = Gen { rng: Pcg32::seeded(seed), case: 0, seed };
        f(&mut g);
        return;
    }
    for case in 0..cases {
        let seed = 0x5eed_0000 + case as u64;
        let mut g = Gen { rng: Pcg32::seeded(seed), case, seed };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut g)
        }));
        if let Err(e) = result {
            eprintln!(
                "property failed at case {case} — replay with FW_PROP_SEED={seed}"
            );
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prop_runs_all_cases() {
        let mut n = 0;
        prop(25, |_g| n += 1);
        assert_eq!(n, 25);
    }

    #[test]
    fn gen_ranges_respected() {
        prop(50, |g| {
            let x = g.usize_in(3..10);
            assert!((3..10).contains(&x));
            let f = g.f32_in(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
            let v = g.bytes(0..16);
            assert!(v.len() < 16);
        });
    }

    #[test]
    #[should_panic]
    fn failing_property_panics() {
        prop(10, |g| {
            assert!(g.case < 5, "deliberate failure");
        });
    }
}
