//! The distribution planner: how one round's update reaches every
//! replica.
//!
//! The paper's bandwidth trick — quantize + patch so cross-DC updates
//! shrink by an order of magnitude — generalizes at the fleet level:
//! the *number of times* an update crosses a DC boundary matters as
//! much as its size.  Two route families:
//!
//! * **Star** — the trainer ships to every replica directly.  Each of
//!   a DC's M replicas costs one inter-DC crossing: `M × len` bytes on
//!   the expensive edge.
//! * **Tree** (relay / fan-out) — the trainer ships **once** per DC to
//!   a head replica, which re-distributes intra-DC: `len` inter-DC
//!   bytes + `(M-1) × len` cheap intra-DC bytes, at the price of one
//!   extra (LAN) hop of publish lag for the non-head replicas.
//!
//! `Auto` picks per DC by predicted inter-DC bytes: tree strictly wins
//! for M ≥ 2, and for M = 1 the star route is chosen (identical bytes,
//! one fewer failure domain — no head to lose).

use crate::fleet::topology::Topology;

/// Route-selection policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    /// Trainer → every replica directly.
    Star,
    /// Trainer → per-DC head, head → DC-local replicas.
    Tree,
    /// Per DC, whichever predicts fewer inter-DC bytes.
    Auto,
}

impl Strategy {
    pub const ALL: [Strategy; 3] = [Strategy::Star, Strategy::Tree, Strategy::Auto];

    pub fn label(&self) -> &'static str {
        match self {
            Strategy::Star => "star",
            Strategy::Tree => "tree",
            Strategy::Auto => "auto",
        }
    }

    /// Parse a CLI flag value (`star|tree|auto`).
    pub fn parse(s: &str) -> Result<Strategy, crate::config::ConfigError> {
        Ok(match s {
            "star" => Strategy::Star,
            "tree" => Strategy::Tree,
            "auto" => Strategy::Auto,
            other => {
                return Err(crate::config::ConfigError::UnknownValue {
                    what: "strategy",
                    got: other.to_string(),
                    want: "star|tree|auto",
                })
            }
        })
    }
}

/// How one DC's replicas receive a round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DcRoute {
    /// Every replica gets its own trainer→replica inter-DC shipment.
    Star,
    /// One inter-DC shipment to `head`; head re-distributes intra-DC.
    Tree { head: usize },
}

/// A resolved plan: one route per DC, in topology order.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DistributionPlan {
    pub per_dc: Vec<DcRoute>,
}

impl DistributionPlan {
    /// Bytes a `len`-byte update puts on inter-DC links under this
    /// plan (loss-free prediction — the planner's cost model).
    pub fn predicted_inter_bytes(&self, topo: &Topology, len: usize) -> u64 {
        self.per_dc
            .iter()
            .zip(&topo.dcs)
            .map(|(route, dc)| match route {
                DcRoute::Star => (dc.replicas * len) as u64,
                DcRoute::Tree { .. } => len as u64,
            })
            .sum()
    }

    /// Bytes the same update puts on intra-DC links.
    pub fn predicted_intra_bytes(&self, topo: &Topology, len: usize) -> u64 {
        self.per_dc
            .iter()
            .zip(&topo.dcs)
            .map(|(route, dc)| match route {
                DcRoute::Star => 0,
                DcRoute::Tree { .. } => ((dc.replicas - 1) * len) as u64,
            })
            .sum()
    }
}

/// Resolve a strategy against a topology.
///
/// The update's byte size cancels out of the inter-DC comparison (tree
/// ships `len`, star ships `replicas × len` per DC), so the plan is a
/// pure function of the topology and policy.
pub fn plan(topo: &Topology, strategy: Strategy) -> DistributionPlan {
    let per_dc = topo
        .dcs
        .iter()
        .map(|dc| match strategy {
            Strategy::Star => DcRoute::Star,
            Strategy::Tree => DcRoute::Tree { head: 0 },
            Strategy::Auto => {
                if dc.replicas >= 2 {
                    DcRoute::Tree { head: 0 }
                } else {
                    DcRoute::Star
                }
            }
        })
        .collect();
    DistributionPlan { per_dc }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::topology::LinkSpec;

    fn topo(dcs: usize, replicas: usize) -> Topology {
        Topology::uniform(dcs, replicas, LinkSpec::wan(), LinkSpec::lan())
    }

    #[test]
    fn strategy_parse_roundtrip() {
        for s in Strategy::ALL {
            assert_eq!(Strategy::parse(s.label()).unwrap(), s);
        }
        assert!(Strategy::parse("mesh").is_err());
    }

    #[test]
    fn auto_picks_tree_for_multi_replica_dcs() {
        let p = plan(&topo(3, 2), Strategy::Auto);
        assert!(p.per_dc.iter().all(|r| matches!(r, DcRoute::Tree { head: 0 })));
        let p1 = plan(&topo(3, 1), Strategy::Auto);
        assert!(p1.per_dc.iter().all(|r| *r == DcRoute::Star));
    }

    #[test]
    fn predicted_bytes_star_vs_tree() {
        let t = topo(3, 4);
        let star = plan(&t, Strategy::Star);
        let tree = plan(&t, Strategy::Tree);
        assert_eq!(star.predicted_inter_bytes(&t, 100), 3 * 4 * 100);
        assert_eq!(star.predicted_intra_bytes(&t, 100), 0);
        assert_eq!(tree.predicted_inter_bytes(&t, 100), 3 * 100);
        assert_eq!(tree.predicted_intra_bytes(&t, 100), 3 * 3 * 100);
        // the planner's whole point: tree ships fewer cross-DC bytes
        assert!(
            tree.predicted_inter_bytes(&t, 100) < star.predicted_inter_bytes(&t, 100)
        );
    }

    #[test]
    fn auto_matches_tree_bytes_when_tree_wins() {
        let t = topo(2, 3);
        let auto = plan(&t, Strategy::Auto);
        let tree = plan(&t, Strategy::Tree);
        assert_eq!(
            auto.predicted_inter_bytes(&t, 64),
            tree.predicted_inter_bytes(&t, 64)
        );
    }
}
