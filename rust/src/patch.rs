//! §6 — byte-level model patching.
//!
//! "Each subsequent inference weights update first computes *model
//! diff* — byte-level difference between old and new weights.  This is
//! possible due to a consistent memory-level structure of weight files.
//! The diffs are compressed, sent to the serving layer, unpacked and
//! applied to previous weights file to obtain the new set of weights."
//!
//! Encoding choices straight from the paper:
//! * "instead of storing absolute indices of bytes that change,
//!   **relative locations** are stored" — each op's offset is a delta
//!   from the end of the previous op;
//! * "small integers denoting these differences are stored as a
//!   **custom integer type**" — LEB128 varints (see `util::varint`);
//! * the op stream is **compressed** — the in-repo LZSS codec
//!   ([`crate::util::compress`]; the offline build has no flate2/zstd).
//!
//! Patch stream format (before compression):
//! ```text
//! magic   [4] b"FWP1"
//! old_len varint
//! new_len varint
//! ops     ( skip varint, run_len varint, run_len bytes )*
//! ```
//! `skip` bytes are copied from the old file, then `run_len` literal
//! bytes replace the corresponding old bytes.  A final implicit skip
//! copies the tail.  Since training rounds keep the file length fixed,
//! old_len == new_len in production; the format still supports growth
//! (appended bytes ride in a final run).

use crate::util::compress as lz;
use crate::util::compress::CompressError;
use crate::util::varint;

pub const MAGIC: &[u8; 4] = b"FWP1";

/// Why a patch failed to parse, apply, or fold.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PatchError {
    /// Zero-byte wire buffer.
    Empty,
    /// Unknown compression tag byte.
    BadTag(u8),
    /// Op stream does not start with `FWP1`.
    BadMagic,
    /// Stream ended inside a varint (`what` names which one).
    Truncated(&'static str),
    /// Patch was diffed against a different base length.
    OldLenMismatch { expected: u64, got: usize },
    /// A skip op walks past the end of the old buffer.
    SkipPastEnd,
    /// A literal run claims more bytes than the op stream holds.
    RunPastEnd,
    /// Applying produced a different length than the header declared.
    LengthMismatch { got: usize, expected: u64 },
    /// Folding needs `old_len == new_len` on every link.
    NotInPlace,
    /// Adjacent fold links disagree on the intermediate length.
    ChainMismatch { a_new: u64, b_old: u64 },
    /// `fold_chain` over zero patches.
    EmptyChain,
    /// Failure folding link `index` of `len`.
    FoldLink { index: usize, len: usize, source: Box<PatchError> },
    /// Failure applying link `index` of `len`.
    ChainLink { index: usize, len: usize, source: Box<PatchError> },
    /// The op stream's LZ payload was corrupt.
    Compress(CompressError),
}

impl std::fmt::Display for PatchError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PatchError::Empty => write!(f, "empty patch"),
            PatchError::BadTag(t) => write!(f, "bad compression tag {t}"),
            PatchError::BadMagic => write!(f, "bad patch magic"),
            PatchError::Truncated(what) => write!(f, "truncated {what}"),
            PatchError::OldLenMismatch { expected, got } => {
                write!(f, "patch expects old of {expected} bytes, got {got}")
            }
            PatchError::SkipPastEnd => write!(f, "skip past end of old"),
            PatchError::RunPastEnd => write!(f, "run past end of patch"),
            PatchError::LengthMismatch { got, expected } => {
                write!(f, "patched length {got} != expected {expected}")
            }
            PatchError::NotInPlace => {
                write!(f, "fold requires in-place patches (old_len == new_len)")
            }
            PatchError::ChainMismatch { a_new, b_old } => {
                write!(f, "fold chain mismatch: a.new_len {a_new} != b.old_len {b_old}")
            }
            PatchError::EmptyChain => write!(f, "empty fold chain"),
            PatchError::FoldLink { index, len, source } => {
                write!(f, "fold link {index}/{len}: {source}")
            }
            PatchError::ChainLink { index, len, source } => {
                write!(f, "chain link {index}/{len}: {source}")
            }
            PatchError::Compress(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for PatchError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            PatchError::FoldLink { source, .. } | PatchError::ChainLink { source, .. } => {
                Some(source)
            }
            PatchError::Compress(e) => Some(e),
            _ => None,
        }
    }
}

impl From<CompressError> for PatchError {
    fn from(e: CompressError) -> PatchError {
        PatchError::Compress(e)
    }
}

/// CLI shim: `fn main` paths print errors as strings.
impl From<PatchError> for String {
    fn from(e: PatchError) -> String {
        e.to_string()
    }
}

/// Compression applied to the op stream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Compression {
    None,
    /// In-repo LZSS ([`crate::util::compress`]).
    Lz,
}

/// A computed patch, ready for the wire.
#[derive(Clone, Debug)]
pub struct Patch {
    pub compression: Compression,
    /// Compressed (or raw) op stream.
    pub payload: Vec<u8>,
    /// Uncompressed op-stream size (for reporting).
    pub raw_len: usize,
}

impl Patch {
    /// Bytes on the wire (payload + 1 tag byte).
    pub fn wire_bytes(&self) -> usize {
        self.payload.len() + 1
    }

    /// Serialize with a leading compression tag.
    pub fn to_wire(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.wire_bytes());
        out.push(match self.compression {
            Compression::None => 0,
            Compression::Lz => 1,
        });
        out.extend_from_slice(&self.payload);
        out
    }

    /// Parse a wire buffer.
    pub fn from_wire(buf: &[u8]) -> Result<Patch, PatchError> {
        let (&tag, payload) = buf.split_first().ok_or(PatchError::Empty)?;
        let compression = match tag {
            0 => Compression::None,
            1 => Compression::Lz,
            t => return Err(PatchError::BadTag(t)),
        };
        Ok(Patch {
            compression,
            payload: payload.to_vec(),
            raw_len: 0,
        })
    }
}

/// Compute the byte diff between two buffers as a raw op stream.
///
/// Runs of differing bytes are merged when the gap between them is
/// smaller than the varint overhead of starting a new op (8 bytes) —
/// fewer, longer ops compress better.
pub fn diff_ops(old: &[u8], new: &[u8]) -> Vec<u8> {
    const MERGE_GAP: usize = 8;
    let mut ops = Vec::new();
    ops.extend_from_slice(MAGIC);
    varint::write_u64(&mut ops, old.len() as u64);
    varint::write_u64(&mut ops, new.len() as u64);

    let common = old.len().min(new.len());
    let mut cursor = 0usize; // position after the last emitted op
    let mut i = 0usize;
    while i < common {
        if old[i] == new[i] {
            i += 1;
            continue;
        }
        // start of a differing run
        let start = i;
        let mut end = i + 1;
        let mut gap = 0;
        while end < common {
            if old[end] != new[end] {
                end += 1;
                gap = 0;
            } else {
                gap += 1;
                end += 1;
                if gap > MERGE_GAP {
                    break;
                }
            }
        }
        let run_end = end - gap; // trim trailing equal bytes
        varint::write_u64(&mut ops, (start - cursor) as u64); // relative skip
        varint::write_u64(&mut ops, (run_end - start) as u64);
        ops.extend_from_slice(&new[start..run_end]);
        cursor = run_end;
        i = run_end;
    }
    if new.len() > common {
        // appended tail
        varint::write_u64(&mut ops, (common - cursor) as u64);
        varint::write_u64(&mut ops, (new.len() - common) as u64);
        ops.extend_from_slice(&new[common..]);
    }
    ops
}

/// Apply a raw op stream to `old`, producing the new buffer.
pub fn apply_ops(old: &[u8], ops: &[u8]) -> Result<Vec<u8>, PatchError> {
    if ops.len() < 4 || &ops[..4] != MAGIC {
        return Err(PatchError::BadMagic);
    }
    let mut pos = 4usize;
    let old_len =
        varint::read_u64(ops, &mut pos).ok_or(PatchError::Truncated("old_len"))?;
    let new_len =
        varint::read_u64(ops, &mut pos).ok_or(PatchError::Truncated("new_len"))?;
    if old_len as usize != old.len() {
        return Err(PatchError::OldLenMismatch { expected: old_len, got: old.len() });
    }
    let mut out = Vec::with_capacity(new_len as usize);
    let mut cursor = 0usize;
    while pos < ops.len() {
        let skip =
            varint::read_u64(ops, &mut pos).ok_or(PatchError::Truncated("skip"))? as usize;
        let run =
            varint::read_u64(ops, &mut pos).ok_or(PatchError::Truncated("run"))? as usize;
        let copy_end = cursor + skip;
        if copy_end > old.len() {
            return Err(PatchError::SkipPastEnd);
        }
        out.extend_from_slice(&old[cursor..copy_end]);
        if pos + run > ops.len() {
            return Err(PatchError::RunPastEnd);
        }
        out.extend_from_slice(&ops[pos..pos + run]);
        pos += run;
        cursor = copy_end + run; // replaced bytes consumed from old
    }
    // implicit tail copy
    if cursor < old.len() && out.len() < new_len as usize {
        let need = new_len as usize - out.len();
        let take = need.min(old.len() - cursor);
        out.extend_from_slice(&old[cursor..cursor + take]);
    }
    if out.len() != new_len as usize {
        return Err(PatchError::LengthMismatch { got: out.len(), expected: new_len });
    }
    Ok(out)
}

fn compress(data: &[u8], c: Compression) -> Vec<u8> {
    match c {
        Compression::None => data.to_vec(),
        Compression::Lz => lz::compress(data),
    }
}

fn decompress(data: &[u8], c: Compression) -> Result<Vec<u8>, PatchError> {
    match c {
        Compression::None => Ok(data.to_vec()),
        Compression::Lz => Ok(lz::decompress(data)?),
    }
}

/// Full pipeline: diff two buffers and compress the op stream.
pub fn make_patch(old: &[u8], new: &[u8], c: Compression) -> Patch {
    let ops = diff_ops(old, new);
    let raw_len = ops.len();
    Patch { compression: c, payload: compress(&ops, c), raw_len }
}

/// Full pipeline inverse: decompress and apply.
pub fn apply_patch(old: &[u8], patch: &Patch) -> Result<Vec<u8>, PatchError> {
    let ops = decompress(&patch.payload, patch.compression)?;
    apply_ops(old, &ops)
}

/// Parse a raw op stream into absolute replacement regions
/// `(start, literal bytes)` plus its `(old_len, new_len)` header.
fn parse_regions(ops: &[u8]) -> Result<(u64, u64, Vec<(usize, Vec<u8>)>), PatchError> {
    if ops.len() < 4 || &ops[..4] != MAGIC {
        return Err(PatchError::BadMagic);
    }
    let mut pos = 4usize;
    let old_len =
        varint::read_u64(ops, &mut pos).ok_or(PatchError::Truncated("old_len"))?;
    let new_len =
        varint::read_u64(ops, &mut pos).ok_or(PatchError::Truncated("new_len"))?;
    let mut regions = Vec::new();
    let mut cursor = 0usize;
    while pos < ops.len() {
        let skip =
            varint::read_u64(ops, &mut pos).ok_or(PatchError::Truncated("skip"))? as usize;
        let run =
            varint::read_u64(ops, &mut pos).ok_or(PatchError::Truncated("run"))? as usize;
        if pos + run > ops.len() {
            return Err(PatchError::RunPastEnd);
        }
        let start = cursor + skip;
        regions.push((start, ops[pos..pos + run].to_vec()));
        pos += run;
        cursor = start + run;
    }
    Ok((old_len, new_len, regions))
}

/// Compose two *in-place* op streams (`a` then `b`, both with
/// `old_len == new_len`) into one stream equivalent to applying them
/// in sequence.  In-place is the fleet's steady state — weight files
/// keep a fixed length round over round — and is what makes
/// composition an overlay: every byte position of the intermediate
/// file maps to the same position of the base, so the folded stream is
/// simply `b`'s regions plus the parts of `a`'s regions `b` did not
/// overwrite.  Length-changing patches are refused (callers fall back
/// to sequential replay).
pub fn fold_ops(a: &[u8], b: &[u8]) -> Result<Vec<u8>, PatchError> {
    let (a_old, a_new, a_regions) = parse_regions(a)?;
    let (b_old, b_new, b_regions) = parse_regions(b)?;
    if a_old != a_new || b_old != b_new {
        return Err(PatchError::NotInPlace);
    }
    if a_new != b_old {
        return Err(PatchError::ChainMismatch { a_new, b_old });
    }

    // a's regions with every b-covered span punched out (b wins)
    let mut pieces: Vec<(usize, Vec<u8>)> = Vec::new();
    for (a_start, a_bytes) in &a_regions {
        let mut seg_start = *a_start;
        let seg_end = a_start + a_bytes.len();
        for (b_start, b_bytes) in &b_regions {
            let b_end = b_start + b_bytes.len();
            if b_end <= seg_start || *b_start >= seg_end {
                continue;
            }
            if *b_start > seg_start {
                pieces.push((
                    seg_start,
                    a_bytes[seg_start - a_start..b_start - a_start].to_vec(),
                ));
            }
            seg_start = seg_start.max(b_end);
            if seg_start >= seg_end {
                break;
            }
        }
        if seg_start < seg_end {
            pieces.push((
                seg_start,
                a_bytes[seg_start - a_start..seg_end - a_start].to_vec(),
            ));
        }
    }
    pieces.extend(b_regions);
    pieces.sort_by_key(|(start, _)| *start);

    // emit, coalescing touching regions (fewer ops compress better)
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    varint::write_u64(&mut out, a_old);
    varint::write_u64(&mut out, b_new);
    let mut cursor = 0usize;
    let mut i = 0usize;
    while i < pieces.len() {
        let start = pieces[i].0;
        let mut bytes = std::mem::take(&mut pieces[i].1);
        i += 1;
        while i < pieces.len() && pieces[i].0 == start + bytes.len() {
            bytes.extend_from_slice(&pieces[i].1);
            i += 1;
        }
        varint::write_u64(&mut out, (start - cursor) as u64);
        varint::write_u64(&mut out, bytes.len() as u64);
        out.extend_from_slice(&bytes);
        cursor = start + bytes.len();
    }
    Ok(out)
}

/// Fold a whole chain of patches into ONE equivalent patch, so a deep
/// catch-up replays a single hop instead of `k` sequential applies
/// (ROADMAP item 5d).  All links must be in-place; errs otherwise
/// (callers fall back to sequential [`apply_chain`] replay).
pub fn fold_chain(patches: &[Patch], c: Compression) -> Result<Patch, PatchError> {
    let first = patches.first().ok_or(PatchError::EmptyChain)?;
    let mut acc = decompress(&first.payload, first.compression)?;
    for (i, p) in patches[1..].iter().enumerate() {
        let ops = decompress(&p.payload, p.compression)?;
        acc = fold_ops(&acc, &ops).map_err(|e| PatchError::FoldLink {
            index: i + 1,
            len: patches.len(),
            source: Box::new(e),
        })?;
    }
    let raw_len = acc.len();
    Ok(Patch { compression: c, payload: compress(&acc, c), raw_len })
}

/// Replay a *delta chain*: apply `patches` in order, each against the
/// previous one's output.  The byte-level twin of the fleet catch-up
/// replay (which runs the same sequence through
/// [`crate::transfer::UpdateReceiver::apply`] so quantized payloads
/// decode along the way); used directly by `fw apply` for offline
/// chain reconstruction, and must land on bytes identical to a fresh
/// snapshot.
pub fn apply_chain(base: &[u8], patches: &[Patch]) -> Result<Vec<u8>, PatchError> {
    let mut cur = base.to_vec();
    for (i, p) in patches.iter().enumerate() {
        cur = apply_patch(&cur, p).map_err(|e| PatchError::ChainLink {
            index: i,
            len: patches.len(),
            source: Box::new(e),
        })?;
    }
    Ok(cur)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop;
    use crate::util::rng::Pcg32;

    fn roundtrip(old: &[u8], new: &[u8], c: Compression) {
        let p = make_patch(old, new, c);
        let got = apply_patch(old, &p).unwrap();
        assert_eq!(got, new);
    }

    #[test]
    fn identical_buffers_tiny_patch() {
        let data = vec![7u8; 100_000];
        let p = make_patch(&data, &data, Compression::Lz);
        let got = apply_patch(&data, &p).unwrap();
        assert_eq!(got, data);
        assert!(p.wire_bytes() < 100, "patch {} bytes", p.wire_bytes());
    }

    #[test]
    fn single_byte_change() {
        let old = vec![0u8; 10_000];
        let mut new = old.clone();
        new[5123] = 42;
        roundtrip(&old, &new, Compression::None);
        let p = make_patch(&old, &new, Compression::None);
        // varint relative offset keeps this tiny
        assert!(p.raw_len < 32, "raw {} bytes", p.raw_len);
    }

    #[test]
    fn all_compressions_roundtrip() {
        let mut rng = Pcg32::seeded(1);
        let old: Vec<u8> = (0..50_000).map(|_| rng.next_u32() as u8).collect();
        let mut new = old.clone();
        for _ in 0..500 {
            let i = rng.below(50_000) as usize;
            new[i] = new[i].wrapping_add(1 + rng.below(255) as u8);
        }
        for c in [Compression::None, Compression::Lz] {
            roundtrip(&old, &new, c);
        }
    }

    #[test]
    fn sparse_changes_much_smaller_than_full_file() {
        let mut rng = Pcg32::seeded(2);
        // simulate a weight file: 1M bytes, 1% of 4-byte words changed
        let old: Vec<u8> = (0..1_000_000).map(|_| rng.next_u32() as u8).collect();
        let mut new = old.clone();
        for _ in 0..2500 {
            let w = rng.below(250_000) as usize * 4;
            for b in 0..4 {
                new[w + b] = rng.next_u32() as u8;
            }
        }
        let p = make_patch(&old, &new, Compression::Lz);
        assert!(
            p.wire_bytes() < old.len() / 10,
            "patch {} vs file {}",
            p.wire_bytes(),
            old.len()
        );
    }

    #[test]
    fn growth_and_shrink() {
        let old = b"hello old world".to_vec();
        let grown = b"hello NEW world plus tail".to_vec();
        roundtrip(&old, &grown, Compression::None);
        let shrunk = b"hello".to_vec();
        roundtrip(&old, &shrunk, Compression::None);
        roundtrip(&[], &old, Compression::None);
        roundtrip(&old, &[], Compression::None);
    }

    #[test]
    fn wrong_base_rejected() {
        let old = vec![1u8; 100];
        let new = vec![2u8; 100];
        let p = make_patch(&old, &new, Compression::None);
        let other = vec![1u8; 99];
        assert!(apply_patch(&other, &p).is_err());
    }

    #[test]
    fn corrupt_patch_rejected() {
        let old = vec![1u8; 100];
        let mut new = old.clone();
        new[50] = 9;
        let p = make_patch(&old, &new, Compression::None);
        let mut bad = p.clone();
        bad.payload.truncate(bad.payload.len() - 1);
        assert!(apply_patch(&old, &bad).is_err());
        assert!(apply_ops(&old, b"XXXX").is_err());
    }

    #[test]
    fn wire_roundtrip() {
        let old = vec![3u8; 1000];
        let mut new = old.clone();
        new[1] = 7;
        let p = make_patch(&old, &new, Compression::Lz);
        let wire = p.to_wire();
        let back = Patch::from_wire(&wire).unwrap();
        assert_eq!(back.compression, Compression::Lz);
        assert_eq!(apply_patch(&old, &back).unwrap(), new);
    }

    #[test]
    fn prop_patch_apply_inverts_diff() {
        prop(60, |g| {
            let old = g.bytes(0..2000);
            let mut new = old.clone();
            // random mutations: point edits, block edits, resize
            match g.usize_in(0..3) {
                0 => {
                    for _ in 0..g.usize_in(0..50) {
                        if new.is_empty() {
                            break;
                        }
                        let n = new.len();
                        let i = g.usize_in(0..n);
                        new[i] = g.u32() as u8;
                    }
                }
                1 => {
                    new.extend(g.bytes(0..300));
                }
                _ => {
                    let n = new.len();
                    new.truncate(g.usize_in(0..n.max(1)));
                }
            }
            for c in [Compression::None, Compression::Lz] {
                let p = make_patch(&old, &new, c);
                assert_eq!(apply_patch(&old, &p).unwrap(), new);
            }
        });
    }

    #[test]
    fn chain_replay_equals_direct_patch() {
        // K chained patches replayed in order == one patch old->newest
        let mut rng = Pcg32::seeded(7);
        let mut snaps = vec![(0..20_000)
            .map(|_| rng.next_u32() as u8)
            .collect::<Vec<u8>>()];
        for _ in 0..5 {
            let mut next = snaps.last().unwrap().clone();
            for _ in 0..300 {
                let i = rng.below(20_000) as usize;
                next[i] = next[i].wrapping_add(1 + rng.below(254) as u8);
            }
            snaps.push(next);
        }
        let chain: Vec<Patch> = snaps
            .windows(2)
            .map(|w| make_patch(&w[0], &w[1], Compression::Lz))
            .collect();
        let replayed = apply_chain(&snaps[0], &chain).unwrap();
        assert_eq!(&replayed, snaps.last().unwrap());
        // a broken link reports its position (wrong-length base)
        let err = apply_chain(&snaps[0][..10_000], &chain).unwrap_err();
        assert!(err.to_string().contains("chain link 0/"), "{err}");
    }

    fn mutate_in_place(rng: &mut Pcg32, buf: &mut [u8], edits: usize) {
        for _ in 0..edits {
            let i = rng.below(buf.len() as u32) as usize;
            buf[i] = buf[i].wrapping_add(1 + rng.below(254) as u8);
        }
    }

    #[test]
    fn folded_chain_equals_sequential_replay() {
        // K in-place patches folded into ONE patch produce bytes
        // identical to replaying the chain link by link — the deep
        // catch-up single-hop guarantee.
        let mut rng = Pcg32::seeded(21);
        let mut snaps = vec![(0..30_000)
            .map(|_| rng.next_u32() as u8)
            .collect::<Vec<u8>>()];
        for _ in 0..6 {
            let mut next = snaps.last().unwrap().clone();
            mutate_in_place(&mut rng, &mut next, 400);
            snaps.push(next);
        }
        let chain: Vec<Patch> = snaps
            .windows(2)
            .map(|w| make_patch(&w[0], &w[1], Compression::Lz))
            .collect();
        let folded = fold_chain(&chain, Compression::Lz).unwrap();
        let via_fold = apply_patch(&snaps[0], &folded).unwrap();
        let via_replay = apply_chain(&snaps[0], &chain).unwrap();
        assert_eq!(via_fold, via_replay);
        assert_eq!(&via_fold, snaps.last().unwrap());
        // one merged hop must not cost more than the summed chain
        let chain_bytes: usize = chain.iter().map(|p| p.wire_bytes()).sum();
        assert!(
            folded.wire_bytes() <= chain_bytes,
            "folded {} > chain {}",
            folded.wire_bytes(),
            chain_bytes
        );
    }

    #[test]
    fn fold_refuses_length_changing_patches() {
        let old = vec![1u8; 100];
        let grown = vec![2u8; 120];
        let a = make_patch(&old, &grown, Compression::None);
        let b = make_patch(&grown, &grown, Compression::None);
        assert!(fold_chain(&[a, b], Compression::None).is_err());
        assert!(fold_chain(&[], Compression::None).is_err());
    }

    #[test]
    fn prop_fold_ops_overlay_is_exact() {
        prop(40, |g| {
            let n = g.usize_in(64..4096);
            let base: Vec<u8> = (0..n).map(|_| g.u32() as u8).collect();
            let mut mid = base.clone();
            let mut rng = Pcg32::seeded(g.u64());
            mutate_in_place(&mut rng, &mut mid, g.usize_in(1..120));
            let mut new = mid.clone();
            mutate_in_place(&mut rng, &mut new, g.usize_in(1..120));
            let a = diff_ops(&base, &mid);
            let b = diff_ops(&mid, &new);
            let folded = fold_ops(&a, &b).unwrap();
            assert_eq!(apply_ops(&base, &folded).unwrap(), new);
        });
    }

    #[test]
    fn merged_runs_have_fewer_ops_than_naive() {
        // clustered changes: 100 dirty 4-byte words in one 4KB region
        let old = vec![0u8; 100_000];
        let mut new = old.clone();
        let mut rng = Pcg32::seeded(3);
        for _ in 0..100 {
            let i = 50_000 + (rng.below(1000) as usize) * 4;
            for b in 0..4 {
                new[i + b] = 0xAB;
            }
        }
        let ops = diff_ops(&old, &new);
        // merging nearby runs: op stream should be near the dirty-region
        // size, far below per-word op overhead
        assert!(ops.len() < 8_000, "ops {} bytes", ops.len());
    }
}
