//! Field-aware factorization block (the red block of Figure 2):
//!
//! `ffm(w, x) = Σ_{j1<j2} ⟨w_{j1,f2}, w_{j2,f1}⟩ · x_{j1} x_{j2}`
//!
//! with the *DiagMask* — only the strict upper triangle of field pairs
//! is produced, "inducing half smaller number of combinations requiring
//! down-stream processing".
//!
//! Layout: the latent row of a bucket is `[fields * k]` floats,
//! field-major (`toward_field * k + kk`), so the inner dot product of a
//! pair is two contiguous stride-1 K-vectors — the property both the
//! CPU SIMD path (rust) and the Pallas kernel's VMEM tiling (python)
//! exploit.  Pair emission order (row-major upper triangle) is part of
//! the cross-layer ABI shared with `python/compile/kernels/ref.py`.

use crate::feature::Example;
use crate::model::optimizer::UpdateRule;
use crate::model::weights::Layout;
use crate::simd::dot;

/// Compute all pair interactions into `pairs` (len = F*(F-1)/2).
/// Returns the scalar FFM output (sum of pairs).
///
/// SIMD dispatch happens once per example (§5): the AVX2 kernels below
/// prefetch every latent row up front (the pair loop's gathers are the
/// dominant memory cost) and keep the whole O(F²) loop inside one
/// `#[target_feature]` region.  On top of the ISA rung, the hot latent
/// dims k ∈ {4, 8, 16} select fully-unrolled `const K` kernel bodies
/// (fwumious_wabbit's `specialize_k!` trick): the per-pair dot and its
/// strip loads unroll with the strip resident in registers, while any
/// other `k` takes the same body with `K = 0`, meaning runtime-`k`.
/// The specialized body performs the identical floating-point operation
/// sequence as the runtime one, so specialization never changes a
/// result bit.
pub fn forward(
    weights: &[f32],
    layout: &Layout,
    fields: usize,
    k: usize,
    ex: &Example,
    pairs: &mut [f32],
) -> f32 {
    #[cfg(target_arch = "x86_64")]
    if crate::simd::isa_level() >= crate::simd::IsaLevel::Avx2Fma
        && (k == 4 || k % 8 == 0)
    {
        // SAFETY: isa_level at or above Avx2Fma implies runtime CPUID
        // confirmed avx2+fma (every avx2 CPU also has sse4.1); the k
        // guard above and the caller's layout/shape contract satisfy
        // forward_avx2's remaining preconditions, and every const-K arm
        // passes K == k or K == 0.
        return unsafe {
            match k {
                4 => forward_avx2::<4>(weights, layout, fields, k, ex, pairs),
                8 => forward_avx2::<8>(weights, layout, fields, k, ex, pairs),
                16 => forward_avx2::<16>(weights, layout, fields, k, ex, pairs),
                _ => forward_avx2::<0>(weights, layout, fields, k, ex, pairs),
            }
        };
    }
    match k {
        4 => forward_generic_k::<4>(weights, layout, fields, k, ex, pairs),
        8 => forward_generic_k::<8>(weights, layout, fields, k, ex, pairs),
        16 => forward_generic_k::<16>(weights, layout, fields, k, ex, pairs),
        _ => forward_generic_k::<0>(weights, layout, fields, k, ex, pairs),
    }
}

/// Bench-only entry: the dispatched rung's kernel with specialization
/// disabled (`K = 0`, runtime-`k` body).  Exists so the Fig. 5 bench
/// can measure the const-`k` win on identical inputs; not part of the
/// serving API.
#[doc(hidden)]
pub fn forward_runtime_k(
    weights: &[f32],
    layout: &Layout,
    fields: usize,
    k: usize,
    ex: &Example,
    pairs: &mut [f32],
) -> f32 {
    #[cfg(target_arch = "x86_64")]
    if crate::simd::isa_level() >= crate::simd::IsaLevel::Avx2Fma
        && (k == 4 || k % 8 == 0)
    {
        // SAFETY: same contract as the dispatch in [`forward`].
        return unsafe { forward_avx2::<0>(weights, layout, fields, k, ex, pairs) };
    }
    forward_generic_k::<0>(weights, layout, fields, k, ex, pairs)
}

/// Portable pair loop (also the SIMD-disabled control arm of Fig. 5).
pub fn forward_generic(
    weights: &[f32],
    layout: &Layout,
    fields: usize,
    k: usize,
    ex: &Example,
    pairs: &mut [f32],
) -> f32 {
    forward_generic_k::<0>(weights, layout, fields, k, ex, pairs)
}

/// Per-pair latent dot: unrolled `0..K` when specialized, the
/// dispatched [`dot::dot`] when `K = 0` (runtime-`k`).  For the
/// specialized dims (4, 8, 16 — all below the vector threshold of
/// `dot`) both forms run the same scalar accumulation order, so the
/// paths are bit-identical.
#[inline(always)]
fn pair_dot<const K: usize>(a: &[f32], b: &[f32]) -> f32 {
    if K == 0 {
        return dot::dot(a, b);
    }
    let mut s = 0.0f32;
    for kk in 0..K {
        s += a[kk] * b[kk];
    }
    s
}

/// Portable pair loop body, const-`k` specializable (`K = 0` means
/// runtime-`k`; otherwise `K == k`).
fn forward_generic_k<const K: usize>(
    weights: &[f32],
    layout: &Layout,
    fields: usize,
    k: usize,
    ex: &Example,
    pairs: &mut [f32],
) -> f32 {
    debug_assert_eq!(pairs.len(), fields * (fields - 1) / 2);
    debug_assert!(K == 0 || K == k, "specialized K must match runtime k");
    let fk = fields * k;
    let base = layout.ffm_off;
    let mut total = 0.0f32;
    let mut p = 0;
    for i in 0..fields {
        let si = &ex.slots[i];
        if si.value == 0.0 {
            // whole row of pairs is zero
            let n = fields - i - 1;
            pairs[p..p + n].fill(0.0);
            p += n;
            continue;
        }
        let row_i = base + si.bucket as usize * fk;
        for j in (i + 1)..fields {
            let sj = &ex.slots[j];
            if sj.value == 0.0 {
                pairs[p] = 0.0;
                p += 1;
                continue;
            }
            let row_j = base + sj.bucket as usize * fk;
            // ⟨w_{i, toward j}, w_{j, toward i}⟩
            let a = &weights[row_i + j * k..row_i + j * k + k];
            let b = &weights[row_j + i * k..row_j + i * k + k];
            let v = pair_dot::<K>(a, b) * si.value * sj.value;
            pairs[p] = v;
            total += v;
            p += 1;
        }
    }
    total
}

/// Whole-loop AVX2 kernel: prefetches all F latent rows, then runs the
/// masked pair loop with vector dots (SSE4.1 `dpps` for k=4, 256-bit
/// FMA + horizontal sum for k multiple of 8).  `K` is the const-`k`
/// specialization knob: `K == k` unrolls the strip loop and folds the
/// k=4 branch at compile time, `K == 0` keeps the runtime-`k` body;
/// both run the identical FP operation sequence.
///
/// # Safety
/// Caller must ensure the CPU supports avx2+fma+sse4.1
/// (runtime-detected), `k == 4 || k % 8 == 0`, `K == 0 || K == k`,
/// `ex.slots.len() == fields`, `pairs.len() == fields*(fields-1)/2`,
/// and every slot bucket within the layout's FFM table so
/// `base + bucket*fk + fk <= weights.len()`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma,sse4.1")]
unsafe fn forward_avx2<const K: usize>(
    weights: &[f32],
    layout: &Layout,
    fields: usize,
    k: usize,
    ex: &Example,
    pairs: &mut [f32],
) -> f32 {
    use std::arch::x86_64::*;
    debug_assert!(K == 0 || K == k, "specialized K must match runtime k");
    let k = if K == 0 { k } else { K };
    let fk = fields * k;
    let base = layout.ffm_off;
    // Prefetch every row referenced by this example: the pair loop
    // reads F*(F-1) scattered K-strips; issuing the loads early
    // overlaps the misses with compute.
    for s in &ex.slots {
        if s.value != 0.0 {
            // SAFETY: bucket is within the FFM table (fn contract), so
            // row..row+fk stays inside `weights`.
            unsafe {
                let row = weights.as_ptr().add(base + s.bucket as usize * fk);
                let mut off = 0usize;
                while off < fk {
                    _mm_prefetch::<_MM_HINT_T0>(row.add(off) as *const i8);
                    off += 16; // one cache line of f32
                }
            }
        }
    }
    let mut total = 0.0f32;
    let mut p = 0usize;
    for i in 0..fields {
        let si = &ex.slots[i];
        if si.value == 0.0 {
            let n = fields - i - 1;
            pairs[p..p + n].fill(0.0);
            p += n;
            continue;
        }
        // SAFETY: bucket within the FFM table bounds row_i (fn
        // contract).
        let row_i = unsafe { weights.as_ptr().add(base + si.bucket as usize * fk) };
        for j in (i + 1)..fields {
            let sj = &ex.slots[j];
            if sj.value == 0.0 {
                pairs[p] = 0.0;
                p += 1;
                continue;
            }
            // SAFETY: bucket bounds row_j; i, j < fields keep both
            // k-strips (offset j*k resp. i*k, length k) inside their
            // fk-float rows.
            let (a, b) = unsafe {
                let row_j =
                    weights.as_ptr().add(base + sj.bucket as usize * fk);
                (row_i.add(j * k), row_j.add(i * k))
            };
            let d = if k == 4 {
                // SAFETY: k == 4 bounds both 4-lane unaligned loads.
                let (va, vb) = unsafe { (_mm_loadu_ps(a), _mm_loadu_ps(b)) };
                _mm_cvtss_f32(_mm_dp_ps::<0xF1>(va, vb))
            } else {
                // k % 8 == 0
                let mut acc = _mm256_setzero_ps();
                let mut kk = 0;
                while kk < k {
                    // SAFETY: kk + 8 <= k bounds both 8-lane loads.
                    unsafe {
                        let va = _mm256_loadu_ps(a.add(kk));
                        let vb = _mm256_loadu_ps(b.add(kk));
                        acc = _mm256_fmadd_ps(va, vb, acc);
                    }
                    kk += 8;
                }
                let hi = _mm256_extractf128_ps::<1>(acc);
                let lo = _mm256_castps256_ps128(acc);
                let s4 = _mm_add_ps(hi, lo);
                let s2 = _mm_add_ps(s4, _mm_movehl_ps(s4, s4));
                _mm_cvtss_f32(_mm_add_ss(s2, _mm_shuffle_ps::<1>(s2, s2)))
            };
            let v = d * si.value * sj.value;
            pairs[p] = v;
            total += v;
            p += 1;
        }
    }
    total
}

/// Partial pair computation for the §5 context cache: computes only
/// the pairs involving at least one CANDIDATE field (j >= ctx_len),
/// leaving the context×context entries of `pairs` untouched (the
/// caller fills those from the cached partial).  `all_slots` must hold
/// context slots in fields `0..ctx_len` and candidate slots after.
pub fn forward_partial(
    weights: &[f32],
    layout: &Layout,
    fields: usize,
    k: usize,
    ctx_len: usize,
    all_slots: &[crate::feature::FeatureSlot],
    pairs: &mut [f32],
) {
    #[cfg(target_arch = "x86_64")]
    if crate::simd::isa_level() >= crate::simd::IsaLevel::Avx2Fma
        && (k == 4 || k % 8 == 0)
    {
        // SAFETY: isa_level at or above Avx2Fma implies runtime CPUID
        // confirmed avx2+fma (every avx2 CPU also has sse4.1); the k
        // guard above and the caller's layout/shape contract satisfy
        // forward_partial_avx2's remaining preconditions, and every
        // const-K arm passes K == k or K == 0.
        unsafe {
            match k {
                4 => forward_partial_avx2::<4>(
                    weights, layout, fields, k, ctx_len, all_slots, pairs,
                ),
                8 => forward_partial_avx2::<8>(
                    weights, layout, fields, k, ctx_len, all_slots, pairs,
                ),
                16 => forward_partial_avx2::<16>(
                    weights, layout, fields, k, ctx_len, all_slots, pairs,
                ),
                _ => forward_partial_avx2::<0>(
                    weights, layout, fields, k, ctx_len, all_slots, pairs,
                ),
            }
        };
        return;
    }
    match k {
        4 => forward_partial_generic_k::<4>(
            weights, layout, fields, k, ctx_len, all_slots, pairs,
        ),
        8 => forward_partial_generic_k::<8>(
            weights, layout, fields, k, ctx_len, all_slots, pairs,
        ),
        16 => forward_partial_generic_k::<16>(
            weights, layout, fields, k, ctx_len, all_slots, pairs,
        ),
        _ => forward_partial_generic_k::<0>(
            weights, layout, fields, k, ctx_len, all_slots, pairs,
        ),
    }
}

/// Portable partial pair loop.
pub fn forward_partial_generic(
    weights: &[f32],
    layout: &Layout,
    fields: usize,
    k: usize,
    ctx_len: usize,
    all_slots: &[crate::feature::FeatureSlot],
    pairs: &mut [f32],
) {
    forward_partial_generic_k::<0>(weights, layout, fields, k, ctx_len, all_slots, pairs)
}

/// Portable partial pair loop body, const-`k` specializable (`K = 0`
/// means runtime-`k`; otherwise `K == k`).
fn forward_partial_generic_k<const K: usize>(
    weights: &[f32],
    layout: &Layout,
    fields: usize,
    k: usize,
    ctx_len: usize,
    all_slots: &[crate::feature::FeatureSlot],
    pairs: &mut [f32],
) {
    debug_assert!(K == 0 || K == k, "specialized K must match runtime k");
    let fk = fields * k;
    let base = layout.ffm_off;
    for i in 0..fields {
        let si = &all_slots[i];
        let j0 = (i + 1).max(ctx_len);
        // row-major upper triangle: indices for fixed i are contiguous
        let row_base = i * (2 * fields - i - 1) / 2;
        if si.value == 0.0 {
            pairs[row_base + (j0 - i - 1)..row_base + (fields - i - 1)].fill(0.0);
            continue;
        }
        let row_i = base + si.bucket as usize * fk;
        for j in j0..fields {
            let sj = &all_slots[j];
            let pi = row_base + (j - i - 1);
            if sj.value == 0.0 {
                pairs[pi] = 0.0;
                continue;
            }
            let row_j = base + sj.bucket as usize * fk;
            let a = &weights[row_i + j * k..row_i + j * k + k];
            let b = &weights[row_j + i * k..row_j + i * k + k];
            pairs[pi] = pair_dot::<K>(a, b) * si.value * sj.value;
        }
    }
}

/// AVX2 partial pair loop with candidate-row prefetch.  `K` is the
/// const-`k` specialization knob (see [`forward_avx2`]).
///
/// # Safety
/// Caller must ensure the CPU supports avx2+fma+sse4.1
/// (runtime-detected), `k == 4 || k % 8 == 0`, `K == 0 || K == k`,
/// `all_slots.len() == fields`, `pairs.len() == fields*(fields-1)/2`,
/// and every slot bucket within the layout's FFM table so
/// `base + bucket*fk + fk <= weights.len()`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma,sse4.1")]
unsafe fn forward_partial_avx2<const K: usize>(
    weights: &[f32],
    layout: &Layout,
    fields: usize,
    k: usize,
    ctx_len: usize,
    all_slots: &[crate::feature::FeatureSlot],
    pairs: &mut [f32],
) {
    use std::arch::x86_64::*;
    debug_assert!(K == 0 || K == k, "specialized K must match runtime k");
    let k = if K == 0 { k } else { K };
    let fk = fields * k;
    let base = layout.ffm_off;
    for s in &all_slots[ctx_len..] {
        if s.value != 0.0 {
            // SAFETY: bucket is within the FFM table (fn contract), so
            // row..row+fk stays inside `weights`.
            unsafe {
                let row = weights.as_ptr().add(base + s.bucket as usize * fk);
                let mut off = 0usize;
                while off < fk {
                    _mm_prefetch::<_MM_HINT_T0>(row.add(off) as *const i8);
                    off += 16;
                }
            }
        }
    }
    for i in 0..fields {
        let si = &all_slots[i];
        let j0 = (i + 1).max(ctx_len);
        let row_base = i * (2 * fields - i - 1) / 2;
        if si.value == 0.0 {
            pairs[row_base + (j0 - i - 1)..row_base + (fields - i - 1)].fill(0.0);
            continue;
        }
        // SAFETY: bucket within the FFM table bounds row_i (fn
        // contract).
        let row_i = unsafe { weights.as_ptr().add(base + si.bucket as usize * fk) };
        for j in j0..fields {
            let sj = &all_slots[j];
            let pi = row_base + (j - i - 1);
            if sj.value == 0.0 {
                pairs[pi] = 0.0;
                continue;
            }
            // SAFETY: bucket bounds row_j; i, j < fields keep both
            // k-strips inside their fk-float rows.
            let (a, b) = unsafe {
                let row_j =
                    weights.as_ptr().add(base + sj.bucket as usize * fk);
                (row_i.add(j * k), row_j.add(i * k))
            };
            let d = if k == 4 {
                // SAFETY: k == 4 bounds both 4-lane unaligned loads.
                let (va, vb) = unsafe { (_mm_loadu_ps(a), _mm_loadu_ps(b)) };
                _mm_cvtss_f32(_mm_dp_ps::<0xF1>(va, vb))
            } else {
                let mut acc = _mm256_setzero_ps();
                let mut kk = 0;
                while kk < k {
                    // SAFETY: kk + 8 <= k bounds both 8-lane loads.
                    unsafe {
                        acc = _mm256_fmadd_ps(
                            _mm256_loadu_ps(a.add(kk)),
                            _mm256_loadu_ps(b.add(kk)),
                            acc,
                        );
                    }
                    kk += 8;
                }
                let hi = _mm256_extractf128_ps::<1>(acc);
                let lo = _mm256_castps256_ps128(acc);
                let s4 = _mm_add_ps(hi, lo);
                let s2 = _mm_add_ps(s4, _mm_movehl_ps(s4, s4));
                _mm_cvtss_f32(_mm_add_ss(s2, _mm_shuffle_ps::<1>(s2, s2)))
            };
            pairs[pi] = d * si.value * sj.value;
        }
    }
}

/// Batched partial pair computation: all B candidates of one request in
/// a single pass (the tentpole of the request-level batching PR).
///
/// * `ctx_slots` — the C shared context slots (fields `0..ctx_len`).
/// * `cand_slots` — `B × (fields − ctx_len)` candidate slots laid out
///   candidate-major (candidate 0's fields, then candidate 1's, …).
/// * `pairs` — batch-strided output, `B × P` with `P = F(F−1)/2`;
///   context×context entries of every stride are left untouched (the
///   caller fills them from the cached [`ContextPartial`]
///   (crate::model::regressor::ContextPartial)).
///
/// The loop is *field-outer*, inverted from the candidate-outer
/// sequential path: each context latent strip `w_{ctx_i, toward j}` is
/// loaded once and stays register-hot while its ctx×cand dots are
/// computed for **all** candidates, and the whole batch shares one
/// prefetch pass.  Per-candidate results are bit-identical for any
/// batch size at a fixed ISA level (the serving layer relies on this —
/// see [`crate::simd::batch`]).
#[allow(clippy::too_many_arguments)]
pub fn forward_partial_batch(
    weights: &[f32],
    layout: &Layout,
    fields: usize,
    k: usize,
    ctx_len: usize,
    ctx_slots: &[crate::feature::FeatureSlot],
    cand_slots: &[crate::feature::FeatureSlot],
    pairs: &mut [f32],
) {
    if ctx_len >= fields {
        // context covers every field: no ctx×cand or cand×cand pairs
        // exist (guards the batch-count division in the kernels).
        return;
    }
    #[cfg(target_arch = "x86_64")]
    if crate::simd::isa_level() >= crate::simd::IsaLevel::Avx2Fma
        && (k == 4 || k % 8 == 0)
    {
        // SAFETY: isa_level at or above Avx2Fma implies runtime CPUID
        // confirmed avx2+fma (every avx2 CPU also has sse4.1); the k
        // guard above, the ctx_len < fields guard, and the caller's
        // layout/shape contract satisfy forward_partial_batch_avx2's
        // remaining preconditions, and every const-K arm passes K == k
        // or K == 0.
        unsafe {
            match k {
                4 => forward_partial_batch_avx2::<4>(
                    weights, layout, fields, k, ctx_len, ctx_slots, cand_slots, pairs,
                ),
                8 => forward_partial_batch_avx2::<8>(
                    weights, layout, fields, k, ctx_len, ctx_slots, cand_slots, pairs,
                ),
                16 => forward_partial_batch_avx2::<16>(
                    weights, layout, fields, k, ctx_len, ctx_slots, cand_slots, pairs,
                ),
                _ => forward_partial_batch_avx2::<0>(
                    weights, layout, fields, k, ctx_len, ctx_slots, cand_slots, pairs,
                ),
            }
        };
        return;
    }
    match k {
        4 => forward_partial_batch_generic_k::<4>(
            weights, layout, fields, k, ctx_len, ctx_slots, cand_slots, pairs,
        ),
        8 => forward_partial_batch_generic_k::<8>(
            weights, layout, fields, k, ctx_len, ctx_slots, cand_slots, pairs,
        ),
        16 => forward_partial_batch_generic_k::<16>(
            weights, layout, fields, k, ctx_len, ctx_slots, cand_slots, pairs,
        ),
        _ => forward_partial_batch_generic_k::<0>(
            weights, layout, fields, k, ctx_len, ctx_slots, cand_slots, pairs,
        ),
    }
}

/// Bench-only entry: the dispatched rung's batched kernel with
/// specialization disabled (`K = 0`, runtime-`k` body).  Counterpart of
/// [`forward_runtime_k`] for the serving-path kernel; not part of the
/// serving API.
#[doc(hidden)]
#[allow(clippy::too_many_arguments)]
pub fn forward_partial_batch_runtime_k(
    weights: &[f32],
    layout: &Layout,
    fields: usize,
    k: usize,
    ctx_len: usize,
    ctx_slots: &[crate::feature::FeatureSlot],
    cand_slots: &[crate::feature::FeatureSlot],
    pairs: &mut [f32],
) {
    if ctx_len >= fields {
        return;
    }
    #[cfg(target_arch = "x86_64")]
    if crate::simd::isa_level() >= crate::simd::IsaLevel::Avx2Fma
        && (k == 4 || k % 8 == 0)
    {
        // SAFETY: same contract as the dispatch in
        // [`forward_partial_batch`].
        unsafe {
            forward_partial_batch_avx2::<0>(
                weights, layout, fields, k, ctx_len, ctx_slots, cand_slots, pairs,
            )
        };
        return;
    }
    forward_partial_batch_generic_k::<0>(
        weights, layout, fields, k, ctx_len, ctx_slots, cand_slots, pairs,
    );
}

/// Portable batched partial pair loop.
#[allow(clippy::too_many_arguments)]
pub fn forward_partial_batch_generic(
    weights: &[f32],
    layout: &Layout,
    fields: usize,
    k: usize,
    ctx_len: usize,
    ctx_slots: &[crate::feature::FeatureSlot],
    cand_slots: &[crate::feature::FeatureSlot],
    pairs: &mut [f32],
) {
    forward_partial_batch_generic_k::<0>(
        weights, layout, fields, k, ctx_len, ctx_slots, cand_slots, pairs,
    )
}

/// Portable batched partial pair loop body, const-`k` specializable
/// (`K = 0` means runtime-`k`; otherwise `K == k`).
#[allow(clippy::too_many_arguments)]
fn forward_partial_batch_generic_k<const K: usize>(
    weights: &[f32],
    layout: &Layout,
    fields: usize,
    k: usize,
    ctx_len: usize,
    ctx_slots: &[crate::feature::FeatureSlot],
    cand_slots: &[crate::feature::FeatureSlot],
    pairs: &mut [f32],
) {
    debug_assert!(K == 0 || K == k, "specialized K must match runtime k");
    let cw = fields - ctx_len;
    debug_assert!(cw > 0, "no candidate fields");
    debug_assert_eq!(ctx_slots.len(), ctx_len);
    debug_assert_eq!(cand_slots.len() % cw, 0);
    let batch = cand_slots.len() / cw;
    let np = fields * (fields - 1) / 2;
    debug_assert_eq!(pairs.len(), batch * np);
    let fk = fields * k;
    let base = layout.ffm_off;
    // Phase A — ctx×cand, context strip pinned across the batch.
    for (i, si) in ctx_slots.iter().enumerate() {
        let row_base = i * (2 * fields - i - 1) / 2;
        let po = row_base + (ctx_len - i - 1); // index of pair (i, ctx_len)
        if si.value == 0.0 {
            for b in 0..batch {
                pairs[b * np + po..b * np + po + cw].fill(0.0);
            }
            continue;
        }
        let row_i = base + si.bucket as usize * fk;
        for jj in 0..cw {
            let j = ctx_len + jj;
            let a = &weights[row_i + j * k..row_i + j * k + k];
            for b in 0..batch {
                let sj = &cand_slots[b * cw + jj];
                let pi = b * np + po + jj;
                if sj.value == 0.0 {
                    pairs[pi] = 0.0;
                    continue;
                }
                let row_j = base + sj.bucket as usize * fk;
                let bv = &weights[row_j + i * k..row_j + i * k + k];
                pairs[pi] = pair_dot::<K>(a, bv) * si.value * sj.value;
            }
        }
    }
    // Phase B — cand×cand, candidate-local.
    for b in 0..batch {
        let cs = &cand_slots[b * cw..(b + 1) * cw];
        let pb = b * np;
        for (ii, si) in cs.iter().enumerate() {
            let i = ctx_len + ii;
            let row_base = i * (2 * fields - i - 1) / 2;
            if si.value == 0.0 {
                pairs[pb + row_base..pb + row_base + (fields - i - 1)].fill(0.0);
                continue;
            }
            let row_i = base + si.bucket as usize * fk;
            for (jj, sj) in cs.iter().enumerate().skip(ii + 1) {
                let j = ctx_len + jj;
                let pi = pb + row_base + (j - i - 1);
                if sj.value == 0.0 {
                    pairs[pi] = 0.0;
                    continue;
                }
                let row_j = base + sj.bucket as usize * fk;
                let a = &weights[row_i + j * k..row_i + j * k + k];
                let bv = &weights[row_j + i * k..row_j + i * k + k];
                pairs[pi] = pair_dot::<K>(a, bv) * si.value * sj.value;
            }
        }
    }
}

/// AVX2 batched partial pair loop: one shared prefetch pass, context
/// strips held in registers across the batch, and ctx×cand dots reduced
/// four candidates at a time through one batched horizontal sum
/// (`hadd` tree — the remainder path uses the same per-dot tree so any
/// candidate's value is independent of where it lands in the batch).
///
/// `K` is the const-`k` specialization knob (see [`forward_avx2`]).
/// When specialized (`K ∈ {8, 16}`), Phase A additionally hoists the
/// context strip into a ymm register array once per column instead of
/// reloading it per candidate — same FMA sequence, fewer loads, so
/// results stay bit-identical to the runtime-`k` body.
///
/// # Safety
/// Caller must ensure the CPU supports avx2+fma+sse4.1
/// (runtime-detected), `k == 4 || k % 8 == 0`, `K == 0 || K == k`,
/// `ctx_len < fields`, `ctx_slots.len() == ctx_len`,
/// `cand_slots.len()` a multiple of `fields - ctx_len`,
/// `pairs.len() == batch * fields*(fields-1)/2`, and every slot bucket
/// within the layout's FFM table so
/// `base + bucket*fk + fk <= weights.len()`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma,sse4.1")]
#[allow(clippy::too_many_arguments)]
unsafe fn forward_partial_batch_avx2<const K: usize>(
    weights: &[f32],
    layout: &Layout,
    fields: usize,
    k: usize,
    ctx_len: usize,
    ctx_slots: &[crate::feature::FeatureSlot],
    cand_slots: &[crate::feature::FeatureSlot],
    pairs: &mut [f32],
) {
    use std::arch::x86_64::*;
    debug_assert!(K == 0 || K == k, "specialized K must match runtime k");

    /// Σ over one 8-lane accumulator via the `hadd` tree:
    /// `((x0+x1)+(x2+x3)) + ((x4+x5)+(x6+x7))`.
    ///
    /// # Safety
    /// Caller must ensure the CPU supports avx2 — the body is
    /// value-only intrinsics (no memory access).
    #[target_feature(enable = "avx2,fma")]
    #[inline]
    unsafe fn hsum8_tree(v: __m256) -> f32 {
        let t = _mm256_hadd_ps(v, v);
        let t = _mm256_hadd_ps(t, t);
        let lo = _mm256_castps256_ps128(t);
        let hi = _mm256_extractf128_ps::<1>(t);
        _mm_cvtss_f32(_mm_add_ss(lo, hi))
    }

    /// Four accumulators reduced at once; lane r of the result equals
    /// `hsum8_tree(acc_r)` bit for bit.
    ///
    /// # Safety
    /// Caller must ensure the CPU supports avx2 — the body is
    /// value-only intrinsics (no memory access).
    #[target_feature(enable = "avx2,fma")]
    #[inline]
    unsafe fn hsum4x8_tree(a: __m256, b: __m256, c: __m256, d: __m256) -> __m128 {
        let ab = _mm256_hadd_ps(a, b);
        let cd = _mm256_hadd_ps(c, d);
        let q = _mm256_hadd_ps(ab, cd);
        _mm_add_ps(_mm256_castps256_ps128(q), _mm256_extractf128_ps::<1>(q))
    }

    let k = if K == 0 { k } else { K };
    let cw = fields - ctx_len;
    let batch = cand_slots.len() / cw;
    let np = fields * (fields - 1) / 2;
    let fk = fields * k;
    let base = layout.ffm_off;
    // One shared prefetch pass for the whole request: context rows and
    // every candidate row, instead of one pass per candidate.
    for s in ctx_slots.iter().chain(cand_slots.iter()) {
        if s.value != 0.0 {
            // SAFETY: bucket is within the FFM table (fn contract), so
            // row..row+fk stays inside `weights`.
            unsafe {
                let row = weights.as_ptr().add(base + s.bucket as usize * fk);
                let mut off = 0usize;
                while off < fk {
                    _mm_prefetch::<_MM_HINT_T0>(row.add(off) as *const i8);
                    off += 16;
                }
            }
        }
    }
    // Phase A — ctx×cand, field-outer.
    for (i, si) in ctx_slots.iter().enumerate() {
        let row_base = i * (2 * fields - i - 1) / 2;
        let po = row_base + (ctx_len - i - 1);
        if si.value == 0.0 {
            for b in 0..batch {
                pairs[b * np + po..b * np + po + cw].fill(0.0);
            }
            continue;
        }
        let vi = si.value;
        // SAFETY: bucket within the FFM table bounds row_i (fn
        // contract).
        let row_i = unsafe { weights.as_ptr().add(base + si.bucket as usize * fk) };
        for jj in 0..cw {
            let j = ctx_len + jj;
            // SAFETY: j < fields keeps the k-strip j*k..j*k+k inside
            // the fk-float row.
            let a = unsafe { row_i.add(j * k) };
            if k == 4 {
                // SAFETY: k == 4 bounds the 4-lane load from strip `a`.
                let va = unsafe { _mm_loadu_ps(a) };
                for b in 0..batch {
                    let sj = &cand_slots[b * cw + jj];
                    // SAFETY: bucket bounds row_j; i < fields and
                    // k == 4 bound the 4-lane load at offset i*k.
                    let vb = unsafe {
                        let row_j =
                            weights.as_ptr().add(base + sj.bucket as usize * fk);
                        _mm_loadu_ps(row_j.add(i * k))
                    };
                    let d = _mm_cvtss_f32(_mm_dp_ps::<0xF1>(va, vb));
                    pairs[b * np + po + jj] = d * vi * sj.value;
                }
                continue;
            }
            // k % 8 == 0: four candidates per batched horizontal sum.
            // When const-K specialized, hoist the context strip into a
            // register array once per column; the runtime-k body
            // (K == 0) reloads it inside the candidate loop.  The FMA
            // sequence is identical either way.
            let hoisted = K > 0 && k <= 16;
            let mut areg = [_mm256_setzero_ps(); 2];
            if hoisted {
                let mut kk = 0usize;
                while kk < k {
                    // SAFETY: kk + 8 <= k <= 16 bounds the 8-lane load
                    // from strip `a` and the areg index.
                    areg[kk / 8] = unsafe { _mm256_loadu_ps(a.add(kk)) };
                    kk += 8;
                }
            }
            let mut b = 0usize;
            while b + 4 <= batch {
                let mut acc = [_mm256_setzero_ps(); 4];
                let mut vals = [0f32; 4];
                for (r, (av, vv)) in acc.iter_mut().zip(vals.iter_mut()).enumerate() {
                    let sj = &cand_slots[(b + r) * cw + jj];
                    *vv = sj.value;
                    // SAFETY: bucket bounds the candidate row, i <
                    // fields offsets its k-strip, and kk + 8 <= k
                    // bounds every 8-lane load from both strips.
                    unsafe {
                        let row_j = weights
                            .as_ptr()
                            .add(base + sj.bucket as usize * fk + i * k);
                        let mut kk = 0usize;
                        while kk < k {
                            let va = if hoisted {
                                areg[kk / 8]
                            } else {
                                _mm256_loadu_ps(a.add(kk))
                            };
                            *av = _mm256_fmadd_ps(
                                va,
                                _mm256_loadu_ps(row_j.add(kk)),
                                *av,
                            );
                            kk += 8;
                        }
                    }
                }
                // SAFETY: avx2 is enabled per this fn's contract
                // (hsum4x8_tree is value-only).
                let d4 = unsafe { hsum4x8_tree(acc[0], acc[1], acc[2], acc[3]) };
                let prod = _mm_mul_ps(
                    _mm_mul_ps(d4, _mm_set1_ps(vi)),
                    _mm_set_ps(vals[3], vals[2], vals[1], vals[0]),
                );
                let mut tmp = [0f32; 4];
                // SAFETY: tmp is a 4-float stack array — exactly the
                // 128-bit store width.
                unsafe { _mm_storeu_ps(tmp.as_mut_ptr(), prod) };
                for (r, &t) in tmp.iter().enumerate() {
                    pairs[(b + r) * np + po + jj] = t;
                }
                b += 4;
            }
            while b < batch {
                let sj = &cand_slots[b * cw + jj];
                let mut acc = _mm256_setzero_ps();
                // SAFETY: bucket bounds the candidate row, i < fields
                // offsets its k-strip, and kk + 8 <= k bounds every
                // 8-lane load from both strips.
                unsafe {
                    let row_j = weights
                        .as_ptr()
                        .add(base + sj.bucket as usize * fk + i * k);
                    let mut kk = 0usize;
                    while kk < k {
                        let va = if hoisted {
                            areg[kk / 8]
                        } else {
                            _mm256_loadu_ps(a.add(kk))
                        };
                        acc = _mm256_fmadd_ps(
                            va,
                            _mm256_loadu_ps(row_j.add(kk)),
                            acc,
                        );
                        kk += 8;
                    }
                }
                // SAFETY: avx2 is enabled per this fn's contract
                // (hsum8_tree is value-only).
                pairs[b * np + po + jj] = unsafe { hsum8_tree(acc) } * vi * sj.value;
                b += 1;
            }
        }
    }
    // Phase B — cand×cand, candidate-local (same per-dot sequence as
    // the Phase-A remainder path).
    for b in 0..batch {
        let cs = &cand_slots[b * cw..(b + 1) * cw];
        let pb = b * np;
        for (ii, si) in cs.iter().enumerate() {
            let i = ctx_len + ii;
            let row_base = i * (2 * fields - i - 1) / 2;
            if si.value == 0.0 {
                pairs[pb + row_base..pb + row_base + (fields - i - 1)].fill(0.0);
                continue;
            }
            // SAFETY: bucket within the FFM table bounds row_i (fn
            // contract).
            let row_i = unsafe { weights.as_ptr().add(base + si.bucket as usize * fk) };
            for (jj, sj) in cs.iter().enumerate().skip(ii + 1) {
                let j = ctx_len + jj;
                let pi = pb + row_base + (j - i - 1);
                // SAFETY: bucket bounds row_j; i, j < fields keep both
                // k-strips inside their fk-float rows.
                let (a, bp) = unsafe {
                    let row_j =
                        weights.as_ptr().add(base + sj.bucket as usize * fk);
                    (row_i.add(j * k), row_j.add(i * k))
                };
                let d = if k == 4 {
                    // SAFETY: k == 4 bounds both 4-lane loads.
                    let (va, vb) = unsafe { (_mm_loadu_ps(a), _mm_loadu_ps(bp)) };
                    _mm_cvtss_f32(_mm_dp_ps::<0xF1>(va, vb))
                } else {
                    let mut acc = _mm256_setzero_ps();
                    let mut kk = 0usize;
                    while kk < k {
                        // SAFETY: kk + 8 <= k bounds both 8-lane loads.
                        unsafe {
                            acc = _mm256_fmadd_ps(
                                _mm256_loadu_ps(a.add(kk)),
                                _mm256_loadu_ps(bp.add(kk)),
                                acc,
                            );
                        }
                        kk += 8;
                    }
                    // SAFETY: avx2 is enabled per this fn's contract
                    // (hsum8_tree is value-only).
                    unsafe { hsum8_tree(acc) }
                };
                pairs[pi] = d * si.value * sj.value;
            }
        }
    }
}

/// Backward from per-pair gradients `dpairs` (same order as `forward`).
///
/// For pair (i, j):
///   d w_{i,j,kk} = dpair · w_{j,i,kk} · x_i x_j
///   d w_{j,i,kk} = dpair · w_{i,j,kk} · x_i x_j
///
/// Both sides read the *pre-update* latent values (copied to a small
/// stack buffer before updating), matching the analytic gradient.
pub fn backward<U: UpdateRule>(
    weights: &mut [f32],
    acc: &mut [f32],
    layout: &Layout,
    fields: usize,
    k: usize,
    ex: &Example,
    dpairs: &[f32],
    rule: &mut U,
) {
    debug_assert_eq!(dpairs.len(), fields * (fields - 1) / 2);
    let fk = fields * k;
    let base = layout.ffm_off;
    let mut buf = [0f32; 64];
    let mut p = 0;
    for i in 0..fields {
        let (vi, bi) = (ex.slots[i].value, ex.slots[i].bucket);
        for j in (i + 1)..fields {
            let g = dpairs[p];
            p += 1;
            let (vj, bj) = (ex.slots[j].value, ex.slots[j].bucket);
            if g == 0.0 || vi == 0.0 || vj == 0.0 {
                continue;
            }
            let scale = g * vi * vj;
            let off_i = base + bi as usize * fk + j * k;
            let off_j = base + bj as usize * fk + i * k;
            debug_assert!(k <= 64, "latent dim > stack buffer");
            buf[..k].copy_from_slice(&weights[off_i..off_i + k]);
            for kk in 0..k {
                let gj = scale * buf[kk]; // uses pre-update w_i
                let gi = scale * weights[off_j + kk];
                rule.update(off_i + kk, &mut weights[off_i + kk], &mut acc[off_i + kk], gi);
                rule.update(off_j + kk, &mut weights[off_j + kk], &mut acc[off_j + kk], gj);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::feature::{Example, FeatureSlot};
    use crate::model::optimizer::GradRecorder;
    use crate::model::weights::{Layout, WeightPool};
    use crate::util::rng::Pcg32;

    fn setup(fields: usize, k: usize) -> (ModelConfig, Layout, WeightPool, Example) {
        let cfg = ModelConfig::ffm(fields, k, 32);
        let layout = Layout::new(&cfg);
        let mut pool = WeightPool::init(&cfg, &layout);
        let mut rng = Pcg32::seeded(42);
        for w in &mut pool.weights[layout.ffm_off..] {
            *w = rng.normal() * 0.3;
        }
        let slots = (0..fields)
            .map(|f| FeatureSlot {
                field: f as u16,
                bucket: rng.below(32),
                value: 0.5 + rng.next_f32(),
            })
            .collect();
        (cfg, layout, pool, Example { label: 1.0, importance: 1.0, slots })
    }

    #[test]
    fn forward_matches_naive() {
        let (cfg, layout, pool, ex) = setup(5, 3);
        let mut pairs = vec![0f32; cfg.pairs()];
        let total = forward(&pool.weights, &layout, 5, 3, &ex, &mut pairs);
        // naive recomputation
        let fk = 5 * 3;
        let mut want_total = 0.0;
        let mut p = 0;
        for i in 0..5 {
            for j in (i + 1)..5 {
                let wi = layout.ffm_off + ex.slots[i].bucket as usize * fk + j * 3;
                let wj = layout.ffm_off + ex.slots[j].bucket as usize * fk + i * 3;
                let mut d = 0.0;
                for kk in 0..3 {
                    d += pool.weights[wi + kk] * pool.weights[wj + kk];
                }
                let v = d * ex.slots[i].value * ex.slots[j].value;
                assert!((pairs[p] - v).abs() < 1e-5, "pair {p}");
                want_total += v;
                p += 1;
            }
        }
        assert!((total - want_total).abs() < 1e-4);
    }

    #[test]
    fn simd_kernel_matches_generic() {
        for k in [4usize, 8, 16] {
            let (cfg, layout, pool, ex) = setup(5, k);
            let mut pairs_simd = vec![0f32; cfg.pairs()];
            let mut pairs_gen = vec![0f32; cfg.pairs()];
            let t1 = forward(&pool.weights, &layout, 5, k, &ex, &mut pairs_simd);
            let t2 =
                forward_generic(&pool.weights, &layout, 5, k, &ex, &mut pairs_gen);
            assert!((t1 - t2).abs() < 1e-4 * (1.0 + t2.abs()), "k={k}");
            for (a, b) in pairs_simd.iter().zip(&pairs_gen) {
                assert!((a - b).abs() < 1e-5, "k={k}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn absent_field_zeroes_its_pairs() {
        let (cfg, layout, pool, mut ex) = setup(4, 2);
        ex.slots[1].value = 0.0;
        let mut pairs = vec![0f32; cfg.pairs()];
        forward(&pool.weights, &layout, 4, 2, &ex, &mut pairs);
        // pairs touching field 1: (0,1)=idx0, (1,2)=idx3, (1,3)=idx4
        assert_eq!(pairs[0], 0.0);
        assert_eq!(pairs[3], 0.0);
        assert_eq!(pairs[4], 0.0);
        assert_ne!(pairs[1], 0.0); // (0,2)
    }

    #[test]
    fn partial_batch_matches_sequential_partial() {
        for k in [2usize, 3, 4, 8, 16] {
            let fields = 6;
            let ctx_len = 3;
            let (cfg, layout, pool, _) = setup(fields, k);
            let np = cfg.pairs();
            let mut rng = Pcg32::seeded(100 + k as u64);
            let slot = |rng: &mut Pcg32, f: usize| FeatureSlot {
                field: f as u16,
                bucket: rng.below(32),
                // every 5th slot absent, mirroring sparse traffic
                value: if rng.below(5) == 0 { 0.0 } else { 0.3 + rng.next_f32() },
            };
            let ctx: Vec<FeatureSlot> =
                (0..ctx_len).map(|f| slot(&mut rng, f)).collect();
            let batch = 7usize;
            let mut cand_flat = Vec::new();
            for _ in 0..batch {
                for f in ctx_len..fields {
                    cand_flat.push(slot(&mut rng, f));
                }
            }
            // sequential reference through the single-candidate kernel
            let cw = fields - ctx_len;
            let mut want = vec![f32::NAN; batch * np];
            for b in 0..batch {
                let mut all = ctx.clone();
                all.extend_from_slice(&cand_flat[b * cw..(b + 1) * cw]);
                forward_partial(
                    &pool.weights,
                    &layout,
                    fields,
                    k,
                    ctx_len,
                    &all,
                    &mut want[b * np..(b + 1) * np],
                );
            }
            // batched kernel; sentinel proves ctx×ctx stays untouched
            let mut got = vec![7.75f32; batch * np];
            forward_partial_batch(
                &pool.weights,
                &layout,
                fields,
                k,
                ctx_len,
                &ctx,
                &cand_flat,
                &mut got,
            );
            for b in 0..batch {
                for i in 0..fields {
                    for j in (i + 1)..fields {
                        let pi = b * np + i * (2 * fields - i - 1) / 2 + (j - i - 1);
                        if j < ctx_len {
                            assert_eq!(got[pi], 7.75, "k={k} b={b} ctx pair touched");
                        } else {
                            assert!(
                                (got[pi] - want[pi]).abs() < 1e-5,
                                "k={k} b={b} pair ({i},{j}): {} vs {}",
                                got[pi],
                                want[pi]
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn partial_batch_is_batch_size_invariant() {
        // Bit-identical results whether a candidate is scored alone or
        // inside a batch — the serving layer's equality contract.
        // Exercises the concrete kernels directly so a concurrent
        // `force_scalar` toggle elsewhere cannot flip the path mid-test.
        type Kernel = fn(
            &[f32],
            &Layout,
            usize,
            usize,
            usize,
            &[FeatureSlot],
            &[FeatureSlot],
            &mut [f32],
        );
        let mut impls: Vec<(&'static str, Kernel)> =
            vec![("generic", forward_partial_batch_generic)];
        #[cfg(target_arch = "x86_64")]
        if std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
            && std::arch::is_x86_feature_detected!("sse4.1")
        {
            fn avx2(
                weights: &[f32],
                layout: &Layout,
                fields: usize,
                k: usize,
                ctx_len: usize,
                ctx_slots: &[FeatureSlot],
                cand_slots: &[FeatureSlot],
                pairs: &mut [f32],
            ) {
                // SAFETY: the feature-detect guard above confirmed
                // avx2+fma+sse4.1; the test only passes k in {4, 8}
                // and shape-consistent slices.
                unsafe {
                    forward_partial_batch_avx2::<0>(
                        weights, layout, fields, k, ctx_len, ctx_slots, cand_slots,
                        pairs,
                    )
                }
            }
            fn avx2_spec(
                weights: &[f32],
                layout: &Layout,
                fields: usize,
                k: usize,
                ctx_len: usize,
                ctx_slots: &[FeatureSlot],
                cand_slots: &[FeatureSlot],
                pairs: &mut [f32],
            ) {
                // SAFETY: the feature-detect guard above confirmed
                // avx2+fma+sse4.1; the test only passes k in {4, 8}
                // and shape-consistent slices, and every const-K arm
                // passes K == k or K == 0.
                unsafe {
                    match k {
                        4 => forward_partial_batch_avx2::<4>(
                            weights, layout, fields, k, ctx_len, ctx_slots,
                            cand_slots, pairs,
                        ),
                        8 => forward_partial_batch_avx2::<8>(
                            weights, layout, fields, k, ctx_len, ctx_slots,
                            cand_slots, pairs,
                        ),
                        16 => forward_partial_batch_avx2::<16>(
                            weights, layout, fields, k, ctx_len, ctx_slots,
                            cand_slots, pairs,
                        ),
                        _ => forward_partial_batch_avx2::<0>(
                            weights, layout, fields, k, ctx_len, ctx_slots,
                            cand_slots, pairs,
                        ),
                    }
                }
            }
            impls.push(("avx2", avx2));
            impls.push(("avx2-spec", avx2_spec));
        }
        for k in [4usize, 8] {
            let fields = 7;
            let ctx_len = 3;
            let (cfg, layout, pool, _) = setup(fields, k);
            let np = cfg.pairs();
            let mut rng = Pcg32::seeded(200 + k as u64);
            let slot = |rng: &mut Pcg32, f: usize| FeatureSlot {
                field: f as u16,
                bucket: rng.below(32),
                value: 0.3 + rng.next_f32(),
            };
            let ctx: Vec<FeatureSlot> =
                (0..ctx_len).map(|f| slot(&mut rng, f)).collect();
            let cw = fields - ctx_len;
            let batch = 6usize;
            let mut cand_flat = Vec::new();
            for _ in 0..batch {
                for f in ctx_len..fields {
                    cand_flat.push(slot(&mut rng, f));
                }
            }
            for (name, kern) in &impls {
                let mut full = vec![0f32; batch * np];
                kern(
                    &pool.weights, &layout, fields, k, ctx_len, &ctx, &cand_flat,
                    &mut full,
                );
                for b in 0..batch {
                    let mut one = vec![0f32; np];
                    kern(
                        &pool.weights,
                        &layout,
                        fields,
                        k,
                        ctx_len,
                        &ctx,
                        &cand_flat[b * cw..(b + 1) * cw],
                        &mut one,
                    );
                    for i in 0..fields {
                        for j in (i + 1).max(ctx_len)..fields {
                            let pi = i * (2 * fields - i - 1) / 2 + (j - i - 1);
                            assert_eq!(
                                one[pi],
                                full[b * np + pi],
                                "{name} k={k} b={b} pair ({i},{j})"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn const_k_specialization_is_bit_identical() {
        // The specialized bodies run the same FP operation sequence as
        // the runtime-k ones — dispatch on k must never change a result
        // bit.  Serialized against rung forcing so the dispatched rung
        // cannot flip between the paired calls.
        let _serial = crate::simd::forcing_test_lock();
        for k in [4usize, 8, 16] {
            let fields = 6;
            let (cfg, layout, pool, ex) = setup(fields, k);
            let np = cfg.pairs();
            let mut spec = vec![0f32; np];
            let mut run = vec![0f32; np];
            let t1 = forward(&pool.weights, &layout, fields, k, &ex, &mut spec);
            let t2 =
                forward_runtime_k(&pool.weights, &layout, fields, k, &ex, &mut run);
            assert_eq!(t1.to_bits(), t2.to_bits(), "k={k} total");
            for (p, (a, b)) in spec.iter().zip(&run).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "k={k} pair {p}");
            }
            // batched serving kernel, spec vs runtime-k dispatch
            let ctx_len = 2;
            let mut rng = Pcg32::seeded(300 + k as u64);
            let slot = |rng: &mut Pcg32, f: usize| FeatureSlot {
                field: f as u16,
                bucket: rng.below(32),
                value: 0.3 + rng.next_f32(),
            };
            let ctx: Vec<FeatureSlot> =
                (0..ctx_len).map(|f| slot(&mut rng, f)).collect();
            let batch = 5usize;
            let mut cand = Vec::new();
            for _ in 0..batch {
                for f in ctx_len..fields {
                    cand.push(slot(&mut rng, f));
                }
            }
            let mut ps = vec![0f32; batch * np];
            let mut pr = vec![0f32; batch * np];
            forward_partial_batch(
                &pool.weights, &layout, fields, k, ctx_len, &ctx, &cand, &mut ps,
            );
            forward_partial_batch_runtime_k(
                &pool.weights, &layout, fields, k, ctx_len, &ctx, &cand, &mut pr,
            );
            for (p, (a, b)) in ps.iter().zip(&pr).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "k={k} batched pair {p}");
            }
        }
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let (cfg, layout, mut pool, ex) = setup(4, 2);
        let f = |w: &[f32]| -> f32 {
            let mut pairs = vec![0f32; cfg.pairs()];
            // loss = weighted sum of pairs with fixed coefficients
            forward(w, &layout, 4, 2, &ex, &mut pairs);
            pairs
                .iter()
                .enumerate()
                .map(|(p, v)| (p as f32 * 0.3 - 0.7) * v)
                .sum()
        };
        let dpairs: Vec<f32> =
            (0..cfg.pairs()).map(|p| p as f32 * 0.3 - 0.7).collect();
        let mut rec = GradRecorder::default();
        let mut acc = pool.acc.clone();
        let w0 = pool.weights.clone();
        backward(&mut pool.weights, &mut acc, &layout, 4, 2, &ex, &dpairs, &mut rec);
        assert_eq!(pool.weights, w0, "recorder must not mutate");
        let analytic = rec.dense(layout.total);
        let eps = 1e-3;
        let mut checked = 0;
        for idx in layout.ffm_off..layout.total {
            if analytic[idx] == 0.0 {
                continue;
            }
            let mut wp = w0.clone();
            wp[idx] += eps;
            let mut wm = w0.clone();
            wm[idx] -= eps;
            let numeric = (f(&wp) - f(&wm)) / (2.0 * eps);
            assert!(
                (numeric - analytic[idx]).abs() < 2e-2 * (1.0 + numeric.abs()),
                "idx={idx} numeric={numeric} analytic={}",
                analytic[idx]
            );
            checked += 1;
        }
        assert!(checked >= 8, "checked only {checked} coords");
    }

    #[test]
    fn shared_bucket_pair_gradients_accumulate() {
        // Two fields hashed to the SAME bucket: gradients touch the
        // same latent row twice and must both apply.
        let cfg = ModelConfig::ffm(2, 2, 8);
        let layout = Layout::new(&cfg);
        let mut pool = WeightPool::init(&cfg, &layout);
        for (i, w) in pool.weights[layout.ffm_off..].iter_mut().enumerate() {
            *w = 0.1 * (i as f32 + 1.0);
        }
        let ex = Example {
            label: 1.0,
            importance: 1.0,
            slots: vec![
                FeatureSlot { field: 0, bucket: 3, value: 1.0 },
                FeatureSlot { field: 1, bucket: 3, value: 1.0 },
            ],
        };
        let mut rec = GradRecorder::default();
        let mut acc = pool.acc.clone();
        backward(&mut pool.weights, &mut acc, &layout, 2, 2, &ex, &[1.0], &mut rec);
        assert_eq!(rec.grads.len(), 4); // 2 sides * k=2
    }
}
