//! `fw audit` — the repo's correctness-invariant linter.
//!
//! The unsafe SIMD kernels and the lock-free serving/fleet planes rely
//! on conventions a compiler cannot check: every `unsafe` site carries
//! a SAFETY contract, every atomic access documents *why* its memory
//! ordering suffices, the hot serving paths never panic through
//! `.unwrap()`, public APIs return typed errors, and every benchmark
//! records the machine context it ran on.  This module turns those
//! conventions into a zero-dependency static-analysis pass that runs in
//! CI (and fails the build) — the same philosophy as the paper's §6
//! "mini-benchmark with every release": regressions are cheapest the
//! moment they appear.
//!
//! The pass is self-hosting: the repo's own test suite runs the auditor
//! over the repo itself ([`run`] from `CARGO_MANIFEST_DIR/..`) and
//! asserts zero findings, so a PR that introduces an undocumented
//! `unsafe` block fails `cargo test` before it ever reaches CI.

mod scanner;

pub use scanner::{scan_bench_env, scan_source};

use std::fmt;
use std::path::{Path, PathBuf};

use crate::util::json::{arr, num, obj, s, Json};

/// Directories scanned by the source rules, relative to the repo root.
pub const SCAN_DIRS: [&str; 3] = ["rust/src", "rust/tests", "benches"];

/// The enforced invariants.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    /// Every line containing the keyword `unsafe` carries a `SAFETY`
    /// (or `/// # Safety`) marker on the line or in the contiguous
    /// comment/attribute block above it.
    SafetyComment,
    /// Every `Ordering::` use outside `#[cfg(test)]` carries an
    /// `ordering:` rationale comment (one block may cover a run of
    /// consecutive atomic accesses).
    OrderingRationale,
    /// No `.unwrap()` / `.expect(` in non-test code under the serving,
    /// fleet, deploy and SIMD planes or the Hogwild loop.
    HotPathUnwrap,
    /// No `pub fn ... -> Result<_, String>` — public APIs return typed
    /// errors.
    StringError,
    /// Every bench emits through `util/bench_env.rs`.
    BenchEnv,
}

impl Rule {
    pub const ALL: [Rule; 5] = [
        Rule::SafetyComment,
        Rule::OrderingRationale,
        Rule::HotPathUnwrap,
        Rule::StringError,
        Rule::BenchEnv,
    ];

    /// Stable machine-readable name (used in JSON output and the
    /// allowlist format).
    pub fn name(self) -> &'static str {
        match self {
            Rule::SafetyComment => "safety-comment",
            Rule::OrderingRationale => "ordering-rationale",
            Rule::HotPathUnwrap => "hot-path-unwrap",
            Rule::StringError => "string-error",
            Rule::BenchEnv => "bench-env",
        }
    }

    pub fn from_name(name: &str) -> Option<Rule> {
        Rule::ALL.into_iter().find(|r| r.name() == name)
    }

    /// One-line fix hint shown with human-format findings.
    pub fn hint(self) -> &'static str {
        match self {
            Rule::SafetyComment => {
                "document the invariant: `// SAFETY: ...` above the site \
                 (or `/// # Safety` on an unsafe fn)"
            }
            Rule::OrderingRationale => {
                "justify the ordering: `// ordering: ...` above the access"
            }
            Rule::HotPathUnwrap => {
                "recover (`unwrap_or_else`), propagate (`?`), or degrade \
                 gracefully — hot paths must not panic via unwrap/expect"
            }
            Rule::StringError => "return a typed error enum instead of String",
            Rule::BenchEnv => "emit results through util/bench_env.rs",
        }
    }
}

impl fmt::Display for Rule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// One rule violation at one site.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Finding {
    pub rule: Rule,
    /// Repo-root-relative path, `/`-separated.
    pub path: String,
    /// 1-based line number.
    pub line: usize,
    /// Trimmed source line (truncated to 90 chars).
    pub snippet: String,
}

/// Why an audit run could not complete.
#[derive(Debug)]
pub enum AuditError {
    /// None of the [`SCAN_DIRS`] exist under the given root — almost
    /// certainly a wrong `--root`.
    NotARepo(PathBuf),
    /// A file or directory could not be read.
    Io { path: PathBuf, source: std::io::Error },
}

impl fmt::Display for AuditError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AuditError::NotARepo(p) => {
                write!(f, "no rust/src, rust/tests or benches under {}", p.display())
            }
            AuditError::Io { path, source } => {
                write!(f, "cannot read {}: {source}", path.display())
            }
        }
    }
}

impl std::error::Error for AuditError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            AuditError::Io { source, .. } => Some(source),
            AuditError::NotARepo(_) => None,
        }
    }
}

/// One suppression: `<rule> <path>[:line]` — see [`Allowlist::parse`].
#[derive(Clone, Debug, PartialEq, Eq)]
struct AllowEntry {
    rule: Rule,
    path: String,
    line: Option<usize>,
}

/// Parsed suppression file.  Findings matching an entry are counted but
/// not reported (and don't fail the audit).
#[derive(Clone, Debug, Default)]
pub struct Allowlist {
    entries: Vec<AllowEntry>,
}

/// A malformed allowlist line (the audit fails rather than silently
/// suppressing nothing).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct AllowlistError {
    pub line: usize,
    pub text: String,
}

impl fmt::Display for AllowlistError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "allowlist line {}: cannot parse '{}'", self.line, self.text)
    }
}

impl std::error::Error for AllowlistError {}

impl Allowlist {
    /// Parse the plain-text format: one `<rule> <path>[:line]` entry
    /// per line; blank lines and `#` comments ignored.  A missing
    /// `:line` suppresses the rule for the whole file.
    pub fn parse(text: &str) -> Result<Allowlist, AllowlistError> {
        let mut entries = Vec::new();
        for (idx, raw) in text.lines().enumerate() {
            let t = raw.trim();
            if t.is_empty() || t.starts_with('#') {
                continue;
            }
            let err = || AllowlistError { line: idx + 1, text: t.to_string() };
            let (rule_name, rest) = t.split_once(char::is_whitespace).ok_or_else(err)?;
            let rule = Rule::from_name(rule_name).ok_or_else(err)?;
            let target = rest.trim();
            let (path, line) = match target.rsplit_once(':') {
                Some((p, l)) if l.chars().all(|c| c.is_ascii_digit()) && !l.is_empty() => {
                    (p, Some(l.parse::<usize>().map_err(|_| err())?))
                }
                _ => (target, None),
            };
            entries.push(AllowEntry { rule, path: path.to_string(), line });
        }
        Ok(Allowlist { entries })
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    fn matches(&self, f: &Finding) -> bool {
        self.entries.iter().any(|e| {
            e.rule == f.rule && e.path == f.path && e.line.is_none_or(|l| l == f.line)
        })
    }
}

/// Outcome of one audit pass.
#[derive(Clone, Debug, Default)]
pub struct AuditReport {
    /// Violations, ordered by rule then path then line.
    pub findings: Vec<Finding>,
    /// `.rs` files scanned.
    pub files_scanned: usize,
    /// Findings suppressed by the allowlist.
    pub suppressed: usize,
}

impl AuditReport {
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// Human-readable report (what `fw audit` prints by default).
    pub fn render_human(&self) -> String {
        let mut out = String::new();
        let mut last_rule = None;
        for f in &self.findings {
            if last_rule != Some(f.rule) {
                out.push_str(&format!("[{}] {}\n", f.rule.name(), f.rule.hint()));
                last_rule = Some(f.rule);
            }
            out.push_str(&format!("  {}:{}: {}\n", f.path, f.line, f.snippet));
        }
        out.push_str(&format!(
            "audit: {} finding(s) across {} file(s) ({} suppressed)\n",
            self.findings.len(),
            self.files_scanned,
            self.suppressed
        ));
        out
    }

    /// Machine-readable report (`fw audit --json`).
    pub fn to_json(&self) -> Json {
        let findings = self
            .findings
            .iter()
            .map(|f| {
                obj(vec![
                    ("rule", s(f.rule.name())),
                    ("path", s(&f.path)),
                    ("line", num(f.line as f64)),
                    ("snippet", s(&f.snippet)),
                ])
            })
            .collect();
        obj(vec![
            ("findings", arr(findings)),
            ("files_scanned", num(self.files_scanned as f64)),
            ("suppressed", num(self.suppressed as f64)),
            ("clean", num(if self.clean() { 1.0 } else { 0.0 })),
        ])
    }
}

fn read_to_string(path: &Path) -> Result<String, AuditError> {
    std::fs::read_to_string(path)
        .map_err(|source| AuditError::Io { path: path.to_path_buf(), source })
}

/// Collect every `.rs` file under `dir`, sorted for deterministic
/// output across filesystems.
fn rs_files(dir: &Path) -> Result<Vec<PathBuf>, AuditError> {
    let mut out = Vec::new();
    let mut stack = vec![dir.to_path_buf()];
    while let Some(d) = stack.pop() {
        let entries = std::fs::read_dir(&d)
            .map_err(|source| AuditError::Io { path: d.clone(), source })?;
        for entry in entries {
            let entry =
                entry.map_err(|source| AuditError::Io { path: d.clone(), source })?;
            let p = entry.path();
            if p.is_dir() {
                stack.push(p);
            } else if p.extension().is_some_and(|e| e == "rs") {
                out.push(p);
            }
        }
    }
    out.sort();
    Ok(out)
}

/// Repo-root-relative `/`-separated path for scanner labeling.
fn rel_label(root: &Path, p: &Path) -> String {
    let rel = p.strip_prefix(root).unwrap_or(p);
    rel.components()
        .map(|c| c.as_os_str().to_string_lossy())
        .collect::<Vec<_>>()
        .join("/")
}

/// Run every rule over the repo at `root`, suppressing allowlisted
/// findings.
pub fn run(root: &Path, allow: &Allowlist) -> Result<AuditReport, AuditError> {
    let scan_roots: Vec<PathBuf> = SCAN_DIRS
        .iter()
        .map(|d| root.join(d))
        .filter(|p| p.is_dir())
        .collect();
    if scan_roots.is_empty() {
        return Err(AuditError::NotARepo(root.to_path_buf()));
    }

    let mut report = AuditReport::default();
    let mut all = Vec::new();
    for dir in &scan_roots {
        for file in rs_files(dir)? {
            let text = read_to_string(&file)?;
            let rel = rel_label(root, &file);
            all.extend(scan_source(&rel, &text));
            if rel.starts_with("benches/") {
                all.extend(scan_bench_env(&rel, &text));
            }
            report.files_scanned += 1;
        }
    }
    all.sort_by(|a, b| {
        (a.rule, &a.path, a.line).cmp(&(b.rule, &b.path, b.line))
    });
    for f in all {
        if allow.matches(&f) {
            report.suppressed += 1;
        } else {
            report.findings.push(f);
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    // ---- per-rule fixtures -----------------------------------------

    #[test]
    fn detects_undocumented_unsafe() {
        let src = "fn f(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n";
        let got = scan_source("rust/src/x.rs", src);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].rule, Rule::SafetyComment);
        assert_eq!(got[0].line, 2);
    }

    #[test]
    fn safety_comment_block_satisfies_rule() {
        let src = "fn f(p: *const u8) -> u8 {\n    // SAFETY: caller keeps p valid.\n    unsafe { *p }\n}\n";
        assert!(scan_source("rust/src/x.rs", src).is_empty());
        // `/// # Safety` doc sections satisfy it too, through rustdoc
        // attributes and further doc lines
        let src = "/// Does things.\n///\n/// # Safety\n/// p must be valid.\npub unsafe fn g(p: *const u8) {}\n";
        assert!(scan_source("rust/src/x.rs", src).is_empty());
    }

    #[test]
    fn safety_marker_in_string_does_not_mask_site() {
        // the keyword inside a string literal is stripped before the
        // rule fires, so a log line mentioning unsafe is not a site
        let src = "fn f() {\n    let m = \"unsafe { }\";\n}\n";
        assert!(scan_source("rust/src/x.rs", src).is_empty());
    }

    #[test]
    fn detects_unjustified_ordering() {
        let src = "use std::sync::atomic::*;\nfn f(a: &AtomicU64) {\n    a.load(Ordering::Acquire);\n}\n";
        let got = scan_source("rust/src/x.rs", src);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].rule, Rule::OrderingRationale);
    }

    #[test]
    fn one_ordering_comment_covers_a_run() {
        let src = "use std::sync::atomic::*;\nfn f(a: &AtomicU64) {\n    // ordering: Relaxed — independent counters.\n    a.fetch_add(1, Ordering::Relaxed);\n    a.fetch_add(2, Ordering::Relaxed);\n}\n";
        assert!(scan_source("rust/src/x.rs", src).is_empty());
    }

    #[test]
    fn ordering_rule_skips_test_modules() {
        let src = "#[cfg(test)]\nmod tests {\n    use std::sync::atomic::*;\n    fn f(a: &AtomicU64) {\n        a.load(Ordering::Relaxed);\n    }\n}\n";
        assert!(scan_source("rust/src/x.rs", src).is_empty());
    }

    #[test]
    fn detects_hot_path_unwrap_only_in_hot_paths() {
        let src = "fn f(x: Option<u8>) -> u8 {\n    x.unwrap()\n}\n";
        let hot = scan_source("rust/src/serve/x.rs", src);
        assert_eq!(hot.len(), 1);
        assert_eq!(hot[0].rule, Rule::HotPathUnwrap);
        assert!(scan_source("rust/src/eval/x.rs", src).is_empty());
        // test code inside a hot-path file is exempt
        let test_src = "#[cfg(test)]\nmod tests {\n    fn f(x: Option<u8>) -> u8 {\n        x.unwrap()\n    }\n}\n";
        assert!(scan_source("rust/src/serve/x.rs", test_src).is_empty());
    }

    #[test]
    fn detects_string_error_in_pub_signature() {
        let src = "pub fn f() -> Result<u32, String> {\n    Ok(1)\n}\n";
        let got = scan_source("rust/src/x.rs", src);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].rule, Rule::StringError);
        assert_eq!(got[0].line, 1);
        // multi-line signatures are accumulated
        let src = "pub fn f(\n    x: u32,\n) -> Result<u32, String> {\n    Ok(x)\n}\n";
        assert_eq!(scan_source("rust/src/x.rs", src).len(), 1);
        // private fns and typed errors pass
        assert!(scan_source("rust/src/x.rs", "fn f() -> Result<u32, String> { Ok(1) }\n").is_empty());
        assert!(scan_source("rust/src/x.rs", "pub fn f() -> Result<u32, AuditError> { Ok(1) }\n").is_empty());
    }

    #[test]
    fn detects_bench_without_bench_env() {
        assert!(scan_bench_env("benches/b.rs", "fn main() {}").is_some());
        assert!(scan_bench_env("benches/b.rs", "use fwumious::util::bench_env;").is_none());
    }

    // ---- allowlist --------------------------------------------------

    #[test]
    fn allowlist_grammar_and_matching() {
        let text = "# comment\n\nsafety-comment rust/src/x.rs:7\nhot-path-unwrap rust/src/serve/y.rs\n";
        let allow = Allowlist::parse(text).expect("valid allowlist");
        assert_eq!(allow.len(), 2);
        let f = |rule, path: &str, line| Finding {
            rule,
            path: path.to_string(),
            line,
            snippet: String::new(),
        };
        assert!(allow.matches(&f(Rule::SafetyComment, "rust/src/x.rs", 7)));
        assert!(!allow.matches(&f(Rule::SafetyComment, "rust/src/x.rs", 8)));
        // file-wide entry matches any line
        assert!(allow.matches(&f(Rule::HotPathUnwrap, "rust/src/serve/y.rs", 31)));
        assert!(!allow.matches(&f(Rule::OrderingRationale, "rust/src/serve/y.rs", 31)));
    }

    #[test]
    fn allowlist_rejects_unknown_rules() {
        let err = Allowlist::parse("no-such-rule rust/src/x.rs\n").unwrap_err();
        assert_eq!(err.line, 1);
    }

    // ---- report rendering -------------------------------------------

    #[test]
    fn report_renders_human_and_json() {
        let report = AuditReport {
            findings: vec![Finding {
                rule: Rule::SafetyComment,
                path: "rust/src/x.rs".to_string(),
                line: 2,
                snippet: "unsafe { *p }".to_string(),
            }],
            files_scanned: 3,
            suppressed: 1,
        };
        let human = report.render_human();
        assert!(human.contains("[safety-comment]"));
        assert!(human.contains("rust/src/x.rs:2"));
        assert!(human.contains("1 finding(s) across 3 file(s) (1 suppressed)"));
        let j = report.to_json();
        assert_eq!(j.get("files_scanned").as_usize(), Some(3));
        assert_eq!(j.get("findings").at(0).get("rule").as_str(), Some("safety-comment"));
        assert_eq!(j.get("clean").as_f64(), Some(0.0));
        // round-trips through the hermetic JSON parser
        let parsed = crate::util::json::parse(&j.to_string()).expect("valid json");
        assert_eq!(parsed.get("suppressed").as_usize(), Some(1));
    }

    #[test]
    fn rule_names_round_trip() {
        for r in Rule::ALL {
            assert_eq!(Rule::from_name(r.name()), Some(r));
        }
        assert_eq!(Rule::from_name("nope"), None);
    }

    // ---- the self-audit: the repo passes its own linter --------------

    #[test]
    fn repo_passes_its_own_audit() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .expect("rust/ has a parent")
            .to_path_buf();
        let allow_path = root.join("audit-allow.txt");
        let allow = match std::fs::read_to_string(&allow_path) {
            Ok(text) => Allowlist::parse(&text).expect("allowlist parses"),
            Err(_) => Allowlist::default(),
        };
        let report = run(&root, &allow).expect("audit runs");
        assert!(report.files_scanned > 50, "scanned {}", report.files_scanned);
        assert!(
            report.clean(),
            "repo fails its own audit:\n{}",
            report.render_human()
        );
    }

    #[test]
    fn run_rejects_non_repo_roots() {
        let dir = std::env::temp_dir().join("fw-audit-not-a-repo");
        let _ = std::fs::create_dir_all(&dir);
        assert!(matches!(
            run(&dir, &Allowlist::default()),
            Err(AuditError::NotARepo(_))
        ));
    }
}
