//! Mini property-testing framework (proptest is unavailable offline).
//!
//! A property runs against many generated cases; on failure the seed
//! is reported so the case can be replayed deterministically:
//!
//! ```no_run
//! # // no_run: doctest binaries don't inherit the xla rpath rustflags
//! use fwumious::testutil::{prop, Gen};
//! prop(100, |g: &mut Gen| {
//!     let xs = g.vec_f32(0..64, -10.0, 10.0);
//!     let sum: f32 = xs.iter().sum();
//!     assert!(sum.is_finite());
//! });
//! ```

use crate::util::rng::Pcg32;

/// Case generator handed to each property invocation.
pub struct Gen {
    rng: Pcg32,
    pub case: usize,
    pub seed: u64,
}

impl std::fmt::Debug for Gen {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Gen").finish_non_exhaustive()
    }
}

impl Gen {
    pub fn rng(&mut self) -> &mut Pcg32 {
        &mut self.rng
    }

    pub fn usize_in(&mut self, range: std::ops::Range<usize>) -> usize {
        if range.is_empty() {
            return range.start;
        }
        range.start + self.rng.below((range.end - range.start) as u32) as usize
    }

    pub fn u32(&mut self) -> u32 {
        self.rng.next_u32()
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range_f32(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.coin(0.5)
    }

    /// Random byte vector with length drawn from `len`.
    pub fn bytes(&mut self, len: std::ops::Range<usize>) -> Vec<u8> {
        let n = self.usize_in(len);
        (0..n).map(|_| (self.rng.next_u32() & 0xff) as u8).collect()
    }

    /// Random f32 vector with length drawn from `len`.
    pub fn vec_f32(&mut self, len: std::ops::Range<usize>, lo: f32, hi: f32) -> Vec<f32> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.f32_in(lo, hi)).collect()
    }

    /// Random normal-distributed f32 vector.
    pub fn vec_normal(&mut self, len: std::ops::Range<usize>, scale: f32) -> Vec<f32> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.rng.normal() * scale).collect()
    }
}

/// Run `f` against `cases` generated cases.  Panics (with the failing
/// seed) on the first failure.  Set `FW_PROP_SEED` to replay one case.
///
/// Under Miri every property shrinks to a handful of cases: the
/// interpreter is ~3 orders of magnitude slower than native, and UB
/// detection needs code-path coverage, not statistical case counts.
pub fn prop(cases: usize, mut f: impl FnMut(&mut Gen)) {
    if let Ok(seed_str) = std::env::var("FW_PROP_SEED") {
        let seed: u64 = seed_str.parse().expect("FW_PROP_SEED must be u64");
        let mut g = Gen { rng: Pcg32::seeded(seed), case: 0, seed };
        f(&mut g);
        return;
    }
    let cases = if cfg!(miri) { cases.min(3) } else { cases };
    for case in 0..cases {
        let seed = 0x5eed_0000 + case as u64;
        let mut g = Gen { rng: Pcg32::seeded(seed), case, seed };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut g)
        }));
        if let Err(e) = result {
            eprintln!(
                "property failed at case {case} — replay with FW_PROP_SEED={seed}"
            );
            std::panic::resume_unwind(e);
        }
    }
}

/// Where a Prometheus exposition failed validation.  `line` is
/// 1-indexed; 0 flags a whole-document failure (no samples at all).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ScrapeError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for ScrapeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.line == 0 {
            write!(f, "{}", self.msg)
        } else {
            write!(f, "line {}: {}", self.line, self.msg)
        }
    }
}

impl std::error::Error for ScrapeError {}

/// CLI shim: `fn main` paths print errors as strings.
impl From<ScrapeError> for String {
    fn from(e: ScrapeError) -> String {
        e.to_string()
    }
}

/// Validate Prometheus text exposition format (the subset
/// `ObsRegistry::render_prometheus` emits, which is also what real
/// scrapers require): well-formed `# HELP`/`# TYPE` lines, legal
/// metric names, numeric sample values, and every sample covered by a
/// preceding `# TYPE` declaration for its base family.
pub fn check_prometheus_text(text: &str) -> Result<(), ScrapeError> {
    fn fail(line: usize, msg: String) -> Result<(), ScrapeError> {
        Err(ScrapeError { line, msg })
    }
    fn valid_name(name: &str) -> bool {
        !name.is_empty()
            && name
                .chars()
                .next()
                .map(|c| c.is_ascii_alphabetic() || c == '_' || c == ':')
                .unwrap_or(false)
            && name
                .chars()
                .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
    }
    const KINDS: [&str; 5] = ["counter", "gauge", "histogram", "summary", "untyped"];
    let mut typed: std::collections::BTreeMap<String, String> = Default::default();
    let mut samples = 0usize;
    for (ln, line) in text.lines().enumerate() {
        let ln = ln + 1;
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.splitn(2, ' ');
            let name = it.next().unwrap_or("");
            let kind = it.next().unwrap_or("").trim();
            if !valid_name(name) {
                return fail(ln, format!("bad metric name in TYPE: '{name}'"));
            }
            if !KINDS.contains(&kind) {
                return fail(ln, format!("unknown metric type '{kind}'"));
            }
            if typed.insert(name.to_string(), kind.to_string()).is_some() {
                return fail(ln, format!("duplicate TYPE for '{name}'"));
            }
            continue;
        }
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let name = rest.split(' ').next().unwrap_or("");
            if !valid_name(name) {
                return fail(ln, format!("bad metric name in HELP: '{name}'"));
            }
            continue;
        }
        if line.starts_with('#') {
            continue; // arbitrary comments are legal
        }
        // sample line: name[{labels}] value
        let (name_part, value_part) = match line.rsplit_once(' ') {
            Some(p) => p,
            None => return fail(ln, format!("sample missing value: '{line}'")),
        };
        let name = name_part.split('{').next().unwrap_or("");
        if !valid_name(name) {
            return fail(ln, format!("bad sample metric name: '{name}'"));
        }
        if let Some(labels) = name_part.split_once('{').map(|(_, l)| l) {
            if !labels.ends_with('}') {
                return fail(ln, format!("unterminated label set: '{line}'"));
            }
        }
        let v = value_part.trim();
        if v.parse::<f64>().is_err() && !matches!(v, "NaN" | "+Inf" | "-Inf") {
            return fail(ln, format!("non-numeric sample value '{v}'"));
        }
        // summary quantile samples and _sum/_count suffixes belong to
        // their base family's TYPE declaration
        let family_typed = typed.contains_key(name)
            || name
                .strip_suffix("_sum")
                .map(|b| typed.get(b).map(String::as_str) == Some("summary"))
                .unwrap_or(false)
            || name
                .strip_suffix("_count")
                .map(|b| typed.get(b).map(String::as_str) == Some("summary"))
                .unwrap_or(false);
        if !family_typed {
            return fail(ln, format!("sample '{name}' has no TYPE declaration"));
        }
        samples += 1;
    }
    if samples == 0 {
        return fail(0, "no samples found".to_string());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prop_runs_all_cases() {
        let mut n = 0;
        prop(25, |_g| n += 1);
        assert_eq!(n, 25);
    }

    #[test]
    fn gen_ranges_respected() {
        prop(50, |g| {
            let x = g.usize_in(3..10);
            assert!((3..10).contains(&x));
            let f = g.f32_in(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
            let v = g.bytes(0..16);
            assert!(v.len() < 16);
        });
    }

    #[test]
    #[should_panic]
    fn failing_property_panics() {
        prop(10, |g| {
            assert!(g.case < 5, "deliberate failure");
        });
    }

    #[test]
    fn prometheus_checker_accepts_well_formed_text() {
        let text = "\
# HELP fw_req_total requests\n\
# TYPE fw_req_total counter\n\
fw_req_total 42\n\
# HELP fw_stage_ns stage latency\n\
# TYPE fw_stage_ns summary\n\
fw_stage_ns{quantile=\"0.5\"} 120.5\n\
fw_stage_ns{quantile=\"0.99\"} 980\n\
fw_stage_ns_sum 100000\n\
fw_stage_ns_count 42\n\
# TYPE fw_depth gauge\n\
fw_depth NaN\n";
        check_prometheus_text(text).expect("well-formed");
    }

    #[test]
    fn prometheus_checker_rejects_malformed_text() {
        // sample without a TYPE declaration
        assert!(check_prometheus_text("fw_orphan 1\n").is_err());
        // bad metric name
        assert!(check_prometheus_text("# TYPE 9bad counter\n9bad 1\n").is_err());
        // non-numeric value
        assert!(
            check_prometheus_text("# TYPE fw_x gauge\nfw_x notanumber\n").is_err()
        );
        // unknown kind
        assert!(check_prometheus_text("# TYPE fw_x widget\nfw_x 1\n").is_err());
        // duplicate TYPE
        assert!(check_prometheus_text(
            "# TYPE fw_x gauge\n# TYPE fw_x gauge\nfw_x 1\n"
        )
        .is_err());
        // empty exposition
        assert!(check_prometheus_text("").is_err());
    }
}
