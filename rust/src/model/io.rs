//! Binary model serialization — the `FWMODEL1` format.
//!
//! Design constraints from §6 of the paper:
//!
//! * **Consistent memory-level structure**: the same config always
//!   produces byte-identical layout, so two training rounds differ only
//!   in the bytes of weights that actually moved — the property the
//!   byte-level patcher exploits.
//! * **Optimizer state is optional**: inference files carry weights
//!   only ("the latter are not required for actual inference, which
//!   immediately reduces the required space by half").
//!
//! Layout (little-endian):
//!
//! ```text
//! magic    [8]  b"FWMODEL1"
//! version  u32
//! arch     u8   (0 linear / 1 ffm / 2 deepffm)
//! has_acc  u8
//! sparse   u8
//! _pad     u8
//! fields   u32
//! latent   u32
//! buckets  u32
//! n_hidden u32, hidden[i] u32 ...
//! lr, ffm_lr, nn_lr, power_t, l2, init_ffm   f32 each
//! seed     u64
//! n_weights u64
//! weights  [n_weights * 4] raw f32
//! acc      [n_weights * 4] raw f32            (if has_acc)
//! ```

use std::io::{self, Read, Write};

use crate::config::{Architecture, ModelConfig};
use crate::model::regressor::Regressor;
use crate::model::weights::{Layout, WeightPool};

pub const MAGIC: &[u8; 8] = b"FWMODEL1";
pub const VERSION: u32 = 1;

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> io::Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "truncated model file",
            ));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> io::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> io::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> io::Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u8(&mut self) -> io::Result<u8> {
        Ok(self.take(1)?[0])
    }
}

/// Serialize a model to bytes.  `include_optimizer` keeps AdaGrad state
/// (training checkpoints); inference deployments drop it.
pub fn to_bytes(reg: &Regressor, include_optimizer: bool) -> Vec<u8> {
    let cfg = &reg.cfg;
    let include_acc = include_optimizer && reg.pool.has_optimizer_state();
    let mut out = Vec::with_capacity(64 + reg.pool.weights.len() * 8);
    out.extend_from_slice(MAGIC);
    put_u32(&mut out, VERSION);
    out.push(match cfg.arch {
        Architecture::Linear => 0,
        Architecture::Ffm => 1,
        Architecture::DeepFfm => 2,
    });
    out.push(include_acc as u8);
    out.push(cfg.sparse_updates as u8);
    out.push(0);
    put_u32(&mut out, cfg.fields as u32);
    put_u32(&mut out, cfg.latent_dim as u32);
    put_u32(&mut out, cfg.buckets);
    put_u32(&mut out, cfg.hidden.len() as u32);
    for &h in &cfg.hidden {
        put_u32(&mut out, h as u32);
    }
    for v in [cfg.lr, cfg.ffm_lr, cfg.nn_lr, cfg.power_t, cfg.l2, cfg.init_ffm] {
        put_f32(&mut out, v);
    }
    out.extend_from_slice(&cfg.seed.to_le_bytes());
    out.extend_from_slice(&(reg.pool.weights.len() as u64).to_le_bytes());
    for &w in &reg.pool.weights {
        put_f32(&mut out, w);
    }
    if include_acc {
        for &a in &reg.pool.acc {
            put_f32(&mut out, a);
        }
    }
    out
}

/// Deserialize a model from bytes.
pub fn from_bytes(buf: &[u8]) -> io::Result<Regressor> {
    let mut r = Reader { buf, pos: 0 };
    if r.take(8)? != MAGIC {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "bad magic"));
    }
    let version = r.u32()?;
    if version != VERSION {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("unsupported version {version}"),
        ));
    }
    let arch = match r.u8()? {
        0 => Architecture::Linear,
        1 => Architecture::Ffm,
        2 => Architecture::DeepFfm,
        a => {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("bad arch byte {a}"),
            ))
        }
    };
    let has_acc = r.u8()? != 0;
    let sparse = r.u8()? != 0;
    let _pad = r.u8()?;
    let fields = r.u32()? as usize;
    let latent = r.u32()? as usize;
    let buckets = r.u32()?;
    let n_hidden = r.u32()? as usize;
    if n_hidden > 64 {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "too many layers"));
    }
    let mut hidden = Vec::with_capacity(n_hidden);
    for _ in 0..n_hidden {
        hidden.push(r.u32()? as usize);
    }
    let mut cfg = match arch {
        Architecture::Linear => ModelConfig::linear(fields, buckets),
        Architecture::Ffm => ModelConfig::ffm(fields, latent, buckets),
        Architecture::DeepFfm => ModelConfig::deep_ffm(fields, latent, buckets, &hidden),
    };
    cfg.lr = r.f32()?;
    cfg.ffm_lr = r.f32()?;
    cfg.nn_lr = r.f32()?;
    cfg.power_t = r.f32()?;
    cfg.l2 = r.f32()?;
    cfg.init_ffm = r.f32()?;
    cfg.seed = r.u64()?;
    cfg.sparse_updates = sparse;
    cfg.validate()
        .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e))?;
    let n = r.u64()? as usize;
    let layout = Layout::new(&cfg);
    if n != layout.total {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("weight count {n} != layout {}", layout.total),
        ));
    }
    let mut weights = Vec::with_capacity(n);
    let wbytes = r.take(n * 4)?;
    for c in wbytes.chunks_exact(4) {
        weights.push(f32::from_le_bytes(c.try_into().unwrap()));
    }
    let acc = if has_acc {
        let abytes = r.take(n * 4)?;
        abytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect()
    } else {
        Vec::new()
    };
    if r.pos != buf.len() {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "trailing bytes"));
    }
    Ok(Regressor::from_parts(cfg, WeightPool { weights, acc }))
}

/// Save to a file.
pub fn save(reg: &Regressor, path: &std::path::Path, include_optimizer: bool) -> io::Result<()> {
    let bytes = to_bytes(reg, include_optimizer);
    let mut f = std::fs::File::create(path)?;
    f.write_all(&bytes)
}

/// Load from a file.
pub fn load(path: &std::path::Path) -> io::Result<Regressor> {
    let mut buf = Vec::new();
    std::fs::File::open(path)?.read_to_end(&mut buf)?;
    from_bytes(&buf)
}

/// Byte offset where the weight payload starts (header size).  The
/// quantizer needs this to slice the payload out of a serialized model.
pub fn payload_offset(cfg: &ModelConfig) -> usize {
    8 + 4 + 4 + 4 * 4 + 4 * cfg.hidden.len() + 6 * 4 + 8 + 8
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::data::synthetic::{DatasetSpec, SyntheticStream};
    use crate::model::Workspace;

    fn trained(arch: u8) -> Regressor {
        let cfg = match arch {
            0 => ModelConfig::linear(4, 256),
            1 => ModelConfig::ffm(4, 2, 256),
            _ => ModelConfig::deep_ffm(4, 2, 256, &[8]),
        };
        let mut reg = Regressor::new(&cfg);
        let mut ws = Workspace::new();
        let mut s = SyntheticStream::with_buckets(DatasetSpec::tiny(), 9, 256);
        for _ in 0..500 {
            let ex = s.next_example();
            reg.learn(&ex, &mut ws);
        }
        reg
    }

    #[test]
    fn roundtrip_all_archs_with_optimizer() {
        for arch in 0..3u8 {
            let reg = trained(arch);
            let bytes = to_bytes(&reg, true);
            let back = from_bytes(&bytes).unwrap();
            assert_eq!(back.pool.weights, reg.pool.weights);
            assert_eq!(back.pool.acc, reg.pool.acc);
            assert_eq!(back.cfg.fields, reg.cfg.fields);
            assert_eq!(back.cfg.hidden, reg.cfg.hidden);
        }
    }

    #[test]
    fn inference_file_half_size() {
        let reg = trained(2);
        let full = to_bytes(&reg, true);
        let inf = to_bytes(&reg, false);
        // weights-only payload is half the weights+acc payload
        let header = payload_offset(&reg.cfg);
        assert_eq!(full.len() - header, 2 * (inf.len() - header));
        let back = from_bytes(&inf).unwrap();
        assert!(!back.pool.has_optimizer_state());
        assert_eq!(back.pool.weights, reg.pool.weights);
    }

    #[test]
    fn payload_offset_matches_format() {
        let reg = trained(2);
        let bytes = to_bytes(&reg, false);
        let off = payload_offset(&reg.cfg);
        // first weight must round-trip from the computed offset
        let w0 = f32::from_le_bytes(bytes[off..off + 4].try_into().unwrap());
        assert_eq!(w0, reg.pool.weights[0]);
    }

    #[test]
    fn same_config_same_byte_layout() {
        // §6 precondition: two training rounds of the same config have
        // byte-aligned files (same length, same header).
        let a = trained(2);
        let mut b = trained(2);
        // perturb one weight: files must differ in exactly 4 bytes
        let idx = b.layout.ffm_off + 10;
        b.pool.weights[idx] += 1.0;
        let ba = to_bytes(&a, false);
        let bb = to_bytes(&b, false);
        assert_eq!(ba.len(), bb.len());
        let diff: usize = ba.iter().zip(&bb).filter(|(x, y)| x != y).count();
        assert!(diff <= 4 && diff > 0, "diff bytes = {diff}");
    }

    #[test]
    fn corrupt_inputs_rejected() {
        let reg = trained(1);
        let bytes = to_bytes(&reg, true);
        assert!(from_bytes(&bytes[..bytes.len() - 1]).is_err()); // truncated
        let mut bad_magic = bytes.clone();
        bad_magic[0] = b'X';
        assert!(from_bytes(&bad_magic).is_err());
        let mut extra = bytes.clone();
        extra.push(0);
        assert!(from_bytes(&extra).is_err());
        let mut bad_arch = bytes.clone();
        bad_arch[12] = 9;
        assert!(from_bytes(&bad_arch).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("fw_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.fw");
        let reg = trained(2);
        save(&reg, &path, true).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.pool.weights, reg.pool.weights);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn loaded_model_predicts_identically() {
        let reg = trained(2);
        let back = from_bytes(&to_bytes(&reg, false)).unwrap();
        let mut s = SyntheticStream::with_buckets(DatasetSpec::tiny(), 10, 256);
        let mut w1 = Workspace::new();
        let mut w2 = Workspace::new();
        for _ in 0..50 {
            let ex = s.next_example();
            assert_eq!(reg.predict(&ex, &mut w1), back.predict(&ex, &mut w2));
        }
    }
}
