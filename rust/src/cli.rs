//! Minimal argument parsing for the `fw` launcher (clap is unavailable
//! in the offline build environment).
//!
//! Grammar: `fw <subcommand> [--flag value]... [--switch]... [positional]...`

use std::collections::BTreeMap;

/// Why the command line was rejected.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CliError {
    /// argv was empty.
    MissingSubcommand,
    /// The first token looked like a flag, not a subcommand.
    UnexpectedToken(String),
    /// A lone `--` separator (unsupported grammar).
    BareDoubleDash,
    /// A typed flag's value failed to parse (`want` names the type).
    BadFlag { name: String, want: &'static str, got: String },
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::MissingSubcommand => write!(f, "missing subcommand"),
            CliError::UnexpectedToken(s) => write!(f, "expected subcommand, got '{s}'"),
            CliError::BareDoubleDash => write!(f, "bare '--' not supported"),
            CliError::BadFlag { name, want, got } => {
                write!(f, "--{name} wants {want}, got '{got}'")
            }
        }
    }
}

impl std::error::Error for CliError {}

/// CLI shim: `fn main` paths print errors as strings.
impl From<CliError> for String {
    fn from(e: CliError) -> String {
        e.to_string()
    }
}

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    pub flags: BTreeMap<String, String>,
    pub switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args, CliError> {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        match it.next() {
            Some(s) if !s.starts_with('-') => args.subcommand = s,
            Some(s) => return Err(CliError::UnexpectedToken(s)),
            None => return Err(CliError::MissingSubcommand),
        }
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    return Err(CliError::BareDoubleDash);
                }
                if let Some((k, v)) = name.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else {
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            // peek() just proved a next token exists
                            let v = it.next().unwrap_or_default();
                            args.flags.insert(name.to_string(), v);
                        }
                        _ => args.switches.push(name.to_string()),
                    }
                }
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn flag_or(&self, name: &str, default: &str) -> String {
        self.flag(name).unwrap_or(default).to_string()
    }

    pub fn usize_flag(&self, name: &str, default: usize) -> Result<usize, CliError> {
        match self.flag(name) {
            Some(v) => v.parse().map_err(|_| CliError::BadFlag {
                name: name.to_string(),
                want: "an integer",
                got: v.to_string(),
            }),
            None => Ok(default),
        }
    }

    pub fn f64_flag(&self, name: &str, default: f64) -> Result<f64, CliError> {
        match self.flag(name) {
            Some(v) => v.parse().map_err(|_| CliError::BadFlag {
                name: name.to_string(),
                want: "a number",
                got: v.to_string(),
            }),
            None => Ok(default),
        }
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

/// Top-level usage text.
pub const USAGE: &str = "\
fwumious (fw) — CPU-based Deep FFMs at scale

USAGE:
    fw <subcommand> [options]

SUBCOMMANDS:
    train      single-pass online training on a synthetic stream
               --dataset criteo|avazu|kdd|tiny  --examples N
               --arch linear|ffm|deepffm  --threads N (hogwild)
               --prefetch N  --out model.fw
    serve      score a synthetic request trace through the serving engine
               --model model.fw  --requests N  --workers N
               --no-context-cache
               --force-isa scalar|avx2|avx512 (clamp the SIMD
               dispatch rung; down-only — a rung the CPU lacks falls
               back to the best available)  --no-simd (alias for
               --force-isa scalar)
               --max-group-candidates N (cross-request union-slate cap)
               --queue-depth N (bounded admission queue per worker)
               --shed-policy reject-new|drop-oldest (full-queue behavior)
               --slo-us N (per-request deadline; 0 disables the
               overload plane)  --degraded-max-candidates N (slate
               truncation cap while degraded)
               --metrics-every SECS (periodic Prometheus render; 0
               off)  --metrics-file PATH (render target; default
               stdout)  --trace-sample N (emit JSONL spans for 1-in-N
               requests)  --trace-file PATH (JSONL sink; default
               stderr; implies --trace-sample 100)
    deploy     run the online deployment plane: continuous Hogwild
               training rounds published through the transfer pipeline
               and hot-swapped into a live serving engine
               --mode raw|quant|patch|quantpatch  --rounds N
               --examples N (per round)  --threads N (hogwild)
               --workers N  --requests N (served per round)
               --dataset criteo|avazu|kdd|tiny  --bits N
    fleet      multi-DC weight distribution fabric: publish Hogwild
               rounds to N data centers x M replicas over simulated
               links, with star/tree route planning and delta-chain
               catch-up (replay vs full resync)
               --dcs N  --replicas N  --strategy star|tree|auto
               --mode raw|quant|patch|quantpatch  --rounds N
               --examples N (per round)  --threads N (hogwild)
               --loss P (per-shipment drop probability)
               --dataset criteo|avazu|kdd|tiny  --bits N
               --chaos (fault-injection soak with live traffic:
               replica crash+restart, fabric crash+checkpoint
               restore, DC partition, replica stall; prints its
               reproducing seed)  --seed N (replay a chaos run)
               --smoke (CI-sized chaos run)
    obs        unified observability snapshot: run deploy rounds with
               live traffic plus a fleet publish into one metrics
               registry and print the Prometheus render
               --rounds N  --examples N  --dataset ...  --out PATH
               --trace-sample N  --trace-file PATH
               --check-file PATH (validate a Prometheus text file
               written by `fw serve --metrics-file` and exit)
    automl     random hyperparameter search (Table 1 protocol)
               --configs N  --threads N  --dataset ...  --examples N
    quantize   quantize a model file        --in a.fw --out a.fwq
    patch      diff two model files         --old a.fw --new b.fw --out p.fwp
    apply      apply a patch (or a comma-separated delta chain, in
               order)                 --old a.fw --patch p1.fwp,p2.fwp --out c.fw
    pjrt       run an AOT artifact against golden vectors
               --artifacts DIR   (needs a build with --features pjrt)
    audit      static-analysis pass over rust/src, rust/tests, benches
               enforcing repo invariants (SAFETY comments on unsafe,
               ordering rationale on atomics, no hot-path unwraps, no
               Result<_, String> in pub signatures, bench_env in every
               bench)           --json  --root DIR (default: auto)
               --allowlist PATH (default: audit-allow.txt)
    bench      alias pointing at `cargo bench` harnesses
    help       this text
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn basic_parse() {
        // NOTE: a `--name` followed by a non-flag token binds as a
        // flag+value pair; bare switches go last (or use `--a --b`).
        let a = parse(&["train", "--examples", "1000", "pos1", "--fast"]);
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.flag("examples"), Some("1000"));
        assert!(a.has("fast"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn equals_form() {
        let a = parse(&["serve", "--workers=8"]);
        assert_eq!(a.usize_flag("workers", 1).unwrap(), 8);
    }

    #[test]
    fn trailing_switch() {
        let a = parse(&["serve", "--no-simd"]);
        assert!(a.has("no-simd"));
    }

    #[test]
    fn defaults_and_errors() {
        let a = parse(&["train"]);
        assert_eq!(a.usize_flag("examples", 5).unwrap(), 5);
        assert_eq!(a.flag_or("dataset", "tiny"), "tiny");
        let a = parse(&["train", "--examples", "NaNv"]);
        assert!(a.usize_flag("examples", 5).is_err());
        assert!(Args::parse(std::iter::empty()).is_err());
        assert!(Args::parse(vec!["--x".to_string()]).is_err());
    }

    #[test]
    fn flag_followed_by_flag_is_switch() {
        let a = parse(&["serve", "--no-simd", "--workers", "4"]);
        assert!(a.has("no-simd"));
        assert_eq!(a.flag("workers"), Some("4"));
    }

    #[test]
    fn force_isa_value_flag_parses() {
        // every accepted rung name maps to a level; bad names don't
        let a = parse(&["serve", "--force-isa", "avx512"]);
        assert_eq!(a.flag("force-isa"), Some("avx512"));
        for name in ["scalar", "avx2", "avx512"] {
            let a = parse(&["serve", "--force-isa", name]);
            assert!(
                crate::simd::IsaLevel::parse(a.flag("force-isa").unwrap()).is_some(),
                "{name}"
            );
        }
        let a = parse(&["serve", "--force-isa=sse9"]);
        assert!(crate::simd::IsaLevel::parse(a.flag("force-isa").unwrap()).is_none());
        // the historical alias still parses as a bare switch
        let a = parse(&["serve", "--no-simd", "--requests", "10"]);
        assert!(a.has("no-simd"));
    }
}
