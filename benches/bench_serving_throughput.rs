//! Headline claim — "more than 300m predictions per second" (fleet-
//! wide, CPU-only).
//!
//! Two measurements:
//!
//! 1. **Batched vs per-candidate scoring** (the request-level batching
//!    tentpole): the same request stream scored candidate-at-a-time
//!    through `predict_with_partial` and request-at-a-time through
//!    `predict_batch_with_partial`.  The batched path amortizes the
//!    prefetch pass, slot assembly and ctx×ctx cache copy across the
//!    fanout and streams MLP weight rows once per 4-candidate register
//!    block.
//! 2. **Engine throughput**: the full serving engine (router → batcher
//!    → context cache → batched SIMD forward) across worker counts,
//!    with latency p50/p99.
//!
//! Emits machine-readable `BENCH_serving_throughput.json` (candidates/
//! sec for both paths, the batched-vs-sequential speedup ratio, per-
//! worker-count engine throughput and latency percentiles) so future
//! PRs can diff regressions.  `--smoke` runs a CI-sized variant.

use fwumious::config::{ModelConfig, ServeConfig};
use fwumious::data::synthetic::{DatasetSpec, SyntheticStream};
use fwumious::model::regressor::Regressor;
use fwumious::model::Workspace;
use fwumious::serve::router::Router;
use fwumious::serve::server::ServingEngine;
use fwumious::serve::trace::TraceGenerator;
use fwumious::serve::{ModelHandle, Request};
use fwumious::util::json::{arr, num, obj, s, Json};

const CTX_FIELDS: usize = 6;
const FANOUT: usize = 16;

fn trained_model(smoke: bool) -> Regressor {
    let spec = DatasetSpec::criteo_like();
    let buckets = if smoke { 1u32 << 14 } else { 1u32 << 18 };
    let steps = if smoke { 3_000 } else { 50_000 };
    let cfg = ModelConfig::deep_ffm(spec.fields(), 8, buckets, &[32]);
    let mut reg = Regressor::new(&cfg);
    let mut ws = Workspace::new();
    let mut s = SyntheticStream::with_buckets(spec, 41, buckets);
    for _ in 0..steps {
        let ex = s.next_example();
        reg.learn(&ex, &mut ws);
    }
    reg
}

/// Candidate-at-a-time scoring (the pre-batching serving inner loop):
/// one cached partial per request, then one `predict_with_partial` call
/// per candidate.
fn run_sequential(reg: &Regressor, reqs: &[Request]) -> (f64, Vec<f32>) {
    let mut ws = Workspace::new();
    let mut scores = Vec::new();
    let t = std::time::Instant::now();
    for req in reqs {
        let cp = reg.context_partial(&req.context);
        for cand in &req.candidates {
            scores.push(reg.predict_with_partial(&cp, cand, &mut ws));
        }
    }
    (t.elapsed().as_secs_f64(), scores)
}

/// Request-at-a-time scoring through the batched path.
fn run_batched(reg: &Regressor, reqs: &[Request]) -> (f64, Vec<f32>) {
    let mut ws = Workspace::new();
    let mut scores = Vec::new();
    let mut out = Vec::new();
    let t = std::time::Instant::now();
    for req in reqs {
        let cp = reg.context_partial(&req.context);
        reg.predict_batch_with_partial(&cp, &req.candidates, &mut ws, &mut out);
        scores.extend_from_slice(&out);
    }
    (t.elapsed().as_secs_f64(), scores)
}

struct EngineRun {
    preds_per_sec: f64,
    hit_rate: f64,
    p50_us: f64,
    p99_us: f64,
}

fn run_engine(reg: &Regressor, workers: usize, requests: usize) -> EngineRun {
    let router = Router::new(workers);
    router.register("m", ModelHandle::new(reg.clone()));
    let engine = ServingEngine::start(
        router,
        ServeConfig {
            workers,
            max_batch: 256,
            max_wait_us: 200,
            context_cache_entries: 65_536,
        },
    );
    let fields = reg.cfg.fields;
    let mut gen = TraceGenerator::new(17, fields, CTX_FIELDS, reg.cfg.buckets, FANOUT);
    let reqs = gen.take(requests, "m");
    let t = std::time::Instant::now();
    let mut pending = Vec::with_capacity(1024);
    for (i, req) in reqs.into_iter().enumerate() {
        pending.push(engine.submit(req).expect("submit"));
        if pending.len() >= 1024 || i + 1 == requests {
            for rx in pending.drain(..) {
                rx.recv().unwrap().expect("score");
            }
        }
    }
    let secs = t.elapsed().as_secs_f64();
    let stats = engine.shutdown();
    assert_eq!(stats.errors, 0);
    let hist = stats.latency.as_ref().expect("latency histogram");
    EngineRun {
        preds_per_sec: stats.candidates as f64 / secs,
        hit_rate: stats.cache_hit_rate(),
        p50_us: hist.quantile_ns(0.5) / 1e3,
        p99_us: hist.quantile_ns(0.99) / 1e3,
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let direct_requests = if smoke { 300 } else { 2_000 };
    println!(
        "== Headline: candidate-scoring throughput (SIMD {}{}) ==\n",
        fwumious::simd::isa_name(),
        if smoke { ", smoke" } else { "" }
    );
    let reg = trained_model(smoke);
    println!(
        "model: DeepFFM {} fields ({} context), K={}, hidden {:?}, {:.0} MB weights, fanout {}",
        reg.cfg.fields,
        CTX_FIELDS,
        reg.cfg.latent_dim,
        reg.cfg.hidden,
        reg.num_weights() as f64 * 4.0 / 1e6,
        FANOUT
    );

    // -- batched vs per-candidate, single thread, identical requests
    let mut gen =
        TraceGenerator::new(29, reg.cfg.fields, CTX_FIELDS, reg.cfg.buckets, FANOUT);
    let reqs = gen.take(direct_requests, "m");
    // warm-up pass (page in the weight table, size the workspaces)
    let _ = run_batched(&reg, &reqs[..reqs.len().min(32)]);
    let _ = run_sequential(&reg, &reqs[..reqs.len().min(32)]);
    let (seq_secs, seq_scores) = run_sequential(&reg, &reqs);
    let (bat_secs, bat_scores) = run_batched(&reg, &reqs);
    assert_eq!(seq_scores.len(), bat_scores.len());
    for (i, (a, b)) in bat_scores.iter().zip(&seq_scores).enumerate() {
        assert!(
            (a - b).abs() < 1e-5,
            "candidate {i}: batched {a} vs sequential {b}"
        );
    }
    let n_cands = (direct_requests * FANOUT) as f64;
    let seq_cps = n_cands / seq_secs;
    let bat_cps = n_cands / bat_secs;
    let speedup = bat_cps / seq_cps;
    println!("\n-- single-thread scoring path (B = {FANOUT} candidates/request) --");
    println!("{:>16} {:>14}", "path", "cands/s");
    println!("{:>16} {:>14.0}", "per-candidate", seq_cps);
    println!("{:>16} {:>14.0}", "batched", bat_cps);
    println!("batched-vs-sequential speedup: {speedup:.2}x");

    // -- full engine across worker counts
    let max_workers = std::thread::available_parallelism()
        .map(|n| n.get().min(if smoke { 2 } else { 16 }))
        .unwrap_or(if smoke { 2 } else { 8 });
    println!(
        "\n{:>8} {:>14} {:>16} {:>8} {:>10} {:>10}",
        "workers", "preds/s", "preds/s/core", "hit%", "p50 us", "p99 us"
    );
    let mut per_core_best = 0f64;
    let mut engine_rows = Vec::new();
    let mut w = 1;
    while w <= max_workers {
        let requests = if smoke { 1_500 * w } else { 6_000 * w };
        let run = run_engine(&reg, w, requests);
        per_core_best = per_core_best.max(run.preds_per_sec / w as f64);
        println!(
            "{:>8} {:>14.0} {:>16.0} {:>7.1}% {:>10.1} {:>10.1}",
            w,
            run.preds_per_sec,
            run.preds_per_sec / w as f64,
            run.hit_rate * 100.0,
            run.p50_us,
            run.p99_us
        );
        engine_rows.push(obj(vec![
            ("workers", num(w as f64)),
            ("preds_per_sec", num(run.preds_per_sec)),
            ("preds_per_sec_per_core", num(run.preds_per_sec / w as f64)),
            ("cache_hit_rate", num(run.hit_rate)),
            ("latency_p50_us", num(run.p50_us)),
            ("latency_p99_us", num(run.p99_us)),
        ]));
        w *= 2;
    }

    let report = obj(vec![
        ("bench", s("serving_throughput")),
        ("smoke", Json::Bool(smoke)),
        ("simd", s(fwumious::simd::isa_name())),
        ("fields", num(reg.cfg.fields as f64)),
        ("context_fields", num(CTX_FIELDS as f64)),
        ("latent_dim", num(reg.cfg.latent_dim as f64)),
        ("fanout", num(FANOUT as f64)),
        ("sequential_cands_per_sec", num(seq_cps)),
        ("batched_cands_per_sec", num(bat_cps)),
        ("speedup_batched_vs_sequential", num(speedup)),
        ("engine", arr(engine_rows)),
        ("per_core_best_preds_per_sec", num(per_core_best)),
        ("cores_for_300m", num(300e6 / per_core_best)),
    ]);
    let path = "BENCH_serving_throughput.json";
    std::fs::write(path, report.to_string()).expect("write bench json");
    println!(
        "\n→ 300M preds/s needs ≈{:.0} cores at the measured per-core rate;",
        300e6 / per_core_best
    );
    println!("  the paper's multi-DC fleet (hundreds of servers × tens of cores) clears that.");
    println!("report -> {path}");
    // The documented guarantee (README / verify skill): batched beats
    // per-candidate by ≥ 1.5x at this fanout.  Only enforceable where
    // the SIMD kernels are live — on scalar-dispatch hosts both arms
    // run identical arithmetic and only call overhead is saved.
    // Asserted after the report write so a regression still leaves the
    // numbers on disk.
    if fwumious::simd::simd_active() {
        assert!(
            speedup >= 1.5,
            "batched path speedup {speedup:.2}x below the 1.5x floor \
             ({bat_cps:.0} vs {seq_cps:.0} cands/s)"
        );
    } else {
        println!("(scalar dispatch host: 1.5x floor not enforced)");
    }
}
