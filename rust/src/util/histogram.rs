//! Log-bucketed latency histogram for serving metrics (p50/p90/p99).
//!
//! Serving latencies span nanoseconds to milliseconds, so buckets grow
//! geometrically: bucket i covers [lo * g^i, lo * g^(i+1)).
//!
//! Two recorders share the geometry: [`LatencyHistogram`] for
//! single-owner accumulation (per-worker stats merged under a lock) and
//! [`AtomicHistogram`] for lock-free concurrent recording (the obs
//! registry's per-worker shards, merged only at snapshot time).

use std::sync::atomic::{AtomicU64, Ordering};

/// Number of geometric buckets.
pub const BUCKETS: usize = 128;
/// Lower edge of bucket 1 (values below land in bucket 0).
pub const LO_NS: f64 = 50.0;
/// Geometric growth factor per bucket (~14% bucket width).
pub const GROWTH: f64 = 1.14;

fn bucket_index(ns: u64) -> usize {
    if (ns as f64) < LO_NS {
        return 0;
    }
    let b = ((ns as f64 / LO_NS).ln() / GROWTH.ln()) as usize;
    b.min(BUCKETS - 1)
}

/// Fixed-size geometric histogram over nanosecond values.
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    counts: Vec<u64>,
    total: u64,
    sum_ns: u128,
    max_ns: u64,
    min_ns: u64,
    lo_ns: f64,
    growth: f64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    /// 128 buckets from 50 ns to ~1.7 s with ~14% resolution.
    pub fn new() -> Self {
        LatencyHistogram {
            counts: vec![0; BUCKETS],
            total: 0,
            sum_ns: 0,
            max_ns: 0,
            min_ns: u64::MAX,
            lo_ns: LO_NS,
            growth: GROWTH,
        }
    }

    fn bucket(&self, ns: u64) -> usize {
        bucket_index(ns)
    }

    /// Record one observation in nanoseconds.
    pub fn record_ns(&mut self, ns: u64) {
        let b = self.bucket(ns);
        self.counts[b] += 1;
        self.total += 1;
        self.sum_ns += ns as u128;
        self.max_ns = self.max_ns.max(ns);
        self.min_ns = self.min_ns.min(ns);
    }

    /// Record a `Duration`.
    pub fn record(&mut self, d: std::time::Duration) {
        self.record_ns(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean_ns(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum_ns as f64 / self.total as f64
        }
    }

    pub fn max_ns(&self) -> u64 {
        self.max_ns
    }

    /// Total of all recorded values in nanoseconds.
    pub fn sum_ns(&self) -> u128 {
        self.sum_ns
    }

    /// Smallest recorded value (0 when nothing has been recorded — the
    /// raw field's `u64::MAX` sentinel must never leak to callers).
    pub fn min_ns(&self) -> u64 {
        if self.total == 0 {
            0
        } else {
            self.min_ns
        }
    }

    /// Approximate quantile with within-bucket linear interpolation.
    ///
    /// The target rank's position inside its bucket is interpolated
    /// linearly between the bucket's lower and upper edge, so the error
    /// is bounded by how non-uniform the data is *within* one ~14%
    /// bucket rather than by the full bucket width.  The result is
    /// clamped to the observed `[min_ns, max_ns]` range — a bucket edge
    /// can overshoot the largest recorded value, and a printed p99
    /// above the printed max reads as corrupt metrics.
    pub fn quantile_ns(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64).max(1);
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if acc + c >= target {
                let lo = if i == 0 {
                    0.0
                } else {
                    self.lo_ns * self.growth.powi(i as i32)
                };
                let hi = self.lo_ns * self.growth.powi(i as i32 + 1);
                let frac = (target - acc) as f64 / c as f64;
                let v = lo + frac * (hi - lo);
                return v.clamp(self.min_ns() as f64, self.max_ns as f64);
            }
            acc += c;
        }
        self.max_ns as f64
    }

    /// Merge another histogram into this one (same geometry by
    /// construction).
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum_ns += other.sum_ns;
        self.max_ns = self.max_ns.max(other.max_ns);
        self.min_ns = self.min_ns.min(other.min_ns);
    }

    /// One-line human-readable summary.
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.1}us p50={:.1}us p90={:.1}us p99={:.1}us max={:.1}us",
            self.total,
            self.mean_ns() / 1e3,
            self.quantile_ns(0.50) / 1e3,
            self.quantile_ns(0.90) / 1e3,
            self.quantile_ns(0.99) / 1e3,
            self.max_ns as f64 / 1e3,
        )
    }
}

/// Lock-free histogram with the same geometry as [`LatencyHistogram`].
///
/// Recording is a handful of relaxed atomic adds — safe to call from
/// any number of threads without coordination. `snapshot()` folds the
/// shard into a plain [`LatencyHistogram`]; under concurrent recording
/// the snapshot is a consistent-enough live view (each field is read
/// atomically but the set of fields is not a single cut), and exact
/// once all recorders have quiesced.
#[derive(Debug)]
pub struct AtomicHistogram {
    counts: Vec<AtomicU64>,
    total: AtomicU64,
    sum_ns: AtomicU64,
    max_ns: AtomicU64,
    min_ns: AtomicU64,
}

impl Default for AtomicHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl AtomicHistogram {
    pub fn new() -> Self {
        AtomicHistogram {
            counts: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            total: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
            min_ns: AtomicU64::new(u64::MAX),
        }
    }

    /// Record one observation in nanoseconds. Never blocks.
    pub fn record_ns(&self, ns: u64) {
        let b = bucket_index(ns);
        // ordering: Relaxed throughout — each field is an independent
        // statistical counter; scrapers read via `snapshot`, which
        // tolerates a mid-record view (totals may momentarily disagree
        // by one observation, which quantile math absorbs).  No other
        // data is published through these atomics.
        self.counts[b].fetch_add(1, Ordering::Relaxed);
        self.total.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
        self.min_ns.fetch_min(ns, Ordering::Relaxed);
    }

    /// Record a `Duration`. Never blocks.
    pub fn record(&self, d: std::time::Duration) {
        self.record_ns(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    pub fn count(&self) -> u64 {
        // ordering: Relaxed — statistical read, see `record_ns`.
        self.total.load(Ordering::Relaxed)
    }

    /// Fold into a plain histogram for quantiles / merging / display.
    pub fn snapshot(&self) -> LatencyHistogram {
        // ordering: Relaxed — see `record_ns`: the snapshot is a
        // statistical view, not a synchronization point.
        let ld = |a: &AtomicU64| a.load(Ordering::Relaxed);
        let mut h = LatencyHistogram::new();
        for (dst, src) in h.counts.iter_mut().zip(&self.counts) {
            *dst = ld(src);
        }
        h.total = ld(&self.total);
        h.sum_ns = ld(&self.sum_ns) as u128;
        h.max_ns = ld(&self.max_ns);
        h.min_ns = ld(&self.min_ns);
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_zero() {
        let h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile_ns(0.5), 0.0);
    }

    #[test]
    fn quantiles_ordered() {
        let mut h = LatencyHistogram::new();
        for i in 1..=10_000u64 {
            h.record_ns(i * 100);
        }
        let p50 = h.quantile_ns(0.5);
        let p90 = h.quantile_ns(0.9);
        let p99 = h.quantile_ns(0.99);
        assert!(p50 <= p90 && p90 <= p99, "{p50} {p90} {p99}");
        // p50 of uniform 100ns..1ms should land near 500_000ns (±bucket).
        assert!((300_000.0..800_000.0).contains(&p50), "{p50}");
    }

    #[test]
    fn mean_exact() {
        let mut h = LatencyHistogram::new();
        h.record_ns(100);
        h.record_ns(300);
        assert_eq!(h.mean_ns(), 200.0);
    }

    #[test]
    fn merge_adds_counts() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        for _ in 0..50 {
            a.record_ns(1_000);
            b.record_ns(100_000);
        }
        a.merge(&b);
        assert_eq!(a.count(), 100);
        assert!(a.quantile_ns(0.9) > 50_000.0);
    }

    #[test]
    fn huge_values_clamp_to_last_bucket() {
        let mut h = LatencyHistogram::new();
        h.record_ns(u64::MAX / 2);
        assert_eq!(h.count(), 1);
        assert!(h.quantile_ns(1.0) > 0.0);
    }

    #[test]
    fn quantiles_never_exceed_observed_max() {
        // Regression: the containing bucket's upper edge used to leak
        // through, so summary() could print p99 > max in one line.
        let mut h = LatencyHistogram::new();
        h.record_ns(1_234);
        for q in [0.5, 0.9, 0.99, 1.0] {
            assert!(
                h.quantile_ns(q) <= 1_234.0,
                "q{q} = {} exceeds max",
                h.quantile_ns(q)
            );
        }
        h.record_ns(999_999);
        assert!(h.quantile_ns(0.99) <= h.max_ns() as f64);
    }

    #[test]
    fn min_tracked_and_empty_safe() {
        // Regression: the raw field initializes to u64::MAX; an empty
        // histogram must report 0, not the sentinel.
        let mut h = LatencyHistogram::new();
        assert_eq!(h.min_ns(), 0);
        h.record_ns(5_000);
        h.record_ns(70);
        h.record_ns(9_000);
        assert_eq!(h.min_ns(), 70);
        // min survives a merge, including with an empty histogram
        let mut other = LatencyHistogram::new();
        other.merge(&h);
        assert_eq!(other.min_ns(), 70);
        other.record_ns(10);
        let mut a = LatencyHistogram::new();
        a.record_ns(500);
        a.merge(&other);
        assert_eq!(a.min_ns(), 10);
    }

    #[test]
    fn single_value_quantiles_exact() {
        // With every observation equal, the min/max clamp pins every
        // quantile to that exact value — no bucket-edge overshoot.
        let mut h = LatencyHistogram::new();
        for _ in 0..5 {
            h.record_ns(1_234);
        }
        for q in [0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(h.quantile_ns(q), 1_234.0, "q{q}");
        }
    }

    #[test]
    fn interpolation_tighter_than_bucket_width() {
        // Uniform 100ns..1ms: true p50 = 500_050ns, true p90 = 900_010ns.
        // The bucket width at those magnitudes is ~14%; interpolation
        // must land strictly tighter (within 7%).
        let mut h = LatencyHistogram::new();
        for i in 1..=10_000u64 {
            h.record_ns(i * 100);
        }
        for (q, truth) in [(0.5, 500_050.0), (0.9, 900_010.0), (0.99, 990_001.0)] {
            let got = h.quantile_ns(q);
            let rel = (got - truth).abs() / truth;
            assert!(rel < 0.07, "q{q}: got {got}, truth {truth}, rel err {rel:.3}");
        }
    }

    #[test]
    fn quantile_monotone_in_q() {
        use crate::testutil::prop;
        prop(30, |g| {
            let mut h = LatencyHistogram::new();
            let n = g.usize_in(1..500);
            for _ in 0..n {
                h.record_ns(g.u64() % 10_000_000 + 1);
            }
            let mut prev = f64::NEG_INFINITY;
            for step in 0..=100 {
                let q = step as f64 / 100.0;
                let v = h.quantile_ns(q);
                assert!(
                    v >= prev,
                    "quantile not monotone: q{q} = {v} < previous {prev}"
                );
                prev = v;
            }
            assert!(h.quantile_ns(1.0) <= h.max_ns() as f64);
            assert!(h.quantile_ns(0.0) >= h.min_ns() as f64);
        });
    }

    #[test]
    fn atomic_matches_sequential() {
        let atomic = AtomicHistogram::new();
        let mut plain = LatencyHistogram::new();
        for i in 1..=2_000u64 {
            atomic.record_ns(i * 37);
            plain.record_ns(i * 37);
        }
        let snap = atomic.snapshot();
        assert_eq!(snap.count(), plain.count());
        assert_eq!(snap.min_ns(), plain.min_ns());
        assert_eq!(snap.max_ns(), plain.max_ns());
        assert_eq!(snap.mean_ns(), plain.mean_ns());
        for q in [0.5, 0.9, 0.99] {
            assert_eq!(snap.quantile_ns(q), plain.quantile_ns(q), "q{q}");
        }
    }

    #[test]
    fn atomic_concurrent_count_exact() {
        use std::sync::Arc;
        let h = Arc::new(AtomicHistogram::new());
        let threads = 4;
        let per = 10_000u64;
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let h = Arc::clone(&h);
                std::thread::spawn(move || {
                    for i in 0..per {
                        h.record_ns(t * 1_000 + i % 997 + 1);
                    }
                })
            })
            .collect();
        for j in handles {
            j.join().unwrap();
        }
        assert_eq!(h.count(), threads * per);
        assert_eq!(h.snapshot().count(), threads * per);
    }
}
