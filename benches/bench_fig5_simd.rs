//! Figure 5 — SIMD-enabled vs SIMD-disabled inference (§5), extended
//! across the full ISA ladder.
//!
//! Paper: "SIMD intrinsics resulted in a consistent 20% speedup for all
//! serving. Up to 25% faster inference."  The engine detects the best
//! rung (scalar → AVX2+FMA → AVX-512) at startup; `ForcedIsaGuard`
//! pins each arm to one rung, so every shape gets a per-rung row — the
//! production control/treatment pair generalized to a ladder.
//!
//! Three measurements:
//!
//! 1. **End-to-end forward per rung × latent dim**: full DeepFFM
//!    `predict` per available rung for K ∈ {4, 8, 16} shapes.
//! 2. **GEMM rung ratio**: the batched `matmul_rowmajor` kernel alone,
//!    per rung.  Where the host has AVX-512 this arm must clear 1.2x
//!    over AVX2 (the 4×32 zmm tile vs the 4×16 ymm tile); hosts
//!    without the rung skip the assert cleanly.
//! 3. **Const-k specialization**: the batched FFM pair kernel with the
//!    const-`K` body (`forward_partial_batch`) vs the same rung's
//!    runtime-`k` body (`forward_partial_batch_runtime_k`).  At k = 8
//!    on the fastest live vector rung the specialized path must clear
//!    1.15x (unrolled strip loops + register-hoisted context strip);
//!    scalar-only hosts skip the floor.

use fwumious::config::ModelConfig;
use fwumious::data::synthetic::{DatasetSpec, SyntheticStream};
use fwumious::feature::{Example, FeatureSlot};
use fwumious::model::regressor::Regressor;
use fwumious::model::weights::{Layout, WeightPool};
use fwumious::model::{block_ffm, Workspace};
use fwumious::simd::{self, ForcedIsaGuard, IsaLevel};
use fwumious::util::bench_env;
use fwumious::util::json::{arr, num, obj, s};
use fwumious::util::rng::Pcg32;
use fwumious::util::timer::median_time;

fn bench_forward(reg: &Regressor, data: &[Example], lvl: IsaLevel, reps: usize) -> f64 {
    // RAII forcing: restored (to unforced) when the arm ends, even on
    // a panicking measurement closure
    let _guard = ForcedIsaGuard::force(lvl);
    let mut ws = Workspace::new();
    median_time(1, reps, || {
        let mut acc = 0.0f32;
        for ex in data {
            acc += reg.predict(ex, &mut ws);
        }
        acc
    })
}

/// Seconds per `matmul_rowmajor` call on a GEMM-shaped workload under a
/// forced rung (the serving MLP's batched hidden-layer multiply).
fn bench_gemm(lvl: IsaLevel, batch: usize, rows: usize, cols: usize, reps: usize) -> f64 {
    let _guard = ForcedIsaGuard::force(lvl);
    let mut rng = Pcg32::seeded(5150);
    let x: Vec<f32> = (0..batch * rows).map(|_| rng.normal() * 0.5).collect();
    let w: Vec<f32> = (0..rows * cols).map(|_| rng.normal() * 0.5).collect();
    let bias: Vec<f32> = (0..cols).map(|_| rng.normal() * 0.5).collect();
    let mut out = vec![0f32; batch * cols];
    median_time(1, reps, || {
        fwumious::simd::batch::matmul_rowmajor(&x, batch, &w, rows, cols, Some(&bias), &mut out);
        out[0]
    })
}

/// FFM pair-kernel fixture for the const-k arm: a pure-FFM layout with
/// a context strip and a candidate slate, scored through the batched
/// partial kernel.
struct PairFixture {
    layout: Layout,
    pool: WeightPool,
    fields: usize,
    k: usize,
    ctx_len: usize,
    ctx: Vec<FeatureSlot>,
    cand: Vec<FeatureSlot>,
    pairs: Vec<f32>,
}

impl PairFixture {
    fn new(k: usize, batch: usize) -> PairFixture {
        let fields = 6usize;
        let ctx_len = 3usize;
        let cfg = ModelConfig::ffm(fields, k, 1 << 12);
        let layout = Layout::new(&cfg);
        let mut pool = WeightPool::init(&cfg, &layout);
        let mut rng = Pcg32::seeded(6000 + k as u64);
        for w in &mut pool.weights[layout.ffm_off..] {
            *w = rng.normal() * 0.3;
        }
        let slot = |rng: &mut Pcg32, f: usize| FeatureSlot {
            field: f as u16,
            bucket: rng.below(1 << 12),
            value: 0.3 + rng.next_f32(),
        };
        let ctx: Vec<FeatureSlot> = (0..ctx_len).map(|f| slot(&mut rng, f)).collect();
        let mut cand = Vec::new();
        for _ in 0..batch {
            for f in ctx_len..fields {
                cand.push(slot(&mut rng, f));
            }
        }
        let np = cfg.pairs();
        PairFixture {
            layout,
            pool,
            fields,
            k,
            ctx_len,
            ctx,
            cand,
            pairs: vec![0f32; batch * np],
        }
    }

    /// Median seconds per batched-kernel sweep (`iters` calls).
    fn run(&mut self, lvl: IsaLevel, iters: usize, reps: usize, specialized: bool) -> f64 {
        let _guard = ForcedIsaGuard::force(lvl);
        median_time(1, reps, || {
            for _ in 0..iters {
                if specialized {
                    block_ffm::forward_partial_batch(
                        &self.pool.weights,
                        &self.layout,
                        self.fields,
                        self.k,
                        self.ctx_len,
                        &self.ctx,
                        &self.cand,
                        &mut self.pairs,
                    );
                } else {
                    block_ffm::forward_partial_batch_runtime_k(
                        &self.pool.weights,
                        &self.layout,
                        self.fields,
                        self.k,
                        self.ctx_len,
                        &self.ctx,
                        &self.cand,
                        &mut self.pairs,
                    );
                }
            }
            self.pairs[0]
        })
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let rungs = simd::available_levels();
    let best = *rungs.last().expect("scalar is always available");
    println!("== Figure 5: SIMD-aware forward pass, per ISA rung ==");
    println!(
        "detected ISA: {} (rungs: {})",
        simd::isa_name(),
        rungs.iter().map(|l| l.name()).collect::<Vec<_>>().join(", ")
    );
    if !simd::simd_active() {
        println!("(host has no AVX2+FMA — every arm runs scalar)");
    }

    // -- 1. end-to-end forward, per rung × latent dim ------------------
    let n = if smoke { 4_000 } else { 30_000 };
    let steps = if smoke { 2_000 } else { 20_000 };
    let reps = if smoke { 3 } else { 5 };
    let mut header = format!("{:<26}", "model (K, hidden)");
    for lvl in &rungs {
        header.push_str(&format!(" {:>12}", lvl.name()));
    }
    println!("\n{header} {:>9}", "best/scl");
    // Larger K benefits more from vectorized latent dots; the hidden
    // layer GEMM vectorizes in all variants.
    let mut shape_rows = Vec::new();
    for (k, hidden) in [(4usize, vec![16usize]), (8, vec![16]), (16, vec![32]), (8, vec![32, 32])] {
        let spec = DatasetSpec::criteo_like();
        let buckets = if smoke { 1u32 << 14 } else { 1u32 << 18 };
        let cfg = ModelConfig::deep_ffm(spec.fields(), k, buckets, &hidden);
        let mut reg = Regressor::new(&cfg);
        let mut ws = Workspace::new();
        let mut stream = SyntheticStream::with_buckets(spec, 13, buckets);
        for _ in 0..steps {
            let ex = stream.next_example();
            reg.learn(&ex, &mut ws);
        }
        let data = stream.take_examples(n);
        let mut line = format!("{:<26}", format!("K={k}, hidden {hidden:?}"));
        let mut arms = Vec::new();
        let mut scalar_secs = f64::NAN;
        let mut best_secs = f64::NAN;
        for &lvl in &rungs {
            let secs = bench_forward(&reg, &data, lvl, reps);
            if lvl == IsaLevel::Scalar {
                scalar_secs = secs;
            }
            if lvl == best {
                best_secs = secs;
            }
            line.push_str(&format!(" {:>10.1}ns", secs / n as f64 * 1e9));
            arms.push(obj(vec![
                ("isa_rung", s(lvl.name())),
                ("k", num(k as f64)),
                ("ns_per_example", num(secs / n as f64 * 1e9)),
            ]));
        }
        let speedup = scalar_secs / best_secs;
        println!("{line} {speedup:>8.2}x");
        shape_rows.push(obj(vec![
            ("latent_dim", num(k as f64)),
            ("hidden", s(&format!("{hidden:?}"))),
            ("arms", arr(arms)),
            ("speedup_best_vs_scalar", num(speedup)),
        ]));
    }

    // -- 2. GEMM rung ratio (the zmm tile's headline kernel) -----------
    let (gb, gr, gc) = if smoke { (32usize, 128usize, 128usize) } else { (64, 256, 256) };
    let gemm_reps = if smoke { 5 } else { 9 };
    let flops = 2.0 * gb as f64 * gr as f64 * gc as f64;
    println!("\n-- batched GEMM (matmul_rowmajor, {gb}x{gr}x{gc}) --");
    println!("{:>12} {:>12} {:>10}", "rung", "gflop/s", "vs scalar");
    let mut gemm_arms = Vec::new();
    let mut gemm_secs = std::collections::BTreeMap::new();
    for &lvl in &rungs {
        let secs = bench_gemm(lvl, gb, gr, gc, gemm_reps);
        gemm_secs.insert(lvl as u8, secs);
        let base = gemm_secs[&(IsaLevel::Scalar as u8)];
        println!(
            "{:>12} {:>12.2} {:>9.2}x",
            lvl.name(),
            flops / secs / 1e9,
            base / secs
        );
        gemm_arms.push(obj(vec![
            ("isa_rung", s(lvl.name())),
            ("gflops", num(flops / secs / 1e9)),
            ("seconds_per_call", num(secs)),
        ]));
    }
    let gemm_512_vs_2 = match (
        gemm_secs.get(&(IsaLevel::Avx2Fma as u8)),
        gemm_secs.get(&(IsaLevel::Avx512 as u8)),
    ) {
        (Some(a2), Some(a5)) => Some(a2 / a5),
        _ => None,
    };
    if let Some(ratio) = gemm_512_vs_2 {
        println!("avx512-vs-avx2 GEMM speedup: {ratio:.2}x");
    } else {
        println!("(no avx512 rung on this host — rung-ratio floor skipped)");
    }

    // -- 3. const-k specialization vs runtime-k, fastest rung ----------
    let pair_batch = 64usize;
    let pair_iters = if smoke { 100 } else { 400 };
    let pair_reps = if smoke { 5 } else { 9 };
    println!("\n-- const-k FFM pair kernel (batch {pair_batch}, rung {}) --", best.name());
    println!("{:>4} {:>14} {:>14} {:>9}", "k", "runtime-k", "const-k", "speedup");
    let mut const_k_rows = Vec::new();
    let mut k8_speedup = None;
    for k in [4usize, 8, 16] {
        let mut fx = PairFixture::new(k, pair_batch);
        let runtime = fx.run(best, pair_iters, pair_reps, false);
        let spec = fx.run(best, pair_iters, pair_reps, true);
        let per_call = |secs: f64| secs / pair_iters as f64 * 1e9;
        let speedup = runtime / spec;
        if k == 8 {
            k8_speedup = Some(speedup);
        }
        println!(
            "{k:>4} {:>12.1}ns {:>12.1}ns {speedup:>8.2}x",
            per_call(runtime),
            per_call(spec)
        );
        const_k_rows.push(obj(vec![
            ("k", num(k as f64)),
            ("isa_rung", s(best.name())),
            ("runtime_k_ns_per_call", num(per_call(runtime))),
            ("const_k_ns_per_call", num(per_call(spec))),
            ("speedup_const_vs_runtime", num(speedup)),
        ]));
    }

    let path = bench_env::write_report(
        "fig5_simd",
        smoke,
        vec![
            ("examples", num(n as f64)),
            ("rungs", arr(rungs.iter().map(|l| s(l.name())).collect())),
            ("shapes", arr(shape_rows)),
            ("gemm", arr(gemm_arms)),
            (
                "gemm_speedup_avx512_vs_avx2",
                gemm_512_vs_2.map(num).unwrap_or(fwumious::util::json::Json::Null),
            ),
            ("const_k", arr(const_k_rows)),
        ],
    );
    println!("\nreport -> {path}");
    println!("paper: ~20% serving speedup, up to 25% faster inference.");

    // Floors asserted after the report write so a regression still
    // leaves the numbers on disk.
    if let Some(ratio) = gemm_512_vs_2 {
        assert!(
            ratio >= 1.2,
            "avx512 GEMM at {ratio:.2}x of avx2, below the 1.2x floor"
        );
    }
    if simd::simd_active() {
        let ks = k8_speedup.expect("k=8 arm always runs");
        assert!(
            ks >= 1.15,
            "const-k path at {ks:.2}x of runtime-k (k=8, rung {}), below the \
             1.15x floor",
            best.name()
        );
    } else {
        println!("(scalar dispatch host: const-k 1.15x floor not enforced)");
    }
}
