//! Logistic-regression block: `lr(w_b, x) = Σ_f w[bucket_f] · x_f`.
//!
//! The yellow block of Figure 2 — hashed linear weights, one per
//! bucket, shared across fields.

use crate::feature::Example;
use crate::model::optimizer::UpdateRule;
use crate::model::weights::Layout;

/// Forward: weighted sum of the example's LR weights.
#[inline]
pub fn forward(weights: &[f32], layout: &Layout, ex: &Example) -> f32 {
    let mut sum = 0.0f32;
    for slot in &ex.slots {
        if slot.value != 0.0 {
            sum += weights[layout.lr_idx(slot.bucket)] * slot.value;
        }
    }
    sum
}

/// Backward: `dL/dw[bucket_f] = g · x_f` where `g = dL/d lr_out`.
#[inline]
pub fn backward<U: UpdateRule>(
    weights: &mut [f32],
    acc: &mut [f32],
    layout: &Layout,
    ex: &Example,
    g: f32,
    rule: &mut U,
) {
    if g == 0.0 {
        return;
    }
    for slot in &ex.slots {
        if slot.value != 0.0 {
            let idx = layout.lr_idx(slot.bucket);
            let (w, a) = (&mut weights[idx], &mut acc[idx]);
            rule.update(idx, w, a, g * slot.value);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::feature::{Example, FeatureSlot};
    use crate::model::optimizer::{GradRecorder, Sgd};
    use crate::model::weights::{Layout, WeightPool};

    fn setup() -> (Layout, WeightPool, Example) {
        let cfg = ModelConfig::linear(3, 16);
        let layout = Layout::new(&cfg);
        let mut pool = WeightPool::init(&cfg, &layout);
        for (i, w) in pool.weights.iter_mut().enumerate() {
            *w = i as f32 * 0.1;
        }
        let ex = Example {
            label: 1.0,
            importance: 1.0,
            slots: vec![
                FeatureSlot { field: 0, bucket: 2, value: 1.0 },
                FeatureSlot { field: 1, bucket: 5, value: 2.0 },
                FeatureSlot { field: 2, bucket: 0, value: 0.0 }, // absent
            ],
        };
        (layout, pool, ex)
    }

    #[test]
    fn forward_weighted_sum() {
        let (layout, pool, ex) = setup();
        // 0.2*1 + 0.5*2 = 1.2; absent field contributes nothing
        assert!((forward(&pool.weights, &layout, &ex) - 1.2).abs() < 1e-6);
    }

    #[test]
    fn backward_grad_is_g_times_value() {
        let (layout, mut pool, ex) = setup();
        let mut rec = GradRecorder::default();
        let mut acc = pool.acc.clone();
        backward(&mut pool.weights, &mut acc, &layout, &ex, 0.5, &mut rec);
        let dense = rec.dense(layout.total);
        assert!((dense[2] - 0.5).abs() < 1e-6);
        assert!((dense[5] - 1.0).abs() < 1e-6);
        assert_eq!(dense[0], 0.0);
    }

    #[test]
    fn backward_zero_grad_noop() {
        let (layout, mut pool, ex) = setup();
        let before = pool.weights.clone();
        let mut acc = pool.acc.clone();
        backward(&mut pool.weights, &mut acc, &layout, &ex, 0.0, &mut Sgd { lr: 1.0 });
        assert_eq!(pool.weights, before);
    }

    #[test]
    fn sgd_moves_weights_down_gradient() {
        let (layout, mut pool, ex) = setup();
        let mut acc = pool.acc.clone();
        backward(&mut pool.weights, &mut acc, &layout, &ex, 1.0, &mut Sgd { lr: 0.1 });
        // w[2] -= 0.1 * 1.0 ; w[5] -= 0.1 * 2.0
        assert!((pool.weights[2] - (0.2 - 0.1)).abs() < 1e-6);
        assert!((pool.weights[5] - (0.5 - 0.2)).abs() < 1e-6);
    }
}
