"""Pallas kernel for the field-aware FFM pairwise interaction (L1).

The compute hot-spot of the DeepFFM forward pass is the field-aware
pairwise interaction with the DiagMask:

    out[b, i, j] = <emb[b, i, j, :], emb[b, j, i, :]> * x_i * x_j   (i < j)

Hardware adaptation (§Hardware-Adaptation in DESIGN.md): the paper's
production engine vectorizes this on CPU SIMD by laying latents out
field-major so the inner dot product is a stride-1 K-loop.  On TPU the
same insight becomes a VMEM-tiled batched contraction: the grid iterates
over the batch dimension, one example's [F, F, K] latent block is staged
into VMEM (F=39, K=4 -> ~24 KB in f32, far below VMEM capacity, leaving
room for multi-example batch tiles), and the K-axis contraction
``einsum('ijk,jik->ij')`` maps onto the MXU/VPU as a transposed
elementwise-multiply + reduce.  BlockSpec expresses the HBM->VMEM
schedule that the CPU code expresses with cache-blocked loops.

``interpret=True`` is mandatory here: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret mode lowers the kernel to plain HLO so
the AOT artifact runs anywhere (including the Rust xla-crate client).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ffm_kernel(emb_ref, vals_ref, out_ref):
    """One grid step: a [TB, F, F, K] tile of examples.

    emb_ref:  [TB, F, F, K] VMEM tile of field-aware latents.
    vals_ref: [TB, F]       feature values.
    out_ref:  [TB, F, F]    masked pair interactions.
    """
    emb = emb_ref[...]
    vals = vals_ref[...]
    tb, f, _, k = emb.shape
    # Transposed-field dot product over K: <emb[b,i,j], emb[b,j,i]>.
    # jnp.swapaxes keeps this a fused multiply+reduce on the VPU; the
    # contraction is K-minor so it vectorizes along the lane dimension.
    dots = jnp.sum(emb * jnp.swapaxes(emb, 1, 2), axis=-1)  # [TB, F, F]
    # Value outer product x_i * x_j.
    xx = vals[:, :, None] * vals[:, None, :]
    # DiagMask: strict upper triangle only (halves downstream combos).
    rows = jax.lax.broadcasted_iota(jnp.int32, (f, f), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (f, f), 1)
    mask = (rows < cols).astype(emb.dtype)
    out_ref[...] = dots * xx * mask[None, :, :]


def ffm_interaction(emb: jnp.ndarray, vals: jnp.ndarray,
                    batch_tile: int = 8) -> jnp.ndarray:
    """Pallas field-aware interaction. emb [B,F,F,K], vals [B,F] -> [B,F,F].

    The grid tiles the batch dimension; each step keeps one tile's latent
    block resident in VMEM.  ``batch_tile`` must divide B (callers pad).
    """
    b, f, f2, k = emb.shape
    assert f == f2, "latent tensor must be [B, F, F, K]"
    if b % batch_tile != 0:
        batch_tile = 1
    grid = (b // batch_tile,)
    return pl.pallas_call(
        _ffm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((batch_tile, f, f, k), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((batch_tile, f), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((batch_tile, f, f), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, f, f), emb.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(emb, vals)


@functools.partial(jax.jit, static_argnames=("batch_tile",))
def ffm_interaction_jit(emb, vals, batch_tile: int = 8):
    """Jitted wrapper used by tests and by the L2 model."""
    return ffm_interaction(emb, vals, batch_tile=batch_tile)


def vmem_bytes_per_tile(f: int, k: int, batch_tile: int,
                        dtype_bytes: int = 4) -> int:
    """Static VMEM footprint estimate for one grid step (for §Perf).

    emb tile + vals tile + out tile, all resident simultaneously.
    """
    emb_b = batch_tile * f * f * k * dtype_bytes
    vals_b = batch_tile * f * dtype_bytes
    out_b = batch_tile * f * f * dtype_bytes
    return emb_b + vals_b + out_b
