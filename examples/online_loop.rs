//! The production loop (§3 + §6 live): continuous online training
//! rounds, each followed by quantize → patch → ship over a simulated
//! inter-DC channel → apply → hot-swap into the serving layer — while
//! requests keep flowing.
//!
//! Prints the per-round bandwidth ledger (Table 4 / Figure 6 live).
//!
//! ```bash
//! cargo run --release --example online_loop
//! ```

use fwumious::config::{ModelConfig, ServeConfig};
use fwumious::data::synthetic::{DatasetSpec, SyntheticStream};
use fwumious::model::regressor::Regressor;
use fwumious::model::{io, Workspace};
use fwumious::serve::router::Router;
use fwumious::serve::server::ServingEngine;
use fwumious::serve::trace::TraceGenerator;
use fwumious::serve::ModelHandle;
use fwumious::transfer::{SimulatedChannel, UpdateMode, UpdatePipeline, UpdateReceiver};

fn main() {
    let spec = DatasetSpec::avazu_like();
    let buckets = 1u32 << 18;
    let cfg = ModelConfig::deep_ffm(spec.fields(), 4, buckets, &[16]);
    let fields = cfg.fields;

    // training DC
    let mut trainer = Regressor::new(&cfg);
    let mut ws = Workspace::new();
    let mut stream = SyntheticStream::with_buckets(spec, 7, buckets);
    let mut pipeline = UpdatePipeline::new(UpdateMode::QuantPatch);
    let mut raw_pipeline = UpdatePipeline::new(UpdateMode::Raw);

    // serving DC
    let handle = ModelHandle::new(trainer.clone());
    let router = Router::new(4);
    router.register("ctr", handle.clone());
    let engine = ServingEngine::start(
        router,
        ServeConfig { workers: 4, ..Default::default() },
    );
    let mut receiver = UpdateReceiver::new(UpdateMode::QuantPatch);
    receiver.set_template(trainer.clone());
    let mut channel = SimulatedChannel::with_bandwidth(125_000_000.0, 0.03); // 1 Gbps
    let mut gen = TraceGenerator::new(3, fields, fields / 2, buckets, 8);

    let raw_bytes = io::to_bytes(&trainer, false).len();
    println!(
        "model: {} weights, raw inference file {:.1} MB",
        trainer.num_weights(),
        raw_bytes as f64 / 1e6
    );
    println!(
        "{:<6} {:>10} {:>9} {:>9} {:>10} {:>9} {:>9}",
        "round", "update(B)", "%of raw", "encode", "wire(s)", "serveAUC", "hit%"
    );

    let rounds = 10;
    let per_round = 50_000;
    for round in 0..rounds {
        // online training window (the paper's "every 5 minutes")
        for _ in 0..per_round {
            let ex = stream.next_example();
            trainer.learn(&ex, &mut ws);
        }
        // encode + ship + apply + swap
        let update = pipeline.encode(&trainer);
        let raw = raw_pipeline.encode(&trainer);
        let wire_secs = channel.ship(&update);
        let fresh = receiver.apply(&update).expect("reconstruct");
        handle.swap(fresh);

        // keep serving against the swapped model
        let mut scores = Vec::new();
        let mut labels = Vec::new();
        for _ in 0..2_000 {
            let req = gen.next_request("ctr");
            let resp = engine.score(req).expect("score");
            // label the top candidate against the stream's ground truth
            // (proxy: just collect score spread for an AUC-vs-self check)
            scores.extend(resp.scores.iter().cloned());
            labels.extend(resp.scores.iter().map(|&s| (s > 0.5) as i32 as f32));
        }
        let stats = engine.stats();
        println!(
            "{:<6} {:>10} {:>8.2}% {:>8.0}ms {:>9.4} {:>9} {:>8.1}%",
            round,
            update.bytes.len(),
            update.bytes.len() as f64 / raw.bytes.len() as f64 * 100.0,
            update.encode_seconds * 1e3,
            wire_secs,
            "-",
            stats.cache_hit_rate() * 100.0
        );
    }
    let stats = engine.shutdown();
    println!(
        "\ntotal shipped: {:.2} MB over {} rounds (raw would be {:.2} MB) — {:.0}x bandwidth saving",
        channel.total_bytes as f64 / 1e6,
        rounds,
        (raw_bytes * rounds) as f64 / 1e6,
        (raw_bytes * rounds) as f64 / channel.total_bytes as f64
    );
    println!(
        "served {} requests, {} errors, latency {}",
        stats.requests,
        stats.errors,
        stats.latency.map(|l| l.summary()).unwrap_or_default()
    );
}
