//! The overload plane (ROADMAP item 2): what a saturated replica does
//! with the traffic it cannot serve in time.
//!
//! Three cooperating pieces:
//!
//! * [`BoundedQueue`] — the admission-controlled worker queue.  A push
//!   against a full queue never blocks: it is shed according to the
//!   configured [`ShedPolicy`](crate::config::ShedPolicy) — either the
//!   arriving request is rejected (`reject-new`) or the oldest queued
//!   request is evicted to make room (`drop-oldest`, so the request
//!   closest to blowing its deadline pays for the freshest one).
//!   Closing the queue wakes every waiting worker immediately, which is
//!   what makes engine shutdown prompt even under second-scale linger
//!   configs.
//! * [`DegradeLevel`] — the degradation ladder.  `Full` serves the
//!   model as configured; `Truncate` caps candidate slates at
//!   `degraded_max_candidates`; `Ffm` additionally drops the neural
//!   head (DeepFFM → FFM); `Lr` scores the linear block only.  Each
//!   rung trades ranking quality for a hard reduction in per-request
//!   kernel work, following the DeepFFM → FFM → LR architecture ladder
//!   the paper's Table 1 quantifies.
//! * [`OverloadController`] — a per-worker hysteresis controller over a
//!   sliding window of observed request latencies.  When the windowed
//!   p99 drifts past the SLO it escalates one rung; when the p99 of a
//!   *fresh* window recovers below `recover_frac · SLO` it re-arms one
//!   rung.  A minimum dwell between transitions prevents flapping.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use crate::config::ShedPolicy;

// ---------------------------------------------------------------- queue

/// Outcome of a [`BoundedQueue::push`].
#[derive(Debug)]
pub enum Push<T> {
    /// Enqueued; queue had room.
    Admitted,
    /// Enqueued, but the oldest queued item was evicted to make room
    /// (`drop-oldest` policy).  The caller owns the casualty — the
    /// serving engine answers its reply channel with a shed error.
    AdmittedDroppingOldest(T),
    /// Queue full under `reject-new`: the new item comes straight back.
    Rejected(T),
    /// Queue closed (engine shut down): the item comes straight back.
    Closed(T),
}

/// Outcome of a [`BoundedQueue::pop_timeout`].
#[derive(Debug, PartialEq, Eq)]
pub enum Pop<T> {
    Item(T),
    TimedOut,
    /// Closed **and** drained — workers exit on this.  A closed queue
    /// still hands out whatever was admitted before the close, so
    /// shutdown never drops accepted work.
    Closed,
}

struct QueueInner<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// A bounded MPSC queue with non-blocking, policy-driven admission.
///
/// Unlike `std::sync::mpsc::sync_channel`, a full queue never blocks
/// the producer (`submit` must answer "shed" in O(1), not stall a
/// traffic thread), the consumer can be woken immediately on close
/// (prompt shutdown regardless of linger timeouts), and `drop-oldest`
/// eviction is possible at all (mpsc offers no producer-side pop).
pub struct BoundedQueue<T> {
    inner: Mutex<QueueInner<T>>,
    readable: Condvar,
    capacity: usize,
}

impl<T> std::fmt::Debug for BoundedQueue<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("BoundedQueue").finish_non_exhaustive()
    }
}

impl<T> BoundedQueue<T> {
    pub fn new(capacity: usize) -> Self {
        BoundedQueue {
            inner: Mutex::new(QueueInner { items: VecDeque::new(), closed: false }),
            readable: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    /// Recover the queue state even if a holder panicked mid-section:
    /// every critical section here leaves the VecDeque structurally
    /// valid (push/pop are atomic w.r.t. the guard), so a poisoned
    /// lock's data is still consistent and serving must not deadlock
    /// the whole worker pool over one panicked thread.
    fn lock(&self) -> std::sync::MutexGuard<'_, QueueInner<T>> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Current queue depth (gauge; racy by nature).
    pub fn len(&self) -> usize {
        self.lock().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Admission-controlled, non-blocking push.
    pub fn push(&self, item: T, policy: ShedPolicy) -> Push<T> {
        let mut q = self.lock();
        if q.closed {
            return Push::Closed(item);
        }
        if q.items.len() < self.capacity {
            q.items.push_back(item);
            drop(q);
            self.readable.notify_one();
            return Push::Admitted;
        }
        match policy {
            ShedPolicy::RejectNew => Push::Rejected(item),
            ShedPolicy::DropOldest => {
                // len == capacity >= 1 here, so a front always exists;
                // degrade to reject rather than panic a worker if the
                // invariant ever breaks
                match q.items.pop_front() {
                    Some(evicted) => {
                        q.items.push_back(item);
                        drop(q);
                        self.readable.notify_one();
                        Push::AdmittedDroppingOldest(evicted)
                    }
                    None => Push::Rejected(item),
                }
            }
        }
    }

    /// Pop, waiting up to `timeout` for an item.  Returns
    /// [`Pop::Closed`] only once the queue is both closed and drained.
    pub fn pop_timeout(&self, timeout: Duration) -> Pop<T> {
        let mut q = self.lock();
        loop {
            if let Some(item) = q.items.pop_front() {
                return Pop::Item(item);
            }
            if q.closed {
                return Pop::Closed;
            }
            // same poison-recovery rationale as `lock`
            let (guard, res) = match self.readable.wait_timeout(q, timeout) {
                Ok(pair) => pair,
                Err(poisoned) => poisoned.into_inner(),
            };
            q = guard;
            if res.timed_out() {
                return match q.items.pop_front() {
                    Some(item) => Pop::Item(item),
                    None if q.closed => Pop::Closed,
                    None => Pop::TimedOut,
                };
            }
        }
    }

    /// Non-blocking pop (shutdown drain).
    pub fn try_pop(&self) -> Option<T> {
        self.lock().items.pop_front()
    }

    /// Close the queue: further pushes bounce with [`Push::Closed`],
    /// every waiting consumer wakes immediately, and pops drain the
    /// remaining items before reporting [`Pop::Closed`].
    pub fn close(&self) {
        self.lock().closed = true;
        self.readable.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.lock().closed
    }
}

// --------------------------------------------------------------- ladder

/// The degradation ladder, cheapest-first from the bottom.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum DegradeLevel {
    /// Serve the model as configured.
    Full = 0,
    /// Truncate candidate slates to `degraded_max_candidates`.
    Truncate = 1,
    /// Truncate + drop the neural head (DeepFFM → FFM).
    Ffm = 2,
    /// Truncate + linear block only (→ LR).
    Lr = 3,
}

impl DegradeLevel {
    pub const LADDER: [DegradeLevel; 4] = [
        DegradeLevel::Full,
        DegradeLevel::Truncate,
        DegradeLevel::Ffm,
        DegradeLevel::Lr,
    ];

    /// Does this rung truncate candidate slates?
    pub fn truncates(&self) -> bool {
        *self != DegradeLevel::Full
    }

    /// Architecture cap this rung imposes on scoring (None = serve the
    /// model's own architecture).
    pub fn arch_cap(&self) -> Option<crate::config::Architecture> {
        match self {
            DegradeLevel::Full | DegradeLevel::Truncate => None,
            DegradeLevel::Ffm => Some(crate::config::Architecture::Ffm),
            DegradeLevel::Lr => Some(crate::config::Architecture::Linear),
        }
    }

    /// One rung further degraded (saturates at [`DegradeLevel::Lr`]).
    pub fn escalated(&self) -> DegradeLevel {
        Self::LADDER[(*self as usize + 1).min(Self::LADDER.len() - 1)]
    }

    /// One rung recovered (saturates at [`DegradeLevel::Full`]).
    pub fn recovered(&self) -> DegradeLevel {
        Self::LADDER[(*self as usize).saturating_sub(1)]
    }

    pub fn label(&self) -> &'static str {
        match self {
            DegradeLevel::Full => "full",
            DegradeLevel::Truncate => "truncate",
            DegradeLevel::Ffm => "ffm",
            DegradeLevel::Lr => "lr",
        }
    }
}

// ----------------------------------------------------------- controller

/// Tuning knobs of the [`OverloadController`] (defaults are what the
/// serving engine uses; tests construct custom ones).
#[derive(Clone, Copy, Debug)]
pub struct OverloadConfig {
    /// The latency SLO in nanoseconds; 0 disables the controller.
    pub slo_ns: u64,
    /// Sliding-window size (latency observations).
    pub window: usize,
    /// Minimum observations before the first verdict of a window.
    pub min_samples: usize,
    /// Minimum observations between transitions (anti-flap dwell).
    pub min_dwell: usize,
    /// Re-arm threshold: recover one rung when windowed p99 drops
    /// below `recover_frac * slo` (hysteresis band below the SLO).
    pub recover_frac: f64,
}

impl OverloadConfig {
    pub fn from_slo_us(slo_us: u64) -> Self {
        OverloadConfig {
            slo_ns: slo_us.saturating_mul(1_000),
            window: 64,
            min_samples: 16,
            min_dwell: 16,
            recover_frac: 0.7,
        }
    }
}

/// Per-worker hysteresis controller walking the [`DegradeLevel`]
/// ladder from windowed latency observations.
///
/// The window is cleared on every transition so each verdict is based
/// on latencies observed *at the current rung* — without that, the
/// pre-transition spike keeps the p99 elevated and the controller
/// over-escalates (and can never re-arm).
pub struct OverloadController {
    cfg: OverloadConfig,
    /// Ring buffer of recent latencies (ns).
    window: Vec<u64>,
    next: usize,
    filled: usize,
    /// Observations since the last transition.
    dwell: usize,
    level: DegradeLevel,
    /// Total transitions (both directions) since construction.
    pub transitions: u64,
}

impl std::fmt::Debug for OverloadController {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("OverloadController").finish_non_exhaustive()
    }
}

impl OverloadController {
    pub fn new(cfg: OverloadConfig) -> Self {
        OverloadController {
            window: vec![0; cfg.window.max(1)],
            next: 0,
            filled: 0,
            dwell: 0,
            level: DegradeLevel::Full,
            cfg,
        }
    }

    /// Controller for a serving config (disabled when the SLO is 0).
    pub fn from_slo_us(slo_us: u64) -> Self {
        Self::new(OverloadConfig::from_slo_us(slo_us))
    }

    pub fn enabled(&self) -> bool {
        self.cfg.slo_ns > 0
    }

    pub fn level(&self) -> DegradeLevel {
        self.level
    }

    /// Record one end-to-end request latency.  Deadline-expired
    /// requests feed the window too — a wait that blew the SLO is the
    /// strongest overload signal there is.
    pub fn observe_ns(&mut self, ns: u64) {
        if !self.enabled() {
            return;
        }
        self.window[self.next] = ns;
        self.next = (self.next + 1) % self.window.len();
        self.filled = (self.filled + 1).min(self.window.len());
        self.dwell += 1;
    }

    /// Windowed p99 (exact over the ring contents; the window is small).
    pub fn windowed_p99_ns(&self) -> u64 {
        if self.filled == 0 {
            return 0;
        }
        let mut v: Vec<u64> = self.window[..self.filled].to_vec();
        v.sort_unstable();
        let idx = ((self.filled as f64) * 0.99).ceil() as usize;
        v[idx.clamp(1, self.filled) - 1]
    }

    /// Evaluate the ladder after a batch of observations; returns the
    /// transition taken, if any.
    pub fn decide(&mut self) -> Option<DegradeLevel> {
        if !self.enabled()
            || self.filled < self.cfg.min_samples
            || self.dwell < self.cfg.min_dwell
        {
            return None;
        }
        let p99 = self.windowed_p99_ns();
        let next = if p99 > self.cfg.slo_ns {
            self.level.escalated()
        } else if (p99 as f64) < self.cfg.recover_frac * self.cfg.slo_ns as f64 {
            self.level.recovered()
        } else {
            self.level
        };
        if next == self.level {
            return None;
        }
        self.level = next;
        self.transitions += 1;
        self.dwell = 0;
        self.filled = 0; // fresh window at the new rung
        self.next = 0;
        Some(next)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // -------------------------------------------------------- queue

    #[test]
    fn queue_reject_new_on_full() {
        let q = BoundedQueue::new(2);
        assert!(matches!(q.push(1, ShedPolicy::RejectNew), Push::Admitted));
        assert!(matches!(q.push(2, ShedPolicy::RejectNew), Push::Admitted));
        assert!(matches!(q.push(3, ShedPolicy::RejectNew), Push::Rejected(3)));
        assert_eq!(q.len(), 2);
        // FIFO order preserved, the rejected item never entered
        assert_eq!(q.pop_timeout(Duration::ZERO), Pop::Item(1));
        assert_eq!(q.pop_timeout(Duration::ZERO), Pop::Item(2));
        assert_eq!(q.pop_timeout(Duration::ZERO), Pop::TimedOut);
    }

    #[test]
    fn queue_drop_oldest_evicts_front() {
        let q = BoundedQueue::new(2);
        q.push(1, ShedPolicy::DropOldest);
        q.push(2, ShedPolicy::DropOldest);
        match q.push(3, ShedPolicy::DropOldest) {
            Push::AdmittedDroppingOldest(old) => assert_eq!(old, 1),
            other => panic!("expected eviction, got {other:?}"),
        }
        assert_eq!(q.pop_timeout(Duration::ZERO), Pop::Item(2));
        assert_eq!(q.pop_timeout(Duration::ZERO), Pop::Item(3));
    }

    #[test]
    fn queue_capacity_zero_is_one() {
        let q = BoundedQueue::new(0);
        assert!(matches!(q.push(1, ShedPolicy::RejectNew), Push::Admitted));
        assert!(matches!(q.push(2, ShedPolicy::RejectNew), Push::Rejected(2)));
    }

    #[test]
    fn queue_close_wakes_and_drains() {
        let q = std::sync::Arc::new(BoundedQueue::new(8));
        q.push(7, ShedPolicy::RejectNew);
        q.close();
        // closed pushes bounce
        assert!(matches!(q.push(8, ShedPolicy::RejectNew), Push::Closed(8)));
        assert!(matches!(q.push(9, ShedPolicy::DropOldest), Push::Closed(9)));
        // admitted-before-close work still drains, then Closed
        assert_eq!(q.pop_timeout(Duration::from_secs(5)), Pop::Item(7));
        assert_eq!(q.pop_timeout(Duration::from_secs(5)), Pop::Closed);
    }

    #[test]
    fn queue_close_wakes_a_blocked_consumer_promptly() {
        let q = std::sync::Arc::new(BoundedQueue::<u32>::new(8));
        let q2 = q.clone();
        let t = std::thread::spawn(move || {
            let start = std::time::Instant::now();
            // a consumer parked on a LONG wait must wake on close, not
            // ride out the timeout
            let r = q2.pop_timeout(Duration::from_secs(30));
            (r, start.elapsed())
        });
        std::thread::sleep(Duration::from_millis(30));
        q.close();
        let (r, waited) = t.join().unwrap();
        assert_eq!(r, Pop::Closed);
        assert!(waited < Duration::from_secs(5), "close did not wake: {waited:?}");
    }

    #[test]
    fn queue_pop_blocks_until_push() {
        let q = std::sync::Arc::new(BoundedQueue::<u32>::new(8));
        let q2 = q.clone();
        let t = std::thread::spawn(move || q2.pop_timeout(Duration::from_secs(30)));
        std::thread::sleep(Duration::from_millis(20));
        q.push(42, ShedPolicy::RejectNew);
        assert_eq!(t.join().unwrap(), Pop::Item(42));
    }

    // ------------------------------------------------------- ladder

    #[test]
    fn ladder_walks_and_saturates() {
        let mut l = DegradeLevel::Full;
        assert!(!l.truncates());
        assert_eq!(l.arch_cap(), None);
        l = l.escalated();
        assert_eq!(l, DegradeLevel::Truncate);
        assert!(l.truncates());
        assert_eq!(l.arch_cap(), None);
        l = l.escalated();
        assert_eq!(l, DegradeLevel::Ffm);
        assert_eq!(l.arch_cap(), Some(crate::config::Architecture::Ffm));
        l = l.escalated();
        assert_eq!(l, DegradeLevel::Lr);
        assert_eq!(l.arch_cap(), Some(crate::config::Architecture::Linear));
        assert_eq!(l.escalated(), DegradeLevel::Lr); // saturates
        assert_eq!(DegradeLevel::Full.recovered(), DegradeLevel::Full);
    }

    // --------------------------------------------------- controller

    fn ctl(slo_us: u64) -> OverloadController {
        OverloadController::new(OverloadConfig {
            min_samples: 8,
            min_dwell: 8,
            window: 32,
            ..OverloadConfig::from_slo_us(slo_us)
        })
    }

    fn feed(c: &mut OverloadController, ns: u64, n: usize) -> Vec<DegradeLevel> {
        let mut trans = Vec::new();
        for _ in 0..n {
            c.observe_ns(ns);
            if let Some(t) = c.decide() {
                trans.push(t);
            }
        }
        trans
    }

    #[test]
    fn controller_disabled_without_slo() {
        let mut c = OverloadController::from_slo_us(0);
        assert!(!c.enabled());
        feed(&mut c, u64::MAX / 2, 1000);
        assert_eq!(c.level(), DegradeLevel::Full);
        assert_eq!(c.transitions, 0);
    }

    #[test]
    fn controller_escalates_then_recovers_with_hysteresis() {
        let mut c = ctl(1_000); // 1ms SLO
        // in-SLO traffic: no transitions
        assert!(feed(&mut c, 500_000, 100).is_empty());
        assert_eq!(c.level(), DegradeLevel::Full);
        // sustained overload: walks down the ladder one dwell at a time
        let down = feed(&mut c, 5_000_000, 100);
        assert!(down.len() >= 2, "escalations: {down:?}");
        assert_eq!(down[0], DegradeLevel::Truncate);
        assert_eq!(c.level(), *down.last().unwrap());
        let worst = c.level();
        assert!(worst >= DegradeLevel::Ffm);
        // grey zone (between recover_frac*slo and slo): holds the rung
        assert!(feed(&mut c, 900_000, 100).is_empty());
        assert_eq!(c.level(), worst);
        // recovery traffic well below the re-arm threshold: walks back
        let up = feed(&mut c, 100_000, 200);
        assert!(!up.is_empty());
        assert_eq!(c.level(), DegradeLevel::Full);
        assert_eq!(*up.last().unwrap(), DegradeLevel::Full);
        assert_eq!(c.transitions, (down.len() + up.len()) as u64);
    }

    #[test]
    fn controller_dwell_bounds_transition_rate() {
        let mut c = ctl(1_000);
        // 24 overloaded observations with dwell 8 allow at most 3
        // transitions no matter how bad the latencies are
        let trans = feed(&mut c, u64::MAX / 4, 24);
        assert!(trans.len() <= 3, "flapping: {trans:?}");
    }

    #[test]
    fn controller_p99_is_windowed() {
        let mut c = ctl(1_000);
        feed(&mut c, 10_000_000, 32);
        let p99_hot = c.windowed_p99_ns();
        assert!(p99_hot >= 10_000_000);
        // transitions cleared the window; a cold window reads fresh
        feed(&mut c, 1_000, 32);
        assert!(c.windowed_p99_ns() <= 10_000_000);
    }
}
