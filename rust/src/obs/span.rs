//! Per-request serving spans: a compact clock each request carries
//! from submit to reply, stamped once per pipeline stage.
//!
//! The serving pipeline is
//!
//! ```text
//! submit → queue-wait → flush → group-assembly → cache → kernel → reply
//! ```
//!
//! Queue-wait and flush are stamped per request (the request's own
//! waits); group-assembly is measured per batch and cache/kernel per
//! context group — those are shared costs, attributed to the request's
//! batch/group in trace events. Stamping is two `Instant::now()` calls
//! and an array write per stage; there is no allocation and no lock.

use std::time::Instant;

/// Pipeline stages in submit→reply order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// Submit → worker pops the job off its bounded queue.
    Queue,
    /// Pop → the batcher flushes the batch containing the job.
    Flush,
    /// Context-group assembly + deadline triage for the whole batch.
    Group,
    /// Context-cache lookup (or partial-forward compute on miss) for
    /// the request's group.
    Cache,
    /// Batched kernel scoring the group's union slate.
    Kernel,
    /// Submit → reply sent (the whole span).
    Total,
}

pub const N_STAGES: usize = 6;

impl Stage {
    pub const ALL: [Stage; N_STAGES] = [
        Stage::Queue,
        Stage::Flush,
        Stage::Group,
        Stage::Cache,
        Stage::Kernel,
        Stage::Total,
    ];

    /// Short label used in trace events.
    pub fn label(self) -> &'static str {
        match self {
            Stage::Queue => "queue",
            Stage::Flush => "flush",
            Stage::Group => "group",
            Stage::Cache => "cache",
            Stage::Kernel => "kernel",
            Stage::Total => "total",
        }
    }

    /// Registry metric name for the per-stage latency histogram.
    pub fn metric_name(self) -> &'static str {
        match self {
            Stage::Queue => "fw_serve_stage_queue_ns",
            Stage::Flush => "fw_serve_stage_flush_ns",
            Stage::Group => "fw_serve_stage_group_ns",
            Stage::Cache => "fw_serve_stage_cache_ns",
            Stage::Kernel => "fw_serve_stage_kernel_ns",
            Stage::Total => "fw_serve_stage_total_ns",
        }
    }
}

/// Nanoseconds accumulated per stage for one request.
#[derive(Clone, Copy, Debug, Default)]
pub struct SpanTimes {
    ns: [u64; N_STAGES],
}

impl SpanTimes {
    pub fn get(&self, stage: Stage) -> u64 {
        self.ns[stage as usize]
    }

    pub fn add(&mut self, stage: Stage, ns: u64) {
        self.ns[stage as usize] += ns;
    }
}

/// Clock a request carries through the pipeline. `stamp` charges the
/// elapsed time since the previous stamp to a stage and resets the
/// reference point.
#[derive(Clone, Copy, Debug)]
pub struct SpanClock {
    /// Submit time — also the deadline/ordering anchor.
    pub submitted: Instant,
    last: Instant,
    pub times: SpanTimes,
}

impl SpanClock {
    pub fn start_at(at: Instant) -> Self {
        SpanClock {
            submitted: at,
            last: at,
            times: SpanTimes::default(),
        }
    }

    pub fn start() -> Self {
        Self::start_at(Instant::now())
    }

    /// Charge `now - last_stamp` to `stage` and move the reference.
    pub fn stamp_at(&mut self, stage: Stage, now: Instant) {
        let ns = now.saturating_duration_since(self.last).as_nanos() as u64;
        self.times.add(stage, ns);
        self.last = now;
    }

    pub fn stamp(&mut self, stage: Stage) {
        self.stamp_at(stage, Instant::now());
    }

    /// Charge externally measured time (shared batch/group costs).
    pub fn add_ns(&mut self, stage: Stage, ns: u64) {
        self.times.add(stage, ns);
    }

    /// Close the span: Total = full submit→now duration.
    pub fn finish_at(&mut self, now: Instant) -> u64 {
        let ns = now.saturating_duration_since(self.submitted).as_nanos() as u64;
        self.times.add(Stage::Total, ns);
        ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn stamps_accumulate_per_stage() {
        let t0 = Instant::now();
        let mut c = SpanClock::start_at(t0);
        c.stamp_at(Stage::Queue, t0 + Duration::from_micros(10));
        c.stamp_at(Stage::Flush, t0 + Duration::from_micros(25));
        c.add_ns(Stage::Kernel, 3_000);
        let total = c.finish_at(t0 + Duration::from_micros(40));
        assert_eq!(c.times.get(Stage::Queue), 10_000);
        assert_eq!(c.times.get(Stage::Flush), 15_000);
        assert_eq!(c.times.get(Stage::Kernel), 3_000);
        assert_eq!(total, 40_000);
        assert_eq!(c.times.get(Stage::Total), 40_000);
    }

    #[test]
    fn stage_tables_cover_all() {
        for s in Stage::ALL {
            assert!(!s.label().is_empty());
            assert!(s.metric_name().starts_with("fw_serve_stage_"));
        }
    }
}
