//! Log-bucketed latency histogram for serving metrics (p50/p90/p99).
//!
//! Serving latencies span nanoseconds to milliseconds, so buckets grow
//! geometrically: bucket i covers [lo * g^i, lo * g^(i+1)).

/// Fixed-size geometric histogram over nanosecond values.
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    counts: Vec<u64>,
    total: u64,
    sum_ns: u128,
    max_ns: u64,
    min_ns: u64,
    lo_ns: f64,
    growth: f64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    /// 128 buckets from 50 ns to ~1.7 s with ~14% resolution.
    pub fn new() -> Self {
        LatencyHistogram {
            counts: vec![0; 128],
            total: 0,
            sum_ns: 0,
            max_ns: 0,
            min_ns: u64::MAX,
            lo_ns: 50.0,
            growth: 1.14,
        }
    }

    fn bucket(&self, ns: u64) -> usize {
        if (ns as f64) < self.lo_ns {
            return 0;
        }
        let b = ((ns as f64 / self.lo_ns).ln() / self.growth.ln()) as usize;
        b.min(self.counts.len() - 1)
    }

    /// Record one observation in nanoseconds.
    pub fn record_ns(&mut self, ns: u64) {
        let b = self.bucket(ns);
        self.counts[b] += 1;
        self.total += 1;
        self.sum_ns += ns as u128;
        self.max_ns = self.max_ns.max(ns);
        self.min_ns = self.min_ns.min(ns);
    }

    /// Record a `Duration`.
    pub fn record(&mut self, d: std::time::Duration) {
        self.record_ns(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean_ns(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum_ns as f64 / self.total as f64
        }
    }

    pub fn max_ns(&self) -> u64 {
        self.max_ns
    }

    /// Smallest recorded value (0 when nothing has been recorded — the
    /// raw field's `u64::MAX` sentinel must never leak to callers).
    pub fn min_ns(&self) -> u64 {
        if self.total == 0 {
            0
        } else {
            self.min_ns
        }
    }

    /// Approximate quantile (upper edge of the containing bucket,
    /// clamped to the observed `max_ns` — the bucket edge can overshoot
    /// the largest recorded value, and a printed p99 above the printed
    /// max reads as corrupt metrics).
    pub fn quantile_ns(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target.max(1) {
                return (self.lo_ns * self.growth.powi(i as i32 + 1))
                    .min(self.max_ns as f64);
            }
        }
        self.max_ns as f64
    }

    /// Merge another histogram into this one (same geometry by
    /// construction).
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum_ns += other.sum_ns;
        self.max_ns = self.max_ns.max(other.max_ns);
        self.min_ns = self.min_ns.min(other.min_ns);
    }

    /// One-line human-readable summary.
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.1}us p50={:.1}us p90={:.1}us p99={:.1}us max={:.1}us",
            self.total,
            self.mean_ns() / 1e3,
            self.quantile_ns(0.50) / 1e3,
            self.quantile_ns(0.90) / 1e3,
            self.quantile_ns(0.99) / 1e3,
            self.max_ns as f64 / 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_zero() {
        let h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile_ns(0.5), 0.0);
    }

    #[test]
    fn quantiles_ordered() {
        let mut h = LatencyHistogram::new();
        for i in 1..=10_000u64 {
            h.record_ns(i * 100);
        }
        let p50 = h.quantile_ns(0.5);
        let p90 = h.quantile_ns(0.9);
        let p99 = h.quantile_ns(0.99);
        assert!(p50 <= p90 && p90 <= p99, "{p50} {p90} {p99}");
        // p50 of uniform 100ns..1ms should land near 500_000ns (±bucket).
        assert!((300_000.0..800_000.0).contains(&p50), "{p50}");
    }

    #[test]
    fn mean_exact() {
        let mut h = LatencyHistogram::new();
        h.record_ns(100);
        h.record_ns(300);
        assert_eq!(h.mean_ns(), 200.0);
    }

    #[test]
    fn merge_adds_counts() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        for _ in 0..50 {
            a.record_ns(1_000);
            b.record_ns(100_000);
        }
        a.merge(&b);
        assert_eq!(a.count(), 100);
        assert!(a.quantile_ns(0.9) > 50_000.0);
    }

    #[test]
    fn huge_values_clamp_to_last_bucket() {
        let mut h = LatencyHistogram::new();
        h.record_ns(u64::MAX / 2);
        assert_eq!(h.count(), 1);
        assert!(h.quantile_ns(1.0) > 0.0);
    }

    #[test]
    fn quantiles_never_exceed_observed_max() {
        // Regression: the containing bucket's upper edge used to leak
        // through, so summary() could print p99 > max in one line.
        let mut h = LatencyHistogram::new();
        h.record_ns(1_234);
        for q in [0.5, 0.9, 0.99, 1.0] {
            assert!(
                h.quantile_ns(q) <= 1_234.0,
                "q{q} = {} exceeds max", h.quantile_ns(q)
            );
        }
        h.record_ns(999_999);
        assert!(h.quantile_ns(0.99) <= h.max_ns() as f64);
    }

    #[test]
    fn min_tracked_and_empty_safe() {
        // Regression: the raw field initializes to u64::MAX; an empty
        // histogram must report 0, not the sentinel.
        let mut h = LatencyHistogram::new();
        assert_eq!(h.min_ns(), 0);
        h.record_ns(5_000);
        h.record_ns(70);
        h.record_ns(9_000);
        assert_eq!(h.min_ns(), 70);
        // min survives a merge, including with an empty histogram
        let mut other = LatencyHistogram::new();
        other.merge(&h);
        assert_eq!(other.min_ns(), 70);
        other.record_ns(10);
        let mut a = LatencyHistogram::new();
        a.record_ns(500);
        a.merge(&other);
        assert_eq!(a.min_ns(), 10);
    }
}
