//! Model / training / serving configuration.
//!
//! Hyperparameters mirror the paper's search space (§2.2): "power of t,
//! learning rates for different types of blocks (ffm, lr),
//! regularization amount".

pub mod parse;

/// Why a configuration was rejected — by [`ModelConfig::validate`], by
/// the key=value parser, or by one of the enum-valued flag parsers
/// (shed policy, fleet strategy, update mode).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ConfigError {
    /// A structural invariant failed (static explanation).
    Invalid(&'static str),
    /// A key's value failed to parse as the expected type.
    BadValue { key: &'static str, got: String },
    /// An enum-like flag got an unrecognized value.
    UnknownValue { what: &'static str, got: String, want: &'static str },
    /// A combination of otherwise-valid keys that cannot be built.
    Unsupported(String),
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Invalid(msg) => write!(f, "{msg}"),
            ConfigError::BadValue { key, got } => {
                write!(f, "bad value for {key}: '{got}'")
            }
            ConfigError::UnknownValue { what, got, want } => {
                write!(f, "unknown {what} '{got}' (want {want})")
            }
            ConfigError::Unsupported(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for ConfigError {}

/// CLI shim: `fn main` paths print errors as strings.
impl From<ConfigError> for String {
    fn from(e: ConfigError) -> String {
        e.to_string()
    }
}

/// Which architecture a [`crate::model::regressor::Regressor`] builds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Architecture {
    /// Logistic regression only (VW-linear class).
    Linear,
    /// LR + FFM (FW-FFM).
    Ffm,
    /// LR + FFM + MLP over MergeNorm (FW-DeepFFM).
    DeepFfm,
}

/// Full model configuration.
#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub arch: Architecture,
    /// Number of FFM fields (namespaces).
    pub fields: usize,
    /// FFM latent dimension K.
    pub latent_dim: usize,
    /// Hashed bucket count (power of two) shared by LR and FFM tables.
    pub buckets: u32,
    /// Hidden layer widths of the neural block (empty = none).
    pub hidden: Vec<usize>,
    /// LR-block learning rate.
    pub lr: f32,
    /// FFM-block learning rate.
    pub ffm_lr: f32,
    /// Neural-block learning rate.
    pub nn_lr: f32,
    /// AdaGrad power_t (0.5 = classic AdaGrad, 0 = plain SGD scaling).
    pub power_t: f32,
    /// L2 regularization (applied to gradients VW-style).
    pub l2: f32,
    /// FFM latent init span: U(-x, x).
    pub init_ffm: f32,
    /// §4.3 — skip zero-global-gradient branches in the neural block.
    pub sparse_updates: bool,
    /// Seed for weight init.
    pub seed: u64,
}

impl ModelConfig {
    pub fn deep_ffm(fields: usize, latent_dim: usize, buckets: u32, hidden: &[usize]) -> Self {
        ModelConfig {
            arch: Architecture::DeepFfm,
            fields,
            latent_dim,
            buckets,
            hidden: hidden.to_vec(),
            ..Self::defaults(fields, latent_dim, buckets)
        }
    }

    pub fn ffm(fields: usize, latent_dim: usize, buckets: u32) -> Self {
        ModelConfig {
            arch: Architecture::Ffm,
            hidden: vec![],
            ..Self::defaults(fields, latent_dim, buckets)
        }
    }

    pub fn linear(fields: usize, buckets: u32) -> Self {
        ModelConfig {
            arch: Architecture::Linear,
            latent_dim: 0,
            hidden: vec![],
            ..Self::defaults(fields, 0, buckets)
        }
    }

    fn defaults(fields: usize, latent_dim: usize, buckets: u32) -> Self {
        assert!(buckets.is_power_of_two(), "buckets must be 2^n");
        ModelConfig {
            arch: Architecture::DeepFfm,
            fields,
            latent_dim,
            buckets,
            hidden: vec![16],
            lr: 0.1,
            ffm_lr: 0.05,
            nn_lr: 0.02,
            power_t: 0.4,
            l2: 0.0,
            init_ffm: 0.1,
            sparse_updates: true,
            seed: 0xf00d,
        }
    }

    /// Number of strict-upper-triangle field pairs P.
    pub fn pairs(&self) -> usize {
        self.fields * (self.fields - 1) / 2
    }

    /// MergeNormLayer width D = 1 + P.
    pub fn merged_dim(&self) -> usize {
        1 + self.pairs()
    }

    /// Sanity-check invariants; returns an explanation on failure.
    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.fields < 1 {
            return Err(ConfigError::Invalid("fields must be >= 1"));
        }
        if !self.buckets.is_power_of_two() {
            return Err(ConfigError::Invalid("buckets must be a power of two"));
        }
        match self.arch {
            Architecture::Linear => {
                if !self.hidden.is_empty() {
                    return Err(ConfigError::Invalid("linear arch cannot have hidden layers"));
                }
            }
            Architecture::Ffm => {
                if self.latent_dim == 0 {
                    return Err(ConfigError::Invalid("ffm arch needs latent_dim > 0"));
                }
                if !self.hidden.is_empty() {
                    return Err(ConfigError::Invalid("ffm arch cannot have hidden layers"));
                }
            }
            Architecture::DeepFfm => {
                if self.latent_dim == 0 {
                    return Err(ConfigError::Invalid("deepffm arch needs latent_dim > 0"));
                }
                if self.hidden.is_empty() {
                    return Err(ConfigError::Invalid("deepffm arch needs >=1 hidden layer"));
                }
                if self.fields < 2 {
                    return Err(ConfigError::Invalid("deepffm needs >=2 fields"));
                }
            }
        }
        if !(0.0..=1.0).contains(&self.power_t) {
            return Err(ConfigError::Invalid("power_t must be in [0,1]"));
        }
        Ok(())
    }
}

/// What admission control does with a request that arrives at a full
/// worker queue (the overload plane's shed policy).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedPolicy {
    /// Refuse the new request (the arriving caller eats the shed).
    RejectNew,
    /// Evict the oldest queued request to admit the new one (the
    /// longest-waiting — and therefore closest-to-deadline — request
    /// eats the shed; freshest traffic keeps flowing).
    DropOldest,
}

impl ShedPolicy {
    pub fn parse(s: &str) -> Result<Self, ConfigError> {
        Ok(match s {
            "reject-new" => ShedPolicy::RejectNew,
            "drop-oldest" => ShedPolicy::DropOldest,
            other => {
                return Err(ConfigError::UnknownValue {
                    what: "shed policy",
                    got: other.to_string(),
                    want: "reject-new|drop-oldest",
                })
            }
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            ShedPolicy::RejectNew => "reject-new",
            ShedPolicy::DropOldest => "drop-oldest",
        }
    }
}

/// Serving-layer configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Worker threads in the inference pool.
    pub workers: usize,
    /// Dynamic batcher: max candidates per batch.
    pub max_batch: usize,
    /// Dynamic batcher: max linger before a partial batch is flushed.
    pub max_wait_us: u64,
    /// Context-cache capacity (entries); 0 disables caching.
    pub context_cache_entries: usize,
    /// Cross-request coalescing: max candidates per kernel pass when a
    /// context group's union slate is scored.  Caps the batch-strided
    /// workspace growth a hot context could otherwise force; oversized
    /// groups are scored in chunks (bit-identical by the kernels'
    /// batch-size-invariance contract).  0 is treated as 1.
    pub max_group_candidates: usize,
    /// Admission control: bounded per-worker queue depth (requests).
    /// A submit against a full queue is shed per [`ShedPolicy`] instead
    /// of blocking the caller.  0 is treated as 1.
    pub queue_depth: usize,
    /// What to shed when a worker queue is full.
    pub shed_policy: ShedPolicy,
    /// Per-request latency SLO in microseconds.  0 disables the
    /// deadline/degraded machinery entirely (legacy behaviour).  When
    /// set: requests are stamped with a deadline at admission, workers
    /// score context groups oldest-deadline-first, fast-fail requests
    /// that expired while queued, and the per-worker
    /// [`crate::serve::overload::OverloadController`] walks the
    /// degradation ladder when the windowed p99 drifts past the SLO.
    pub request_slo_us: u64,
    /// Degraded mode: candidate-slate truncation cap applied while the
    /// overload controller sits at [`crate::serve::overload::DegradeLevel::Truncate`]
    /// or below.  0 is treated as 1 (a slate always keeps its top
    /// candidate).
    pub degraded_max_candidates: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: 4,
            max_batch: 256,
            max_wait_us: 200,
            context_cache_entries: 65_536,
            max_group_candidates: 1024,
            queue_depth: 4096,
            shed_policy: ShedPolicy::RejectNew,
            request_slo_us: 0,
            degraded_max_candidates: 16,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_validate() {
        assert!(ModelConfig::deep_ffm(8, 4, 1 << 10, &[16]).validate().is_ok());
        assert!(ModelConfig::ffm(8, 4, 1 << 10).validate().is_ok());
        assert!(ModelConfig::linear(8, 1 << 10).validate().is_ok());
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut c = ModelConfig::deep_ffm(8, 4, 1 << 10, &[16]);
        c.hidden.clear();
        assert!(c.validate().is_err());

        let mut c = ModelConfig::ffm(8, 4, 1 << 10);
        c.latent_dim = 0;
        assert!(c.validate().is_err());

        let mut c = ModelConfig::linear(8, 1 << 10);
        c.power_t = 2.0;
        assert!(c.validate().is_err());
    }

    #[test]
    #[should_panic]
    fn non_power_of_two_buckets_panic() {
        ModelConfig::linear(4, 1000);
    }

    #[test]
    fn shed_policy_parse_roundtrip() {
        for p in [ShedPolicy::RejectNew, ShedPolicy::DropOldest] {
            assert_eq!(ShedPolicy::parse(p.label()).unwrap(), p);
        }
        assert!(ShedPolicy::parse("drop-newest").is_err());
        // the overload plane is off by default: no SLO, generous queue
        let d = ServeConfig::default();
        assert_eq!(d.request_slo_us, 0);
        assert_eq!(d.shed_policy, ShedPolicy::RejectNew);
        assert!(d.queue_depth >= 1);
    }

    #[test]
    fn derived_dims() {
        let c = ModelConfig::deep_ffm(8, 4, 1 << 10, &[16]);
        assert_eq!(c.pairs(), 28);
        assert_eq!(c.merged_dim(), 29);
    }
}
