//! Figure 6 — speedup from jointly using quantization and model
//! patching (vs patching alone) on the transfer plane (§6).
//!
//! Replays a sequence of online updates through both pipelines and
//! reports per-round bytes-on-wire plus simulated transfer time at a
//! 1 Gbps inter-DC link.  Paper: ~10x smaller updates regularly
//! produced; total time spent patching + quantizing stays within the
//! online window.

use fwumious::config::ModelConfig;
use fwumious::data::synthetic::{DatasetSpec, SyntheticStream};
use fwumious::model::regressor::Regressor;
use fwumious::model::Workspace;
use fwumious::transfer::{SimulatedChannel, UpdateMode, UpdatePipeline};
use fwumious::util::bench_env;
use fwumious::util::json::num;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let spec = DatasetSpec::criteo_like();
    let buckets = 1u32 << 18;
    let cfg = ModelConfig::deep_ffm(spec.fields(), 4, buckets, &[16]);
    let mut reg = Regressor::new(&cfg);
    let mut ws = Workspace::new();
    let mut stream = SyntheticStream::with_buckets(spec, 37, buckets);
    // warm phase
    for _ in 0..120_000 {
        let ex = stream.next_example();
        reg.learn(&ex, &mut ws);
    }
    let raw = fwumious::model::io::to_bytes(&reg, false).len();

    let mut p_only = UpdatePipeline::new(UpdateMode::PatchOnly);
    let mut p_quant = UpdatePipeline::new(UpdateMode::QuantPatch);
    let mut ch_only = SimulatedChannel::new();
    let mut ch_quant = SimulatedChannel::new();

    println!("== Figure 6: patch-only vs patch+quant over online rounds ==");
    println!("raw inference file: {:.1} MB; link: 1 Gbps\n", raw as f64 / 1e6);
    println!(
        "{:<7} {:>12} {:>12} {:>9} {:>11} {:>11}",
        "round", "patch(B)", "q+patch(B)", "ratio", "wire p(s)", "wire qp(s)"
    );
    // Production regime: a 5-minute round touches a small fraction of
    // the weight space (the paper's models are multi-GB).
    let rounds = 10;
    for round in 0..rounds {
        for _ in 0..4_000 {
            let ex = stream.next_example();
            reg.learn(&ex, &mut ws);
        }
        let u1 = p_only.encode(&reg);
        let u2 = p_quant.encode(&reg);
        let t1 = ch_only.ship(&u1);
        let t2 = ch_quant.ship(&u2);
        if round == 0 {
            // bootstrap round ships full files for both
            println!(
                "{:<7} {:>12} {:>12} {:>9} {:>11.4} {:>11.4}   (bootstrap)",
                round,
                u1.bytes.len(),
                u2.bytes.len(),
                "-",
                t1,
                t2
            );
            continue;
        }
        println!(
            "{:<7} {:>12} {:>12} {:>8.1}x {:>11.4} {:>11.4}",
            round,
            u1.bytes.len(),
            u2.bytes.len(),
            u1.bytes.len() as f64 / u2.bytes.len() as f64,
            t1,
            t2
        );
    }
    // ---- mature-model regime: a converged production model's online
    // updates are mostly SMALLER than one quantization bucket, so the
    // quantized file barely changes and the patch collapses — the
    // paper's non-linear "10x smaller updates regularly produced".
    reg.cfg.lr *= 0.02;
    reg.cfg.ffm_lr *= 0.02;
    reg.cfg.nn_lr *= 0.02;
    println!("\n-- mature-model regime (converged weights, small online updates) --");
    println!(
        "{:<7} {:>12} {:>12} {:>9}",
        "round", "patch(B)", "q+patch(B)", "ratio"
    );
    let mut mature_ratio = 0.0;
    for round in 0..5 {
        for _ in 0..4_000 {
            let ex = stream.next_example();
            reg.learn(&ex, &mut ws);
        }
        let u1 = p_only.encode(&reg);
        let u2 = p_quant.encode(&reg);
        ch_only.ship(&u1);
        ch_quant.ship(&u2);
        mature_ratio = u1.bytes.len() as f64 / u2.bytes.len() as f64;
        println!(
            "{:<7} {:>12} {:>12} {:>8.1}x",
            round,
            u1.bytes.len(),
            u2.bytes.len(),
            mature_ratio
        );
    }
    println!("mature-regime compound gain (patch vs quant+patch): {mature_ratio:.1}x");

    println!(
        "\ntotals: patch-only {:.2} MB / {:.2}s wire; quant+patch {:.2} MB / {:.2}s wire",
        ch_only.total_bytes as f64 / 1e6,
        ch_only.total_seconds,
        ch_quant.total_bytes as f64 / 1e6,
        ch_quant.total_seconds
    );
    println!(
        "steady-state bandwidth saving of quantization on top of patching: {:.1}x",
        ch_only.total_bytes as f64 / ch_quant.total_bytes as f64
    );
    let path = bench_env::write_report(
        "fig6_transfer",
        smoke,
        vec![
            ("raw_bytes", num(raw as f64)),
            ("rounds", num(rounds as f64)),
            ("patch_only_total_bytes", num(ch_only.total_bytes as f64)),
            ("quant_patch_total_bytes", num(ch_quant.total_bytes as f64)),
            ("patch_only_wire_seconds", num(ch_only.total_seconds)),
            ("quant_patch_wire_seconds", num(ch_quant.total_seconds)),
            (
                "steady_state_saving",
                num(ch_only.total_bytes as f64 / ch_quant.total_bytes as f64),
            ),
            ("mature_regime_ratio", num(mature_ratio)),
        ],
    );
    println!("report -> {path}");
    println!("paper: ~10x smaller updates regularly produced when combined (non-linear gain).");
}
