//! Shared envelope for `BENCH_*.json` reports.
//!
//! Every bench emits the same outer fields (schema version, bench
//! name, smoke flag, ISA level, thread count, timestamp) so the perf
//! trajectory across PRs is joinable: a downstream consumer can group
//! any two reports by `schema_version` + `isa` + `threads_available`
//! and compare payloads without per-bench parsing logic.

use std::time::{SystemTime, UNIX_EPOCH};

use crate::util::json::{num, s, Json};

/// Bump when envelope fields change shape or meaning.
pub const SCHEMA_VERSION: u64 = 1;

/// Build a full report: envelope fields first, then the bench's own
/// payload pairs. Payload keys must not collide with envelope keys
/// (`schema_version`, `bench`, `smoke`, `isa`, `threads_available`,
/// `unix_time_seconds`) — collisions panic, because a payload silently
/// overwriting the envelope would corrupt cross-PR joins.
pub fn report(bench: &str, smoke: bool, payload: Vec<(&str, Json)>) -> Json {
    const RESERVED: [&str; 6] = [
        "schema_version",
        "bench",
        "smoke",
        "isa",
        "threads_available",
        "unix_time_seconds",
    ];
    for (k, _) in &payload {
        assert!(
            !RESERVED.contains(k),
            "bench payload key '{k}' collides with the envelope"
        );
    }
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let now = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let mut pairs = vec![
        ("schema_version", num(SCHEMA_VERSION as f64)),
        ("bench", s(bench)),
        ("smoke", Json::Bool(smoke)),
        ("isa", s(crate::simd::isa_name())),
        ("threads_available", num(threads as f64)),
        ("unix_time_seconds", num(now as f64)),
    ];
    pairs.extend(payload);
    crate::util::json::obj(pairs)
}

/// Build the report and write it to `BENCH_<bench>.json` in the
/// current directory. Returns the path written.
pub fn write_report(bench: &str, smoke: bool, payload: Vec<(&str, Json)>) -> String {
    let path = format!("BENCH_{bench}.json");
    let doc = report(bench, smoke, payload);
    std::fs::write(&path, doc.to_string()).expect("write bench json");
    path
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    #[test]
    fn envelope_fields_present_and_typed() {
        let doc = report("unit", true, vec![("rounds", num(3.0))]);
        let text = doc.to_string();
        let back = parse(&text).expect("report serializes to valid json");
        assert_eq!(
            back.get("schema_version").as_f64(),
            Some(SCHEMA_VERSION as f64)
        );
        assert_eq!(back.get("bench").as_str(), Some("unit"));
        assert_eq!(back.get("smoke"), &Json::Bool(true));
        assert_eq!(back.get("isa").as_str(), Some(crate::simd::isa_name()));
        assert!(back.get("threads_available").as_f64().unwrap() >= 1.0);
        assert!(back.get("unix_time_seconds").as_f64().unwrap() > 0.0);
        assert_eq!(back.get("rounds").as_f64(), Some(3.0));
    }

    #[test]
    #[should_panic(expected = "collides with the envelope")]
    fn payload_cannot_shadow_envelope() {
        report("unit", false, vec![("isa", s("spoofed"))]);
    }
}
