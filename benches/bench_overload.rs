//! Overload-plane bench: what the serving engine does when offered
//! load exceeds capacity.
//!
//! A plain engine collapses past saturation: queues grow without
//! bound, every request's latency climbs toward the queueing delay,
//! and goodput (requests answered with scores, in SLO) falls as the
//! engine burns kernel time on requests nobody is waiting for
//! anymore.  The overload plane (bounded admission queues + shed
//! policy, deadline fast-fail, degraded-mode slates) is supposed to
//! pin goodput at capacity instead: excess traffic is shed in O(1) at
//! submit or expired before kernel work, and the work that IS done
//! goes to requests still inside their SLO budget.
//!
//! Protocol:
//!
//! 1. **Capacity**: closed-loop run (bounded in-flight window, never
//!    sheds) → requests/sec at saturation.
//! 2. **Open-loop arms** at {0.5, 1, 1.5, 2, 3}× capacity: requests
//!    are submitted on a paced schedule regardless of how the engine
//!    is doing (the open-loop model of real traffic).  Per arm:
//!    goodput, shed rate, expiry rate, served p99, degraded-mode
//!    transitions.
//!
//! Emits `BENCH_overload.json`.  `--smoke` runs a CI-sized variant.
//! After the report is written, arms at ≥2× capacity assert the
//! headline property: goodput within 10% of the best arm's goodput
//! while shed+expired is nonzero — overload degrades the EXCESS, not
//! the engine.

use std::time::{Duration, Instant};

use fwumious::config::{ModelConfig, ServeConfig, ShedPolicy};
use fwumious::model::regressor::Regressor;
use fwumious::serve::router::Router;
use fwumious::serve::server::ServingEngine;
use fwumious::serve::trace::TraceGenerator;
use fwumious::serve::{ModelHandle, Request, ServeError};
use fwumious::util::bench_env;
use fwumious::util::json::{arr, num, obj};

const FIELDS: usize = 6;
const CTX_FIELDS: usize = 3;
const FANOUT: usize = 32;
const WORKERS: usize = 2;
const SLO_US: u64 = 20_000;

fn model() -> Regressor {
    Regressor::new(&ModelConfig::deep_ffm(FIELDS, 4, 1 << 12, &[32]))
}

fn engine(reg: &Regressor) -> ServingEngine {
    let router = Router::new(WORKERS);
    router.register("m", ModelHandle::new(reg.clone()));
    ServingEngine::start(
        router,
        ServeConfig {
            workers: WORKERS,
            max_batch: 128,
            max_wait_us: 200,
            context_cache_entries: 65_536,
            queue_depth: 512,
            shed_policy: ShedPolicy::RejectNew,
            request_slo_us: SLO_US,
            degraded_max_candidates: 8,
            ..ServeConfig::default()
        },
    )
}

fn request_pool(reg: &Regressor, n: usize) -> Vec<Request> {
    let mut gen = TraceGenerator::new(47, FIELDS, CTX_FIELDS, reg.cfg.buckets, FANOUT);
    gen.take(n, "m")
}

/// Closed-loop saturation throughput: a bounded in-flight window keeps
/// every worker busy without ever overflowing the admission queue.
fn measure_capacity(reg: &Regressor, pool: &[Request], requests: usize) -> f64 {
    let eng = engine(reg);
    let t = Instant::now();
    let mut inflight = Vec::with_capacity(256);
    for i in 0..requests {
        inflight.push(eng.submit(pool[i % pool.len()].clone()).expect("closed loop"));
        if inflight.len() >= 256 || i + 1 == requests {
            for rx in inflight.drain(..) {
                rx.recv().unwrap().expect("closed loop never sheds");
            }
        }
    }
    let secs = t.elapsed().as_secs_f64();
    eng.shutdown();
    requests as f64 / secs
}

struct Arm {
    multiplier: f64,
    offered_rps: f64,
    submitted: u64,
    served: u64,
    shed: u64,
    expired: u64,
    goodput_rps: f64,
    p99_us: f64,
    degraded_transitions: u64,
}

/// Open-loop arm: submissions follow a fixed schedule derived from the
/// offered rate; the engine's only defense is the overload plane.
fn run_open_loop(reg: &Regressor, pool: &[Request], offered_rps: f64, secs: f64) -> Arm {
    let eng = engine(reg);
    let n = (offered_rps * secs) as usize;
    let mut rxs = Vec::with_capacity(n);
    let mut shed = 0u64;
    let start = Instant::now();
    for i in 0..n {
        let due = start + Duration::from_secs_f64(i as f64 / offered_rps);
        while Instant::now() < due {
            std::hint::spin_loop();
        }
        match eng.submit(pool[i % pool.len()].clone()) {
            Ok(rx) => rxs.push(rx),
            Err(ServeError::Shed(_)) => shed += 1,
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    let mut served = 0u64;
    let mut expired = 0u64;
    for rx in &rxs {
        match rx.recv().expect("worker replies before shutdown") {
            Ok(_) => served += 1,
            Err(ServeError::Shed(_)) => shed += 1,
            Err(ServeError::DeadlineExpired { .. }) => expired += 1,
            Err(e) => panic!("unexpected reply error: {e}"),
        }
    }
    let total = start.elapsed().as_secs_f64();
    let stats = eng.shutdown();
    assert_eq!(stats.errors, 0);
    let p99_us = stats
        .latency
        .as_ref()
        .map(|h| h.quantile_ns(0.99) / 1e3)
        .unwrap_or(0.0);
    Arm {
        multiplier: 0.0, // caller fills
        offered_rps,
        submitted: n as u64,
        served,
        shed,
        expired,
        goodput_rps: served as f64 / total,
        p99_us,
        degraded_transitions: stats.degraded_transitions,
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    println!(
        "== Overload plane: goodput vs offered load (SIMD {}{}) ==\n",
        fwumious::simd::isa_name(),
        if smoke { ", smoke" } else { "" }
    );
    let reg = model();
    let pool = request_pool(&reg, 1024);
    println!(
        "model: DeepFFM {FIELDS} fields ({CTX_FIELDS} context), fanout {FANOUT}, \
         {WORKERS} workers, SLO {SLO_US}us, queue depth 512, reject-new"
    );

    // warm-up (page weights, size workspaces) then capacity
    measure_capacity(&reg, &pool, 2_000);
    let cap_requests = if smoke { 8_000 } else { 40_000 };
    let capacity = measure_capacity(&reg, &pool, cap_requests);
    println!("closed-loop capacity: {capacity:.0} req/s\n");

    let arm_secs = if smoke { 0.4 } else { 1.5 };
    println!(
        "{:>6} {:>12} {:>10} {:>10} {:>8} {:>8} {:>12} {:>10} {:>8}",
        "mult",
        "offered/s",
        "submitted",
        "goodput/s",
        "shed",
        "expired",
        "shed+exp %",
        "p99 us",
        "trans"
    );
    let mut arms = Vec::new();
    for &mult in &[0.5f64, 1.0, 1.5, 2.0, 3.0] {
        let mut arm = run_open_loop(&reg, &pool, capacity * mult, arm_secs);
        arm.multiplier = mult;
        let lost = arm.shed + arm.expired;
        println!(
            "{:>6.1} {:>12.0} {:>10} {:>10.0} {:>8} {:>8} {:>11.1}% {:>10.1} {:>8}",
            mult,
            arm.offered_rps,
            arm.submitted,
            arm.goodput_rps,
            arm.shed,
            arm.expired,
            lost as f64 * 100.0 / arm.submitted.max(1) as f64,
            arm.p99_us,
            arm.degraded_transitions
        );
        arms.push(arm);
    }

    let peak_goodput = arms.iter().map(|a| a.goodput_rps).fold(0.0, f64::max);
    let path = bench_env::write_report(
        "overload",
        smoke,
        vec![
            ("workers", num(WORKERS as f64)),
            ("fanout", num(FANOUT as f64)),
            ("slo_us", num(SLO_US as f64)),
            ("capacity_rps", num(capacity)),
            ("peak_goodput_rps", num(peak_goodput)),
            (
                "arms",
                arr(arms
                    .iter()
                    .map(|a| {
                        obj(vec![
                            ("multiplier", num(a.multiplier)),
                            ("offered_rps", num(a.offered_rps)),
                            ("submitted", num(a.submitted as f64)),
                            ("served", num(a.served as f64)),
                            ("shed", num(a.shed as f64)),
                            ("expired", num(a.expired as f64)),
                            ("goodput_rps", num(a.goodput_rps)),
                            ("served_p99_us", num(a.p99_us)),
                            ("degraded_transitions", num(a.degraded_transitions as f64)),
                        ])
                    })
                    .collect()),
            ),
        ],
    );
    println!("\nreport -> {path}");

    // The headline property, asserted after the report write so a
    // regression still leaves the numbers on disk: past 2× capacity
    // the engine sheds the excess and holds goodput within 10% of the
    // best arm — no congestion collapse.
    for a in arms.iter().filter(|a| a.multiplier >= 2.0) {
        assert!(
            a.shed + a.expired > 0,
            "{}x capacity shed nothing — admission control is not engaging",
            a.multiplier
        );
        assert!(
            a.goodput_rps >= 0.9 * peak_goodput,
            "goodput collapsed at {}x capacity: {:.0} req/s vs peak {:.0}",
            a.multiplier,
            a.goodput_rps,
            peak_goodput
        );
    }
    println!("goodput held within 10% of peak at >=2x offered load, shedding the excess.");
}
