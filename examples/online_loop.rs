//! The production loop (§3 + §6 live), now driven by the deployment
//! plane subsystem: [`fwumious::deploy::DeploymentLoop`] owns the
//! continuous train → encode → ship → decode → hot-swap rounds while
//! this example keeps request traffic flowing against the serving
//! engine and prints the per-round bandwidth/lag ledger (Table 4 /
//! Figure 6 live).
//!
//! ```bash
//! cargo run --release --example online_loop
//! ```

use fwumious::config::{ModelConfig, ServeConfig};
use fwumious::data::synthetic::DatasetSpec;
use fwumious::deploy::{DeployConfig, DeploymentLoop};
use fwumious::serve::trace::TraceGenerator;
use fwumious::transfer::UpdateMode;

fn main() {
    let spec = DatasetSpec::avazu_like();
    let buckets = 1u32 << 18;
    let model = ModelConfig::deep_ffm(spec.fields(), 4, buckets, &[16]);
    let fields = model.fields;

    let mut cfg = DeployConfig::new(model, spec, UpdateMode::QuantPatch);
    cfg.examples_per_round = 50_000;
    cfg.train_threads = 2;
    cfg.holdout_examples = 5_000;
    cfg.serve = ServeConfig { workers: 4, ..Default::default() };

    let mut dl = DeploymentLoop::new(cfg);
    println!(
        "model: {} weights; serving '{}' on {} workers; wire mode: {}",
        dl.trainer().num_weights(),
        dl.cfg.model_name,
        dl.cfg.serve.workers,
        dl.cfg.mode.label()
    );
    println!(
        "{:<6} {:>10} {:>9} {:>9} {:>10} {:>9} {:>9}",
        "round", "update(B)", "%of raw", "encode", "lag(s)", "serveAUC", "hit%"
    );

    let client = dl.client();
    let mut gen = TraceGenerator::new(3, fields, fields / 2, buckets, 8);
    let rounds = 10;
    for _ in 0..rounds {
        // one online training window + publish + swap
        let r = dl.run_round().expect("round failed");

        // keep serving against the swapped model
        for _ in 0..2_000 {
            let req = gen.next_request(&dl.cfg.model_name);
            client.score(req).expect("score");
        }
        let stats = dl.engine().stats();
        println!(
            "{:<6} {:>10} {:>8.2}% {:>8.0}ms {:>10.4} {:>9.4} {:>8.1}%",
            r.round,
            r.update_bytes,
            r.update_bytes as f64 / r.raw_bytes as f64 * 100.0,
            r.encode_seconds * 1e3,
            r.lag_seconds,
            r.holdout_auc,
            stats.cache_hit_rate() * 100.0
        );
    }

    let metrics = dl.metrics().clone();
    let channel = dl.channel().clone();
    drop(client);
    let stats = dl.shutdown();
    println!(
        "\ntotal shipped: {:.2} MB over {} rounds (raw would be {:.2} MB) — {:.1}x bandwidth saving",
        channel.total_bytes as f64 / 1e6,
        metrics.rounds,
        metrics.raw_bytes_total as f64 / 1e6,
        metrics.bandwidth_saving()
    );
    println!(
        "mean publish lag {:.3}s; served {} requests, {} errors, latency {}",
        metrics.mean_lag_seconds(),
        stats.requests,
        stats.errors,
        stats.latency.map(|l| l.summary()).unwrap_or_default()
    );
}
