//! Small self-contained utilities: PRNG, math, histograms, varints,
//! JSON, timing.  The offline build environment ships no `rand`,
//! `serde` or `criterion`, so these substrates are implemented here.

pub mod histogram;
pub mod json;
pub mod math;
pub mod rng;
pub mod timer;
pub mod varint;
