"""L1 correctness: Pallas FFM-interaction kernel vs the pure-jnp oracle.

This is the core correctness signal for the kernel — hypothesis sweeps
shapes, dtypes, batch tilings and value distributions and asserts
allclose against ``ref.py``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ffm_interaction import (ffm_interaction,
                                             vmem_bytes_per_tile)
from compile.kernels.ref import (ffm_interaction_ref, ffm_scalar_ref,
                                 triu_flatten)


def _rand(key, shape, dtype, scale=1.0):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def _case(b, f, k, seed, dtype=jnp.float32, val_scale=1.0):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    emb = _rand(k1, (b, f, f, k), dtype)
    vals = _rand(k2, (b, f), dtype, scale=val_scale)
    return emb, vals


class TestKernelVsRef:
    @pytest.mark.parametrize("b,f,k", [(1, 2, 1), (4, 4, 2), (8, 8, 4),
                                       (16, 39, 4), (3, 5, 7)])
    def test_matches_ref(self, b, f, k):
        emb, vals = _case(b, f, k, seed=b * 100 + f * 10 + k)
        got = ffm_interaction(emb, vals)
        want = ffm_interaction_ref(emb, vals)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_diag_and_lower_triangle_zero(self):
        emb, vals = _case(4, 6, 3, seed=1)
        out = np.asarray(ffm_interaction(emb, vals))
        for i in range(6):
            for j in range(i + 1):
                assert (out[:, i, j] == 0).all(), (i, j)

    def test_batch_tile_invariance(self):
        emb, vals = _case(16, 5, 3, seed=3)
        full = ffm_interaction(emb, vals, batch_tile=16)
        tiled = ffm_interaction(emb, vals, batch_tile=4)
        single = ffm_interaction(emb, vals, batch_tile=1)
        np.testing.assert_allclose(full, tiled, rtol=1e-6)
        np.testing.assert_allclose(full, single, rtol=1e-6)

    def test_non_divisible_batch_falls_back(self):
        emb, vals = _case(7, 4, 2, seed=5)
        got = ffm_interaction(emb, vals, batch_tile=8)  # 8 does not divide 7
        want = ffm_interaction_ref(emb, vals)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_value_scaling_bilinear(self):
        """out(c*x) == c^2-scaled pairwise: interaction is bilinear in x."""
        emb, vals = _case(2, 4, 2, seed=9)
        base = np.asarray(ffm_interaction(emb, vals))
        scaled = np.asarray(ffm_interaction(emb, vals * 2.0))
        np.testing.assert_allclose(scaled, base * 4.0, rtol=1e-5)

    def test_zero_values_zero_output(self):
        emb, vals = _case(2, 4, 2, seed=10)
        out = ffm_interaction(emb, jnp.zeros_like(vals))
        assert np.abs(np.asarray(out)).max() == 0.0

    def test_symmetric_pair_semantics(self):
        """out[i,j] uses <emb[i,j], emb[j,i]>, not <emb[i,j], emb[i,j]>."""
        b, f, k = 1, 3, 2
        emb = jnp.zeros((b, f, f, k), jnp.float32)
        emb = emb.at[0, 0, 1].set(jnp.array([1.0, 2.0]))
        emb = emb.at[0, 1, 0].set(jnp.array([3.0, 4.0]))
        # the "wrong" orientation — must NOT contribute to out[0,0,1]
        emb = emb.at[0, 0, 2].set(jnp.array([100.0, 100.0]))
        vals = jnp.ones((b, f), jnp.float32)
        out = np.asarray(ffm_interaction(emb, vals))
        np.testing.assert_allclose(out[0, 0, 1], 1 * 3 + 2 * 4, rtol=1e-6)
        np.testing.assert_allclose(out[0, 1, 2], 0.0, atol=1e-7)


@settings(max_examples=40, deadline=None)
@given(
    b=st.integers(1, 12),
    f=st.integers(2, 10),
    k=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
    val_scale=st.sampled_from([0.0, 0.1, 1.0, 10.0]),
)
def test_kernel_matches_ref_hypothesis(b, f, k, seed, val_scale):
    emb, vals = _case(b, f, k, seed=seed, val_scale=val_scale)
    got = ffm_interaction(emb, vals)
    want = ffm_interaction_ref(emb, vals)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(b=st.integers(1, 8), f=st.integers(2, 8), k=st.integers(1, 4),
       seed=st.integers(0, 10**6))
def test_scalar_ffm_equals_masked_sum(b, f, k, seed):
    emb, vals = _case(b, f, k, seed=seed)
    total = ffm_scalar_ref(emb, vals)
    flat = triu_flatten(ffm_interaction(emb, vals))
    np.testing.assert_allclose(np.asarray(flat).sum(axis=1),
                               np.asarray(total), rtol=1e-4, atol=1e-5)


def test_triu_flatten_order():
    """Pair order is part of the cross-layer ABI: row-major upper triangle."""
    f = 4
    mat = jnp.arange(f * f, dtype=jnp.float32).reshape(1, f, f)
    flat = np.asarray(triu_flatten(mat))[0]
    # (0,1)=1 (0,2)=2 (0,3)=3 (1,2)=6 (1,3)=7 (2,3)=11
    np.testing.assert_array_equal(flat, [1, 2, 3, 6, 7, 11])


def test_vmem_estimate_fits_tpu_vmem():
    """Production shape (F=39, K=4, tile=8) must fit well under 16 MB VMEM."""
    assert vmem_bytes_per_tile(39, 4, 8) < 16 * 2**20 // 4
