//! §5 — SIMD-instruction-aware forward pass.
//!
//! "These hardware instruction level optimizations needed to be
//! carefully implemented as the space of serving hardware is not
//! homogeneous, meaning that on-the-fly instruction detection, and
//! subsequent utilization of appropriate binary needed to be put in
//! place."
//!
//! This module implements exactly that: the hot kernels (dot products,
//! axpy, dense matvec, the FFM pairwise inner loop) exist in a scalar
//! form and an AVX2+FMA form, and a process-wide dispatch decision is
//! taken once at startup via `is_x86_feature_detected!`.  Benchmarks
//! (Figure 5) can force the scalar path through [`force_scalar`].

pub mod batch;
pub mod dot;

use std::sync::atomic::{AtomicU8, Ordering};

/// Selected instruction set.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IsaLevel {
    Scalar = 0,
    Avx2Fma = 1,
}

const UNSET: u8 = u8::MAX;
static FORCED: AtomicU8 = AtomicU8::new(UNSET);
static RESOLVED: AtomicU8 = AtomicU8::new(UNSET);

/// Detect the best ISA available on this machine (honouring any
/// force).  The CPUID probe runs once; afterwards this is a single
/// relaxed atomic load — cheap enough for per-kernel dispatch.
#[inline]
pub fn isa_level() -> IsaLevel {
    // ordering: Relaxed throughout — both cells hold a self-contained
    // one-byte dispatch decision; no other data is published through
    // them.  Racing threads may each run the idempotent CPUID probe
    // once, converging on the same value.
    match FORCED.load(Ordering::Relaxed) {
        0 => return IsaLevel::Scalar,
        1 => return IsaLevel::Avx2Fma,
        _ => {}
    }
    // ordering: Relaxed — see above.
    let r = RESOLVED.load(Ordering::Relaxed);
    if r != UNSET {
        return if r == 1 { IsaLevel::Avx2Fma } else { IsaLevel::Scalar };
    }
    let d = detect();
    // ordering: Relaxed — see above.
    RESOLVED.store(d as u8, Ordering::Relaxed);
    d
}

fn detect() -> IsaLevel {
    // Miri has no CPUID and cannot execute vendor intrinsics — the
    // scalar kernels are the only sound path under the interpreter, so
    // the probe is compiled out entirely there.
    #[cfg(all(target_arch = "x86_64", not(miri)))]
    {
        if std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
        {
            return IsaLevel::Avx2Fma;
        }
    }
    IsaLevel::Scalar
}

/// Force a specific ISA level (Figure 5's SIMD-disabled control runs).
///
/// This mutates a process-wide atomic and never restores it: reserve it
/// for process-scoped decisions (the `fw --scalar` CLI flag).  Tests
/// and benches must use [`ForcedIsaGuard`] instead, which restores the
/// prior forced state on drop.
pub fn force_scalar(on: bool) {
    let v = if on { IsaLevel::Scalar as u8 } else { UNSET };
    // ordering: Relaxed — self-contained dispatch byte, see
    // `isa_level`.
    FORCED.store(v, Ordering::Relaxed);
}

/// Scoped ISA forcing: forces the scalar kernels on construction and
/// restores the *previous* forced state — including "unforced" — when
/// dropped, LIFO-nestable.
///
/// [`force_scalar`] leaves the process-wide dispatch atomic mutated
/// forever; a test that forced scalar and forgot (or panicked before)
/// the restore silently poisoned every concurrently-running
/// `cargo test` thread onto the scalar path.  The guard bounds the
/// mutation to a scope — though while it lives, *other* threads still
/// observe the forced level (the dispatch decision is inherently
/// process-global), so equality tests comparing forced-scalar against
/// SIMD results should call concrete kernels directly where bit-exact
/// dispatch matters.
pub struct ForcedIsaGuard {
    prev: u8,
}

impl std::fmt::Debug for ForcedIsaGuard {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ForcedIsaGuard").finish_non_exhaustive()
    }
}

impl ForcedIsaGuard {
    /// Force the scalar kernels until the guard drops (Figure 5's
    /// SIMD-disabled control arm).
    pub fn scalar() -> Self {
        ForcedIsaGuard {
            // ordering: Relaxed — self-contained dispatch byte, see
            // `isa_level`; the swap makes force+remember one atomic
            // step so LIFO-nested guards restore correctly.
            prev: FORCED.swap(IsaLevel::Scalar as u8, Ordering::Relaxed),
        }
    }
}

impl Drop for ForcedIsaGuard {
    fn drop(&mut self) {
        // ordering: Relaxed — self-contained dispatch byte, see
        // `isa_level`.
        FORCED.store(self.prev, Ordering::Relaxed);
    }
}

/// True when the AVX2+FMA path is live.
pub fn simd_active() -> bool {
    isa_level() == IsaLevel::Avx2Fma
}

/// Human-readable description for logs/metrics.
pub fn isa_name() -> &'static str {
    match isa_level() {
        IsaLevel::Scalar => "scalar",
        IsaLevel::Avx2Fma => "avx2+fma",
    }
}

/// Serializes tests that mutate the process-wide `FORCED` atomic: the
/// dispatch decision is global, so forcing tests running on parallel
/// `cargo test` threads would otherwise observe each other's state.
#[cfg(test)]
pub(crate) fn forcing_test_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn force_scalar_round_trip() {
        let _serial = forcing_test_lock();
        force_scalar(true);
        assert_eq!(isa_level(), IsaLevel::Scalar);
        force_scalar(false);
        let _ = isa_level(); // whatever the host supports
    }

    #[test]
    fn forced_isa_guard_restores_prior_state() {
        let _serial = forcing_test_lock();
        // nested guards restore LIFO; the outer restore re-establishes
        // whatever was forced before the guards existed
        let outer_forced = FORCED.load(Ordering::Relaxed);
        {
            let _g1 = ForcedIsaGuard::scalar();
            assert_eq!(isa_level(), IsaLevel::Scalar);
            {
                let _g2 = ForcedIsaGuard::scalar();
                assert_eq!(isa_level(), IsaLevel::Scalar);
            }
            // inner drop restored g1's forcing, not "unforced"
            assert_eq!(FORCED.load(Ordering::Relaxed), IsaLevel::Scalar as u8);
        }
        assert_eq!(FORCED.load(Ordering::Relaxed), outer_forced);
    }

    #[test]
    fn forced_isa_guard_restores_on_panic() {
        let _serial = forcing_test_lock();
        let before = FORCED.load(Ordering::Relaxed);
        let result = std::panic::catch_unwind(|| {
            let _g = ForcedIsaGuard::scalar();
            panic!("unwinding must not leak the forced level");
        });
        assert!(result.is_err());
        assert_eq!(FORCED.load(Ordering::Relaxed), before);
    }

    #[test]
    fn isa_name_nonempty() {
        assert!(!isa_name().is_empty());
    }
}
