//! §4.1 — asynchronous data prefetching for model warm-up.
//!
//! "By implementing async learning cycles, multiple rounds of 'future'
//! data can be downloaded upfront, making sure the learning engine has
//! constant influx of data.  Data pre-fetch in practice results in up
//! to 4x faster pre-warming."
//!
//! A background thread pulls chunks from the wrapped [`DataSource`]
//! into a bounded queue (`std::sync::mpsc::sync_channel`), so chunk
//! production (downloading / parsing / generation) overlaps with the
//! learner consuming previous chunks.  `depth` bounds the number of
//! in-flight chunks — the paper's "multiple rounds of future data".

use std::sync::mpsc::{sync_channel, Receiver};
use std::thread::JoinHandle;

use crate::data::DataSource;
use crate::feature::Example;

/// A chunk of prefetched examples.
pub type Chunk = Vec<Example>;

/// Background prefetcher over any [`DataSource`].
pub struct Prefetcher {
    rx: Receiver<Chunk>,
    handle: Option<JoinHandle<()>>,
}

impl std::fmt::Debug for Prefetcher {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Prefetcher").finish_non_exhaustive()
    }
}

impl Prefetcher {
    /// Spawn the producer thread.
    ///
    /// * `chunk_size` — examples per chunk.
    /// * `depth` — max queued chunks (back-pressure bound).
    /// * `limit` — total examples to produce (None = until exhausted).
    pub fn spawn<S: DataSource + 'static>(
        mut source: S,
        chunk_size: usize,
        depth: usize,
        limit: Option<usize>,
    ) -> Self {
        let (tx, rx) = sync_channel::<Chunk>(depth.max(1));
        let handle = std::thread::Builder::new()
            .name("fw-prefetch".into())
            .spawn(move || {
                let mut remaining = limit.unwrap_or(usize::MAX);
                while remaining > 0 {
                    let want = chunk_size.min(remaining);
                    let mut chunk = Vec::with_capacity(want);
                    let got = source.next_chunk(want, &mut chunk);
                    if got == 0 {
                        break;
                    }
                    remaining -= got;
                    if tx.send(chunk).is_err() {
                        break; // consumer dropped
                    }
                }
            })
            .expect("spawn prefetch thread");
        Prefetcher { rx, handle: Some(handle) }
    }

    /// Blocking pull of the next chunk; `None` when the stream ends.
    pub fn next_chunk(&mut self) -> Option<Chunk> {
        self.rx.recv().ok()
    }

    /// Iterate over all chunks.
    pub fn chunks(&mut self) -> impl Iterator<Item = Chunk> + '_ {
        std::iter::from_fn(move || self.next_chunk())
    }
}

impl Drop for Prefetcher {
    fn drop(&mut self) {
        // Draining the receiver unblocks a producer stuck on send.
        while self.rx.try_recv().is_ok() {}
        drop(std::mem::replace(&mut self.rx, {
            let (_tx, rx) = sync_channel(1);
            rx
        }));
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// A [`DataSource`] with a configurable per-chunk production delay —
/// models the "download" cost that prefetching hides.  Used by
/// `bench_table2_hogwild` and the warm-up tests.
pub struct DelayedSource<S: DataSource> {
    inner: S,
    delay: std::time::Duration,
}

impl<S: DataSource> std::fmt::Debug for DelayedSource<S> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DelayedSource").finish_non_exhaustive()
    }
}

impl<S: DataSource> DelayedSource<S> {
    pub fn new(inner: S, delay: std::time::Duration) -> Self {
        DelayedSource { inner, delay }
    }
}

impl<S: DataSource> DataSource for DelayedSource<S> {
    fn next_chunk(&mut self, n: usize, out: &mut Vec<Example>) -> usize {
        std::thread::sleep(self.delay);
        self.inner.next_chunk(n, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{DatasetSpec, SyntheticStream};
    use crate::data::IterSource;
    use std::time::Duration;

    #[test]
    fn delivers_all_examples_in_order_of_chunks() {
        let src = SyntheticStream::new(DatasetSpec::tiny(), 3);
        let mut pf = Prefetcher::spawn(src, 100, 4, Some(1000));
        let total: usize = pf.chunks().map(|c| c.len()).sum();
        assert_eq!(total, 1000);
    }

    #[test]
    fn respects_limit_and_chunk_size() {
        let src = SyntheticStream::new(DatasetSpec::tiny(), 4);
        let mut pf = Prefetcher::spawn(src, 64, 2, Some(130));
        let sizes: Vec<usize> = pf.chunks().map(|c| c.len()).collect();
        assert_eq!(sizes, vec![64, 64, 2]);
    }

    #[test]
    fn finite_source_terminates() {
        let exs: Vec<_> =
            (0..10).map(|_| crate::feature::Example::empty(2)).collect();
        let mut pf =
            Prefetcher::spawn(IterSource::new(exs.into_iter()), 4, 2, None);
        let total: usize = pf.chunks().map(|c| c.len()).sum();
        assert_eq!(total, 10);
    }

    #[test]
    fn prefetch_overlaps_slow_production() {
        // With production delay D per chunk and consumption delay C,
        // prefetching should bring total wall time near max-side rather
        // than the sum. Generous bounds keep this robust on CI.
        let delay = Duration::from_millis(5);
        let chunks = 8;
        let make = || {
            DelayedSource::new(
                SyntheticStream::new(DatasetSpec::tiny(), 5),
                delay,
            )
        };
        // Sequential: produce then consume.
        let t0 = std::time::Instant::now();
        let mut src = make();
        let mut buf = Vec::new();
        for _ in 0..chunks {
            src.next_chunk(10, &mut buf);
            std::thread::sleep(delay); // consume
        }
        let seq = t0.elapsed();

        // Prefetched: producer thread runs ahead.
        let t0 = std::time::Instant::now();
        let mut pf = Prefetcher::spawn(make(), 10, 4, Some(80));
        while let Some(_c) = pf.next_chunk() {
            std::thread::sleep(delay); // consume
        }
        let pre = t0.elapsed();
        assert!(
            pre < seq,
            "prefetch {pre:?} not faster than sequential {seq:?}"
        );
    }

    #[test]
    fn drop_mid_stream_does_not_hang() {
        let src = SyntheticStream::new(DatasetSpec::tiny(), 6);
        let mut pf = Prefetcher::spawn(src, 100, 2, Some(1_000_000));
        let _ = pf.next_chunk();
        drop(pf); // must join cleanly
    }
}
