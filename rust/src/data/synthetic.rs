//! Synthetic CTR stream generators.
//!
//! Substitutes for the paper's benchmark datasets (Criteo, Avazu,
//! KDD2012) — see DESIGN.md §3.  Each generator reproduces the
//! properties that drive the paper's *relative* results:
//!
//! * field structure (continuous + categorical namespaces),
//! * heavy-tailed categorical value distributions (Zipf),
//! * a nonlinear ground truth with genuine field interactions (so FFMs
//!   beat linear models once enough data is seen),
//! * temporal drift (ground-truth random walk) and **OOD windows**
//!   (distribution shifts producing the light-gray out-of-distribution
//!   regions in Figure 3),
//! * label noise bounding the achievable AUC.
//!
//! Labels depend only on raw (field, id) pairs — never on the hashed
//! bucket — so the same stream can be consumed at any bucket size.

use crate::feature::hash;
use crate::feature::{Example, FeatureSlot};
use crate::util::rng::{Pcg32, Zipf};

/// Dataset shape description.
#[derive(Clone, Debug)]
pub struct DatasetSpec {
    pub name: String,
    /// Continuous fields (log-transformed values).
    pub cont_fields: usize,
    /// Categorical fields.
    pub cat_fields: usize,
    /// Cardinality of each categorical field's vocabulary.
    pub cardinality: u64,
    /// Zipf exponent for value popularity.
    pub zipf_s: f64,
    /// Base click-through rate.
    pub base_ctr: f64,
    /// Std-dev of the per-step ground-truth random walk (drift).
    pub drift: f64,
    /// Every `ood_every` examples, an OOD window of `ood_len` examples
    /// shifts the id distribution (0 disables).
    pub ood_every: usize,
    pub ood_len: usize,
    /// Strength of pairwise (field-interaction) effects vs linear ones.
    pub interaction_scale: f32,
    /// Label noise: fraction of labels flipped.
    pub noise: f64,
}

impl DatasetSpec {
    /// Criteo-like: 13 continuous + 26 categorical, strong interactions.
    pub fn criteo_like() -> Self {
        DatasetSpec {
            name: "criteo-like".into(),
            cont_fields: 3,
            cat_fields: 10,
            cardinality: 50_000,
            zipf_s: 1.2,
            base_ctr: 0.26,
            drift: 0.002,
            ood_every: 120_000,
            ood_len: 12_000,
            interaction_scale: 1.0,
            noise: 0.02,
        }
    }

    /// Avazu-like: all-categorical, fewer fields, higher skew.
    pub fn avazu_like() -> Self {
        DatasetSpec {
            name: "avazu-like".into(),
            cont_fields: 0,
            cat_fields: 12,
            cardinality: 200_000,
            zipf_s: 1.35,
            base_ctr: 0.17,
            drift: 0.004,
            ood_every: 90_000,
            ood_len: 15_000,
            interaction_scale: 0.8,
            noise: 0.03,
        }
    }

    /// KDD2012-like: many fields, very skewed, low CTR, strong drift —
    /// the paper notes "apparent variability" in this data.
    pub fn kdd_like() -> Self {
        DatasetSpec {
            name: "kdd2012-like".into(),
            cont_fields: 2,
            cat_fields: 9,
            cardinality: 500_000,
            zipf_s: 1.5,
            base_ctr: 0.044,
            drift: 0.008,
            ood_every: 60_000,
            ood_len: 20_000,
            interaction_scale: 1.2,
            noise: 0.04,
        }
    }

    /// Tiny spec for unit tests.
    pub fn tiny() -> Self {
        DatasetSpec {
            name: "tiny".into(),
            cont_fields: 1,
            cat_fields: 3,
            cardinality: 100,
            zipf_s: 1.1,
            base_ctr: 0.3,
            drift: 0.0,
            ood_every: 0,
            ood_len: 0,
            interaction_scale: 1.0,
            noise: 0.0,
        }
    }

    pub fn fields(&self) -> usize {
        self.cont_fields + self.cat_fields
    }
}

/// Deterministic pseudo-random ground-truth weight for a (salt, key)
/// pair, uniform in [-scale, scale].  Hash-derived: no table storage,
/// unbounded vocabulary.
#[inline]
fn gt_weight(salt: u32, key: u64, scale: f32) -> f32 {
    let h = hash::murmur3_32(&key.to_le_bytes(), salt);
    (h as f32 / u32::MAX as f32 * 2.0 - 1.0) * scale
}

/// The synthetic stream: an infinite iterator of hashed [`Example`]s.
pub struct SyntheticStream {
    pub spec: DatasetSpec,
    rng: Pcg32,
    zipf: Zipf,
    mask: u32,
    step: usize,
    /// Ground-truth global bias random walk (drift).
    bias_walk: f64,
    /// Interacting field pairs of the ground truth.
    gt_pairs: Vec<(u16, u16)>,
    logit_offset: f64,
}

impl std::fmt::Debug for SyntheticStream {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SyntheticStream").finish_non_exhaustive()
    }
}

impl SyntheticStream {
    /// Default bucket space 2^18 (the paper's hashed weight spaces are
    /// fixed-size power-of-two arrays).
    pub fn new(spec: DatasetSpec, seed: u64) -> Self {
        Self::with_buckets(spec, seed, 1 << 18)
    }

    pub fn criteo_like(seed: u64) -> Self {
        Self::new(DatasetSpec::criteo_like(), seed)
    }

    pub fn avazu_like(seed: u64) -> Self {
        Self::new(DatasetSpec::avazu_like(), seed)
    }

    pub fn kdd_like(seed: u64) -> Self {
        Self::new(DatasetSpec::kdd_like(), seed)
    }

    pub fn with_buckets(spec: DatasetSpec, seed: u64, buckets: u32) -> Self {
        assert!(buckets.is_power_of_two());
        let rng = Pcg32::new(seed, 0xda7a);
        let f = spec.fields() as u16;
        // A fixed random subset of field pairs carries interactions.
        // IMPORTANT: the ground truth is a property of the DATASET, not
        // of the stream seed — derive it from the spec name so two
        // streams with different seeds sample the same task (train and
        // held-out splits must agree on what is being learned).
        let mut gt_rng = Pcg32::new(
            hash::murmur3_32(spec.name.as_bytes(), 0x6707) as u64,
            0x6707,
        );
        let mut gt_pairs = Vec::new();
        for i in 0..f {
            for j in (i + 1)..f {
                if gt_rng.coin(0.35) {
                    gt_pairs.push((i, j));
                }
            }
        }
        if gt_pairs.is_empty() && f >= 2 {
            gt_pairs.push((0, 1));
        }
        // Calibrate the logit offset to hit base_ctr: the realized
        // ground-truth weights carry a dataset-specific mean effect, so
        // probe it on a throwaway stream and solve for the offset.
        let zipf = Zipf::new(spec.cardinality, spec.zipf_s);
        let mut stream = SyntheticStream {
            spec,
            rng,
            zipf,
            mask: buckets - 1,
            step: 0,
            bias_walk: 0.0,
            gt_pairs,
            logit_offset: 0.0,
        };
        let probes = 2000;
        let mut effects = Vec::with_capacity(probes);
        {
            let mut probe = SyntheticStream {
                spec: stream.spec.clone(),
                rng: Pcg32::new(seed ^ 0xca1b, 0xca1b),
                zipf: Zipf::new(stream.spec.cardinality, stream.spec.zipf_s),
                mask: stream.mask,
                step: 0,
                bias_walk: 0.0,
                gt_pairs: stream.gt_pairs.clone(),
                logit_offset: 0.0,
            };
            // disable drift/noise/OOD during probing
            probe.spec.drift = 0.0;
            probe.spec.noise = 0.0;
            probe.spec.ood_every = 0;
            for _ in 0..probes {
                let (_ex, raw) = probe.gen_with_logit();
                effects.push(raw);
            }
        }
        // Solve E[sigmoid(offset + effect)] == base_ctr by bisection —
        // a plain mean-shift undershoots because sigmoid of a wide
        // logit distribution regresses toward 0.5 (Jensen).
        let target = stream.spec.base_ctr;
        let mean_p = |off: f64| -> f64 {
            effects
                .iter()
                .map(|e| 1.0 / (1.0 + (-(off + e)).exp()))
                .sum::<f64>()
                / effects.len() as f64
        };
        let (mut lo, mut hi) = (-20.0f64, 20.0f64);
        for _ in 0..60 {
            let mid = 0.5 * (lo + hi);
            if mean_p(mid) < target {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        stream.logit_offset = 0.5 * (lo + hi);
        stream
    }

    /// Whether the current step sits inside an OOD window.
    pub fn in_ood_window(&self) -> bool {
        self.spec.ood_every > 0
            && (self.step % self.spec.ood_every) < self.spec.ood_len
    }

    /// Generate the next example.
    pub fn next_example(&mut self) -> Example {
        self.gen_with_logit().0
    }

    /// Generate the next example, also returning the raw ground-truth
    /// feature effect (logit minus offset/drift) for calibration.
    fn gen_with_logit(&mut self) -> (Example, f64) {
        let f = self.spec.fields();
        let ood = self.in_ood_window();
        // OOD windows remap ids: the serving distribution shifts while
        // the ground truth stays put — exactly what stresses stability.
        let ood_salt: u64 = if ood {
            0x00d_u64 ^ (((self.step / self.spec.ood_every.max(1)) as u64) << 32)
        } else {
            0
        };

        let mut ids = Vec::with_capacity(f);
        let mut vals = Vec::with_capacity(f);
        // Continuous fields: log-normal-ish positive values, id fixed
        // per field (a continuous feature is one weight, scaled).
        for _ in 0..self.spec.cont_fields {
            let raw = (self.rng.normal() * 0.8).exp(); // lognormal
            ids.push(1u64); // single token per continuous field
            vals.push((1.0 + raw).ln()); // the paper's log transform
        }
        // Categorical fields: Zipf-distributed ids.
        for _ in 0..self.spec.cat_fields {
            let mut id = self.zipf.sample(&mut self.rng);
            if ood {
                id = id.wrapping_add(ood_salt % self.spec.cardinality);
            }
            ids.push(id);
            vals.push(1.0);
        }

        // Ground-truth logit.
        let mut effect = 0.0f64;
        for (fi, (&id, &v)) in ids.iter().zip(&vals).enumerate() {
            let key = (fi as u64) << 48 | id;
            effect += (gt_weight(0x11ea5, key, 0.8) * v) as f64;
        }
        for &(a, b) in &self.gt_pairs {
            let key = (ids[a as usize] << 20) ^ ids[b as usize] ^ ((a as u64) << 56) ^ ((b as u64) << 48);
            effect += (gt_weight(0x9a115, key, self.spec.interaction_scale)
                * vals[a as usize]
                * vals[b as usize]) as f64;
        }
        let logit = self.logit_offset + self.bias_walk + effect;
        let p = 1.0 / (1.0 + (-logit).exp());
        let mut label = if self.rng.coin(p) { 1.0 } else { 0.0 };
        if self.spec.noise > 0.0 && self.rng.coin(self.spec.noise) {
            label = 1.0 - label;
        }

        // Drift: ground truth random-walks over time.
        self.bias_walk += self.rng.normal() as f64 * self.spec.drift;
        self.step += 1;

        // Hash into the bucket space.
        let slots = ids
            .iter()
            .zip(&vals)
            .enumerate()
            .map(|(fi, (&id, &v))| FeatureSlot {
                field: fi as u16,
                bucket: hash::id_bucket(fi as u32 + 1, id, self.mask),
                value: v,
            })
            .collect();
        (Example { label, importance: 1.0, slots }, effect)
    }

    /// Take `n` examples into a vector.
    pub fn take_examples(&mut self, n: usize) -> Vec<Example> {
        (0..n).map(|_| self.next_example()).collect()
    }
}

impl Iterator for SyntheticStream {
    type Item = Example;

    fn next(&mut self) -> Option<Example> {
        Some(self.next_example())
    }
}

impl crate::data::DataSource for SyntheticStream {
    fn next_chunk(&mut self, n: usize, out: &mut Vec<Example>) -> usize {
        for _ in 0..n {
            let ex = self.next_example();
            out.push(ex);
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = SyntheticStream::new(DatasetSpec::tiny(), 5);
        let mut b = SyntheticStream::new(DatasetSpec::tiny(), 5);
        for _ in 0..200 {
            assert_eq!(a.next_example(), b.next_example());
        }
    }

    #[test]
    fn seeds_change_stream() {
        let mut a = SyntheticStream::new(DatasetSpec::tiny(), 5);
        let mut b = SyntheticStream::new(DatasetSpec::tiny(), 6);
        let same = (0..100)
            .filter(|_| a.next_example().label == b.next_example().label)
            .count();
        assert!(same < 95);
    }

    #[test]
    fn base_rate_roughly_matches() {
        // Tight check with drift/noise/OOD disabled (pure calibration)…
        for mut spec in [DatasetSpec::criteo_like(), DatasetSpec::avazu_like()] {
            spec.drift = 0.0;
            spec.noise = 0.0;
            spec.ood_every = 0;
            let target = spec.base_ctr;
            let mut s = SyntheticStream::new(spec, 7);
            let n = 20_000;
            let pos: f64 = (0..n)
                .map(|_| s.next_example().label as f64)
                .sum::<f64>()
                / n as f64;
            assert!((pos - target).abs() < 0.04, "ctr={pos} target={target}");
        }
        // …loose check with the full nonstationary machinery on (the
        // drift random walk legitimately moves the realized CTR).
        let spec = DatasetSpec::avazu_like();
        let target = spec.base_ctr;
        let mut s = SyntheticStream::new(spec, 7);
        let pos: f64 = (0..20_000)
            .map(|_| s.next_example().label as f64)
            .sum::<f64>()
            / 20_000.0;
        assert!((pos - target).abs() < 0.2, "drifted ctr={pos}");
    }

    #[test]
    fn labels_are_learnable_not_random() {
        // A feature-conditional CTR must differ measurably from the
        // marginal for popular ids — otherwise no model could learn.
        let mut s = SyntheticStream::new(DatasetSpec::tiny(), 9);
        let mut by_bucket: std::collections::HashMap<u32, (f64, f64)> =
            Default::default();
        for _ in 0..30_000 {
            let ex = s.next_example();
            let e = by_bucket.entry(ex.slots[1].bucket).or_insert((0.0, 0.0));
            e.0 += ex.label as f64;
            e.1 += 1.0;
        }
        let rates: Vec<f64> = by_bucket
            .values()
            .filter(|(_, n)| *n > 300.0)
            .map(|(s, n)| s / n)
            .collect();
        assert!(rates.len() >= 3);
        let spread = rates.iter().cloned().fold(f64::MIN, f64::max)
            - rates.iter().cloned().fold(f64::MAX, f64::min);
        assert!(spread > 0.05, "spread={spread}");
    }

    #[test]
    fn field_count_and_hashing() {
        let spec = DatasetSpec::criteo_like();
        let f = spec.fields();
        let mut s = SyntheticStream::with_buckets(spec, 3, 1 << 12);
        let ex = s.next_example();
        assert_eq!(ex.fields(), f);
        assert!(ex.slots.iter().all(|sl| sl.bucket < (1 << 12)));
        // continuous fields carry log-transformed values
        assert!(ex.slots[0].value > 0.0);
    }

    #[test]
    fn ood_windows_fire() {
        let mut spec = DatasetSpec::tiny();
        spec.ood_every = 100;
        spec.ood_len = 10;
        let mut s = SyntheticStream::new(spec, 11);
        let mut flags = Vec::new();
        for _ in 0..250 {
            flags.push(s.in_ood_window());
            s.next_example();
        }
        assert!(flags[..10].iter().all(|&x| x));
        assert!(!flags[50]);
        assert!(flags[105]);
    }

    #[test]
    fn iterator_and_source_impls() {
        use crate::data::DataSource;
        let mut s = SyntheticStream::new(DatasetSpec::tiny(), 2);
        assert!(s.next().is_some());
        let mut buf = Vec::new();
        assert_eq!(s.next_chunk(32, &mut buf), 32);
        assert_eq!(buf.len(), 32);
    }
}
