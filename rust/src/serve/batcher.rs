//! Dynamic candidate batching.
//!
//! Requests queue until either `max_batch` candidates have accumulated
//! or the oldest queued request has lingered `max_wait`; then the batch
//! flushes to a scoring worker.  Small linger bounds tail latency while
//! batching amortizes per-request overhead — the standard serving
//! trade-off (vLLM-router-style).

use std::collections::HashMap;
use std::time::{Duration, Instant};

use crate::serve::context_cache::context_key;
use crate::serve::Request;

/// A flushed batch of requests.
///
/// `items` is **grouping-stable**: requests keep their arrival order
/// across the flush, so [`context_groups`] over a batch's contents
/// yields the same group memberships (and the same member order inside
/// each group) every time it is computed.
#[derive(Debug)]
pub struct Batch<T> {
    pub items: Vec<(Request, T)>,
    /// Total candidates across the batch.
    pub candidates: usize,
    /// Why the batch flushed (observability / tests).
    pub reason: FlushReason,
}

/// One same-context group within a flushed batch: the requests that
/// share a (model, context) pair and can therefore be scored against
/// one cached [`crate::model::regressor::ContextPartial`] in one
/// union-slate kernel pass.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ContextGroup {
    /// Indices into the flushed batch's `items`, in arrival order.
    pub members: Vec<usize>,
    /// Total candidates across the members.
    pub candidates: usize,
}

/// Group requests by (model, context) in first-seen order.
///
/// Keys are the exact [`context_key`] bytes the context cache uses
/// (version pinned to 0 — the scorer resolves each group's model ONCE,
/// so every member is scored against the same weight version and the
/// version cannot split a group).  Exact byte keys mean no hash-
/// collision risk: two requests land in one group iff their model name
/// and every (bucket, value-bits) pair agree.
pub fn context_groups<'a, I>(reqs: I) -> Vec<ContextGroup>
where
    I: IntoIterator<Item = &'a Request>,
{
    let mut groups: Vec<ContextGroup> = Vec::new();
    let mut index: HashMap<Vec<u8>, usize> = HashMap::new();
    let mut key = Vec::new();
    for (i, req) in reqs.into_iter().enumerate() {
        context_key(&mut key, &req.model, 0, &req.context);
        match index.get(&key) {
            Some(&g) => {
                groups[g].members.push(i);
                groups[g].candidates += req.candidates.len();
            }
            None => {
                index.insert(key.clone(), groups.len());
                groups.push(ContextGroup {
                    members: vec![i],
                    candidates: req.candidates.len(),
                });
            }
        }
    }
    groups
}

/// Compact, stable hash of a request's context-group key — FNV-1a over
/// the exact [`context_key`] bytes [`context_groups`] groups on
/// (version pinned to 0, same as grouping).  Trace events carry this
/// instead of the raw key so coalesced requests are correlatable in
/// logs without dumping feature bytes.
pub fn group_key_hash(model: &str, context: &[crate::feature::FeatureSlot]) -> u64 {
    let mut key = Vec::new();
    context_key(&mut key, model, 0, context);
    crate::obs::trace::fnv1a64(&key)
}

impl<T> Batch<T> {
    /// Same-context groups of this batch's requests, first-seen order —
    /// the group metadata a scorer plans kernel passes from.  (The
    /// engine's hot path unzips `items` and calls the free
    /// [`context_groups`] on the request slice directly; this method is
    /// the same computation for callers still holding the batch.)
    pub fn context_groups(&self) -> Vec<ContextGroup> {
        context_groups(self.items.iter().map(|(r, _)| r))
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlushReason {
    /// Candidate budget reached.
    Full,
    /// Oldest request exceeded the linger deadline.
    Deadline,
    /// Explicit drain (shutdown).
    Drain,
}

/// Accumulates requests into batches.  `T` is an opaque per-request
/// tag (the server threads use reply channels).
pub struct DynamicBatcher<T> {
    pub max_batch: usize,
    pub max_wait: Duration,
    queue: Vec<(Request, T)>,
    queued_candidates: usize,
    oldest: Option<Instant>,
}

impl<T> std::fmt::Debug for DynamicBatcher<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DynamicBatcher").finish_non_exhaustive()
    }
}

impl<T> DynamicBatcher<T> {
    pub fn new(max_batch: usize, max_wait: Duration) -> Self {
        DynamicBatcher {
            max_batch: max_batch.max(1),
            max_wait,
            queue: Vec::new(),
            queued_candidates: 0,
            oldest: None,
        }
    }

    pub fn queued_requests(&self) -> usize {
        self.queue.len()
    }

    pub fn queued_candidates(&self) -> usize {
        self.queued_candidates
    }

    /// Enqueue a request; returns a batch if the push filled it.
    pub fn push(&mut self, req: Request, tag: T) -> Option<Batch<T>> {
        self.push_at(req, tag, Instant::now())
    }

    /// [`push`](Self::push) with an injected arrival time.  The server
    /// threads pass `Instant::now()`; deterministic tests inject a
    /// synthetic clock so deadline behaviour needs no real sleeping.
    pub fn push_at(&mut self, req: Request, tag: T, now: Instant) -> Option<Batch<T>> {
        self.queued_candidates += req.candidates.len();
        if self.queue.is_empty() {
            self.oldest = Some(now);
        }
        self.queue.push((req, tag));
        if self.queued_candidates >= self.max_batch {
            return Some(self.flush(FlushReason::Full));
        }
        None
    }

    /// Time left until the deadline flush (None when queue is empty).
    pub fn time_until_deadline(&self) -> Option<Duration> {
        self.time_until_deadline_at(Instant::now())
    }

    /// [`time_until_deadline`](Self::time_until_deadline) against an
    /// injected clock.
    pub fn time_until_deadline_at(&self, now: Instant) -> Option<Duration> {
        self.oldest
            .map(|t| self.max_wait.saturating_sub(now.saturating_duration_since(t)))
    }

    /// Flush if the oldest request has waited past the linger budget.
    pub fn poll_deadline(&mut self) -> Option<Batch<T>> {
        self.poll_deadline_at(Instant::now())
    }

    /// [`poll_deadline`](Self::poll_deadline) against an injected clock.
    pub fn poll_deadline_at(&mut self, now: Instant) -> Option<Batch<T>> {
        match self.oldest {
            Some(t)
                if now.saturating_duration_since(t) >= self.max_wait
                    && !self.queue.is_empty() =>
            {
                Some(self.flush(FlushReason::Deadline))
            }
            _ => None,
        }
    }

    /// Unconditional flush (shutdown path).
    pub fn drain(&mut self) -> Option<Batch<T>> {
        if self.queue.is_empty() {
            None
        } else {
            Some(self.flush(FlushReason::Drain))
        }
    }

    fn flush(&mut self, reason: FlushReason) -> Batch<T> {
        let items = std::mem::take(&mut self.queue);
        let candidates = self.queued_candidates;
        self.queued_candidates = 0;
        self.oldest = None;
        Batch { items, candidates, reason }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::feature::FeatureSlot;

    fn req(n_cands: usize) -> Request {
        Request {
            model: "m".into(),
            context: vec![FeatureSlot { field: 0, bucket: 1, value: 1.0 }],
            candidates: (0..n_cands)
                .map(|i| vec![FeatureSlot { field: 1, bucket: i as u32, value: 1.0 }])
                .collect(),
        }
    }

    #[test]
    fn flushes_when_full() {
        let mut b = DynamicBatcher::new(10, Duration::from_secs(10));
        assert!(b.push(req(4), 0u32).is_none());
        assert!(b.push(req(4), 1).is_none());
        let batch = b.push(req(4), 2).expect("should flush");
        assert_eq!(batch.reason, FlushReason::Full);
        assert_eq!(batch.candidates, 12);
        assert_eq!(batch.items.len(), 3);
        assert_eq!(b.queued_requests(), 0);
        assert_eq!(b.queued_candidates(), 0);
    }

    #[test]
    fn zero_candidate_request_never_triggers_full_flush() {
        // A zero-candidate request adds nothing to the candidate
        // budget, so even max_batch=1 must not flush on its push; it
        // rides out to the linger deadline (or a drain) like any other
        // queued request and keeps its place in the batch.
        let t0 = Instant::now();
        let mut b = DynamicBatcher::new(1, Duration::from_millis(5));
        assert!(b.push_at(req(0), 0u32, t0).is_none(), "empty slate flushed Full");
        assert_eq!(b.queued_requests(), 1);
        assert_eq!(b.queued_candidates(), 0);
        let batch = b
            .poll_deadline_at(t0 + Duration::from_millis(5))
            .expect("deadline flush");
        assert_eq!(batch.reason, FlushReason::Deadline);
        assert_eq!(batch.items.len(), 1);
        assert_eq!(batch.candidates, 0);
        // and via drain too
        b.push_at(req(0), 1, t0);
        let drained = b.drain().expect("drain flush");
        assert_eq!(drained.reason, FlushReason::Drain);
        assert_eq!(drained.candidates, 0);
    }

    #[test]
    fn deadline_flush_with_injected_clock() {
        // no real sleeps: the whole deadline lifecycle runs against a
        // synthetic clock
        let t0 = Instant::now();
        let mut b = DynamicBatcher::new(1000, Duration::from_millis(5));
        b.push_at(req(2), 0u32, t0);
        assert_eq!(
            b.time_until_deadline_at(t0 + Duration::from_millis(2)),
            Some(Duration::from_millis(3))
        );
        assert!(b.poll_deadline_at(t0 + Duration::from_millis(4)).is_none());
        let batch = b
            .poll_deadline_at(t0 + Duration::from_millis(5))
            .expect("deadline batch");
        assert_eq!(batch.reason, FlushReason::Deadline);
        assert_eq!(batch.items.len(), 1);
        assert_eq!(b.queued_requests(), 0);
        // after the flush the deadline disappears
        assert!(b.time_until_deadline_at(t0 + Duration::from_secs(1)).is_none());
    }

    #[test]
    fn deadline_from_oldest_not_newest() {
        let t0 = Instant::now();
        let mut b = DynamicBatcher::new(1000, Duration::from_millis(20));
        b.push_at(req(1), 0u32, t0);
        // newer request must not reset the clock
        b.push_at(req(1), 1, t0 + Duration::from_millis(12));
        assert!(b.poll_deadline_at(t0 + Duration::from_millis(19)).is_none());
        let batch = b
            .poll_deadline_at(t0 + Duration::from_millis(22))
            .expect("oldest-request deadline");
        assert_eq!(batch.items.len(), 2);
    }

    #[test]
    fn all_flush_reasons_deterministic() {
        let t0 = Instant::now();
        // Full: candidate budget reached on push
        let mut b = DynamicBatcher::new(4, Duration::from_secs(1));
        assert!(b.push_at(req(2), 0u32, t0).is_none());
        let full = b.push_at(req(2), 1, t0).expect("full flush");
        assert_eq!(full.reason, FlushReason::Full);
        // Deadline: linger expired on the injected clock
        b.push_at(req(1), 2, t0);
        let deadline = b
            .poll_deadline_at(t0 + Duration::from_secs(2))
            .expect("deadline flush");
        assert_eq!(deadline.reason, FlushReason::Deadline);
        // Drain: explicit shutdown flush
        b.push_at(req(1), 3, t0);
        let drain = b.drain().expect("drain flush");
        assert_eq!(drain.reason, FlushReason::Drain);
        assert_eq!(drain.items[0].1, 3);
    }

    #[test]
    fn clock_going_backwards_is_safe() {
        // a now() earlier than the oldest arrival must not panic or
        // flush (saturating duration arithmetic)
        let t0 = Instant::now();
        let mut b = DynamicBatcher::new(100, Duration::from_millis(10));
        b.push_at(req(1), 0u32, t0 + Duration::from_millis(50));
        assert!(b.poll_deadline_at(t0).is_none());
        assert_eq!(
            b.time_until_deadline_at(t0),
            Some(Duration::from_millis(10))
        );
    }

    #[test]
    fn drain_and_empty_behaviour() {
        let mut b: DynamicBatcher<u32> =
            DynamicBatcher::new(10, Duration::from_secs(1));
        assert!(b.drain().is_none());
        assert!(b.poll_deadline().is_none());
        assert!(b.time_until_deadline().is_none());
        b.push(req(1), 7);
        let batch = b.drain().unwrap();
        assert_eq!(batch.reason, FlushReason::Drain);
        assert_eq!(batch.items[0].1, 7);
    }

    fn req_ctx(model: &str, ctx_bucket: u32, n_cands: usize) -> Request {
        Request {
            model: model.into(),
            context: vec![FeatureSlot { field: 0, bucket: ctx_bucket, value: 1.0 }],
            candidates: (0..n_cands)
                .map(|i| vec![FeatureSlot { field: 1, bucket: i as u32, value: 1.0 }])
                .collect(),
        }
    }

    #[test]
    fn context_groups_first_seen_order_and_membership() {
        // interleaved arrivals: A B A C B A — groups must come out in
        // first-seen order with members in arrival order
        let reqs = [
            req_ctx("m", 1, 2), // 0: A
            req_ctx("m", 2, 3), // 1: B
            req_ctx("m", 1, 1), // 2: A
            req_ctx("m", 3, 4), // 3: C
            req_ctx("m", 2, 2), // 4: B
            req_ctx("m", 1, 5), // 5: A
        ];
        let groups = context_groups(reqs.iter());
        assert_eq!(groups.len(), 3);
        assert_eq!(groups[0].members, vec![0, 2, 5]);
        assert_eq!(groups[0].candidates, 8);
        assert_eq!(groups[1].members, vec![1, 4]);
        assert_eq!(groups[1].candidates, 5);
        assert_eq!(groups[2].members, vec![3]);
        assert_eq!(groups[2].candidates, 4);
    }

    #[test]
    fn context_groups_split_on_model_value_and_bucket() {
        // same context bucket under two model names -> two groups; a
        // value change (not just bucket) also splits
        let mut v = req_ctx("m", 7, 1);
        v.context[0].value = 0.5;
        let reqs = [req_ctx("m", 7, 1), req_ctx("other", 7, 1), v];
        let groups = context_groups(reqs.iter());
        assert_eq!(groups.len(), 3);
        assert!(groups.iter().all(|g| g.members.len() == 1));
    }

    #[test]
    fn group_key_hash_tracks_grouping_identity() {
        // Requests that context_groups would coalesce share a hash;
        // model or context differences split it.
        let a = req_ctx("m", 7, 1);
        let b = req_ctx("m", 7, 3); // same group key, different slate
        let c = req_ctx("other", 7, 1);
        let mut d = req_ctx("m", 7, 1);
        d.context[0].value = 0.5;
        let h = |r: &Request| group_key_hash(&r.model, &r.context);
        assert_eq!(h(&a), h(&b));
        assert_ne!(h(&a), h(&c));
        assert_ne!(h(&a), h(&d));
    }

    #[test]
    fn flushed_batch_exposes_stable_groups() {
        let mut b = DynamicBatcher::new(100, Duration::from_secs(1));
        b.push(req_ctx("m", 1, 2), 0u32);
        b.push(req_ctx("m", 2, 2), 1);
        b.push(req_ctx("m", 1, 2), 2);
        let batch = b.drain().expect("drain");
        let g1 = batch.context_groups();
        assert_eq!(g1.len(), 2);
        let g2 = batch.context_groups();
        assert_eq!(g1, g2, "grouping must be deterministic");
        assert_eq!(g1[0].members, vec![0, 2]);
        // arrival order survived the flush (grouping-stable contents)
        assert_eq!(batch.items[0].1, 0);
        assert_eq!(batch.items[2].1, 2);
    }

    #[test]
    fn single_oversized_request_flushes_immediately() {
        // One request whose candidate count alone exceeds max_batch
        // must flush on its own push as Full — never linger for the
        // deadline — with every queue counter reset so nothing drifts
        // across the flush boundary.
        let t0 = Instant::now();
        let mut b = DynamicBatcher::new(4, Duration::from_secs(1));
        let batch = b.push_at(req(9), 0u32, t0).expect("flush");
        assert_eq!(batch.reason, FlushReason::Full);
        assert_eq!(batch.candidates, 9);
        assert_eq!(batch.items.len(), 1);
        assert_eq!(b.queued_requests(), 0);
        assert_eq!(b.queued_candidates(), 0);
        // oldest is cleared: no stale deadline survives the flush
        assert!(b.time_until_deadline_at(t0 + Duration::from_secs(10)).is_none());
        assert!(b.poll_deadline_at(t0 + Duration::from_secs(10)).is_none());
        // the next undersized push starts a fresh batch from zero, with
        // a fresh linger clock
        let t1 = t0 + Duration::from_secs(20);
        assert!(b.push_at(req(2), 1, t1).is_none());
        assert_eq!(b.queued_candidates(), 2);
        assert_eq!(
            b.time_until_deadline_at(t1),
            Some(Duration::from_secs(1))
        );
        let drained = b.drain().expect("drain");
        assert_eq!(drained.candidates, 2);
        assert_eq!(drained.items[0].1, 1);
        assert_eq!(b.queued_candidates(), 0);
    }
}
