//! Fleet topology: data centers, replicas, and the simulated links
//! between them.
//!
//! The shape mirrors the paper's deployment: one training site
//! publishes weight updates to serving replicas spread over multiple
//! data centers.  The expensive edges are the trainer→DC WAN links;
//! the edges inside a DC are cheap LAN.  Each link carries bandwidth,
//! RTT and a loss probability — loss is what forces the catch-up
//! protocol (a dropped update leaves a replica behind the head
//! version until it replays the missed patch chain or resyncs).

use crate::fleet::metrics::LinkLedger;
use crate::util::rng::Pcg32;

/// Physical properties of one simulated link.
///
/// THE wire-time model of the repo: [`transfer_seconds`]
/// (Self::transfer_seconds) (rtt + len/bandwidth) is the single
/// implementation both this module's [`SimLink`] and the transfer
/// plane's [`crate::transfer::SimulatedChannel`] bill through (the
/// channel holds a `LinkSpec` and delegates).  `loss` applies only to
/// the lossy fleet links; the transfer channel is the reliable pipe.
#[derive(Clone, Copy, Debug)]
pub struct LinkSpec {
    /// Bytes per second.
    pub bandwidth_bps: f64,
    /// Per-shipment round-trip overhead in seconds.
    pub rtt_seconds: f64,
    /// Probability that a shipment is lost in transit.
    pub loss: f64,
}

impl LinkSpec {
    /// 10 Gbps intra-DC LAN, 0.5 ms RTT, no loss.
    pub fn lan() -> Self {
        LinkSpec { bandwidth_bps: 1.25e9, rtt_seconds: 0.0005, loss: 0.0 }
    }

    /// 1 Gbps inter-DC WAN, 30 ms RTT, no loss.
    pub fn wan() -> Self {
        LinkSpec { bandwidth_bps: 1.25e8, rtt_seconds: 0.03, loss: 0.0 }
    }

    /// Same link with a loss probability.
    pub fn with_loss(mut self, loss: f64) -> Self {
        self.loss = loss;
        self
    }

    /// Simulated seconds to move `len` bytes (derived, never slept).
    pub fn transfer_seconds(&self, len: usize) -> f64 {
        self.rtt_seconds + len as f64 / self.bandwidth_bps
    }
}

/// One data center: how many serving replicas it hosts and the links
/// reaching / crossing it.
#[derive(Clone, Debug)]
pub struct DcSpec {
    pub name: String,
    pub replicas: usize,
    /// Trainer → this DC (the cross-DC edge the planner minimizes).
    pub inter: LinkSpec,
    /// Replica → replica inside this DC (fan-out-tree re-distribution).
    pub intra: LinkSpec,
}

/// The whole serving fleet, trainer excluded (the trainer is the
/// implicit root every route starts from).
#[derive(Clone, Debug)]
pub struct Topology {
    pub dcs: Vec<DcSpec>,
}

/// Address of one replica: (data center, index within the DC).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ReplicaId {
    pub dc: usize,
    pub replica: usize,
}

impl Topology {
    /// `dcs` identical data centers of `replicas` replicas each.
    pub fn uniform(dcs: usize, replicas: usize, inter: LinkSpec, intra: LinkSpec) -> Self {
        assert!(dcs >= 1, "need at least one data center");
        assert!(replicas >= 1, "need at least one replica per DC");
        Topology {
            dcs: (0..dcs)
                .map(|i| DcSpec {
                    name: format!("dc{i}"),
                    replicas,
                    inter,
                    intra,
                })
                .collect(),
        }
    }

    pub fn total_replicas(&self) -> usize {
        self.dcs.iter().map(|d| d.replicas).sum()
    }

    /// All replica addresses, DC-major (the fabric's flattened order).
    pub fn replica_ids(&self) -> Vec<ReplicaId> {
        let mut out = Vec::with_capacity(self.total_replicas());
        for (dc, spec) in self.dcs.iter().enumerate() {
            for replica in 0..spec.replicas {
                out.push(ReplicaId { dc, replica });
            }
        }
        out
    }

    /// Position of `id` in the DC-major flattened replica order.
    pub fn flat_index(&self, id: ReplicaId) -> usize {
        self.dcs[..id.dc].iter().map(|d| d.replicas).sum::<usize>() + id.replica
    }
}

/// A stateful simulated link: spec + ledger + (deterministic) loss.
#[derive(Clone, Debug)]
pub struct SimLink {
    pub spec: LinkSpec,
    pub ledger: LinkLedger,
}

impl SimLink {
    pub fn new(spec: LinkSpec) -> Self {
        SimLink { spec, ledger: LinkLedger::default() }
    }

    /// Ship `len` bytes.  The sender pays bandwidth whether or not the
    /// shipment arrives.  Returns the wire seconds on delivery, `None`
    /// when the shipment is lost (`force_drop` loses it regardless of
    /// the link's loss probability — the test/soak fault injector).
    pub fn ship(&mut self, len: usize, rng: &mut Pcg32, force_drop: bool) -> Option<f64> {
        let secs = self.spec.transfer_seconds(len);
        let lost =
            force_drop || (self.spec.loss > 0.0 && rng.next_f64() < self.spec.loss);
        self.ledger.record(len, secs, !lost);
        if lost {
            None
        } else {
            Some(secs)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_topology_shape() {
        let t = Topology::uniform(3, 2, LinkSpec::wan(), LinkSpec::lan());
        assert_eq!(t.dcs.len(), 3);
        assert_eq!(t.total_replicas(), 6);
        let ids = t.replica_ids();
        assert_eq!(ids.len(), 6);
        assert_eq!(ids[0], ReplicaId { dc: 0, replica: 0 });
        assert_eq!(ids[5], ReplicaId { dc: 2, replica: 1 });
        for (i, id) in ids.iter().enumerate() {
            assert_eq!(t.flat_index(*id), i);
        }
    }

    #[test]
    fn transfer_seconds_scale_with_bytes() {
        let l = LinkSpec { bandwidth_bps: 1_000_000.0, rtt_seconds: 0.01, loss: 0.0 };
        assert!((l.transfer_seconds(500_000) - 0.51).abs() < 1e-9);
        // LAN moves the same payload orders of magnitude faster
        let lan = LinkSpec::lan().transfer_seconds(1 << 20);
        let wan = LinkSpec::wan().transfer_seconds(1 << 20);
        assert!(lan < wan);
    }

    #[test]
    fn lossless_link_always_delivers() {
        let mut link = SimLink::new(LinkSpec::lan());
        let mut rng = Pcg32::seeded(1);
        for _ in 0..100 {
            assert!(link.ship(1000, &mut rng, false).is_some());
        }
        assert_eq!(link.ledger.messages, 100);
        assert_eq!(link.ledger.drops, 0);
        assert_eq!(link.ledger.bytes, 100_000);
    }

    #[test]
    fn forced_drop_loses_but_still_bills() {
        let mut link = SimLink::new(LinkSpec::wan());
        let mut rng = Pcg32::seeded(2);
        assert!(link.ship(1000, &mut rng, true).is_none());
        assert_eq!(link.ledger.drops, 1);
        assert_eq!(link.ledger.bytes, 1000, "sender pays for lost shipments");
    }

    #[test]
    fn lossy_link_drops_roughly_at_rate() {
        let mut link = SimLink::new(LinkSpec::wan().with_loss(0.5));
        let mut rng = Pcg32::seeded(3);
        for _ in 0..2000 {
            link.ship(10, &mut rng, false);
        }
        assert!(
            (700..1300).contains(&(link.ledger.drops as usize)),
            "drops {} far from 50%",
            link.ledger.drops
        );
    }
}
