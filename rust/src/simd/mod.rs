//! §5 — SIMD-instruction-aware forward pass.
//!
//! "These hardware instruction level optimizations needed to be
//! carefully implemented as the space of serving hardware is not
//! homogeneous, meaning that on-the-fly instruction detection, and
//! subsequent utilization of appropriate binary needed to be put in
//! place."
//!
//! This module implements exactly that: the hot kernels (dot products,
//! axpy, dense matvec, the batched GEMM-lite spine, the FFM pairwise
//! inner loop) exist on a ladder of ISA rungs — scalar, AVX2+FMA, and
//! AVX-512 (F/BW/DQ/VL) — and a process-wide dispatch decision is taken
//! once at startup via `is_x86_feature_detected!`.  Every rung above the
//! CPU's capability falls back to the best available one, so forcing is
//! clamp-down-only and a binary built here runs unchanged across a
//! heterogeneous fleet.  Benchmarks (Figure 5) force specific rungs
//! through [`ForcedIsaGuard`]; the `FW_FORCE_ISA` environment variable
//! clamps the *detected* default the same way for whole test processes.

pub mod batch;
pub mod dot;

use std::sync::atomic::{AtomicU8, Ordering};

/// Selected instruction set, ordered weakest to strongest: dispatch
/// sites test `isa_level() >= IsaLevel::Avx2Fma` so a stronger rung
/// implies every weaker rung's kernels remain callable.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum IsaLevel {
    Scalar = 0,
    Avx2Fma = 1,
    Avx512 = 2,
}

impl IsaLevel {
    /// Decode the dispatch byte stored in the atomics below; anything
    /// out of range (notably `UNSET`) decodes to the weakest rung.
    fn from_u8(v: u8) -> IsaLevel {
        match v {
            2 => IsaLevel::Avx512,
            1 => IsaLevel::Avx2Fma,
            _ => IsaLevel::Scalar,
        }
    }

    /// Parse a rung name as accepted by `fw --force-isa` and
    /// `FW_FORCE_ISA` ("scalar" | "avx2" | "avx512"; the long metric
    /// names "avx2+fma" / "avx512vl" are accepted as aliases).
    pub fn parse(s: &str) -> Option<IsaLevel> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(IsaLevel::Scalar),
            "avx2" | "avx2+fma" => Some(IsaLevel::Avx2Fma),
            "avx512" | "avx512vl" => Some(IsaLevel::Avx512),
            _ => None,
        }
    }

    /// Human-readable rung name (stable: recorded in `BENCH_*.json`
    /// envelopes and the `fw_isa_level` gauge help text).
    pub fn name(self) -> &'static str {
        match self {
            IsaLevel::Scalar => "scalar",
            IsaLevel::Avx2Fma => "avx2+fma",
            IsaLevel::Avx512 => "avx512",
        }
    }
}

const UNSET: u8 = u8::MAX;
static FORCED: AtomicU8 = AtomicU8::new(UNSET);
static RESOLVED: AtomicU8 = AtomicU8::new(UNSET);
static HW_BEST: AtomicU8 = AtomicU8::new(UNSET);

/// Detect the best ISA available on this machine (honouring any
/// force).  The CPUID probe runs once; afterwards this is a single
/// relaxed atomic load — cheap enough for per-kernel dispatch.
#[inline]
pub fn isa_level() -> IsaLevel {
    // ordering: Relaxed throughout — all three cells hold a
    // self-contained one-byte dispatch decision; no other data is
    // published through them.  Racing threads may each run the
    // idempotent CPUID probe once, converging on the same value.
    let f = FORCED.load(Ordering::Relaxed);
    if f != UNSET {
        return IsaLevel::from_u8(f);
    }
    // ordering: Relaxed — see above.
    let r = RESOLVED.load(Ordering::Relaxed);
    if r != UNSET {
        return IsaLevel::from_u8(r);
    }
    let d = detect();
    // ordering: Relaxed — see above.
    RESOLVED.store(d as u8, Ordering::Relaxed);
    d
}

/// The strongest rung this CPU can execute, ignoring any forcing and
/// the `FW_FORCE_ISA` clamp.  Forcing APIs clamp against this so a
/// requested rung the hardware lacks degrades to the best available
/// one instead of dispatching illegal instructions.
pub fn best_available() -> IsaLevel {
    // ordering: Relaxed — self-contained dispatch byte, see
    // `isa_level`.
    let c = HW_BEST.load(Ordering::Relaxed);
    if c != UNSET {
        return IsaLevel::from_u8(c);
    }
    let b = probe();
    // ordering: Relaxed — see `isa_level`.
    HW_BEST.store(b as u8, Ordering::Relaxed);
    b
}

/// Every rung this CPU can run, weakest first (always starts with
/// [`IsaLevel::Scalar`]).  Benches and the cross-rung parity property
/// iterate this to cover the whole ladder on whatever host they run.
pub fn available_levels() -> Vec<IsaLevel> {
    let best = best_available();
    let mut v = vec![IsaLevel::Scalar];
    if best >= IsaLevel::Avx2Fma {
        v.push(IsaLevel::Avx2Fma);
    }
    if best >= IsaLevel::Avx512 {
        v.push(IsaLevel::Avx512);
    }
    v
}

/// One-shot CPUID probe for the strongest rung.
fn probe() -> IsaLevel {
    // Miri has no CPUID and cannot execute vendor intrinsics — the
    // scalar kernels are the only sound path under the interpreter, so
    // the probe is compiled out entirely there.
    #[cfg(all(target_arch = "x86_64", not(miri)))]
    {
        if std::arch::is_x86_feature_detected!("avx512f")
            && std::arch::is_x86_feature_detected!("avx512bw")
            && std::arch::is_x86_feature_detected!("avx512dq")
            && std::arch::is_x86_feature_detected!("avx512vl")
            && std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
        {
            return IsaLevel::Avx512;
        }
        if std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
        {
            return IsaLevel::Avx2Fma;
        }
    }
    IsaLevel::Scalar
}

/// Resolve the process default: the hardware's best rung, clamped down
/// by `FW_FORCE_ISA` when set to a parsable rung name (unparsable
/// values are ignored — a fleet-wide env var must never turn into a
/// startup failure).  The env clamp only lowers the default; it cannot
/// enable a rung the CPU lacks, and [`ForcedIsaGuard`] still overrides
/// it (so forcing tests behave identically under every CI matrix leg).
fn detect() -> IsaLevel {
    let best = best_available();
    match std::env::var("FW_FORCE_ISA").ok().and_then(|v| IsaLevel::parse(&v)) {
        Some(clamp) => clamp.min(best),
        None => best,
    }
}

/// Force a specific ISA level process-wide, clamped down to the best
/// rung the CPU actually supports; `None` removes the force.
///
/// This mutates a process-wide atomic and never restores it: reserve it
/// for process-scoped decisions (the `fw serve --force-isa` CLI flag).
/// Tests and benches must use [`ForcedIsaGuard`] instead, which
/// restores the prior forced state on drop.
pub fn force_isa(level: Option<IsaLevel>) {
    let v = match level {
        Some(l) => l.min(best_available()) as u8,
        None => UNSET,
    };
    // ordering: Relaxed — self-contained dispatch byte, see
    // `isa_level`.
    FORCED.store(v, Ordering::Relaxed);
}

/// Force the scalar kernels (Figure 5's SIMD-disabled control runs) —
/// the historical single-rung forcing entry, kept as an alias of
/// [`force_isa`].
pub fn force_scalar(on: bool) {
    force_isa(if on { Some(IsaLevel::Scalar) } else { None });
}

/// Scoped ISA forcing: forces a rung on construction and restores the
/// *previous* forced state — including "unforced" — when dropped,
/// LIFO-nestable.  Forcing is clamp-down-only: requesting a rung the
/// CPU lacks forces the best available one instead.
///
/// [`force_isa`] leaves the process-wide dispatch atomic mutated
/// forever; a test that forced scalar and forgot (or panicked before)
/// the restore silently poisoned every concurrently-running
/// `cargo test` thread onto the scalar path.  The guard bounds the
/// mutation to a scope — though while it lives, *other* threads still
/// observe the forced level (the dispatch decision is inherently
/// process-global), so forcing tests must serialize through
/// [`forcing_lock`], and equality tests comparing forced-scalar against
/// SIMD results should call concrete kernels directly where bit-exact
/// dispatch matters.
pub struct ForcedIsaGuard {
    prev: u8,
}

impl std::fmt::Debug for ForcedIsaGuard {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ForcedIsaGuard").finish_non_exhaustive()
    }
}

impl ForcedIsaGuard {
    /// Force `level` (clamped down to [`best_available`]) until the
    /// guard drops.
    pub fn force(level: IsaLevel) -> Self {
        ForcedIsaGuard {
            // ordering: Relaxed — self-contained dispatch byte, see
            // `isa_level`; the swap makes force+remember one atomic
            // step so LIFO-nested guards restore correctly.
            prev: FORCED.swap(level.min(best_available()) as u8, Ordering::Relaxed),
        }
    }

    /// Force the scalar kernels until the guard drops (Figure 5's
    /// SIMD-disabled control arm).
    pub fn scalar() -> Self {
        ForcedIsaGuard::force(IsaLevel::Scalar)
    }
}

impl Drop for ForcedIsaGuard {
    fn drop(&mut self) {
        // ordering: Relaxed — self-contained dispatch byte, see
        // `isa_level`.
        FORCED.store(self.prev, Ordering::Relaxed);
    }
}

/// True when any vector path (AVX2+FMA or stronger) is live.
pub fn simd_active() -> bool {
    isa_level() >= IsaLevel::Avx2Fma
}

/// Human-readable description of the live rung for logs/metrics,
/// exhaustive over [`IsaLevel`].
pub fn isa_name() -> &'static str {
    isa_level().name()
}

/// Serializes code that mutates the process-wide forced-ISA atomic:
/// the dispatch decision is global, so forcing tests or bench arms
/// running on parallel threads would otherwise observe each other's
/// state.  Any test asserting *bit-exact* equality through the
/// dispatched entry points should either hold this lock or call the
/// concrete kernels directly.
pub fn forcing_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    // The protected state (the FORCED atomic) stays consistent across a
    // panicking holder — a poisoned lock only means a forcing test
    // failed, so keep serializing instead of cascading the panic.
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
pub(crate) use forcing_lock as forcing_test_lock;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn force_scalar_round_trip() {
        let _serial = forcing_test_lock();
        force_scalar(true);
        assert_eq!(isa_level(), IsaLevel::Scalar);
        force_scalar(false);
        let _ = isa_level(); // whatever the host supports
    }

    #[test]
    fn forced_isa_guard_restores_prior_state() {
        let _serial = forcing_test_lock();
        // nested guards restore LIFO; the outer restore re-establishes
        // whatever was forced before the guards existed
        let outer_forced = FORCED.load(Ordering::Relaxed);
        {
            let _g1 = ForcedIsaGuard::scalar();
            assert_eq!(isa_level(), IsaLevel::Scalar);
            {
                let _g2 = ForcedIsaGuard::scalar();
                assert_eq!(isa_level(), IsaLevel::Scalar);
            }
            // inner drop restored g1's forcing, not "unforced"
            assert_eq!(FORCED.load(Ordering::Relaxed), IsaLevel::Scalar as u8);
        }
        assert_eq!(FORCED.load(Ordering::Relaxed), outer_forced);
    }

    #[test]
    fn forced_isa_guard_restores_on_panic() {
        let _serial = forcing_test_lock();
        let before = FORCED.load(Ordering::Relaxed);
        let result = std::panic::catch_unwind(|| {
            let _g = ForcedIsaGuard::scalar();
            panic!("unwinding must not leak the forced level");
        });
        assert!(result.is_err());
        assert_eq!(FORCED.load(Ordering::Relaxed), before);
    }

    #[test]
    fn forcing_clamps_down_to_best_available() {
        let _serial = forcing_test_lock();
        let best = best_available();
        for req in [IsaLevel::Scalar, IsaLevel::Avx2Fma, IsaLevel::Avx512] {
            let g = ForcedIsaGuard::force(req);
            assert_eq!(
                isa_level(),
                req.min(best),
                "forcing {req:?} on a host whose best rung is {best:?}"
            );
            drop(g);
        }
        // process-wide forcing clamps identically
        force_isa(Some(IsaLevel::Avx512));
        assert_eq!(isa_level(), IsaLevel::Avx512.min(best));
        force_isa(None);
    }

    #[test]
    fn available_levels_is_a_prefix_ladder() {
        let levels = available_levels();
        assert_eq!(levels[0], IsaLevel::Scalar);
        assert!(levels.windows(2).all(|w| w[0] < w[1]), "{levels:?}");
        assert_eq!(*levels.last().unwrap(), best_available());
    }

    #[test]
    fn parse_round_trips_every_rung_name() {
        for l in [IsaLevel::Scalar, IsaLevel::Avx2Fma, IsaLevel::Avx512] {
            assert_eq!(IsaLevel::parse(l.name()), Some(l));
        }
        assert_eq!(IsaLevel::parse("avx2"), Some(IsaLevel::Avx2Fma));
        assert_eq!(IsaLevel::parse("avx512"), Some(IsaLevel::Avx512));
        assert_eq!(IsaLevel::parse(" AVX512 "), Some(IsaLevel::Avx512));
        assert_eq!(IsaLevel::parse("sse9"), None);
    }

    #[test]
    fn rung_order_matches_dispatch_tests() {
        assert!(IsaLevel::Scalar < IsaLevel::Avx2Fma);
        assert!(IsaLevel::Avx2Fma < IsaLevel::Avx512);
    }

    #[test]
    fn isa_name_nonempty() {
        assert!(!isa_name().is_empty());
    }
}
