//! The online deployment plane — §3 + §6 run as one live system.
//!
//! The paper's production regime is not any single component but the
//! *loop*: Hogwild online training produces a weight snapshot every few
//! minutes, the snapshot is quantized and byte-patched for cross-DC
//! transfer, and serving workers hot-swap it without dropping traffic
//! (the always-online FFM deployments of Juan et al., arXiv:1701.04099).
//! [`DeploymentLoop`] owns that round lifecycle end to end:
//!
//! ```text
//!   train ──► encode ──► channel ──► decode ──► swap
//!   (Hogwild  (UpdatePipeline:       (UpdateReceiver   (ModelHandle::swap
//!    rounds)   raw/quant/patch/       reconstructs      + cache epoch
//!              quant+patch)           the weights)      invalidation)
//! ```
//!
//! Serving continues concurrently throughout — traffic drivers score
//! through [`crate::serve::server::ServeClient`] clones while rounds
//! run — and the loop exposes per-round lag/bandwidth/AUC metrics (the
//! numbers behind Table 4 and Figure 6, measured live instead of in
//! isolation).  [`harness`] builds the deterministic soak rig on top.

pub mod harness;

use std::time::Instant;

use crate::config::{ModelConfig, ServeConfig};
use crate::data::synthetic::{DatasetSpec, SyntheticStream};
use crate::eval::auc;
use crate::feature::Example;
use crate::model::regressor::Regressor;
use crate::model::{io, Workspace};
use crate::obs::{Counter, Gauge, HistogramShard, ObsOptions, RequestTracer};
use crate::serve::router::Router;
use crate::serve::server::{ServeClient, ServeStats, ServingEngine};
use crate::serve::ModelHandle;
use crate::train::hogwild::{train_chunk, HogwildConfig};
use crate::transfer::{SimulatedChannel, UpdateMode, UpdatePipeline, UpdateReceiver};
use crate::util::json::{num, obj, s};

/// Configuration of one deployment plane instance.
#[derive(Clone, Debug)]
pub struct DeployConfig {
    /// Model architecture served and trained.
    pub model: ModelConfig,
    /// Synthetic traffic shape feeding the trainer.
    pub dataset: DatasetSpec,
    /// Wire encoding (the four Table-4 arms).
    pub mode: UpdateMode,
    /// Examples consumed per training round (the "5-minute window").
    pub examples_per_round: usize,
    /// Hogwild threads for each round (1 = sequential, deterministic).
    pub train_threads: usize,
    /// Rolling-AUC window for the per-round training trace.
    pub auc_window: usize,
    /// Serving engine configuration.
    pub serve: ServeConfig,
    /// Name the model is registered under in the router.
    pub model_name: String,
    /// Held-out examples scored after every swap (AUC trend); 0
    /// disables the evaluation.
    pub holdout_examples: usize,
    /// Simulated inter-DC link.
    pub bandwidth_bps: f64,
    pub rtt_seconds: f64,
    /// Base seed for the training / holdout streams.
    pub seed: u64,
}

impl DeployConfig {
    /// Sensible defaults around a given model/dataset/mode.
    pub fn new(model: ModelConfig, dataset: DatasetSpec, mode: UpdateMode) -> Self {
        DeployConfig {
            model,
            dataset,
            mode,
            examples_per_round: 10_000,
            train_threads: 1,
            auc_window: 2_000,
            serve: ServeConfig::default(),
            model_name: "ctr".into(),
            holdout_examples: 2_000,
            bandwidth_bps: 125_000_000.0, // 1 Gbps
            rtt_seconds: 0.03,
            seed: 0xf10c,
        }
    }
}

/// Everything measured about one train→publish→swap round.
#[derive(Clone, Debug)]
pub struct RoundReport {
    /// 0-based round index.
    pub round: usize,
    /// Examples trained this round.
    pub examples: usize,
    /// Wall time of the Hogwild training phase.
    pub train_seconds: f64,
    /// Mean rolling-AUC of this round's progressive validation.
    pub train_auc: f64,
    /// Encoder wall time (Table 4 "Avg. time spent").
    pub encode_seconds: f64,
    /// Simulated wire time on the inter-DC channel.
    pub wire_seconds: f64,
    /// Receiver decode + reconstruction wall time.
    pub apply_seconds: f64,
    /// Bytes shipped for this update.
    pub update_bytes: usize,
    /// Size of the raw inference file (the baseline this update is
    /// measured against).
    pub raw_bytes: usize,
    /// Model version after the swap.
    pub version: u64,
    /// Publish lag: snapshot ready → serving on the new weights
    /// (encode + wire + apply + swap).
    pub lag_seconds: f64,
    /// Held-out AUC of the *served* (post-swap) model; NaN when the
    /// holdout evaluation is disabled.
    pub holdout_auc: f64,
}

/// Accumulated loop metrics (the live Table-4/Figure-6 ledger).
#[derive(Clone, Debug, Default)]
pub struct DeployMetrics {
    pub rounds: u64,
    pub examples: u64,
    pub update_bytes_total: u64,
    pub raw_bytes_total: u64,
    pub encode_seconds_total: f64,
    pub wire_seconds_total: f64,
    pub apply_seconds_total: f64,
    pub lag_seconds_total: f64,
    pub last_version: u64,
    pub last_holdout_auc: f64,
}

impl DeployMetrics {
    fn absorb(&mut self, r: &RoundReport) {
        self.rounds += 1;
        self.examples += r.examples as u64;
        self.update_bytes_total += r.update_bytes as u64;
        self.raw_bytes_total += r.raw_bytes as u64;
        self.encode_seconds_total += r.encode_seconds;
        self.wire_seconds_total += r.wire_seconds;
        self.apply_seconds_total += r.apply_seconds;
        self.lag_seconds_total += r.lag_seconds;
        self.last_version = r.version;
        self.last_holdout_auc = r.holdout_auc;
    }

    /// Raw-bytes / shipped-bytes ratio (×1 for `UpdateMode::Raw`).
    pub fn bandwidth_saving(&self) -> f64 {
        if self.update_bytes_total == 0 {
            0.0
        } else {
            self.raw_bytes_total as f64 / self.update_bytes_total as f64
        }
    }

    pub fn mean_lag_seconds(&self) -> f64 {
        if self.rounds == 0 {
            0.0
        } else {
            self.lag_seconds_total / self.rounds as f64
        }
    }
}

/// Registry handles for the deploy plane's own signals (rounds, lag,
/// swap latency, update bytes, holdout AUC).
struct DeployObs {
    rounds: Gauge,
    round_lag: Gauge,
    holdout_auc: Gauge,
    update_bytes: Counter,
    swap_ns: HistogramShard,
    tracer: Option<RequestTracer>,
}

/// The deployment plane: training DC, transfer plane and serving DC
/// wired into one continuously publishing loop.
pub struct DeploymentLoop {
    pub cfg: DeployConfig,
    trainer: Regressor,
    stream: SyntheticStream,
    pipeline: UpdatePipeline,
    receiver: UpdateReceiver,
    channel: SimulatedChannel,
    handle: ModelHandle,
    engine: ServingEngine,
    holdout: Vec<Example>,
    metrics: DeployMetrics,
    round: usize,
    obs: DeployObs,
}

impl DeploymentLoop {
    /// Build the full plane: fresh model, registered serving engine,
    /// transfer pipeline/receiver pair and a held-out evaluation set.
    pub fn new(cfg: DeployConfig) -> Self {
        Self::with_obs(cfg, ObsOptions::default())
    }

    /// [`new`](Self::new) recording into a caller-provided registry
    /// (and optionally tracing swap events), so serving, deploy, and
    /// training signals land in ONE scrape.
    pub fn with_obs(cfg: DeployConfig, obs: ObsOptions) -> Self {
        let trainer = Regressor::new(&cfg.model);
        let stream = SyntheticStream::with_buckets(
            cfg.dataset.clone(),
            cfg.seed,
            cfg.model.buckets,
        );
        let mut holdout_stream = SyntheticStream::with_buckets(
            cfg.dataset.clone(),
            cfg.seed ^ 0x0e1d_0a7a,
            cfg.model.buckets,
        );
        let holdout = holdout_stream.take_examples(cfg.holdout_examples);

        let pipeline = UpdatePipeline::new(cfg.mode);
        let mut receiver = UpdateReceiver::new(cfg.mode);
        receiver.set_template(trainer.clone());
        let channel =
            SimulatedChannel::with_bandwidth(cfg.bandwidth_bps, cfg.rtt_seconds);

        let handle = ModelHandle::new(trainer.clone());
        let router = Router::new(cfg.serve.workers);
        router.register(&cfg.model_name, handle.clone());
        let engine =
            ServingEngine::start_with_obs(router, cfg.serve.clone(), obs.clone());
        let reg = engine.obs_registry().clone();
        let deploy_obs = DeployObs {
            rounds: reg.gauge("fw_deploy_rounds", "publish rounds completed"),
            round_lag: reg.gauge(
                "fw_deploy_round_lag_seconds",
                "last round's publish lag (encode + wire + apply + swap)",
            ),
            holdout_auc: reg.gauge(
                "fw_deploy_holdout_auc",
                "held-out AUC of the served model after the last swap",
            ),
            update_bytes: reg.counter(
                "fw_deploy_update_bytes_total",
                "bytes shipped across rounds",
            ),
            swap_ns: reg.histogram_shard(
                "fw_deploy_swap_ns",
                "hot-swap latency (snapshot publish to cache invalidation)",
            ),
            tracer: obs.tracer,
        };

        DeploymentLoop {
            cfg,
            trainer,
            stream,
            pipeline,
            receiver,
            channel,
            handle,
            engine,
            holdout,
            metrics: DeployMetrics::default(),
            round: 0,
            obs: deploy_obs,
        }
    }

    /// One full round: train → encode → ship → decode → swap.
    pub fn run_round(&mut self) -> Result<RoundReport, String> {
        self.run_round_with(|_, _| {})
    }

    /// [`run_round`](Self::run_round) with a hook that observes the
    /// reconstructed model *before* it is swapped in (the soak harness
    /// registers expected scores there, so concurrent traffic never
    /// sees a version it cannot verify).  The hook receives the fresh
    /// model and the version it will be published as.
    pub fn run_round_with(
        &mut self,
        before_swap: impl FnOnce(&Regressor, u64),
    ) -> Result<RoundReport, String> {
        let round = self.round;
        // 1. online training window
        let chunk = self.stream.take_examples(self.cfg.examples_per_round);
        let stats = train_chunk(
            &mut self.trainer,
            &chunk,
            HogwildConfig { threads: self.cfg.train_threads.max(1) },
            self.cfg.auc_window,
        );
        let train_auc = if stats.auc_points.is_empty() {
            f64::NAN
        } else {
            stats.auc_points.iter().sum::<f64>() / stats.auc_points.len() as f64
        };
        // 2. encode for the wire
        let update = self.pipeline.encode(&self.trainer);
        let raw_bytes = self
            .pipeline
            .last_raw_len()
            .unwrap_or_else(|| io::to_bytes(&self.trainer, false).len());
        // 3. ship across the simulated inter-DC link
        let wire_seconds = self.channel.ship(&update);
        // 4. receive + reconstruct
        let t_apply = Instant::now();
        let fresh = self.receiver.apply(&update)?;
        let apply_seconds = t_apply.elapsed().as_secs_f64();
        // 5. publish: atomic snapshot swap + cache invalidation
        let next_version = self.handle.version() + 1;
        before_swap(&fresh, next_version);
        let t_swap = Instant::now();
        let version = self.handle.swap(fresh);
        self.engine.invalidate_caches();
        let swap_seconds = t_swap.elapsed().as_secs_f64();
        debug_assert_eq!(version, next_version);

        let holdout_auc = self.holdout_auc();
        let report = RoundReport {
            round,
            examples: chunk.len(),
            train_seconds: stats.wall_seconds,
            train_auc,
            encode_seconds: update.encode_seconds,
            wire_seconds,
            apply_seconds,
            update_bytes: update.bytes.len(),
            raw_bytes,
            version,
            lag_seconds: update.encode_seconds
                + wire_seconds
                + apply_seconds
                + swap_seconds,
            holdout_auc,
        };
        self.metrics.absorb(&report);
        self.round += 1;

        // Registry view of the round: training throughput/AUC, round
        // lag, swap latency, shipped bytes — same registry as serving.
        stats.export_to(self.engine.obs_registry());
        self.obs.rounds.set(self.round as f64);
        self.obs.round_lag.set(report.lag_seconds);
        if report.holdout_auc.is_finite() {
            self.obs.holdout_auc.set(report.holdout_auc);
        }
        self.obs.update_bytes.add(report.update_bytes as u64);
        self.obs
            .swap_ns
            .record_ns((swap_seconds * 1e9).min(u64::MAX as f64) as u64);
        if let Some(tr) = self.obs.tracer.as_ref() {
            tr.emit(&obj(vec![
                ("event", s("deploy_swap")),
                ("round", num(round as f64)),
                ("version", num(version as f64)),
                ("swap_ns", num(swap_seconds * 1e9)),
                ("lag_seconds", num(report.lag_seconds)),
                ("update_bytes", num(report.update_bytes as f64)),
            ]));
        }
        Ok(report)
    }

    /// Run `n` rounds back to back.
    pub fn run_rounds(&mut self, n: usize) -> Result<Vec<RoundReport>, String> {
        (0..n).map(|_| self.run_round()).collect()
    }

    /// AUC of the currently *served* model on the fixed held-out set.
    pub fn holdout_auc(&self) -> f64 {
        if self.holdout.is_empty() {
            return f64::NAN;
        }
        let model = self.handle.load();
        let mut ws = Workspace::new();
        let mut scores = Vec::with_capacity(self.holdout.len());
        let mut labels = Vec::with_capacity(self.holdout.len());
        for ex in &self.holdout {
            scores.push(model.predict(ex, &mut ws));
            labels.push(ex.label);
        }
        auc(&scores, &labels)
    }

    // ------------------------------------------------------- accessors

    /// The serving engine (submit / stats on the caller's thread).
    pub fn engine(&self) -> &ServingEngine {
        &self.engine
    }

    /// A clonable traffic handle for driver threads (submits after
    /// [`shutdown`](Self::shutdown) fail with an error).
    pub fn client(&self) -> ServeClient {
        self.engine.client()
    }

    /// The hot-swappable model slot serving traffic.
    pub fn handle(&self) -> &ModelHandle {
        &self.handle
    }

    /// Trainer-side model state (the next snapshot's source).
    pub fn trainer(&self) -> &Regressor {
        &self.trainer
    }

    /// Sender-side pipeline (base-file introspection).
    pub fn pipeline(&self) -> &UpdatePipeline {
        &self.pipeline
    }

    /// Receiver-side state (base-file introspection).
    pub fn receiver(&self) -> &UpdateReceiver {
        &self.receiver
    }

    /// Bandwidth ledger of the simulated channel.
    pub fn channel(&self) -> &SimulatedChannel {
        &self.channel
    }

    /// Accumulated loop metrics.
    pub fn metrics(&self) -> &DeployMetrics {
        &self.metrics
    }

    /// Rounds completed so far.
    pub fn rounds_run(&self) -> usize {
        self.round
    }

    /// Stop serving; returns the engine's final statistics.
    pub fn shutdown(self) -> ServeStats {
        self.engine.shutdown()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg(mode: UpdateMode) -> DeployConfig {
        let mut spec = DatasetSpec::tiny();
        spec.cat_fields = 4; // 1 cont + 4 cat = 5 fields
        let model = ModelConfig::deep_ffm(5, 2, 1 << 10, &[8]);
        let mut cfg = DeployConfig::new(model, spec, mode);
        cfg.examples_per_round = 1500;
        cfg.holdout_examples = 800;
        cfg.serve = ServeConfig {
            workers: 2,
            max_batch: 32,
            max_wait_us: 100,
            context_cache_entries: 1024,
            max_group_candidates: 1024,
            ..ServeConfig::default()
        };
        cfg
    }

    #[test]
    fn rounds_publish_monotonic_versions_and_metrics() {
        let mut dl = DeploymentLoop::new(small_cfg(UpdateMode::QuantPatch));
        assert_eq!(dl.handle().version(), 1);
        let reports = dl.run_rounds(3).unwrap();
        assert_eq!(reports.len(), 3);
        for (i, r) in reports.iter().enumerate() {
            assert_eq!(r.round, i);
            assert_eq!(r.version, 2 + i as u64); // v1 was the bootstrap
            assert_eq!(r.examples, 1500);
            assert!(r.update_bytes > 0);
            assert!(r.raw_bytes > 0);
            assert!(r.lag_seconds >= 0.0);
            assert!(r.holdout_auc.is_finite());
        }
        let m = dl.metrics();
        assert_eq!(m.rounds, 3);
        assert_eq!(m.examples, 4500);
        assert_eq!(m.last_version, 4);
        // steady-state quant+patch updates undercut raw files
        assert!(m.bandwidth_saving() > 1.0, "saving {}", m.bandwidth_saving());
        let stats = dl.shutdown();
        assert_eq!(stats.errors, 0);
    }

    #[test]
    fn served_model_tracks_trainer_within_mode_tolerance() {
        for mode in UpdateMode::ALL {
            let mut dl = DeploymentLoop::new(small_cfg(mode));
            dl.run_rounds(2).unwrap();
            let served = dl.handle().load();
            let trainer = dl.trainer();
            let max_err = served
                .pool
                .weights
                .iter()
                .zip(&trainer.pool.weights)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            if mode.is_quantized() {
                assert!(max_err < 1e-3, "{mode:?} err {max_err}");
            } else {
                assert_eq!(max_err, 0.0, "{mode:?} must be lossless");
            }
            dl.shutdown();
        }
    }

    #[test]
    fn rounds_export_into_shared_registry() {
        use crate::obs::{ObsRegistry, RequestTracer, TraceSink};
        use std::sync::Arc;

        let reg = Arc::new(ObsRegistry::new());
        let obs = crate::obs::ObsOptions::with_registry(reg.clone())
            .tracer(RequestTracer::new(1, TraceSink::memory()));
        let mut dl =
            DeploymentLoop::with_obs(small_cfg(UpdateMode::QuantPatch), obs);
        dl.run_rounds(2).unwrap();

        assert_eq!(reg.gauge_value("fw_deploy_rounds"), Some(2.0));
        let lag = reg.gauge_value("fw_deploy_round_lag_seconds").unwrap();
        assert!(lag >= 0.0);
        let auc = reg.gauge_value("fw_deploy_holdout_auc").unwrap();
        assert!(auc.is_finite());
        let shipped = reg.counter_value("fw_deploy_update_bytes_total").unwrap();
        assert_eq!(shipped, dl.metrics().update_bytes_total);
        let swaps = reg.histogram_snapshot("fw_deploy_swap_ns").unwrap();
        assert_eq!(swaps.count(), 2);
        // the training chunks exported through the same registry
        assert_eq!(
            reg.counter_value("fw_train_examples_total"),
            Some(2 * 1500)
        );
        assert!(reg.gauge_value("fw_train_rolling_auc").is_some());

        // one render exposes serving + deploy + train series together
        let text = reg.render_prometheus();
        crate::testutil::check_prometheus_text(&text).expect("well-formed");
        assert!(text.contains("fw_deploy_swap_ns{quantile=\"0.99\"}"));
        assert!(text.contains("fw_serve_stage_total_ns"));
        assert!(text.contains("fw_train_examples_per_sec"));

        // every round traced exactly one deploy_swap event
        let tracer = dl.obs.tracer.clone().unwrap();
        tracer.flush();
        let events: Vec<String> = tracer
            .sink()
            .drain()
            .into_iter()
            .filter(|l| l.contains("\"deploy_swap\""))
            .collect();
        assert_eq!(events.len(), 2);
        let parsed = crate::util::json::parse(&events[1]).unwrap();
        assert_eq!(parsed.get("event").as_str(), Some("deploy_swap"));
        assert_eq!(parsed.get("round").as_f64(), Some(1.0));
        dl.shutdown();
    }

    #[test]
    fn before_swap_hook_sees_next_version() {
        let mut dl = DeploymentLoop::new(small_cfg(UpdateMode::Raw));
        let mut observed = None;
        dl.run_round_with(|reg, v| {
            observed = Some((reg.pool.weights.len(), v));
        })
        .unwrap();
        let (n, v) = observed.expect("hook ran");
        assert_eq!(v, 2);
        assert_eq!(n, dl.trainer().num_weights());
        assert_eq!(dl.handle().version(), 2);
        dl.shutdown();
    }
}
