//! Cross-plane chaos soak: the fleet soak of [`super::soak`] with
//! randomized crash/partition/stall fault injection layered on top of
//! the recovery plane.
//!
//! Every run injects — at seed-chosen rounds — at least one of each:
//!
//! * **replica crash + restart** — a replica is torn down (engine and
//!   all) and rebuilt from its last durable cursor
//!   ([`FleetFabric::restart_replica`]), then healed to head by
//!   catch-up.
//! * **fabric crash + restore** — the whole distribution plane
//!   (pipeline, log, replicas, RNG) is dropped and rebuilt from the
//!   last on-disk checkpoint ([`FleetFabric::restore_from_path`]),
//!   resuming bit-identically while traffic keeps flowing.
//! * **DC partition** — the trainer→DC link fails every shipment for
//!   1–2 rounds; the health machine walks the DC's replicas down the
//!   ladder and the recovery probe resurrects them after it heals.
//! * **replica stall** — one frozen replica, same ladder.
//!
//! Traffic drivers route through the shared [`HealthBoard`]
//! (`route(hint)`), so requests go around Suspect/Dead replicas
//! instead of stalling on them.  The invariants checked are the soak's
//! (zero torn responses fleet-wide, eventual bit-identical
//! convergence) plus recovery-plane visibility: health transitions,
//! publish retries, and recovery replay timings must all land in the
//! shared [`ObsRegistry`].
//!
//! The whole fault schedule derives from one `Pcg32` seed, printed at
//! the start of every run (`chaos seed: 0x...`) and settable via
//! `fw fleet --chaos --seed N` — any failure reproduces from that one
//! number.

use std::collections::HashSet;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, RwLock};

use crate::config::{ModelConfig, ServeConfig};
use crate::data::synthetic::{DatasetSpec, SyntheticStream};
use crate::deploy::harness::probe_scores;
use crate::fleet::{
    FleetConfig, FleetFabric, FleetMetrics, HealthBoard, LinkSpec, ReplicaCheckpoint,
    RoundOutcome, Strategy, Topology,
};
use crate::model::regressor::Regressor;
use crate::obs::ObsRegistry;
use crate::serve::server::ServeClient;
use crate::serve::trace::TraceGenerator;
use crate::serve::Request;
use crate::train::hogwild::{train_chunk, HogwildConfig};
use crate::transfer::UpdateMode;
use crate::util::rng::Pcg32;

/// Chaos soak parameters.
#[derive(Clone, Debug)]
pub struct ChaosConfig {
    pub mode: UpdateMode,
    pub dcs: usize,
    pub replicas_per_dc: usize,
    /// Train→publish rounds (the ISSUE floor for the full soak is 20;
    /// the harness itself requires ≥ 8 so the fault schedule's quarters
    /// are non-empty).
    pub rounds: usize,
    pub examples_per_round: usize,
    pub train_threads: usize,
    pub traffic_threads: usize,
    pub probes: usize,
    /// Fabric checkpoint cadence in rounds (must be ≤ rounds/4 so a
    /// checkpoint exists before the scheduled fabric crash).
    pub checkpoint_every: usize,
    /// Per-round probability of an extra random fault on top of the
    /// four mandatory ones.
    pub extra_fault_prob: f64,
    /// The single number that reproduces the entire run.
    pub seed: u64,
}

impl ChaosConfig {
    /// CI-sized: 8 rounds, 2 DCs × 2 replicas, every fault kind once.
    pub fn smoke(mode: UpdateMode, seed: u64) -> Self {
        ChaosConfig {
            mode,
            dcs: 2,
            replicas_per_dc: 2,
            rounds: 8,
            examples_per_round: 500,
            train_threads: 2,
            traffic_threads: 2,
            probes: 10,
            checkpoint_every: 2,
            extra_fault_prob: 0.1,
            seed,
        }
    }

    /// The full ISSUE-scale soak: ≥20 rounds, 3 DCs × 2 replicas.
    pub fn full(mode: UpdateMode, seed: u64) -> Self {
        ChaosConfig {
            mode,
            dcs: 3,
            replicas_per_dc: 2,
            rounds: 24,
            examples_per_round: 900,
            train_threads: 2,
            traffic_threads: 3,
            probes: 12,
            checkpoint_every: 3,
            extra_fault_prob: 0.15,
            seed,
        }
    }
}

/// One injected fault, scheduled for a specific round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fault {
    /// Freeze replica `replica` for `rounds` publish rounds.
    Stall { replica: usize, rounds: u64 },
    /// Cut the trainer→DC link for `rounds` publish rounds.
    Partition { dc: usize, rounds: u64 },
    /// Kill replica `replica` and restart it from its last durable
    /// cursor.
    ReplicaCrash { replica: usize },
    /// Kill the whole fabric and restore from the last on-disk
    /// checkpoint.
    FabricCrash,
}

/// How many faults of each kind a run injected.
#[derive(Clone, Copy, Debug, Default)]
pub struct FaultLog {
    pub stalls: u32,
    pub partitions: u32,
    pub replica_restarts: u32,
    pub fabric_restores: u32,
}

/// Everything a chaos soak observed.
#[derive(Clone, Debug)]
pub struct ChaosReport {
    pub mode: UpdateMode,
    /// Reproduces the entire run (also printed at startup).
    pub seed: u64,
    pub rounds: Vec<RoundOutcome>,
    pub faults: FaultLog,
    pub probe_checks: u64,
    /// Responses matching NO published version (must be 0).
    pub torn_responses: u64,
    pub versions_observed: usize,
    /// Requests the health board steered away from their first-choice
    /// replica.
    pub routed_around: u64,
    /// Scores that failed because an engine was mid-restart (skipped,
    /// not torn).
    pub probe_errors: u64,
    pub caught_up_at_converge: usize,
    pub replicas_bit_identical: bool,
    pub replicas_match_reference: bool,
    pub serve_errors: u64,
    /// `fw_fleet_health_transitions_total` at the end of the run.
    pub health_transitions: u64,
    /// Samples in `fw_recovery_replay_ns` (restarts + recovery probes).
    pub recovery_samples: u64,
    pub metrics: FleetMetrics,
}

impl ChaosReport {
    /// Panic (with the reproducing seed) unless every chaos invariant
    /// held.
    pub fn assert_healthy(&self) {
        let ctx = format!("{:?} chaos seed {:#x}", self.mode, self.seed);
        assert_eq!(
            self.torn_responses, 0,
            "{ctx}: {} of {} responses matched no published version",
            self.torn_responses, self.probe_checks
        );
        assert!(self.probe_checks > 0, "{ctx}: no probes were scored");
        assert!(
            self.versions_observed >= 2,
            "{ctx}: only {} version(s) served",
            self.versions_observed
        );
        assert!(self.faults.stalls >= 1, "{ctx}: no stall injected");
        assert!(self.faults.partitions >= 1, "{ctx}: no partition injected");
        assert!(
            self.faults.replica_restarts >= 1,
            "{ctx}: no replica crash injected"
        );
        assert!(
            self.faults.fabric_restores >= 1,
            "{ctx}: no fabric crash injected"
        );
        assert!(
            self.replicas_bit_identical,
            "{ctx}: replicas diverged at convergence"
        );
        assert!(
            self.replicas_match_reference,
            "{ctx}: converged replicas differ from the reference"
        );
        assert_eq!(self.serve_errors, 0, "{ctx}: serving errors");
        assert!(
            self.health_transitions >= 2,
            "{ctx}: faults ran but only {} health transitions recorded",
            self.health_transitions
        );
        assert!(
            self.recovery_samples >= 1,
            "{ctx}: no recovery replay timing recorded"
        );
        assert!(
            self.metrics.retries >= 1,
            "{ctx}: faults ran but no publish retry was attempted"
        );
    }
}

/// Derive the full fault schedule from the seed: one mandatory fault
/// of each kind in its own quarter of the run (stall, then replica
/// crash, then fabric crash, then partition), plus random extras.
/// Durations are clamped so every partition/stall expires before the
/// final round's end-of-run convergence barrier.
pub fn fault_schedule(cfg: &ChaosConfig, rng: &mut Pcg32) -> Vec<Vec<Fault>> {
    let r = cfg.rounds;
    assert!(r >= 8, "chaos soak needs >= 8 rounds, got {r}");
    let n = (cfg.dcs * cfg.replicas_per_dc) as u32;
    let q = r / 4;
    let mut sched: Vec<Vec<Fault>> = vec![Vec::new(); r];

    let clamp = |round: usize, want: u64| -> u64 {
        want.min((r - 1 - round) as u64)
    };
    // quarter 1: stall
    let s1 = 1 + rng.below(q.max(1) as u32) as usize;
    sched[s1].push(Fault::Stall {
        replica: rng.below(n) as usize,
        rounds: clamp(s1, 1 + rng.below(2) as u64).max(1),
    });
    // quarter 2: replica crash + restart from cursor
    let s2 = q + rng.below(q.max(1) as u32) as usize;
    sched[s2].push(Fault::ReplicaCrash { replica: rng.below(n) as usize });
    // quarter 3: fabric crash + restore from checkpoint
    let s3 = 2 * q + rng.below(q.max(1) as u32) as usize;
    sched[s3].push(Fault::FabricCrash);
    // quarter 4: partition (expiring before the run ends)
    let s4 = 3 * q + rng.below((r - 2 - 3 * q).max(1) as u32) as usize;
    sched[s4].push(Fault::Partition {
        dc: rng.below(cfg.dcs as u32) as usize,
        rounds: clamp(s4, 1 + rng.below(2) as u64).max(1),
    });
    // random extras (never a second fabric crash — one full restore
    // per run keeps the runtime bounded)
    for round in 1..r.saturating_sub(2) {
        if rng.next_f64() >= cfg.extra_fault_prob {
            continue;
        }
        let fault = match rng.below(3) {
            0 => Fault::Stall {
                replica: rng.below(n) as usize,
                rounds: clamp(round, 1 + rng.below(2) as u64),
            },
            1 => Fault::Partition {
                dc: rng.below(cfg.dcs as u32) as usize,
                rounds: clamp(round, 1 + rng.below(2) as u64),
            },
            _ => Fault::ReplicaCrash { replica: rng.below(n) as usize },
        };
        let dead = matches!(
            fault,
            Fault::Stall { rounds: 0, .. } | Fault::Partition { rounds: 0, .. }
        );
        if !dead {
            sched[round].push(fault);
        }
    }
    sched
}

/// What the traffic drivers read while the fabric churns underneath:
/// per-replica clients plus the health board they route through.  The
/// main thread takes the write lock around every restart/restore, so
/// drivers never score a mid-teardown engine.
struct ServingView {
    clients: Vec<ServeClient>,
    board: Arc<HealthBoard>,
}

type Published = Arc<RwLock<Vec<(u64, Vec<Vec<f32>>)>>>;

#[allow(clippy::type_complexity)]
fn traffic_driver(
    view: Arc<RwLock<ServingView>>,
    probes: Vec<Request>,
    published: Published,
    stop: Arc<AtomicBool>,
    offset: usize,
) -> (u64, u64, u64, u64, HashSet<u64>) {
    let mut checks = 0u64;
    let mut torn = 0u64;
    let mut routed_around = 0u64;
    let mut errors = 0u64;
    let mut versions = HashSet::new();
    let mut i = offset;
    // ordering: Relaxed — the flag only ends the loop; drivers join
    // afterwards, so no data is published through it.
    while !stop.load(Ordering::Relaxed) {
        let probe_idx = i % probes.len();
        let scored = {
            // Poison recovery: the view is replaced wholesale under the
            // write guard (clients vec + board assigned as units), so a
            // poisoned lock still holds a coherent serving view.
            let v = view.read().unwrap_or_else(|e| e.into_inner());
            let hint = i % v.clients.len();
            let idx = v.board.route(hint);
            if idx != hint {
                routed_around += 1;
            }
            v.clients[idx].score(probes[probe_idx].clone())
        };
        i += 1;
        let resp = match scored {
            Ok(r) => r,
            Err(_) => {
                // engine raced a restart; skip, never count as torn
                errors += 1;
                std::thread::yield_now();
                continue;
            }
        };
        checks += 1;
        // poison recovery: snapshots are appended whole under the guard
        let reg = published.read().unwrap_or_else(|e| e.into_inner());
        match reg
            .iter()
            .rev()
            .find(|(_, scores)| scores[probe_idx] == resp.scores)
        {
            Some((seq, _)) => {
                versions.insert(*seq);
            }
            None => torn += 1,
        }
    }
    (checks, torn, routed_around, errors, versions)
}

fn clients_of(fabric: &FleetFabric) -> Vec<ServeClient> {
    fabric
        .replicas()
        .iter()
        .map(|r| {
            r.client().unwrap_or_else(|| {
                // ChaosConfig always sets `serve` on the fleet config
                panic!("chaos replica has no serving engine")
            })
        })
        .collect()
}

/// Run one chaos soak.  Prints the reproducing seed first; invariant
/// verdicts live in the report ([`ChaosReport::assert_healthy`]).
pub fn run_chaos_soak(cfg: ChaosConfig) -> ChaosReport {
    println!("chaos seed: {:#x}", cfg.seed);
    let mut chaos_rng = Pcg32::seeded(cfg.seed);
    let schedule = fault_schedule(&cfg, &mut chaos_rng);

    let mut spec = DatasetSpec::tiny();
    spec.cat_fields = 4;
    let fields = spec.fields();
    let model_cfg = ModelConfig::deep_ffm(fields, 2, 1 << 12, &[8]);
    let template = Regressor::new(&model_cfg);
    let mut trainer = template.clone();
    let mut stream =
        SyntheticStream::with_buckets(spec, cfg.seed, model_cfg.buckets);

    let topo = Topology::uniform(
        cfg.dcs,
        cfg.replicas_per_dc,
        LinkSpec::wan(),
        LinkSpec::lan(),
    );
    let mut fcfg = FleetConfig::new(topo, cfg.mode);
    fcfg.strategy = Strategy::Auto;
    fcfg.seed = cfg.seed ^ 0x11;
    fcfg.serve = Some(ServeConfig {
        workers: 1,
        max_batch: 32,
        max_wait_us: 100,
        context_cache_entries: 1_024,
        max_group_candidates: 1024,
        ..ServeConfig::default()
    });
    let model_name = fcfg.model_name.clone();
    let mut fabric = FleetFabric::new(fcfg.clone(), &template);
    let registry = ObsRegistry::new();
    fabric.set_obs(&registry);

    let ckpt_path = std::env::temp_dir().join(format!(
        "fw_chaos_{}_{:?}_{:x}.ckpt",
        std::process::id(),
        cfg.mode,
        cfg.seed
    ));
    let n_replicas = cfg.dcs * cfg.replicas_per_dc;
    // durable cursors, refreshed at every fabric checkpoint; a crashed
    // replica restarts from these, not from live state
    let mut cursors: Vec<ReplicaCheckpoint> =
        (0..n_replicas).map(|i| fabric.checkpoint_replica(i)).collect();
    let mut have_checkpoint = false;

    let mut gen = TraceGenerator::new(
        cfg.seed ^ 0x7ea5,
        fields,
        2,
        model_cfg.buckets,
        4,
    );
    let probes: Vec<Request> = (0..cfg.probes.max(1))
        .map(|_| gen.next_request(&model_name))
        .collect();

    let published: Published = Arc::new(RwLock::new(vec![(
        0,
        probe_scores(&template, &probes),
    )]));
    let stop = Arc::new(AtomicBool::new(false));
    let view = Arc::new(RwLock::new(ServingView {
        clients: clients_of(&fabric),
        board: fabric.health_board().clone(),
    }));

    let mut drivers = Vec::new();
    for t in 0..cfg.traffic_threads.max(1) {
        let view = view.clone();
        let probes = probes.clone();
        let published = published.clone();
        let stop = stop.clone();
        drivers.push(
            std::thread::Builder::new()
                .name(format!("fw-chaos-traffic-{t}"))
                .spawn(move || traffic_driver(view, probes, published, stop, t))
                .unwrap_or_else(|e| {
                    // a chaos soak without drivers observes nothing
                    panic!("cannot spawn traffic driver {t}: {e}")
                }),
        );
    }

    let mut faults = FaultLog::default();
    let mut serve_errors = 0u64;
    let mut rounds = Vec::with_capacity(cfg.rounds);
    for r in 0..cfg.rounds {
        for fault in &schedule[r] {
            match *fault {
                Fault::Stall { replica, rounds } => {
                    fabric.stall_replica(replica, rounds);
                    faults.stalls += 1;
                }
                Fault::Partition { dc, rounds } => {
                    fabric.partition_dc(dc, rounds);
                    faults.partitions += 1;
                }
                Fault::ReplicaCrash { replica } => {
                    // block traffic while the engine is swapped
                    // (poison recovery: see `traffic_driver`)
                    let mut v = view.write().unwrap_or_else(|e| e.into_inner());
                    fabric
                        .restart_replica(replica, &cursors[replica])
                        .unwrap_or_else(|e| {
                            panic!(
                                "{:?} seed {:#x}: restart replica {replica}: {e}",
                                cfg.mode, cfg.seed
                            )
                        });
                    v.clients[replica] = fabric.replicas()[replica]
                        .client()
                        .unwrap_or_else(|| {
                            panic!(
                                "{:?} seed {:#x}: restarted replica {replica} \
                                 has no serving engine",
                                cfg.mode, cfg.seed
                            )
                        });
                    faults.replica_restarts += 1;
                }
                Fault::FabricCrash => {
                    if !have_checkpoint {
                        continue; // schedule guarantees this never fires
                    }
                    let restored = FleetFabric::restore_from_path(
                        fcfg.clone(),
                        &template,
                        &ckpt_path,
                    )
                    .unwrap_or_else(|e| {
                        panic!(
                            "{:?} seed {:#x}: fabric restore: {e}",
                            cfg.mode, cfg.seed
                        )
                    });
                    let old = std::mem::replace(&mut fabric, restored);
                    fabric.set_obs(&registry);
                    // poison recovery: see `traffic_driver`
                    let mut v = view.write().unwrap_or_else(|e| e.into_inner());
                    serve_errors += old
                        .shutdown()
                        .into_iter()
                        .flatten()
                        .map(|s| s.errors)
                        .sum::<u64>();
                    v.clients = clients_of(&fabric);
                    v.board = fabric.health_board().clone();
                    cursors = (0..n_replicas)
                        .map(|i| fabric.checkpoint_replica(i))
                        .collect();
                    faults.fabric_restores += 1;
                }
            }
        }

        let chunk = stream.take_examples(cfg.examples_per_round);
        train_chunk(
            &mut trainer,
            &chunk,
            HogwildConfig { threads: cfg.train_threads.max(1) },
            1_000,
        );
        let published2 = published.clone();
        let probes_ref = &probes;
        let outcome = fabric
            .publish_with(&trainer, |seq, fresh| {
                let scores = probe_scores(fresh, probes_ref);
                // poison recovery: see `traffic_driver`
                published2
                    .write()
                    .unwrap_or_else(|e| e.into_inner())
                    .push((seq, scores));
            })
            .unwrap_or_else(|e| {
                panic!("{:?} seed {:#x} round {r}: {e}", cfg.mode, cfg.seed)
            });
        rounds.push(outcome);

        if (r + 1) % cfg.checkpoint_every.max(1) == 0 {
            fabric.write_checkpoint(&ckpt_path).unwrap_or_else(|e| {
                panic!("{:?} seed {:#x}: checkpoint: {e}", cfg.mode, cfg.seed)
            });
            cursors =
                (0..n_replicas).map(|i| fabric.checkpoint_replica(i)).collect();
            have_checkpoint = true;
        }
    }

    let caught_up_at_converge = fabric.converge().unwrap_or_else(|e| {
        panic!("{:?} seed {:#x}: converge: {e}", cfg.mode, cfg.seed)
    });

    let reference = fabric
        .reference()
        .unwrap_or_else(|| {
            panic!(
                "{:?} seed {:#x}: no reference model after {} rounds",
                cfg.mode, cfg.seed, cfg.rounds
            )
        })
        .pool
        .weights
        .clone();
    let first = fabric.replicas()[0].model().pool.weights.clone();
    let mut replicas_bit_identical = true;
    let mut replicas_match_reference = true;
    for rep in fabric.replicas() {
        let model = rep.model();
        if model.pool.weights != first {
            replicas_bit_identical = false;
        }
        if model.pool.weights != reference {
            replicas_match_reference = false;
        }
    }

    // ordering: Relaxed — see the load in `traffic_driver`.
    stop.store(true, Ordering::Relaxed);
    let mut probe_checks = 0u64;
    let mut torn_responses = 0u64;
    let mut routed_around = 0u64;
    let mut probe_errors = 0u64;
    let mut versions = HashSet::new();
    for d in drivers {
        let (c, t, ra, e, v) = match d.join() {
            Ok(r) => r,
            // re-raise the driver's own panic (it carries the failed
            // invariant) instead of a generic join failure
            Err(payload) => std::panic::resume_unwind(payload),
        };
        probe_checks += c;
        torn_responses += t;
        routed_around += ra;
        probe_errors += e;
        versions.extend(v);
    }

    let metrics = fabric.metrics();
    serve_errors += fabric
        .shutdown()
        .into_iter()
        .flatten()
        .map(|s| s.errors)
        .sum::<u64>();
    let _ = std::fs::remove_file(&ckpt_path);
    ChaosReport {
        mode: cfg.mode,
        seed: cfg.seed,
        rounds,
        faults,
        probe_checks,
        torn_responses,
        versions_observed: versions.len(),
        routed_around,
        probe_errors,
        caught_up_at_converge,
        replicas_bit_identical,
        replicas_match_reference,
        serve_errors,
        health_transitions: registry
            .counter_value("fw_fleet_health_transitions_total")
            .unwrap_or(0),
        recovery_samples: registry
            .histogram_snapshot("fw_recovery_replay_ns")
            .map(|h| h.count())
            .unwrap_or(0),
        metrics,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_covers_every_fault_kind_and_is_reproducible() {
        let cfg = ChaosConfig::full(UpdateMode::QuantPatch, 0xc4a05);
        let mut rng = Pcg32::seeded(cfg.seed);
        let sched = fault_schedule(&cfg, &mut rng);
        assert_eq!(sched.len(), cfg.rounds);
        let all: Vec<&Fault> = sched.iter().flatten().collect();
        assert!(all.iter().any(|f| matches!(f, Fault::Stall { .. })));
        assert!(all.iter().any(|f| matches!(f, Fault::Partition { .. })));
        assert!(all.iter().any(|f| matches!(f, Fault::ReplicaCrash { .. })));
        assert!(all.iter().any(|f| matches!(f, Fault::FabricCrash)));
        // stalls/partitions always expire before the final round
        for (round, faults) in sched.iter().enumerate() {
            for f in faults {
                match *f {
                    Fault::Stall { rounds, .. } | Fault::Partition { rounds, .. } => {
                        assert!(rounds >= 1);
                        assert!(round + rounds as usize <= cfg.rounds - 1);
                    }
                    _ => {}
                }
            }
        }
        // same seed → same schedule
        let mut rng2 = Pcg32::seeded(cfg.seed);
        assert_eq!(fault_schedule(&cfg, &mut rng2), sched);
        // different seed → (almost surely) different schedule
        let mut rng3 = Pcg32::seeded(cfg.seed ^ 1);
        assert_ne!(fault_schedule(&cfg, &mut rng3), sched);
    }

    #[test]
    fn chaos_soak_smoke() {
        // one mode at CI scale; the ≥20-round soak across modes runs in
        // tests/chaos_soak.rs
        let report = run_chaos_soak(ChaosConfig::smoke(UpdateMode::QuantPatch, 7));
        report.assert_healthy();
        assert_eq!(report.rounds.len(), 8);
    }
}
