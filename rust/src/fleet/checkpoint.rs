//! Durable crash-recovery checkpoints.
//!
//! One on-disk format (`FWCKPT1`) shared by the fabric and the deploy
//! loop: a little-endian length-prefixed section stream wrapped in a
//! magic header and a CRC32 trailer, written via temp-file +
//! `rename` so a crash mid-write can never leave a torn checkpoint
//! where a good one used to be — readers either see the complete old
//! file or the complete new one.
//!
//! ```text
//! ┌──────────┬──────────────────────────────┬───────────┐
//! │ FWCKPT1\0 │  payload (ByteWriter stream) │ CRC32(all) │
//! └──────────┴──────────────────────────────┴───────────┘
//! ```
//!
//! The payload for a fabric checkpoint ([`FabricCheckpoint`]) is the
//! *complete* distribution state: the sender pipeline's diff bases,
//! the retained patch log, every replica's seq cursor + receiver
//! base, the deterministic RNG position, fault-injection countdowns,
//! and all counters/ledgers.  Restoring it therefore resumes the run
//! **bit-identically** — the next publish encodes the same diff,
//! draws the same loss coins, and bills the same ledgers as an
//! uninterrupted fabric would have.

use std::path::Path;

use crate::fleet::metrics::{LagStat, LinkLedger};
use crate::transfer::{FleetError, UpdateMode};
use crate::util::crc32::crc32;

/// File magic; the trailing byte doubles as a format version slot.
pub const MAGIC: [u8; 8] = *b"FWCKPT1\0";

// ------------------------------------------------------------ framing

/// Wrap a payload in magic + CRC32 trailer.
pub fn seal(payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(MAGIC.len() + payload.len() + 4);
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(payload);
    let crc = crc32(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// Verify magic + CRC and return the payload slice.
pub fn unseal(bytes: &[u8]) -> Result<&[u8], FleetError> {
    if bytes.len() < MAGIC.len() + 4 {
        return Err(FleetError::Corrupt(format!(
            "checkpoint too short ({} bytes)",
            bytes.len()
        )));
    }
    if bytes[..MAGIC.len()] != MAGIC {
        return Err(FleetError::Corrupt("bad checkpoint magic".into()));
    }
    let body_end = bytes.len() - 4;
    let t = &bytes[body_end..];
    let stored = u32::from_le_bytes([t[0], t[1], t[2], t[3]]);
    let actual = crc32(&bytes[..body_end]);
    if stored != actual {
        return Err(FleetError::Corrupt(format!(
            "checkpoint CRC mismatch (stored {stored:#010x}, computed {actual:#010x})"
        )));
    }
    Ok(&bytes[MAGIC.len()..body_end])
}

/// Seal `payload` and write it to `path` atomically: the sealed bytes
/// go to a sibling `.tmp` file first, then `rename` over the target.
pub fn write_atomic(path: &Path, payload: &[u8]) -> Result<(), FleetError> {
    let sealed = seal(payload);
    let mut tmp = path.as_os_str().to_owned();
    tmp.push(".tmp");
    let tmp = std::path::PathBuf::from(tmp);
    std::fs::write(&tmp, &sealed)
        .map_err(|e| FleetError::Io(format!("write {}: {e}", tmp.display())))?;
    std::fs::rename(&tmp, path)
        .map_err(|e| FleetError::Io(format!("rename to {}: {e}", path.display())))
}

/// Read and verify a sealed checkpoint file; returns the payload.
pub fn read_file(path: &Path) -> Result<Vec<u8>, FleetError> {
    let bytes = std::fs::read(path)
        .map_err(|e| FleetError::Io(format!("read {}: {e}", path.display())))?;
    unseal(&bytes).map(|p| p.to_vec())
}

/// Little-endian section writer for checkpoint payloads.
#[derive(Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl std::fmt::Debug for ByteWriter {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ByteWriter").finish_non_exhaustive()
    }
}

impl ByteWriter {
    pub fn new() -> Self {
        ByteWriter { buf: Vec::new() }
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f64(&mut self, v: f64) {
        self.put_u64(v.to_bits());
    }

    /// Length-prefixed byte section.
    pub fn put_bytes(&mut self, v: &[u8]) {
        self.put_u64(v.len() as u64);
        self.buf.extend_from_slice(v);
    }

    pub fn put_opt_bytes(&mut self, v: Option<&[u8]>) {
        match v {
            Some(b) => {
                self.put_u8(1);
                self.put_bytes(b);
            }
            None => self.put_u8(0),
        }
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }
}

/// Mirror reader; every getter fails with [`FleetError::Corrupt`] on
/// truncation instead of panicking, so a damaged file surfaces as a
/// matchable error.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl std::fmt::Debug for ByteReader<'_> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ByteReader").finish_non_exhaustive()
    }
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        ByteReader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], FleetError> {
        if self.pos + n > self.buf.len() {
            return Err(FleetError::Corrupt(format!(
                "checkpoint truncated at offset {} (wanted {n} more bytes of {})",
                self.pos,
                self.buf.len()
            )));
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    pub fn get_u8(&mut self) -> Result<u8, FleetError> {
        Ok(self.take(1)?[0])
    }

    pub fn get_u32(&mut self) -> Result<u32, FleetError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn get_u64(&mut self) -> Result<u64, FleetError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    pub fn get_f64(&mut self) -> Result<f64, FleetError> {
        Ok(f64::from_bits(self.get_u64()?))
    }

    pub fn get_bytes(&mut self) -> Result<Vec<u8>, FleetError> {
        let len = self.get_u64()? as usize;
        Ok(self.take(len)?.to_vec())
    }

    pub fn get_opt_bytes(&mut self) -> Result<Option<Vec<u8>>, FleetError> {
        match self.get_u8()? {
            0 => Ok(None),
            1 => Ok(Some(self.get_bytes()?)),
            t => Err(FleetError::Corrupt(format!("bad option tag {t}"))),
        }
    }

    /// Assert the payload was consumed exactly.
    pub fn done(&self) -> Result<(), FleetError> {
        if self.pos == self.buf.len() {
            Ok(())
        } else {
            Err(FleetError::Corrupt(format!(
                "{} trailing bytes after checkpoint payload",
                self.buf.len() - self.pos
            )))
        }
    }
}

pub fn mode_tag(mode: UpdateMode) -> u8 {
    match mode {
        UpdateMode::Raw => 0,
        UpdateMode::Quant => 1,
        UpdateMode::PatchOnly => 2,
        UpdateMode::QuantPatch => 3,
    }
}

pub fn mode_from_tag(tag: u8) -> Result<UpdateMode, FleetError> {
    Ok(match tag {
        0 => UpdateMode::Raw,
        1 => UpdateMode::Quant,
        2 => UpdateMode::PatchOnly,
        3 => UpdateMode::QuantPatch,
        t => return Err(FleetError::Corrupt(format!("bad update-mode tag {t}"))),
    })
}

// ----------------------------------------------------- fabric payload

/// One replica's durable cursor: last applied seq plus the receiver
/// base bytes the next chained patch applies against.
#[derive(Clone, Debug, PartialEq)]
pub struct ReplicaCheckpoint {
    pub seq: u64,
    pub base: Option<Vec<u8>>,
    /// Health gauge encoding ([`crate::fleet::health::HealthState`]).
    pub health: u8,
    /// Heartbeat age (consecutive failed contacts) at checkpoint time.
    pub failed_rounds: u32,
}

/// The complete distribution-plane state of a [`crate::fleet::FleetFabric`].
#[derive(Clone, Debug)]
pub struct FabricCheckpoint {
    pub mode: UpdateMode,
    pub head: u64,
    /// Exact PCG position `(state, inc)` of the loss/jitter RNG.
    pub rng_state: (u64, u64),
    /// Sender pipeline diff bases.
    pub prev_raw: Option<Vec<u8>>,
    pub prev_quant: Option<Vec<u8>>,
    /// Retained update log; `log[i]` is publish seq `i+1`, blanked
    /// (compacted) entries are empty.
    pub log: Vec<Vec<u8>>,
    pub log_blanked: u64,
    pub replicas: Vec<ReplicaCheckpoint>,
    pub rounds: u64,
    pub max_skew: u64,
    pub replays: u64,
    pub resyncs: u64,
    pub converged_rounds: u64,
    pub retries: u64,
    pub skipped_publishes: u64,
    pub lag: Vec<LagStat>,
    pub inter: Vec<LinkLedger>,
    pub intra: Vec<LinkLedger>,
    pub forced_drops: u32,
    /// Per-DC partition countdowns (rounds remaining).
    pub partitioned: Vec<u64>,
    /// Per-replica stall countdowns (rounds remaining).
    pub stalled: Vec<u64>,
}

impl FabricCheckpoint {
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.put_u8(1); // payload version
        w.put_u8(mode_tag(self.mode));
        w.put_u64(self.head);
        w.put_u64(self.rng_state.0);
        w.put_u64(self.rng_state.1);
        w.put_opt_bytes(self.prev_raw.as_deref());
        w.put_opt_bytes(self.prev_quant.as_deref());
        w.put_u64(self.log.len() as u64);
        for entry in &self.log {
            w.put_bytes(entry);
        }
        w.put_u64(self.log_blanked);
        w.put_u64(self.replicas.len() as u64);
        for r in &self.replicas {
            w.put_u64(r.seq);
            w.put_opt_bytes(r.base.as_deref());
            w.put_u8(r.health);
            w.put_u32(r.failed_rounds);
        }
        w.put_u64(self.rounds);
        w.put_u64(self.max_skew);
        w.put_u64(self.replays);
        w.put_u64(self.resyncs);
        w.put_u64(self.converged_rounds);
        w.put_u64(self.retries);
        w.put_u64(self.skipped_publishes);
        w.put_u64(self.lag.len() as u64);
        for l in &self.lag {
            w.put_u64(l.publishes);
            w.put_f64(l.total_seconds);
            w.put_f64(l.last_seconds);
        }
        for links in [&self.inter, &self.intra] {
            w.put_u64(links.len() as u64);
            for l in links.iter() {
                w.put_u64(l.bytes);
                w.put_f64(l.seconds);
                w.put_u64(l.messages);
                w.put_u64(l.drops);
            }
        }
        w.put_u32(self.forced_drops);
        w.put_u64(self.partitioned.len() as u64);
        for &p in &self.partitioned {
            w.put_u64(p);
        }
        w.put_u64(self.stalled.len() as u64);
        for &s in &self.stalled {
            w.put_u64(s);
        }
        w.finish()
    }

    pub fn from_bytes(payload: &[u8]) -> Result<FabricCheckpoint, FleetError> {
        let mut r = ByteReader::new(payload);
        let version = r.get_u8()?;
        if version != 1 {
            return Err(FleetError::Corrupt(format!(
                "unsupported fabric checkpoint version {version}"
            )));
        }
        let mode = mode_from_tag(r.get_u8()?)?;
        let head = r.get_u64()?;
        let rng_state = (r.get_u64()?, r.get_u64()?);
        let prev_raw = r.get_opt_bytes()?;
        let prev_quant = r.get_opt_bytes()?;
        let n_log = r.get_u64()? as usize;
        let mut log = Vec::with_capacity(n_log);
        for _ in 0..n_log {
            log.push(r.get_bytes()?);
        }
        let log_blanked = r.get_u64()?;
        let n_replicas = r.get_u64()? as usize;
        let mut replicas = Vec::with_capacity(n_replicas);
        for _ in 0..n_replicas {
            replicas.push(ReplicaCheckpoint {
                seq: r.get_u64()?,
                base: r.get_opt_bytes()?,
                health: r.get_u8()?,
                failed_rounds: r.get_u32()?,
            });
        }
        let rounds = r.get_u64()?;
        let max_skew = r.get_u64()?;
        let replays = r.get_u64()?;
        let resyncs = r.get_u64()?;
        let converged_rounds = r.get_u64()?;
        let retries = r.get_u64()?;
        let skipped_publishes = r.get_u64()?;
        let n_lag = r.get_u64()? as usize;
        let mut lag = Vec::with_capacity(n_lag);
        for _ in 0..n_lag {
            lag.push(LagStat {
                publishes: r.get_u64()?,
                total_seconds: r.get_f64()?,
                last_seconds: r.get_f64()?,
            });
        }
        let mut ledgers = |r: &mut ByteReader| -> Result<Vec<LinkLedger>, FleetError> {
            let n = r.get_u64()? as usize;
            let mut out = Vec::with_capacity(n);
            for _ in 0..n {
                out.push(LinkLedger {
                    bytes: r.get_u64()?,
                    seconds: r.get_f64()?,
                    messages: r.get_u64()?,
                    drops: r.get_u64()?,
                });
            }
            Ok(out)
        };
        let inter = ledgers(&mut r)?;
        let intra = ledgers(&mut r)?;
        let forced_drops = r.get_u32()?;
        let n_part = r.get_u64()? as usize;
        let mut partitioned = Vec::with_capacity(n_part);
        for _ in 0..n_part {
            partitioned.push(r.get_u64()?);
        }
        let n_stall = r.get_u64()? as usize;
        let mut stalled = Vec::with_capacity(n_stall);
        for _ in 0..n_stall {
            stalled.push(r.get_u64()?);
        }
        r.done()?;
        Ok(FabricCheckpoint {
            mode,
            head,
            rng_state,
            prev_raw,
            prev_quant,
            log,
            log_blanked,
            replicas,
            rounds,
            max_skew,
            replays,
            resyncs,
            converged_rounds,
            retries,
            skipped_publishes,
            lag,
            inter,
            intra,
            forced_drops,
            partitioned,
            stalled,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> FabricCheckpoint {
        FabricCheckpoint {
            mode: UpdateMode::QuantPatch,
            head: 7,
            rng_state: (0xdead_beef_cafe_f00d, 0x1234_5678_9abc_def1),
            prev_raw: Some(vec![1, 2, 3, 4]),
            prev_quant: Some(vec![9, 8, 7]),
            log: vec![Vec::new(), vec![5, 6], vec![7]],
            log_blanked: 1,
            replicas: vec![
                ReplicaCheckpoint {
                    seq: 7,
                    base: Some(vec![9, 8, 7]),
                    health: 0,
                    failed_rounds: 0,
                },
                ReplicaCheckpoint {
                    seq: 5,
                    base: Some(vec![4, 4]),
                    health: 2,
                    failed_rounds: 3,
                },
            ],
            rounds: 7,
            max_skew: 2,
            replays: 1,
            resyncs: 1,
            converged_rounds: 5,
            retries: 4,
            skipped_publishes: 2,
            lag: vec![
                LagStat { publishes: 7, total_seconds: 3.5, last_seconds: 0.5 },
                LagStat { publishes: 5, total_seconds: 9.0, last_seconds: 2.0 },
            ],
            inter: vec![LinkLedger {
                bytes: 4096,
                seconds: 1.25,
                messages: 9,
                drops: 2,
            }],
            intra: vec![LinkLedger::default()],
            forced_drops: 1,
            partitioned: vec![0, 2],
            stalled: vec![0, 1],
        }
    }

    #[test]
    fn payload_roundtrip_is_exact() {
        let ckpt = sample();
        let bytes = ckpt.to_bytes();
        let back = FabricCheckpoint::from_bytes(&bytes).unwrap();
        assert_eq!(back.mode, ckpt.mode);
        assert_eq!(back.head, ckpt.head);
        assert_eq!(back.rng_state, ckpt.rng_state);
        assert_eq!(back.prev_raw, ckpt.prev_raw);
        assert_eq!(back.prev_quant, ckpt.prev_quant);
        assert_eq!(back.log, ckpt.log);
        assert_eq!(back.log_blanked, ckpt.log_blanked);
        assert_eq!(back.replicas, ckpt.replicas);
        assert_eq!(back.retries, ckpt.retries);
        assert_eq!(back.partitioned, ckpt.partitioned);
        assert_eq!(back.stalled, ckpt.stalled);
        assert_eq!(back.lag.len(), 2);
        assert_eq!(back.lag[1].publishes, 5);
        assert_eq!(back.inter[0].bytes, 4096);
        assert_eq!(back.forced_drops, 1);
    }

    #[test]
    fn seal_detects_any_single_byte_corruption() {
        let payload = sample().to_bytes();
        let sealed = seal(&payload);
        assert_eq!(unseal(&sealed).unwrap(), &payload[..]);
        // flip one byte anywhere — magic, payload, or trailer — and
        // unseal must refuse
        for pos in [0, MAGIC.len() + 3, sealed.len() - 2] {
            let mut bad = sealed.clone();
            bad[pos] ^= 0x40;
            assert!(
                matches!(unseal(&bad), Err(FleetError::Corrupt(_))),
                "corruption at {pos} went undetected"
            );
        }
        assert!(matches!(unseal(&sealed[..4]), Err(FleetError::Corrupt(_))));
    }

    #[test]
    fn atomic_write_then_read_roundtrips() {
        let dir = std::env::temp_dir()
            .join(format!("fwckpt_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("fabric.ckpt");
        let ckpt = sample();
        write_atomic(&path, &ckpt.to_bytes()).unwrap();
        // no temp file left behind
        assert!(!dir.join("fabric.ckpt.tmp").exists());
        let payload = read_file(&path).unwrap();
        let back = FabricCheckpoint::from_bytes(&payload).unwrap();
        assert_eq!(back.head, ckpt.head);
        // overwrite is atomic too: the new content fully replaces
        let mut ckpt2 = ckpt.clone();
        ckpt2.head = 99;
        write_atomic(&path, &ckpt2.to_bytes()).unwrap();
        let back2 =
            FabricCheckpoint::from_bytes(&read_file(&path).unwrap()).unwrap();
        assert_eq!(back2.head, 99);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_file_is_io_not_corrupt() {
        let err = read_file(Path::new("/nonexistent/fw.ckpt")).unwrap_err();
        assert!(matches!(err, FleetError::Io(_)), "{err:?}");
    }

    #[test]
    fn truncated_payload_is_matchable() {
        let bytes = sample().to_bytes();
        let err = FabricCheckpoint::from_bytes(&bytes[..bytes.len() / 2])
            .unwrap_err();
        assert!(matches!(err, FleetError::Corrupt(_)), "{err:?}");
    }
}
