//! §4.2 — Hogwild lock-free multithreaded training.
//!
//! "Weight overlaps/overrides are allowed as the trade off for
//! multi-threaded updates. [...] In practice, the times for bigger
//! models went from multiple weeks to days. [...] Weight degradation
//! due to Hogwild was A/B tested and does not appear to cause any
//! noticeable RPM drops."
//!
//! Implementation: N worker threads share one [`Regressor`] *without
//! synchronization*, exactly as in Recht et al. (Hogwild!, NeurIPS'11)
//! and the production engine.  Each worker keeps its own [`Workspace`]
//! and consumes its own shard of the input chunk.  Races on individual
//! f32 weights can lose updates — that is the accepted trade-off; the
//! sparse, hashed gradient footprint makes collisions rare.
//!
//! # Safety
//!
//! The shared-`&mut` aliasing below is intentional and confined to the
//! weight pool's f32/acc arrays: every racy access is a plain aligned
//! 4-byte load or store (x86: single `mov`), so torn values cannot
//! occur on the supported targets; stale values are accepted by the
//! algorithm.  The block/layout structure itself is never mutated
//! during a Hogwild round.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::eval::RollingAuc;
use crate::feature::Example;
use crate::model::regressor::Regressor;
use crate::model::Workspace;

/// Cell that hands out racy mutable references to the shared model.
struct RacyRegressor {
    ptr: *mut Regressor,
}

// SAFETY: the pointee outlives every worker (threads are scoped inside
// `train_chunk_batched`, which holds `&mut Regressor` for the whole
// round) and cross-thread access follows the Hogwild contract above.
unsafe impl Send for RacyRegressor {}
// SAFETY: see the Send impl — shared access is the Hogwild contract.
unsafe impl Sync for RacyRegressor {}

impl RacyRegressor {
    /// # Safety
    /// Caller must uphold the Hogwild contract described above: the
    /// returned aliasing `&mut` may only be used for plain aligned
    /// 4-byte weight loads/stores, never structural mutation.
    #[allow(clippy::mut_from_ref)]
    unsafe fn get(&self) -> &mut Regressor {
        // SAFETY: `ptr` was created from a live `&mut Regressor` in
        // `train_chunk_batched` and the scoped threads it spawns cannot
        // outlive that borrow; aliasing is the documented Hogwild
        // trade-off (module docs).
        unsafe { &mut *self.ptr }
    }
}

/// Hogwild trainer configuration.
#[derive(Clone, Copy, Debug)]
pub struct HogwildConfig {
    pub threads: usize,
}

impl Default for HogwildConfig {
    fn default() -> Self {
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4);
        HogwildConfig { threads }
    }
}

/// Result of one Hogwild round.
#[derive(Clone, Debug)]
pub struct HogwildStats {
    pub examples: usize,
    pub threads: usize,
    pub wall_seconds: f64,
    /// Per-window AUC points (merged across threads, unordered).
    pub auc_points: Vec<f64>,
}

impl HogwildStats {
    /// Training throughput of this chunk.
    pub fn examples_per_sec(&self) -> f64 {
        if self.wall_seconds <= 0.0 {
            0.0
        } else {
            self.examples as f64 / self.wall_seconds
        }
    }

    /// Export this chunk's outcome into a metrics registry: the example
    /// counter accumulates across chunks; throughput and rolling AUC
    /// are last-chunk gauges.
    pub fn export_to(&self, reg: &crate::obs::ObsRegistry) {
        reg.counter("fw_train_examples_total", "examples trained")
            .add(self.examples as u64);
        reg.gauge(
            "fw_train_examples_per_sec",
            "training throughput of the last chunk",
        )
        .set(self.examples_per_sec());
        reg.gauge("fw_train_threads", "Hogwild threads of the last chunk")
            .set(self.threads as f64);
        if let Some(&a) = self.auc_points.last() {
            reg.gauge(
                "fw_train_rolling_auc",
                "last rolling progressive-validation AUC window",
            )
            .set(a);
        }
    }
}

/// Train one chunk of examples across `cfg.threads` threads sharing the
/// regressor without locks.  Returns round statistics.
///
/// Per-example inner loop: delegates to [`train_chunk_batched`] with a
/// micro-batch of 1, which is bit-identical to the sequential trainer
/// at one thread.
pub fn train_chunk(
    reg: &mut Regressor,
    chunk: &[Example],
    cfg: HogwildConfig,
    auc_window: usize,
) -> HogwildStats {
    train_chunk_batched(reg, chunk, cfg, auc_window, 1)
}

/// [`train_chunk`] with minibatch training inside each worker: every
/// 256-example work-stealing slice is carved into `minibatch`-example
/// micro-batches pushed through [`Regressor::learn_batch`], so the
/// dense neural tower runs on the batched GEMM-lite spine while the
/// sparse LR/FFM blocks stay per-example (hashed collisions are the
/// Hogwild contract — §4.2).  `minibatch <= 1` runs the plain
/// per-example `learn()` loop (and `learn_batch` itself delegates
/// 1-example tails to `learn()`), so the B = 1 path stays bit-identical
/// to sequential training.
pub fn train_chunk_batched(
    reg: &mut Regressor,
    chunk: &[Example],
    cfg: HogwildConfig,
    auc_window: usize,
    minibatch: usize,
) -> HogwildStats {
    let threads = cfg.threads.max(1);
    let start = std::time::Instant::now();
    let next = AtomicUsize::new(0);
    let racy = RacyRegressor { ptr: reg as *mut Regressor };
    // Work-stealing over fixed-size slices keeps threads busy even when
    // example costs vary (deep layers skip work per §4.3).
    const BATCH: usize = 256;
    let mut all_points: Vec<Vec<f64>> = Vec::new();
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for _ in 0..threads {
            let next = &next;
            let racy = &racy;
            handles.push(scope.spawn(move || {
                let mut ws = Workspace::new();
                let mut scores = Vec::new();
                let mut eval = RollingAuc::new(auc_window);
                loop {
                    // ordering: Relaxed — the counter only parcels out
                    // disjoint slice bounds; the chunk itself is read
                    // through the pre-spawn shared borrow, and weight
                    // races are the documented Hogwild trade-off.
                    let lo = next.fetch_add(BATCH, Ordering::Relaxed);
                    if lo >= chunk.len() {
                        break;
                    }
                    let hi = (lo + BATCH).min(chunk.len());
                    if minibatch <= 1 {
                        for ex in &chunk[lo..hi] {
                            // SAFETY: Hogwild contract (module docs).
                            let r = unsafe { racy.get() };
                            let p = r.learn(ex, &mut ws);
                            eval.add(p, ex.label);
                        }
                    } else {
                        for mb in chunk[lo..hi].chunks(minibatch) {
                            // SAFETY: Hogwild contract (module docs).
                            let r = unsafe { racy.get() };
                            r.learn_batch(mb, &mut ws, &mut scores);
                            for (&p, ex) in scores.iter().zip(mb) {
                                eval.add(p, ex.label);
                            }
                        }
                    }
                }
                eval.finish();
                eval.points
            }));
        }
        for h in handles {
            match h.join() {
                Ok(points) => all_points.push(points),
                // re-raise the worker's own panic so the root cause
                // (not a generic join failure) reaches the caller
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
    });
    HogwildStats {
        examples: chunk.len(),
        threads,
        wall_seconds: start.elapsed().as_secs_f64(),
        auc_points: all_points.into_iter().flatten().collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::data::synthetic::{DatasetSpec, SyntheticStream};
    use crate::train::Trainer;

    fn chunk(n: usize, seed: u64) -> Vec<Example> {
        let mut s = SyntheticStream::with_buckets(DatasetSpec::tiny(), seed, 256);
        s.take_examples(n)
    }

    #[test]
    fn single_thread_hogwild_matches_sequential() {
        let cfg = ModelConfig::ffm(4, 2, 256);
        let data = chunk(3000, 7);
        let mut a = Regressor::new(&cfg);
        train_chunk(&mut a, &data, HogwildConfig { threads: 1 }, 1000);
        let mut t = Trainer::with_window(Regressor::new(&cfg), 1000);
        t.learn_chunk(&data);
        assert_eq!(a.pool.weights, t.reg.pool.weights);
    }

    #[test]
    fn multithreaded_model_stays_finite_and_learns() {
        let cfg = ModelConfig::deep_ffm(4, 2, 256, &[8]);
        let data = chunk(20_000, 8);
        let mut reg = Regressor::new(&cfg);
        let stats =
            train_chunk(&mut reg, &data, HogwildConfig { threads: 4 }, 2000);
        assert_eq!(stats.examples, 20_000);
        assert_eq!(stats.threads, 4);
        assert!(reg.pool.weights.iter().all(|w| w.is_finite()));
        // trained model beats chance on held-out data
        let test = chunk(3000, 9);
        let mut t = Trainer::new(reg);
        let auc = t.test_auc(&test);
        assert!(auc > 0.55, "hogwild auc {auc}");
    }

    #[test]
    fn stats_export_accumulates_examples() {
        let reg = crate::obs::ObsRegistry::new();
        let stats = HogwildStats {
            examples: 1_000,
            threads: 2,
            wall_seconds: 0.5,
            auc_points: vec![0.6, 0.7],
        };
        stats.export_to(&reg);
        stats.export_to(&reg); // counter accumulates, gauges refresh
        assert_eq!(reg.counter_value("fw_train_examples_total"), Some(2_000));
        assert_eq!(reg.gauge_value("fw_train_examples_per_sec"), Some(2_000.0));
        assert_eq!(reg.gauge_value("fw_train_rolling_auc"), Some(0.7));
    }

    #[test]
    fn all_examples_processed_exactly_once_counterwise() {
        // AUC point count implies every window was seen; with W=500 and
        // 4 threads over 6000 examples there are 12 windows total
        // (distributed across threads ± partials).
        let cfg = ModelConfig::linear(4, 256);
        let data = chunk(6000, 10);
        let mut reg = Regressor::new(&cfg);
        let stats =
            train_chunk(&mut reg, &data, HogwildConfig { threads: 4 }, 500);
        let total: f64 = stats.auc_points.len() as f64;
        assert!(
            (8.0..=16.0).contains(&total),
            "unexpected window count {total}"
        );
    }

    #[test]
    fn empty_chunk_is_noop() {
        let cfg = ModelConfig::linear(4, 256);
        let mut reg = Regressor::new(&cfg);
        let w0 = reg.pool.weights.clone();
        let stats = train_chunk(&mut reg, &[], HogwildConfig { threads: 3 }, 100);
        assert_eq!(stats.examples, 0);
        assert_eq!(reg.pool.weights, w0);
    }

    #[test]
    fn more_threads_than_examples_exits_cleanly() {
        // With 8 threads and 3 examples only one worker wins a
        // fetch_add slice; the others must exit without learning and
        // merge empty AUC windows.
        let cfg = ModelConfig::ffm(4, 2, 256);
        let data = chunk(3, 11);
        let seq = {
            let mut t = Trainer::with_window(Regressor::new(&cfg), 100);
            t.learn_chunk(&data);
            t.reg
        };
        let mut reg = Regressor::new(&cfg);
        let stats = train_chunk(&mut reg, &data, HogwildConfig { threads: 8 }, 100);
        assert_eq!(stats.examples, 3);
        assert_eq!(stats.threads, 8);
        // single winner -> identical to sequential training
        assert_eq!(reg.pool.weights, seq.pool.weights);
        // losers contributed no partial windows beyond the winner's
        assert!(stats.auc_points.len() <= 1, "{:?}", stats.auc_points);
    }

    #[test]
    fn minibatch_hogwild_learns_and_stays_finite() {
        let cfg = ModelConfig::deep_ffm(4, 2, 256, &[8]);
        let data = chunk(20_000, 12);
        let mut reg = Regressor::new(&cfg);
        let stats = train_chunk_batched(
            &mut reg,
            &data,
            HogwildConfig { threads: 4 },
            2000,
            8,
        );
        assert_eq!(stats.examples, 20_000);
        assert!(reg.pool.weights.iter().all(|w| w.is_finite()));
        let test = chunk(3000, 13);
        let mut t = Trainer::new(reg);
        let auc = t.test_auc(&test);
        assert!(auc > 0.55, "minibatch hogwild auc {auc}");
    }

    #[test]
    fn minibatch_one_matches_per_example_bitwise() {
        // The batched entry point with B = 1 must stay on the exact
        // learn() arithmetic (single thread -> fully deterministic).
        let cfg = ModelConfig::deep_ffm(4, 2, 256, &[8]);
        let data = chunk(2000, 14);
        let mut a = Regressor::new(&cfg);
        train_chunk_batched(&mut a, &data, HogwildConfig { threads: 1 }, 500, 1);
        let mut t = Trainer::with_window(Regressor::new(&cfg), 500);
        t.learn_chunk(&data);
        assert_eq!(a.pool.weights, t.reg.pool.weights);
    }
}
