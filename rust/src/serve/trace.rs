//! Synthetic production request traces (DESIGN.md §3).
//!
//! Models the §5 traffic shape: each request carries one *context*
//! (user/page features) and N *candidates* (item features).  Contexts
//! repeat with a Zipf distribution — "part of the feature space is very
//! consistent for each candidate batch" — which is precisely what makes
//! context caching pay off (Figure 4).

use crate::feature::{hash, FeatureSlot};
use crate::serve::Request;
use crate::util::rng::{Pcg32, Zipf};

/// Generates a stream of requests against a model with `fields` total
/// fields, the first `ctx_fields` of which are context.
pub struct TraceGenerator {
    rng: Pcg32,
    ctx_zipf: Zipf,
    cand_zipf: Zipf,
    pub fields: usize,
    pub ctx_fields: usize,
    mask: u32,
    /// Candidates per request.
    pub fanout: usize,
    /// Number of distinct context identities.
    pub ctx_universe: u64,
}

impl std::fmt::Debug for TraceGenerator {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TraceGenerator").finish_non_exhaustive()
    }
}

impl TraceGenerator {
    /// `buckets` must match the served model's bucket count.
    pub fn new(seed: u64, fields: usize, ctx_fields: usize, buckets: u32, fanout: usize) -> Self {
        assert!(ctx_fields < fields);
        assert!(buckets.is_power_of_two());
        TraceGenerator {
            rng: Pcg32::new(seed, 0x7ace),
            ctx_zipf: Zipf::new(5_000, 1.2),
            cand_zipf: Zipf::new(100_000, 1.1),
            fields,
            ctx_fields,
            mask: buckets - 1,
            fanout,
            ctx_universe: 5_000,
        }
    }

    /// Tune context repetition (smaller universe / higher skew = more
    /// cache hits; the Figure-4 sweep varies this).
    pub fn set_context_skew(&mut self, universe: u64, zipf_s: f64) {
        self.ctx_universe = universe;
        self.ctx_zipf = Zipf::new(universe, zipf_s);
    }

    fn slots_for(&mut self, identity: u64, fields: std::ops::Range<usize>, salt: u32) -> Vec<FeatureSlot> {
        fields
            .map(|f| {
                // each field's raw id derives deterministically from the
                // identity, so a repeated context reproduces identical slots
                let id = identity
                    .wrapping_mul(0x9e37_79b9_7f4a_7c15)
                    .wrapping_add(f as u64);
                FeatureSlot {
                    field: f as u16,
                    bucket: hash::id_bucket(salt + f as u32, id, self.mask),
                    value: 1.0,
                }
            })
            .collect()
    }

    /// Next request for `model`.
    pub fn next_request(&mut self, model: &str) -> Request {
        let ctx_id = self.ctx_zipf.sample(&mut self.rng);
        let context = self.slots_for(ctx_id, 0..self.ctx_fields, 0xc0);
        let candidates = (0..self.fanout)
            .map(|_| {
                let cand_id = self.cand_zipf.sample(&mut self.rng);
                self.slots_for(cand_id, self.ctx_fields..self.fields, 0xca)
            })
            .collect();
        Request { model: model.to_string(), context, candidates }
    }

    /// Generate a whole trace.
    pub fn take(&mut self, n: usize, model: &str) -> Vec<Request> {
        (0..n).map(|_| self.next_request(model)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_shape() {
        let mut g = TraceGenerator::new(1, 8, 3, 1 << 10, 5);
        let r = g.next_request("m");
        assert_eq!(r.context.len(), 3);
        assert_eq!(r.candidates.len(), 5);
        assert!(r.candidates.iter().all(|c| c.len() == 5));
        // fields numbered correctly
        assert_eq!(r.context[0].field, 0);
        assert_eq!(r.candidates[0][0].field, 3);
        assert!(r.context.iter().all(|s| s.bucket < (1 << 10)));
    }

    #[test]
    fn contexts_repeat_candidates_vary() {
        let mut g = TraceGenerator::new(2, 6, 2, 1 << 10, 3);
        let reqs = g.take(2000, "m");
        let mut ctx_seen = std::collections::HashSet::new();
        let mut cand_seen = std::collections::HashSet::new();
        for r in &reqs {
            ctx_seen.insert(
                r.context.iter().map(|s| s.bucket).collect::<Vec<_>>(),
            );
            for c in &r.candidates {
                cand_seen.insert(c.iter().map(|s| s.bucket).collect::<Vec<_>>());
            }
        }
        // Zipf contexts collapse to far fewer distinct identities than
        // requests; candidates stay diverse.
        assert!(ctx_seen.len() < 1200, "contexts {}", ctx_seen.len());
        assert!(cand_seen.len() > 2000, "candidates {}", cand_seen.len());
    }

    #[test]
    fn same_identity_same_slots() {
        let mut a = TraceGenerator::new(3, 6, 2, 1 << 10, 1);
        let mut b = TraceGenerator::new(3, 6, 2, 1 << 10, 1);
        let ra = a.next_request("m");
        let rb = b.next_request("m");
        assert_eq!(ra.context, rb.context);
    }

    #[test]
    fn skew_control_changes_repetition() {
        let distinct = |universe, s| {
            let mut g = TraceGenerator::new(4, 6, 2, 1 << 10, 1);
            g.set_context_skew(universe, s);
            let reqs = g.take(3000, "m");
            let mut seen = std::collections::HashSet::new();
            for r in &reqs {
                seen.insert(r.context[0].bucket);
            }
            seen.len()
        };
        assert!(distinct(50, 1.4) < distinct(50_000, 1.01));
    }
}
