//! Feature extraction: murmur-style hashing, the Vowpal-Wabbit-inspired
//! text input format, and namespace (field) descriptors.
//!
//! Fwumious Wabbit inherits VW's input conventions: one example per
//! line, `|NS feat[:value] ...` groups, hashed into a fixed bucket
//! space.  One namespace maps to one FFM *field*.

pub mod hash;
pub mod namespace;
pub mod parser;

/// A single (field, bucket, value) occurrence after hashing.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FeatureSlot {
    /// Field (namespace) index, 0-based, < ModelConfig::fields.
    pub field: u16,
    /// Hashed bucket index, already masked to the model's bucket space.
    pub bucket: u32,
    /// Feature value (1.0 for plain categoricals, log-transformed for
    /// continuous features per the paper's preprocessing).
    pub value: f32,
}

/// A parsed, hashed training/serving example: exactly one feature per
/// field (the production layout; absent fields carry value 0.0 so they
/// contribute nothing to any block).
#[derive(Clone, Debug, PartialEq)]
pub struct Example {
    /// Click label: 1.0 / 0.0.  Serving-time examples carry NaN.
    pub label: f32,
    /// Importance weight (1.0 default).
    pub importance: f32,
    /// One slot per field, index == field id.
    pub slots: Vec<FeatureSlot>,
}

impl Example {
    /// An empty example with `fields` zero-valued slots.
    pub fn empty(fields: usize) -> Self {
        Example {
            label: f32::NAN,
            importance: 1.0,
            slots: (0..fields)
                .map(|f| FeatureSlot { field: f as u16, bucket: 0, value: 0.0 })
                .collect(),
        }
    }

    pub fn fields(&self) -> usize {
        self.slots.len()
    }

    /// True when a label is attached (training examples).
    pub fn is_labeled(&self) -> bool {
        !self.label.is_nan()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_example_contributes_nothing() {
        let e = Example::empty(5);
        assert_eq!(e.fields(), 5);
        assert!(!e.is_labeled());
        assert!(e.slots.iter().all(|s| s.value == 0.0));
        assert_eq!(e.slots[3].field, 3);
    }
}
