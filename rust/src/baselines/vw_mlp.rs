//! VW-mlp baseline: Vowpal Wabbit's `--nn` reduction — a single tanh
//! hidden layer over the hashed inputs plus the direct linear term.
//!
//! The paper observes "adding deep layers to VW models in most cases
//! resulted in worse performance" and substantially longer runtimes;
//! this implementation reproduces the architecture faithfully so the
//! benchmark can reproduce that observation.
//!
//!   h_j   = tanh( Σ_f w_h[bucket_f, j] · x_f )
//!   logit = Σ_f w_l[bucket_f] · x_f + Σ_j v_j · h_j
//!   p     = σ(logit)

use crate::baselines::OnlineModel;
use crate::feature::Example;
use crate::util::math::sigmoid;
use crate::util::rng::Pcg32;

/// VW `--nn <units>` style model.
pub struct VwMlp {
    name: String,
    /// Direct (linear) hashed weights [buckets].
    w_lin: Vec<f32>,
    acc_lin: Vec<f32>,
    /// Hidden hashed weights [buckets * units].
    w_hid: Vec<f32>,
    acc_hid: Vec<f32>,
    /// Output weights [units].
    v: Vec<f32>,
    acc_v: Vec<f32>,
    pub lr: f32,
    pub power_t: f32,
    units: usize,
    mask: u32,
    h: Vec<f32>, // scratch
}

impl std::fmt::Debug for VwMlp {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("VwMlp").finish_non_exhaustive()
    }
}

impl VwMlp {
    pub fn new(buckets: u32, units: usize, lr: f32, power_t: f32, seed: u64) -> Self {
        assert!(buckets.is_power_of_two());
        let mut rng = Pcg32::seeded(seed);
        let n = buckets as usize;
        VwMlp {
            name: "VW-mlp".into(),
            w_lin: vec![0.0; n],
            acc_lin: vec![1.0; n],
            w_hid: (0..n * units).map(|_| rng.normal() * 0.05).collect(),
            acc_hid: vec![1.0; n * units],
            v: (0..units).map(|_| rng.normal() * 0.1).collect(),
            acc_v: vec![1.0; units],
            lr,
            power_t,
            units,
            mask: buckets - 1,
            h: vec![0.0; units],
        }
    }

    fn forward(&mut self, ex: &Example) -> f32 {
        let u = self.units;
        self.h.iter_mut().for_each(|x| *x = 0.0);
        let mut lin = 0.0f32;
        for slot in &ex.slots {
            if slot.value == 0.0 {
                continue;
            }
            let b = (slot.bucket & self.mask) as usize;
            lin += self.w_lin[b] * slot.value;
            let row = &self.w_hid[b * u..(b + 1) * u];
            for j in 0..u {
                self.h[j] += row[j] * slot.value;
            }
        }
        for j in 0..u {
            self.h[j] = self.h[j].tanh();
        }
        let mut s = lin;
        for j in 0..u {
            s += self.v[j] * self.h[j];
        }
        s
    }

    #[inline]
    fn ada(lr: f32, pt: f32, acc: &mut f32, w: &mut f32, g: f32) {
        *acc += g * g;
        let denom = if pt == 0.5 { acc.sqrt() } else { acc.powf(pt) };
        *w -= lr * g / denom;
    }
}

impl OnlineModel for VwMlp {
    fn name(&self) -> &str {
        &self.name
    }

    fn learn(&mut self, ex: &Example) -> f32 {
        let logit = self.forward(ex);
        let p = sigmoid(logit);
        let d = (p - ex.label) * ex.importance;
        if d == 0.0 {
            return p;
        }
        let u = self.units;
        // dlogit/dv_j = h_j ; dlogit/dh_j = v_j ; dh/dpre = 1 - h^2
        let mut dpre = vec![0f32; u];
        for j in 0..u {
            let dv = d * self.h[j];
            dpre[j] = d * self.v[j] * (1.0 - self.h[j] * self.h[j]);
            Self::ada(self.lr, self.power_t, &mut self.acc_v[j], &mut self.v[j], dv);
        }
        for slot in &ex.slots {
            if slot.value == 0.0 {
                continue;
            }
            let b = (slot.bucket & self.mask) as usize;
            Self::ada(
                self.lr,
                self.power_t,
                &mut self.acc_lin[b],
                &mut self.w_lin[b],
                d * slot.value,
            );
            for j in 0..u {
                let idx = b * u + j;
                Self::ada(
                    self.lr,
                    self.power_t,
                    &mut self.acc_hid[idx],
                    &mut self.w_hid[idx],
                    dpre[j] * slot.value,
                );
            }
        }
        p
    }

    fn predict(&mut self, ex: &Example) -> f32 {
        let logit = self.forward(ex);
        sigmoid(logit)
    }

    fn num_weights(&self) -> usize {
        self.w_lin.len() + self.w_hid.len() + self.v.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{DatasetSpec, SyntheticStream};
    use crate::eval::RollingAuc;

    #[test]
    fn learns_above_chance() {
        let mut m = VwMlp::new(256, 4, 0.15, 0.5, 3);
        let mut s = SyntheticStream::with_buckets(DatasetSpec::tiny(), 13, 256);
        let mut roll = RollingAuc::new(2000);
        for _ in 0..14_000 {
            let ex = s.next_example();
            let p = m.learn(&ex);
            roll.add(p, ex.label);
        }
        let last = *roll.points.last().unwrap();
        assert!(last > 0.58, "auc {last}");
    }

    #[test]
    fn gradient_direction_sane() {
        // after many positive examples with a fixed input, p -> 1
        let mut m = VwMlp::new(64, 3, 0.3, 0.5, 5);
        let mut s = SyntheticStream::with_buckets(DatasetSpec::tiny(), 14, 64);
        let mut ex = s.next_example();
        ex.label = 1.0;
        let p0 = m.predict(&ex);
        for _ in 0..200 {
            m.learn(&ex);
        }
        let p1 = m.predict(&ex);
        assert!(p1 > p0 && p1 > 0.9, "p0={p0} p1={p1}");
    }

    #[test]
    fn weights_finite_under_training() {
        let mut m = VwMlp::new(128, 8, 0.5, 0.3, 7);
        let mut s = SyntheticStream::with_buckets(DatasetSpec::tiny(), 15, 128);
        for _ in 0..5000 {
            let ex = s.next_example();
            m.learn(&ex);
        }
        assert!(m.w_hid.iter().all(|w| w.is_finite()));
        assert!(m.v.iter().all(|w| w.is_finite()));
    }
}
