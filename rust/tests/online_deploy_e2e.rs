//! End-to-end soak of the online deployment plane (the tentpole test):
//!
//!   Hogwild train rounds ──► UpdatePipeline (all four Table-4 arms)
//!   ──► SimulatedChannel ──► UpdateReceiver ──► atomic swap into a
//!   live ServingEngine — with traffic-driver threads scoring probes
//!   concurrently the whole time.
//!
//! Each mode runs ≥ 5 rounds and must uphold (see `deploy::harness`):
//!   (a) every served response matches exactly one published snapshot
//!       (previous or fresh — never a torn mix of two weight sets),
//!   (b) receiver-side reconstruction is bit-identical to the sender's
//!       base file (and, for quantized modes, the served weights equal
//!       the dequantized receiver bytes),
//!   (c) held-out AUC of the served model is non-decreasing across
//!       rounds within tolerance.

// Soak/e2e scale: far too slow under the Miri interpreter (~1000x);
// the nightly Miri job covers the scalar kernels and unit props
// instead.
#![cfg(not(miri))]

use fwumious::deploy::harness::{run_soak, SoakConfig};
use fwumious::transfer::UpdateMode;

/// Hogwild interleaving + 2000-sample AUC estimation jitter.
const AUC_TOLERANCE: f64 = 0.04;

#[test]
fn soak_raw_mode() {
    let report = run_soak(SoakConfig::quick(UpdateMode::Raw));
    assert!(report.rounds.len() >= 5);
    report.assert_healthy(AUC_TOLERANCE);
    // raw ships the full inference file every round
    assert_eq!(report.shipped_bytes, report.raw_bytes);
}

#[test]
fn soak_quant_mode() {
    let report = run_soak(SoakConfig::quick(UpdateMode::Quant));
    assert!(report.rounds.len() >= 5);
    report.assert_healthy(AUC_TOLERANCE);
    // 16-bit codes: roughly half the raw f32 payload every round
    assert!(
        report.shipped_bytes < report.raw_bytes * 3 / 4,
        "quant shipped {} !< 3/4 of raw {}",
        report.shipped_bytes,
        report.raw_bytes
    );
}

#[test]
fn soak_patch_mode() {
    let report = run_soak(SoakConfig::quick(UpdateMode::PatchOnly));
    assert!(report.rounds.len() >= 5);
    report.assert_healthy(AUC_TOLERANCE);
    // bootstrap round ships the full file; steady-state patches are
    // smaller than the raw baseline
    let steady = report.rounds.last().unwrap();
    assert!(
        steady.update_bytes < steady.raw_bytes,
        "steady-state patch {} !< raw {}",
        steady.update_bytes,
        steady.raw_bytes
    );
    assert!(report.shipped_bytes < report.raw_bytes);
}

#[test]
fn soak_quant_patch_mode() {
    let report = run_soak(SoakConfig::quick(UpdateMode::QuantPatch));
    assert!(report.rounds.len() >= 5);
    report.assert_healthy(AUC_TOLERANCE);
    // the production configuration: far below the raw bill in total,
    // and steady-state updates undercut even the quantized full file
    assert!(
        report.shipped_bytes < report.raw_bytes / 2,
        "quant+patch shipped {} !< half of raw {}",
        report.shipped_bytes,
        report.raw_bytes
    );
    let steady = report.rounds.last().unwrap();
    assert!(
        steady.update_bytes < steady.raw_bytes / 2,
        "steady-state update {} !< raw {} / 2",
        steady.update_bytes,
        steady.raw_bytes
    );
}

#[test]
fn soak_rounds_report_consistently() {
    // one more raw soak, checking the report plumbing end to end
    let mut cfg = SoakConfig::quick(UpdateMode::Raw);
    cfg.rounds = 5;
    let report = run_soak(cfg);
    assert_eq!(report.rounds.len(), 5);
    for (i, r) in report.rounds.iter().enumerate() {
        assert_eq!(r.round, i);
        assert_eq!(r.version, i as u64 + 2); // bootstrap was version 1
        assert!(r.lag_seconds >= r.wire_seconds);
        assert!(r.update_bytes > 0);
        assert!(r.holdout_auc.is_finite());
    }
    assert_eq!(report.holdout_aucs.len(), 5);
    // versions: bootstrap + one per round were published; traffic saw
    // at least two of them (a live mid-run swap)
    assert!(report.versions_observed >= 2);
    assert!(report.serve_stats.requests > 0);
}
