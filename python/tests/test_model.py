"""L2 correctness: DeepFFM graph shapes, semantics, and AOT round-trip."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.model import (MERGE_NORM_EPS, DeepFfmConfig, arg_specs,
                           deep_ffm_forward, example_args, lr_forward,
                           make_batched_fn, merge_norm_layer,
                           mlp_param_shapes)


def small_cfg(hidden=(8,), batch=8):
    return DeepFfmConfig(fields=4, latent_dim=2, buckets=64,
                         hidden=hidden, batch=batch)


class TestConfig:
    def test_pairs_and_merged_dim(self):
        cfg = DeepFfmConfig(fields=8, latent_dim=4, buckets=16,
                            hidden=(16,), batch=4)
        assert cfg.pairs == 28
        assert cfg.merged_dim == 29

    def test_name_encodes_architecture(self):
        assert "h16x16" in DeepFfmConfig(8, 4, 16, (16, 16), 4).name()
        assert "hffm" in DeepFfmConfig(8, 4, 16, (), 4).name()

    def test_mlp_param_shapes(self):
        cfg = small_cfg(hidden=(8, 5))
        shapes = mlp_param_shapes(cfg)
        d = cfg.merged_dim
        assert shapes == [(d, 8), (8,), (8, 5), (5,), (5,), ()]

    def test_ffm_config_has_no_mlp(self):
        assert mlp_param_shapes(small_cfg(hidden=())) == []


class TestForward:
    def test_output_shape_and_range(self):
        cfg = small_cfg()
        lr, ffm, mlp, idx, vals = example_args(cfg)
        p = deep_ffm_forward(cfg, lr, ffm, mlp, idx, vals)
        assert p.shape == (cfg.batch,)
        assert ((p > 0) & (p < 1)).all()

    def test_lr_forward_matches_manual(self):
        table = jnp.array([0.5, -1.0, 2.0, 0.0])
        idx = jnp.array([[0, 2], [1, 3]], jnp.int32)
        vals = jnp.array([[1.0, 2.0], [3.0, 1.0]])
        out = lr_forward(table, idx, vals)
        np.testing.assert_allclose(out, [0.5 + 4.0, -3.0], rtol=1e-6)

    def test_merge_norm_rms_is_one(self):
        lr_out = jnp.array([2.0, -1.0])
        ffm = jnp.array([[1.0, 0.5, -2.0], [0.0, 0.0, 0.0]])
        m = merge_norm_layer(lr_out, ffm)
        rms = np.sqrt((np.asarray(m) ** 2).mean(axis=1))
        np.testing.assert_allclose(rms[0], 1.0, rtol=1e-4)
        # all-zero-except-lr row still finite thanks to eps
        assert np.isfinite(np.asarray(m)).all()

    def test_pure_ffm_logit_decomposition(self):
        """Pure FFM config: p == sigmoid(lr + sum pairs)."""
        cfg = small_cfg(hidden=())
        lr, ffm, mlp, idx, vals = example_args(cfg, seed=3)
        from compile.kernels.ref import ffm_scalar_ref
        emb = ffm[idx]
        manual = jax.nn.sigmoid(lr_forward(lr, idx, vals)
                                + ffm_scalar_ref(emb, vals))
        got = deep_ffm_forward(cfg, lr, ffm, [], idx, vals)
        np.testing.assert_allclose(got, manual, rtol=1e-5)

    def test_two_hidden_layers_run(self):
        cfg = small_cfg(hidden=(8, 4))
        lr, ffm, mlp, idx, vals = example_args(cfg, seed=5)
        p = deep_ffm_forward(cfg, lr, ffm, mlp, idx, vals)
        assert p.shape == (cfg.batch,)

    def test_batched_fn_returns_1tuple(self):
        cfg = small_cfg()
        lr, ffm, mlp, idx, vals = example_args(cfg)
        out = make_batched_fn(cfg)(lr, ffm, *mlp, idx, vals)
        assert isinstance(out, tuple) and len(out) == 1

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 10**6), f=st.integers(2, 6),
           k=st.integers(1, 4), h=st.sampled_from([(), (4,), (8, 4)]))
    def test_forward_finite_hypothesis(self, seed, f, k, h):
        cfg = DeepFfmConfig(fields=f, latent_dim=k, buckets=32,
                            hidden=h, batch=4)
        lr, ffm, mlp, idx, vals = example_args(cfg, seed=seed)
        p = deep_ffm_forward(cfg, lr, ffm, mlp, idx, vals)
        assert np.isfinite(np.asarray(p)).all()
        assert ((np.asarray(p) >= 0) & (np.asarray(p) <= 1)).all()


class TestAot:
    def test_lowering_produces_hlo_text(self):
        from compile.aot import lower_variant
        cfg = small_cfg()
        text = lower_variant(cfg)
        assert "ENTRY" in text and "HloModule" in text

    def test_lowering_deterministic(self):
        from compile.aot import lower_variant
        cfg = small_cfg(hidden=())
        assert lower_variant(cfg) == lower_variant(cfg)

    def test_manifest_entry_schema(self):
        from compile.aot import manifest_entry
        cfg = small_cfg()
        e = manifest_entry(cfg)
        assert e["args"][0]["name"] == "lr_table"
        assert e["args"][-1]["name"] == "vals"
        assert e["output"]["shape"] == [cfg.batch]
        # arg count: 2 tables + mlp params + idx + vals
        assert len(e["args"]) == 2 + len(mlp_param_shapes(cfg)) + 2

    def test_arg_specs_match_example_args(self):
        cfg = small_cfg()
        specs = arg_specs(cfg)
        lr, ffm, mlp, idx, vals = example_args(cfg)
        flat = [lr, ffm, *mlp, idx, vals]
        assert len(specs) == len(flat)
        for s, a in zip(specs, flat):
            assert tuple(s.shape) == tuple(a.shape)


class TestGolden:
    def test_golden_export_is_consistent(self):
        from compile.golden import GOLDEN_CFG, export
        g = export(GOLDEN_CFG, seed=7)
        assert len(g["probs"]) == GOLDEN_CFG.batch
        assert len(g["lr_table"]) == GOLDEN_CFG.buckets
        assert len(g["ffm_table"]) == (GOLDEN_CFG.buckets
                                       * GOLDEN_CFG.fields
                                       * GOLDEN_CFG.latent_dim)
        assert all(0.0 < p < 1.0 for p in g["probs"])

    def test_golden_deterministic(self):
        from compile.golden import GOLDEN_CFG, export
        a = export(GOLDEN_CFG, seed=7)
        b = export(GOLDEN_CFG, seed=7)
        assert a["probs"] == b["probs"]
