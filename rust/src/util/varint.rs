//! LEB128-style variable-length integers.
//!
//! The weight patcher (§6 of the paper) stores *relative* byte offsets
//! and run lengths as "custom integer types — instead of storing whole
//! ints, compressed versions (small ints are impacted the most) are
//! stored".  This module is that custom integer type: unsigned LEB128,
//! 7 bits per byte, little-endian groups, high bit = continuation.

/// Append `v` to `out` as LEB128. Returns the number of bytes written.
pub fn write_u64(out: &mut Vec<u8>, mut v: u64) -> usize {
    let mut n = 0;
    loop {
        let byte = (v & 0x7f) as u8;
        v >>= 7;
        n += 1;
        if v == 0 {
            out.push(byte);
            return n;
        }
        out.push(byte | 0x80);
    }
}

/// Decode a LEB128 value from `buf[pos..]`, advancing `pos`.
/// Returns `None` on truncated or oversized (>10 byte) input.
pub fn read_u64(buf: &[u8], pos: &mut usize) -> Option<u64> {
    let mut v: u64 = 0;
    let mut shift = 0u32;
    loop {
        let byte = *buf.get(*pos)?;
        *pos += 1;
        if shift == 63 && byte > 1 {
            return None; // overflow
        }
        v |= ((byte & 0x7f) as u64) << shift;
        if byte & 0x80 == 0 {
            return Some(v);
        }
        shift += 7;
        if shift > 63 {
            return None;
        }
    }
}

/// Encoded size without writing.
pub fn size_u64(v: u64) -> usize {
    if v == 0 {
        return 1;
    }
    (64 - v.leading_zeros() as usize).div_ceil(7)
}

/// ZigZag-encode a signed value so small magnitudes stay small.
#[inline]
pub fn zigzag(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

/// Inverse of [`zigzag`].
#[inline]
pub fn unzigzag(v: u64) -> i64 {
    ((v >> 1) as i64) ^ -((v & 1) as i64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn roundtrip_edges() {
        for v in [0u64, 1, 127, 128, 16383, 16384, u32::MAX as u64, u64::MAX] {
            let mut buf = Vec::new();
            let n = write_u64(&mut buf, v);
            assert_eq!(n, buf.len());
            assert_eq!(n, size_u64(v));
            let mut pos = 0;
            assert_eq!(read_u64(&buf, &mut pos), Some(v));
            assert_eq!(pos, buf.len());
        }
    }

    #[test]
    fn small_values_one_byte() {
        for v in 0..128u64 {
            assert_eq!(size_u64(v), 1);
        }
        assert_eq!(size_u64(128), 2);
    }

    #[test]
    fn truncated_returns_none() {
        let mut buf = Vec::new();
        write_u64(&mut buf, u64::MAX);
        buf.pop();
        let mut pos = 0;
        assert_eq!(read_u64(&buf, &mut pos), None);
    }

    #[test]
    fn empty_returns_none() {
        let mut pos = 0;
        assert_eq!(read_u64(&[], &mut pos), None);
    }

    #[test]
    fn zigzag_roundtrip() {
        for v in [0i64, -1, 1, -64, 63, i64::MIN, i64::MAX, -123456789] {
            assert_eq!(unzigzag(zigzag(v)), v);
        }
        // small negatives stay small
        assert!(size_u64(zigzag(-1)) == 1);
        assert!(size_u64(zigzag(-60)) == 1);
    }

    #[test]
    fn prop_roundtrip_random() {
        let mut rng = Pcg32::seeded(11);
        let mut buf = Vec::new();
        let mut vals = Vec::new();
        for _ in 0..1000 {
            let v = rng.next_u64() >> (rng.below(64));
            vals.push(v);
            write_u64(&mut buf, v);
        }
        let mut pos = 0;
        for &v in &vals {
            assert_eq!(read_u64(&buf, &mut pos), Some(v));
        }
        assert_eq!(pos, buf.len());
    }
}
