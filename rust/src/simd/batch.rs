//! Multi-vector kernels for request-level batched candidate scoring.
//!
//! The serving hot path (§5) scores B candidates that all share one
//! request context.  The single-vector kernels in [`super::dot`] stream
//! the neural block's weight matrix from memory once *per candidate*;
//! the kernels here restructure the inner loops candidate-major so each
//! weight row is loaded once per 4-candidate register block:
//!
//! * [`matmul_rowmajor`] — a register-blocked `B×in · in×out` GEMM-lite
//!   for the neural block (4 batch rows × 16 output columns per tile,
//!   AVX2+FMA with a scalar fallback).
//! * [`rowwise_sum`] / [`rowwise_sumsq`] — batched horizontal sums over
//!   the rows of a `B × n` matrix, used for the batched FFM logit and
//!   the batched MergeNorm RMS.
//!
//! Numerical contract (the serving layer relies on it): at a fixed ISA
//! level every output element is produced by the same operation
//! sequence regardless of the batch size, so scoring a candidate alone
//! (B = 1) is **bit-identical** to scoring it inside a larger batch.
//! That is why the kernels never take the "skip zero inputs" shortcut
//! of the single-vector matvec, and why the remainder paths mirror the
//! blocked paths' per-element accumulation order exactly.

use super::{isa_level, IsaLevel};

/// Batched dense forward: `out[b*cols + j] = bias[j] + Σ_i x[b*rows + i]
/// * w[i*cols + j]` for `b` in `0..batch`.
///
/// `w` is the neural block's row-major `[rows × cols]` matrix; `x`
/// holds `batch` input rows back to back.  The AVX2 kernel loads each
/// weight strip once per 4-candidate block instead of once per
/// candidate, turning the per-candidate matvec's latency-bound
/// accumulator chains into 8 independent chains per tile.
pub fn matmul_rowmajor(
    x: &[f32],
    batch: usize,
    w: &[f32],
    rows: usize,
    cols: usize,
    bias: Option<&[f32]>,
    out: &mut [f32],
) {
    debug_assert!(rows > 0 && cols > 0);
    debug_assert_eq!(x.len(), batch * rows);
    debug_assert_eq!(w.len(), rows * cols);
    debug_assert_eq!(out.len(), batch * cols);
    match isa_level() {
        IsaLevel::Scalar => matmul_scalar(x, batch, w, rows, cols, bias, out),
        #[cfg(target_arch = "x86_64")]
        IsaLevel::Avx2Fma => {
            if cols >= 8 {
                unsafe { matmul_avx2(x, batch, w, rows, cols, bias, out) }
            } else {
                matmul_scalar(x, batch, w, rows, cols, bias, out)
            }
        }
        #[cfg(not(target_arch = "x86_64"))]
        _ => matmul_scalar(x, batch, w, rows, cols, bias, out),
    }
}

/// Portable batched matmul (also the non-x86 fallback).
pub fn matmul_scalar(
    x: &[f32],
    batch: usize,
    w: &[f32],
    rows: usize,
    cols: usize,
    bias: Option<&[f32]>,
    out: &mut [f32],
) {
    for (xr, or) in x
        .chunks_exact(rows)
        .zip(out.chunks_exact_mut(cols))
        .take(batch)
    {
        match bias {
            Some(bv) => or.copy_from_slice(bv),
            None => or.fill(0.0),
        }
        for (i, &xi) in xr.iter().enumerate() {
            for (o, &wv) in or.iter_mut().zip(&w[i * cols..(i + 1) * cols]) {
                *o += xi * wv;
            }
        }
    }
}

/// `out[b] = Σ_j m[b*cols + j]` — batched horizontal sum over rows.
pub fn rowwise_sum(m: &[f32], rows: usize, cols: usize, out: &mut [f32]) {
    debug_assert!(cols > 0);
    debug_assert_eq!(m.len(), rows * cols);
    debug_assert_eq!(out.len(), rows);
    match isa_level() {
        IsaLevel::Scalar => rowwise_sum_scalar(m, cols, out),
        #[cfg(target_arch = "x86_64")]
        IsaLevel::Avx2Fma => {
            if cols >= 8 {
                unsafe { rowwise_sum_avx2(m, cols, out) }
            } else {
                rowwise_sum_scalar(m, cols, out)
            }
        }
        #[cfg(not(target_arch = "x86_64"))]
        _ => rowwise_sum_scalar(m, cols, out),
    }
}

/// `out[b] = Σ_j m[b*cols + j]²` — batched sum of squares (the batched
/// MergeNorm's per-candidate RMS numerator).
pub fn rowwise_sumsq(m: &[f32], rows: usize, cols: usize, out: &mut [f32]) {
    debug_assert!(cols > 0);
    debug_assert_eq!(m.len(), rows * cols);
    debug_assert_eq!(out.len(), rows);
    match isa_level() {
        IsaLevel::Scalar => rowwise_sumsq_scalar(m, cols, out),
        #[cfg(target_arch = "x86_64")]
        IsaLevel::Avx2Fma => {
            if cols >= 8 {
                unsafe { rowwise_sumsq_avx2(m, cols, out) }
            } else {
                rowwise_sumsq_scalar(m, cols, out)
            }
        }
        #[cfg(not(target_arch = "x86_64"))]
        _ => rowwise_sumsq_scalar(m, cols, out),
    }
}

fn rowwise_sum_scalar(m: &[f32], cols: usize, out: &mut [f32]) {
    for (row, o) in m.chunks_exact(cols).zip(out.iter_mut()) {
        let mut s = 0.0f32;
        for &v in row {
            s += v;
        }
        *o = s;
    }
}

fn rowwise_sumsq_scalar(m: &[f32], cols: usize, out: &mut [f32]) {
    for (row, o) in m.chunks_exact(cols).zip(out.iter_mut()) {
        let mut s = 0.0f32;
        for &v in row {
            s += v * v;
        }
        *o = s;
    }
}

// ------------------------------------------------------------------ avx2

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn matmul_avx2(
    x: &[f32],
    batch: usize,
    w: &[f32],
    rows: usize,
    cols: usize,
    bias: Option<&[f32]>,
    out: &mut [f32],
) {
    let mut b = 0usize;
    while b + 4 <= batch {
        mm_rows::<4>(x, b, w, rows, cols, bias, out);
        b += 4;
    }
    while b < batch {
        mm_rows::<1>(x, b, w, rows, cols, bias, out);
        b += 1;
    }
}

/// `R` batch rows through all column tiles.  Per-element accumulation
/// order is independent of `R` (bias load, then one FMA per input row
/// in order) — the bit-identity contract of the module.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
#[inline]
#[allow(clippy::needless_range_loop)]
unsafe fn mm_rows<const R: usize>(
    x: &[f32],
    b: usize,
    w: &[f32],
    rows: usize,
    cols: usize,
    bias: Option<&[f32]>,
    out: &mut [f32],
) {
    use std::arch::x86_64::*;
    let wp = w.as_ptr();
    let mut xp = [std::ptr::null::<f32>(); R];
    for (r, p) in xp.iter_mut().enumerate() {
        *p = x.as_ptr().add((b + r) * rows);
    }
    let mut j = 0usize;
    // 16-wide column tiles: 2 weight loads serve R candidates (2R FMAs)
    while j + 16 <= cols {
        let mut acc0 = [_mm256_setzero_ps(); R];
        let mut acc1 = [_mm256_setzero_ps(); R];
        if let Some(bv) = bias {
            let b0 = _mm256_loadu_ps(bv.as_ptr().add(j));
            let b1 = _mm256_loadu_ps(bv.as_ptr().add(j + 8));
            for r in 0..R {
                acc0[r] = b0;
                acc1[r] = b1;
            }
        }
        for i in 0..rows {
            let w0 = _mm256_loadu_ps(wp.add(i * cols + j));
            let w1 = _mm256_loadu_ps(wp.add(i * cols + j + 8));
            for r in 0..R {
                let vx = _mm256_set1_ps(*xp[r].add(i));
                acc0[r] = _mm256_fmadd_ps(vx, w0, acc0[r]);
                acc1[r] = _mm256_fmadd_ps(vx, w1, acc1[r]);
            }
        }
        for r in 0..R {
            _mm256_storeu_ps(out.as_mut_ptr().add((b + r) * cols + j), acc0[r]);
            _mm256_storeu_ps(out.as_mut_ptr().add((b + r) * cols + j + 8), acc1[r]);
        }
        j += 16;
    }
    while j + 8 <= cols {
        let mut acc = [_mm256_setzero_ps(); R];
        if let Some(bv) = bias {
            let b0 = _mm256_loadu_ps(bv.as_ptr().add(j));
            for a in acc.iter_mut() {
                *a = b0;
            }
        }
        for i in 0..rows {
            let w0 = _mm256_loadu_ps(wp.add(i * cols + j));
            for r in 0..R {
                let vx = _mm256_set1_ps(*xp[r].add(i));
                acc[r] = _mm256_fmadd_ps(vx, w0, acc[r]);
            }
        }
        for r in 0..R {
            _mm256_storeu_ps(out.as_mut_ptr().add((b + r) * cols + j), acc[r]);
        }
        j += 8;
    }
    while j < cols {
        for r in 0..R {
            let mut s = match bias {
                Some(bv) => bv[j],
                None => 0.0,
            };
            for i in 0..rows {
                s += *xp[r].add(i) * *wp.add(i * cols + j);
            }
            out[(b + r) * cols + j] = s;
        }
        j += 1;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
#[inline]
unsafe fn hsum8(v: std::arch::x86_64::__m256) -> f32 {
    use std::arch::x86_64::*;
    let hi = _mm256_extractf128_ps::<1>(v);
    let lo = _mm256_castps256_ps128(v);
    let s4 = _mm_add_ps(hi, lo);
    let s2 = _mm_add_ps(s4, _mm_movehl_ps(s4, s4));
    _mm_cvtss_f32(_mm_add_ss(s2, _mm_shuffle_ps::<1>(s2, s2)))
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn rowwise_sum_avx2(m: &[f32], cols: usize, out: &mut [f32]) {
    use std::arch::x86_64::*;
    for (row, o) in m.chunks_exact(cols).zip(out.iter_mut()) {
        let p = row.as_ptr();
        let mut acc = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 8 <= cols {
            acc = _mm256_add_ps(acc, _mm256_loadu_ps(p.add(i)));
            i += 8;
        }
        let mut s = hsum8(acc);
        while i < cols {
            s += row[i];
            i += 1;
        }
        *o = s;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn rowwise_sumsq_avx2(m: &[f32], cols: usize, out: &mut [f32]) {
    use std::arch::x86_64::*;
    for (row, o) in m.chunks_exact(cols).zip(out.iter_mut()) {
        let p = row.as_ptr();
        let mut acc = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 8 <= cols {
            let v = _mm256_loadu_ps(p.add(i));
            acc = _mm256_fmadd_ps(v, v, acc);
            i += 8;
        }
        let mut s = hsum8(acc);
        while i < cols {
            s += row[i] * row[i];
            i += 1;
        }
        *o = s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn randvec(rng: &mut Pcg32, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal()).collect()
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Pcg32::seeded(11);
        for (batch, rows, cols) in [
            (1, 5, 16),
            (3, 7, 8),
            (4, 13, 16),
            (5, 9, 32),
            (9, 46, 16),
            (2, 7, 7),
            (6, 11, 20),
            (8, 10, 72),
            (7, 1, 9),
        ] {
            let x = randvec(&mut rng, batch * rows);
            let w = randvec(&mut rng, rows * cols);
            let bias = randvec(&mut rng, cols);
            for with_bias in [false, true] {
                let b = if with_bias { Some(&bias[..]) } else { None };
                let mut out = vec![0f32; batch * cols];
                matmul_rowmajor(&x, batch, &w, rows, cols, b, &mut out);
                for bb in 0..batch {
                    for j in 0..cols {
                        let mut want = if with_bias { bias[j] } else { 0.0 };
                        for i in 0..rows {
                            want += x[bb * rows + i] * w[i * cols + j];
                        }
                        let got = out[bb * cols + j];
                        assert!(
                            (got - want).abs() < 1e-3 * (1.0 + want.abs()),
                            "b={batch} r={rows} c={cols} elem=({bb},{j}): {got} vs {want}"
                        );
                    }
                }
            }
        }
    }

    /// Concrete kernels under test, bypassing the forceable global
    /// dispatch (other tests may flip [`force_scalar`] concurrently).
    fn matmul_impls() -> Vec<(
        &'static str,
        fn(&[f32], usize, &[f32], usize, usize, Option<&[f32]>, &mut [f32]),
    )> {
        let mut impls: Vec<(
            &'static str,
            fn(&[f32], usize, &[f32], usize, usize, Option<&[f32]>, &mut [f32]),
        )> = vec![("scalar", matmul_scalar)];
        #[cfg(target_arch = "x86_64")]
        if std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
        {
            fn avx2(
                x: &[f32],
                batch: usize,
                w: &[f32],
                rows: usize,
                cols: usize,
                bias: Option<&[f32]>,
                out: &mut [f32],
            ) {
                unsafe { matmul_avx2(x, batch, w, rows, cols, bias, out) }
            }
            impls.push(("avx2", avx2));
        }
        impls
    }

    #[test]
    fn matmul_batch_invariant_bitwise() {
        // The serving layer depends on B=1 results being bit-identical
        // to the same row scored inside any larger batch, per kernel.
        let mut rng = Pcg32::seeded(12);
        for (batch, rows, cols) in [(6, 17, 16), (9, 8, 24), (5, 30, 40), (8, 46, 16)] {
            let x = randvec(&mut rng, batch * rows);
            let w = randvec(&mut rng, rows * cols);
            let bias = randvec(&mut rng, cols);
            for (name, mm) in matmul_impls() {
                let mut full = vec![0f32; batch * cols];
                mm(&x, batch, &w, rows, cols, Some(&bias), &mut full);
                for b in 0..batch {
                    let mut one = vec![0f32; cols];
                    mm(
                        &x[b * rows..(b + 1) * rows],
                        1,
                        &w,
                        rows,
                        cols,
                        Some(&bias),
                        &mut one,
                    );
                    assert_eq!(one, full[b * cols..(b + 1) * cols], "{name} row {b}");
                }
            }
        }
    }

    #[test]
    fn matmul_impls_agree_within_tolerance() {
        let mut rng = Pcg32::seeded(13);
        let (batch, rows, cols) = (6, 23, 48);
        let x = randvec(&mut rng, batch * rows);
        let w = randvec(&mut rng, rows * cols);
        let mut slow = vec![0f32; batch * cols];
        matmul_scalar(&x, batch, &w, rows, cols, None, &mut slow);
        for (name, mm) in matmul_impls() {
            let mut fast = vec![0f32; batch * cols];
            mm(&x, batch, &w, rows, cols, None, &mut fast);
            for (a, b) in fast.iter().zip(&slow) {
                assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "{name}");
            }
        }
    }

    #[test]
    fn rowwise_sums_match_naive() {
        let mut rng = Pcg32::seeded(14);
        for (rows, cols) in [(1, 3), (4, 8), (3, 17), (5, 46), (2, 64), (6, 9)] {
            let m = randvec(&mut rng, rows * cols);
            let mut sum = vec![0f32; rows];
            let mut ssq = vec![0f32; rows];
            rowwise_sum(&m, rows, cols, &mut sum);
            rowwise_sumsq(&m, rows, cols, &mut ssq);
            for r in 0..rows {
                let want_s: f32 = m[r * cols..(r + 1) * cols].iter().sum();
                let want_q: f32 = m[r * cols..(r + 1) * cols].iter().map(|v| v * v).sum();
                assert!((sum[r] - want_s).abs() < 1e-3 * (1.0 + want_s.abs()));
                assert!((ssq[r] - want_q).abs() < 1e-3 * (1.0 + want_q.abs()));
            }
        }
    }

    #[test]
    fn rowwise_sums_batch_invariant_bitwise() {
        // Per concrete kernel (dispatch-independent): a row's sum of
        // squares is identical alone or inside a batch.
        let mut rng = Pcg32::seeded(15);
        let (rows, cols) = (7, 46);
        let m = randvec(&mut rng, rows * cols);
        let mut impls: Vec<(&'static str, fn(&[f32], usize, &mut [f32]))> =
            vec![("scalar", rowwise_sumsq_scalar)];
        #[cfg(target_arch = "x86_64")]
        if std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
        {
            fn avx2(m: &[f32], cols: usize, out: &mut [f32]) {
                unsafe { rowwise_sumsq_avx2(m, cols, out) }
            }
            impls.push(("avx2", avx2));
        }
        for (name, ssq) in impls {
            let mut full = vec![0f32; rows];
            ssq(&m, cols, &mut full);
            for r in 0..rows {
                let mut one = vec![0f32; 1];
                ssq(&m[r * cols..(r + 1) * cols], cols, &mut one);
                assert_eq!(one[0], full[r], "{name} row {r}");
            }
        }
    }
}
