//! §6 — the weight transfer plane between training and serving.
//!
//! "Hundreds of live models that take up to 10G of memory (per update)
//! are constantly transferred across the network" — this module is the
//! in-process simulation of that plane (DESIGN.md §3): a training job
//! produces weight snapshots every round; an [`UpdatePipeline`] encodes
//! them (raw / quantized / patched / quantized+patched — Table 4's four
//! rows), ships them over a [`SimulatedChannel`] that accounts bytes
//! and models bandwidth, and an [`UpdateReceiver`] reconstructs the
//! inference weights for hot-swapping into the serving layer.

use std::time::Instant;

use crate::model::io;
use crate::model::regressor::Regressor;
use crate::patch::{self, Compression, Patch};
use crate::quant;

/// Transfer/fleet-plane errors, typed so recovery code can *match* on
/// the failure class (mirroring the serving plane's
/// [`crate::serve::ServeError`]) instead of sniffing string prefixes:
/// a [`Gap`](Self::Gap) triggers the catch-up protocol, a
/// [`Corrupt`](Self::Corrupt) payload or checkpoint must never be
/// installed, a [`LinkDown`](Self::LinkDown) routes around the dead
/// link and retries later.
#[derive(Clone, Debug, PartialEq)]
pub enum FleetError {
    /// A chained update arrived out of sequence; applying it would
    /// patch against the wrong base and silently corrupt the weights.
    Gap { expected: u64, got: u64 },
    /// Payload or durable state failed validation (bad magic, CRC
    /// mismatch, truncated stream, wrong-length base...).
    Corrupt(String),
    /// An inter-DC link is (or behaved as) partitioned: every attempt
    /// within the retry budget failed.
    LinkDown { dc: usize },
    /// A specific replica did not respond (crashed or stalled).
    Unreachable { replica: usize },
    /// The receiver has no structural template for weight-only
    /// payloads (`set_template` was never called).
    MissingTemplate,
    /// No update has been published yet, so there is no base to
    /// resync or checkpoint from.
    NothingPublished,
    /// Durable-state I/O failure (checkpoint read/write/rename).
    Io(String),
}

impl std::fmt::Display for FleetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FleetError::Gap { expected, got } => {
                write!(f, "sequence gap: expected {expected}, got {got}")
            }
            FleetError::Corrupt(msg) => write!(f, "corrupt payload: {msg}"),
            FleetError::LinkDown { dc } => write!(f, "link to dc{dc} is down"),
            FleetError::Unreachable { replica } => {
                write!(f, "replica {replica} unreachable")
            }
            FleetError::MissingTemplate => {
                write!(f, "receiver missing model template (call set_template)")
            }
            FleetError::NothingPublished => write!(f, "nothing published yet"),
            FleetError::Io(msg) => write!(f, "checkpoint io: {msg}"),
        }
    }
}

impl std::error::Error for FleetError {}

impl From<FleetError> for String {
    fn from(e: FleetError) -> String {
        e.to_string()
    }
}

/// Encoding strategy for one update — the four arms of Table 4.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum UpdateMode {
    /// Ship the full inference weight file.
    Raw,
    /// Ship the quantized weight file (fw-quantization).
    Quant,
    /// Ship a byte patch against the previous raw file (fw-patcher).
    PatchOnly,
    /// Quantize, then patch against the previous quantized file
    /// (fw-patcher + fw-quantization — the production configuration).
    QuantPatch,
}

impl UpdateMode {
    pub const ALL: [UpdateMode; 4] = [
        UpdateMode::Raw,
        UpdateMode::Quant,
        UpdateMode::PatchOnly,
        UpdateMode::QuantPatch,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            UpdateMode::Raw => "no processing (baseline)",
            UpdateMode::Quant => "fw-quantization",
            UpdateMode::PatchOnly => "fw-patcher",
            UpdateMode::QuantPatch => "fw-patcher + fw-quantization",
        }
    }

    /// Parse a CLI flag value (`raw|quant|patch|quantpatch`).
    pub fn parse(s: &str) -> Result<UpdateMode, crate::config::ConfigError> {
        Ok(match s {
            "raw" => UpdateMode::Raw,
            "quant" => UpdateMode::Quant,
            "patch" => UpdateMode::PatchOnly,
            "quantpatch" | "quant+patch" => UpdateMode::QuantPatch,
            other => {
                return Err(crate::config::ConfigError::UnknownValue {
                    what: "update mode",
                    got: other.to_string(),
                    want: "raw|quant|patch|quantpatch",
                })
            }
        })
    }

    /// True for the modes that ship quantized (lossy) weights.
    pub fn is_quantized(&self) -> bool {
        matches!(self, UpdateMode::Quant | UpdateMode::QuantPatch)
    }

    /// True for the modes whose updates form a *delta chain*: update N
    /// is a byte patch against the base produced by update N-1, so it
    /// can only be applied in sequence.  Raw/Quant updates are full
    /// files and can be applied from any starting state.
    pub fn is_chained(&self) -> bool {
        matches!(self, UpdateMode::PatchOnly | UpdateMode::QuantPatch)
    }
}

/// One encoded update as it crosses the wire.
#[derive(Clone, Debug)]
pub struct WireUpdate {
    pub mode: UpdateMode,
    pub bytes: Vec<u8>,
    /// Encoder wall time (Table 4's "Avg. time spent").
    pub encode_seconds: f64,
}

/// Sender state: remembers the previous round's encodings for diffing.
pub struct UpdatePipeline {
    pub mode: UpdateMode,
    pub compression: Compression,
    /// α/β bound precisions for the quantizer.
    pub alpha: u8,
    pub beta: u8,
    prev_raw: Option<Vec<u8>>,
    prev_quant: Option<Vec<u8>>,
    /// Grid reuse across rounds (§6 "dynamically select viable weight
    /// ranges"): keep quantizing on the same grid while the weights
    /// stay inside it, so consecutive quantized files differ only where
    /// weights actually moved.
    prev_grid: Option<quant::QuantHeader>,
}

impl std::fmt::Debug for UpdatePipeline {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("UpdatePipeline").finish_non_exhaustive()
    }
}

impl UpdatePipeline {
    pub fn new(mode: UpdateMode) -> Self {
        UpdatePipeline {
            mode,
            compression: Compression::Lz,
            alpha: 2,
            beta: 2,
            prev_raw: None,
            prev_quant: None,
            prev_grid: None,
        }
    }

    /// Quantize on a stable grid: reuse the previous round's grid while
    /// it still covers the weights; re-derive (with 25% headroom) when
    /// the distribution escapes.
    fn quantize_stable(&mut self, weights: &[f32]) -> Vec<u8> {
        if let Some(grid) = &self.prev_grid {
            if let Some(codes) = quant::quantize_with(grid, weights) {
                return quant::to_bytes(grid, &codes);
            }
        }
        let (h, codes) =
            quant::quantize_headroom(weights, self.alpha, self.beta, 0.25);
        let out = quant::to_bytes(&h, &codes);
        self.prev_grid = Some(h);
        out
    }

    /// Encode the current model state for the wire.  The first round
    /// has no base to diff against, so patch modes fall back to full
    /// files (exactly like production bootstrap).
    pub fn encode(&mut self, reg: &Regressor) -> WireUpdate {
        let t = Instant::now();
        // Inference weights only (optimizer state never ships — §6).
        let raw = io::to_bytes(reg, false);
        let out = match self.mode {
            UpdateMode::Raw => raw.clone(),
            UpdateMode::Quant => {
                let q = self.quantize_stable(&reg.pool.weights);
                self.prev_quant = Some(q.clone());
                q
            }
            UpdateMode::PatchOnly => match &self.prev_raw {
                Some(prev) => {
                    patch::make_patch(prev, &raw, self.compression).to_wire()
                }
                None => raw.clone(),
            },
            UpdateMode::QuantPatch => {
                let q = self.quantize_stable(&reg.pool.weights);
                let wire = match &self.prev_quant {
                    Some(prev) => {
                        patch::make_patch(prev, &q, self.compression).to_wire()
                    }
                    None => q.clone(),
                };
                self.prev_quant = Some(q);
                wire
            }
        };
        self.prev_raw = Some(raw);
        WireUpdate {
            mode: self.mode,
            bytes: out,
            encode_seconds: t.elapsed().as_secs_f64(),
        }
    }

    /// The sender-side base file for this mode's next diff: the raw
    /// `FWMODEL1` bytes for raw/patch modes, the quantized `FWQ1` bytes
    /// for the quantized modes.  The deployment harness cross-checks
    /// this against [`UpdateReceiver::base_bytes`] — the patch channel
    /// must reconstruct it bit-for-bit on the receiving side.
    pub fn sent_bytes(&self) -> Option<&[u8]> {
        match self.mode {
            UpdateMode::Raw | UpdateMode::PatchOnly => self.prev_raw.as_deref(),
            UpdateMode::Quant | UpdateMode::QuantPatch => self.prev_quant.as_deref(),
        }
    }

    /// Size of the last round's raw inference file
    /// ([`UpdatePipeline::encode`] serializes it every round regardless
    /// of mode) — the Table-4 baseline the shipped update is measured
    /// against.
    pub fn last_raw_len(&self) -> Option<usize> {
        self.prev_raw.as_ref().map(|b| b.len())
    }

    /// Snapshot the pipeline's diffing state for a durable checkpoint:
    /// `(prev_raw, prev_quant)`.  The quantizer grid is *not* exported
    /// — it is embedded in the `FWQ1` header of `prev_quant` and
    /// re-derived on restore, so the checkpoint cannot desynchronize
    /// grid and codes.
    pub fn export_state(&self) -> (Option<Vec<u8>>, Option<Vec<u8>>) {
        (self.prev_raw.clone(), self.prev_quant.clone())
    }

    /// Restore the state captured by [`export_state`](Self::export_state).
    /// After this, the next [`encode`](Self::encode) diffs against the
    /// checkpointed bases exactly as an uninterrupted pipeline would.
    pub fn restore_state(
        &mut self,
        prev_raw: Option<Vec<u8>>,
        prev_quant: Option<Vec<u8>>,
    ) -> Result<(), FleetError> {
        self.prev_grid = match &prev_quant {
            Some(q) => {
                let (header, _codes) =
                    quant::from_bytes(q).map_err(|e| FleetError::Corrupt(e.to_string()))?;
                Some(header)
            }
            None => None,
        };
        self.prev_raw = prev_raw;
        self.prev_quant = prev_quant;
        Ok(())
    }
}

/// Receiver state: reconstructs inference weights from wire updates.
pub struct UpdateReceiver {
    mode: UpdateMode,
    base_raw: Option<Vec<u8>>,
    base_quant: Option<Vec<u8>>,
    /// Structural template cloned when decoding weight-only (quantized)
    /// payloads — the serving layer always knows its model skeleton.
    template: Option<Regressor>,
}

impl std::fmt::Debug for UpdateReceiver {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("UpdateReceiver").finish_non_exhaustive()
    }
}

impl UpdateReceiver {
    pub fn new(mode: UpdateMode) -> Self {
        UpdateReceiver { mode, base_raw: None, base_quant: None, template: None }
    }

    /// Install the structural template for weight-only payloads.
    pub fn set_template(&mut self, template: Regressor) {
        self.template = Some(template);
    }

    /// The receiver's wire mode.
    pub fn mode(&self) -> UpdateMode {
        self.mode
    }

    /// Drop all base state (the template survives).  The next
    /// [`apply`](Self::apply) is then treated as a bootstrap full file
    /// — exactly the state a brand-new replica starts from.
    pub fn reset(&mut self) {
        self.base_raw = None;
        self.base_quant = None;
    }

    /// Full-snapshot resync: install `full_base` — the *sender's* base
    /// file for this mode ([`UpdatePipeline::sent_bytes`]) — as this
    /// receiver's base and decode the model it encodes.  This is the
    /// catch-up escape hatch for a replica whose delta chain is broken
    /// (missed updates beyond the sender's replay window): after a
    /// resync the receiver is bit-identical to an up-to-date replica
    /// and the next chained patch applies cleanly.
    pub fn resync(&mut self, full_base: &[u8]) -> Result<Regressor, FleetError> {
        self.reset();
        let update = WireUpdate {
            mode: self.mode,
            bytes: full_base.to_vec(),
            encode_seconds: 0.0,
        };
        self.apply(&update)
    }

    /// The receiver-side reconstructed base file (mirror of
    /// [`UpdatePipeline::sent_bytes`]): raw `FWMODEL1` bytes for
    /// raw/patch modes, quantized `FWQ1` bytes for quantized modes.
    pub fn base_bytes(&self) -> Option<&[u8]> {
        match self.mode {
            UpdateMode::Raw | UpdateMode::PatchOnly => self.base_raw.as_deref(),
            UpdateMode::Quant | UpdateMode::QuantPatch => self.base_quant.as_deref(),
        }
    }

    /// Apply one wire update; returns the reconstructed inference model.
    pub fn apply(&mut self, update: &WireUpdate) -> Result<Regressor, FleetError> {
        assert_eq!(update.mode, self.mode, "pipeline/receiver mode mismatch");
        match self.mode {
            UpdateMode::Raw => {
                self.base_raw = Some(update.bytes.clone());
                io::from_bytes(&update.bytes)
                    .map_err(|e| FleetError::Corrupt(e.to_string()))
            }
            UpdateMode::Quant => {
                self.base_quant = Some(update.bytes.clone());
                self.decode_quant_model(&update.bytes)
            }
            UpdateMode::PatchOnly => {
                let full = match &self.base_raw {
                    Some(prev) => {
                        let p = Patch::from_wire(&update.bytes)
                            .map_err(|e| FleetError::Corrupt(e.to_string()))?;
                        patch::apply_patch(prev, &p)
                            .map_err(|e| FleetError::Corrupt(e.to_string()))?
                    }
                    None => update.bytes.clone(),
                };
                self.base_raw = Some(full.clone());
                io::from_bytes(&full).map_err(|e| FleetError::Corrupt(e.to_string()))
            }
            UpdateMode::QuantPatch => {
                let q = match &self.base_quant {
                    Some(prev) => {
                        let p = Patch::from_wire(&update.bytes)
                            .map_err(|e| FleetError::Corrupt(e.to_string()))?;
                        patch::apply_patch(prev, &p)
                            .map_err(|e| FleetError::Corrupt(e.to_string()))?
                    }
                    None => update.bytes.clone(),
                };
                self.base_quant = Some(q.clone());
                self.decode_quant_model(&q)
            }
        }
    }

    fn decode_quant_model(&mut self, qbytes: &[u8]) -> Result<Regressor, FleetError> {
        let weights = quant::dequantize_from_bytes(qbytes)
            .map_err(|e| FleetError::Corrupt(e.to_string()))?;
        let template = self.template.as_ref().ok_or(FleetError::MissingTemplate)?;
        let mut reg = template.clone();
        if weights.len() != reg.pool.weights.len() {
            return Err(FleetError::Corrupt(format!(
                "quantized weight count {} != template {}",
                weights.len(),
                reg.pool.weights.len()
            )));
        }
        reg.pool.weights = weights;
        reg.pool.acc = Vec::new();
        Ok(reg)
    }
}

/// Simulated inter-DC link: counts bytes and models transfer time at a
/// configured bandwidth + RTT.  (The bandwidth bill is the paper's
/// headline §6 metric; time here is derived, not slept.)
///
/// The wire-time physics live in ONE place —
/// [`crate::fleet::topology::LinkSpec::transfer_seconds`] — shared with
/// the fleet fabric's [`crate::fleet::topology::SimLink`], so the two
/// link models can never drift apart.  This channel is the lossless
/// trainer→receiver pipe; the fleet's `SimLink` adds loss on top of the
/// same spec.
#[derive(Clone, Debug)]
pub struct SimulatedChannel {
    /// Link physics (bandwidth + RTT; `loss` is unused — this channel
    /// is the reliable pipe).
    pub link: crate::fleet::topology::LinkSpec,
    /// Ledger: total bytes shipped.
    pub total_bytes: u64,
    /// Ledger: total simulated seconds spent on the wire.
    pub total_seconds: f64,
    /// Messages shipped.
    pub messages: u64,
}

impl SimulatedChannel {
    /// 1 Gbps, 30 ms RTT defaults.
    pub fn new() -> Self {
        Self::with_bandwidth(125_000_000.0, 0.03)
    }

    pub fn with_bandwidth(bandwidth_bps: f64, rtt_seconds: f64) -> Self {
        SimulatedChannel {
            link: crate::fleet::topology::LinkSpec {
                bandwidth_bps,
                rtt_seconds,
                loss: 0.0,
            },
            total_bytes: 0,
            total_seconds: 0.0,
            messages: 0,
        }
    }

    /// Ship an update; returns the simulated transfer seconds
    /// (delegated to the shared
    /// [`crate::fleet::topology::LinkSpec::transfer_seconds`] model).
    pub fn ship(&mut self, update: &WireUpdate) -> f64 {
        let secs = self.link.transfer_seconds(update.bytes.len());
        self.total_bytes += update.bytes.len() as u64;
        self.total_seconds += secs;
        self.messages += 1;
        secs
    }
}

impl Default for SimulatedChannel {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::data::synthetic::{DatasetSpec, SyntheticStream};
    use crate::model::Workspace;

    fn trained_rounds(rounds: usize, per_round: usize) -> Vec<Regressor> {
        let cfg = ModelConfig::deep_ffm(4, 2, 1 << 10, &[8]);
        let mut reg = Regressor::new(&cfg);
        let mut ws = Workspace::new();
        let mut s = SyntheticStream::with_buckets(DatasetSpec::tiny(), 31, 1 << 10);
        let mut out = Vec::new();
        for _ in 0..rounds {
            for _ in 0..per_round {
                let ex = s.next_example();
                reg.learn(&ex, &mut ws);
            }
            out.push(reg.clone());
        }
        out
    }

    #[test]
    fn raw_mode_roundtrip() {
        let snaps = trained_rounds(2, 500);
        let mut pipe = UpdatePipeline::new(UpdateMode::Raw);
        let mut recv = UpdateReceiver::new(UpdateMode::Raw);
        for snap in &snaps {
            let u = pipe.encode(snap);
            let got = recv.apply(&u).unwrap();
            assert_eq!(got.pool.weights, snap.pool.weights);
        }
    }

    #[test]
    fn patch_mode_reconstructs_exactly() {
        let snaps = trained_rounds(4, 300);
        let mut pipe = UpdatePipeline::new(UpdateMode::PatchOnly);
        let mut recv = UpdateReceiver::new(UpdateMode::PatchOnly);
        for snap in &snaps {
            let u = pipe.encode(snap);
            let got = recv.apply(&u).unwrap();
            assert_eq!(got.pool.weights, snap.pool.weights);
            assert!(!got.pool.has_optimizer_state());
        }
    }

    #[test]
    fn quant_modes_reconstruct_within_bucket() {
        for mode in [UpdateMode::Quant, UpdateMode::QuantPatch] {
            let snaps = trained_rounds(3, 300);
            let mut pipe = UpdatePipeline::new(mode);
            let mut recv = UpdateReceiver::new(mode);
            recv.set_template(snaps[0].clone());
            for snap in &snaps {
                let u = pipe.encode(snap);
                let got = recv.apply(&u).unwrap();
                let max_err = got
                    .pool
                    .weights
                    .iter()
                    .zip(&snap.pool.weights)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0f32, f32::max);
                assert!(max_err < 1e-3, "{mode:?} max_err {max_err}");
            }
        }
    }

    #[test]
    fn table4_ordering_of_update_sizes() {
        // steady-state rounds: raw > quant > patch > quant+patch
        let snaps = trained_rounds(3, 400);
        let mut sizes = std::collections::HashMap::new();
        for mode in UpdateMode::ALL {
            let mut pipe = UpdatePipeline::new(mode);
            let mut last = 0usize;
            for snap in &snaps {
                last = pipe.encode(snap).bytes.len();
            }
            sizes.insert(mode, last);
        }
        let raw = sizes[&UpdateMode::Raw];
        let q = sizes[&UpdateMode::Quant];
        let p = sizes[&UpdateMode::PatchOnly];
        let qp = sizes[&UpdateMode::QuantPatch];
        assert!(q < raw, "quant {q} !< raw {raw}");
        assert!(p < raw, "patch {p} !< raw {raw}");
        assert!(qp < q && qp < p, "q+p {qp} !< min(q {q}, p {p})");
    }

    #[test]
    fn sender_and_receiver_bases_bit_identical() {
        // §6's core guarantee: after every round, the receiver's
        // reconstructed base file equals the sender's byte-for-byte —
        // that is what keeps round N+1's diff applicable.
        for mode in UpdateMode::ALL {
            let snaps = trained_rounds(3, 300);
            let mut pipe = UpdatePipeline::new(mode);
            let mut recv = UpdateReceiver::new(mode);
            recv.set_template(snaps[0].clone());
            for (round, snap) in snaps.iter().enumerate() {
                let u = pipe.encode(snap);
                let got = recv.apply(&u).unwrap();
                assert_eq!(
                    pipe.sent_bytes(),
                    recv.base_bytes(),
                    "{mode:?} round {round}: bases diverged"
                );
                // quantized modes: the served weights are exactly the
                // dequantized base bytes (bit-identical reconstruction)
                if mode.is_quantized() {
                    let deq = quant::dequantize_from_bytes(
                        recv.base_bytes().unwrap(),
                    )
                    .unwrap();
                    assert_eq!(got.pool.weights, deq, "{mode:?} round {round}");
                }
            }
        }
    }

    #[test]
    fn update_mode_parse_roundtrip() {
        for (s, m) in [
            ("raw", UpdateMode::Raw),
            ("quant", UpdateMode::Quant),
            ("patch", UpdateMode::PatchOnly),
            ("quantpatch", UpdateMode::QuantPatch),
        ] {
            assert_eq!(UpdateMode::parse(s).unwrap(), m);
        }
        assert!(UpdateMode::parse("gzip").is_err());
    }

    #[test]
    fn channel_ledger() {
        let mut ch = SimulatedChannel::with_bandwidth(1_000_000.0, 0.01);
        let u = WireUpdate {
            mode: UpdateMode::Raw,
            bytes: vec![0; 500_000],
            encode_seconds: 0.0,
        };
        let secs = ch.ship(&u);
        assert!((secs - 0.51).abs() < 1e-9);
        ch.ship(&u);
        assert_eq!(ch.total_bytes, 1_000_000);
        assert_eq!(ch.messages, 2);
    }

    #[test]
    fn channel_and_fleet_link_share_one_physics() {
        // The channel delegates to LinkSpec::transfer_seconds — the
        // fleet's SimLink uses the same function, so identical specs
        // must bill identical wire time (the "unify the two link
        // models" ROADMAP item).
        use crate::fleet::topology::{LinkSpec, SimLink};
        use crate::util::rng::Pcg32;
        let mut ch = SimulatedChannel::with_bandwidth(2_000_000.0, 0.025);
        let mut link = SimLink::new(LinkSpec {
            bandwidth_bps: 2_000_000.0,
            rtt_seconds: 0.025,
            loss: 0.0,
        });
        let mut rng = Pcg32::seeded(9);
        for len in [0usize, 1, 1337, 250_000, 4_000_000] {
            let u = WireUpdate {
                mode: UpdateMode::Raw,
                bytes: vec![0; len],
                encode_seconds: 0.0,
            };
            let a = ch.ship(&u);
            let b = link.ship(len, &mut rng, false).expect("lossless");
            assert_eq!(a, b, "len={len}: channel {a} vs fleet link {b}");
        }
        assert_eq!(ch.total_bytes, link.ledger.bytes);
    }

    #[test]
    fn resync_rejoins_a_broken_delta_chain() {
        // a receiver that misses updates cannot apply later chained
        // patches; after a resync from the sender's base it can.
        for mode in [UpdateMode::PatchOnly, UpdateMode::QuantPatch] {
            let snaps = trained_rounds(4, 300);
            let mut pipe = UpdatePipeline::new(mode);
            let mut good = UpdateReceiver::new(mode);
            let mut lossy = UpdateReceiver::new(mode);
            good.set_template(snaps[0].clone());
            lossy.set_template(snaps[0].clone());
            // rounds 0..2: lossy receiver drops round 1 entirely
            for (i, snap) in snaps[..3].iter().enumerate() {
                let u = pipe.encode(snap);
                good.apply(&u).unwrap();
                if i != 1 {
                    if i == 2 {
                        // base diverged: chained patch must not apply
                        assert_ne!(lossy.base_bytes(), good.base_bytes());
                    }
                    let _ = lossy.apply(&u);
                }
            }
            // resync from the sender's current base, then the chain
            // continues bit-identically
            let got = lossy.resync(pipe.sent_bytes().unwrap()).unwrap();
            assert_eq!(lossy.base_bytes(), good.base_bytes(), "{mode:?}");
            let reference = good.resync(pipe.sent_bytes().unwrap()).unwrap();
            assert_eq!(got.pool.weights, reference.pool.weights);
            let u = pipe.encode(&snaps[3]);
            let a = lossy.apply(&u).unwrap();
            let b = good.apply(&u).unwrap();
            assert_eq!(a.pool.weights, b.pool.weights, "{mode:?}");
            assert_eq!(lossy.base_bytes(), good.base_bytes(), "{mode:?}");
        }
    }

    #[test]
    fn receiver_without_template_errors_gracefully() {
        let snaps = trained_rounds(1, 100);
        let mut pipe = UpdatePipeline::new(UpdateMode::Quant);
        let mut recv = UpdateReceiver::new(UpdateMode::Quant);
        let u = pipe.encode(&snaps[0]);
        // the error is *matchable* — no string sniffing
        assert_eq!(recv.apply(&u).unwrap_err(), FleetError::MissingTemplate);
    }

    #[test]
    fn corrupt_wire_payload_is_a_matchable_error() {
        let snaps = trained_rounds(2, 200);
        let mut pipe = UpdatePipeline::new(UpdateMode::PatchOnly);
        let mut recv = UpdateReceiver::new(UpdateMode::PatchOnly);
        recv.apply(&pipe.encode(&snaps[0])).unwrap();
        let mut u = pipe.encode(&snaps[1]);
        u.bytes.truncate(u.bytes.len() / 2);
        match recv.apply(&u) {
            Err(FleetError::Corrupt(_)) => {}
            other => panic!("expected Corrupt, got {other:?}"),
        }
    }

    #[test]
    fn pipeline_state_roundtrip_resumes_the_delta_chain() {
        // export/restore mid-chain: a rebuilt pipeline must produce
        // bit-identical updates from the checkpointed bases (the
        // sender-side half of crash recovery), including the quantizer
        // grid recovered from the FWQ1 header.
        for mode in UpdateMode::ALL {
            let snaps = trained_rounds(4, 300);
            let mut pipe = UpdatePipeline::new(mode);
            pipe.encode(&snaps[0]);
            pipe.encode(&snaps[1]);
            let (prev_raw, prev_quant) = pipe.export_state();
            let mut resumed = UpdatePipeline::new(mode);
            resumed.restore_state(prev_raw, prev_quant).unwrap();
            for snap in &snaps[2..] {
                let a = pipe.encode(snap);
                let b = resumed.encode(snap);
                assert_eq!(a.bytes, b.bytes, "{mode:?} diverged after restore");
            }
            assert_eq!(pipe.sent_bytes(), resumed.sent_bytes(), "{mode:?}");
        }
    }

    #[test]
    fn reconstructed_model_predicts_close_to_original() {
        let snaps = trained_rounds(2, 2000);
        let mut pipe = UpdatePipeline::new(UpdateMode::QuantPatch);
        let mut recv = UpdateReceiver::new(UpdateMode::QuantPatch);
        recv.set_template(snaps[0].clone());
        let mut got = None;
        for snap in &snaps {
            got = Some(recv.apply(&pipe.encode(snap)).unwrap());
        }
        let got = got.unwrap();
        let orig = snaps.last().unwrap();
        let mut s = SyntheticStream::with_buckets(DatasetSpec::tiny(), 32, 1 << 10);
        let mut w1 = Workspace::new();
        let mut w2 = Workspace::new();
        for _ in 0..200 {
            let ex = s.next_example();
            let a = orig.predict(&ex, &mut w1);
            let b = got.predict(&ex, &mut w2);
            assert!((a - b).abs() < 0.01, "pred drift {a} vs {b}");
        }
    }
}
