//! Cross-module property tests (the offline stand-in for proptest; see
//! `fwumious::testutil::prop`).  Each property states a system
//! invariant the paper's machinery depends on.

use fwumious::config::ModelConfig;
use fwumious::data::synthetic::{DatasetSpec, SyntheticStream};
use fwumious::eval::auc;
use fwumious::fleet::{FleetConfig, FleetFabric, LinkSpec, Topology};
use fwumious::model::io;
use fwumious::model::regressor::Regressor;
use fwumious::model::Workspace;
use fwumious::patch::{apply_patch, make_patch, Compression};
use fwumious::quant;
use fwumious::testutil::prop;
use fwumious::transfer::{UpdateMode, UpdatePipeline, UpdateReceiver};
use fwumious::util::{compress, varint};

/// §6: apply(old, diff(old, new)) == new for arbitrary buffers.
#[test]
fn prop_patch_identity() {
    prop(80, |g| {
        let old = g.bytes(0..4096);
        let mut new = old.clone();
        // structured mutations typical of weight drift: 4-byte words
        for _ in 0..g.usize_in(0..100) {
            if new.len() < 4 {
                break;
            }
            let i = g.usize_in(0..new.len() - 3);
            for b in 0..4 {
                new[i + b] = g.u32() as u8;
            }
        }
        let p = make_patch(&old, &new, Compression::Lz);
        assert_eq!(apply_patch(&old, &p).unwrap(), new);
    });
}

/// §6: quant error ≤ bucket/2 and dequant(quant(x)) is idempotent
/// (quantizing an already-quantized vector is lossless).
#[test]
fn prop_quant_idempotent() {
    prop(40, |g| {
        let scale = g.f32_in(0.05, 4.0);
        let w = g.vec_normal(1..1500, scale);
        let (h, c) = quant::quantize(&w, 2, 2);
        let w1 = quant::dequantize(&h, &c);
        for (a, b) in w.iter().zip(&w1) {
            assert!((a - b).abs() <= h.bucket * 0.5 + 1e-5);
        }
        let (h2, c2) = quant::quantize(&w1, 2, 2);
        let w2 = quant::dequantize(&h2, &c2);
        for (a, b) in w1.iter().zip(&w2) {
            assert!(
                (a - b).abs() <= h2.bucket + 1e-5,
                "re-quantization drifted: {a} vs {b}"
            );
        }
    });
}

/// Model serialization: from_bytes(to_bytes(m)) == m for random
/// trained models of every architecture.
#[test]
fn prop_model_io_roundtrip() {
    prop(12, |g| {
        let buckets = 1u32 << g.usize_in(6..10);
        let fields = g.usize_in(2..6);
        let k = g.usize_in(1..4);
        let cfg = match g.usize_in(0..3) {
            0 => ModelConfig::linear(fields, buckets),
            1 => ModelConfig::ffm(fields, k, buckets),
            _ => {
                let h = vec![g.usize_in(2..10)];
                ModelConfig::deep_ffm(fields, k, buckets, &h)
            }
        };
        let mut reg = Regressor::new(&cfg);
        let mut ws = Workspace::new();
        let mut spec = DatasetSpec::tiny();
        spec.cont_fields = 1.min(fields - 1);
        spec.cat_fields = fields - spec.cont_fields;
        let mut s = SyntheticStream::with_buckets(spec, g.u64(), buckets);
        for _ in 0..200 {
            let ex = s.next_example();
            reg.learn(&ex, &mut ws);
        }
        let back = io::from_bytes(&io::to_bytes(&reg, true)).unwrap();
        assert_eq!(back.pool.weights, reg.pool.weights);
        assert_eq!(back.pool.acc, reg.pool.acc);
    });
}

/// AUC invariances: monotone-affine score transforms preserve AUC;
/// label flip maps a to 1-a.
#[test]
fn prop_auc_invariances() {
    prop(40, |g| {
        let n = g.usize_in(10..400);
        let scores: Vec<f32> = (0..n).map(|_| g.f32_in(0.0, 1.0)).collect();
        let labels: Vec<f32> = (0..n)
            .map(|_| if g.bool() { 1.0 } else { 0.0 })
            .collect();
        let a = auc(&scores, &labels);
        assert!((0.0..=1.0).contains(&a));
        // affine transform
        let s2: Vec<f32> = scores.iter().map(|v| v * 3.0 + 0.5).collect();
        assert!((auc(&s2, &labels) - a).abs() < 1e-12);
        // label flip
        let flipped: Vec<f32> = labels.iter().map(|y| 1.0 - y).collect();
        assert!((auc(&scores, &flipped) - (1.0 - a)).abs() < 1e-9);
    });
}

/// Context-cache equivalence: for any split point C, cached partial +
/// candidate completion == full forward.
#[test]
#[cfg_attr(miri, ignore)] // minutes under the interpreter even at 3 cases
fn prop_context_split_equivalence() {
    prop(15, |g| {
        let buckets = 1u32 << 8;
        let fields = g.usize_in(3..7);
        let cfg = match g.usize_in(0..2) {
            0 => ModelConfig::ffm(fields, g.usize_in(1..4), buckets),
            _ => ModelConfig::deep_ffm(fields, g.usize_in(1..4), buckets, &[6]),
        };
        let mut reg = Regressor::new(&cfg);
        let mut ws = Workspace::new();
        let mut spec = DatasetSpec::tiny();
        spec.cont_fields = 0;
        spec.cat_fields = fields;
        let mut s = SyntheticStream::with_buckets(spec, g.u64(), buckets);
        for _ in 0..300 {
            let ex = s.next_example();
            reg.learn(&ex, &mut ws);
        }
        for _ in 0..20 {
            let ex = s.next_example();
            let c = g.usize_in(1..fields);
            let full = reg.predict(&ex, &mut ws);
            let cp = reg.context_partial(&ex.slots[..c]);
            let via = reg.predict_with_partial(&cp, &ex.slots[c..], &mut ws);
            assert!((full - via).abs() < 1e-5, "split {c}: {full} vs {via}");
        }
    });
}

/// §6: the quantized byte format is a lossless container — header and
/// codes survive to_bytes/from_bytes exactly.
#[test]
fn prop_quant_bytes_roundtrip() {
    prop(40, |g| {
        let scale = g.f32_in(0.05, 3.0);
        let w = g.vec_normal(0..1200, scale);
        let alpha = g.usize_in(1..4) as u8;
        let beta = g.usize_in(1..4) as u8;
        let (h, codes) = quant::quantize(&w, alpha, beta);
        let bytes = quant::to_bytes(&h, &codes);
        let (h2, codes2) = quant::from_bytes(&bytes).unwrap();
        assert_eq!(h, h2);
        assert_eq!(codes, codes2);
    });
}

/// The wire codec under the patcher: decompress(compress(x)) == x on
/// weight-file-shaped inputs (repetitive headers + dense f32 payloads).
#[test]
fn prop_lz_roundtrip_on_model_shaped_data() {
    prop(30, |g| {
        let mut data = b"FWMODEL1".to_vec();
        for _ in 0..g.usize_in(0..800) {
            data.extend_from_slice(&g.f32_in(-1.0, 1.0).to_le_bytes());
        }
        // runs of unchanged bytes, like consecutive snapshots
        let pad = g.usize_in(0..600);
        data.resize(data.len() + pad, 0u8);
        let c = compress::compress(&data);
        assert_eq!(compress::decompress(&c).unwrap(), data);
    });
}

/// §6 end-to-end: every UpdateMode's pipeline→receiver roundtrip
/// reconstructs the sender's weights (exactly for raw/patch, within
/// half a quantization bucket otherwise), and the receiver's base file
/// always mirrors the sender's bit-for-bit.
#[test]
#[cfg_attr(miri, ignore)] // minutes under the interpreter even at 3 cases
fn prop_transfer_modes_reconstruct() {
    prop(8, |g| {
        let buckets = 1u32 << 9;
        let cfg = ModelConfig::ffm(4, 2, buckets);
        let mut reg = Regressor::new(&cfg);
        let mut ws = Workspace::new();
        let mut s =
            SyntheticStream::with_buckets(DatasetSpec::tiny(), g.u64(), buckets);
        let mode = *g.rng().choose(&UpdateMode::ALL);
        let mut pipe = UpdatePipeline::new(mode);
        let mut recv = UpdateReceiver::new(mode);
        recv.set_template(reg.clone());
        for _ in 0..g.usize_in(1..4) {
            for _ in 0..400 {
                let ex = s.next_example();
                reg.learn(&ex, &mut ws);
            }
            let got = recv.apply(&pipe.encode(&reg)).unwrap();
            assert_eq!(pipe.sent_bytes(), recv.base_bytes(), "{mode:?}");
            match mode {
                UpdateMode::Raw | UpdateMode::PatchOnly => {
                    assert_eq!(got.pool.weights, reg.pool.weights, "{mode:?}");
                }
                UpdateMode::Quant | UpdateMode::QuantPatch => {
                    let max_err = got
                        .pool
                        .weights
                        .iter()
                        .zip(&reg.pool.weights)
                        .map(|(a, b)| (a - b).abs())
                        .fold(0.0f32, f32::max);
                    assert!(max_err < 1e-3, "{mode:?} err {max_err}");
                }
            }
        }
    });
}

/// Fleet delta chains: K chained updates with random drop-then-
/// catch-up points (random modes, random replay windows) leave every
/// replica bit-identical to a fresh full snapshot decoded straight
/// from the sender's base file.
#[test]
#[cfg_attr(miri, ignore)] // minutes under the interpreter even at 3 cases
fn prop_fleet_delta_chain_catchup_bit_identical() {
    // bit-exact across the whole run: serialize against rung forcing
    // (the cross-rung parity property toggles the dispatch atomic)
    let _serial = fwumious::simd::forcing_lock();
    prop(6, |g| {
        let buckets = 1u32 << 9;
        let cfg = ModelConfig::ffm(4, 2, buckets);
        let mode = *g.rng().choose(&UpdateMode::ALL);
        let topo = Topology::uniform(2, 2, LinkSpec::wan(), LinkSpec::lan());
        let mut fcfg = FleetConfig::new(topo, mode);
        // 0 disables replay entirely (resync-only fleet)
        fcfg.max_chain = g.usize_in(0..4);
        fcfg.seed = g.u64();
        let mut reg = Regressor::new(&cfg);
        let mut fabric = FleetFabric::new(fcfg, &reg);
        let mut ws = Workspace::new();
        let mut s =
            SyntheticStream::with_buckets(DatasetSpec::tiny(), g.u64(), buckets);
        let rounds = g.usize_in(2..6);
        for _ in 0..rounds {
            if g.bool() {
                fabric.force_drops(g.usize_in(1..4) as u32);
            }
            for _ in 0..300 {
                let ex = s.next_example();
                reg.learn(&ex, &mut ws);
            }
            fabric.publish(&reg).unwrap();
        }
        fabric.converge().unwrap();
        // a brand-new receiver fed only the sender's current base must
        // decode the exact same weights every replica converged to
        let mut fresh = UpdateReceiver::new(mode);
        fresh.set_template(Regressor::new(&cfg));
        let expect = fresh.resync(fabric.sender_base().unwrap()).unwrap();
        assert_eq!(
            expect.pool.weights,
            fabric.reference().unwrap().pool.weights,
            "{mode:?}: reference receiver drifted from the sender base"
        );
        for rep in fabric.replicas() {
            assert_eq!(rep.seq(), fabric.head(), "{mode:?} {:?}", rep.id);
            assert_eq!(
                rep.model().pool.weights,
                expect.pool.weights,
                "{mode:?} {:?}: replica differs from fresh snapshot",
                rep.id
            );
        }
    });
}

/// Tentpole invariant of the crash-recovery PR: for every UpdateMode
/// and any crash point, a fabric checkpointed mid-run (through the
/// full `FWCKPT1` byte serialization), dropped, restored, and driven
/// through the remaining snapshots is bit-identical to one that never
/// crashed — head version, sender base file, every replica's weights
/// and cursor, RNG-driven drop placement and the byte ledgers alike.
#[test]
#[cfg_attr(miri, ignore)] // minutes under the interpreter even at 3 cases
fn prop_crash_restore_replays_bit_identically() {
    use fwumious::fleet::FabricCheckpoint;
    // bit-exact across the whole run: serialize against rung forcing
    let _serial = fwumious::simd::forcing_lock();
    prop(6, |g| {
        let buckets = 1u32 << 9;
        let cfg = ModelConfig::ffm(4, 2, buckets);
        let mode = *g.rng().choose(&UpdateMode::ALL);
        let template = Regressor::new(&cfg);
        // one shared snapshot sequence feeds both runs
        let mut reg = template.clone();
        let mut ws = Workspace::new();
        let mut s =
            SyntheticStream::with_buckets(DatasetSpec::tiny(), g.u64(), buckets);
        let rounds = g.usize_in(4..8);
        let snaps: Vec<Regressor> = (0..rounds)
            .map(|_| {
                for _ in 0..250 {
                    let ex = s.next_example();
                    reg.learn(&ex, &mut ws);
                }
                reg.clone()
            })
            .collect();
        // identical drop schedule for both runs; the fabric's own RNG
        // decides placement, and restore resumes that RNG exactly
        let drops: Vec<u32> = (0..rounds)
            .map(|_| if g.bool() { g.usize_in(1..3) as u32 } else { 0 })
            .collect();
        let topo = Topology::uniform(2, 2, LinkSpec::wan(), LinkSpec::lan());
        let mut fcfg = FleetConfig::new(topo, mode);
        fcfg.seed = g.u64();
        let crash_at = g.usize_in(1..rounds);

        let run = |fab: &mut FleetFabric, from: usize, to: usize| {
            for r in from..to {
                if drops[r] > 0 {
                    fab.force_drops(drops[r]);
                }
                fab.publish(&snaps[r]).unwrap();
            }
        };

        let mut gold = FleetFabric::new(fcfg.clone(), &template);
        run(&mut gold, 0, rounds);

        let mut doomed = FleetFabric::new(fcfg.clone(), &template);
        run(&mut doomed, 0, crash_at);
        let bytes = doomed.checkpoint().to_bytes();
        drop(doomed); // the crash
        let ckpt = FabricCheckpoint::from_bytes(&bytes)
            .unwrap_or_else(|e| panic!("{mode:?}: decode: {e}"));
        let mut revived = FleetFabric::restore(fcfg.clone(), &template, &ckpt)
            .unwrap_or_else(|e| panic!("{mode:?}: restore: {e}"));
        assert_eq!(revived.head(), crash_at as u64, "{mode:?}");
        run(&mut revived, crash_at, rounds);

        assert_eq!(revived.head(), gold.head(), "{mode:?}");
        assert_eq!(revived.sender_base(), gold.sender_base(), "{mode:?}");
        for (a, b) in revived.replicas().iter().zip(gold.replicas()) {
            assert_eq!(a.seq(), b.seq(), "{mode:?} {:?}", a.id);
            assert_eq!(
                a.model().pool.weights,
                b.model().pool.weights,
                "{mode:?} {:?}: restored replica diverged from gold",
                a.id
            );
        }
        let (mg, mr) = (gold.metrics(), revived.metrics());
        assert_eq!(mr.inter_bytes(), mg.inter_bytes(), "{mode:?}");
        assert_eq!(mr.intra_bytes(), mg.intra_bytes(), "{mode:?}");
        assert_eq!(mr.drops(), mg.drops(), "{mode:?}");
        assert_eq!(mr.replays, mg.replays, "{mode:?}");
        assert_eq!(mr.resyncs, mg.resyncs, "{mode:?}");
    });
}

/// Varint + zigzag total round-trip over adversarial values.
#[test]
fn prop_varint_roundtrip() {
    prop(60, |g| {
        let mut buf = Vec::new();
        let vals: Vec<u64> = (0..g.usize_in(1..200))
            .map(|_| g.u64() >> g.usize_in(0..64))
            .collect();
        for &v in &vals {
            varint::write_u64(&mut buf, v);
        }
        let mut pos = 0;
        for &v in &vals {
            assert_eq!(varint::read_u64(&buf, &mut pos), Some(v));
        }
        assert_eq!(pos, buf.len());
        // zigzag
        let signed: Vec<i64> = (0..50).map(|_| g.u64() as i64).collect();
        for &s in &signed {
            assert_eq!(varint::unzigzag(varint::zigzag(s)), s);
        }
    });
}

/// Training stability: no weight ever becomes non-finite across random
/// hyperparameters (clamped sigmoid + AdaGrad must keep things sane).
#[test]
#[cfg_attr(miri, ignore)] // minutes under the interpreter even at 3 cases
fn prop_training_stays_finite() {
    prop(10, |g| {
        let buckets = 1u32 << 8;
        let mut cfg = ModelConfig::deep_ffm(4, 2, buckets, &[g.usize_in(2..12)]);
        cfg.lr = g.f32_in(0.01, 0.9);
        cfg.ffm_lr = g.f32_in(0.01, 0.9);
        cfg.nn_lr = g.f32_in(0.01, 0.5);
        cfg.power_t = g.f32_in(0.0, 0.6);
        cfg.seed = g.u64();
        let mut reg = Regressor::new(&cfg);
        let mut ws = Workspace::new();
        let mut s = SyntheticStream::with_buckets(DatasetSpec::tiny(), g.u64(), buckets);
        for _ in 0..1500 {
            let ex = s.next_example();
            let p = reg.learn(&ex, &mut ws);
            assert!(p.is_finite());
        }
        assert!(reg.pool.weights.iter().all(|w| w.is_finite()));
    });
}

/// Hogwild with any thread count produces a usable (finite, learning)
/// model — lost updates are tolerated, corruption is not.
#[test]
#[cfg_attr(miri, ignore)] // minutes under the interpreter even at 3 cases
fn prop_hogwild_robustness() {
    use fwumious::train::hogwild::{train_chunk, HogwildConfig};
    prop(6, |g| {
        let buckets = 1u32 << 8;
        let cfg = ModelConfig::ffm(4, 2, buckets);
        let mut s = SyntheticStream::with_buckets(DatasetSpec::tiny(), g.u64(), buckets);
        let chunk = s.take_examples(4000);
        let mut reg = Regressor::new(&cfg);
        let threads = g.usize_in(1..9);
        train_chunk(&mut reg, &chunk, HogwildConfig { threads }, 1000);
        assert!(reg.pool.weights.iter().all(|w| w.is_finite()));
        // still predicts both classes
        let mut ws = Workspace::new();
        let preds: Vec<f32> = (0..200)
            .map(|_| reg.predict(&s.next_example(), &mut ws))
            .collect();
        let spread = preds.iter().cloned().fold(f32::MIN, f32::max)
            - preds.iter().cloned().fold(f32::MAX, f32::min);
        assert!(spread > 1e-4, "degenerate constant predictor");
    });
}

/// Tentpole invariant of the batched scoring PR: for every
/// architecture, latent dim and context split,
/// `predict_batch_with_partial` matches scoring the same candidates one
/// at a time through `predict_with_partial`, and both match the full
/// (uncached, unbatched) forward pass — zero-valued slots included.
#[test]
#[cfg_attr(miri, ignore)] // minutes under the interpreter even at 3 cases
fn prop_batched_scoring_matches_sequential() {
    use fwumious::feature::{Example, FeatureSlot};
    prop(20, |g| {
        let buckets = 1u32 << 8;
        let fields = g.usize_in(4..10);
        let k = [2usize, 4, 8, 16][g.usize_in(0..4)];
        for arch in 0..3usize {
            let cfg = match arch {
                0 => ModelConfig::linear(fields, buckets),
                1 => ModelConfig::ffm(fields, k, buckets),
                _ => ModelConfig::deep_ffm(fields, k, buckets, &[8]),
            };
            let mut reg = Regressor::new(&cfg);
            for w in reg.pool.weights.iter_mut() {
                *w = g.f32_in(-0.4, 0.4);
            }
            let ctx_len = g.usize_in(1..fields);
            let slot = |g: &mut fwumious::testutil::Gen, f: usize| FeatureSlot {
                field: f as u16,
                bucket: g.u32() & (buckets - 1),
                value: if g.usize_in(0..5) == 0 { 0.0 } else { g.f32_in(0.1, 1.5) },
            };
            let ctx: Vec<FeatureSlot> =
                (0..ctx_len).map(|f| slot(g, f)).collect();
            let bsz = g.usize_in(1..13);
            let cands: Vec<Vec<FeatureSlot>> = (0..bsz)
                .map(|_| (ctx_len..fields).map(|f| slot(g, f)).collect())
                .collect();
            let cp = reg.context_partial(&ctx);
            let mut ws_seq = Workspace::new();
            let seq: Vec<f32> = cands
                .iter()
                .map(|cand| reg.predict_with_partial(&cp, cand, &mut ws_seq))
                .collect();
            let mut ws_b = Workspace::new();
            let mut got = Vec::new();
            reg.predict_batch_with_partial(&cp, &cands, &mut ws_b, &mut got);
            assert_eq!(got.len(), bsz);
            let mut ws_f = Workspace::new();
            for (b, cand) in cands.iter().enumerate() {
                assert!(
                    (got[b] - seq[b]).abs() < 1e-5,
                    "arch {arch} f={fields} k={k} c={ctx_len} b={b}: \
                     batched {} vs sequential {}",
                    got[b],
                    seq[b]
                );
                let mut slots = ctx.clone();
                slots.extend_from_slice(cand);
                let ex = Example { label: 0.0, importance: 1.0, slots };
                let full = reg.predict(&ex, &mut ws_f);
                assert!(
                    (got[b] - full).abs() < 1e-5,
                    "arch {arch} f={fields} k={k} c={ctx_len} b={b}: \
                     batched {} vs full {full}",
                    got[b]
                );
            }
        }
    });
}

/// Tentpole invariant of the cross-request coalescing PR: scoring a
/// flushed slate of requests through the group planner — same-context
/// requests coalesced into one union-slate kernel pass, chunked at the
/// workspace cap — must be **bitwise** identical to scoring each
/// request alone through the per-request batched path, on all three
/// architectures.  Random mixes of shared/unique contexts, candidate
/// fanouts k ∈ {0, 1, 2, 8} and caps small enough that hot groups hit
/// the chunking path.
#[test]
#[cfg_attr(miri, ignore)] // minutes under the interpreter even at 3 cases
fn prop_grouped_scoring_matches_per_request() {
    use fwumious::feature::FeatureSlot;
    use fwumious::serve::context_cache::ContextCache;
    use fwumious::serve::router::Router;
    use fwumious::serve::server::score_requests_coalesced;
    use fwumious::serve::{ModelHandle, Request};
    // bit-exact grouped-vs-sequential: serialize against rung forcing
    let _serial = fwumious::simd::forcing_lock();
    prop(10, |g| {
        let buckets = 1u32 << 8;
        for arch in 0..3usize {
            let fields = g.usize_in(4..9);
            let k = [2usize, 4, 8][g.usize_in(0..3)];
            let cfg = match arch {
                0 => ModelConfig::linear(fields, buckets),
                1 => ModelConfig::ffm(fields, k, buckets),
                _ => ModelConfig::deep_ffm(fields, k, buckets, &[8]),
            };
            let mut reg = Regressor::new(&cfg);
            for w in reg.pool.weights.iter_mut() {
                *w = g.f32_in(-0.4, 0.4);
            }
            let ctx_len = g.usize_in(1..fields);
            let slot = |g: &mut fwumious::testutil::Gen, f: usize| FeatureSlot {
                field: f as u16,
                bucket: g.u32() & (buckets - 1),
                value: if g.usize_in(0..5) == 0 {
                    0.0
                } else {
                    g.f32_in(0.1, 1.5)
                },
            };
            // a few distinct contexts, shared by several requests
            let n_ctx = g.usize_in(1..4);
            let contexts: Vec<Vec<FeatureSlot>> = (0..n_ctx)
                .map(|_| (0..ctx_len).map(|f| slot(g, f)).collect())
                .collect();
            let n_req = g.usize_in(2..9);
            let reqs: Vec<Request> = (0..n_req)
                .map(|_| {
                    let fanout = [0usize, 1, 2, 8][g.usize_in(0..4)];
                    Request {
                        model: "m".into(),
                        context: contexts[g.usize_in(0..n_ctx)].clone(),
                        candidates: (0..fanout)
                            .map(|_| (ctx_len..fields).map(|f| slot(g, f)).collect())
                            .collect(),
                    }
                })
                .collect();
            let router = Router::new(1);
            router.register("m", ModelHandle::new(reg.clone()));
            // caps 1 and 3 force chunked union slates; 1024 never chunks
            let cap = [1usize, 3, 1024][g.usize_in(0..3)];
            let mut cache = ContextCache::new(64);
            let mut ws = Workspace::new();
            let (grouped, plan) =
                score_requests_coalesced(&router, &mut cache, &mut ws, cap, &reqs);
            assert_eq!(grouped.len(), n_req);
            assert!(plan.groups as usize <= n_ctx, "more groups than contexts");
            // reference: the per-request batched path (PR 3's serving
            // inner loop), fresh workspace
            let mut ws_ref = Workspace::new();
            for (i, req) in reqs.iter().enumerate() {
                let cp = reg.context_partial(&req.context);
                let mut want = Vec::new();
                reg.predict_batch_with_partial(&cp, &req.candidates, &mut ws_ref, &mut want);
                let got = grouped[i]
                    .as_ref()
                    .unwrap_or_else(|e| panic!("request {i} errored: {e}"));
                assert_eq!(
                    got.scores, want,
                    "arch {arch} fields={fields} k={k} cap={cap} req {i}: \
                     grouped path diverged from per-request path"
                );
            }
        }
    });
}

/// Tentpole invariant of the batched training PR: `learn_batch` is the
/// same learner.  B = 1 must be **bit-identical** to `learn()` (scores,
/// weights and AdaGrad accumulators), and a B-example micro-batch must
/// record the same weight updates (via `GradRecorder`) as B per-example
/// backward passes at the same frozen weights — within fp reassociation
/// — on all three architectures, for B ∈ {2, 4, 8}.
#[test]
#[cfg_attr(miri, ignore)] // minutes under the interpreter even at 3 cases
fn prop_learn_batch_matches_per_example() {
    use fwumious::model::optimizer::GradRecorder;
    // B=1 bit-identity: serialize against rung forcing
    let _serial = fwumious::simd::forcing_lock();
    prop(6, |g| {
        let buckets = 1u32 << 8;
        let k = [2usize, 4, 8][g.usize_in(0..3)];
        for arch in 0..3usize {
            let mut cfg = match arch {
                0 => ModelConfig::linear(4, buckets),
                1 => ModelConfig::ffm(4, k, buckets),
                _ => ModelConfig::deep_ffm(4, k, buckets, &[g.usize_in(4..12)]),
            };
            cfg.seed = g.u64();
            let mut s =
                SyntheticStream::with_buckets(DatasetSpec::tiny(), g.u64(), buckets);

            // B = 1: the full learning sequence is bit-identical.
            let warm = s.take_examples(48);
            let mut a = Regressor::new(&cfg);
            let mut b = Regressor::new(&cfg);
            let mut ws_a = Workspace::new();
            let mut ws_b = Workspace::new();
            let mut scores = Vec::new();
            for ex in &warm {
                let pa = a.learn(ex, &mut ws_a);
                b.learn_batch(std::slice::from_ref(ex), &mut ws_b, &mut scores);
                assert_eq!(pa.to_bits(), scores[0].to_bits(), "arch {arch}");
            }
            assert_eq!(a.pool.weights, b.pool.weights, "arch {arch} weights");
            assert_eq!(a.pool.acc, b.pool.acc, "arch {arch} acc");

            // B in {2, 4, 8}: recorded batched gradients == summed
            // per-example gradients at the (warm) frozen weights.
            for bs in [2usize, 4, 8] {
                let exs = s.take_examples(bs);
                let total = a.layout.total;
                let mut want = vec![0f32; total];
                let mut p_want = Vec::new();
                {
                    let mut reg = a.clone();
                    let mut ws = Workspace::new();
                    for ex in &exs {
                        let p = reg.predict(ex, &mut ws);
                        p_want.push(p);
                        let d = (p - ex.label) * ex.importance;
                        let mut r_lr = GradRecorder::default();
                        let mut r_ffm = GradRecorder::default();
                        let mut r_nn = GradRecorder::default();
                        reg.backward(ex, &mut ws, d, &mut r_lr, &mut r_ffm, &mut r_nn);
                        for rec in [r_lr, r_ffm, r_nn] {
                            for (w, gv) in want.iter_mut().zip(rec.dense(total)) {
                                *w += gv;
                            }
                        }
                    }
                }
                let mut reg = a.clone();
                let mut ws = Workspace::new();
                let mut p_got = Vec::new();
                reg.predict_batch(&exs, &mut ws, &mut p_got);
                assert_eq!(p_got.len(), bs);
                for (i, (pg, pw)) in p_got.iter().zip(&p_want).enumerate() {
                    assert!(
                        (pg - pw).abs() < 1e-5,
                        "arch {arch} B={bs} score {i}: {pg} vs {pw}"
                    );
                }
                let d: Vec<f32> = exs
                    .iter()
                    .zip(&p_got)
                    .map(|(ex, &p)| (p - ex.label) * ex.importance)
                    .collect();
                let mut r_lr = GradRecorder::default();
                let mut r_ffm = GradRecorder::default();
                let mut r_nn = GradRecorder::default();
                reg.backward_batch(&exs, &mut ws, &d, &mut r_lr, &mut r_ffm, &mut r_nn);
                let mut got = vec![0f32; total];
                for rec in [r_lr, r_ffm, r_nn] {
                    for (w, gv) in got.iter_mut().zip(rec.dense(total)) {
                        *w += gv;
                    }
                }
                for i in 0..total {
                    assert!(
                        (got[i] - want[i]).abs() < 1e-5 * (1.0 + want[i].abs()),
                        "arch {arch} B={bs} grad {i}: {} vs {}",
                        got[i],
                        want[i]
                    );
                }
            }
        }
    });
}

/// Batch-strided workspace buffers make resize bugs easy to hit: a
/// single `Workspace` interleaved across models of different geometry
/// (fields / latent dim / hidden widths) and different batch sizes must
/// score bit-identically to a fresh workspace every time.
#[test]
#[cfg_attr(miri, ignore)] // minutes under the interpreter even at 3 cases
fn workspace_survives_interleaved_model_dims() {
    use fwumious::serve::trace::TraceGenerator;
    // bit-exact stale-vs-fresh workspace: serialize against rung forcing
    let _serial = fwumious::simd::forcing_lock();
    let cfgs = [
        ModelConfig::deep_ffm(4, 2, 256, &[8]),
        ModelConfig::deep_ffm(9, 8, 512, &[32, 16]),
        ModelConfig::ffm(6, 4, 256),
        ModelConfig::linear(5, 256),
        ModelConfig::deep_ffm(7, 16, 1024, &[16]),
    ];
    let regs: Vec<Regressor> = cfgs
        .iter()
        .enumerate()
        .map(|(i, cfg)| {
            let mut reg = Regressor::new(cfg);
            let mut rng = fwumious::util::rng::Pcg32::seeded(900 + i as u64);
            for w in reg.pool.weights.iter_mut() {
                *w = rng.normal() * 0.2;
            }
            reg
        })
        .collect();
    let mut shared = Workspace::new();
    for round in 0..3u64 {
        // batch size varies per round so strided buffers grow AND shrink
        let fanout = [16usize, 1, 5][round as usize];
        for (i, reg) in regs.iter().enumerate() {
            let fields = reg.cfg.fields;
            let ctx_fields = (fields / 2).max(1);
            let mut gen = TraceGenerator::new(
                round * 31 + i as u64,
                fields,
                ctx_fields,
                reg.cfg.buckets,
                fanout,
            );
            let req = gen.next_request("m");
            let cp = reg.context_partial(&req.context);
            let mut got = Vec::new();
            reg.predict_batch_with_partial(&cp, &req.candidates, &mut shared, &mut got);
            let mut fresh = Workspace::new();
            let mut want = Vec::new();
            reg.predict_batch_with_partial(&cp, &req.candidates, &mut fresh, &mut want);
            assert_eq!(
                got, want,
                "round {round} model {i}: stale workspace state leaked"
            );
        }
    }
}

/// Miri anchor: the dispatch entry points agree with naive reference
/// loops.  Under the interpreter the scalar kernels are the executed
/// path by construction (`simd::detect` compiles the CPUID probe out
/// under `cfg(miri)`), so this is the nightly Miri job's tour of the
/// real kernel code; natively it doubles as a dispatch-vs-reference
/// tolerance check on whatever ISA the host has.  Deliberately no
/// `ForcedIsaGuard` here — the dispatch atomic is process-global and
/// forcing it would race the bit-exact props on sibling test threads.
#[test]
fn miri_scalar_kernels_roundtrip() {
    use fwumious::simd::{batch, dot};
    prop(4, |g| {
        // single-vector kernels vs naive loops
        let n = g.usize_in(1..40);
        let a = g.vec_f32(n..n + 1, -1.0, 1.0);
        let b = g.vec_f32(n..n + 1, -1.0, 1.0);
        let want_dot: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot::dot(&a, &b) - want_dot).abs() < 1e-4);
        let alpha = g.f32_in(-2.0, 2.0);
        let mut y = b.clone();
        dot::axpy(alpha, &a, &mut y);
        for i in 0..n {
            assert!((y[i] - (b[i] + alpha * a[i])).abs() < 1e-5);
        }
        // batched matmul vs per-row matvec
        let (batch_n, rows, cols) =
            (g.usize_in(1..5), g.usize_in(1..6), g.usize_in(1..12));
        let x = g.vec_f32(batch_n * rows..batch_n * rows + 1, -1.0, 1.0);
        let w = g.vec_f32(rows * cols..rows * cols + 1, -1.0, 1.0);
        let bias = g.vec_f32(cols..cols + 1, -1.0, 1.0);
        let mut out = vec![0f32; batch_n * cols];
        batch::matmul_rowmajor(&x, batch_n, &w, rows, cols, Some(&bias), &mut out);
        for bi in 0..batch_n {
            let mut want = bias.clone();
            dot::matvec_rowmajor(
                &x[bi * rows..(bi + 1) * rows],
                &w,
                Some(&bias),
                &mut want,
            );
            for j in 0..cols {
                assert!(
                    (out[bi * cols + j] - want[j]).abs() < 1e-4,
                    "matmul row {bi} col {j}"
                );
            }
        }
        // rowwise reductions vs naive sums
        let mut sums = vec![0f32; batch_n];
        let mut sq = vec![0f32; batch_n];
        batch::rowwise_sum(&out, batch_n, cols, &mut sums);
        batch::rowwise_sumsq(&out, batch_n, cols, &mut sq);
        for bi in 0..batch_n {
            let row = &out[bi * cols..(bi + 1) * cols];
            let s: f32 = row.iter().sum();
            let s2: f32 = row.iter().map(|v| v * v).sum();
            assert!((sums[bi] - s).abs() < 1e-4);
            assert!((sq[bi] - s2).abs() < 1e-4);
        }
    });
}

/// The ISA-ladder contract: every rung the host offers, forced via
/// `ForcedIsaGuard` under the process-wide forcing lock, agrees with
/// the scalar reference on every dispatched kernel — the vector spine
/// (`dot`/`axpy`/`matvec_rowmajor`), the batched GEMM trio, the
/// rowwise reductions, and the FFM pair kernels at k ∈ {2, 4, 8, 16}
/// — across ragged shapes straddling the 8/16-lane thresholds and the
/// 32-element dot cutover.  A forced-Scalar rung must reproduce the
/// reference bit-for-bit (same code path by construction); vector
/// rungs get a 1e-5 relative tolerance (fp reassociation only).
#[test]
#[cfg_attr(miri, ignore)] // CPUID probe compiled out under Miri: one rung only
fn prop_cross_rung_kernel_parity() {
    use fwumious::feature::{Example, FeatureSlot};
    use fwumious::model::block_ffm;
    use fwumious::model::weights::{Layout, WeightPool};
    use fwumious::simd::{self, batch, dot, ForcedIsaGuard, IsaLevel};
    use fwumious::util::rng::Pcg32;

    // the guard swaps a process-global dispatch atomic: serialize with
    // every other bit-exact property in this binary
    let _serial = simd::forcing_lock();

    fn close(got: f32, want: f32, bit: bool, what: &str) {
        if bit {
            assert_eq!(
                got.to_bits(),
                want.to_bits(),
                "{what}: {got} != {want} bitwise"
            );
        } else {
            assert!(
                (got - want).abs() < 1e-5 * (1.0 + want.abs()),
                "{what}: {got} vs {want}"
            );
        }
    }

    // --- the dense spine: (batch, rows, cols) ragged shapes ---
    let shapes = [
        (1usize, 3usize, 5usize), // everything below every threshold
        (2, 7, 8),                // one ymm column strip exactly
        (3, 9, 17),               // zmm strip + 1-wide tail
        (2, 17, 33),              // dot above its scalar cutover
        (1, 33, 48),              // register-blocked matvec shapes
        (4, 5, 100),              // wide rows, ragged 4-lane tail
    ];
    let mut rng = Pcg32::seeded(0xC0FFEE);
    for (case, &(bn, rows, cols)) in shapes.iter().enumerate() {
        let fill = |rng: &mut Pcg32, n: usize| -> Vec<f32> {
            (0..n).map(|_| rng.normal() * 0.5).collect()
        };
        let x = fill(&mut rng, bn * rows);
        let w = fill(&mut rng, rows * cols);
        let bias = fill(&mut rng, cols);
        let dy = fill(&mut rng, bn * cols);
        let va = fill(&mut rng, cols);
        let vb = fill(&mut rng, cols);
        let alpha = 0.75f32;

        struct Ref {
            dot: f32,
            axpy: Vec<f32>,
            mv: Vec<f32>,
            mm: Vec<f32>,
            mt: Vec<f32>,
            xt: Vec<f32>,
            sum: Vec<f32>,
            sq: Vec<f32>,
        }
        let run = |lvl: IsaLevel| -> Ref {
            let _g = ForcedIsaGuard::force(lvl);
            let mut axpy = vb.clone();
            dot::axpy(alpha, &va, &mut axpy);
            let mut mv = vec![0f32; cols];
            dot::matvec_rowmajor(&x[..rows], &w, Some(&bias), &mut mv);
            let mut mm = vec![0f32; bn * cols];
            batch::matmul_rowmajor(&x, bn, &w, rows, cols, Some(&bias), &mut mm);
            let mut mt = vec![0f32; bn * rows];
            batch::matmul_transposed(&dy, bn, &w, rows, cols, &mut mt);
            let mut xt = vec![0f32; rows * cols];
            batch::matmul_xt_dy(&x, bn, &dy, rows, cols, &mut xt);
            let mut sum = vec![0f32; bn];
            batch::rowwise_sum(&mm, bn, cols, &mut sum);
            let mut sq = vec![0f32; bn];
            batch::rowwise_sumsq(&mm, bn, cols, &mut sq);
            Ref { dot: dot::dot(&va, &vb), axpy, mv, mm, mt, xt, sum, sq }
        };

        let want = run(IsaLevel::Scalar);
        for lvl in simd::available_levels() {
            let got = run(lvl);
            let bit = lvl == IsaLevel::Scalar;
            let tag = format!("case {case} rung {}", lvl.name());
            close(got.dot, want.dot, bit, &format!("{tag} dot"));
            for (name, g, r) in [
                ("axpy", &got.axpy, &want.axpy),
                ("matvec", &got.mv, &want.mv),
                ("matmul", &got.mm, &want.mm),
                ("matmul_t", &got.mt, &want.mt),
                ("xt_dy", &got.xt, &want.xt),
                ("rowwise_sum", &got.sum, &want.sum),
                ("rowwise_sumsq", &got.sq, &want.sq),
            ] {
                assert_eq!(g.len(), r.len());
                for (i, (a, b)) in g.iter().zip(r.iter()).enumerate() {
                    close(*a, *b, bit, &format!("{tag} {name}[{i}]"));
                }
            }
        }
    }

    // --- the FFM pair kernels, per rung × latent dim ---
    for k in [2usize, 4, 8, 16] {
        let fields = 6usize;
        let ctx_len = 2usize;
        let cfg = ModelConfig::ffm(fields, k, 64);
        let layout = Layout::new(&cfg);
        let mut pool = WeightPool::init(&cfg, &layout);
        let mut rng = Pcg32::seeded(7000 + k as u64);
        for w in &mut pool.weights[layout.ffm_off..] {
            *w = rng.normal() * 0.3;
        }
        let slot = |rng: &mut Pcg32, f: usize| FeatureSlot {
            field: f as u16,
            bucket: rng.below(64),
            value: if rng.below(6) == 0 { 0.0 } else { 0.3 + rng.next_f32() },
        };
        let slots: Vec<FeatureSlot> =
            (0..fields).map(|f| slot(&mut rng, f)).collect();
        let ex = Example { label: 1.0, importance: 1.0, slots };
        let ctx: Vec<FeatureSlot> =
            (0..ctx_len).map(|f| slot(&mut rng, f)).collect();
        let batch_n = 5usize;
        let cw = fields - ctx_len;
        let mut cand = Vec::new();
        for _ in 0..batch_n {
            for f in ctx_len..fields {
                cand.push(slot(&mut rng, f));
            }
        }
        assert_eq!(cand.len(), batch_n * cw);
        let np = cfg.pairs();

        let run = |lvl: IsaLevel| -> (f32, Vec<f32>, Vec<f32>) {
            let _g = ForcedIsaGuard::force(lvl);
            let mut pairs = vec![0f32; np];
            let total =
                block_ffm::forward(&pool.weights, &layout, fields, k, &ex, &mut pairs);
            // ctx×ctx entries stay at the init value on every rung, so
            // a plain element-wise compare covers them too
            let mut bp = vec![0f32; batch_n * np];
            block_ffm::forward_partial_batch(
                &pool.weights,
                &layout,
                fields,
                k,
                ctx_len,
                &ctx,
                &cand,
                &mut bp,
            );
            (total, pairs, bp)
        };

        let (wt, wp, wbp) = run(IsaLevel::Scalar);
        for lvl in simd::available_levels() {
            let (gt, gp, gbp) = run(lvl);
            let bit = lvl == IsaLevel::Scalar;
            let tag = format!("ffm k={k} rung {}", lvl.name());
            close(gt, wt, bit, &format!("{tag} total"));
            for (i, (a, b)) in gp.iter().zip(wp.iter()).enumerate() {
                close(*a, *b, bit, &format!("{tag} pair[{i}]"));
            }
            for (i, (a, b)) in gbp.iter().zip(wbp.iter()).enumerate() {
                close(*a, *b, bit, &format!("{tag} batch-pair[{i}]"));
            }
        }
    }
}
