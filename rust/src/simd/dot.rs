//! Vector kernels with runtime scalar/AVX2+FMA/AVX-512 dispatch.
//!
//! These are the inner loops of both training and inference: FFM latent
//! dot products, LR accumulation, and the neural block's dense matvec
//! (the paper reached for BLAS here; our hand-rolled FMA matvec serves
//! the same role without an external dependency).  Each kernel exists
//! per rung of the [`IsaLevel`] ladder; the AVX-512 variants widen the
//! 8-lane ymm loops to 16-lane zmm with the same explicit reduction
//! trees, so within one rung results are deterministic.

use super::{isa_level, IsaLevel};

/// Below this length the vector path loses to the scalar loop: the
/// `#[target_feature]` call boundary (never inlined into plain-ABI
/// callers) plus the horizontal reduction cost more than a handful of
/// scalar FMAs.  FFM latent dots (K = 2..8) take the scalar path; the
/// MergeNorm/ MLP vectors (D, H = 16..) take the wide path.
const SIMD_MIN_LEN: usize = 32;

/// `sum_i a[i] * b[i]`
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    if a.len() < SIMD_MIN_LEN {
        return dot_scalar(a, b);
    }
    match isa_level() {
        IsaLevel::Scalar => dot_scalar(a, b),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `isa_level` returns Avx2Fma only after runtime CPUID
        // confirmed avx2+fma; equal lengths are the kernel's contract,
        // asserted above.
        IsaLevel::Avx2Fma => unsafe { dot_avx2(a, b) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `isa_level` returns Avx512 only after runtime CPUID
        // confirmed avx512f/bw/dq/vl (+avx2+fma); equal lengths are the
        // kernel's contract, asserted above.
        IsaLevel::Avx512 => unsafe { dot_avx512(a, b) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => dot_scalar(a, b),
    }
}

/// `y[i] += alpha * x[i]`
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    if x.len() < SIMD_MIN_LEN {
        return axpy_scalar(alpha, x, y);
    }
    match isa_level() {
        IsaLevel::Scalar => axpy_scalar(alpha, x, y),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `isa_level` returns Avx2Fma only after runtime CPUID
        // confirmed avx2+fma; equal lengths are the kernel's contract,
        // asserted above.
        IsaLevel::Avx2Fma => unsafe { axpy_avx2(alpha, x, y) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `isa_level` returns Avx512 only after runtime CPUID
        // confirmed avx512f/bw/dq/vl (+avx2+fma); equal lengths are the
        // kernel's contract, asserted above.
        IsaLevel::Avx512 => unsafe { axpy_avx512(alpha, x, y) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => axpy_scalar(alpha, x, y),
    }
}

/// Dense matvec: `out[j] = sum_i x[i] * w[i*cols + j]` (+ optional bias).
/// Row-major `w` of shape `[rows=x.len(), cols=out.len()]` — the layout
/// used by the neural block so a *row* of `w` is the fan-out of one
/// input unit (enables §4.3 sparse skipping of zero inputs).
///
/// Dispatch happens ONCE per call, not per row — the AVX2 kernel keeps
/// the accumulator in registers across all rows (the `#[target_feature]`
/// call boundary is too expensive to pay per row).
pub fn matvec_rowmajor(x: &[f32], w: &[f32], bias: Option<&[f32]>, out: &mut [f32]) {
    let cols = out.len();
    debug_assert_eq!(w.len(), x.len() * cols);
    #[cfg(target_arch = "x86_64")]
    {
        let lvl = isa_level();
        if cols >= 16 && lvl == IsaLevel::Avx512 {
            // SAFETY: `isa_level` returns Avx512 only after runtime
            // CPUID confirmed avx512f/bw/dq/vl (+avx2+fma); the
            // `w.len() == x.len() * cols` shape the kernel indexes by
            // is asserted above.
            unsafe { matvec_avx512(x, w, bias, out) };
            return;
        }
        // narrow outputs on an AVX-512 host still take the ymm kernel:
        // every AVX-512 CPU has avx2+fma, and 8-lane tiles fit cols in
        // 8..16 better than masked zmm would.
        if cols >= 8 && lvl >= IsaLevel::Avx2Fma {
            // SAFETY: `isa_level` at or above Avx2Fma implies runtime
            // CPUID confirmed avx2+fma; the `w.len() == x.len() * cols`
            // shape the kernel indexes by is asserted above.
            unsafe { matvec_avx2(x, w, bias, out) };
            return;
        }
    }
    matvec_scalar(x, w, bias, out);
}

/// Scalar matvec (also the non-x86 fallback).
pub fn matvec_scalar(x: &[f32], w: &[f32], bias: Option<&[f32]>, out: &mut [f32]) {
    let cols = out.len();
    match bias {
        Some(b) => out.copy_from_slice(b),
        None => out.fill(0.0),
    }
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue; // sparse input skip (ReLU outputs are often 0)
        }
        axpy_scalar(xi, &w[i * cols..(i + 1) * cols], out);
    }
}

// ---------------------------------------------------------------- scalar

pub fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    let mut s = 0.0;
    for i in 0..a.len() {
        s += a[i] * b[i];
    }
    s
}

pub fn axpy_scalar(alpha: f32, x: &[f32], y: &mut [f32]) {
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

// ------------------------------------------------------------------ avx2

/// # Safety
/// Caller must ensure the CPU supports avx2+fma (runtime-detected) and
/// `a.len() == b.len()`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
    use std::arch::x86_64::*;
    let n = a.len();
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let mut i = 0;
    // two accumulators hide FMA latency
    while i + 16 <= n {
        // SAFETY: i + 16 <= n == a.len() == b.len() bounds all four
        // 8-lane unaligned loads.
        unsafe {
            let va0 = _mm256_loadu_ps(a.as_ptr().add(i));
            let vb0 = _mm256_loadu_ps(b.as_ptr().add(i));
            acc0 = _mm256_fmadd_ps(va0, vb0, acc0);
            let va1 = _mm256_loadu_ps(a.as_ptr().add(i + 8));
            let vb1 = _mm256_loadu_ps(b.as_ptr().add(i + 8));
            acc1 = _mm256_fmadd_ps(va1, vb1, acc1);
        }
        i += 16;
    }
    while i + 8 <= n {
        // SAFETY: i + 8 <= n bounds both 8-lane unaligned loads.
        unsafe {
            let va = _mm256_loadu_ps(a.as_ptr().add(i));
            let vb = _mm256_loadu_ps(b.as_ptr().add(i));
            acc0 = _mm256_fmadd_ps(va, vb, acc0);
        }
        i += 8;
    }
    let acc = _mm256_add_ps(acc0, acc1);
    let hi = _mm256_extractf128_ps(acc, 1);
    let lo = _mm256_castps256_ps128(acc);
    let s4 = _mm_add_ps(hi, lo);
    let s2 = _mm_add_ps(s4, _mm_movehl_ps(s4, s4));
    let s1 = _mm_add_ss(s2, _mm_shuffle_ps(s2, s2, 1));
    let mut s = _mm_cvtss_f32(s1);
    while i < n {
        s += a[i] * b[i];
        i += 1;
    }
    s
}

/// # Safety
/// Caller must ensure the CPU supports avx2+fma (runtime-detected) and
/// `x.len() == y.len()`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn axpy_avx2(alpha: f32, x: &[f32], y: &mut [f32]) {
    use std::arch::x86_64::*;
    let n = x.len();
    let va = _mm256_set1_ps(alpha);
    let mut i = 0;
    while i + 8 <= n {
        // SAFETY: i + 8 <= n == x.len() == y.len() bounds the loads
        // and the store.
        unsafe {
            let vx = _mm256_loadu_ps(x.as_ptr().add(i));
            let vy = _mm256_loadu_ps(y.as_ptr().add(i));
            _mm256_storeu_ps(y.as_mut_ptr().add(i), _mm256_fmadd_ps(va, vx, vy));
        }
        i += 8;
    }
    while i < n {
        y[i] += alpha * x[i];
        i += 1;
    }
}

/// Register-blocked AVX2 matvec: for cols ≤ 64 the whole output vector
/// lives in ymm accumulators across all rows (one load+store of `out`
/// total); wider outputs fall back to an in-function row/axpy loop.
///
/// # Safety
/// Caller must ensure the CPU supports avx2+fma (runtime-detected),
/// `w.len() == x.len() * out.len()` (row-major `[rows, cols]`), and
/// `bias.len() == out.len()` when a bias is given.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn matvec_avx2(x: &[f32], w: &[f32], bias: Option<&[f32]>, out: &mut [f32]) {
    use std::arch::x86_64::*;
    let cols = out.len();
    let vec_cols = cols & !7; // multiple of 8 part
    if cols % 8 == 0 && cols <= 64 {
        let nacc = cols / 8;
        let mut acc = [_mm256_setzero_ps(); 8];
        if let Some(b) = bias {
            for (k, a) in acc.iter_mut().enumerate().take(nacc) {
                // SAFETY: k * 8 + 8 <= cols == b.len() (caller
                // contract) bounds the load.
                *a = unsafe { _mm256_loadu_ps(b.as_ptr().add(k * 8)) };
            }
        }
        for (i, &xi) in x.iter().enumerate() {
            if xi == 0.0 {
                continue;
            }
            let vx = _mm256_set1_ps(xi);
            // SAFETY: i < x.len() and w.len() == x.len() * cols keep
            // row i (and its k*8+8 <= cols lanes below) in bounds.
            let row = unsafe { w.as_ptr().add(i * cols) };
            for (k, a) in acc.iter_mut().enumerate().take(nacc) {
                // SAFETY: see `row` above.
                *a = unsafe {
                    _mm256_fmadd_ps(vx, _mm256_loadu_ps(row.add(k * 8)), *a)
                };
            }
        }
        for (k, a) in acc.iter().enumerate().take(nacc) {
            // SAFETY: k * 8 + 8 <= cols == out.len() bounds the store.
            unsafe { _mm256_storeu_ps(out.as_mut_ptr().add(k * 8), *a) };
        }
        return;
    }
    // general shape: bias copy then fused per-row AXPY (still one
    // target_feature entry for the whole matvec)
    match bias {
        Some(b) => out.copy_from_slice(b),
        None => out.fill(0.0),
    }
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        // SAFETY: i < x.len() and w.len() == x.len() * cols keep row i
        // in bounds through offset cols - 1.
        let row = unsafe { w.as_ptr().add(i * cols) };
        let vx = _mm256_set1_ps(xi);
        let mut j = 0;
        while j < vec_cols {
            // SAFETY: j + 8 <= vec_cols <= cols bounds the row/out
            // loads and the out store.
            unsafe {
                let vy = _mm256_loadu_ps(out.as_ptr().add(j));
                let vw = _mm256_loadu_ps(row.add(j));
                _mm256_storeu_ps(
                    out.as_mut_ptr().add(j),
                    _mm256_fmadd_ps(vx, vw, vy),
                );
            }
            j += 8;
        }
        while j < cols {
            // SAFETY: j < cols bounds the scalar tail read of row i.
            out[j] += xi * unsafe { *row.add(j) };
            j += 1;
        }
    }
}

// ---------------------------------------------------------------- avx512

/// Deterministic 16-lane horizontal sum: fold the zmm halves into one
/// ymm add, then the same explicit extract/movehl/shuffle tree the
/// AVX2 kernels use (never `_mm512_reduce_add_ps`, whose reduction
/// order is implementation-defined — rung determinism is part of the
/// batch-invariance contract).
///
/// # Safety
/// Caller must ensure the CPU supports avx512f+avx512dq — the body is
/// value-only intrinsics (no memory access).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512dq,avx2,fma")]
#[inline]
pub(super) unsafe fn hsum16(v: std::arch::x86_64::__m512) -> f32 {
    use std::arch::x86_64::*;
    let hi8 = _mm512_extractf32x8_ps::<1>(v);
    let lo8 = _mm512_castps512_ps256(v);
    let s8 = _mm256_add_ps(hi8, lo8);
    let hi = _mm256_extractf128_ps::<1>(s8);
    let lo = _mm256_castps256_ps128(s8);
    let s4 = _mm_add_ps(hi, lo);
    let s2 = _mm_add_ps(s4, _mm_movehl_ps(s4, s4));
    _mm_cvtss_f32(_mm_add_ss(s2, _mm_shuffle_ps::<1>(s2, s2)))
}

/// # Safety
/// Caller must ensure the CPU supports avx512f/bw/dq/vl (+avx2+fma,
/// runtime-detected) and `a.len() == b.len()`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512bw,avx512dq,avx512vl,avx2,fma")]
unsafe fn dot_avx512(a: &[f32], b: &[f32]) -> f32 {
    use std::arch::x86_64::*;
    let n = a.len();
    let mut acc0 = _mm512_setzero_ps();
    let mut acc1 = _mm512_setzero_ps();
    let mut i = 0;
    // two accumulators hide FMA latency
    while i + 32 <= n {
        // SAFETY: i + 32 <= n == a.len() == b.len() bounds all four
        // 16-lane unaligned loads.
        unsafe {
            let va0 = _mm512_loadu_ps(a.as_ptr().add(i));
            let vb0 = _mm512_loadu_ps(b.as_ptr().add(i));
            acc0 = _mm512_fmadd_ps(va0, vb0, acc0);
            let va1 = _mm512_loadu_ps(a.as_ptr().add(i + 16));
            let vb1 = _mm512_loadu_ps(b.as_ptr().add(i + 16));
            acc1 = _mm512_fmadd_ps(va1, vb1, acc1);
        }
        i += 32;
    }
    while i + 16 <= n {
        // SAFETY: i + 16 <= n bounds both 16-lane unaligned loads.
        unsafe {
            let va = _mm512_loadu_ps(a.as_ptr().add(i));
            let vb = _mm512_loadu_ps(b.as_ptr().add(i));
            acc0 = _mm512_fmadd_ps(va, vb, acc0);
        }
        i += 16;
    }
    // SAFETY: avx512f+avx512dq are enabled per this fn's contract
    // (hsum16 is value-only).
    let mut s = unsafe { hsum16(_mm512_add_ps(acc0, acc1)) };
    while i < n {
        s += a[i] * b[i];
        i += 1;
    }
    s
}

/// # Safety
/// Caller must ensure the CPU supports avx512f/bw/dq/vl (+avx2+fma,
/// runtime-detected) and `x.len() == y.len()`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512bw,avx512dq,avx512vl,avx2,fma")]
unsafe fn axpy_avx512(alpha: f32, x: &[f32], y: &mut [f32]) {
    use std::arch::x86_64::*;
    let n = x.len();
    let va = _mm512_set1_ps(alpha);
    let mut i = 0;
    while i + 16 <= n {
        // SAFETY: i + 16 <= n == x.len() == y.len() bounds the loads
        // and the store.
        unsafe {
            let vx = _mm512_loadu_ps(x.as_ptr().add(i));
            let vy = _mm512_loadu_ps(y.as_ptr().add(i));
            _mm512_storeu_ps(y.as_mut_ptr().add(i), _mm512_fmadd_ps(va, vx, vy));
        }
        i += 16;
    }
    while i < n {
        y[i] += alpha * x[i];
        i += 1;
    }
}

/// Register-blocked AVX-512 matvec: for cols ≤ 128 the whole output
/// vector lives in zmm accumulators across all rows (one load+store of
/// `out` total); wider or non-multiple-of-16 outputs fall back to an
/// in-function row/axpy loop with 16-lane tiles and a scalar tail.
///
/// # Safety
/// Caller must ensure the CPU supports avx512f/bw/dq/vl (+avx2+fma,
/// runtime-detected), `w.len() == x.len() * out.len()` (row-major
/// `[rows, cols]`), and `bias.len() == out.len()` when a bias is given.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512bw,avx512dq,avx512vl,avx2,fma")]
unsafe fn matvec_avx512(x: &[f32], w: &[f32], bias: Option<&[f32]>, out: &mut [f32]) {
    use std::arch::x86_64::*;
    let cols = out.len();
    let vec_cols = cols & !15; // multiple of 16 part
    if cols % 16 == 0 && cols <= 128 {
        let nacc = cols / 16;
        let mut acc = [_mm512_setzero_ps(); 8];
        if let Some(b) = bias {
            for (k, a) in acc.iter_mut().enumerate().take(nacc) {
                // SAFETY: k * 16 + 16 <= cols == b.len() (caller
                // contract) bounds the load.
                *a = unsafe { _mm512_loadu_ps(b.as_ptr().add(k * 16)) };
            }
        }
        for (i, &xi) in x.iter().enumerate() {
            if xi == 0.0 {
                continue;
            }
            let vx = _mm512_set1_ps(xi);
            // SAFETY: i < x.len() and w.len() == x.len() * cols keep
            // row i (and its k*16+16 <= cols lanes below) in bounds.
            let row = unsafe { w.as_ptr().add(i * cols) };
            for (k, a) in acc.iter_mut().enumerate().take(nacc) {
                // SAFETY: see `row` above.
                *a = unsafe {
                    _mm512_fmadd_ps(vx, _mm512_loadu_ps(row.add(k * 16)), *a)
                };
            }
        }
        for (k, a) in acc.iter().enumerate().take(nacc) {
            // SAFETY: k * 16 + 16 <= cols == out.len() bounds the
            // store.
            unsafe { _mm512_storeu_ps(out.as_mut_ptr().add(k * 16), *a) };
        }
        return;
    }
    // general shape: bias copy then fused per-row AXPY (still one
    // target_feature entry for the whole matvec)
    match bias {
        Some(b) => out.copy_from_slice(b),
        None => out.fill(0.0),
    }
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        // SAFETY: i < x.len() and w.len() == x.len() * cols keep row i
        // in bounds through offset cols - 1.
        let row = unsafe { w.as_ptr().add(i * cols) };
        let vx = _mm512_set1_ps(xi);
        let mut j = 0;
        while j < vec_cols {
            // SAFETY: j + 16 <= vec_cols <= cols bounds the row/out
            // loads and the out store.
            unsafe {
                let vy = _mm512_loadu_ps(out.as_ptr().add(j));
                let vw = _mm512_loadu_ps(row.add(j));
                _mm512_storeu_ps(
                    out.as_mut_ptr().add(j),
                    _mm512_fmadd_ps(vx, vw, vy),
                );
            }
            j += 16;
        }
        while j < cols {
            // SAFETY: j < cols bounds the scalar tail read of row i.
            out[j] += xi * unsafe { *row.add(j) };
            j += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simd::{forcing_test_lock, ForcedIsaGuard};
    use crate::util::rng::Pcg32;

    fn randvec(rng: &mut Pcg32, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal()).collect()
    }

    #[test]
    fn dot_matches_scalar_all_lengths() {
        let mut rng = Pcg32::seeded(1);
        for n in [0, 1, 3, 7, 8, 9, 15, 16, 17, 31, 64, 100, 1000] {
            let a = randvec(&mut rng, n);
            let b = randvec(&mut rng, n);
            let want = dot_scalar(&a, &b);
            let got = dot(&a, &b);
            assert!(
                (want - got).abs() <= 1e-3 * (1.0 + want.abs()),
                "n={n} want={want} got={got}"
            );
        }
    }

    #[test]
    fn axpy_matches_scalar() {
        let mut rng = Pcg32::seeded(2);
        for n in [0, 1, 5, 8, 13, 32, 100] {
            let x = randvec(&mut rng, n);
            let mut y1 = randvec(&mut rng, n);
            let mut y2 = y1.clone();
            axpy_scalar(0.37, &x, &mut y1);
            axpy(0.37, &x, &mut y2);
            for i in 0..n {
                assert!((y1[i] - y2[i]).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn matvec_matches_naive() {
        let mut rng = Pcg32::seeded(3);
        // cover: scalar (<8), register-blocked (8..=64 mult of 8),
        // general avx2 (non-multiple / wide)
        for (rows, cols) in [(13, 7), (13, 16), (29, 64), (13, 20), (7, 72)] {
            let x = randvec(&mut rng, rows);
            let w = randvec(&mut rng, rows * cols);
            let b = randvec(&mut rng, cols);
            let mut out = vec![0.0; cols];
            matvec_rowmajor(&x, &w, Some(&b), &mut out);
            for j in 0..cols {
                let mut want = b[j];
                for i in 0..rows {
                    want += x[i] * w[i * cols + j];
                }
                assert!(
                    (out[j] - want).abs() < 1e-3 * (1.0 + want.abs()),
                    "rows={rows} cols={cols} j={j}"
                );
            }
        }
    }

    #[test]
    fn matvec_simd_equals_scalar() {
        let mut rng = Pcg32::seeded(9);
        for (rows, cols) in [(79, 16), (16, 16), (33, 40), (5, 128)] {
            let x = randvec(&mut rng, rows);
            let w = randvec(&mut rng, rows * cols);
            let mut simd = vec![0.0; cols];
            matvec_rowmajor(&x, &w, None, &mut simd);
            let mut scalar = vec![0.0; cols];
            matvec_scalar(&x, &w, None, &mut scalar);
            for j in 0..cols {
                assert!((simd[j] - scalar[j]).abs() < 1e-3 * (1.0 + scalar[j].abs()));
            }
        }
    }

    #[test]
    fn matvec_skips_zero_inputs_correctly() {
        let mut rng = Pcg32::seeded(4);
        let (rows, cols) = (6, 4);
        let mut x = randvec(&mut rng, rows);
        x[1] = 0.0;
        x[4] = 0.0;
        let w = randvec(&mut rng, rows * cols);
        let mut fast = vec![0.0; cols];
        matvec_rowmajor(&x, &w, None, &mut fast);
        let mut naive = vec![0.0; cols];
        for j in 0..cols {
            for i in 0..rows {
                naive[j] += x[i] * w[i * cols + j];
            }
        }
        for j in 0..cols {
            assert!((fast[j] - naive[j]).abs() < 1e-5);
        }
    }

    #[test]
    fn forced_scalar_equals_simd_numerics() {
        let mut rng = Pcg32::seeded(5);
        let a = randvec(&mut rng, 256);
        let b = randvec(&mut rng, 256);
        let _serial = forcing_test_lock();
        let s = {
            let _scalar = ForcedIsaGuard::scalar();
            dot(&a, &b)
        };
        let v = dot(&a, &b);
        assert!((s - v).abs() < 1e-2 * (1.0 + s.abs()), "s={s} v={v}");
    }

    #[test]
    fn every_available_rung_agrees_on_dot_and_matvec() {
        let mut rng = Pcg32::seeded(6);
        let a = randvec(&mut rng, 100);
        let b = randvec(&mut rng, 100);
        let (rows, cols) = (17, 48);
        let x = randvec(&mut rng, rows);
        let w = randvec(&mut rng, rows * cols);
        let want_dot = dot_scalar(&a, &b);
        let mut want_mv = vec![0.0f32; cols];
        matvec_scalar(&x, &w, None, &mut want_mv);
        let _serial = forcing_test_lock();
        for lvl in crate::simd::available_levels() {
            let _g = ForcedIsaGuard::force(lvl);
            let got = dot(&a, &b);
            assert!(
                (got - want_dot).abs() < 1e-3 * (1.0 + want_dot.abs()),
                "{lvl:?}: dot {got} vs {want_dot}"
            );
            let mut mv = vec![0.0f32; cols];
            matvec_rowmajor(&x, &w, None, &mut mv);
            for j in 0..cols {
                assert!(
                    (mv[j] - want_mv[j]).abs() < 1e-3 * (1.0 + want_mv[j].abs()),
                    "{lvl:?}: matvec col {j}"
                );
            }
        }
    }
}
