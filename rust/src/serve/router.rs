//! Request routing: model registry plus context-affinity sharding.
//!
//! The engine serves "more than a hundred models" concurrently; the
//! router resolves a request's model name to its [`ModelHandle`] and
//! picks a worker shard.  Sharding hashes the *context* so repeated
//! contexts land on the same worker — maximizing that worker's
//! context-cache hit rate (§5).

use std::collections::HashMap;
use std::sync::{Arc, RwLock};

use crate::feature::hash::Murmur3x32;
use crate::feature::FeatureSlot;
use crate::serve::{ModelHandle, Request};

/// Thread-safe model registry + shard picker.
#[derive(Clone)]
pub struct Router {
    models: Arc<RwLock<HashMap<String, ModelHandle>>>,
    pub shards: usize,
}

impl std::fmt::Debug for Router {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Router").finish_non_exhaustive()
    }
}

impl Router {
    pub fn new(shards: usize) -> Self {
        Router {
            models: Arc::new(RwLock::new(HashMap::new())),
            shards: shards.max(1),
        }
    }

    /// A router over the SAME model registry with a different shard
    /// count.  [`crate::serve::server::ServingEngine::start`] derives
    /// its routing from the worker count through this: a shard count
    /// that disagrees with the worker count would force a second modulo
    /// at dispatch, re-scrambling [`Self::shard_for_context`]'s pinned
    /// context→shard assignment and with it every warm context cache.
    pub fn with_shards(&self, shards: usize) -> Router {
        Router { models: self.models.clone(), shards: shards.max(1) }
    }

    /// Register (or replace) a model under `name`.
    ///
    /// Registry mutations are single HashMap inserts/removes under the
    /// guard, so a poisoned lock still holds a structurally valid map;
    /// recover it rather than taking down every serving thread that
    /// touches the registry after one panicked writer.
    pub fn register(&self, name: &str, handle: ModelHandle) {
        self.models
            .write()
            .unwrap_or_else(|e| e.into_inner())
            .insert(name.to_string(), handle);
    }

    /// Remove a model; returns whether it existed.
    pub fn deregister(&self, name: &str) -> bool {
        self.models
            .write()
            .unwrap_or_else(|e| e.into_inner())
            .remove(name)
            .is_some()
    }

    /// Look up a model handle.
    pub fn resolve(&self, name: &str) -> Option<ModelHandle> {
        self.models
            .read()
            .unwrap_or_else(|e| e.into_inner())
            .get(name)
            .cloned()
    }

    pub fn model_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self
            .models
            .read()
            .unwrap_or_else(|e| e.into_inner())
            .keys()
            .cloned()
            .collect();
        v.sort();
        v
    }

    /// Context-affinity shard for a request.
    pub fn shard_for(&self, req: &Request) -> usize {
        Self::shard_for_context(&req.context, self.shards)
    }

    /// Hash a context's buckets into a shard id.
    ///
    /// Streams each bucket word straight into the murmur state — no
    /// per-request byte buffer.  A `u32` is exactly one murmur block,
    /// so this is bit-identical to hashing the buckets' concatenated
    /// LE bytes (the pre-streaming implementation); existing context→
    /// shard affinity is pinned by `shard_assignments_are_pinned`.
    pub fn shard_for_context(ctx: &[FeatureSlot], shards: usize) -> usize {
        let mut h = Murmur3x32::new(0x5a5a);
        for s in ctx {
            h.push_u32(s.bucket);
        }
        (h.finish() as usize) % shards.max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::model::regressor::Regressor;

    fn handle() -> ModelHandle {
        ModelHandle::new(Regressor::new(&ModelConfig::linear(4, 256)))
    }

    fn ctx(buckets: &[u32]) -> Vec<FeatureSlot> {
        buckets
            .iter()
            .enumerate()
            .map(|(f, &b)| FeatureSlot { field: f as u16, bucket: b, value: 1.0 })
            .collect()
    }

    #[test]
    fn register_resolve_deregister() {
        let r = Router::new(4);
        assert!(r.resolve("ctr").is_none());
        r.register("ctr", handle());
        r.register("cvr", handle());
        assert!(r.resolve("ctr").is_some());
        assert_eq!(r.model_names(), vec!["ctr", "cvr"]);
        assert!(r.deregister("ctr"));
        assert!(!r.deregister("ctr"));
        assert!(r.resolve("ctr").is_none());
    }

    #[test]
    fn same_context_same_shard() {
        let r = Router::new(8);
        let req = Request {
            model: "m".into(),
            context: ctx(&[1, 2, 3]),
            candidates: vec![],
        };
        let a = r.shard_for(&req);
        let b = r.shard_for(&req);
        assert_eq!(a, b);
        assert!(a < 8);
    }

    #[test]
    fn different_contexts_spread() {
        let shards = 8;
        let mut counts = vec![0usize; shards];
        for i in 0..8000u32 {
            let c = ctx(&[i, i * 7 + 1]);
            counts[Router::shard_for_context(&c, shards)] += 1;
        }
        let min = *counts.iter().min().unwrap();
        let max = *counts.iter().max().unwrap();
        assert!(min > 700 && max < 1400, "skewed shards: {counts:?}");
    }

    #[test]
    fn shard_assignments_are_pinned() {
        // Reference values computed from murmur3_32 (seed 0x5a5a) over
        // the buckets' concatenated LE bytes.  These must NEVER change:
        // context→shard affinity decides which worker's context cache
        // holds a given context, and shifting it invalidates every
        // warm cache in the fleet on deploy.
        for (buckets, shard8) in [
            (&[1u32, 2, 3][..], 2usize),
            (&[42][..], 2),
            (&[7, 100, 3000, 65536][..], 4),
            (&[0, 0][..], 4),
            (&[123_456_789][..], 7),
            (&[1, 2, 3, 4, 5, 6, 7][..], 7),
        ] {
            let c = ctx(buckets);
            assert_eq!(
                Router::shard_for_context(&c, 8),
                shard8,
                "affinity shifted for {buckets:?}"
            );
        }
        // and the raw 32-bit hashes behind them (shards = 2^32 would
        // overflow usize on 32-bit targets, so pin via modulo 5 too)
        assert_eq!(Router::shard_for_context(&ctx(&[1, 2, 3]), 5), 4);
        assert_eq!(Router::shard_for_context(&ctx(&[42]), 5), 1);
        assert_eq!(Router::shard_for_context(&ctx(&[0, 0]), 5), 0);
    }

    #[test]
    fn registry_shared_across_clones() {
        let r = Router::new(2);
        let r2 = r.clone();
        r.register("m", handle());
        assert!(r2.resolve("m").is_some());
    }

    #[test]
    fn with_shards_shares_registry_and_overrides_count() {
        let r = Router::new(7);
        r.register("m", handle());
        let derived = r.with_shards(4);
        assert_eq!(derived.shards, 4);
        assert!(derived.resolve("m").is_some());
        // registrations flow both ways (same registry)
        derived.register("n", handle());
        assert!(r.resolve("n").is_some());
        // degenerate counts clamp like Router::new
        assert_eq!(r.with_shards(0).shards, 1);
        // the derived router shards exactly as shard_for_context over
        // its own count — no second modulo anywhere
        let c = ctx(&[1, 2, 3]);
        let req = Request { model: "m".into(), context: c.clone(), candidates: vec![] };
        assert_eq!(derived.shard_for(&req), Router::shard_for_context(&c, 4));
    }
}
