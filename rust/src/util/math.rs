//! Numerically careful scalar math shared across blocks and evaluators.

/// Logistic sigmoid with clamping to avoid overflow in `exp`.
#[inline]
pub fn sigmoid(x: f32) -> f32 {
    let x = x.clamp(-30.0, 30.0);
    1.0 / (1.0 + (-x).exp())
}

/// Binary cross-entropy for a predicted probability `p` and label `y`
/// in {0, 1}.  Probabilities are clamped away from 0/1.
#[inline]
pub fn logloss(p: f32, y: f32) -> f64 {
    let p = p.clamp(1e-7, 1.0 - 1e-7) as f64;
    let y = y as f64;
    -(y * p.ln() + (1.0 - y) * (1.0 - p).ln())
}

/// Relative Information Gain against a base rate: 1 - LL(model)/LL(base).
/// The paper reports RIG alongside AUC/logloss.
pub fn rig(model_ll: f64, base_rate: f64, n: usize) -> f64 {
    if n == 0 {
        return 0.0;
    }
    let b = base_rate.clamp(1e-7, 1.0 - 1e-7);
    let base_ll = -(b * b.ln() + (1.0 - b) * (1.0 - b).ln());
    if base_ll == 0.0 {
        return 0.0;
    }
    1.0 - (model_ll / n as f64) / base_ll
}

/// `round` to a number of decimal places — the paper's α/β rounding of
/// quantization bounds ("minimum and maximum are rounded to α and β
/// decimals").
#[inline]
pub fn round_decimals(x: f32, decimals: u32) -> f32 {
    let m = 10f64.powi(decimals as i32);
    ((x as f64 * m).round() / m) as f32
}

/// ReLU.
#[inline]
pub fn relu(x: f32) -> f32 {
    if x > 0.0 {
        x
    } else {
        0.0
    }
}

/// Mean and (population) standard deviation of a slice.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    (mean, var.sqrt())
}

/// NaN-last total order: finite values ascending, every NaN (either
/// sign — x86's default quiet NaN from `0.0 / 0.0` has the sign bit
/// set, so [`f64::total_cmp`] alone would sort it to the *front* and
/// silently shift every quantile low) after them.  Shared NaN policy
/// for the quantiles here and [`crate::eval::auc`]'s rank sort.
pub fn nan_last(a: &f64, b: &f64) -> std::cmp::Ordering {
    a.is_nan().cmp(&b.is_nan()).then_with(|| a.total_cmp(b))
}

/// [`nan_last`] for `f32` slices (see it for the sign-bit rationale).
pub fn nan_last_f32(a: &f32, b: &f32) -> std::cmp::Ordering {
    a.is_nan().cmp(&b.is_nan()).then_with(|| a.total_cmp(b))
}

/// Median of a slice (copies + sorts).
///
/// NaN-tolerant: a poisoned sample (e.g. a NaN latency point feeding
/// bench JSON emission) sorts to the tail regardless of its sign bit
/// and is *reported* by the affected quantiles instead of panicking
/// the whole bench.
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(nan_last);
    let m = v.len() / 2;
    if v.len() % 2 == 1 {
        v[m]
    } else {
        0.5 * (v[m - 1] + v[m])
    }
}

/// Linear-interpolated percentile of a slice; `q` in `[0, 1]`
/// (copies + sorts).  `percentile(xs, 0.5)` agrees with [`median`].
/// NaN-tolerant via the NaN-last order (see [`median`]).
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(nan_last);
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (pos - lo as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sigmoid_basics() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-6);
        assert!(sigmoid(100.0) > 0.999);
        assert!(sigmoid(-100.0) < 0.001);
        assert!(sigmoid(f32::MAX).is_finite());
    }

    #[test]
    fn sigmoid_monotone() {
        let mut prev = sigmoid(-10.0);
        for i in -99..100 {
            let cur = sigmoid(i as f32 * 0.1);
            assert!(cur >= prev);
            prev = cur;
        }
    }

    #[test]
    fn logloss_perfect_and_wrong() {
        assert!(logloss(0.999999, 1.0) < 1e-4);
        assert!(logloss(0.000001, 1.0) > 10.0);
        assert!(logloss(0.5, 1.0) > 0.69 && logloss(0.5, 1.0) < 0.70);
    }

    #[test]
    fn logloss_finite_at_extremes() {
        assert!(logloss(0.0, 1.0).is_finite());
        assert!(logloss(1.0, 0.0).is_finite());
    }

    #[test]
    fn rig_zero_for_base_rate_predictor() {
        // A model predicting exactly the base rate has RIG 0.
        let n = 1000;
        let base = 0.3;
        let ll: f64 = (0..n)
            .map(|i| logloss(0.3, if i < 300 { 1.0 } else { 0.0 }))
            .sum();
        let r = rig(ll, base, n);
        assert!(r.abs() < 1e-3, "rig={r}");
    }

    #[test]
    fn round_decimals_works() {
        assert_eq!(round_decimals(1.23456, 2), 1.23);
        assert_eq!(round_decimals(-0.0049, 2), -0.0);
        assert_eq!(round_decimals(9.996, 2), 10.0);
    }

    #[test]
    fn median_and_mean_std() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        let (m, s) = mean_std(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((m - 5.0).abs() < 1e-9);
        assert!((s - 2.0).abs() < 1e-9);
    }

    #[test]
    fn median_percentile_survive_nan() {
        // Regression: partial_cmp(..).unwrap() panicked on the first
        // NaN sample.  Every NaN — either sign bit, so including x86's
        // default 0.0/0.0 quiet NaN — must sort to the tail: unaffected
        // quantiles stay meaningful and the poisoned tail is reported
        // as NaN instead of aborting a bench run.
        // -f64::NAN is bit-identical to x86's default 0.0/0.0 result
        for nan in [f64::NAN, -f64::NAN] {
            let xs = [2.0, nan, 1.0];
            assert_eq!(median(&xs), 2.0);
            assert_eq!(percentile(&xs, 0.0), 1.0);
            assert_eq!(percentile(&xs, 0.5), 2.0);
            assert!(percentile(&xs, 1.0).is_nan());
        }
        let all_nan = [f64::NAN, -f64::NAN];
        assert!(median(&all_nan).is_nan());
    }

    #[test]
    fn percentile_interpolates_and_matches_median() {
        let xs = [4.0, 1.0, 2.0, 3.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 4.0);
        assert_eq!(percentile(&xs, 0.5), median(&xs));
        assert!((percentile(&xs, 0.25) - 1.75).abs() < 1e-12);
        assert_eq!(percentile(&[], 0.5), 0.0);
        assert_eq!(percentile(&[7.0], 0.9), 7.0);
    }
}
