//! Quickstart: train a Deep FFM single-pass on a synthetic CTR stream,
//! evaluate it, save/load it, and score a few examples.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use fwumious::config::ModelConfig;
use fwumious::data::synthetic::SyntheticStream;
use fwumious::eval::RollingAuc;
use fwumious::model::regressor::Regressor;
use fwumious::model::{io, Workspace};

fn main() {
    // 1. A model: 13 fields, 4-dim latents, 2^18 hashed buckets, one
    //    16-unit hidden layer over the MergeNorm(LR, FFM) vector.
    let cfg = ModelConfig::deep_ffm(13, 4, 1 << 18, &[16]);
    let mut model = Regressor::new(&cfg);
    let mut ws = Workspace::new();
    println!(
        "DeepFFM: {} weights ({:.1} MB inference file)",
        model.num_weights(),
        model.num_weights() as f64 * 4.0 / 1e6
    );

    // 2. A stream: criteo-like synthetic CTR traffic (13 fields).
    let mut stream = SyntheticStream::criteo_like(42);
    assert_eq!(stream.spec.fields(), 13);

    // 3. Single-pass online training with progressive validation.
    let mut roll = RollingAuc::new(10_000);
    let t = std::time::Instant::now();
    let n = 120_000;
    for _ in 0..n {
        let ex = stream.next_example();
        let p = model.learn(&ex, &mut ws);
        roll.add(p, ex.label);
    }
    let secs = t.elapsed().as_secs_f64();
    println!(
        "trained {n} examples in {secs:.2}s ({:.0} ex/s), SIMD: {}",
        n as f64 / secs,
        fwumious::simd::isa_name()
    );
    println!("rolling AUC trace: {:?}", summarize(&roll.points));
    println!("mean logloss {:.4}  RIG {:.4}", roll.mean_logloss(), roll.rig());

    // 4. Save inference weights (optimizer state dropped — §6).
    let path = std::env::temp_dir().join("quickstart_model.fw");
    io::save(&model, &path, false).expect("save");
    let loaded = io::load(&path).expect("load");
    println!(
        "saved + reloaded {} ({} bytes)",
        path.display(),
        std::fs::metadata(&path).unwrap().len()
    );

    // 5. Score fresh traffic with the loaded model.
    let mut scores = Vec::new();
    let mut labels = Vec::new();
    for _ in 0..20_000 {
        let ex = stream.next_example();
        scores.push(loaded.predict(&ex, &mut ws));
        labels.push(ex.label);
    }
    println!("held-out AUC: {:.4}", fwumious::eval::auc(&scores, &labels));
    std::fs::remove_file(&path).ok();
}

fn summarize(points: &[f64]) -> Vec<f64> {
    points
        .iter()
        .map(|p| (p * 1000.0).round() / 1000.0)
        .collect()
}
