//! In-repo LZ compression for the transfer plane.
//!
//! The paper compresses patch op streams before shipping them between
//! data centers (§6: "the diffs are compressed, sent to the serving
//! layer, unpacked and applied").  The offline build environment has no
//! flate2/zstd, so the codec lives here: a classic LZSS — greedy
//! longest-match against a hash table of 4-byte prefixes — framed with
//! the same LEB128 varints the patcher already uses.  Correctness (the
//! decompressor inverts the compressor on every input) matters more
//! than ratio; on the patcher's op streams the dominant savings come
//! from the diff itself, compression just squeezes the repetitive
//! skip/run structure.
//!
//! Stream format:
//! ```text
//! raw_len  varint    uncompressed byte count
//! token*   varint tag
//!            tag & 1 == 0 -> literal run: (tag >> 1) bytes follow
//!            tag & 1 == 1 -> match: len = tag >> 1, then varint dist;
//!                            copies len bytes from out[-dist..]
//! ```
//! Matches are at least [`MIN_MATCH`] bytes and may overlap their own
//! output (dist < len encodes a repeated pattern, RLE-style).

use crate::util::varint;

/// Shortest encodable back-reference.
const MIN_MATCH: usize = 4;
/// Longest single match token (longer matches are split; a split match
/// keeps the same distance, since source and destination advance
/// together).  Bounding the per-token length lets the decompressor
/// reject corrupt streams before allocating unbounded output: a valid
/// stream of S bytes can decode to at most ~S/2 * MAX_MATCH bytes.
const MAX_MATCH: usize = 1 << 20;
/// Hash table size (16-bit keys over 4-byte prefixes).
const HASH_BITS: u32 = 16;

#[inline]
fn hash4(data: &[u8], i: usize) -> usize {
    let v = u32::from_le_bytes([data[i], data[i + 1], data[i + 2], data[i + 3]]);
    (v.wrapping_mul(0x9e37_79b1) >> (32 - HASH_BITS)) as usize
}

fn flush_literals(out: &mut Vec<u8>, lits: &[u8]) {
    if lits.is_empty() {
        return;
    }
    varint::write_u64(out, (lits.len() as u64) << 1);
    out.extend_from_slice(lits);
}

/// Compress `data`.  Never fails; worst case the output is the input
/// plus a few bytes of framing.
pub fn compress(data: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() / 2 + 16);
    varint::write_u64(&mut out, data.len() as u64);
    if data.len() < MIN_MATCH {
        flush_literals(&mut out, data);
        return out;
    }
    // hash of 4-byte prefix -> most recent position seen
    let mut head = vec![usize::MAX; 1 << HASH_BITS];
    let mut lit_start = 0usize;
    let mut i = 0usize;
    while i + MIN_MATCH <= data.len() {
        let h = hash4(data, i);
        let cand = head[h];
        head[h] = i;
        let mut match_len = 0usize;
        if cand != usize::MAX && data[cand..cand + MIN_MATCH] == data[i..i + MIN_MATCH] {
            let max = data.len() - i;
            let mut l = MIN_MATCH;
            while l < max && data[cand + l] == data[i + l] {
                l += 1;
            }
            match_len = l;
        }
        if match_len >= MIN_MATCH {
            flush_literals(&mut out, &data[lit_start..i]);
            let dist = (i - cand) as u64;
            let mut remaining = match_len;
            while remaining > 0 {
                let n = remaining.min(MAX_MATCH);
                varint::write_u64(&mut out, ((n as u64) << 1) | 1);
                varint::write_u64(&mut out, dist);
                remaining -= n;
            }
            // index the positions the match skips over so later matches
            // can reference them
            let end = i + match_len;
            let mut j = i + 1;
            while j < end && j + MIN_MATCH <= data.len() {
                head[hash4(data, j)] = j;
                j += 1;
            }
            i = end;
            lit_start = end;
        } else {
            i += 1;
        }
    }
    flush_literals(&mut out, &data[lit_start..]);
    out
}

/// Why a [`decompress`] rejected its input stream.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CompressError {
    /// Stream ended inside a varint (`what` names which one).
    Truncated(&'static str),
    /// A token would decode past the declared raw length.
    TokenOverrun,
    /// A literal run claims more bytes than the stream holds.
    LiteralPastEnd,
    /// A match token exceeds the [`MAX_MATCH`] per-token cap.
    MatchTooLong(usize),
    /// A match distance of 0 or beyond the produced output.
    BadDistance(usize),
    /// The stream decoded to a different length than it declared.
    LengthMismatch { got: usize, expected: usize },
}

impl std::fmt::Display for CompressError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CompressError::Truncated(what) => write!(f, "lz: truncated {what}"),
            CompressError::TokenOverrun => {
                write!(f, "lz: token overruns declared length")
            }
            CompressError::LiteralPastEnd => {
                write!(f, "lz: literal run past end of stream")
            }
            CompressError::MatchTooLong(n) => {
                write!(f, "lz: match length {n} exceeds token cap")
            }
            CompressError::BadDistance(d) => write!(f, "lz: bad match distance {d}"),
            CompressError::LengthMismatch { got, expected } => {
                write!(f, "lz: decompressed {got} bytes, expected {expected}")
            }
        }
    }
}

impl std::error::Error for CompressError {}

/// CLI shim: `fn main` paths print errors as strings.
impl From<CompressError> for String {
    fn from(e: CompressError) -> String {
        e.to_string()
    }
}

/// Decompress a [`compress`] stream.  Rejects malformed input.
pub fn decompress(data: &[u8]) -> Result<Vec<u8>, CompressError> {
    let mut pos = 0usize;
    let raw_len = varint::read_u64(data, &mut pos)
        .ok_or(CompressError::Truncated("length"))? as usize;
    // Output growth is bounded token by token: literal runs cannot
    // exceed the stream itself and match tokens are capped at
    // MAX_MATCH, so a corrupt/hostile length varint yields a clean
    // error after at most ~(stream tokens * MAX_MATCH) of growth, not
    // an unbounded allocation.  Capacity is only a hint.
    let mut out: Vec<u8> = Vec::with_capacity(raw_len.min(64 << 20));
    while pos < data.len() {
        let tag =
            varint::read_u64(data, &mut pos).ok_or(CompressError::Truncated("tag"))?;
        let n = (tag >> 1) as usize;
        if n > raw_len - out.len() {
            return Err(CompressError::TokenOverrun);
        }
        if tag & 1 == 0 {
            if n > data.len() - pos {
                return Err(CompressError::LiteralPastEnd);
            }
            out.extend_from_slice(&data[pos..pos + n]);
            pos += n;
        } else {
            if n > MAX_MATCH {
                return Err(CompressError::MatchTooLong(n));
            }
            let dist = varint::read_u64(data, &mut pos)
                .ok_or(CompressError::Truncated("distance"))? as usize;
            if dist == 0 || dist > out.len() {
                return Err(CompressError::BadDistance(dist));
            }
            let start = out.len() - dist;
            // byte-by-byte: overlapping matches replicate their own tail
            for k in 0..n {
                let b = out[start + k];
                out.push(b);
            }
        }
    }
    if out.len() != raw_len {
        return Err(CompressError::LengthMismatch {
            got: out.len(),
            expected: raw_len,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop;
    use crate::util::rng::Pcg32;

    fn roundtrip(data: &[u8]) {
        let c = compress(data);
        assert_eq!(decompress(&c).unwrap(), data, "len {}", data.len());
    }

    #[test]
    fn trivial_inputs() {
        roundtrip(&[]);
        roundtrip(&[1]);
        roundtrip(&[1, 2, 3]);
        roundtrip(&[0; 4]);
        roundtrip(b"abcd");
    }

    #[test]
    fn constant_runs_collapse() {
        let data = vec![7u8; 100_000];
        let c = compress(&data);
        assert!(c.len() < 64, "constant run compressed to {} bytes", c.len());
        assert_eq!(decompress(&c).unwrap(), data);
    }

    #[test]
    fn runs_longer_than_max_match_split_and_roundtrip() {
        // a multi-MB constant region exceeds MAX_MATCH and must be
        // emitted as several capped match tokens with the same distance
        let data = vec![42u8; 3 * MAX_MATCH + 12_345];
        let c = compress(&data);
        assert!(c.len() < 64, "split-run stream is {} bytes", c.len());
        assert_eq!(decompress(&c).unwrap(), data);
    }

    #[test]
    fn repeated_pattern_compresses() {
        let pat = b"fwumious-wabbit-";
        let mut data = Vec::new();
        for _ in 0..1000 {
            data.extend_from_slice(pat);
        }
        let c = compress(&data);
        assert!(c.len() < data.len() / 10, "{} vs {}", c.len(), data.len());
        roundtrip(&data);
    }

    #[test]
    fn random_data_overhead_bounded() {
        let mut rng = Pcg32::seeded(3);
        let data: Vec<u8> = (0..50_000).map(|_| rng.next_u32() as u8).collect();
        let c = compress(&data);
        // incompressible input: small framing overhead only
        assert!(c.len() < data.len() + data.len() / 16 + 16);
        roundtrip(&data);
    }

    #[test]
    fn corrupt_streams_rejected() {
        assert!(decompress(&[]).is_err());
        let c = compress(b"hello world, hello world, hello world");
        // truncation
        assert!(decompress(&c[..c.len() - 1]).is_err());
        // declared length mismatch
        let mut bad = c.clone();
        bad[0] = bad[0].wrapping_add(1);
        assert!(decompress(&bad).is_err());
    }

    #[test]
    fn prop_roundtrip_structured() {
        prop(60, |g| {
            // mix of random spans and repeated spans, like patch op
            // streams (varint headers + literal weight bytes)
            let mut data = Vec::new();
            for _ in 0..g.usize_in(0..12) {
                if g.bool() {
                    data.extend(g.bytes(0..200));
                } else {
                    let chunk = g.bytes(1..16);
                    for _ in 0..g.usize_in(1..50) {
                        data.extend_from_slice(&chunk);
                    }
                }
            }
            let c = compress(&data);
            assert_eq!(decompress(&c).unwrap(), data);
        });
    }
}
