//! Table 2 — impact of Hogwild-based training (§4.2) and async
//! prefetching (§4.1) on warm-up and online-round times.
//!
//! Paper: warm-up 8d → 23h with 48 threads; online round 20m → 4m with
//! 4 threads.  Our testbed scales the workload down; the *ratio*
//! structure (multi-fold speedup from threads, additional speedup from
//! prefetch when the source is slow) is the reproduced result.

use std::time::Duration;

use fwumious::config::ModelConfig;
use fwumious::data::prefetch::DelayedSource;
use fwumious::data::synthetic::{DatasetSpec, SyntheticStream};
use fwumious::model::regressor::Regressor;
use fwumious::train::hogwild::{train_chunk, HogwildConfig};
use fwumious::train::warmup::{warmup, WarmupConfig};
use fwumious::util::bench_env;
use fwumious::util::json::{arr, num, obj, s};
use fwumious::util::timer::fmt_duration;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let spec = DatasetSpec::criteo_like();
    let buckets = 1u32 << 18;
    let cfg = ModelConfig::deep_ffm(spec.fields(), 4, buckets, &[16]);
    let max_threads = std::thread::available_parallelism()
        .map(|n| n.get().min(8))
        .unwrap_or(4);
    println!(
        "testbed: {} core(s) available — thread-scaling ratios are only\n\
         observable on multi-core hosts; on 1 core this bench validates\n\
         overhead (ratios ≈ 1x) and the prefetch arm's latency hiding.\n",
        max_threads
    );

    // ---- warm-up arm: historical replay with a slow (delayed) source
    println!("== Table 2a: warm-up time (slow historical source, 200k examples) ==");
    println!(
        "{:<34} {:>10} {:>9}",
        "configuration", "wall", "speedup"
    );
    let total = 200_000;
    let delay = Duration::from_millis(6); // per-chunk "download"
    let mk = || DelayedSource::new(
        SyntheticStream::with_buckets(DatasetSpec::criteo_like(), 42, buckets),
        delay,
    );
    let mut baseline = 0.0f64;
    let mut warmup_rows = Vec::new();
    for (label, prefetch, threads) in [
        ("control (sync, 1 thread)", 0usize, 1usize),
        ("prefetch only", 4, 1),
        (&format!("hogwild only ({max_threads} threads)"), 0, max_threads),
        (&format!("prefetch + hogwild ({max_threads} threads)"), 4, max_threads),
    ] {
        let mut reg = Regressor::new(&cfg);
        let rep = warmup(
            &mut reg,
            mk(),
            WarmupConfig { chunk_size: 4096, prefetch_depth: prefetch, threads, total },
        );
        if baseline == 0.0 {
            baseline = rep.wall_seconds;
        }
        println!(
            "{:<34} {:>10} {:>8.2}x",
            label,
            fmt_duration(rep.wall_seconds),
            baseline / rep.wall_seconds
        );
        warmup_rows.push(obj(vec![
            ("configuration", s(label)),
            ("prefetch_depth", num(prefetch as f64)),
            ("threads", num(threads as f64)),
            ("wall_seconds", num(rep.wall_seconds)),
            ("speedup", num(baseline / rep.wall_seconds)),
        ]));
    }

    // ---- online-round arm: fixed in-memory chunk, 1 vs N threads
    println!("\n== Table 2b: online training round (in-memory chunk, 150k examples) ==");
    println!("{:<34} {:>10} {:>9}", "configuration", "wall", "speedup");
    let mut stream = SyntheticStream::with_buckets(DatasetSpec::criteo_like(), 43, buckets);
    let chunk = stream.take_examples(150_000);
    let mut reg = Regressor::new(&cfg);
    // warm the weight tables first so the round is steady-state
    train_chunk(&mut reg, &chunk, HogwildConfig { threads: max_threads }, usize::MAX);
    let mut base = 0.0f64;
    let mut round_rows = Vec::new();
    for threads in [1usize, 2, 4, max_threads] {
        let mut r = reg.clone();
        let stats = train_chunk(&mut r, &chunk, HogwildConfig { threads }, usize::MAX);
        if base == 0.0 {
            base = stats.wall_seconds;
        }
        println!(
            "{:<34} {:>10} {:>8.2}x",
            format!("FW-deepFFM-hogwild ({threads} threads)"),
            fmt_duration(stats.wall_seconds),
            base / stats.wall_seconds
        );
        round_rows.push(obj(vec![
            ("threads", num(threads as f64)),
            ("wall_seconds", num(stats.wall_seconds)),
            ("examples_per_sec", num(stats.examples_per_sec())),
            ("speedup", num(base / stats.wall_seconds)),
        ]));
    }
    let path = bench_env::write_report(
        "table2_hogwild",
        smoke,
        vec![
            ("warmup_examples", num(total as f64)),
            ("round_examples", num(150_000.0)),
            ("max_threads", num(max_threads as f64)),
            ("warmup_arms", arr(warmup_rows)),
            ("round_arms", arr(round_rows)),
        ],
    );
    println!("\nreport -> {path}");
    println!("paper: warm-up 8d→23h (48 thr); online round 20m→4m (4 thr).");
    println!("expected shape: multi-fold thread speedup; prefetch hides source latency.");
}
