//! §5 — context caching over a radix tree.
//!
//! "FW does an additional pass only with the context part, where it
//! identifies and caches frequent parts of the context.  On subsequent
//! candidate passes it reuses this information on-the-fly instead of
//! re-calculating it for each context-candidate pair."
//!
//! The cached value is a [`ContextPartial`]: the context's LR partial
//! sum and the context×context FFM pair interactions — everything in
//! the forward pass that does not involve candidate features.  Keys are
//! the context's (bucket, value) byte string; lookups run over a
//! path-compressed radix tree (the production engine's
//! `src/radix_tree.rs`).
//!
//! Eviction is epoch-based: when the entry count exceeds capacity the
//! tree is cleared wholesale.  With Zipf-repeated contexts the hit rate
//! recovers within a few thousand requests, and clearing is O(1) —
//! matching the production engine's tolerance for approximate caching.
//! A swap of the underlying model weights also clears the cache (stale
//! partials must never be served).

use std::sync::Arc;

use crate::feature::FeatureSlot;
use crate::model::regressor::{ContextPartial, Regressor};

/// Path-compressed radix (prefix) tree over byte keys.
pub struct RadixTree<V> {
    root: Node<V>,
    len: usize,
}

impl<V> std::fmt::Debug for RadixTree<V> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RadixTree").finish_non_exhaustive()
    }
}

struct Node<V> {
    /// Compressed edge label leading INTO this node.
    label: Vec<u8>,
    value: Option<V>,
    children: Vec<Node<V>>,
}

impl<V> Node<V> {
    fn new(label: Vec<u8>) -> Self {
        Node { label, value: None, children: Vec::new() }
    }

    fn child_starting(&self, b: u8) -> Option<usize> {
        self.children.iter().position(|c| c.label.first() == Some(&b))
    }
}

impl<V> Default for RadixTree<V> {
    fn default() -> Self {
        Self::new()
    }
}

impl<V> RadixTree<V> {
    pub fn new() -> Self {
        RadixTree { root: Node::new(Vec::new()), len: 0 }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn clear(&mut self) {
        self.root = Node::new(Vec::new());
        self.len = 0;
    }

    /// Longest common prefix length of two slices.
    fn lcp(a: &[u8], b: &[u8]) -> usize {
        a.iter().zip(b).take_while(|(x, y)| x == y).count()
    }

    pub fn insert(&mut self, key: &[u8], value: V) -> Option<V> {
        let mut node = &mut self.root;
        let mut rest = key;
        loop {
            if rest.is_empty() {
                let old = node.value.replace(value);
                if old.is_none() {
                    self.len += 1;
                }
                return old;
            }
            match node.child_starting(rest[0]) {
                None => {
                    let mut leaf = Node::new(rest.to_vec());
                    leaf.value = Some(value);
                    node.children.push(leaf);
                    self.len += 1;
                    return None;
                }
                Some(ci) => {
                    let lcp = Self::lcp(&node.children[ci].label, rest);
                    let child_label_len = node.children[ci].label.len();
                    if lcp == child_label_len {
                        // descend
                        node = &mut node.children[ci];
                        rest = &rest[lcp..];
                    } else {
                        // split the edge
                        let child = node.children.remove(ci);
                        let mut mid = Node::new(child.label[..lcp].to_vec());
                        let mut tail = child;
                        tail.label = tail.label[lcp..].to_vec();
                        mid.children.push(tail);
                        if rest.len() == lcp {
                            mid.value = Some(value);
                            self.len += 1;
                            node.children.push(mid);
                            return None;
                        }
                        let mut leaf = Node::new(rest[lcp..].to_vec());
                        leaf.value = Some(value);
                        mid.children.push(leaf);
                        node.children.push(mid);
                        self.len += 1;
                        return None;
                    }
                }
            }
        }
    }

    pub fn get(&self, key: &[u8]) -> Option<&V> {
        let mut node = &self.root;
        let mut rest = key;
        loop {
            if rest.is_empty() {
                return node.value.as_ref();
            }
            let ci = node.child_starting(rest[0])?;
            let child = &node.children[ci];
            if rest.len() < child.label.len()
                || rest[..child.label.len()] != child.label[..]
            {
                return None;
            }
            rest = &rest[child.label.len()..];
            node = child;
        }
    }
}

/// Build the byte key identifying a (model, weight version, context)
/// triple: model name + NUL + version, then (bucket, value-bits) per
/// slot.  Shared by the cache itself and the cross-request group
/// planner ([`crate::serve::batcher::context_groups`]), so "same cache
/// key" and "same context group" can never drift apart.  Versioned
/// keys make partials computed against swapped-out weights unreachable
/// immediately (no cross-model or cross-version mixing).
pub fn context_key(buf: &mut Vec<u8>, model: &str, version: u64, ctx: &[FeatureSlot]) {
    buf.clear();
    buf.extend_from_slice(model.as_bytes());
    buf.push(0);
    buf.extend_from_slice(&version.to_le_bytes());
    for s in ctx {
        buf.extend_from_slice(&s.bucket.to_le_bytes());
        buf.extend_from_slice(&s.value.to_bits().to_le_bytes());
    }
}

/// Serving-level context cache.
pub struct ContextCache {
    tree: RadixTree<Arc<ContextPartial>>,
    /// Max entries before an epoch clear; 0 disables caching entirely.
    pub capacity: usize,
    pub hits: u64,
    pub misses: u64,
    key_buf: Vec<u8>,
}

impl std::fmt::Debug for ContextCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ContextCache").finish_non_exhaustive()
    }
}

impl ContextCache {
    pub fn new(capacity: usize) -> Self {
        ContextCache {
            tree: RadixTree::new(),
            capacity,
            hits: 0,
            misses: 0,
            key_buf: Vec::new(),
        }
    }

    /// Fetch (or compute and insert) the partial forward for `ctx`
    /// against `reg` at `model_version`.
    pub fn get_or_compute(
        &mut self,
        reg: &Regressor,
        model_version: u64,
        ctx: &[FeatureSlot],
    ) -> Arc<ContextPartial> {
        self.get_or_compute_named(reg, "", model_version, ctx)
    }

    /// Multi-model variant: `model` disambiguates cache entries.
    pub fn get_or_compute_named(
        &mut self,
        reg: &Regressor,
        model: &str,
        model_version: u64,
        ctx: &[FeatureSlot],
    ) -> Arc<ContextPartial> {
        if self.capacity == 0 {
            self.misses += 1;
            return Arc::new(reg.context_partial(ctx));
        }
        let mut key = std::mem::take(&mut self.key_buf);
        context_key(&mut key, model, model_version, ctx);
        if let Some(v) = self.tree.get(&key) {
            self.hits += 1;
            let out = v.clone();
            self.key_buf = key;
            return out;
        }
        self.misses += 1;
        let cp = Arc::new(reg.context_partial(ctx));
        if self.tree.len() >= self.capacity {
            self.tree.clear(); // epoch eviction
        }
        self.tree.insert(&key, cp.clone());
        self.key_buf = key;
        cp
    }

    /// Drop every cached partial (the swap hook: the serving engine
    /// calls this through its cache epoch when new weights are swapped
    /// in, so stale partials are reclaimed immediately rather than
    /// lingering until the epoch eviction).  Hit/miss counters survive.
    pub fn clear(&mut self) {
        self.tree.clear();
    }

    /// Raw-key variant (§5's production path): the UNHASHED context
    /// bytes are the cache key, so a cache hit skips context feature
    /// hashing, slot assembly AND the partial forward.  `compute` runs
    /// only on miss.
    pub fn get_or_compute_keyed(
        &mut self,
        key: &[u8],
        compute: impl FnOnce() -> ContextPartial,
    ) -> Arc<ContextPartial> {
        if self.capacity == 0 {
            self.misses += 1;
            return Arc::new(compute());
        }
        if let Some(v) = self.tree.get(key) {
            self.hits += 1;
            return v.clone();
        }
        self.misses += 1;
        let cp = Arc::new(compute());
        if self.tree.len() >= self.capacity {
            self.tree.clear();
        }
        self.tree.insert(key, cp.clone());
        cp
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    pub fn entries(&self) -> usize {
        self.tree.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::testutil::prop;

    #[test]
    fn radix_insert_get_basic() {
        let mut t = RadixTree::new();
        assert!(t.is_empty());
        assert_eq!(t.insert(b"romane", 1), None);
        assert_eq!(t.insert(b"romanus", 2), None);
        assert_eq!(t.insert(b"romulus", 3), None);
        assert_eq!(t.insert(b"rubens", 4), None);
        assert_eq!(t.insert(b"ruber", 5), None);
        assert_eq!(t.len(), 5);
        assert_eq!(t.get(b"romane"), Some(&1));
        assert_eq!(t.get(b"romanus"), Some(&2));
        assert_eq!(t.get(b"romulus"), Some(&3));
        assert_eq!(t.get(b"rubens"), Some(&4));
        assert_eq!(t.get(b"ruber"), Some(&5));
        assert_eq!(t.get(b"roman"), None); // interior, no value
        assert_eq!(t.get(b"rom"), None);
        assert_eq!(t.get(b"x"), None);
    }

    #[test]
    fn radix_overwrite_and_prefix_values() {
        let mut t = RadixTree::new();
        t.insert(b"ab", 1);
        t.insert(b"abc", 2);
        t.insert(b"a", 3);
        assert_eq!(t.len(), 3);
        assert_eq!(t.insert(b"ab", 9), Some(1));
        assert_eq!(t.len(), 3);
        assert_eq!(t.get(b"a"), Some(&3));
        assert_eq!(t.get(b"ab"), Some(&9));
        assert_eq!(t.get(b"abc"), Some(&2));
        assert_eq!(t.get(b""), None);
        t.insert(b"", 0);
        assert_eq!(t.get(b""), Some(&0));
    }

    #[test]
    fn radix_prop_matches_hashmap() {
        prop(40, |g| {
            let mut t = RadixTree::new();
            let mut m = std::collections::HashMap::new();
            for _ in 0..g.usize_in(1..200) {
                let key = g.bytes(0..12);
                let v = g.u32();
                t.insert(&key, v);
                m.insert(key, v);
            }
            assert_eq!(t.len(), m.len());
            for (k, v) in &m {
                assert_eq!(t.get(k), Some(v), "key {k:?}");
            }
            // absent keys
            for _ in 0..20 {
                let k = g.bytes(13..20);
                assert_eq!(t.get(&k), m.get(&k));
            }
        });
    }

    fn trained_regressor() -> Regressor {
        use crate::data::synthetic::{DatasetSpec, SyntheticStream};
        use crate::model::Workspace;
        let cfg = ModelConfig::deep_ffm(4, 2, 256, &[8]);
        let mut reg = Regressor::new(&cfg);
        let mut ws = Workspace::new();
        let mut s = SyntheticStream::with_buckets(DatasetSpec::tiny(), 41, 256);
        for _ in 0..2000 {
            let ex = s.next_example();
            reg.learn(&ex, &mut ws);
        }
        reg
    }

    #[test]
    fn cache_hits_on_repeated_context() {
        use crate::data::synthetic::{DatasetSpec, SyntheticStream};
        let reg = trained_regressor();
        let mut cache = ContextCache::new(1024);
        let mut s = SyntheticStream::with_buckets(DatasetSpec::tiny(), 42, 256);
        let ex = s.next_example();
        let ctx = &ex.slots[..2];
        let a = cache.get_or_compute(&reg, 1, ctx);
        let b = cache.get_or_compute(&reg, 1, ctx);
        assert_eq!(cache.hits, 1);
        assert_eq!(cache.misses, 1);
        assert_eq!(*a, *b);
    }

    #[test]
    fn cache_scores_match_uncached() {
        use crate::data::synthetic::{DatasetSpec, SyntheticStream};
        use crate::model::Workspace;
        let reg = trained_regressor();
        let mut cache = ContextCache::new(64);
        let mut s = SyntheticStream::with_buckets(DatasetSpec::tiny(), 43, 256);
        let mut ws = Workspace::new();
        for _ in 0..300 {
            let ex = s.next_example();
            let cp = cache.get_or_compute(&reg, 1, &ex.slots[..2]);
            let cached = reg.predict_with_partial(&cp, &ex.slots[2..], &mut ws);
            let full = reg.predict(&ex, &mut ws);
            assert!((cached - full).abs() < 1e-6);
        }
    }

    #[test]
    fn version_change_invalidates() {
        use crate::data::synthetic::{DatasetSpec, SyntheticStream};
        let reg = trained_regressor();
        let mut cache = ContextCache::new(1024);
        let mut s = SyntheticStream::with_buckets(DatasetSpec::tiny(), 44, 256);
        let ex = s.next_example();
        cache.get_or_compute(&reg, 1, &ex.slots[..2]);
        assert_eq!(cache.entries(), 1);
        // new model version -> versioned key misses (no stale reuse)
        cache.get_or_compute(&reg, 2, &ex.slots[..2]);
        assert_eq!(cache.misses, 2);
        assert_eq!(cache.hits, 0);
        // old-version entry is unreachable but still counted until the
        // epoch clear reclaims it
        assert_eq!(cache.entries(), 2);
    }

    #[test]
    fn clear_drops_entries_keeps_counters() {
        use crate::data::synthetic::{DatasetSpec, SyntheticStream};
        let reg = trained_regressor();
        let mut cache = ContextCache::new(1024);
        let mut s = SyntheticStream::with_buckets(DatasetSpec::tiny(), 47, 256);
        let ex = s.next_example();
        cache.get_or_compute(&reg, 1, &ex.slots[..2]);
        cache.get_or_compute(&reg, 1, &ex.slots[..2]);
        assert_eq!((cache.hits, cache.misses, cache.entries()), (1, 1, 1));
        cache.clear();
        assert_eq!(cache.entries(), 0);
        // same context recomputes after the clear — no stale reuse
        cache.get_or_compute(&reg, 1, &ex.slots[..2]);
        assert_eq!((cache.hits, cache.misses, cache.entries()), (1, 2, 1));
    }

    #[test]
    fn capacity_zero_disables() {
        use crate::data::synthetic::{DatasetSpec, SyntheticStream};
        let reg = trained_regressor();
        let mut cache = ContextCache::new(0);
        let mut s = SyntheticStream::with_buckets(DatasetSpec::tiny(), 45, 256);
        let ex = s.next_example();
        cache.get_or_compute(&reg, 1, &ex.slots[..2]);
        cache.get_or_compute(&reg, 1, &ex.slots[..2]);
        assert_eq!(cache.hits, 0);
        assert_eq!(cache.entries(), 0);
    }

    #[test]
    fn epoch_eviction_bounds_entries() {
        use crate::data::synthetic::{DatasetSpec, SyntheticStream};
        let reg = trained_regressor();
        let mut cache = ContextCache::new(16);
        let mut s = SyntheticStream::with_buckets(DatasetSpec::tiny(), 46, 256);
        for _ in 0..200 {
            let ex = s.next_example();
            cache.get_or_compute(&reg, 1, &ex.slots[..2]);
        }
        assert!(cache.entries() <= 16);
    }
}
