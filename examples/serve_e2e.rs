//! END-TO-END DRIVER — proves all layers compose on a real workload.
//!
//! 1. Validate the AOT path: the PJRT-loaded HLO artifacts (JAX model
//!    with the Pallas FFM kernel, compiled by `make artifacts`) must
//!    reproduce the golden vectors AND the native Rust forward pass.
//! 2. Train a DeepFFM online on a criteo-like synthetic stream (Hogwild
//!    + prefetch warm-up).
//! 3. Deploy it to the serving engine (router → dynamic batcher →
//!    context cache → SIMD forward) and replay a Zipf request trace.
//! 4. Report throughput + latency percentiles + cache hit rate.
//!
//! Results are recorded in EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_e2e
//! ```

use fwumious::config::{ModelConfig, ServeConfig};
use fwumious::data::synthetic::{DatasetSpec, SyntheticStream};
use fwumious::feature::{Example, FeatureSlot};
use fwumious::model::regressor::Regressor;
use fwumious::model::Workspace;
use fwumious::serve::router::Router;
use fwumious::serve::server::ServingEngine;
use fwumious::serve::trace::TraceGenerator;
use fwumious::serve::ModelHandle;
use fwumious::train::warmup::{warmup, WarmupConfig};

fn main() {
    stage1_pjrt_cross_check();
    let model = stage2_train();
    stage3_serve(model);
}

/// Stage 1 — L1 (Pallas) == L2 (JAX) == PJRT == native Rust.
/// Needs the `pjrt` feature (the xla crate); the hermetic default build
/// skips straight to the native stages.
#[cfg(not(feature = "pjrt"))]
fn stage1_pjrt_cross_check() {
    println!("== stage 1: AOT artifact cross-check (PJRT vs golden vs native)");
    println!("   built without the `pjrt` feature — skipping (see rust/Cargo.toml)");
}

#[cfg(feature = "pjrt")]
fn stage1_pjrt_cross_check() {
    use fwumious::runtime::{
        default_artifact_dir, load_goldens, ArgValue, Manifest, PjrtEngine,
    };
    println!("== stage 1: AOT artifact cross-check (PJRT vs golden vs native)");
    let dir = default_artifact_dir();
    if !dir.join("golden.json").exists() {
        println!("   artifacts missing — run `make artifacts` (skipping stage 1)");
        return;
    }
    let manifest = Manifest::load(&dir).expect("manifest");
    let goldens = load_goldens(&dir).expect("goldens");
    let engine = PjrtEngine::cpu().expect("pjrt cpu client");
    for g in &goldens {
        let compiled = engine.compile(&manifest, &g.name).expect("compile");
        let mut argv = vec![
            ArgValue::F32(g.lr_table.clone()),
            ArgValue::F32(g.ffm_table.clone()),
        ];
        for m in &g.mlp {
            argv.push(ArgValue::F32(m.clone()));
        }
        argv.push(ArgValue::I32(g.idx.clone()));
        argv.push(ArgValue::F32(g.vals.clone()));
        let probs = compiled.run(&argv).expect("execute");
        let max_err = probs
            .iter()
            .zip(&g.probs)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        println!("   {}: max |pjrt − golden| = {max_err:.2e}", g.name);
        assert!(max_err < 1e-4);
    }
    println!("   AOT path verified ✓");
}

/// Stage 2 — warm up a production-shaped model.
fn stage2_train() -> Regressor {
    println!("== stage 2: Hogwild + prefetch warm-up on criteo-like stream");
    let spec = DatasetSpec::criteo_like();
    let cfg = ModelConfig::deep_ffm(spec.fields(), 4, 1 << 18, &[16]);
    let mut model = Regressor::new(&cfg);
    let stream = SyntheticStream::with_buckets(spec.clone(), 42, cfg.buckets);
    let threads = std::thread::available_parallelism()
        .map(|n| n.get().min(8))
        .unwrap_or(4);
    let report = warmup(
        &mut model,
        stream,
        WarmupConfig {
            chunk_size: 8192,
            prefetch_depth: 4,
            threads,
            total: 400_000,
        },
    );
    println!(
        "   {} examples, {} threads, {:.2}s ({:.0} ex/s)",
        report.examples,
        threads,
        report.wall_seconds,
        report.examples as f64 / report.wall_seconds
    );
    // held-out sanity
    let mut ws = Workspace::new();
    let mut eval = SyntheticStream::with_buckets(spec, 777, cfg.buckets);
    let test: Vec<Example> = (0..30_000).map(|_| eval.next_example()).collect();
    let (scores, labels): (Vec<f32>, Vec<f32>) = test
        .iter()
        .map(|ex| (model.predict(ex, &mut ws), ex.label))
        .unzip();
    let auc = fwumious::eval::auc(&scores, &labels);
    println!("   held-out AUC {auc:.4}");
    assert!(auc > 0.6, "model failed to learn");
    model
}

/// Stage 3 — deploy and serve a request trace.
fn stage3_serve(model: Regressor) {
    println!("== stage 3: serving (router → batcher → context cache → SIMD)");
    let fields = model.cfg.fields;
    let buckets = model.cfg.buckets;
    let ctx_fields = fields / 2;
    let workers = std::thread::available_parallelism()
        .map(|n| n.get().min(8))
        .unwrap_or(4);
    let router = Router::new(workers);
    router.register("ctr", ModelHandle::new(model));
    let engine = ServingEngine::start(
        router,
        ServeConfig {
            workers,
            max_batch: 256,
            max_wait_us: 200,
            context_cache_entries: 65_536,
            max_group_candidates: 1024,
            ..ServeConfig::default()
        },
    );
    let mut gen = TraceGenerator::new(11, fields, ctx_fields, buckets, 16);
    let requests = 50_000usize;
    let t = std::time::Instant::now();
    let mut pending = Vec::with_capacity(512);
    let mut scored = 0u64;
    for i in 0..requests {
        pending.push(engine.submit(gen.next_request("ctr")).expect("submit"));
        if pending.len() >= 512 || i + 1 == requests {
            for rx in pending.drain(..) {
                scored += rx.recv().unwrap().expect("score").scores.len() as u64;
            }
        }
    }
    let secs = t.elapsed().as_secs_f64();
    let stats = engine.shutdown();
    println!(
        "   {requests} requests / {scored} candidate scores in {secs:.2}s — {:.0} req/s, {:.0} preds/s ({} workers, SIMD {})",
        requests as f64 / secs,
        scored as f64 / secs,
        workers,
        fwumious::simd::isa_name()
    );
    println!(
        "   context-cache hit rate {:.1}% over {} batches",
        stats.cache_hit_rate() * 100.0,
        stats.batches
    );
    if let Some(l) = &stats.latency {
        println!("   request latency: {}", l.summary());
    }
    assert_eq!(stats.errors, 0);
    let per_core = scored as f64 / secs / workers as f64;
    println!(
        "   ≈{:.2}M preds/s/core → the paper's 300M preds/s needs ≈{:.0} cores fleet-wide",
        per_core / 1e6,
        300e6 / per_core
    );
}

// Silence unused import when FeatureSlot is only used via Example internals.
#[allow(unused)]
fn _t(_: FeatureSlot) {}
