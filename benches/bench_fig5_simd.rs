//! Figure 5 — SIMD-enabled vs SIMD-disabled inference (§5).
//!
//! Paper: "SIMD intrinsics resulted in a consistent 20% speedup for all
//! serving. Up to 25% faster inference."  The engine detects AVX2+FMA
//! at startup and can be forced onto the scalar path — exactly the
//! production control/treatment pair.

use fwumious::config::ModelConfig;
use fwumious::data::synthetic::{DatasetSpec, SyntheticStream};
use fwumious::feature::Example;
use fwumious::model::regressor::Regressor;
use fwumious::model::Workspace;
use fwumious::simd;
use fwumious::util::bench_env;
use fwumious::util::json::{arr, num, obj, s};
use fwumious::util::timer::median_time;

fn bench_forward(reg: &Regressor, data: &[Example], scalar: bool) -> f64 {
    // RAII forcing: restored (to unforced) when the arm ends, even on
    // a panicking measurement closure
    let _guard = scalar.then(simd::ForcedIsaGuard::scalar);
    let mut ws = Workspace::new();
    median_time(1, 5, || {
        let mut acc = 0.0f32;
        for ex in data {
            acc += reg.predict(ex, &mut ws);
        }
        acc
    })
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    println!("== Figure 5: SIMD-aware forward pass ==");
    println!("detected ISA: {}", simd::isa_name());
    if !simd::simd_active() {
        println!("(host has no AVX2+FMA — both arms will run scalar)");
    }
    let n = 30_000;
    println!(
        "\n{:<26} {:>12} {:>12} {:>9}",
        "model (K, hidden)", "scalar", "simd", "speedup"
    );
    // Larger K benefits more from vectorized latent dots; the hidden
    // layer matvec vectorizes in all variants.
    let mut rows = Vec::new();
    for (k, hidden) in [(4usize, vec![16usize]), (8, vec![16]), (16, vec![32]), (8, vec![32, 32])] {
        let spec = DatasetSpec::criteo_like();
        let buckets = 1u32 << 18;
        let cfg = ModelConfig::deep_ffm(spec.fields(), k, buckets, &hidden);
        let mut reg = Regressor::new(&cfg);
        let mut ws = Workspace::new();
        let mut s = SyntheticStream::with_buckets(spec, 13, buckets);
        for _ in 0..20_000 {
            let ex = s.next_example();
            reg.learn(&ex, &mut ws);
        }
        let data = s.take_examples(n);
        let scalar = bench_forward(&reg, &data, true);
        let vector = bench_forward(&reg, &data, false);
        println!(
            "{:<26} {:>9.1}ns {:>9.1}ns {:>8.2}x",
            format!("K={k}, hidden {hidden:?}"),
            scalar / n as f64 * 1e9,
            vector / n as f64 * 1e9,
            scalar / vector
        );
        rows.push(obj(vec![
            ("latent_dim", num(k as f64)),
            ("hidden", s(&format!("{hidden:?}"))),
            ("scalar_ns_per_example", num(scalar / n as f64 * 1e9)),
            ("simd_ns_per_example", num(vector / n as f64 * 1e9)),
            ("speedup", num(scalar / vector)),
        ]));
    }
    let path = bench_env::write_report(
        "fig5_simd",
        smoke,
        vec![("examples", num(n as f64)), ("shapes", arr(rows))],
    );
    println!("\nreport -> {path}");
    println!("paper: ~20% serving speedup, up to 25% faster inference.");
    println!("expected: speedup ≥ 1.2x on the production-like shapes (grows with K).");
}
