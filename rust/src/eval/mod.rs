//! Evaluation: streaming AUC in rolling windows (the paper's Figure 3 /
//! Table 1 protocol — "AUC scores computed in a rolling window of 30k
//! instances"), logloss, RIG, and the stability statistics table.

use crate::util::math::{logloss, mean_std, median, rig};

/// Exact AUC of a (score, label) set via rank statistics.
/// Ties share the average rank.  Returns 0.5 for degenerate sets.
///
/// NaN-tolerant like [`median`](crate::util::math::median): a poisoned
/// score (e.g. a Hogwild race briefly driving a weight non-finite mid-
/// bench) ranks at the tail — either NaN sign bit — and skews the
/// number instead of panicking the evaluation thread.
pub fn auc(scores: &[f32], labels: &[f32]) -> f64 {
    assert_eq!(scores.len(), labels.len());
    let n = scores.len();
    let pos = labels.iter().filter(|&&y| y > 0.5).count();
    let neg = n - pos;
    if pos == 0 || neg == 0 {
        return 0.5;
    }
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| crate::util::math::nan_last_f32(&scores[a], &scores[b]));
    // sum of positive ranks with tie averaging
    let mut rank_sum = 0.0f64;
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && scores[idx[j + 1]] == scores[idx[i]] {
            j += 1;
        }
        let avg_rank = (i + j) as f64 / 2.0 + 1.0;
        for &k in &idx[i..=j] {
            if labels[k] > 0.5 {
                rank_sum += avg_rank;
            }
        }
        i = j + 1;
    }
    (rank_sum - (pos as f64 * (pos as f64 + 1.0)) / 2.0)
        / (pos as f64 * neg as f64)
}

/// Rolling-window evaluator: accumulates (score, label) pairs, emits
/// one AUC point per full window (non-overlapping tumbling windows of
/// `window` instances, matching the paper's per-window traces).
pub struct RollingAuc {
    window: usize,
    scores: Vec<f32>,
    labels: Vec<f32>,
    /// AUC per completed window.
    pub points: Vec<f64>,
    /// Sum of logloss over everything seen.
    total_ll: f64,
    total_n: usize,
    total_pos: usize,
}

impl std::fmt::Debug for RollingAuc {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RollingAuc").finish_non_exhaustive()
    }
}

impl RollingAuc {
    pub fn new(window: usize) -> Self {
        RollingAuc {
            window: window.max(2),
            scores: Vec::new(),
            labels: Vec::new(),
            points: Vec::new(),
            total_ll: 0.0,
            total_n: 0,
            total_pos: 0,
        }
    }

    /// Record one prediction (before-the-label, progressive validation).
    pub fn add(&mut self, score: f32, label: f32) {
        self.total_ll += logloss(score, label);
        self.total_n += 1;
        if label > 0.5 {
            self.total_pos += 1;
        }
        self.scores.push(score);
        self.labels.push(label);
        if self.scores.len() >= self.window {
            self.points.push(auc(&self.scores, &self.labels));
            self.scores.clear();
            self.labels.clear();
        }
    }

    /// Flush a final partial window (if it holds both classes).
    pub fn finish(&mut self) {
        if self.scores.len() >= 100 {
            self.points.push(auc(&self.scores, &self.labels));
            self.scores.clear();
            self.labels.clear();
        }
    }

    pub fn seen(&self) -> usize {
        self.total_n
    }

    pub fn mean_logloss(&self) -> f64 {
        if self.total_n == 0 {
            0.0
        } else {
            self.total_ll / self.total_n as f64
        }
    }

    /// Relative information gain vs the observed base rate.
    pub fn rig(&self) -> f64 {
        if self.total_n == 0 {
            return 0.0;
        }
        rig(
            self.total_ll,
            self.total_pos as f64 / self.total_n as f64,
            self.total_n,
        )
    }
}

/// The Table-1 row: stability statistics of a rolling-AUC trace plus a
/// held-out test AUC.
#[derive(Clone, Debug, PartialEq)]
pub struct StabilityStats {
    pub avg: f64,
    pub median: f64,
    pub max: f64,
    pub std: f64,
    pub min: f64,
    pub test: f64,
}

impl StabilityStats {
    pub fn from_trace(points: &[f64], test_auc: f64) -> Self {
        if points.is_empty() {
            return StabilityStats {
                avg: 0.5,
                median: 0.5,
                max: 0.5,
                std: 0.0,
                min: 0.5,
                test: test_auc,
            };
        }
        let (avg, std) = mean_std(points);
        StabilityStats {
            avg,
            median: median(points),
            max: points.iter().cloned().fold(f64::MIN, f64::max),
            std,
            min: points.iter().cloned().fold(f64::MAX, f64::min),
            test: test_auc,
        }
    }

    /// Table row in the paper's column order.
    pub fn row(&self, algo: &str) -> String {
        format!(
            "{:<12} {:>7.4} {:>7.4} {:>7.4} {:>7.4} {:>7.4} {:>7.4}",
            algo, self.avg, self.median, self.max, self.std, self.min, self.test
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn auc_survives_nan_scores() {
        // Regression: partial_cmp(..).unwrap() panicked the evaluating
        // thread on the first NaN score (same class as the
        // median/percentile fix in util::math).  Either NaN sign bit
        // must rank at the tail and merely skew the number.
        for nan in [f32::NAN, -f32::NAN] {
            // NaN ranks last among the negatives: 0.8/0.9 hold ranks
            // 3/4 of 5 -> auc (7 - 3) / (2 * 3) = 2/3 exactly.
            let s = [0.1f32, 0.2, nan, 0.8, 0.9];
            let y = [0.0f32, 0.0, 0.0, 1.0, 1.0];
            let a = auc(&s, &y);
            assert!(a.is_finite());
            assert!((a - 2.0 / 3.0).abs() < 1e-12, "auc={a}");
        }
    }

    #[test]
    fn auc_perfect_and_inverted() {
        let s = [0.1f32, 0.2, 0.8, 0.9];
        let y = [0.0f32, 0.0, 1.0, 1.0];
        assert_eq!(auc(&s, &y), 1.0);
        let y_inv = [1.0f32, 1.0, 0.0, 0.0];
        assert_eq!(auc(&s, &y_inv), 0.0);
    }

    #[test]
    fn auc_random_is_half() {
        let mut rng = Pcg32::seeded(1);
        let n = 20_000;
        let s: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
        let y: Vec<f32> = (0..n)
            .map(|_| if rng.coin(0.3) { 1.0 } else { 0.0 })
            .collect();
        let a = auc(&s, &y);
        assert!((a - 0.5).abs() < 0.02, "auc={a}");
    }

    #[test]
    fn auc_ties_averaged() {
        // all scores equal -> AUC must be exactly 0.5
        let s = [0.7f32; 10];
        let y = [1.0f32, 0.0, 1.0, 0.0, 1.0, 0.0, 1.0, 0.0, 1.0, 0.0];
        assert!((auc(&s, &y) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn auc_degenerate_single_class() {
        assert_eq!(auc(&[0.1, 0.9], &[1.0, 1.0]), 0.5);
        assert_eq!(auc(&[], &[]), 0.5);
    }

    #[test]
    fn auc_invariant_to_monotone_transform() {
        let mut rng = Pcg32::seeded(2);
        let s: Vec<f32> = (0..500).map(|_| rng.next_f32()).collect();
        let y: Vec<f32> = (0..500)
            .map(|i| if (s[i] + 0.3 * rng.normal()) > 0.5 { 1.0 } else { 0.0 })
            .collect();
        let a1 = auc(&s, &y);
        // affine transform: exactly order-preserving in f32
        let s2: Vec<f32> = s.iter().map(|v| v * 0.5 + 0.25).collect();
        let a2 = auc(&s2, &y);
        assert!((a1 - a2).abs() < 1e-12);
        // nonlinear monotone transform: small tolerance for f32 ties
        let s3: Vec<f32> = s.iter().map(|v| v.exp()).collect();
        let a3 = auc(&s3, &y);
        assert!((a1 - a3).abs() < 1e-3);
    }

    #[test]
    fn rolling_windows_emit_points() {
        let mut r = RollingAuc::new(100);
        let mut rng = Pcg32::seeded(3);
        for _ in 0..1050 {
            let y = if rng.coin(0.4) { 1.0 } else { 0.0 };
            let s = 0.3 + 0.4 * y + 0.2 * rng.normal();
            r.add(s.clamp(0.001, 0.999), y);
        }
        assert_eq!(r.points.len(), 10);
        r.finish(); // 50 leftovers < 100 min -> no extra point
        assert_eq!(r.points.len(), 10);
        assert!(r.points.iter().all(|&a| a > 0.6), "{:?}", r.points);
        assert_eq!(r.seen(), 1050);
        assert!(r.mean_logloss() > 0.0);
    }

    #[test]
    fn rig_positive_for_informed_model() {
        let mut r = RollingAuc::new(1000);
        let mut rng = Pcg32::seeded(4);
        for _ in 0..5000 {
            let y = if rng.coin(0.3) { 1.0f32 } else { 0.0 };
            r.add(if y > 0.5 { 0.6 } else { 0.15 }, y);
        }
        assert!(r.rig() > 0.1, "rig={}", r.rig());
    }

    #[test]
    fn stability_stats_from_trace() {
        let trace = [0.7, 0.75, 0.8, 0.65, 0.72];
        let st = StabilityStats::from_trace(&trace, 0.77);
        assert_eq!(st.max, 0.8);
        assert_eq!(st.min, 0.65);
        assert_eq!(st.median, 0.72);
        assert!((st.avg - 0.724).abs() < 1e-9);
        assert_eq!(st.test, 0.77);
        assert!(st.row("FW-DeepFFM").contains("FW-DeepFFM"));
    }

    #[test]
    fn stability_stats_empty_trace() {
        let st = StabilityStats::from_trace(&[], 0.6);
        assert_eq!(st.avg, 0.5);
        assert_eq!(st.test, 0.6);
    }
}
