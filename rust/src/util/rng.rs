//! Deterministic PRNG (PCG-XSH-RR 64/32) plus sampling helpers.
//!
//! Everything in the engine that needs randomness — weight init,
//! synthetic data, AutoML search, property tests — goes through this so
//! runs are reproducible from a single seed.

/// PCG-XSH-RR 64/32: small, fast, statistically solid for our purposes.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Seed-only constructor (stream 54).
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 54)
    }

    /// Export the generator's exact position — `(state, inc)` — so a
    /// checkpoint can persist it and [`from_state`](Self::from_state)
    /// can resume the stream without replaying draws.
    pub fn state(&self) -> (u64, u64) {
        (self.state, self.inc)
    }

    /// Rebuild a generator at an exact exported position (the
    /// checkpoint-restore inverse of [`state`](Self::state)).
    pub fn from_state(state: u64, inc: u64) -> Self {
        Pcg32 { state, inc }
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    /// Uniform in [0, 1) with f64 resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    #[inline]
    pub fn below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64) * (n as u64);
        let mut l = m as u32;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (n as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.next_f32().max(1e-12);
        let u2 = self.next_f32();
        (-2.0 * (u1 as f64).ln()).sqrt() as f32
            * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Bernoulli draw.
    #[inline]
    pub fn coin(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Pick a uniformly random element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len() as u32) as usize]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below((i + 1) as u32) as usize;
            items.swap(i, j);
        }
    }
}

/// Zipf-like sampler over `n` ranks with exponent `s`, using the
/// rejection-inversion method of Hörmann & Derflinger.  Categorical
/// feature values in CTR traffic are heavily skewed; this drives the
/// synthetic generators.
#[derive(Clone, Debug)]
pub struct Zipf {
    n: u64,
    s: f64,
    h_x1: f64,
    h_n: f64,
    dist: f64,
}

impl Zipf {
    pub fn new(n: u64, s: f64) -> Self {
        assert!(n >= 1);
        let h = |x: f64, s: f64| -> f64 {
            if (s - 1.0).abs() < 1e-9 {
                x.ln()
            } else {
                (x.powf(1.0 - s) - 1.0) / (1.0 - s)
            }
        };
        let h_x1 = h(1.5, s) - 1.0f64.powf(-s);
        let h_n = h(n as f64 + 0.5, s);
        Zipf { n, s, h_x1, h_n, dist: h_n - h_x1 }
    }

    fn h_inv(&self, x: f64) -> f64 {
        if (self.s - 1.0).abs() < 1e-9 {
            x.exp()
        } else {
            (1.0 + x * (1.0 - self.s)).powf(1.0 / (1.0 - self.s))
        }
    }

    /// Draw a rank in [1, n], rank 1 most likely.
    pub fn sample(&self, rng: &mut Pcg32) -> u64 {
        loop {
            let u = self.h_x1 + rng.next_f64() * self.dist;
            let x = self.h_inv(u);
            let k = (x + 0.5).floor().clamp(1.0, self.n as f64);
            // Accept k with probability proportional to its true mass.
            let ratio = (k).powf(-self.s);
            let approx = self.h_inv(self.h_x1 + rng.next_f64() * self.dist);
            if (k - x).abs() <= 0.5 || ratio >= approx.powf(-self.s) * 0.5 {
                return k as u64;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Pcg32::seeded(9);
        let mut b = Pcg32::seeded(9);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn state_roundtrip_resumes_the_stream() {
        let mut a = Pcg32::seeded(11);
        for _ in 0..37 {
            a.next_u32();
        }
        let (state, inc) = a.state();
        let mut b = Pcg32::from_state(state, inc);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Pcg32::seeded(3);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Pcg32::seeded(4);
        let mut counts = [0u32; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_mean_and_var() {
        let mut r = Pcg32::seeded(5);
        let n = 50_000;
        let (mut s, mut s2) = (0f64, 0f64);
        for _ in 0..n {
            let x = r.normal() as f64;
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn zipf_rank1_most_frequent() {
        let z = Zipf::new(1000, 1.1);
        let mut r = Pcg32::seeded(6);
        let mut c1 = 0;
        let mut c100 = 0;
        for _ in 0..20_000 {
            match z.sample(&mut r) {
                1 => c1 += 1,
                100 => c100 += 1,
                _ => {}
            }
        }
        assert!(c1 > c100 * 5, "c1={c1} c100={c100}");
    }

    #[test]
    fn zipf_in_range() {
        let z = Zipf::new(50, 1.3);
        let mut r = Pcg32::seeded(7);
        for _ in 0..10_000 {
            let k = z.sample(&mut r);
            assert!((1..=50).contains(&k));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(8);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
